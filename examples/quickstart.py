"""Quickstart: the paper's placement engine in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Builds a query workload, fits all six placement algorithms, replays the
trace, and prints the span/energy comparison (the paper's core result), then
shows replica selection answering a live query.
"""

import numpy as np

from repro.core import (
    ALGORITHMS, PlacementService, Simulator, random_workload,
)


def main():
    # 1. a workload: 500 items, 1500 queries over a structured item graph
    wl = random_workload(num_items=500, num_queries=1500, density=6, seed=7)
    hg = wl.hypergraph
    print(f"workload: {hg}")

    # 2. simulate every algorithm on 24 partitions of capacity 30
    sim = Simulator(num_partitions=24, capacity=30)
    print(f"{'algorithm':10s} {'avg span':>9s} {'energy kJ':>10s} "
          f"{'repl.':>6s} {'fit s':>6s}")
    for name, fn in ALGORITHMS.items():
        r = sim.run(hg, fn, name=name, seed=0)
        print(f"{name:10s} {r.avg_span:9.3f} {r.energy_joules/1e3:10.1f} "
              f"{r.replication_factor:6.2f} {r.placement_seconds:6.2f}")

    # 3. production API: fit once, answer placement queries forever
    svc = PlacementService("lmbr", seed=0)
    plan = svc.fit(wl.queries, 500, num_partitions=24, capacity=30)
    q = wl.queries[0]
    parts, reads = plan.select(q)
    print(f"\nquery {list(map(int, q))[:8]}... spans {len(parts)} partitions")
    for p, items in zip(parts, reads):
        print(f"  partition {p:2d} serves items {list(map(int, items))}")

    # 4. two-level (pod/host) placement for a TPU fleet
    hp = svc.fit_hierarchical(wl.queries, 500, num_pods=2, hosts_per_pod=12,
                              host_capacity=30)
    pod_spans = [hp.spans(q)[0] for q in wl.queries[:200]]
    print(f"\nhierarchical: {100*np.mean(np.array(pod_spans)==1):.0f}% of "
          f"queries stay inside one pod")


if __name__ == "__main__":
    main()
