"""Serving example: batched prefill + decode of a reduced MoE model, with the
online expert-placement refit loop.

    PYTHONPATH=src python examples/serve_tiny.py
"""

import sys

from repro.launch.serve import main as serve_main


def main():
    sys.argv = [
        "serve", "--arch", "qwen3-moe-30b-a3b", "--reduced",
        "--requests", "8", "--prefill-len", "32", "--decode-len", "16",
        "--batch", "4",
    ]
    return serve_main()


if __name__ == "__main__":
    raise SystemExit(main())
