"""End-to-end training driver example: train a reduced GLM4-family model for
a few hundred steps through the FULL substrate (placement-aware pipeline,
fault-tolerant runner, checkpointing, straggler avoidance) and verify the
loss drops.

    PYTHONPATH=src python examples/train_tiny_lm.py [--steps 300]

This is the same code path as `python -m repro.launch.train --arch glm4-9b
--reduced`; kept as an example so the public API usage is visible.
"""

import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", type=str, default="glm4-9b")
    args = ap.parse_args()
    sys.argv = [
        "train", "--arch", args.arch, "--reduced",
        "--steps", str(args.steps), "--batch", "8", "--seq", "128",
        "--ckpt-every", "100", "--inject-failures",
        "--ckpt-dir", "/tmp/repro_example_ckpt",
    ]
    return train_main()


if __name__ == "__main__":
    raise SystemExit(main())
