"""The paper's technique applied to MoE serving: mine a routing trace, fit
expert placement with LMBR, and run the actual MoE model with the placed
dispatch tables, comparing all-to-all fan-out (span) against standard
contiguous expert parallelism.

    PYTHONPATH=src python examples/moe_expert_placement.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core import (
    baseline_contiguous_placement, plan_expert_placement,
    synthetic_routing_trace,
)
from repro.models import dispatch_from_plan, forward, init_params


def main():
    cfg = reduce_config(get_config("qwen3-moe-30b-a3b"), dtype="float32")
    e, ranks, slots = cfg.moe.num_experts, 4, cfg.moe.num_experts // 4 + 2

    # 1. routing trace (in production: mined from the serving fleet)
    trace = synthetic_routing_trace(e, 500, top_k=cfg.moe.top_k, seed=0)

    # 2. the paper's placement vs standard contiguous EP
    base = baseline_contiguous_placement(e, ranks, slots_per_rank=slots)
    plan = plan_expert_placement(trace, e, ranks, slots, algorithm="lmbr")
    print(f"experts={e} ranks={ranks} slots/rank={slots} "
          f"(replication budget: {ranks*slots - e} slots)")
    print(f"avg a2a fan-out (span): contiguous={base.avg_span(trace):.2f} "
          f"-> placed={plan.avg_span(trace):.2f}")
    a0 = base.a2a_bytes(trace, 2048, 2 * cfg.d_model)
    a1 = plan.a2a_bytes(trace, 2048, 2 * cfg.d_model)
    print(f"estimated a2a payload: {a0/1e9:.2f}GB -> {a1/1e9:.2f}GB "
          f"({100*(1-a1/a0):.0f}% less)")
    counts = plan.replica_counts()
    print(f"replicated experts: {(counts > 1).sum()} "
          f"(max copies {counts.max()})")

    # 3. run the real model with the placed dispatch — same function value
    disp = dispatch_from_plan(plan)
    params = init_params(cfg, jax.random.PRNGKey(0), moe_dispatch=disp)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                                cfg.vocab_size)
    logits, _, aux, _ = forward(cfg, params, tokens, moe_dispatch=disp,
                                chunk=32)
    assert bool(jnp.isfinite(logits).all())
    print(f"model forward with placed experts OK; "
          f"token drop fraction {float(aux.get('drop_frac', 0) or 0):.3f}")


if __name__ == "__main__":
    main()
