"""Roofline table: renders experiments/dryrun/*.json (written by
repro.launch.dryrun) into the per-cell table EXPERIMENTS.md §Roofline uses.

Run the dry-runs first:
    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
"""

from __future__ import annotations

import glob
import json
import os

from .common import emit_csv

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_records(mesh: str | None = None, variant: str = "baseline"):
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if mesh and r.get("mesh") != mesh:
            continue
        if r.get("variant", "baseline") != variant:
            continue
        recs.append(r)
    return recs


def run(quick: bool = True) -> list[dict]:
    recs = load_records()
    if not recs:
        print("# no dry-run records found — run repro.launch.dryrun first")
        return []
    from repro.configs import SHAPE_GRID, get_config
    from repro.launch.roofline import corrected_terms

    rows = []
    for r in recs:
        base = dict(arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                    status=r["status"])
        if r["status"] != "ok":
            rows.append(dict(base, note=r.get("reason", r.get("error", ""))[:60]))
            continue
        # primary columns follow the prescribed methodology (cost_analysis +
        # static HLO collective parse; XLA:CPU counts while bodies once —
        # caveat verified & documented in EXPERIMENTS.md §Roofline).
        # analytic_* supplements give the closed-form MFU view.
        from repro.launch.roofline import analytic_bytes, analytic_flops
        cfg = get_config(r["arch"])
        shape = SHAPE_GRID[r["shape"]]
        t = r["roofline"]
        chips = t["chips"]
        a_flops = analytic_flops(cfg, shape) / chips
        a_bytes = analytic_bytes(cfg, shape, chips,
                                 r.get("optimizer", "adamw"))
        a_compute = a_flops / 197e12
        a_bound = max(a_compute, a_bytes / 819e9, t["collective_s"])
        rows.append(dict(
            base,
            compute_s=f"{t['compute_s']:.4f}",
            memory_s=f"{t['memory_s']:.4f}",
            collective_s=f"{t['collective_s']:.4f}",
            dominant=t["dominant"],
            bound_s=f"{t['step_lower_bound_s']:.4f}",
            roofline_frac=f"{t['roofline_fraction']:.3f}",
            analytic_compute_s=f"{a_compute:.4f}",
            analytic_memory_s=f"{a_bytes / 819e9:.4f}",
            analytic_frac=f"{a_compute / a_bound if a_bound else 0:.3f}",
            temp_gb=round(r["memory"].get("temp_size_in_bytes", 0) / 1e9, 1),
            note="",
        ))
    emit_csv("roofline_table", rows)
    ok = [r for r in rows if r["status"] == "ok"]
    if ok:
        worst = min(ok, key=lambda r: float(r["roofline_frac"]))
        coll = max(ok, key=lambda r: float(r["collective_s"]))
        print(f"# worst roofline fraction: {worst['arch']}/{worst['shape']}"
              f"@{worst['mesh']} = {worst['roofline_frac']}")
        print(f"# most collective-bound: {coll['arch']}/{coll['shape']}"
              f"@{coll['mesh']} = {coll['collective_s']}s")
    return rows


if __name__ == "__main__":
    run()
