"""Paper fig. 6(f)-(h): 3-way replication (RF=3, N = 3*N_e).

Compares HPA (no replication), Random-3W, SDA and PRA-3W while sweeping the
number of queries, the query size (ADI) and the item-graph density.
(LMBR is excluded here, as in the paper: it cannot honor an exact-RF
constraint and its runtime is high.)
"""

from __future__ import annotations

import numpy as np

from repro.core import (
    ALGORITHMS, THREE_WAY_ALGORITHMS, Simulator, min_partitions,
    random_workload,
)

from .common import Timer, emit_csv

ALGOS = ["hpa", "random3", "sda", "pra3"]


def _run_cell(make_wl, runs):
    rows = []
    for name in ALGOS:
        spans = []
        for r in range(runs):
            wl = make_wl(seed=r)
            hg = wl.hypergraph
            ne = min_partitions(hg, 50)
            n = 3 * ne
            sim = Simulator(num_partitions=n, capacity=50)
            fn = ALGORITHMS[name] if name == "hpa" else THREE_WAY_ALGORITHMS[name]
            res = sim.run(hg, fn, name=name, seed=r)
            spans.append(res.avg_span)
        rows.append(dict(algorithm=name, avg_span=round(float(np.mean(spans)), 4)))
    return rows


def run(quick: bool = True) -> list[dict]:
    runs = 1 if quick else 3
    out = []

    nqs = [1000, 4000, 8000, 11000] if quick else [1000, 3000, 5000, 7000, 9000, 11000]
    for nq in nqs:
        for row in _run_cell(
            lambda seed, nq=nq: random_workload(1000, nq, 3, 11, 20, seed=seed),
            runs,
        ):
            out.append(dict(sweep="num_queries", x=nq, **row))

    qsizes = [2, 4, 6, 8, 10] if quick else [2, 3, 4, 5, 6, 7, 8, 9, 10]
    for q in qsizes:
        for row in _run_cell(
            lambda seed, q=q: random_workload(1000, 4000, q, q, 20, seed=seed),
            runs,
        ):
            out.append(dict(sweep="query_size", x=q, **row))

    densities = [2, 5, 10, 20] if quick else [2, 4, 6, 8, 10, 14, 20]
    for d in densities:
        for row in _run_cell(
            lambda seed, d=d: random_workload(1000, 4000, 3, 11, d, seed=seed),
            runs,
        ):
            out.append(dict(sweep="density", x=d, **row))

    emit_csv("fig6_3way", out, ["sweep", "x", "algorithm", "avg_span"])
    return out


if __name__ == "__main__":
    run(quick=True)
