"""Span-engine benchmark: per-edge reference greedy cover vs the batched
bitset engine (numpy backend, and the jitted JAX gain kernel when available)
at ISPD98 ibm01/ibm04 scale.

Emits benchmarks/results/BENCH_spans.json so the perf trajectory of the hot
path is tracked across PRs; also printed as CSV for eyeballing.
"""

from __future__ import annotations

import time

import numpy as np

from repro import flags
from repro.core import ALGORITHMS, ispd_like_workload
from repro.core.setcover import batched_spans_csr, greedy_set_cover

from .common import emit_csv, save_json

# (circuit, num_nodes, workload seed): ibm01 / ibm04 of the ISPD98 suite
SCALES = [("ibm01-like", 12752, 0), ("ibm04-like", 27507, 3)]


def _reference_spans(hg, member) -> np.ndarray:
    """The pre-engine path: one Python greedy loop per hyperedge."""
    out = np.zeros(hg.num_edges, dtype=np.int64)
    for e in range(hg.num_edges):
        out[e] = len(greedy_set_cover(hg.edge(e), member))
    return out


def run(quick: bool = True) -> list[dict]:
    rows = []
    scales = SCALES[:1] if quick else SCALES
    for circuit, n_nodes, seed in scales:
        wl = ispd_like_workload(num_nodes=n_nodes, seed=seed)
        hg = wl.hypergraph
        capacity = int(np.ceil(n_nodes / 20))
        pl = ALGORITHMS["ihpa"](hg, 35, capacity, seed=0)
        member = pl.member

        t0 = time.perf_counter()
        ref = _reference_spans(hg, member)
        t_ref = time.perf_counter() - t0

        engines = [("batched-numpy", "numpy")]
        try:
            import jax  # noqa: F401
            engines.append(("batched-jax", "jax"))
            # per-bucket dispatch (numpy below span_dispatch_threshold,
            # accelerated above) — the default engine since PR 2
            engines.append(("batched-auto", "auto"))
        except ImportError:
            pass
        rows.append(dict(
            circuit=circuit, edges=hg.num_edges, engine="reference-loop",
            seconds=round(t_ref, 4), speedup=1.0,
            avg_span=round(float(ref.mean()), 4),
        ))
        for label, backend in engines:
            flags.FLAGS["span_backend"] = backend
            try:
                # warm (jit compile for the jax backend), then measure
                batched_spans_csr(hg.edge_ptr, hg.edge_nodes, member)
                t0 = time.perf_counter()
                spans = batched_spans_csr(hg.edge_ptr, hg.edge_nodes, member)
                dt = time.perf_counter() - t0
            finally:
                flags.reset()
            assert (spans == ref).all(), f"{label} diverged from reference"
            rows.append(dict(
                circuit=circuit, edges=hg.num_edges, engine=label,
                seconds=round(dt, 4),
                speedup=round(t_ref / max(dt, 1e-9), 1),
                avg_span=round(float(spans.mean()), 4),
            ))
            print(f"  {rows[-1]}", flush=True)
    emit_csv("bench_spans", rows,
             ["circuit", "edges", "engine", "seconds", "speedup", "avg_span"])
    save_json("BENCH_spans", rows)
    return rows


if __name__ == "__main__":
    run(quick=True)
