"""Beyond-paper applications of the placement engine inside the framework:

  (A) MoE expert placement — all-to-all fan-out (span) + payload reduction
      for qwen3-moe-like (128 experts) and deepseek-v3-like (256 experts)
      routing traces across EP ranks.
  (B) Input-pipeline shard placement — batch-assembly host span under
      mixture sampling, with failure/straggler re-covering.
  (C) Checkpoint-shard restore span — a restoring host contacts few storage
      nodes.
"""

from __future__ import annotations

import numpy as np

from repro.core import (
    baseline_contiguous_placement, mixture_batch_recipes,
    plan_expert_placement, plan_shard_placement, synthetic_routing_trace,
)

from .common import Timer, emit_csv


def expert_rows(quick: bool) -> list[dict]:
    cases = [
        # (tag, experts, ranks, slots, top_k)  slots*ranks-experts = replicas
        ("qwen3-moe-30b(128e,16ranks)", 128, 16, 10, 8),
        ("deepseek-v3(256e,32ranks)", 256, 32, 10, 8),
    ]
    rows = []
    for tag, ne, nr, slots, k in cases:
        trace = synthetic_routing_trace(ne, 400 if quick else 2000, top_k=k, seed=0)
        base = baseline_contiguous_placement(ne, nr, slots_per_rank=slots)
        for algo in (["lmbr", "pra3"] if quick else ["lmbr", "ihpa", "ds", "pra3"]):
            with Timer() as t:
                plan = plan_expert_placement(trace, ne, nr, slots, algorithm=algo,
                                             seed=0)
            b_span, p_span = base.avg_span(trace), plan.avg_span(trace)
            b_a2a = base.a2a_bytes(trace, 4096, 4096)
            p_a2a = plan.a2a_bytes(trace, 4096, 4096)
            rows.append(dict(
                case=tag, algorithm=algo,
                span_contiguous=round(b_span, 3), span_placed=round(p_span, 3),
                a2a_reduction_pct=round(100 * (1 - p_a2a / b_a2a), 1),
                fit_seconds=round(t.seconds, 2),
            ))
    return rows


def shard_rows(quick: bool) -> list[dict]:
    recipes = mixture_batch_recipes(512, 300 if quick else 1500,
                                    shards_per_batch=12, seed=0)
    rows = []
    for algo in ["random3", "sda", "pra3", "ihpa3"]:
        with Timer() as t:
            plan = plan_shard_placement(recipes, 512, 64, capacity=30,
                                        algorithm=algo, seed=0)
        # failure resilience: re-cover every batch with 2 dead hosts
        dead = {0, 1}
        spans_fail = []
        for r in recipes[:100]:
            hosts, _ = plan.cover_excluding(r, dead)
            spans_fail.append(len(hosts))
        rows.append(dict(
            algorithm=algo, avg_span=round(plan.avg_span(recipes), 3),
            avg_span_2dead=round(float(np.mean(spans_fail)), 3),
            survives_2=plan.survives_failures(2),
            fit_seconds=round(t.seconds, 2),
        ))
    return rows


def ckpt_rows(quick: bool) -> list[dict]:
    # restore-sets: host h reads its parameter shards (contiguous slices of
    # the ckpt) + optimizer shards; model-parallel groups share shards
    rng = np.random.default_rng(0)
    num_shards, num_hosts = 256, 32
    restores = []
    for h in range(num_hosts):
        base = (h * num_shards // num_hosts + np.arange(8)) % num_shards
        shared = rng.choice(num_shards, 4, replace=False)  # embedding/norm shards
        restores.append(np.unique(np.concatenate([base, shared])))
    rows = []
    for algo in ["random3", "pra3"]:
        plan = plan_shard_placement(restores, num_shards, 16, capacity=64,
                                    algorithm=algo, seed=0)
        rows.append(dict(
            algorithm=algo,
            avg_restore_span=round(plan.avg_span(restores), 3),
            survives_2=plan.survives_failures(2),
        ))
    return rows


def run(quick: bool = True) -> list[dict]:
    e = expert_rows(quick)
    emit_csv("app_expert_placement", e)
    s = shard_rows(quick)
    emit_csv("app_shard_placement", s)
    c = ckpt_rows(quick)
    emit_csv("app_ckpt_restore", c)
    return e + s + c


if __name__ == "__main__":
    run(quick=True)
