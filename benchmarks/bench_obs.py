"""Observability overhead benchmark: the instrumentation must be free.

Three sections, one BENCH_obs.json:

  * identity — the same lmbr-stress serving trace routed under every
    ``obs_level`` ("off", "counters", "trace") must produce BIT-IDENTICAL
    covers (chosen partitions, spans, load ledger).  Observability hooks
    only read state; any divergence is a hard failure.
  * overhead — paired per-slice timing of `ReplicaRouter.route_csr` on the
    lmbr-stress trace, "off" vs each level interleaved (min across rounds
    on every side of a pair, median slice ratio).  Gates:
      - ``counters / off`` median ratio <= ``COUNTERS_GATE`` (1.03 — the
        3% budget from the issue),
      - ``off-hooks``: the disabled hook sequence (one accessor call plus
        ``.active`` checks per microbatch) is timed DIRECTLY in a tight
        loop and bounded against the median microbatch duration at
        <= ``OFF_GATE`` (1.005, the 0.5% budget) — wall-clock pairing on a
        shared CI container cannot resolve 0.5%, the hook loop can; an
        ``off-rerun`` wall-clock row is still reported (ungated) as the
        honest noise floor,
      - ``trace / off`` is reported but ungated (trace mode buys a full
        Chrome timeline; it is allowed to cost).
  * roundtrip — after the counters pass, ``parse_prom_text(to_prom_text())``
    must equal ``snapshot()`` exactly; after the trace pass, the Chrome
    trace JSON must parse and contain the serve.microbatch spans.
  * health — the PR 10 monitoring gates, on a ``run_online`` replay with a
    deterministic three-partition kill and a later repair:
      - ``storm``: the degraded-rate AND load-skew alerts must FIRE within
        one health window (``health_window`` snapshots) of the kill and
        RESOLVE after the repair,
      - ``clean``: the identical monitored replay without faults must fire
        ZERO alerts,
      - the storm replay's serving results (spans, access load) must stay
        bit-identical to the same replay with observability off —
        monitoring observes, it never steers.
    The counters-mode hot-path overhead of the monitoring release stays
    under the same ``COUNTERS_GATE`` as before (health work happens at
    snapshot cadence, not per microbatch).

Emits benchmarks/results/BENCH_obs.json; see benchmarks/README.md for the
row schema.
"""

from __future__ import annotations

import gc
import json
import time

import numpy as np

from repro import flags, obs
from repro.core import ALGORITHMS, LMBR_STRESS_DEFAULTS, lmbr_stress_workload
from repro.online import ReplicaRouter

from .common import emit_csv, save_json

KEYS = [
    "section", "level", "seconds", "qps", "ratio", "gate",
    "identical", "avg_span", "events", "series",
]

# counters-mode serving overhead ceiling (the issue's 3% budget).  The
# registry work per microbatch is two dict lookups, three counter
# increments and one histogram bisect — measured ~0.5-1% on the 1-core CI
# container; 1.03 keeps a regression loud without flaking.
COUNTERS_GATE = 1.03
# "off" budget (0.5%): gated analytically — per-microbatch hook cost from
# a tight loop over the exact disabled-path sequence, divided by the
# median measured microbatch duration.  The wall-clock off-rerun row is
# reported ungated because this container's slice noise floor (~2%) sits
# above the budget.
OFF_GATE = 1.005


def _time_slice(router, ptr, nodes, reps: int = 5) -> float:
    """min-of-``reps`` seconds for one ``route_csr`` slice."""
    ts = np.inf
    for _ in range(reps):
        t0 = time.perf_counter()
        router.route_csr(ptr, nodes)
        ts = min(ts, time.perf_counter() - t0)
    return ts


def _full_route(member: np.ndarray, hg):
    """One whole-trace route on a fresh router (for identity checks)."""
    router = ReplicaRouter(member)
    batch = router.route_csr(hg.edge_ptr, hg.edge_nodes)
    return batch, router.load.copy()


def run(quick: bool = True) -> list[dict]:
    from repro.core.setcover import _accel_backend

    _accel_backend()  # pay the one-time jax import outside the timings
    flags.reset()
    obs.reset()

    wl = lmbr_stress_workload()
    hg = wl.hypergraph
    n = LMBR_STRESS_DEFAULTS["num_partitions"]
    cap = LMBR_STRESS_DEFAULTS["capacity"]
    # serving overhead is layout-independent; a random layout keeps the
    # tier's fit cost out of the benchmark (same choice as bench_online)
    pl = ALGORITHMS["random"](hg, n, cap, seed=0)
    nq = hg.num_edges

    slice_q = 1000
    slices = []
    for lo in range(0, nq, slice_q):
        hi = min(lo + slice_q, nq)
        ptr = hg.edge_ptr[lo: hi + 1] - hg.edge_ptr[lo]
        nodes = hg.edge_nodes[hg.edge_ptr[lo]: hg.edge_ptr[hi]]
        slices.append((ptr, nodes))

    rows: list[dict] = []

    # -------------------------------------------------------- identity
    flags.FLAGS["obs_level"] = "off"
    base_batch, base_load = _full_route(pl.member, hg)
    for lvl in ("counters", "trace"):
        flags.FLAGS["obs_level"] = lvl
        obs.reset()
        batch, load = _full_route(pl.member, hg)
        same = (np.array_equal(batch.spans, base_batch.spans)
                and np.array_equal(batch.cover_parts, base_batch.cover_parts)
                and np.array_equal(batch.pin_parts, base_batch.pin_parts)
                and np.array_equal(load, base_load))
        if not same:
            raise AssertionError(f"obs_level={lvl!r} changed routing results")
        rows.append(dict(section="identity", level=lvl, identical=True,
                         avg_span=round(float(batch.spans.mean()), 4)))

    # -------------------------------------------------------- overhead
    # paired per-slice timing: every slice times ALL levels back to back
    # (min-of-5 each), so drift in machine speed between passes cancels
    # out of the ratios; the reported overhead is the median slice ratio
    # (same robustness choice as bench_online's router section)
    levels = ("off", "counters", "off-rerun", "trace")
    rounds = 4
    obs.reset()
    routers = {lvl: ReplicaRouter(pl.member) for lvl in levels}
    flags.FLAGS["obs_level"] = "off"
    for ptr, nodes in slices:  # warm-up: caches, allocator
        routers["off"].route_csr(ptr, nodes)
    per_slice: dict[str, list[float]] = {
        lvl: [np.inf] * len(slices) for lvl in levels}
    for _ in range(rounds):  # min across rounds rides out transient noise
        for i, (ptr, nodes) in enumerate(slices):
            gc.collect()
            for lvl in levels:
                flags.FLAGS["obs_level"] = lvl.replace("-rerun", "")
                t = _time_slice(routers[lvl], ptr, nodes, reps=2)
                per_slice[lvl][i] = min(per_slice[lvl][i], t)
    trace_events = len(obs.tracer().events)

    base_slices = per_slice["off"]
    base_total = float(sum(base_slices))
    gates = {"counters": COUNTERS_GATE, "off-rerun": None, "trace": None}
    rows.append(dict(section="overhead", level="off",
                     seconds=round(base_total, 3),
                     qps=round(nq / max(base_total, 1e-9)), ratio=1.0))

    # "off" gate: time the disabled hook sequence itself (what
    # _route_microbatch pays when obs_level == "off" — one registry()
    # accessor plus two .active checks) and bound it against the median
    # microbatch duration
    flags.FLAGS["obs_level"] = "off"
    it = 200_000
    t_hook = np.inf
    for _ in range(3):
        gc.collect()
        t0 = time.perf_counter()
        for _ in range(it):
            reg = obs.registry()
            if reg.active:
                pass
            if reg.active:
                pass
        t_hook = min(t_hook, (time.perf_counter() - t0) / it)
    mb = int(flags.FLAGS["router_microbatch"])
    mb_per_slice = -(-slice_q // mb)
    med_slice = float(np.median(base_slices))
    off_ratio = 1.0 + t_hook * mb_per_slice / max(med_slice, 1e-9)
    if off_ratio > OFF_GATE:
        raise AssertionError(
            f"disabled hooks cost {off_ratio - 1.0:.5f} of a microbatch "
            f"> {OFF_GATE - 1.0} gate ({t_hook * 1e9:.0f} ns/hook)"
        )
    rows.append(dict(section="overhead", level="off-hooks",
                     seconds=round(t_hook * 1e9),  # ns per hook sequence
                     ratio=round(off_ratio, 6), gate=OFF_GATE))
    for lvl in ("counters", "off-rerun", "trace"):
        total = float(sum(per_slice[lvl]))
        ratios = [t / max(b, 1e-9)
                  for t, b in zip(per_slice[lvl], base_slices)]
        med = float(np.median(ratios))
        gate = gates[lvl]
        if gate is not None and med > gate:
            raise AssertionError(
                f"obs_level={lvl!r} median slice overhead {med:.4f}x "
                f"> {gate}x gate (slices: {[round(r, 3) for r in ratios]})"
            )
        rows.append(dict(section="overhead", level=lvl,
                         seconds=round(total, 3),
                         qps=round(nq / max(total, 1e-9)),
                         ratio=round(med, 4), gate=gate,
                         events=trace_events if lvl == "trace" else None))

    # -------------------------------------------------------- roundtrip
    flags.FLAGS["obs_level"] = "counters"
    obs.reset()
    _full_route(pl.member, hg)
    reg = obs.registry()
    snap = reg.snapshot()
    parsed = obs.parse_prom_text(reg.to_prom_text())
    if parsed != snap:
        missing = set(snap) ^ set(parsed)
        raise AssertionError(f"prometheus round-trip diverged: {missing}")
    rows.append(dict(section="roundtrip", level="counters",
                     series=len(snap), identical=True))

    flags.FLAGS["obs_level"] = "trace"
    obs.reset()
    _full_route(pl.member, hg)
    doc = json.loads(obs.tracer().to_chrome_trace())
    micro = [e for e in doc["traceEvents"]
             if e.get("name") == "serve.microbatch"]
    if not micro:
        raise AssertionError("trace mode produced no serve.microbatch spans")
    rows.append(dict(section="roundtrip", level="trace",
                     events=len(doc["traceEvents"]), identical=True))

    # ----------------------------------------------------------- health
    from repro.core import Simulator, random_workload
    from repro.obs import HealthMonitor

    hwl = random_workload(num_items=120, num_queries=4000, density=6, seed=2)
    kill_at, heal_at = 1000, 2500
    storm = [(kill_at, "down", 3), (kill_at, "down", 5),
             (kill_at, "down", 7), (heal_at, "repair", 1),
             (heal_at + 1, "up", 3), (heal_at + 1, "up", 5),
             (heal_at + 1, "up", 7)]
    snap_every, hw = 100, 4
    variant = (f"routermb64+obscounters+obssnap{snap_every}+obshealth1"
               f"+healthw{hw}+healthskew3.0")

    def _health_run(events, monitored: bool):
        flags.set_variant(variant if monitored else "routermb64")
        obs.reset()
        mon = HealthMonitor.from_flags() if monitored else None
        res = Simulator(10, 30).run_online(
            hwl.hypergraph, ALGORITHMS["hpa"], seed=0, events=list(events),
            auto_repair=False, health=mon,
        )
        return res, mon

    res_off, _ = _health_run(storm, monitored=False)
    res_storm, mon_storm = _health_run(storm, monitored=True)
    if not (np.array_equal(res_off.spans, res_storm.spans)
            and np.array_equal(res_off.access_load, res_storm.access_load)):
        raise AssertionError("health monitoring changed serving results")

    # snapshot index of the kill vs of each fire: both alerts must fire
    # within one health window (hw snapshots) of the kill, and resolve
    snap_t = mon_storm.store.series("online_served_queries").times()
    fires = {h["alert"]: h["t"] for h in mon_storm.history
             if h["kind"] == "fire"}
    kill_idx = int((snap_t < kill_at).sum())
    worst_lag = 0
    for rule in ("degraded_rate", "load_skew"):
        if rule not in fires:
            raise AssertionError(f"{rule} did not fire under the storm")
        lag = int((snap_t <= fires[rule]).sum()) - kill_idx
        worst_lag = max(worst_lag, lag)
        if lag > hw:
            raise AssertionError(
                f"{rule} fired {lag} snapshots after the kill "
                f"> {hw} (one health window)"
            )
        if mon_storm.alerts[rule].state != "ok":
            raise AssertionError(f"{rule} never resolved after the repair")
    rows.append(dict(section="health", level="storm", identical=True,
                     events=len(mon_storm.history), ratio=worst_lag,
                     gate=hw, series=len(snap_t)))

    _, mon_clean = _health_run([], monitored=True)
    if mon_clean.history:
        raise AssertionError(
            f"clean run fired alerts: {mon_clean.history}"
        )
    rows.append(dict(section="health", level="clean", identical=True,
                     events=0, series=mon_clean.stats["checks"]))

    flags.reset()
    obs.reset()

    for r in rows:
        print(f"  {r}", flush=True)
    emit_csv("bench_obs", rows, KEYS)
    save_json("BENCH_obs", rows)
    return rows


if __name__ == "__main__":
    run(quick=True)
