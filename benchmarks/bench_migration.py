"""Live plan migration benchmark: full plan swap under lmbr-stress traffic.

One section, one BENCH_migration.json: the lmbr-stress trace is served
through `Simulator.run_online` while the layout migrates from a random
placement onto a cold LMBR fit — the worst-case "full plan swap" diff
(thousands of copies AND drops).  Two runs:

  * ``instant`` — the legacy atomic hot-swap (``migration_bandwidth`` 0):
    the diff applies between two microbatches, data teleports for free.
    This is the span baseline the paced run's regret is measured against.
  * ``paced`` — the same swap streamed as bandwidth-paced replica
    transfers with union-layout serving (`repro.online.migration`).

Gates (AssertionError aborts the bench):

  * the paced run serves with ZERO degraded queries — union serving never
    loses routability mid-migration;
  * concurrent in-flight bytes never exceed the plan's declared
    ``inflight_bound`` (concurrency x distinct destinations x max copy);
  * the migration completes inside the trace and the final live matrix is
    BIT-IDENTICAL to the target plan (both runs);
  * capacity never exceeds ``capacity * (1 + migration_headroom)``.

``span_regret`` — the paced run's avg served span minus the instant
run's — is reported in the JSON (not gated: it is the price of moving
data at finite bandwidth, the quantity this subsystem exists to expose).

Emits benchmarks/results/BENCH_migration.json; see benchmarks/README.md
for the row schema.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (
    ALGORITHMS,
    LMBR_STRESS_DEFAULTS,
    Placement,
    Simulator,
    lmbr_stress_workload,
)
from repro.online import plan_migration

from .common import emit_csv, save_json

KEYS = [
    "section", "engine", "seconds", "avg_span", "degraded", "copies",
    "drops", "transfer_gb", "wasted_gb", "max_inflight_gb",
    "inflight_bound_gb", "ticks", "span_regret", "bit_identical", "done",
]

MIGRATE_AT = 2000  # trace position of the migrate event
HEADROOM = 0.15
CONCURRENCY = 4


def _capture_fit(old: Placement):
    """A fit function returning a copy of ``old`` whose member matrix the
    bench keeps a handle on: `run_online`'s live layout SHARES it, so after
    the run the handle is the final live matrix (the bit-identity gate)."""
    state: dict = {}

    def fit(hg, n, cap, **kw):
        pl = Placement(old.member.copy(), old.capacity, old.node_weights)
        state["member"] = pl.member
        return pl

    return fit, state


def _one_run(sim, hg, old, mplan, engine: str):
    fit, state = _capture_fit(old)
    t0 = time.perf_counter()
    res = sim.run_online(hg, fit, name=f"migration-{engine}",
                         events=[(MIGRATE_AT, "migrate", mplan)])
    dt = time.perf_counter() - t0
    return res, state["member"], dt


def run(quick: bool = True) -> list[dict]:
    from repro.core.setcover import _accel_backend

    _accel_backend()  # pay the one-time jax import outside the timings
    wl = lmbr_stress_workload()
    hg = wl.hypergraph
    n = LMBR_STRESS_DEFAULTS["num_partitions"]
    cap = LMBR_STRESS_DEFAULTS["capacity"]
    fit_moves = 300 if quick else LMBR_STRESS_DEFAULTS["max_moves"]

    old = ALGORITHMS["random"](hg, n, cap, seed=0)
    new = ALGORITHMS["lmbr"](hg, n, cap, seed=0, max_moves=fit_moves)
    w = hg.node_weights
    sim = Simulator(n, cap)

    base = plan_migration(old.member, new.member, node_weights=w,
                          bandwidth=0.0, concurrency=CONCURRENCY,
                          headroom=HEADROOM)
    # pace so the swap drains in well under the post-event trace slack
    ticks_left = hg.num_edges - MIGRATE_AT
    bandwidth = max(1.0, np.ceil(
        base.bytes_to_move(w) / (0.5 * ticks_left)
    ))
    paced = plan_migration(old.member, new.member, node_weights=w,
                           bandwidth=float(bandwidth),
                           concurrency=CONCURRENCY, headroom=HEADROOM)
    bound_gb = paced.inflight_bound(w) * sim.item_gb

    rows = []
    spans = {}
    for engine, mplan in (("instant", base), ("paced", paced)):
        res, final_member, dt = _one_run(sim, hg, old, mplan, engine)
        s = res.online_stats
        if not s["migration_done"]:
            raise AssertionError(
                f"{engine} migration did not complete inside the trace "
                f"(bandwidth {mplan.bandwidth}, {mplan.num_copies} copies)"
            )
        if engine == "paced" and s["degraded_queries"]:
            raise AssertionError(
                f"paced migration degraded {s['degraded_queries']} queries"
                " — union serving must never lose routability"
            )
        if s["migration_max_inflight_gb"] > bound_gb + 1e-9:
            raise AssertionError(
                f"in-flight bytes {s['migration_max_inflight_gb']} exceed "
                f"the declared bound {bound_gb}"
            )
        if not np.array_equal(final_member, new.member):
            raise AssertionError(
                f"{engine} final layout is not bit-identical to the target"
            )
        if not (res.loads <= cap * (1.0 + HEADROOM) + 1e-9).all():
            raise AssertionError(f"{engine} run violated the headroom bound")
        spans[engine] = float(res.spans.mean())
        rows.append(dict(
            section="migration", engine=engine, seconds=round(dt, 3),
            avg_span=round(spans[engine], 4),
            degraded=int(s["degraded_queries"]),
            copies=int(s["migration_copies"]),
            drops=int(s["migration_drops"]),
            transfer_gb=s["migration_transfer_gb"],
            wasted_gb=s["migration_wasted_gb"],
            max_inflight_gb=s["migration_max_inflight_gb"],
            inflight_bound_gb=round(bound_gb, 4),
            ticks=int(s["migration_ticks"]),
            span_regret=None, bit_identical=True,
            done=bool(s["migration_done"]),
        ))
    rows[-1]["span_regret"] = round(spans["paced"] - spans["instant"], 4)

    for r in rows:
        print(f"  {r}", flush=True)
    emit_csv("bench_migration", rows, KEYS)
    save_json("BENCH_migration", rows)
    return rows


if __name__ == "__main__":
    run(quick=True)
