"""Online serving benchmark: router throughput, drift recovery, failover.

Three sections, one BENCH_online.json:

  * router — serving throughput (queries/sec) on the lmbr-stress trace:
    ``scalar-loop`` (one `cover_for_query` per query, the pre-subsystem
    serving path), ``microbatched`` (`ReplicaRouter`, one batched cover per
    ``router_microbatch`` queries) and ``balanced`` (microbatched + the
    load-aware tie-break).  The microbatched covers are asserted
    BIT-IDENTICAL to the scalar loop (chosen partitions AND per-item replica
    attribution), and the run aborts if the microbatched speedup falls
    under ``ROUTER_GATE`` (8x — see the constant for the calibration).
  * drift — a fig6→shifted-workload splice served through
    `Simulator.run_online` with the drift detector armed: the trigger must
    fire, and the post-refit windowed avg_span must land within 10% of a
    cold LMBR fit on the new workload (asserted).
  * failover — kill EVERY single partition (and a few pairs) of a fitted
    layout, repair through `FailoverManager`, and compare the repaired
    trace avg_span against a from-scratch refit on the surviving
    partitions.  Coverage must be fully restored and every single-kill
    ratio must stay within 15% (asserted).

Emits benchmarks/results/BENCH_online.json; see benchmarks/README.md for
the row schema.
"""

from __future__ import annotations

import gc
import time

import numpy as np

from repro import flags
from repro.core import (
    ALGORITHMS,
    Hypergraph,
    LMBR_STRESS_DEFAULTS,
    Placement,
    PlacementService,
    Simulator,
    cover_for_query,
    lmbr_stress_workload,
    random_workload,
    spans_for_workload,
)
from repro.online import FailoverManager, ReplicaRouter

from .common import emit_csv, save_json

KEYS = [
    "section", "engine", "seconds", "qps", "speedup", "identical",
    "load_imbalance", "avg_span", "kills", "ratio", "worst_ratio",
    "drift_fires", "plan_swaps", "windowed_avg_span", "cold_avg_span",
    "repaired_items", "restored_coverage",
]

# microbatched-router speedup floor.  PR 4 measured 12-18x; the current
# 1-core CI container lands at ~10.6x with a fresh process and 9.3-9.8x
# when bench_lmbr runs first in the same process (verified identical on
# the untouched PR 5 tree, so it is machine drift, not an engine
# regression).  8x keeps real regressions loud without flaking on the
# in-process sequence bench-smoke runs.
ROUTER_GATE = 8.0


# ------------------------------------------------------------------ router
def _router_rows(quick: bool) -> list[dict]:
    wl = lmbr_stress_workload()
    hg = wl.hypergraph
    n = LMBR_STRESS_DEFAULTS["num_partitions"]
    cap = LMBR_STRESS_DEFAULTS["capacity"]
    # serving throughput is layout-independent; a random layout keeps the
    # tier's fit cost out of the serving benchmark
    pl = ALGORITHMS["random"](hg, n, cap, seed=0)
    nq = hg.num_edges

    # exactness gate first (covers AND replica attribution), so the big
    # reference-result list is freed before anything is timed
    router = ReplicaRouter(pl.member)
    batch = router.route_csr(hg.edge_ptr, hg.edge_nodes)
    full_spans = batch.spans
    for e in range(nq):
        chosen, accessed = cover_for_query(hg.edge(e), pl.member)
        assert list(batch.chosen(e)) == chosen, f"query {e} cover diverged"
        cov = batch.cover(e)
        for p, items in zip(chosen, accessed):
            assert np.array_equal(cov[p], items), f"query {e} attribution"

    # paired per-slice timing: each trace slice times the scalar loop and
    # the microbatched router back to back (min-of-2 on BOTH sides, so the
    # measurement is symmetric), which keeps transient CPU contention from
    # hitting only one side of a pair; the reported speedup is the median
    # slice ratio (robust against a slow or fast outlier slice)
    slice_q = 2000
    t_scalar = 0.0
    t_batch = 0.0
    ratios = []
    for lo in range(0, nq, slice_q):
        hi = min(lo + slice_q, nq)
        gc.collect()
        ts = np.inf
        for _ in range(2):
            t0 = time.perf_counter()
            for e in range(lo, hi):
                cover_for_query(hg.edge(e), pl.member)
            ts = min(ts, time.perf_counter() - t0)
        ptr = hg.edge_ptr[lo: hi + 1] - hg.edge_ptr[lo]
        nodes = hg.edge_nodes[hg.edge_ptr[lo]: hg.edge_ptr[hi]]
        tb = np.inf
        for _ in range(2):
            t0 = time.perf_counter()
            batch = router.route_csr(ptr, nodes)
            tb = min(tb, time.perf_counter() - t0)
        t_scalar += ts
        t_batch += tb
        ratios.append(ts / max(tb, 1e-9))
    speedup = float(np.median(ratios))
    if speedup < ROUTER_GATE:
        raise AssertionError(
            f"microbatched router median slice speedup {speedup:.1f}x "
            f"< {ROUTER_GATE}x gate (slices: {[round(r, 1) for r in ratios]})"
        )

    balanced = ReplicaRouter(pl.member, balance=True)
    balanced.route_csr(hg.edge_ptr, hg.edge_nodes)
    balanced.load[:] = 0.0
    t_bal = np.inf
    for _ in range(3):
        gc.collect()
        t0 = time.perf_counter()
        bbatch = balanced.route_csr(hg.edge_ptr, hg.edge_nodes)
        t_bal = min(t_bal, time.perf_counter() - t0)
    balanced.load[:] = 0.0  # report single-trace ledger metrics
    bbatch = balanced.route_csr(hg.edge_ptr, hg.edge_nodes)

    rows = [
        dict(section="router", engine="scalar-loop",
             seconds=round(t_scalar, 3), qps=round(nq / t_scalar),
             speedup=1.0, identical=True,
             avg_span=round(float(full_spans.mean()), 4),
             load_imbalance=None),
        dict(section="router", engine="microbatched",
             seconds=round(t_batch, 3), qps=round(nq / max(t_batch, 1e-9)),
             speedup=round(speedup, 1), identical=True,
             avg_span=round(float(full_spans.mean()), 4),
             load_imbalance=round(router.load_imbalance(), 3)),
        dict(section="router", engine="balanced",
             seconds=round(t_bal, 3), qps=round(nq / max(t_bal, 1e-9)),
             speedup=round(t_scalar / max(t_bal, 1e-9), 1), identical=False,
             avg_span=round(float(bbatch.spans.mean()), 4),
             load_imbalance=round(balanced.load_imbalance(), 3)),
    ]
    return rows


# ------------------------------------------------------------------- drift
def _drift_rows(quick: bool) -> list[dict]:
    n, cap = 40, 50
    fit_moves = 120 if quick else 300
    old = random_workload(1000, 4000, 3, 11, 20, seed=0)
    new = random_workload(1000, 4000, 3, 11, 20, seed=7)
    window = int(flags.FLAGS["drift_window"])
    # splice: a slice of yesterday's traffic, then the shifted workload
    trace = Hypergraph.from_edges(
        [old.hypergraph.edge(e) for e in range(2000)]
        + [new.hypergraph.edge(e) for e in range(new.hypergraph.num_edges)],
        num_nodes=1000,
    )
    sim = Simulator(n, cap)
    res = sim.run_online(
        old.hypergraph, ALGORITHMS["lmbr"], name="lmbr+drift", trace=trace,
        service=PlacementService("lmbr", seed=0), refit_moves=400,
        seed=0, max_moves=fit_moves,
    )
    stats = res.online_stats
    if not stats["drift_fires"]:
        raise AssertionError("drift trigger did not fire on the splice")
    # cold fit on the new workload, judged on the same tail window the
    # detector's windowed avg_span covers
    cold = ALGORITHMS["lmbr"](new.hypergraph, n, cap, seed=0,
                              max_moves=fit_moves)
    tail = trace.subhypergraph_edges(
        np.arange(trace.num_edges - window, trace.num_edges)
    )
    cold_span = float(spans_for_workload(tail, cold).mean())
    ratio = stats["windowed_avg_span"] / cold_span
    if ratio > 1.10:
        raise AssertionError(
            f"post-refit windowed avg_span {stats['windowed_avg_span']:.3f} "
            f"is {ratio:.3f}x the cold fit ({cold_span:.3f}) > 1.10 gate"
        )
    return [dict(
        section="drift", engine="run_online",
        drift_fires=stats["drift_fires"], plan_swaps=stats["plan_swaps"],
        windowed_avg_span=stats["windowed_avg_span"],
        cold_avg_span=round(cold_span, 4), ratio=round(ratio, 4),
    )]


# ---------------------------------------------------------------- failover
def _kill_and_repair(hg, pl, kills, cap):
    """Kill `kills`, repair, return (repaired avg_span, repaired count).

    The wave-batched repair is asserted BIT-IDENTICAL to the retained
    per-item reference (`FailoverManager.repair_reference`) on every kill
    scenario — same copies, same destinations."""
    live = Placement(pl.member.copy(), cap, hg.node_weights)
    fo = FailoverManager(live)
    ref_live = Placement(pl.member.copy(), cap, hg.node_weights)
    fo_ref = FailoverManager(ref_live)
    for p in kills:
        fo.partition_down(p)
        fo_ref.partition_down(p)
    repaired = fo.repair(hg, k=1)
    ref_repaired = fo_ref.repair_reference(hg, k=1)
    if not (np.array_equal(repaired, ref_repaired)
            and (live.member == ref_live.member).all()):
        raise AssertionError(
            f"batched repair diverged from the reference after {kills}"
        )
    if len(fo.uncovered_items()):
        raise AssertionError(f"repair left items uncovered after {kills}")
    live.validate()  # repair must respect capacity
    return float(spans_for_workload(hg, live).mean()), fo.stats


def _surviving_refit_span(hg, n, cap, kills, fit_moves) -> float:
    """From-scratch LMBR fit using only the surviving partitions."""
    cold = ALGORITHMS["lmbr"](hg, n - len(kills), cap, seed=0,
                              max_moves=fit_moves)
    return float(spans_for_workload(hg, cold).mean())


def _failover_rows(quick: bool) -> list[dict]:
    n, cap = 12, 40
    fit_moves = 80 if quick else 200
    wl = random_workload(300, 1200, 3, 11, 8, seed=0)
    hg = wl.hypergraph
    pl = ALGORITHMS["lmbr"](hg, n, cap, seed=0, max_moves=fit_moves)

    rows = []
    ratios = []
    repaired_total = 0
    for p in range(n):  # "any single partition": all of them
        span, stats = _kill_and_repair(hg, pl, [p], cap)
        cold = _surviving_refit_span(hg, n, cap, [p], fit_moves)
        ratios.append(span / cold)
        repaired_total += stats["repaired_items"]
    worst = max(ratios)
    if worst > 1.15:
        raise AssertionError(
            f"single-partition repair worst ratio {worst:.3f} > 1.15 gate"
        )
    rows.append(dict(
        section="failover", engine="repair", kills=1,
        ratio=round(float(np.mean(ratios)), 4), worst_ratio=round(worst, 4),
        repaired_items=repaired_total, restored_coverage=True,
    ))

    pair_ratios = []
    repaired_total = 0
    pairs = [(0, 1), (3, 7), (5, 11)]
    for kills in pairs:
        span, stats = _kill_and_repair(hg, pl, list(kills), cap)
        cold = _surviving_refit_span(hg, n, cap, list(kills), fit_moves)
        pair_ratios.append(span / cold)
        repaired_total += stats["repaired_items"]
    rows.append(dict(
        section="failover", engine="repair", kills=2,
        ratio=round(float(np.mean(pair_ratios)), 4),
        worst_ratio=round(max(pair_ratios), 4),
        repaired_items=repaired_total, restored_coverage=True,
    ))
    return rows


def run(quick: bool = True) -> list[dict]:
    from repro.core.setcover import _accel_backend

    _accel_backend()  # pay the one-time jax import outside the timings
    flags.reset()
    rows = []
    rows += _router_rows(quick)
    rows += _drift_rows(quick)
    rows += _failover_rows(quick)
    for r in rows:
        print(f"  {r}", flush=True)
    emit_csv("bench_online", rows, KEYS)
    save_json("BENCH_online", rows)
    return rows


if __name__ == "__main__":
    run(quick=True)
