"""Paper fig. 1 / fig. 5 analogue: query span vs response time vs energy.

The paper measures 6 queries (TPC-H1/2 complex joins, TPC-H3/4 + Q-Sum simple
aggregates, Q-Join) on 20 EC2 machines under (i) horizontal partitioning
across all 20 machines and (ii) an LMBR-driven co-located placement (avg span
3), with a Mantis-style power model.  This container has no hardware
counters, so we reproduce the experiment inside the calibrated simulator
(DESIGN.md §8):

  response_time(q) = scan_gb/(span * scan_rate)            # parallel scan
                     + shuffle_gb(q, span) / net_rate      # join shuffles
                     + startup * span                      # coordination
  energy(q)        = simulator's affine model (work + per-machine + network)

with shuffle_gb ~ 0 for single-table aggregates and ~ input size for joins.
Checked claims: (1) complex joins get FASTER and cheaper with co-location;
(2) simple aggregates get slower but still cheaper; (3) energy drops for all
queries (paper: 31-79%).
"""

from __future__ import annotations

import numpy as np

from repro.core import EnergyModel

from .common import emit_csv

SCAN_RATE_GB_S = 0.25   # per-machine effective scan rate
NET_RATE_GB_S = 0.10    # effective shuffle bandwidth per query
STARTUP_S = 0.05        # per-machine coordination overhead

# (name, scanned GB, join?) — TPC-H-flavored mix from the paper
QUERIES = [
    ("TPC-H1", 18.0, True),
    ("TPC-H2", 12.0, True),
    ("TPC-H3", 8.0, False),
    ("TPC-H4", 6.0, False),
    ("Q-Join", 10.0, True),
    ("Q-Sum", 7.0, False),
]


def response_time(scan_gb: float, span: int, join: bool) -> float:
    shuffle_gb = 0.9 * scan_gb * (span - 1) / span if join else 0.02 * scan_gb
    return scan_gb / (span * SCAN_RATE_GB_S) + shuffle_gb / NET_RATE_GB_S + STARTUP_S * span


def energy(scan_gb: float, span: int, join: bool, em: EnergyModel) -> float:
    shuffle_gb = 0.9 * scan_gb * (span - 1) / span if join else 0.02 * scan_gb
    return em.query_energy(scan_gb, span, shuffle_gb)


def run(quick: bool = True) -> list[dict]:
    em = EnergyModel()
    out = []
    for name, gb, join in QUERIES:
        t20, e20 = response_time(gb, 20, join), energy(gb, 20, join, em)
        t3, e3 = response_time(gb, 3, join), energy(gb, 3, join, em)
        out.append(dict(
            query=name, kind="join" if join else "aggregate",
            rt_span20_s=round(t20, 2), rt_lmbr_span3_s=round(t3, 2),
            energy_span20_kj=round(e20 / 1e3, 2),
            energy_lmbr_span3_kj=round(e3 / 1e3, 2),
            energy_reduction_pct=round(100 * (1 - e3 / e20), 1),
            rt_change_pct=round(100 * (t3 / t20 - 1), 1),
        ))
    emit_csv("fig5_energy_model", out)
    # claim checks
    joins = [r for r in out if r["kind"] == "join"]
    aggs = [r for r in out if r["kind"] == "aggregate"]
    assert all(r["rt_change_pct"] < 0 for r in joins), "joins should speed up"
    assert all(r["rt_change_pct"] > 0 for r in aggs), "aggregates trade latency"
    assert all(r["energy_reduction_pct"] > 0 for r in out), "energy must drop"
    print("# claims: joins faster+cheaper / aggregates slower but cheaper / "
          "all queries cheaper — all hold")
    return out


if __name__ == "__main__":
    run()
