"""Paper fig. 7: Snowflake dataset (homogeneous items).

Tree-shaped data-item graph (levels=3, degree=5, 15 attrs/table, 2000 items,
N_e = 20); average span + placement time as partitions grow 20 -> 45.
"""

from __future__ import annotations

import numpy as np

from repro.core import ALGORITHMS, Simulator, snowflake_workload

from .common import Timer, emit_csv

ALGOS = ["random", "hpa", "ihpa", "pra", "ds", "lmbr"]


def run(quick: bool = True) -> list[dict]:
    runs = 1 if quick else 3
    npars = [20, 30, 40, 45] if quick else [20, 25, 30, 35, 40, 45]
    out = []
    for npar in npars:
        for name in ALGOS:
            spans, times = [], []
            for r in range(runs):
                wl = snowflake_workload(
                    levels=3, degree=5, attrs_per_table=15,
                    num_items=2000, num_queries=4000, seed=r,
                )
                sim = Simulator(num_partitions=npar, capacity=100)
                with Timer() as t:
                    res = sim.run(wl.hypergraph, ALGORITHMS[name], name=name,
                                  seed=r)
                spans.append(res.avg_span)
                times.append(t.seconds)
            out.append(dict(
                num_partitions=npar, algorithm=name,
                avg_span=round(float(np.mean(spans)), 4),
                place_seconds=round(float(np.mean(times)), 3),
            ))
    emit_csv("fig7_snowflake", out,
             ["num_partitions", "algorithm", "avg_span", "place_seconds"])
    return out


if __name__ == "__main__":
    run(quick=True)
