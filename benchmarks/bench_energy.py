"""Heterogeneous cluster model benchmark: energy-aware placement, durability.

Three sections, one BENCH_energy.json, three hard gates:

  * identity — the web-mid tier fitted twice: the scalar-capacity call and
    the same fit driven through `NodeProfile.homogeneous` (normalized
    capacity + uniform access-cost vector).  The members must be
    BIT-IDENTICAL (asserted) — the PR 7 refactor's contract that a
    homogeneous profile reproduces every pre-profile number exactly.
  * energy — the same tier refitted under ``placement_objective="energy"``:
    replicas concentrate onto a capacity-descending active-row prefix so
    idle machines can power down.  Gates: active machines drop by
    >= ``MACHINE_GATE`` (30%) and avg_span stays <= ``SPAN_GATE`` (1.10x)
    of the span-objective fit — the span-vs-active-machines Pareto point
    the energy literature trades along.
  * durability — the fig6 tier fitted with and without a durability
    ceiling (``durability_eps``, homogeneous ``fail_prob=0.02`` so every
    item needs >= 2 replicas).  Gates: no item's loss probability exceeds
    the ceiling (``validate_durability``, asserted) and the constrained
    fit's avg_span is <= ``DURAB_GATE`` (1.05x) the unconstrained fit —
    durability copies are extra replicas, so co-location must not degrade.

Emits benchmarks/results/BENCH_energy.json; see benchmarks/README.md for
the row schema.
"""

from __future__ import annotations

import time

import numpy as np

from repro import flags
from repro.core import (
    EnergyModel,
    NodeProfile,
    PlacementService,
    lmbr,
    random_workload,
    spans_for_workload,
    validate_durability,
    web_scale_workload,
)
from repro.core.cluster import _loss_probs

from .common import emit_csv, save_json

KEYS = [
    "section", "tier", "mode", "items", "queries", "partitions", "seconds",
    "avg_span", "span_ratio", "active_machines", "machine_cut_pct",
    "cluster_power_w", "rf", "durability_eps", "p_loss_max",
    "durability_copies", "identical",
]

MACHINE_GATE = 30.0   # energy objective powers down >= 30% of machines
SPAN_GATE = 1.10      # ... at <= 1.10x the span-objective avg_span
DURAB_GATE = 1.05     # durability ceiling costs <= 1.05x unconstrained span


def _fit_row(hg, n, cap, moves, **extra):
    t0 = time.perf_counter()
    pl = lmbr(hg, n, cap, seed=0, max_moves=moves, **extra)
    dt = time.perf_counter() - t0
    return pl, dt


# ------------------------------------------------- identity + energy (web)
def _web_rows(quick: bool) -> list[dict]:
    wl = web_scale_workload(num_items=2500, num_queries=10_000,
                            num_clusters=48, cross_frac=0.05, seed=0)
    hg = wl.hypergraph
    n, cap, moves = 24, 210, 400
    em = EnergyModel()
    prof = NodeProfile.homogeneous(n, cap)

    span_fit, t_span = _fit_row(hg, n, cap, moves)
    span_avg = float(spans_for_workload(hg, span_fit).mean())
    span_loads = span_fit.partition_weights()
    span_active = int((span_loads > 0).sum())

    # gate 1: the homogeneous-profile path is bit-identical
    prof_fit, t_prof = _fit_row(hg, n, prof.capacity_arg(), moves,
                                node_cost=prof.access_cost)
    if not (span_fit.member == prof_fit.member).all():
        raise AssertionError(
            "homogeneous NodeProfile fit diverged from the scalar-capacity "
            "fit on web-mid (bit-identity contract)"
        )

    # gate 2: the energy objective's Pareto point
    flags.FLAGS["placement_objective"] = "energy"
    try:
        energy_fit, t_energy = _fit_row(hg, n, cap, moves)
    finally:
        flags.reset()
    energy_fit.validate()
    energy_avg = float(spans_for_workload(hg, energy_fit).mean())
    energy_loads = energy_fit.partition_weights()
    energy_active = int((energy_loads > 0).sum())
    cut = 100.0 * (1 - energy_active / max(span_active, 1))
    ratio = energy_avg / max(span_avg, 1e-12)
    if cut < MACHINE_GATE:
        raise AssertionError(
            f"energy objective cut only {cut:.1f}% of active machines "
            f"({span_active} -> {energy_active}) < {MACHINE_GATE}% gate"
        )
    if ratio > SPAN_GATE:
        raise AssertionError(
            f"energy objective avg_span {energy_avg:.4f} is {ratio:.3f}x "
            f"the span objective ({span_avg:.4f}) > {SPAN_GATE} gate"
        )

    base = dict(tier=wl.name, items=hg.num_nodes, queries=hg.num_edges,
                partitions=n)
    return [
        dict(base, section="identity", mode="scalar-capacity",
             seconds=round(t_span, 2), avg_span=round(span_avg, 4),
             active_machines=span_active,
             cluster_power_w=round(em.cluster_power(span_loads, prof), 1),
             rf=round(span_fit.replication_factor(), 3), identical=True),
        dict(base, section="identity", mode="homogeneous-profile",
             seconds=round(t_prof, 2), avg_span=round(span_avg, 4),
             active_machines=span_active, identical=True),
        dict(base, section="energy", mode="energy-objective",
             seconds=round(t_energy, 2), avg_span=round(energy_avg, 4),
             span_ratio=round(ratio, 4), active_machines=energy_active,
             machine_cut_pct=round(cut, 1),
             cluster_power_w=round(em.cluster_power(energy_loads, prof), 1),
             rf=round(energy_fit.replication_factor(), 3)),
    ]


# ------------------------------------------------------------- durability
def _durability_rows(quick: bool) -> list[dict]:
    wl = random_workload(seed=0)  # the fig6 tier: 1000 items, 4000 queries
    hg = wl.hypergraph
    # generous capacity: LMBR's default move budget may fill ~50*N copies,
    # and the durability pass needs free rows left for its extra replicas
    n, cap, eps = 48, 100, 1e-3
    prof = NodeProfile.homogeneous(n, cap, fail_prob=0.02)  # 0.02^2 <= eps
    svc = PlacementService(seed=0)
    queries = wl.queries

    t0 = time.perf_counter()
    free = svc.fit(queries, hg.num_nodes, n, cap)
    t_free = time.perf_counter() - t0
    free_avg = free.avg_span(queries)

    t0 = time.perf_counter()
    durable = svc.fit(queries, hg.num_nodes, n, profile=prof,
                      durability_eps=eps)
    t_dur = time.perf_counter() - t0
    dur_avg = durable.avg_span(queries)

    # gate 3a: the ceiling holds for every placed item
    validate_durability(durable.as_placement(), prof, eps)
    loss = _loss_probs(durable.member, prof.fail_prob)
    placed = durable.member.any(axis=0)
    p_loss_max = float(loss[placed].max()) if placed.any() else 0.0

    # gate 3b: durability copies must not shred co-location
    ratio = dur_avg / max(free_avg, 1e-12)
    if ratio > DURAB_GATE:
        raise AssertionError(
            f"durability-constrained avg_span {dur_avg:.4f} is "
            f"{ratio:.3f}x the unconstrained fit ({free_avg:.4f}) "
            f"> {DURAB_GATE} gate"
        )

    base = dict(tier=wl.name, items=hg.num_nodes, queries=hg.num_edges,
                partitions=n, section="durability")
    return [
        dict(base, mode="unconstrained", seconds=round(t_free, 2),
             avg_span=round(free_avg, 4),
             rf=round(free.as_placement().replication_factor(), 3)),
        dict(base, mode=f"eps={eps:g}", seconds=round(t_dur, 2),
             avg_span=round(dur_avg, 4), span_ratio=round(ratio, 4),
             durability_eps=eps, p_loss_max=float(f"{p_loss_max:.2e}"),
             durability_copies=int(durable.stats["durability_copies"]),
             rf=round(durable.as_placement().replication_factor(), 3)),
    ]


def run(quick: bool = True) -> list[dict]:
    flags.reset()
    rows = []
    rows += _web_rows(quick)
    rows += _durability_rows(quick)
    for r in rows:
        print(f"  {r}", flush=True)
    emit_csv("bench_energy", rows, KEYS)
    save_json("BENCH_energy", rows)
    return rows


if __name__ == "__main__":
    run(quick=True)
