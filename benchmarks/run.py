"""Benchmark driver — one module per paper figure/table plus the framework
applications.  Default is quick mode (single seed, reduced sweep points;
orderings are stable); pass --full for the paper-fidelity sweeps.

  python -m benchmarks.run [--full] [--only fig6_random,fig9_ispd,...]
"""

from __future__ import annotations

import argparse
import importlib
import sys
import time

# (module, headline) in run order
SECTIONS = [
    ("energy_model", "fig1/5: span vs latency vs energy (calibrated model)"),
    ("fig6_random", "fig6a-e: Random dataset, 6 algorithms"),
    ("fig6_3way", "fig6f-h: 3-way replication"),
    ("fig7_snowflake", "fig7: Snowflake dataset"),
    ("fig8_tpch", "fig8: TPC-H heterogeneous item sizes"),
    ("fig9_ispd", "fig9: ISPD98-like circuit hypergraphs"),
    ("bench_spans", "span engine: reference loop vs batched bitset (+jax)"),
    ("bench_lmbr", "LMBR move engine: reference peel vs vectorized + cache"),
    ("bench_online", "online serving: router qps, drift recovery, failover"),
    ("bench_migration", "live migration: paced full plan swap vs instant"),
    ("bench_scale", "cluster-scale: streaming ingestion, sharded parallel fits"),
    ("bench_energy", "heterogeneous cluster: energy objective, durability"),
    ("bench_obs", "observability: off/counters/trace identity + overhead"),
    ("placement_applications", "framework: MoE experts / shards / checkpoints"),
    ("kernel_bench", "Pallas kernels vs jnp oracles (CPU interpret)"),
    ("roofline_table", "roofline terms from dry-run artifacts"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-fidelity sweeps")
    ap.add_argument("--only", type=str, default="",
                    help="comma-separated module names to run")
    args = ap.parse_args()
    only = set(filter(None, args.only.split(",")))

    t_start = time.time()
    summary: list[tuple[str, float, str]] = []
    for mod_name, headline in SECTIONS:
        if only and mod_name not in only:
            continue
        print(f"\n===== {mod_name}: {headline} =====", flush=True)
        try:
            mod = importlib.import_module(f"benchmarks.{mod_name}")
        except ImportError as exc:
            print(f"  [skipped: {exc}]")
            summary.append((mod_name, 0.0, "skipped"))
            continue
        t0 = time.time()
        try:
            mod.run(quick=not args.full)
            status = "ok"
        except Exception as exc:  # keep the suite going; report at the end
            print(f"  [FAILED: {type(exc).__name__}: {exc}]")
            status = f"FAILED:{type(exc).__name__}"
        summary.append((mod_name, time.time() - t0, status))

    print("\n===== summary =====")
    print("name,us_per_call,derived")
    for name, secs, status in summary:
        print(f"{name},{secs*1e6:.0f},{status}")
    print(f"# total: {time.time()-t_start:.1f}s")
    if any(s.startswith("FAILED") for _, _, s in summary):
        sys.exit(1)


if __name__ == "__main__":
    main()
