"""Paper fig. 6(a)-(e): Random dataset, homogeneous items.

Sweeps: number of partitions, query size (ADI), number of queries, data-item
graph density — average query span + placement time for the six algorithms.

Paper defaults: |D|=1000, minQ=3, maxQ=11, NQ=4000, C=50, NPar=40, density=20.
The paper averages 10 random runs; `runs` trades fidelity for wall-time
(--full uses 3, quick uses 1 — orderings are stable across seeds).
"""

from __future__ import annotations

import numpy as np

from repro.core import ALGORITHMS, Simulator, random_workload

from .common import Timer, emit_csv

ALGOS = ["random", "hpa", "ihpa", "pra", "ds", "lmbr"]


def _avg_over_runs(make_wl, num_partitions, capacity, runs, algos=ALGOS):
    rows = []
    for name in algos:
        spans, times = [], []
        for r in range(runs):
            wl = make_wl(seed=r)
            sim = Simulator(num_partitions=num_partitions, capacity=capacity)
            with Timer() as t:
                res = sim.run(wl.hypergraph, ALGORITHMS[name], name=name, seed=r)
            spans.append(res.avg_span)
            times.append(t.seconds)
        rows.append(
            dict(algorithm=name, avg_span=round(float(np.mean(spans)), 4),
                 place_seconds=round(float(np.mean(times)), 3))
        )
    return rows


def run(quick: bool = True) -> list[dict]:
    runs = 1 if quick else 3
    out = []

    # --- (a)+(b): increasing number of partitions (N_e = 20)
    npars = [20, 30, 40, 45] if quick else [20, 25, 30, 35, 40, 45]
    for npar in npars:
        for row in _avg_over_runs(
            lambda seed: random_workload(1000, 4000, 3, 11, 20, seed=seed),
            npar, 50, runs,
        ):
            out.append(dict(sweep="num_partitions", x=npar, **row))

    # --- (c): increasing query size (minQ = maxQ = x)
    qsizes = [2, 4, 6, 8, 10] if quick else [2, 3, 4, 5, 6, 7, 8, 9, 10]
    for q in qsizes:
        for row in _avg_over_runs(
            lambda seed, q=q: random_workload(1000, 4000, q, q, 20, seed=seed),
            40, 50, runs,
        ):
            out.append(dict(sweep="query_size", x=q, **row))

    # --- (d): increasing number of queries
    nqs = [1000, 4000, 8000, 11000] if quick else [1000, 3000, 5000, 7000, 9000, 11000]
    for nq in nqs:
        for row in _avg_over_runs(
            lambda seed, nq=nq: random_workload(1000, nq, 3, 11, 20, seed=seed),
            40, 50, runs,
        ):
            out.append(dict(sweep="num_queries", x=nq, **row))

    # --- (e): increasing data-item-graph density
    densities = [2, 5, 10, 20] if quick else [2, 4, 6, 8, 10, 14, 20]
    for d in densities:
        for row in _avg_over_runs(
            lambda seed, d=d: random_workload(1000, 4000, 3, 11, d, seed=seed),
            40, 50, runs,
        ):
            out.append(dict(sweep="density", x=d, **row))

    emit_csv("fig6_random", out,
             ["sweep", "x", "algorithm", "avg_span", "place_seconds"])
    return out


if __name__ == "__main__":
    run(quick=True)
