"""Paper fig. 8: TPC-H-style benchmark with heterogeneous item sizes.

Snowflake schema, item sizes log-skewed 25KB..28GB (SF=25), partition
capacity 100GB; span vs number of partitions.  The paper's observation:
with extreme size skew, placement freedom shrinks and the gap between the
smart algorithms and the baselines narrows.
"""

from __future__ import annotations

import numpy as np

from repro.core import ALGORITHMS, Simulator, tpch_heterogeneous

from .common import Timer, emit_csv

ALGOS = ["random", "hpa", "ihpa", "pra", "ds", "lmbr"]


def run(quick: bool = True) -> list[dict]:
    runs = 1 if quick else 3
    npars = [20, 30, 40, 45] if quick else [20, 25, 30, 35, 40, 45]
    out = []
    for npar in npars:
        for name in ALGOS:
            spans, times = [], []
            for r in range(runs):
                wl = tpch_heterogeneous(num_items=2000, num_queries=4000, seed=r)
                # N_e for the generated weights is ~20 at capacity 100GB;
                # verify and clamp so every npar >= N_e
                sim = Simulator(num_partitions=npar, capacity=100.0)
                with Timer() as t:
                    res = sim.run(wl.hypergraph, ALGORITHMS[name], name=name,
                                  seed=r)
                spans.append(res.avg_span)
                times.append(t.seconds)
            out.append(dict(
                num_partitions=npar, algorithm=name,
                avg_span=round(float(np.mean(spans)), 4),
                place_seconds=round(float(np.mean(times)), 3),
            ))
    emit_csv("fig8_tpch_hetero", out,
             ["num_partitions", "algorithm", "avg_span", "place_seconds"])
    return out


if __name__ == "__main__":
    run(quick=True)
