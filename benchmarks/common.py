"""Shared benchmark plumbing: CSV emission, timing, result collection."""

from __future__ import annotations

import csv
import io
import json
import os
import time

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def ensure_results_dir() -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return RESULTS_DIR


def emit_csv(name: str, rows: list[dict], keys: list[str] | None = None) -> str:
    """Print rows as CSV to stdout and persist to benchmarks/results/<name>.csv."""
    if not rows:
        print(f"# {name}: no rows")
        return ""
    keys = keys or list(rows[0].keys())
    buf = io.StringIO()
    w = csv.DictWriter(buf, fieldnames=keys, extrasaction="ignore")
    w.writeheader()
    for r in rows:
        w.writerow(r)
    text = buf.getvalue()
    print(f"### {name}")
    print(text)
    ensure_results_dir()
    with open(os.path.join(RESULTS_DIR, f"{name}.csv"), "w") as f:
        f.write(text)
    return text


def save_json(name: str, obj) -> None:
    ensure_results_dir()
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(obj, f, indent=1, default=str)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
