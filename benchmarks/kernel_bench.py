"""Kernel microbench: Pallas kernels (interpret mode, correctness-scale) vs
jnp oracles, plus the analytic FLOPs / arithmetic-intensity table that feeds
the TPU roofline (wall-clock on this CPU container is NOT a TPU signal; the
interpret run only proves the kernels execute the same math)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.decode_attention.kernel import decode_attention as dec_k
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.kernel import flash_attention as fa_k
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.ssd_scan.kernel import ssd_scan as ssd_k
from repro.kernels.ssd_scan.ref import ssd_scan_ref

from .common import emit_csv


def _attn_flops(b, h, s, t, d):
    return 4.0 * b * h * s * t * d  # qk + pv


def run(quick: bool = True) -> list[dict]:
    rows = []
    key = jax.random.PRNGKey(0)

    # span-gain kernel (batched replica selection): the fused Pallas
    # mask+popcount+reduce in interpret mode, the jitted jnp backend, and the
    # numpy oracle must agree exactly (integer kernel -> max_err must be 0).
    # Runs first so the span engine signal survives failures in the
    # attention kernels below.
    from repro.kernels.span_gain.ops import span_gains
    from repro.kernels.span_gain.ref import span_gain_ref

    rng = np.random.default_rng(0)
    E, N, W = 4096, 35, 2  # ~ibm-scale bucket: 4k queries, 35 partitions
    codes = rng.integers(0, 2**63, size=(E, N, W), dtype=np.uint64)
    rem = rng.integers(0, 2**63, size=(E, W), dtype=np.uint64)
    oracle = span_gain_ref(codes, rem)
    # interpret-mode pallas at correctness scale (full scale is minutes of
    # pure-Python grid stepping; the small slice proves the same math)
    ei = 64
    got_i = span_gains(codes[:ei], rem[:ei], force="interpret")
    err = int(np.abs(got_i - oracle[:ei]).max())
    span_gains(codes, rem, force="jax")  # jit warmup
    t0 = time.perf_counter()
    got = span_gains(codes, rem, force="jax")
    t_jax = time.perf_counter() - t0
    err = max(err, int(np.abs(got - oracle).max()))
    # one greedy round touches E*N*W words: popcount+add ~ 2 ops/word
    g_flops = 2.0 * E * N * W
    g_bytes = (E * N * W + E * W) * 8
    rows.append(dict(
        kernel="span_gain", max_err=f"{err:.2e}",
        interpret_s=round(t_jax, 4),
        deploy_flops=f"{g_flops:.2e}", deploy_ai=round(g_flops / g_bytes, 2),
        mxu_bound=False,  # popcount runs on the VPU, HBM-streamed
    ))
    print(f"  {rows[-1]}", flush=True)

    # dispatch-threshold calibration: numpy-vs-jax wall clock per bucket
    # size; the crossover feeds flags.FLAGS["span_dispatch_threshold"]
    # (auto mode sends rounds below it to numpy, above it to the
    # accelerated backend).
    cal_sizes = (256, 1024, 4096) if quick else (64, 256, 1024, 4096, 16384)
    crossover = None
    for A in cal_sizes:
        c, r = codes[:A], rem[:A]
        got = span_gains(c, r, force="jax")  # warm per-shape jit
        cal_err = int(np.abs(got - oracle[:A]).max())
        t = {}
        for f in ("numpy", "jax"):
            t0 = time.perf_counter()
            for _ in range(5):
                span_gains(c, r, force=f)
            t[f] = (time.perf_counter() - t0) / 5
        if crossover is None and t["jax"] < t["numpy"]:
            crossover = A * N * W
        rows.append(dict(
            kernel=f"span_gain_calibration_{A}", max_err=f"{cal_err:.2e}",
            interpret_s=round(t["jax"], 5),
            deploy_flops=f"{2.0 * A * N * W:.2e}",
            deploy_ai=f"numpy={t['numpy'] * 1e3:.2f}ms jax={t['jax'] * 1e3:.2f}ms",
            mxu_bound=False,
        ))
    from repro import flags as _flags

    found = (f"~{crossover} words" if crossover is not None
             else f"none up to {max(cal_sizes) * N * W} words (numpy wins)")
    print(f"  span_gain numpy->jax crossover {found} "
          f"(flag default {_flags.FLAGS['span_dispatch_threshold']})",
          flush=True)

    # tile-shape sweep: the Pallas span_gain kernel across candidate
    # (block_a, block_n) tilings on randomized already-padded shapes,
    # asserted exactly against the numpy oracle through the same
    # uint64 -> uint32-lane split the dispatcher performs.  Integer kernel:
    # any tiling that diverges from the oracle is a hard failure, so the
    # (8, 128) default is validated beyond interpret smoke tests.
    from repro.kernels.span_gain.kernel import span_gain as span_gain_kernel

    tile_rng = np.random.default_rng(7)
    for block_a, block_n in ((8, 128), (16, 128), (8, 256), (32, 128)):
        At = block_a * int(tile_rng.integers(1, 4))
        Nt = block_n * int(tile_rng.integers(1, 3))
        Wt = int(tile_rng.integers(1, 4))
        tcodes = tile_rng.integers(0, 2**63, size=(At, Nt, Wt),
                                   dtype=np.uint64)
        trem = tile_rng.integers(0, 2**63, size=(At, Wt), dtype=np.uint64)
        tcodes[0, 0, 0] = np.uint64(0xFFFFFFFFFFFFFFFF)  # full-lane words
        trem[0, 0] = np.uint64(0xFFFFFFFFFFFFFFFF)
        c32 = (tcodes[..., None].view(np.uint32)
               .reshape(At, Nt, 2 * Wt).transpose(0, 2, 1))
        r32 = trem[..., None].view(np.uint32).reshape(At, 2 * Wt)
        t0 = time.perf_counter()
        got_t = np.asarray(span_gain_kernel(
            np.ascontiguousarray(c32), r32, block_a=block_a,
            block_n=block_n, interpret=True,
        ))
        t_tile = time.perf_counter() - t0
        tile_err = int(np.abs(got_t - span_gain_ref(tcodes, trem)).max())
        rows.append(dict(
            kernel=f"span_gain_tile_{block_a}x{block_n}",
            max_err=f"{tile_err:.2e}", interpret_s=round(t_tile, 3),
            deploy_flops=f"{2.0 * At * Nt * Wt:.2e}",
            deploy_ai=f"A={At} N={Nt} W={Wt}", mxu_bound=False,
        ))
        assert tile_err == 0, (block_a, block_n)
    print(f"  span_gain tilings exact on randomized shapes", flush=True)

    # whole-round cover-loop calibration: one uniform-size bucket through
    # batched_cover_csr under the per-round host loop vs the device-resident
    # lax.while_loop (span_round_backend).  Covers asserted identical; the
    # wall-clock crossover feeds flags.FLAGS["span_round_threshold"].
    from repro.core.hypergraph import Hypergraph
    from repro.core.setcover import batched_cover_csr

    cov_rng = np.random.default_rng(3)
    n_items, n_parts = 4096, 32
    member = cov_rng.random((n_parts, n_items)) < 0.25
    member[0] |= ~member.any(axis=0)
    round_sizes = (256, 2048, 8192) if quick else (256, 2048, 8192, 32768)
    round_cross = None
    for B in round_sizes:
        qs = [cov_rng.choice(n_items, size=48, replace=False)
              for _ in range(B)]
        hgb = Hypergraph.from_edges(qs, num_nodes=n_items)
        res, times = {}, {}
        for backend in ("numpy", "device"):
            _flags.FLAGS["span_round_backend"] = backend
            try:
                cov = batched_cover_csr(hgb.edge_ptr, hgb.edge_nodes, member)
                t0 = time.perf_counter()
                for _ in range(3):
                    cov = batched_cover_csr(hgb.edge_ptr, hgb.edge_nodes,
                                            member)
                times[backend] = (time.perf_counter() - t0) / 3
                res[backend] = (cov.spans, cov.cover_ptr, cov.cover_parts)
            finally:
                _flags.reset()
        for w, g in zip(res["numpy"], res["device"]):
            np.testing.assert_array_equal(g, w)
        # W words per query at 48 items -> B * N * ceil(48/64) packed words
        words = B * n_parts * 1
        if round_cross is None and times["device"] < times["numpy"]:
            round_cross = words
        rows.append(dict(
            kernel=f"span_round_calibration_{B}", max_err="0.00e+00",
            interpret_s=round(times["device"], 5),
            deploy_flops=f"{2.0 * words:.2e}",
            deploy_ai=(f"numpy={times['numpy'] * 1e3:.2f}ms "
                       f"device={times['device'] * 1e3:.2f}ms"),
            mxu_bound=False,
        ))
    found_r = (f"~{round_cross} words" if round_cross is not None
               else f"none up to {max(round_sizes) * n_parts} words")
    print(f"  span_round host->device crossover {found_r} "
          f"(flag default {_flags.FLAGS['span_round_threshold']})",
          flush=True)

    # lockstep-peel kernel (LMBR Algorithm 5, device-resident): interpret
    # Pallas at correctness scale + the jitted jnp lockstep at batch scale,
    # both against the f64 numpy oracle.  Integer weights: trajectories are
    # f32-exact, so max_err must be 0.
    from repro.kernels.lockstep_peel.ops import lockstep_peel
    from repro.kernels.lockstep_peel.ref import lockstep_peel_ref

    peel_rng = np.random.default_rng(5)
    Gp, Kp, Up = (12, 24, 48) if quick else (32, 48, 96)
    inc = np.zeros((Gp, Kp, Up), dtype=np.float64)
    nvalid = peel_rng.integers(8, Up + 1, size=Gp).astype(np.int64)
    for g in range(Gp):
        for k in range(Kp):
            pins = np.unique(peel_rng.integers(0, nvalid[g], size=4))
            inc[g, k, pins] = 1.0
    wep = peel_rng.integers(1, 9, size=(Gp, Kp)).astype(np.float64)
    nodewp = np.zeros((Gp, Up), dtype=np.float64)
    for g in range(Gp):
        nodewp[g, : nvalid[g]] = peel_rng.integers(1, 5, size=int(nvalid[g]))
    want_p = lockstep_peel_ref(inc, wep, nodewp, nvalid)
    gi = 2  # interpret slice: pure-Python grid stepping is minutes at scale
    t0 = time.perf_counter()
    got_pi = lockstep_peel(inc[:gi], wep[:gi], nodewp[:gi], nvalid[:gi],
                           force="interpret")
    t_pint = time.perf_counter() - t0
    perr = max(
        int(np.abs(g - w[:gi]).max()) for g, w in zip(got_pi, want_p)
    )
    lockstep_peel(inc, wep, nodewp, nvalid, force="jax")  # jit warmup
    t0 = time.perf_counter()
    got_pj = lockstep_peel(inc, wep, nodewp, nvalid, force="jax")
    t_pjax = time.perf_counter() - t0
    perr = max(perr, max(
        int(np.abs(g - w).max()) for g, w in zip(got_pj, want_p)
    ))
    # one peel round: argmin over U + 2 (K, U) contractions per pair
    p_flops = 2.0 * Gp * Kp * Up * Up
    p_bytes = Gp * Kp * Up * 4
    rows.append(dict(
        kernel="lockstep_peel", max_err=f"{perr:.2e}",
        interpret_s=round(t_pint, 3),
        deploy_flops=f"{p_flops:.2e}", deploy_ai=round(p_flops / p_bytes, 2),
        mxu_bound=False,  # one-hot contractions stream VMEM, VPU-bound
    ))
    print(f"  lockstep_peel exact (jax batch {t_pjax * 1e3:.1f}ms)",
          flush=True)

    # flash attention: correctness + roofline terms at deployment scale
    b, h, kh, s, d = 1, 4, 2, 256, 64
    q = jax.random.normal(key, (b, h, s, d), jnp.float32)
    k = jax.random.normal(key, (b, kh, s, d), jnp.float32)
    v = jax.random.normal(key, (b, kh, s, d), jnp.float32)
    t0 = time.perf_counter()
    out = fa_k(q, k, v, causal=True, block_q=64, block_kv=64, interpret=True)
    t_int = time.perf_counter() - t0
    ref = flash_attention_ref(q, k, v, causal=True)
    err = float(jnp.max(jnp.abs(out - ref)))
    # deployment shape: prefill_32k per device (batch 2, 2 heads after TP)
    dep_flops = _attn_flops(2, 2, 32768, 32768, 128) / 2  # causal half
    dep_bytes = 2 * 2 * 32768 * 128 * 2 * 3  # q,k,v bf16 streamed
    rows.append(dict(
        kernel="flash_attention", max_err=f"{err:.2e}",
        interpret_s=round(t_int, 2),
        deploy_flops=f"{dep_flops:.2e}", deploy_ai=round(dep_flops / dep_bytes, 1),
        mxu_bound=dep_flops / dep_bytes > 240,
    ))

    # decode attention
    t = 512
    q1 = jax.random.normal(key, (2, 4, 64), jnp.float32)
    k1 = jax.random.normal(key, (2, 2, t, 64), jnp.float32)
    v1 = jax.random.normal(key, (2, 2, t, 64), jnp.float32)
    kv_pos = jnp.broadcast_to(jnp.arange(t)[None], (2, t)).astype(jnp.int32)
    q_pos = jnp.full((2,), t - 1, jnp.int32)
    t0 = time.perf_counter()
    out = dec_k(q1, k1, v1, kv_pos, q_pos, block_kv=128, interpret=True)
    t_int = time.perf_counter() - t0
    err = float(jnp.max(jnp.abs(out - decode_attention_ref(q1, k1, v1, kv_pos,
                                                           q_pos))))
    dep_flops = _attn_flops(8, 2, 1, 32768, 128)
    dep_bytes = 8 * 2 * 32768 * 128 * 2 * 2  # stream k,v bf16
    rows.append(dict(
        kernel="decode_attention", max_err=f"{err:.2e}",
        interpret_s=round(t_int, 2),
        deploy_flops=f"{dep_flops:.2e}", deploy_ai=round(dep_flops / dep_bytes, 2),
        mxu_bound=False,  # decode is HBM-bound by construction
    ))

    # ssd scan
    bs, ss, hh, p, n = 1, 128, 2, 16, 16
    x = jax.random.normal(key, (bs, ss, hh, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(key, (bs, ss, hh)))
    a = -jnp.exp(jax.random.normal(key, (hh,)) * 0.3)
    bm = jax.random.normal(key, (bs, ss, n)) * 0.3
    cm = jax.random.normal(key, (bs, ss, n)) * 0.3
    t0 = time.perf_counter()
    out = ssd_k(x, dt, a, bm, cm, chunk=32, interpret=True)
    t_int = time.perf_counter() - t0
    err = float(jnp.max(jnp.abs(out - ssd_scan_ref(x, dt, a, bm, cm))))
    L = 256
    dep_flops = 2.0 * (L * L * 128 + 2 * L * 128 * 64 * 80)  # per chunk/head grp
    rows.append(dict(
        kernel="ssd_scan", max_err=f"{err:.2e}", interpret_s=round(t_int, 2),
        deploy_flops=f"{dep_flops:.2e}", deploy_ai="chunked-matmul",
        mxu_bound=True,
    ))
    emit_csv("kernel_bench", rows)
    worst = max(float(r["max_err"]) for r in rows)
    assert worst < 5e-3, f"kernel/oracle divergence {worst}"
    return rows


if __name__ == "__main__":
    run()
