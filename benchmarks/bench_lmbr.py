"""LMBR move-engine benchmark: pure-Python reference peel (the retained
Algorithm 5 oracle, gain cache off) vs the vectorized engine (batched
lockstep peel + epoch-keyed gain cache, the default since PR 3).

Three tiers:

  * fig6-quick — the paper's Random dataset at fig. 6 defaults (bounded
    move budget so the quick gate stays cheap);
  * fig9-quick — the ibm01-like ISPD98 circuit at fig. 9 settings;
  * lmbr-stress — the larger tier (``repro.core.lmbr_stress_workload``)
    the pre-vectorization engine could not finish interactively.  The
    reference runs under a wall-clock budget; blowing it marks the row
    ``infeasible`` and reports the budget as a lower bound.

On the two quick tiers the placements of both engines are asserted
BIT-IDENTICAL (same membership matrix, hence same spans) — the perf rows
are only emitted if exactness holds.  Emits
benchmarks/results/BENCH_lmbr.json; see benchmarks/README.md for the row
schema.

Methodology: every engine starts from the SAME precomputed balanced
HPA assignment (lmbr's own Algorithm-4 warm start, built once per tier and
passed via ``initial=``), and the accelerated span backend is imported
before the first timing — so the rows compare pure move-engine work, not
who pays the partitioner memo or the one-time jax import.
"""

from __future__ import annotations

import signal
import threading
import time

import numpy as np

from repro import flags
from repro.core import (
    ALGORITHMS,
    LMBR_STRESS_DEFAULTS,
    Placement,
    hpa_partition,
    ispd_like_workload,
    lmbr_stress_workload,
    random_workload,
    spans_for_workload,
)

from .common import emit_csv, save_json

# reference wall-clock budget on the stress tier (seconds)
REF_BUDGET_QUICK = 60.0
REF_BUDGET_FULL = 600.0


class _Timeout(Exception):
    pass


def _run_with_budget(fn, budget: float):
    """Run fn() under a SIGALRM budget (main thread only; without signal
    support the budget is not enforced and the call just runs)."""
    if threading.current_thread() is not threading.main_thread():
        return fn(), False

    def _raise(signum, frame):
        raise _Timeout()

    old = signal.signal(signal.SIGALRM, _raise)
    signal.setitimer(signal.ITIMER_REAL, budget)
    done: list = []  # survives a _Timeout that lands after fn() finished
    try:
        done.append(fn())
    except _Timeout:
        pass
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0)
        signal.signal(signal.SIGALRM, old)
    if done:
        return done[0], False
    return None, True


def _warm_start(hg, n, capacity) -> Placement:
    """lmbr's own Algorithm-4 balanced start, built once per tier so every
    engine times pure move-engine work from an identical placement."""
    bal_cap = min(
        capacity,
        hg.total_node_weight() / n * 1.1 + float(hg.node_weights.max()),
    )
    assign = hpa_partition(hg, n, bal_cap, seed=0, nruns=2)
    pl = Placement.empty(n, hg.num_nodes, capacity, hg.node_weights)
    pl.member[assign, np.arange(hg.num_nodes)] = True
    return pl


def _time_engine(hg, n, capacity, max_moves, initial, variant: str,
                 budget=None):
    """Fit LMBR under a flags variant; returns (placement, seconds, timed_out)."""
    flags.set_variant(variant)
    try:
        t0 = time.perf_counter()
        if budget is None:
            pl = ALGORITHMS["lmbr"](hg, n, capacity, seed=0,
                                    max_moves=max_moves, initial=initial)
            timed_out = False
        else:
            pl, timed_out = _run_with_budget(
                lambda: ALGORITHMS["lmbr"](
                    hg, n, capacity, seed=0, max_moves=max_moves,
                    initial=initial,
                ),
                budget,
            )
        dt = time.perf_counter() - t0
    finally:
        flags.reset()
    return pl, dt, timed_out


def _tier_rows(tier, hg, n, capacity, max_moves, ref_budget=None):
    rows = []
    initial = _warm_start(hg, n, capacity)
    vec_pl, t_vec, _ = _time_engine(
        hg, n, capacity, max_moves, initial, "baseline"
    )
    ref_pl, t_ref, ref_out = _time_engine(
        hg, n, capacity, max_moves, initial, "peelreference+lmbrcache0",
        budget=ref_budget,
    )
    identical = None
    if ref_pl is not None:
        identical = bool((ref_pl.member == vec_pl.member).all())
        if not identical:  # hard gate, -O-proof: never emit diverged rows
            raise AssertionError(
                f"{tier}: vectorized LMBR diverged from reference"
            )
    avg_span = round(float(spans_for_workload(hg, vec_pl).mean()), 4)
    stats = vec_pl.stats or {}
    ref_stats = (ref_pl.stats or {}) if ref_pl is not None else {}
    rows.append(dict(
        tier=tier, engine="reference-peel",
        seconds=round(t_ref, 2), speedup=1.0,
        infeasible=bool(ref_out), identical=identical,
        # a timed-out reference produced no placement: report nothing for it
        avg_span=avg_span if ref_pl is not None else None,
        moves=ref_stats.get("moves"), gain_calls=None, cache_hits=None,
    ))
    base = dict(
        tier=tier, infeasible=False, identical=identical, avg_span=avg_span,
        moves=stats.get("moves"), gain_calls=stats.get("gain_calls"),
    )
    rows.append(dict(
        base, engine="vectorized", seconds=round(t_vec, 2),
        speedup=round(t_ref / max(t_vec, 1e-9), 1),
        cache_hits=stats.get("gain_cache_hits"),
    ))
    # cache ablation: vectorized peel, epoch cache off
    nc_pl, t_nc, _ = _time_engine(
        hg, n, capacity, max_moves, initial, "lmbrcache0"
    )
    if not (nc_pl.member == vec_pl.member).all():
        raise AssertionError(f"{tier}: gain cache changed the placement")
    rows.append(dict(
        base, engine="vectorized-nocache", seconds=round(t_nc, 2),
        speedup=round(t_ref / max(t_nc, 1e-9), 1), cache_hits=0,
    ))
    # size-dispatched hybrid: tiny peels -> reference, the rest batched
    # (recovers the reference's edge on sparse near-span-1 tiers)
    au_pl, t_au, _ = _time_engine(
        hg, n, capacity, max_moves, initial, "peelauto"
    )
    if not (au_pl.member == vec_pl.member).all():
        raise AssertionError(f"{tier}: peelauto changed the placement")
    rows.append(dict(
        base, engine="vectorized-auto", seconds=round(t_au, 2),
        speedup=round(t_ref / max(t_au, 1e-9), 1),
        cache_hits=(au_pl.stats or {}).get("gain_cache_hits"),
    ))
    for r in rows:
        print(f"  {r}", flush=True)
    return rows


def run(quick: bool = True) -> list[dict]:
    from repro.core.setcover import _accel_backend

    _accel_backend()  # pay the one-time jax import outside the timings
    rows = []
    # fig6 quick tier: paper Random defaults, bounded move budget
    wl = random_workload(1000, 4000, 3, 11, 20, seed=0)
    rows += _tier_rows("fig6-quick", wl.hypergraph, 40, 50,
                       max_moves=120 if quick else 300)
    # fig9 quick tier: ibm01-like circuit at fig. 9 settings
    wl = ispd_like_workload(num_nodes=12752, seed=0)
    capacity = int(np.ceil(12752 / 20))
    rows += _tier_rows("fig9-quick", wl.hypergraph, 35, capacity,
                       max_moves=60 if quick else 150)
    # stress tier: reference under a wall-clock budget
    wl = lmbr_stress_workload()
    rows += _tier_rows(
        "lmbr-stress", wl.hypergraph,
        LMBR_STRESS_DEFAULTS["num_partitions"],
        LMBR_STRESS_DEFAULTS["capacity"],
        max_moves=LMBR_STRESS_DEFAULTS["max_moves"],
        ref_budget=REF_BUDGET_QUICK if quick else REF_BUDGET_FULL,
    )
    emit_csv("bench_lmbr", rows,
             ["tier", "engine", "seconds", "speedup", "infeasible",
              "identical", "avg_span", "moves", "gain_calls", "cache_hits"])
    save_json("BENCH_lmbr", rows)
    return rows


if __name__ == "__main__":
    run(quick=True)
