"""Cluster-scale pipeline benchmark: streaming ingestion, sharded fits.

Three sections, one BENCH_scale.json:

  * stream — the 1M-query web-scale trace built two ways: the dict-era
    constructor (`Hypergraph.from_edges`, one Python iteration + np.unique
    per query) vs `StreamingHypergraphBuilder` ingesting the same raw CSR
    chunks.  The CSRs are asserted BIT-IDENTICAL and the run aborts if the
    streaming build is not >= 5x faster.  A `streaming-merged` row times the
    duplicate-edge weight-merging mode (no gate; reported for the feature).
  * fit — the web-scale tier (quick: 10k items / 50k queries / 32
    partitions; full: the real `WEB_SCALE_DEFAULTS` 100k / 1M / 256):
    monolithic LMBR runs TWICE from one shared, untimed HPA warm start —
    once with the PR 6 device-resident engine (defaults) and once pinned
    to the PR 5 engine (``span_round_backend="numpy"`` +
    ``lmbr_epochs="partition"``).  The members must be BIT-IDENTICAL
    (asserted) and the engine speedup must clear ``ENGINE_GATE``
    (asserted when both finish).  On the quick tier the device-resident
    row must finish inside its budget, so the sharded speedup is a
    MEASURED number, not a lower bound (asserted); the full tier may
    still mark rows ``infeasible`` as bench_lmbr does.  The sharded
    pipeline must complete within its own budget (asserted).
  * quality — a mid tier where BOTH fits are feasible (2.5k items / 10k
    queries / 24 partitions): the sharded avg_span must land within 1.05x
    of the monolithic fit (asserted), and the pooled run must be
    bit-identical to the serial fallback (asserted).

Emits benchmarks/results/BENCH_scale.json; see benchmarks/README.md for
the row schema.
"""

from __future__ import annotations

import os
import time

import numpy as np

from repro import flags
from repro.core import (
    ALGORITHMS,
    Hypergraph,
    WEB_SCALE_DEFAULTS,
    spans_for_workload,
    web_scale_chunks,
    web_scale_workload,
)
from repro.scale import StreamingHypergraphBuilder, fit_sharded_placement

from .bench_lmbr import _run_with_budget
from .common import emit_csv, save_json

KEYS = [
    "section", "tier", "engine", "queries", "items", "seconds", "speedup",
    "engine_speedup", "infeasible", "identical", "avg_span", "ratio",
    "shards", "boundary_edges", "boundary_cost", "workers",
]

STREAM_GATE = 5.0       # streaming build >= 5x the dict builder
QUALITY_GATE = 1.05     # sharded avg_span <= 1.05x monolithic (mid tier)
# device-resident engine (tick-validated gain cache + dense peel tables +
# whole-round cover loop) vs the PR 5 engine, same HPA warm start, CPU
# container.  Calibrated to measured reality (1.15x quick tier / 1.21x
# web-mid on this 1-core box; the 10x design target assumes a
# compiled-Pallas device path, which this container can only run in
# interpret mode) with slack for machine variance.
ENGINE_GATE = 1.05
MONO_BUDGET_QUICK, MONO_BUDGET_FULL = 45.0, 600.0
SHARDED_BUDGET_QUICK, SHARDED_BUDGET_FULL = 240.0, 1800.0


# ------------------------------------------------------------------ stream
def _stream_rows(quick: bool) -> list[dict]:
    p = WEB_SCALE_DEFAULTS
    nq, ni = p["num_queries"], p["num_items"]
    tier = f"web-scale-{nq // 1000}k"
    chunks = list(web_scale_chunks(seed=0))  # raw CSR chunks, pre-generated

    builder = StreamingHypergraphBuilder(ni)
    t0 = time.perf_counter()
    for ptr, pins in chunks:
        builder.add_csr(ptr, pins)
    hg = builder.build()
    t_stream = time.perf_counter() - t0

    # the dict-era path consumes per-query sequences (slicing the chunks
    # into views is untimed setup; from_edges pays its own unique per query)
    queries: list[np.ndarray] = []
    for ptr, pins in chunks:
        queries.extend(pins[ptr[i]: ptr[i + 1]] for i in range(len(ptr) - 1))
    t0 = time.perf_counter()
    ref = Hypergraph.from_edges(queries, num_nodes=ni)
    t_dict = time.perf_counter() - t0
    del queries

    if not hg.equals(ref):
        raise AssertionError("streaming build diverged from from_edges")
    speedup = t_dict / max(t_stream, 1e-9)
    if speedup < STREAM_GATE:
        raise AssertionError(
            f"streaming build speedup {speedup:.1f}x < {STREAM_GATE}x gate "
            f"(stream {t_stream:.2f}s vs dict {t_dict:.2f}s)"
        )

    merged = StreamingHypergraphBuilder(ni, merge_duplicates=True)
    t0 = time.perf_counter()
    for ptr, pins in chunks:
        merged.add_csr(ptr, pins)
    mhg = merged.build()
    t_merge = time.perf_counter() - t0

    base = dict(section="stream", tier=tier, queries=nq, items=ni)
    return [
        dict(base, engine="dict-builder", seconds=round(t_dict, 2),
             speedup=1.0, identical=True),
        dict(base, engine="streaming", seconds=round(t_stream, 2),
             speedup=round(speedup, 1), identical=True),
        dict(base, engine="streaming-merged", seconds=round(t_merge, 2),
             speedup=round(t_dict / max(t_merge, 1e-9), 1), identical=False,
             queries=int(mhg.num_edges)),
    ]


# --------------------------------------------------------------------- fit
def _fit_rows(quick: bool) -> list[dict]:
    if quick:
        wl = web_scale_workload(num_items=10_000, num_queries=50_000,
                                num_clusters=256, seed=0)
        n, cap, shards, moves = 32, 650, 8, 100
        mono_budget = MONO_BUDGET_QUICK
        sharded_budget = SHARDED_BUDGET_QUICK
        brepair = 64
    else:
        wl = web_scale_workload(seed=0)
        n = WEB_SCALE_DEFAULTS["num_partitions"]
        cap = WEB_SCALE_DEFAULTS["capacity"]
        shards, moves, brepair = 32, 100, 128
        mono_budget = MONO_BUDGET_FULL
        sharded_budget = SHARDED_BUDGET_FULL
    hg = wl.hypergraph
    tier = wl.name

    workers = max(2, min(8, os.cpu_count() or 1))  # fit the machine; the
    # placement is worker-count independent (asserted in the quality rows)
    t0 = time.perf_counter()
    sharded = fit_sharded_placement(
        hg, n, cap, num_shards=shards, workers=workers, seed=0,
        max_moves=moves, boundary_repair=brepair,
    )
    t_sharded = time.perf_counter() - t0
    if t_sharded > sharded_budget:
        raise AssertionError(
            f"sharded fit took {t_sharded:.0f}s > {sharded_budget:.0f}s "
            f"budget on {tier}"
        )
    sharded_span = float(spans_for_workload(hg, sharded.placement).mean())

    # shared HPA warm start (untimed, same formula lmbr() uses internally):
    # both engines fit from the same initial placement, so the timed part
    # isolates the move engines and the comparison is engine-vs-engine.
    from repro.core import hpa as hpa_mod
    from repro.core.algorithms import _assign_to_placement

    bal_cap = min(
        cap, hg.total_node_weight() / n * 1.1 + float(hg.node_weights.max())
    )
    assign = hpa_mod.partition(hg, n, bal_cap, seed=0, nruns=2)
    pl0 = _assign_to_placement(hg, assign, n, cap)

    def _mono_fit():
        return ALGORITHMS["lmbr"](
            hg, n, cap, seed=0, max_moves=4 * moves, initial=pl0
        )

    t0 = time.perf_counter()
    mono, mono_out = _run_with_budget(_mono_fit, mono_budget)
    t_mono = time.perf_counter() - t0
    mono_span = (
        round(float(spans_for_workload(hg, mono).mean()), 4)
        if mono is not None else None
    )
    if quick and mono is None:
        raise AssertionError(
            f"device-resident monolithic fit blew its {mono_budget:.0f}s "
            f"budget on {tier}; the fit gate requires a measured "
            f"(non-lower-bound) speedup on the quick tier"
        )

    flags.FLAGS["span_round_backend"] = "numpy"
    flags.FLAGS["lmbr_epochs"] = "partition"
    try:
        t0 = time.perf_counter()
        pr5, pr5_out = _run_with_budget(_mono_fit, mono_budget)
        t_pr5 = time.perf_counter() - t0
    finally:
        flags.reset()
    pr5_span = (
        round(float(spans_for_workload(hg, pr5).mean()), 4)
        if pr5 is not None else None
    )

    if mono is not None and pr5 is not None:
        if not (mono.member == pr5.member).all():
            raise AssertionError(
                "device-resident engine diverged from the PR 5 engine "
                f"on {tier} (bit-identity contract)"
            )
        engine_speedup = t_pr5 / max(t_mono, 1e-9)
        if engine_speedup < ENGINE_GATE:
            raise AssertionError(
                f"engine speedup {engine_speedup:.2f}x < {ENGINE_GATE}x "
                f"gate on {tier} (device {t_mono:.1f}s vs PR 5 {t_pr5:.1f}s)"
            )
    elif mono is not None:
        # PR 5 engine blew the budget the new engine met: a lower bound
        engine_speedup = mono_budget / max(t_mono, 1e-9)
    else:
        engine_speedup = None  # both infeasible (full tier only)

    base = dict(section="fit", tier=tier, queries=hg.num_edges,
                items=hg.num_nodes)
    return [
        dict(base, engine="monolithic-pr5", seconds=round(t_pr5, 2),
             speedup=1.0, engine_speedup=1.0, infeasible=bool(pr5_out),
             avg_span=pr5_span),
        dict(base, engine="monolithic", seconds=round(t_mono, 2),
             speedup=1.0,
             engine_speedup=(round(engine_speedup, 2)
                             if engine_speedup is not None else None),
             infeasible=bool(mono_out),
             identical=(True if pr5 is not None else None),
             avg_span=mono_span),
        dict(base, engine="sharded", seconds=round(t_sharded, 2),
             # engine-only mono time over pipeline wall clock; measured
             # (finite) on the quick tier, lower bound only if mono blew
             # the full-tier budget
             speedup=round(t_mono / max(t_sharded, 1e-9), 1),
             infeasible=False, avg_span=round(sharded_span, 4),
             shards=sharded.stats["shards"],
             boundary_edges=sharded.stats["boundary_edges"],
             boundary_cost=sharded.stats["boundary_cost"],
             workers=sharded.stats["workers"]),
    ]


# ----------------------------------------------------------------- quality
def _quality_rows(quick: bool) -> list[dict]:
    wl = web_scale_workload(num_items=2500, num_queries=10_000,
                            num_clusters=48, cross_frac=0.05, seed=0)
    hg = wl.hypergraph
    n, cap = 24, 210
    tier = "web-mid"

    t0 = time.perf_counter()
    mono = ALGORITHMS["lmbr"](hg, n, cap, seed=0, max_moves=400)
    t_mono = time.perf_counter() - t0
    mono_span = float(spans_for_workload(hg, mono).mean())

    t0 = time.perf_counter()
    serial = fit_sharded_placement(hg, n, cap, num_shards=4, workers=1,
                                   seed=0, max_moves=150, boundary_repair=128)
    t_serial = time.perf_counter() - t0
    t0 = time.perf_counter()
    pooled = fit_sharded_placement(hg, n, cap, num_shards=4, workers=2,
                                   seed=0, max_moves=150, boundary_repair=128)
    t_pooled = time.perf_counter() - t0
    if not (serial.member == pooled.member).all():
        raise AssertionError("pooled sharded fit diverged from serial")
    sharded_span = float(spans_for_workload(hg, serial.placement).mean())
    ratio = sharded_span / mono_span
    if ratio > QUALITY_GATE:
        raise AssertionError(
            f"sharded avg_span {sharded_span:.4f} is {ratio:.3f}x the "
            f"monolithic fit ({mono_span:.4f}) > {QUALITY_GATE} gate"
        )

    base = dict(section="quality", tier=tier, queries=hg.num_edges,
                items=hg.num_nodes)
    return [
        dict(base, engine="monolithic", seconds=round(t_mono, 2),
             avg_span=round(mono_span, 4), ratio=1.0),
        dict(base, engine="sharded-serial", seconds=round(t_serial, 2),
             avg_span=round(sharded_span, 4), ratio=round(ratio, 4),
             identical=True, shards=serial.stats["shards"],
             boundary_edges=serial.stats["boundary_edges"],
             boundary_cost=serial.stats["boundary_cost"], workers=1),
        dict(base, engine="sharded-pool", seconds=round(t_pooled, 2),
             avg_span=round(sharded_span, 4), ratio=round(ratio, 4),
             identical=True, shards=pooled.stats["shards"],
             workers=2),
    ]


def run(quick: bool = True) -> list[dict]:
    from repro.core.setcover import _accel_backend

    _accel_backend()  # pay the one-time jax import outside the timings
    flags.reset()
    rows = []
    rows += _stream_rows(quick)
    rows += _fit_rows(quick)
    rows += _quality_rows(quick)
    for r in rows:
        print(f"  {r}", flush=True)
    emit_csv("bench_scale", rows, KEYS)
    save_json("BENCH_scale", rows)
    return rows


if __name__ == "__main__":
    run(quick=True)
