"""Paper fig. 9: ISPD98-like circuit hypergraphs.

The paper runs ibm01..ibm10 (12,752..69,429 nodes, density ~1) with capacity
set so N_e = 20, and plots average span at 35 partitions.  The ISPD98 files
are not redistributable offline, so we generate structurally matched
hypergraphs (same node counts, density ~1.1, circuit-like pin distribution);
see DESIGN.md §8.

Quick mode runs the first 4 sizes; --full runs all 10.
"""

from __future__ import annotations

import numpy as np

from repro.core import ALGORITHMS, Simulator, ispd_like_workload

from .common import Timer, emit_csv

# ibm01..ibm10 node counts from the ISPD98 suite
IBM_SIZES = [12752, 19601, 23136, 27507, 29347, 32498, 45926, 51309, 53395, 69429]
ALGOS = ["random", "hpa", "ihpa", "pra", "ds", "lmbr"]


def run(quick: bool = True) -> list[dict]:
    sizes = IBM_SIZES[:4] if quick else IBM_SIZES
    out = []
    for i, n_nodes in enumerate(sizes):
        wl = ispd_like_workload(num_nodes=n_nodes, seed=i)
        hg = wl.hypergraph
        capacity = int(np.ceil(n_nodes / 20))  # exactly 20 partitions suffice
        sim = Simulator(num_partitions=35, capacity=capacity)
        for name in ALGOS:
            kw = dict(seed=0)
            if name == "lmbr":
                kw["max_moves"] = 600  # bounded for wall-time; paper notes
                # LMBR's high runtime on these inputs
            with Timer() as t:
                res = sim.run(hg, ALGORITHMS[name], name=name, **kw)
            out.append(dict(
                circuit=f"ibm{i+1:02d}-like", nodes=n_nodes,
                algorithm=name, avg_span=round(res.avg_span, 4),
                place_seconds=round(t.seconds, 2),
            ))
            print(f"  {out[-1]}", flush=True)
    emit_csv("fig9_ispd", out,
             ["circuit", "nodes", "algorithm", "avg_span", "place_seconds"])
    return out


if __name__ == "__main__":
    run(quick=True)
