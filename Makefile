# Shared gates for every PR: run the same commands CI / the next session runs.
PY := PYTHONPATH=src python

.PHONY: test test-fast test-migration bench-smoke bench ci docs-check

# tier-1 verify (ROADMAP contract) — fully green since PR 2 fixed the
# seed's jax/pallas API drift; keep it that way.
test:
	$(PY) -m pytest -x -q

# the PR gate: fast tests + cheap engine perf signals + honest docs
ci: test-fast bench-smoke docs-check

# README/ARCHITECTURE/benchmarks docs: snippets run, commands and flag
# names exist (tools/docs_check.py); the obs_report CLI renders the
# committed tiny fixture so the report path can't rot
docs-check:
	$(PY) tools/docs_check.py
	$(PY) tools/obs_report.py tools/fixtures/tiny_trace.jsonl --prom tools/fixtures/tiny_prom.txt > /dev/null

# skip the slow end-to-end train/distribution tests
test-fast:
	$(PY) -m pytest -x -q -m "not slow"

# the migration invariant suite under BOTH sharded-fit paths: serial
# (--scale-workers 1) and the process pool (--scale-workers 2); the
# sharded-target test parametrizes over the worker counts
test-migration:
	$(PY) -m pytest -x -q tests/test_migration.py --scale-workers 1
	$(PY) -m pytest -x -q tests/test_migration.py --scale-workers 2

# cheap perf signal: span engine + LMBR move engine + online serving +
# live migration + cluster-scale pipeline + heterogeneous-cluster gates
# (BENCH_spans.json, BENCH_lmbr.json, BENCH_online.json,
# BENCH_migration.json, BENCH_scale.json, BENCH_energy.json); the JSONs
# are copied to the repo root as the committed baselines (results/ is
# gitignored scratch)
bench-smoke:
	$(PY) -m benchmarks.run --only bench_spans,bench_lmbr,bench_online,bench_migration,bench_scale,bench_energy,bench_obs
	cp benchmarks/results/BENCH_*.json .

# full quick benchmark suite (all paper figures, single seed)
bench:
	$(PY) -m benchmarks.run
