# Shared gates for every PR: run the same commands CI / the next session runs.
PY := PYTHONPATH=src python

.PHONY: test test-fast bench-smoke bench

# tier-1 verify (ROADMAP contract).  NB: currently red on pre-existing
# jax/pallas API drift in tests/test_kernels.py (failing since the seed);
# the gate is "no worse than the previous PR", not "green".
test:
	$(PY) -m pytest -x -q

# skip the slow end-to-end train/distribution tests
test-fast:
	$(PY) -m pytest -x -q -m "not slow"

# cheap perf signal: span engine old-vs-new timings (BENCH_spans.json)
bench-smoke:
	$(PY) -m benchmarks.run --only bench_spans

# full quick benchmark suite (all paper figures, single seed)
bench:
	$(PY) -m benchmarks.run
