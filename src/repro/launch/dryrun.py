import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh without allocating a single parameter.

  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--quick]

Per cell this records: compile success, per-device memory analysis (proves
the layout fits HBM), cost_analysis FLOPs/bytes, per-collective payload
bytes, and the derived roofline terms (launch/roofline.py).  Results append
to experiments/dryrun/<cell>.json which EXPERIMENTS.md and
benchmarks/roofline_table.py read.

The 512 virtual host devices exist ONLY here (first two lines above) — tests
and benchmarks see the real single-device CPU.
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import SHAPE_GRID, get_config, list_configs  # noqa: E402
from repro.launch import roofline as rf  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import (  # noqa: E402
    input_specs, make_prefill_step, make_serve_step, make_train_step,
    opt_struct, param_struct, pick_optimizer, serve_cache_struct,
    shape_skip_reason,
)
from repro.models import identity_dispatch  # noqa: E402
from repro.optim.optimizers import make_optimizer  # noqa: E402
from repro.parallel import batch_shardings, cache_shardings, param_shardings  # noqa: E402

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")
OUT_DIR = os.path.abspath(OUT_DIR)


def _mem_dict(mem) -> dict:
    keys = [
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ]
    out = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             chunk: int = 512, variant: str = "baseline",
             mesh=None, extra_tag: str = "") -> dict:
    cfg = get_config(arch)
    shape = SHAPE_GRID[shape_name]
    rec = dict(arch=arch, shape=shape_name,
               mesh="2x16x16" if multi_pod else "16x16",
               variant=variant, kind=shape.kind)
    skip = shape_skip_reason(cfg, shape)
    if skip:
        rec.update(status="skipped", reason=skip)
        return rec

    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    from repro.parallel import set_active_mesh
    set_active_mesh(mesh)  # enables in-model activation sharding pins
    chips = int(np.prod(list(mesh.shape.values())))
    ep_ranks = mesh.shape["model"]
    dispatch = identity_dispatch(cfg.moe.num_experts, ep_ranks) if cfg.moe \
        else None

    t0 = time.time()
    try:
        pstruct = param_struct(cfg, moe_dispatch=dispatch)
        pshard = param_shardings(pstruct, mesh)
        specs = input_specs(cfg, shape)
        if shape.kind == "train":
            step, opt = make_train_step(cfg, moe_dispatch=dispatch,
                                        chunk=chunk)
            ostruct = opt_struct(cfg, opt, pstruct)
            oshard = param_shardings(ostruct, mesh)
            bshard = batch_shardings(specs["batch"], mesh)
            fn = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                         donate_argnums=(0, 1))
            args = (pstruct, ostruct, specs["batch"])
            rec["optimizer"] = pick_optimizer(cfg)
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, moe_dispatch=dispatch, chunk=chunk)
            bshard = batch_shardings(specs["batch"], mesh)
            fn = jax.jit(step, in_shardings=(pshard, bshard))
            args = (pstruct, specs["batch"])
        else:  # decode
            window_only = shape.name == "long_500k"
            step = make_serve_step(cfg, moe_dispatch=dispatch, chunk=chunk)
            cstruct = serve_cache_struct(cfg, shape.global_batch,
                                         shape.seq_len,
                                         window_only=window_only)
            cshard = cache_shardings(cstruct, mesh)
            tshard = batch_shardings(
                {"tokens": specs["tokens"], "positions": specs["positions"]},
                mesh,
            )
            fn = jax.jit(
                step,
                in_shardings=(pshard, cshard, tshard["tokens"],
                              tshard["positions"]),
                donate_argnums=(1,),
            )
            args = (pstruct, cstruct, specs["tokens"], specs["positions"])

        with mesh:
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        colls = rf.collective_stats(hlo)
        coll_bytes = sum(v["bytes"] for v in colls.values())
        flops = float(cost.get("flops", 0.0))
        bytes_accessed = float(cost.get("bytes accessed", 0.0))
        terms = rf.roofline(flops, bytes_accessed, coll_bytes, chips)
        mflops = rf.model_flops(cfg, shape, shape.kind == "train")
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            memory=_mem_dict(mem),
            flops_per_device=flops,
            bytes_per_device=bytes_accessed,
            collective_bytes_per_device=coll_bytes,
            collectives={k: v for k, v in colls.items() if v["count"]},
            roofline=terms,
            model_flops_global=mflops,
            useful_flops_ratio=(
                round(mflops / (flops * chips), 4) if flops else None
            ),
            hlo_lines=hlo.count("\n"),
        )
    except Exception as exc:
        rec.update(status="error", error=f"{type(exc).__name__}: {exc}",
                   traceback=traceback.format_exc()[-2000:])
    return rec


def save_record(rec: dict) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    tag = f"{rec['arch']}__{rec['shape']}__{rec['mesh'].replace('x','_')}"
    if rec.get("variant", "baseline") != "baseline":
        tag += f"__{rec['variant']}"
    path = os.path.join(OUT_DIR, tag + ".json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None,
                    choices=list(SHAPE_GRID) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape) cell on this mesh")
    ap.add_argument("--chunk", type=int, default=512)
    ap.add_argument("--variant", type=str, default="baseline")
    ap.add_argument("--skip-existing", action="store_true",
                    help="skip cells whose record file already exists")
    args = ap.parse_args()

    from repro.flags import set_variant
    set_variant(args.variant if args.variant != "baseline" else "")

    cells = []
    if args.all:
        for arch in list_configs():
            for shape in SHAPE_GRID:
                cells.append((arch, shape))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    ok = True
    for arch, shape in cells:
        if args.skip_existing:
            mesh_tag = "2_16_16" if args.multi_pod else "16_16"
            tag = f"{arch}__{shape}__{mesh_tag}"
            if args.variant != "baseline":
                tag += f"__{args.variant}"
            if os.path.exists(os.path.join(OUT_DIR, tag + ".json")):
                print(f"[cached ] {arch:22s} {shape:12s}", flush=True)
                continue
        rec = run_cell(arch, shape, args.multi_pod, chunk=args.chunk,
                       variant=args.variant, mesh=mesh)
        path = save_record(rec)
        status = rec["status"]
        extra = ""
        if status == "ok":
            r = rec["roofline"]
            extra = (f"dominant={r['dominant']} "
                     f"bound={r['step_lower_bound_s']:.3f}s "
                     f"frac={r['roofline_fraction']:.2f} "
                     f"compile={rec['compile_s']}s")
            print(json.dumps(rec["memory"]))
            print(json.dumps({k: v for k, v in rec["collectives"].items()}))
        elif status == "error":
            ok = False
            extra = rec["error"]
        else:
            extra = rec["reason"][:60]
        print(f"[{status:7s}] {arch:22s} {shape:12s} {rec['mesh']:8s} {extra}",
              flush=True)
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
