"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --steps 100 \
      --reduced --batch 8 --seq 128 [--devices 8 --mesh 2x4]

On this CPU container --reduced trains a smoke-sized variant of the chosen
architecture for real (loss goes down); on a TPU fleet the same driver with
the production mesh and full config is the deployment path.  Integrates the
full substrate: placement-aware input pipeline, fault-tolerant runner with
checkpoint/restart, straggler avoidance, optional int8 cross-pod gradient
compression (--grad-compression), MoE expert placement refresh.
"""

from __future__ import annotations

import argparse
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-sized config (CPU-friendly)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--devices", type=int, default=0,
                    help="virtual host devices (0 = real devices)")
    ap.add_argument("--mesh", type=str, default="",
                    help="'DxM' data x model (default: all devices on data)")
    ap.add_argument("--ckpt-dir", type=str, default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--num-shards", type=int, default=64)
    ap.add_argument("--num-hosts", type=int, default=8)
    ap.add_argument("--inject-failures", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}"
        )
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.checkpoint import CheckpointManager
    from repro.configs import get_config, reduce_config
    from repro.data import PlacementAwarePipeline
    from repro.launch.steps import make_train_step
    from repro.models import identity_dispatch, init_params
    from repro.optim.optimizers import make_optimizer
    from repro.parallel import (batch_shardings, param_shardings,
                                set_active_mesh)
    from repro.runtime import FaultTolerantRunner

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg, dtype="float32")

    mesh = None
    if args.mesh:
        d, m = map(int, args.mesh.split("x"))
        mesh = jax.make_mesh((d, m), ("data", "model"))
        set_active_mesh(mesh)

    dispatch = None
    if cfg.moe:
        ranks = mesh.shape["model"] if mesh else 1
        dispatch = identity_dispatch(cfg.moe.num_experts, ranks)

    opt = make_optimizer("adamw", args.lr)
    step_fn, _ = make_train_step(cfg, optimizer=opt, moe_dispatch=dispatch,
                                 chunk=max(32, args.seq // 4))
    params = init_params(cfg, jax.random.PRNGKey(0), moe_dispatch=dispatch)
    opt_state = opt.init(params)

    if mesh is not None:
        pshard = param_shardings(jax.eval_shape(lambda: params), mesh)
        oshard = param_shardings(jax.eval_shape(lambda: opt_state), mesh)
        params = jax.device_put(params, pshard)
        opt_state = jax.device_put(opt_state, oshard)
        jit_step = jax.jit(step_fn, in_shardings=(pshard, oshard, None),
                           donate_argnums=(0, 1))
    else:
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    pipeline = PlacementAwarePipeline(
        num_shards=args.num_shards, num_hosts=args.num_hosts,
        vocab_size=cfg.vocab_size, batch_size=args.batch, seq_len=args.seq,
    )
    ckpt = CheckpointManager(args.ckpt_dir, keep=2, async_save=True)

    metrics_log = []

    def run_step(state, batch):
        p, o = state
        dev_batch = {
            "tokens": jnp.asarray(batch["tokens"]),
            "targets": jnp.asarray(batch["targets"]),
        }
        if cfg.frontend:
            dev_batch["frontend"] = jnp.zeros(
                (args.batch, cfg.frontend_len, cfg.d_model), jnp.float32
            )
        p, o, metrics = jit_step(p, o, dev_batch)
        metrics_log.append(float(metrics["loss"]))
        return (p, o), metrics

    runner = FaultTolerantRunner(
        run_step, (params, opt_state), pipeline, ckpt,
        ckpt_every=args.ckpt_every,
    )
    if args.inject_failures:
        runner.kill_input_host(0)

    t0 = time.time()
    result = runner.run(args.steps)
    dt = time.time() - t0
    first = np.mean(metrics_log[:5]) if metrics_log else float("nan")
    last = np.mean(metrics_log[-5:]) if metrics_log else float("nan")
    print(f"steps={result['steps']} restarts={result['restarts']} "
          f"avg_input_span={result['avg_input_span']:.2f} "
          f"idle_hosts={pipeline.idle_host_fraction():.2f}")
    print(f"loss: first5={first:.4f} last5={last:.4f} "
          f"({'improved' if last < first else 'NOT improved'}) "
          f"wall={dt:.1f}s")
    for step, ev in result["events"][:10]:
        print(f"  event@{step}: {ev}")
    return 0 if last < first else 1


if __name__ == "__main__":
    raise SystemExit(main())
