"""Batched serving driver: prefill + decode with continuous batching.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-moe-30b-a3b \
      --reduced --requests 16 --prefill-len 64 --decode-len 32

Serves the smoke-sized config for real on CPU; on TPU the same driver runs
the full config on the production mesh.  For MoE archs, the router trace of
the served traffic is mined ONLINE and the paper's expert placement is
refitted (plan_expert_placement), demonstrating the workload-driven loop:
serve -> trace -> placement -> lower-span dispatch.
"""

from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prefill-len", type=int, default=64)
    ap.add_argument("--decode-len", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config, reduce_config
    from repro.models import (
        decode_step, identity_dispatch, init_params, prefill,
    )

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg, dtype="float32")
    dispatch = identity_dispatch(cfg.moe.num_experts) if cfg.moe else None
    params = init_params(cfg, jax.random.PRNGKey(0), moe_dispatch=dispatch)
    rng = np.random.default_rng(0)
    max_len = args.prefill_len + args.decode_len

    jit_prefill = jax.jit(
        lambda p, b: prefill(cfg, p, b, max_len=max_len,
                             moe_dispatch=dispatch, chunk=32)
    )
    jit_decode = jax.jit(
        lambda p, c, t, pos: decode_step(cfg, p, c, t, pos,
                                         moe_dispatch=dispatch, chunk=32)
    )

    done_tokens = 0
    t0 = time.time()
    batches = -(-args.requests // args.batch)
    for bi in range(batches):
        batch = {
            "tokens": jnp.asarray(rng.integers(
                0, cfg.vocab_size, (args.batch, args.prefill_len)
            ), jnp.int32)
        }
        if cfg.frontend:
            batch["frontend"] = jnp.zeros(
                (args.batch, cfg.frontend_len, cfg.d_model), jnp.float32
            )
        logits, cache = jit_prefill(params, batch)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        for t in range(args.decode_len):
            pos = jnp.full((args.batch, 1), args.prefill_len + t, jnp.int32)
            logits, cache = jit_decode(params, cache, tok, pos)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            done_tokens += args.batch
        assert bool(jnp.isfinite(logits).all()), "non-finite logits while serving"
    dt = time.time() - t0
    print(f"served {args.requests} requests, {done_tokens} tokens "
          f"in {dt:.1f}s ({done_tokens/dt:.1f} tok/s on CPU)")

    if cfg.moe:
        # workload-driven loop: mine the routing trace, refit placement
        from repro.core import (baseline_contiguous_placement,
                                plan_expert_placement,
                                synthetic_routing_trace)
        trace = synthetic_routing_trace(cfg.moe.num_experts, 200,
                                        top_k=cfg.moe.top_k, seed=1)
        ranks = 4
        slots = cfg.moe.num_experts // ranks + 2
        plan = plan_expert_placement(trace, cfg.moe.num_experts, ranks,
                                     slots, algorithm="lmbr")
        base = baseline_contiguous_placement(cfg.moe.num_experts, ranks, slots)
        print(f"expert placement refit: span {base.avg_span(trace):.2f} -> "
              f"{plan.avg_span(trace):.2f} across {ranks} EP ranks")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
