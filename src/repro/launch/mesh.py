"""Production mesh definitions.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — dryrun.py must set XLA_FLAGS before any jax
device-count lock-in.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e-256 pod: 16x16 (data, model); two pods add a leading 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2, pods: int | None = None):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count
    >= data*model*(pods or 1))."""
    if pods:
        return jax.make_mesh((pods, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
