"""Roofline term derivation from compiled dry-run artifacts.

Per (arch x shape x mesh) the dry-run records HLO_FLOPs and HLO bytes from
compiled.cost_analysis() and the per-collective payload bytes parsed from the
optimized HLO text.  This module turns those into the three roofline terms
(seconds) on TPU v5e and identifies the dominant bottleneck:

  compute    = HLO_FLOPs / (chips * 197e12)        [bf16 peak / chip]
  memory     = HLO_bytes / (chips * 819e9)         [HBM BW / chip]
  collective = collective_bytes / (chips * 50e9)   [~ICI link BW / chip]

cost_analysis() on an SPMD-partitioned module reports PER-DEVICE numbers, so
global = per_device * chips and the division by chips cancels; we keep the
formula shape from the assignment and feed it global values.
"""

from __future__ import annotations

import re

PEAK_FLOPS = 197e12      # bf16 / chip (v5e)
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / link (ICI)
DCN_BW = 6.25e9          # bytes/s / host-ish (25GbE class) for 'pod' traffic

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(pred|[sfu]\d+|bf16|c64|c128)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every typed shape literal in `text` (handles tuple
    result shapes)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def collective_stats(hlo_text: str) -> dict:
    """Per-op-kind payload bytes (result-shape convention, per device)."""
    stats = {k: {"count": 0, "bytes": 0} for k in COLLECTIVE_OPS}
    # HLO line shape: `%name = <result-shape> op-name(...), ...`; async ops
    # appear as op-start/op-done pairs — count the start only.
    pat = re.compile(
        r"=\s+(.*?)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|"
        r"collective-permute)(-start)?\("
    )
    for line in hlo_text.splitlines():
        m = pat.search(line)
        if not m:
            continue
        shape_txt, op = m.group(1), m.group(2)
        stats[op]["count"] += 1
        stats[op]["bytes"] += _shape_bytes(shape_txt)
    return stats


def roofline(flops_per_dev: float, bytes_per_dev: float,
             coll_bytes_per_dev: float, chips: int,
             dcn_bytes_per_dev: float = 0.0) -> dict:
    compute_s = flops_per_dev / PEAK_FLOPS
    memory_s = bytes_per_dev / HBM_BW
    collective_s = coll_bytes_per_dev / LINK_BW + dcn_bytes_per_dev / DCN_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    bound_s = max(terms.values())
    return dict(
        terms,
        dominant=dominant.replace("_s", ""),
        step_lower_bound_s=bound_s,
        roofline_fraction=(compute_s / bound_s) if bound_s > 0 else 0.0,
        chips=chips,
    )


def model_flops(cfg, shape, training: bool) -> float:
    """MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D (inference),
    D = tokens processed this step."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


# --------------------------------------------------- analytic corrections
# XLA:CPU cost_analysis counts while-loop bodies ONCE (verified empirically:
# flops are layer-count-invariant under scan — EXPERIMENTS.md §Roofline), so
# raw HLO numbers on scanned models are per-layer-body, not per-step.  The
# corrected terms below therefore use closed-form compute/memory models plus
# trip-count-scaled HLO collective bytes.  On a real TPU this correction
# disappears (profile-derived costs); the formulas are standard MFU
# accounting (attention term included, remat recompute counted).


def _attention_flops(cfg, tokens: int, ctx: int, decode: bool) -> float:
    """2*(qk+pv) = 4 * tokens * ctx_avg * H * head_dim, per layer-sum."""
    hd = cfg.resolved_head_dim
    total = 0.0
    win = None
    try:
        from repro.models.model import layer_windows
        wins = layer_windows(cfg)
    except Exception:
        wins = [cfg.sliding_window] * cfg.num_layers
    for w in wins:
        if cfg.attention == "none":
            continue
        c = ctx if w is None else min(ctx, w)
        eff = c if decode else c / 2  # causal halves prefill/train
        total += 4.0 * tokens * eff * cfg.num_heads * hd
    if cfg.attention == "hybrid" and cfg.ssm:
        # SSD term: chunked matmuls ~ 2*L_chunk per token per head dim
        s_ = cfg.ssm
        d_in = cfg.d_model * s_.expand
        total += cfg.num_layers * (
            2.0 * tokens * s_.chunk_size * d_in
            + 4.0 * tokens * s_.state_dim * d_in
        )
    if cfg.attention == "none" and cfg.ssm:
        s_ = cfg.ssm
        d_in = cfg.d_model * s_.expand
        chunk = 1 if decode else s_.chunk_size
        total += cfg.num_layers * (
            2.0 * tokens * chunk * d_in + 4.0 * tokens * s_.state_dim * d_in
        )
    return total


def analytic_flops(cfg, shape) -> float:
    """Global FLOPs per step: parameter matmuls + attention, remat counted."""
    tokens = shape.global_batch * (
        shape.seq_len if shape.kind != "decode" else 1
    )
    ctx = shape.seq_len
    n = cfg.active_param_count()
    param_term = 2.0 * n * tokens
    attn_term = _attention_flops(cfg, tokens, ctx, shape.kind == "decode")
    fwd = param_term + attn_term
    if shape.kind == "train":
        # bwd = 2x fwd; full remat adds ~1x fwd recompute
        return 4.0 * fwd
    return fwd


def analytic_bytes(cfg, shape, chips: int, optimizer: str = "adamw") -> float:
    """Per-device HBM traffic lower bound: weight stream + activation stream
    + KV/state cache stream + optimizer state traffic (train)."""
    dtype_b = 2.0  # bf16
    tokens = shape.global_batch * (
        shape.seq_len if shape.kind != "decode" else 1
    )
    n_total = cfg.param_count()
    d = cfg.d_model
    dp = max(chips // 16, 1)             # data(*pod) axes; model axis = 16
    tok_dev = tokens / dp if shape.global_batch % dp == 0 or tokens >= dp \
        else tokens
    weights_dev = n_total * dtype_b / chips   # 2D-sharded weight stream
    # ~8 d-wide activation reads+writes per layer per token (qkv/o + mlp)
    act_stream = tok_dev * d * dtype_b * cfg.num_layers * 8
    if shape.kind == "train":
        # fwd + bwd + remat re-fwd weight streams; grads + optimizer states
        opt_mult = 12.0 if optimizer == "adamw" else 6.0
        return (3 * weights_dev + n_total * opt_mult / chips
                + 3 * act_stream)
    if shape.kind == "prefill":
        return weights_dev + act_stream
    # decode: stream local weights + the KV/state cache once
    kv = 0.0
    try:
        from repro.models.model import layer_windows
        wins = layer_windows(cfg)
    except Exception:
        wins = [cfg.sliding_window] * cfg.num_layers
    for w in wins:
        ctx = shape.seq_len if w is None else min(shape.seq_len, w)
        if cfg.attention == "mla" and cfg.mla:
            kv += ctx * (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim)
        elif cfg.attention != "none":
            kv += ctx * 2 * cfg.num_kv_heads * cfg.resolved_head_dim
        if cfg.ssm and cfg.attention in ("none", "hybrid"):
            s_ = cfg.ssm
            d_in = d * s_.expand
            kv += (d_in // s_.head_dim) * s_.head_dim * s_.state_dim * 2
    kv_dev = shape.global_batch * kv * dtype_b / dp
    return weights_dev + kv_dev + act_stream


def corrected_terms(rec: dict, cfg, shape) -> dict:
    """Re-derive roofline terms from a dry-run record with the while-loop
    undercount corrected (analytic compute/memory; HLO collectives scaled by
    the layer-scan trip count)."""
    chips = rec["roofline"]["chips"]
    scanned = not (cfg.attention == "hybrid" and shape.kind == "decode")
    l_eff = cfg.num_layers if scanned else 1
    opt = rec.get("optimizer", "adamw")
    flops_dev = analytic_flops(cfg, shape) / chips
    bytes_dev = analytic_bytes(cfg, shape, chips, opt)
    coll_dev = rec["collective_bytes_per_device"] * l_eff
    out = roofline(flops_dev, bytes_dev, coll_dev, chips)
    out["correction"] = f"analytic flops/bytes; coll x{l_eff}"
    return out
