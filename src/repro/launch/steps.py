"""Step builders + ShapeDtypeStruct input specs shared by the dry-run,
trainer and server.

Every step is a pure function suitable for jax.jit with explicit shardings;
nothing here allocates device memory (input_specs returns ShapeDtypeStructs,
param/cache structures come from jax.eval_shape).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs import SHAPE_GRID, ModelConfig, ShapeConfig
from repro.models import (
    decode_step, init_cache, init_params, prefill, train_loss,
)
from repro.models.model import _run_encoder  # noqa: F401  (enc-dec serving)
from repro.optim import clip_by_global_norm
from repro.optim.optimizers import make_optimizer

__all__ = [
    "make_train_step", "make_prefill_step", "make_serve_step",
    "input_specs", "param_struct", "opt_struct", "serve_cache_struct",
    "pick_optimizer", "shape_skip_reason",
]


def pick_optimizer(cfg: ModelConfig) -> str:
    """Adafactor for the 100B+ class (optimizer-state HBM), AdamW otherwise."""
    return "adafactor" if cfg.param_count() > 5e10 else "adamw"


def make_train_step(cfg, optimizer=None, moe_dispatch=None, chunk=512):
    from repro.flags import FLAGS

    opt = optimizer or make_optimizer(pick_optimizer(cfg), 3e-4)
    accum = int(FLAGS["accum_steps"])
    loss_fn = functools.partial(train_loss, cfg, moe_dispatch=moe_dispatch,
                                chunk=chunk)

    def train_step(params, opt_state, batch):
        if accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            # microbatched gradient accumulation: activation live-set shrinks
            # by `accum`x; grads accumulate in the parameter dtype, sharded
            # exactly like the parameters (FSDP accumulators)
            micro = jax.tree.map(
                lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]),
                batch,
            )

            def mb(carry, mbatch):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mbatch)
                gsum = jax.tree.map(
                    lambda a, gg: a + gg.astype(a.dtype), gsum, g)
                return (gsum, lsum + l), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype),
                                 params)
            (gsum, lsum), _ = jax.lax.scan(mb, (zeros, 0.0), micro)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = lsum / accum
            metrics = {"loss": loss, "xent": loss}
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        updates, new_opt_state = opt.update(grads, opt_state, params)
        new_params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32))
            .astype(p.dtype), params, updates,
        )
        metrics = dict(metrics, grad_norm=gnorm)
        return new_params, new_opt_state, metrics

    return train_step, opt


def make_prefill_step(cfg, moe_dispatch=None, chunk=512, window_only=False):
    def prefill_step(params, batch):
        return prefill(cfg, params, batch, moe_dispatch=moe_dispatch,
                       chunk=chunk, window_only=window_only)

    return prefill_step


def make_serve_step(cfg, moe_dispatch=None, chunk=512):
    def serve_step(params, cache, tokens, positions):
        return decode_step(cfg, params, cache, tokens, positions,
                           moe_dispatch=moe_dispatch, chunk=chunk)

    return serve_step


# ------------------------------------------------------------- shape structs
def shape_skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> str | None:
    """Documented grid skips (DESIGN.md §4)."""
    if shape.name == "long_500k" and not cfg.is_subquadratic():
        return ("full-attention arch: 500k dense KV cache is not deployable; "
                "run sub-quadratic archs (ssm/hybrid/swa) instead")
    return None


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this step kind."""
    gb, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    sd = jax.ShapeDtypeStruct
    if shape.kind == "train":
        batch = {"tokens": sd((gb, s), i32), "targets": sd((gb, s), i32)}
        if cfg.frontend:
            batch["frontend"] = sd((gb, cfg.frontend_len, cfg.d_model), f32)
        return {"batch": batch}
    if shape.kind == "prefill":
        batch = {"tokens": sd((gb, s), i32)}
        if cfg.frontend:
            batch["frontend"] = sd((gb, cfg.frontend_len, cfg.d_model), f32)
        return {"batch": batch}
    # decode: one new token against a resident cache of length s
    return {
        "tokens": sd((gb, 1), i32),
        "positions": sd((gb, 1), i32),
    }


def param_struct(cfg, moe_dispatch=None):
    return jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0),
                            moe_dispatch=moe_dispatch)
    )


def opt_struct(cfg, opt, params_struct):
    return jax.eval_shape(opt.init, params_struct)


def serve_cache_struct(cfg, batch: int, max_len: int, *, window_only=False):
    def build():
        cache = init_cache(cfg, batch, max_len, window_only=window_only)
        if cfg.encoder_layers:
            f = cfg.frontend_len
            cache["encoder"] = (
                jnp.zeros((batch, f, cfg.d_model), jnp.dtype(cfg.dtype)),
                jnp.zeros((batch, f), jnp.int32),
            )
        return cache

    return jax.eval_shape(build)
