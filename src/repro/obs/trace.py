"""Structured trace recorder producing Chrome-trace JSON and JSONL.

``Tracer`` records flat event dicts in the Chrome trace-event format
(https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU):

* ``span("fit.hpa", k=8)`` — a context manager emitting one complete
  ("ph": "X") event on exit, with microsecond ``ts``/``dur`` relative to
  the tracer's epoch.  Spans nest naturally: synchronous callers share
  tid 0, so viewers reconstruct the tree from ts/dur containment.
* ``event("drift.fire")`` — an instant ("i") event.
* ``counter("online", served=..., inflight=...)`` — a counter ("C")
  event; Perfetto renders these as stacked time series.
* ``complete(name, t0, t1, **args)`` — an explicit complete event from
  two ``time.perf_counter()`` stamps, for work that does not nest as a
  ``with`` block (e.g. a migration transfer that starts in one
  ``advance()`` call and lands in a later one).

``to_chrome_trace()`` serialises to the JSON object format that
chrome://tracing and https://ui.perfetto.dev load directly;
``to_jsonl()`` emits one event per line for streaming consumers.

``NULL_TRACER`` implements the same surface as no-ops (``span`` returns a
shared no-op context manager), so hot paths pay one attribute check when
``flags.obs_level != "trace"``.
"""

from __future__ import annotations

import json
import time

__all__ = ["Tracer", "NullTracer", "NULL_TRACER", "NULL_SPAN"]


class _Span:
    """Context manager emitting one complete event on exit."""

    __slots__ = ("_tracer", "name", "args", "t0")

    def __init__(self, tracer, name, args):
        self._tracer = tracer
        self.name = name
        self.args = args

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        self._tracer.complete(self.name, self.t0, time.perf_counter(),
                              **self.args)
        return False


class Tracer:
    active = True

    def __init__(self, pid: int = 0):
        self.pid = pid
        self.events: list = []
        self.epoch = time.perf_counter()

    def _us(self, t_pc: float) -> float:
        return (t_pc - self.epoch) * 1e6

    # -- recording -------------------------------------------------------
    def span(self, name: str, **args) -> _Span:
        return _Span(self, name, args)

    def complete(self, name: str, t0: float, t1: float, **args):
        """Complete event from two ``time.perf_counter()`` stamps."""
        self.events.append({
            "name": name, "ph": "X", "ts": self._us(t0),
            "dur": (t1 - t0) * 1e6, "pid": self.pid, "tid": 0,
            "args": args,
        })

    def event(self, name: str, **args):
        self.events.append({
            "name": name, "ph": "i", "s": "t",
            "ts": self._us(time.perf_counter()), "pid": self.pid, "tid": 0,
            "args": args,
        })

    def counter(self, name: str, **values):
        self.events.append({
            "name": name, "ph": "C",
            "ts": self._us(time.perf_counter()), "pid": self.pid, "tid": 0,
            "args": values,
        })

    # -- export ----------------------------------------------------------
    def to_chrome_trace(self) -> str:
        return json.dumps(
            {"traceEvents": self.events, "displayTimeUnit": "ms"}
        )

    def to_jsonl(self) -> str:
        return "\n".join(json.dumps(e) for e in self.events)

    def spans(self, name: str | None = None) -> list:
        """Complete ("X") events, optionally filtered by exact name."""
        return [e for e in self.events
                if e["ph"] == "X" and (name is None or e["name"] == name)]

    def clear(self):
        self.events.clear()
        self.epoch = time.perf_counter()


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


NULL_SPAN = _NullSpan()


class NullTracer:
    """No-op stand-in for ``Tracer`` when tracing is disabled."""

    active = False
    events = ()

    def span(self, name: str, **args):
        return NULL_SPAN

    def complete(self, name: str, t0: float, t1: float, **args):
        pass

    def event(self, name: str, **args):
        pass

    def counter(self, name: str, **values):
        pass

    def to_chrome_trace(self) -> str:
        return '{"traceEvents": []}'

    def to_jsonl(self) -> str:
        return ""

    def spans(self, name: str | None = None) -> list:
        return []

    def clear(self):
        pass


NULL_TRACER = NullTracer()
