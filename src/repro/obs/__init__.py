"""repro.obs — unified observability: metrics registry + structured tracer.

Level selection is flag-driven and re-read on every accessor call, so code
never caches the wrong object across a ``flags.set_variant``:

* ``flags.FLAGS["obs_level"] == "off"``      -> ``registry()`` is
  ``NULL_REGISTRY``, ``tracer()`` is ``NULL_TRACER`` (both no-op
  singletons; zero allocations on hot paths).
* ``"counters"``                              -> real ``Registry``, null
  tracer.
* ``"trace"``                                 -> real ``Registry`` + real
  ``Tracer``.

The contract (gated by tests/test_obs.py and benchmarks/bench_obs.py): no
observability level may change placement or serving RESULTS — hooks only
read state — and ``"off"`` must be timing-neutral on the serving loop.

``timed(name, **args)`` is the repo-wide timing idiom replacing scattered
``time.perf_counter()`` pairs: it always measures (``.seconds`` is valid
at every obs level, so ``fit_seconds``-style stats keep their values) and
additionally records a trace span when ``obs_level == "trace"``.
"""

from __future__ import annotations

import time

from .. import flags as _flags
from .registry import (Registry, NullRegistry, NULL_REGISTRY,
                       DEFAULT_BUCKETS, parse_prom_text)
from .trace import Tracer, NullTracer, NULL_TRACER, NULL_SPAN
from .timeseries import SeriesRing, TimeSeriesStore
from .health import SLORule, Alert, HealthMonitor
from .analyze import (load_events, build_span_tree, aggregate_spans,
                      critical_path, top_slowest, render_report)

__all__ = [
    "Registry", "NullRegistry", "NULL_REGISTRY", "DEFAULT_BUCKETS",
    "parse_prom_text", "Tracer", "NullTracer", "NULL_TRACER", "NULL_SPAN",
    "SeriesRing", "TimeSeriesStore", "SLORule", "Alert", "HealthMonitor",
    "load_events", "build_span_tree", "aggregate_spans", "critical_path",
    "top_slowest", "render_report",
    "level", "registry", "tracer", "reset", "timed",
]

LEVELS = ("off", "counters", "trace")

_REGISTRY = Registry()
_TRACER = Tracer()


def level() -> str:
    """Current ``obs_level`` flag value (validated)."""
    lv = _flags.FLAGS.get("obs_level", "off")
    if lv not in LEVELS:
        raise ValueError(f"unknown obs_level {lv!r}; expected one of {LEVELS}")
    return lv


def registry():
    """The live ``Registry`` at "counters"/"trace", else ``NULL_REGISTRY``."""
    return NULL_REGISTRY if _flags.FLAGS.get("obs_level", "off") == "off" \
        else _REGISTRY


def tracer():
    """The live ``Tracer`` at "trace", else ``NULL_TRACER``."""
    return _TRACER if _flags.FLAGS.get("obs_level", "off") == "trace" \
        else NULL_TRACER


def reset():
    """Drop all recorded metrics and trace events (flags are untouched)."""
    _REGISTRY.clear()
    _TRACER.clear()


class timed:
    """Always-on timing context manager; trace span when tracing.

    ``with obs.timed("fit.place", algorithm="lmbr") as t: ...`` then read
    ``t.seconds``.  Replaces bare ``time.perf_counter()`` pairs so stats
    like ``fit_seconds`` keep identical values at every obs level while
    the same region shows up in the Chrome trace when enabled.
    """

    __slots__ = ("name", "args", "t0", "seconds")

    def __init__(self, name: str, **args):
        self.name = name
        self.args = args
        self.t0 = 0.0
        self.seconds = 0.0

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        t1 = time.perf_counter()
        self.seconds = t1 - self.t0
        tr = tracer()
        if tr.active:
            tr.complete(self.name, self.t0, t1, **self.args)
        return False
