"""Metrics registry: counters, gauges, and fixed-bucket histograms.

One process-wide ``Registry`` (owned by ``repro.obs``) holds every
instrument keyed by ``(name, sorted label items)``.  Instruments are
plain-Python accumulators — no locks, no background threads — because the
whole repo is single-process and the hot paths only touch them behind an
``registry().active`` check.

``snapshot()`` flattens the registry into a ``{series_id: value}`` dict in
Prometheus exposition naming (``name{label="v"}``, histogram ``_bucket`` /
``_sum`` / ``_count`` series), ``to_prom_text()`` renders the text
exposition format, and ``parse_prom_text()`` parses it back — the pair
round-trips exactly (``parse_prom_text(to_prom_text()) == snapshot()``),
which tests/test_obs.py gates.

The ``NULL_REGISTRY`` singleton implements the same surface as no-ops with
``active = False``; hot paths hold zero instruments and allocate nothing
while ``flags.obs_level == "off"``.
"""

from __future__ import annotations

from bisect import bisect_left

__all__ = [
    "Counter", "Gauge", "GaugeVector", "Histogram", "Registry",
    "NullRegistry", "NULL_REGISTRY", "DEFAULT_BUCKETS", "parse_prom_text",
]

# default latency buckets, in seconds (upper bounds; +Inf is implicit).
DEFAULT_BUCKETS = (
    100e-6, 250e-6, 500e-6, 1e-3, 2.5e-3, 5e-3, 10e-3, 25e-3, 50e-3,
    100e-3, 250e-3, 500e-3, 1.0, 2.5,
)


class Counter:
    """Monotonically increasing accumulator."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0):
        self.value += n


class Gauge:
    """Scalar that can move both ways."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float):
        self.value = float(v)

    def add(self, n: float):
        self.value += n


class GaugeVector:
    """Indexed gauge family (one series per element, label index="i").

    ``set()`` keeps a reference to the given sequence; values are copied
    out lazily at snapshot time, so hot paths pay one attribute store per
    update (e.g. the router's per-partition load ledger).
    """

    __slots__ = ("values",)

    def __init__(self):
        self.values = ()

    def set(self, values):
        self.values = values


class Histogram:
    """Fixed-bucket histogram (cumulative le-buckets at snapshot time)."""

    __slots__ = ("uppers", "counts", "sum", "count")

    def __init__(self, buckets=DEFAULT_BUCKETS):
        self.uppers = tuple(float(b) for b in buckets)
        self.counts = [0] * (len(self.uppers) + 1)  # last slot = +Inf
        self.sum = 0.0
        self.count = 0

    def observe(self, x: float):
        self.counts[bisect_left(self.uppers, x)] += 1
        self.sum += x
        self.count += 1


def _series_id(name: str, labels) -> str:
    if not labels:
        return name
    inner = ",".join(f'{k}="{_escape(str(v))}"' for k, v in labels)
    return f"{name}{{{inner}}}"


def _escape(v: str) -> str:
    return v.replace("\\", r"\\").replace('"', r"\"").replace("\n", r"\n")


def _fmt(upper: float) -> str:
    """Bucket upper bound formatted for the le label (round-trippable)."""
    return repr(upper)


class Registry:
    """Process-wide instrument store.  See module docstring."""

    active = True

    def __init__(self):
        self._metrics: dict = {}   # (name, labels tuple) -> instrument
        self._kinds: dict = {}     # name -> "counter" | "gauge" | ...

    # -- instrument accessors (get-or-create) ---------------------------
    def counter(self, name: str, **labels) -> Counter:
        return self._get(name, "counter", Counter, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(name, "gauge", Gauge, labels)

    def gauge_vector(self, name: str, **labels) -> GaugeVector:
        return self._get(name, "gauge", GaugeVector, labels)

    def histogram(self, name: str, buckets=DEFAULT_BUCKETS,
                  **labels) -> Histogram:
        return self._get(name, "histogram", lambda: Histogram(buckets),
                         labels)

    def _get(self, name, kind, factory, labels):
        prev = self._kinds.setdefault(name, kind)
        if prev != kind:
            raise ValueError(
                f"metric {name!r} already registered as {prev}, not {kind}"
            )
        key = (name, tuple(sorted(labels.items())))
        inst = self._metrics.get(key)
        if inst is None:
            inst = self._metrics[key] = factory()
        return inst

    # -- convenience one-shots ------------------------------------------
    def inc(self, name: str, n: float = 1.0, **labels):
        self.counter(name, **labels).inc(n)

    def set(self, name: str, v: float, **labels):
        self.gauge(name, **labels).set(v)

    def observe(self, name: str, x: float, **labels):
        self.histogram(name, **labels).observe(x)

    # -- export ----------------------------------------------------------
    def snapshot(self) -> dict:
        """Flat ``{prom series id: float value}`` view of every instrument."""
        out: dict = {}
        for (name, labels), inst in self._metrics.items():
            if isinstance(inst, (Counter, Gauge)):
                out[_series_id(name, labels)] = float(inst.value)
            elif isinstance(inst, GaugeVector):
                for i, v in enumerate(inst.values):
                    out[_series_id(name, labels + (("index", i),))] = float(v)
            else:  # Histogram
                cum = 0
                for upper, c in zip(inst.uppers, inst.counts):
                    cum += c
                    lb = labels + (("le", _fmt(upper)),)
                    out[_series_id(name + "_bucket", lb)] = float(cum)
                lb = labels + (("le", "+Inf"),)
                out[_series_id(name + "_bucket", lb)] = float(inst.count)
                out[_series_id(name + "_sum", labels)] = float(inst.sum)
                out[_series_id(name + "_count", labels)] = float(inst.count)
        return out

    def to_prom_text(self) -> str:
        """Prometheus text exposition of the full registry."""
        lines: list = []
        seen: set = set()
        for (name, _labels) in self._metrics:
            if name not in seen:
                seen.add(name)
                lines.append(f"# TYPE {name} {self._kinds[name]}")
        for series, value in sorted(self.snapshot().items()):
            lines.append(f"{series} {value!r}")
        return "\n".join(lines) + ("\n" if lines else "")

    def clear(self):
        self._metrics.clear()
        self._kinds.clear()


def parse_prom_text(text: str) -> dict:
    """Parse ``to_prom_text()`` output back into a ``snapshot()`` dict."""
    out: dict = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        series, _, value = line.rpartition(" ")
        out[series] = float(value)
    return out


class _NullInstrument:
    """Accepts every instrument mutation as a no-op."""

    __slots__ = ()

    def inc(self, n: float = 1.0):
        pass

    def set(self, v):
        pass

    def add(self, n: float):
        pass

    def observe(self, x: float):
        pass


_NULL_INSTRUMENT = _NullInstrument()


class NullRegistry:
    """No-op stand-in for ``Registry`` when ``obs_level == "off"``.

    Every accessor returns the shared ``_NullInstrument`` singleton, so
    instrumented hot paths allocate nothing and store nothing.
    """

    active = False

    def counter(self, name: str, **labels):
        return _NULL_INSTRUMENT

    def gauge(self, name: str, **labels):
        return _NULL_INSTRUMENT

    def gauge_vector(self, name: str, **labels):
        return _NULL_INSTRUMENT

    def histogram(self, name: str, buckets=DEFAULT_BUCKETS, **labels):
        return _NULL_INSTRUMENT

    def inc(self, name: str, n: float = 1.0, **labels):
        pass

    def set(self, name: str, v: float, **labels):
        pass

    def observe(self, name: str, x: float, **labels):
        pass

    def snapshot(self) -> dict:
        return {}

    def to_prom_text(self) -> str:
        return ""

    def clear(self):
        pass


NULL_REGISTRY = NullRegistry()
