"""Offline trace analytics over Chrome-trace events.

A `Tracer` (PR 9) records flat complete/instant/counter events; this
module turns them back into structure after the run:

* `load_events` — read a trace from JSONL (one event per line,
  ``Tracer.to_jsonl``) or the Chrome JSON object format
  (``{"traceEvents": [...]}``, ``Tracer.to_chrome_trace``).
* `build_span_tree` — reconstruct the span tree from ts/dur containment
  per tid (synchronous callers share tid 0, so nesting IS containment).
  Spans that only *partially* overlap an open span — e.g. a
  ``migration.transfer`` stamped at transfer start but landing several
  microbatches later — are treated as parentless roots rather than
  misattributed to whichever microbatch they happen to straddle.
* `aggregate_spans` — per-span-name count / total / self / min / max /
  mean wall time, where self time is the span's duration minus its direct
  children's (clamped at 0; clock jitter can make children sum past the
  parent).
* `critical_path` — from the named root (default ``fit.place``, the
  fit's umbrella span in ``run_online``), repeatedly descend into the
  longest child: the chain a latency optimisation has to shorten.
* `top_slowest` — top-k slowest events of one name (default
  ``serve.microbatch``).
* `render_report` — the plain-text run report ``tools/obs_report.py``
  prints, optionally joined with a prom snapshot's headline counters.

Durations are microseconds throughout (the trace-event unit); the report
renders milliseconds.
"""

from __future__ import annotations

import json

__all__ = [
    "load_events", "SpanNode", "build_span_tree", "aggregate_spans",
    "critical_path", "top_slowest", "render_report",
    "FIT_ROOT_SPAN", "MICROBATCH_SPAN",
]

FIT_ROOT_SPAN = "fit.place"
MICROBATCH_SPAN = "serve.microbatch"


def load_events(text: str) -> list:
    """Parse trace events from JSONL or Chrome JSON object text."""
    text = text.strip()
    if not text:
        return []
    try:
        obj = json.loads(text)
    except json.JSONDecodeError:
        # multi-line JSONL: one event object per line
        return [json.loads(line) for line in text.splitlines() if line.strip()]
    if isinstance(obj, dict) and "traceEvents" in obj:
        events = obj["traceEvents"]
        if not isinstance(events, list):
            raise ValueError("traceEvents is not a list")
        return events
    if isinstance(obj, list):
        return obj
    return [obj]  # a single-event JSONL file


class SpanNode:
    """One complete ("X") event with its reconstructed children."""

    __slots__ = ("event", "children", "parent")

    def __init__(self, event: dict):
        self.event = event
        self.children: list[SpanNode] = []
        self.parent: "SpanNode | None" = None

    @property
    def name(self) -> str:
        return self.event["name"]

    @property
    def ts(self) -> float:
        return float(self.event["ts"])

    @property
    def dur(self) -> float:
        return float(self.event.get("dur", 0.0))

    @property
    def end(self) -> float:
        return self.ts + self.dur

    @property
    def self_time(self) -> float:
        return max(0.0, self.dur - sum(c.dur for c in self.children))

    def __repr__(self) -> str:  # debugging aid
        return (f"SpanNode({self.name!r}, ts={self.ts:.1f}, "
                f"dur={self.dur:.1f}, children={len(self.children)})")


def build_span_tree(events: list) -> "list[SpanNode]":
    """Reconstruct the span forest from ts/dur containment; returns the
    roots in chronological order.  See the module docstring for how
    partially-overlapping spans are handled."""
    nodes = [SpanNode(e) for e in events if e.get("ph") == "X"]
    by_tid: dict = {}
    for node in nodes:
        key = (node.event.get("pid", 0), node.event.get("tid", 0))
        by_tid.setdefault(key, []).append(node)
    roots: list[SpanNode] = []
    for group in by_tid.values():
        # parents first at equal ts: longer duration wins
        group.sort(key=lambda s: (s.ts, -s.dur))
        stack: list[SpanNode] = []
        for node in group:
            while stack and node.ts >= stack[-1].end:
                stack.pop()
            if not stack:
                roots.append(node)
                stack.append(node)
            elif node.end <= stack[-1].end:
                node.parent = stack[-1]
                stack[-1].children.append(node)
                stack.append(node)
            else:
                # partial overlap (async span like migration.transfer):
                # parentless, and never a parent itself
                roots.append(node)
    roots.sort(key=lambda s: s.ts)
    return roots


def _walk(roots: "list[SpanNode]"):
    stack = list(roots)
    while stack:
        node = stack.pop()
        yield node
        stack.extend(node.children)


def aggregate_spans(events: list) -> dict:
    """Per-name aggregation over complete events: ``{name: {count,
    total_us, self_us, min_us, max_us, mean_us}}``."""
    agg: dict = {}
    for node in _walk(build_span_tree(events)):
        row = agg.get(node.name)
        if row is None:
            row = agg[node.name] = dict(
                count=0, total_us=0.0, self_us=0.0,
                min_us=float("inf"), max_us=0.0, mean_us=0.0,
            )
        row["count"] += 1
        row["total_us"] += node.dur
        row["self_us"] += node.self_time
        row["min_us"] = min(row["min_us"], node.dur)
        row["max_us"] = max(row["max_us"], node.dur)
    for row in agg.values():
        row["mean_us"] = row["total_us"] / row["count"]
    return agg


def critical_path(events: list,
                  root_name: str = FIT_ROOT_SPAN) -> "list[SpanNode]":
    """The longest root span named ``root_name`` (any root if absent),
    then its longest child, recursively — the chain to shorten first."""
    roots = build_span_tree(events)
    named = [r for r in roots if r.name == root_name]
    pool = named if named else roots
    if not pool:
        return []
    node = max(pool, key=lambda s: s.dur)
    path = [node]
    while node.children:
        node = max(node.children, key=lambda s: s.dur)
        path.append(node)
    return path


def top_slowest(events: list, name: str = MICROBATCH_SPAN,
                k: int = 5) -> list:
    """Top-``k`` slowest complete events named ``name`` (raw event
    dicts, slowest first)."""
    xs = [e for e in events
          if e.get("ph") == "X" and e.get("name") == name]
    xs.sort(key=lambda e: -float(e.get("dur", 0.0)))
    return xs[:k]


# --------------------------------------------------------------- reporting
_HEADLINE_METRICS = (
    "router_served_queries_total", "router_microbatches_total",
    "router_plan_swaps_total", "online_degraded_queries",
    "migration_transferred_total", "migration_wasted_total",
    "drift_fires_total", "drift_refits_total",
    "health_alerts_fired_total", "health_alerts_resolved_total",
    "health_alerts_active",
)


def _ms(us: float) -> str:
    return f"{us / 1e3:.3f}ms"


def render_report(events: list, prom_snapshot: "dict | None" = None,
                  top_k: int = 5) -> str:
    """Plain-text run report: event census, span aggregation, fit
    critical path, slowest microbatches, headline prom counters."""
    lines: list[str] = ["== trace =="]
    census: dict = {}
    for e in events:
        census[e.get("ph", "?")] = census.get(e.get("ph", "?"), 0) + 1
    lines.append(
        f"events: {len(events)} "
        f"({', '.join(f'{ph}={n}' for ph, n in sorted(census.items()))})"
    )

    agg = aggregate_spans(events)
    if agg:
        lines.append("")
        lines.append("-- spans by total time --")
        lines.append(f"{'name':<28} {'count':>6} {'total':>12} "
                     f"{'self':>12} {'mean':>12} {'max':>12}")
        for name, row in sorted(agg.items(),
                                key=lambda kv: -kv[1]["total_us"]):
            lines.append(
                f"{name:<28} {row['count']:>6} {_ms(row['total_us']):>12} "
                f"{_ms(row['self_us']):>12} {_ms(row['mean_us']):>12} "
                f"{_ms(row['max_us']):>12}"
            )

    path = critical_path(events)
    if path:
        lines.append("")
        lines.append(f"-- critical path ({path[0].name}) --")
        for depth, node in enumerate(path):
            lines.append(f"{'  ' * depth}{node.name:<28} "
                         f"dur={_ms(node.dur)} self={_ms(node.self_time)}")

    slow = top_slowest(events, k=top_k)
    if slow:
        lines.append("")
        lines.append(f"-- slowest {MICROBATCH_SPAN} (top {len(slow)}) --")
        for e in slow:
            args = e.get("args", {})
            extra = f" queries={args['queries']}" if "queries" in args else ""
            lines.append(f"dur={_ms(float(e.get('dur', 0.0)))} "
                         f"ts={_ms(float(e.get('ts', 0.0)))}{extra}")

    alerts = [e for e in events if e.get("ph") == "i"
              and str(e.get("name", "")).startswith("alert.")]
    if alerts:
        lines.append("")
        lines.append("-- alerts --")
        for e in alerts:
            args = e.get("args", {})
            lines.append(
                f"{e['name']:<14} rule={args.get('rule', '?')} "
                f"value={args.get('value')} threshold={args.get('threshold')}"
            )

    if prom_snapshot:
        lines.append("")
        lines.append("== metrics ==")
        for name in _HEADLINE_METRICS:
            if name in prom_snapshot:
                lines.append(f"{name:<32} {prom_snapshot[name]:g}")
    return "\n".join(lines) + "\n"
