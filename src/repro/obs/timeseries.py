"""Fixed-capacity time-series store over registry snapshots.

The metrics `Registry` (PR 9) is a point-in-time view: counters only ever
hold their latest value.  `TimeSeriesStore` turns the periodic
``run_online`` snapshots (``obs_snapshot_every``) into bounded history —
one ring buffer per series id — and exposes the windowed aggregations the
health monitor's SLO rules consume:

* ``delta(name, n)`` / ``rate(name, n)`` — counter movement over the last
  ``n`` samples (rate per unit of the ingest time axis; ``run_online``
  feeds served-query counts as ``t``, so rates are per query and fully
  deterministic).
* ``mean`` / ``vmin`` / ``vmax`` / ``last`` — gauge aggregations over the
  window.
* ``ewma(name, alpha)`` — exponentially weighted mean over the ring.
* ``vector_delta(base, n)`` — per-index window delta of a ``GaugeVector``
  family (series ``base{index="i"}``), e.g. the router's per-partition
  load ledger, for skew rules.
* ``histogram_quantile(name, q, n)`` — quantile from the windowed DELTA of
  a cumulative ``_bucket`` family (Prometheus-style linear interpolation
  inside the bucket; a quantile landing in the ``+Inf`` bucket reports the
  highest finite bound), e.g. p99 of ``router_microbatch_seconds``.

Ring buffers are preallocated float64 pairs; ``ingest`` appends every
series of a snapshot at one time coordinate, so the store's cost is
O(series) per snapshot and capped by ``capacity`` per series forever.
"""

from __future__ import annotations

import re

import numpy as np

__all__ = ["SeriesRing", "TimeSeriesStore"]

_INDEX_RE = re.compile(r'^(?P<base>[^{]+)\{index="(?P<i>\d+)"\}$')
_BUCKET_RE = re.compile(r'^(?P<base>[^{]+)_bucket\{(?:.*?)le="(?P<le>[^"]+)"\}$')


class SeriesRing:
    """One series' bounded history: parallel (t, v) float64 rings."""

    __slots__ = ("capacity", "_t", "_v", "_pos", "count")

    def __init__(self, capacity: int):
        if capacity < 2:
            raise ValueError(f"ring capacity must be >= 2, got {capacity}")
        self.capacity = int(capacity)
        self._t = np.zeros(self.capacity, dtype=np.float64)
        self._v = np.zeros(self.capacity, dtype=np.float64)
        self._pos = 0      # next write slot
        self.count = 0     # samples held (saturates at capacity)

    def __len__(self) -> int:
        return self.count

    def append(self, t: float, v: float) -> None:
        self._t[self._pos] = t
        self._v[self._pos] = v
        self._pos = (self._pos + 1) % self.capacity
        if self.count < self.capacity:
            self.count += 1

    def _window(self, arr: np.ndarray, n: int | None) -> np.ndarray:
        k = self.count if n is None else min(int(n), self.count)
        if k <= 0:
            return np.zeros(0, dtype=np.float64)
        # chronological: the k samples ending at the last write
        idx = (self._pos - k + np.arange(k)) % self.capacity
        return arr[idx]

    def values(self, n: int | None = None) -> np.ndarray:
        """Last ``n`` values (all held samples when ``n`` is None),
        oldest first."""
        return self._window(self._v, n)

    def times(self, n: int | None = None) -> np.ndarray:
        return self._window(self._t, n)

    def last(self) -> float:
        if not self.count:
            raise ValueError("empty series")
        return float(self._v[(self._pos - 1) % self.capacity])


class TimeSeriesStore:
    """Ring-buffered history of registry snapshots; see module docstring."""

    def __init__(self, capacity: int = 512):
        self.capacity = int(capacity)
        self._series: dict[str, SeriesRing] = {}

    # ------------------------------------------------------------- recording
    def record(self, name: str, t: float, value: float) -> None:
        ring = self._series.get(name)
        if ring is None:
            ring = self._series[name] = SeriesRing(self.capacity)
        ring.append(float(t), float(value))

    def ingest(self, snapshot: dict, t: float) -> None:
        """Append every series of a ``Registry.snapshot()`` at time ``t``."""
        for name, value in snapshot.items():
            self.record(name, t, value)

    # ------------------------------------------------------------- accessors
    def __contains__(self, name: str) -> bool:
        return name in self._series

    def names(self) -> list[str]:
        return sorted(self._series)

    def series(self, name: str) -> SeriesRing | None:
        return self._series.get(name)

    def window(self, name: str, n: int | None = None) -> np.ndarray:
        ring = self._series.get(name)
        return ring.values(n) if ring is not None else np.zeros(0)

    # ---------------------------------------------------------- aggregations
    def last(self, name: str) -> float | None:
        ring = self._series.get(name)
        return ring.last() if ring is not None and ring.count else None

    def delta(self, name: str, n: int) -> float | None:
        """value[last] - value[first] over the last ``n`` samples; None
        until the series holds at least two samples."""
        ring = self._series.get(name)
        if ring is None or ring.count < 2:
            return None
        v = ring.values(n)
        return float(v[-1] - v[0])

    def rate(self, name: str, n: int = 2) -> float | None:
        """delta / elapsed-time over the last ``n`` samples (per unit of
        the ingest time axis); None without two samples or zero elapsed."""
        ring = self._series.get(name)
        if ring is None or ring.count < 2:
            return None
        v, t = ring.values(n), ring.times(n)
        dt = float(t[-1] - t[0])
        if dt <= 0:
            return None
        return float(v[-1] - v[0]) / dt

    def mean(self, name: str, n: int | None = None) -> float | None:
        v = self.window(name, n)
        return float(v.mean()) if len(v) else None

    def vmin(self, name: str, n: int | None = None) -> float | None:
        v = self.window(name, n)
        return float(v.min()) if len(v) else None

    def vmax(self, name: str, n: int | None = None) -> float | None:
        v = self.window(name, n)
        return float(v.max()) if len(v) else None

    def ewma(self, name: str, alpha: float = 0.3,
             n: int | None = None) -> float | None:
        """Exponentially weighted mean over the (windowed) ring, newest
        sample weighted ``alpha``."""
        v = self.window(name, n)
        if not len(v):
            return None
        acc = float(v[0])
        for x in v[1:]:
            acc = alpha * float(x) + (1.0 - alpha) * acc
        return acc

    # ----------------------------------------------------- vector / histogram
    def vector_delta(self, base: str, n: int) -> np.ndarray:
        """Per-index window delta of the gauge-vector family
        ``base{index="i"}``, ordered by index.  Indices whose series hold
        fewer than two samples (e.g. a partition that appeared mid-window)
        contribute 0."""
        rows: list[tuple[int, float]] = []
        prefix = base + "{"
        for name, ring in self._series.items():
            if not name.startswith(prefix):
                continue
            m = _INDEX_RE.match(name)
            if m is None or m.group("base") != base:
                continue
            d = self.delta(name, n)
            rows.append((int(m.group("i")), 0.0 if d is None else d))
        if not rows:
            return np.zeros(0, dtype=np.float64)
        rows.sort()
        out = np.zeros(rows[-1][0] + 1, dtype=np.float64)
        for i, d in rows:
            out[i] = d
        return out

    def histogram_quantile(self, base: str, q: float,
                           n: int | None = None) -> float | None:
        """Quantile from the windowed delta of the cumulative bucket family
        ``base_bucket{le="..."}``.

        With ``n`` None the latest cumulative counts are used (whole-run
        quantile); otherwise the per-bucket delta over the last ``n``
        samples (windowed quantile).  Linear interpolation inside the
        bucket, Prometheus-style: below the first bound interpolates from
        0, and a quantile landing in the ``+Inf`` bucket reports the
        highest finite bound.  None when the window saw no observations.
        """
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        uppers: list[float] = []
        counts: list[float] = []
        inf_count: float | None = None
        for name, ring in self._series.items():
            m = _BUCKET_RE.match(name)
            if m is None or m.group("base") != base:
                continue
            if n is None:
                c = ring.last() if ring.count else None
            else:
                c = self.delta(name, n)
            if c is None:
                continue
            le = m.group("le")
            if le == "+Inf":
                inf_count = float(c)
            else:
                uppers.append(float(le))
                counts.append(float(c))
        if inf_count is None and not uppers:
            return None
        order = np.argsort(uppers)
        ub = np.asarray(uppers, dtype=np.float64)[order]
        cum = np.asarray(counts, dtype=np.float64)[order]
        total = inf_count if inf_count is not None else (
            float(cum[-1]) if len(cum) else 0.0
        )
        if total <= 0:
            return None
        target = q * total
        prev_cum, prev_ub = 0.0, 0.0
        for u, c in zip(ub, cum):
            if c >= target:
                span = c - prev_cum
                if span <= 0:
                    return float(u)
                frac = (target - prev_cum) / span
                return float(prev_ub + (u - prev_ub) * frac)
            prev_cum, prev_ub = float(c), float(u)
        # target falls in the +Inf bucket: report the highest finite bound
        return float(ub[-1]) if len(ub) else None
