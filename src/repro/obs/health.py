"""Health monitoring: declarative SLO rules over windowed time series.

The paper's control signal is the *average query span*, not latency; PR 9
exposed it (and load, degraded counts, migration backlog) as point-in-time
counters.  This module is the bridge from observation to control: a
`HealthMonitor` evaluates declarative `SLORule`s against a
`TimeSeriesStore` fed by the periodic ``run_online`` registry snapshots,
drives a firing -> resolved alert state machine with hysteresis, and hands
every transition to an ``on_alert`` callback — the entry point the
ROADMAP's hot-key autoscaler will consume.

* `SLORule` — name + a value function over the store (windowed avg span
  vs the fit-time baseline, p99 microbatch latency, degraded-query rate,
  partition load skew p99/mean, migration in-flight backlog are the
  built-ins from `HealthMonitor.from_flags`) + comparison + threshold +
  fire/resolve hysteresis counts.
* `Alert` — per-rule state: ``ok`` or ``firing``, with breach/clear
  streaks so a rule must breach ``fire_after`` consecutive evaluations to
  fire and hold clear for ``resolve_after`` to resolve — drift refits and
  failover storms cross a threshold for one window without flapping.
* EWMA z-score anomaly detection (``health_anomaly_z`` > 0): every rule's
  value stream additionally feeds an exponentially weighted mean/variance
  tracker; after a warmup, ``|value - ewma_mean| / ewma_std`` past the
  z threshold raises a ``<rule>_anomaly`` alert through the same state
  machine — a regime *change* fires even while the absolute SLO holds.

Alerts surface three ways, all read-only (the observation-changes-nothing
contract): tracer instant events (``alert.fire`` / ``alert.resolve``),
registry counters (``health_alerts_fired_total`` /
``health_alerts_resolved_total``, gauge ``health_alerts_active``), and the
``on_alert(alert, firing)`` callback.  `Simulator.run_online` folds the
fired/resolved totals into ``online_stats["alerts_fired"/"alerts_resolved"]``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import numpy as np

from .. import flags as _flags
from .timeseries import TimeSeriesStore

__all__ = ["SLORule", "Alert", "HealthMonitor"]


@dataclasses.dataclass
class SLORule:
    """One declarative SLO: fire while ``value(store) <op> threshold``.

    ``value`` returns the rule's current reading off the store, or None
    when the window holds too little data (no state change).  ``op`` is
    ``">"`` or ``"<"``.  Hysteresis: ``fire_after`` consecutive breaches
    to fire, ``resolve_after`` consecutive clears to resolve."""

    name: str
    value: Callable[[TimeSeriesStore], "float | None"]
    op: str
    threshold: float
    fire_after: int = 1
    resolve_after: int = 2

    def breached(self, v: float) -> bool:
        if self.op == ">":
            return v > self.threshold
        if self.op == "<":
            return v < self.threshold
        raise ValueError(f"unknown SLO op {self.op!r}")


@dataclasses.dataclass
class Alert:
    """Mutable per-rule alert state (one per rule, plus one per anomaly
    tracker).  ``fired_at`` / ``resolved_at`` are the ingest time
    coordinates (served+degraded queries under ``run_online``) of the most
    recent transitions."""

    name: str
    threshold: float
    state: str = "ok"          # "ok" | "firing"
    breach_streak: int = 0
    clear_streak: int = 0
    fires: int = 0
    resolves: int = 0
    fired_at: float | None = None
    resolved_at: float | None = None
    last_value: float | None = None

    @property
    def firing(self) -> bool:
        return self.state == "firing"


class _Ewma:
    """EWMA mean/variance tracker for the z-score anomaly detector."""

    __slots__ = ("alpha", "mean", "var", "count")

    def __init__(self, alpha: float):
        self.alpha = float(alpha)
        self.mean = 0.0
        self.var = 0.0
        self.count = 0

    def zscore(self, x: float) -> float | None:
        """z of ``x`` against the PRE-update statistics, then update."""
        z: float | None = None
        if self.count > 0:
            diff = x - self.mean
            std = math.sqrt(self.var)
            if std > 1e-12:
                z = diff / std
            else:
                # a flat history: any movement is infinitely surprising
                z = 0.0 if abs(diff) <= 1e-12 else math.inf
        diff = x - self.mean
        incr = self.alpha * diff
        self.mean += incr
        self.var = (1.0 - self.alpha) * (self.var + diff * incr)
        self.count += 1
        return z


class HealthMonitor:
    """Evaluates SLO rules between microbatches; see module docstring.

    Construct with explicit rules, or `from_flags` for the built-in rule
    set configured by the ``health_*`` flags.  ``observe(snapshot, t)`` is
    the single entry point `run_online` calls at every periodic snapshot:
    it ingests the snapshot into the store and runs one evaluation pass.
    """

    def __init__(self, rules: "list[SLORule]",
                 store: TimeSeriesStore | None = None,
                 on_alert: "Callable[[Alert, bool], None] | None" = None,
                 anomaly_z: float = 0.0, anomaly_alpha: float = 0.3,
                 anomaly_warmup: int = 5):
        names = [r.name for r in rules]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate SLO rule names: {sorted(names)}")
        self.rules = list(rules)
        self.store = store if store is not None else TimeSeriesStore()
        self.on_alert = on_alert
        self.anomaly_z = float(anomaly_z)
        self.anomaly_alpha = float(anomaly_alpha)
        self.anomaly_warmup = int(anomaly_warmup)
        self.baseline_span: float | None = None
        self.alerts: dict[str, Alert] = {
            r.name: Alert(r.name, r.threshold) for r in self.rules
        }
        self._ewma: dict[str, _Ewma] = {}
        self.history: list[dict] = []   # transition log, append-only
        self.stats = dict(checks=0, alerts_fired=0, alerts_resolved=0)

    # ------------------------------------------------------------ baseline
    def set_baseline(self, avg_span: float) -> None:
        """Pin the fit-time average span the windowed span rule compares
        against (``run_online`` supplies it right after the fit)."""
        self.baseline_span = float(avg_span)

    # ------------------------------------------------------------- ingest
    def observe(self, snapshot: dict, t: float) -> "list[Alert]":
        """Ingest one registry snapshot at time ``t`` and evaluate every
        rule.  Returns the alerts that TRANSITIONED this pass."""
        self.store.ingest(snapshot, t)
        return self.evaluate(t)

    def evaluate(self, t: float) -> "list[Alert]":
        self.stats["checks"] += 1
        transitions: list[Alert] = []
        for rule in self.rules:
            v = rule.value(self.store)
            if v is None:
                continue
            alert = self.alerts[rule.name]
            alert.last_value = float(v)
            if self._update(alert, rule.breached(float(v)),
                            rule.fire_after, rule.resolve_after, t):
                transitions.append(alert)
            if self.anomaly_z > 0:
                a = self._anomaly_pass(rule, float(v), t)
                if a is not None:
                    transitions.append(a)
        return transitions

    def _anomaly_pass(self, rule: SLORule, v: float,
                      t: float) -> "Alert | None":
        tracker = self._ewma.get(rule.name)
        if tracker is None:
            tracker = self._ewma[rule.name] = _Ewma(self.anomaly_alpha)
        z = tracker.zscore(v)
        if z is None or tracker.count <= self.anomaly_warmup:
            return None
        name = f"{rule.name}_anomaly"
        alert = self.alerts.get(name)
        if alert is None:
            alert = self.alerts[name] = Alert(name, self.anomaly_z)
        alert.last_value = abs(z) if math.isfinite(z) else float("inf")
        fired = self._update(alert, abs(z) > self.anomaly_z,
                             rule.fire_after, rule.resolve_after, t)
        return alert if fired else None

    # -------------------------------------------------------- state machine
    def _update(self, alert: Alert, breach: bool, fire_after: int,
                resolve_after: int, t: float) -> bool:
        """Advance one alert's state machine; True iff it transitioned."""
        if breach:
            alert.breach_streak += 1
            alert.clear_streak = 0
            if alert.state == "ok" and alert.breach_streak >= fire_after:
                alert.state = "firing"
                alert.fires += 1
                alert.fired_at = float(t)
                self._transition(alert, firing=True, t=t)
                return True
        else:
            alert.clear_streak += 1
            alert.breach_streak = 0
            if alert.state == "firing" and alert.clear_streak >= resolve_after:
                alert.state = "ok"
                alert.resolves += 1
                alert.resolved_at = float(t)
                self._transition(alert, firing=False, t=t)
                return True
        return False

    def _transition(self, alert: Alert, firing: bool, t: float) -> None:
        from .. import obs as _obs  # runtime import: obs/__init__ imports us

        kind = "fire" if firing else "resolve"
        self.stats["alerts_fired" if firing else "alerts_resolved"] += 1
        self.history.append(dict(
            t=float(t), alert=alert.name, kind=kind,
            value=alert.last_value, threshold=alert.threshold,
        ))
        reg = _obs.registry()
        if reg.active:
            reg.inc(f"health_alerts_{kind}d_total")
            reg.set("health_alerts_active", float(len(self.active_alerts())))
            tr = _obs.tracer()
            if tr.active:
                tr.event(f"alert.{kind}", rule=alert.name,
                         value=alert.last_value, threshold=alert.threshold)
        if self.on_alert is not None:
            self.on_alert(alert, firing)

    # ------------------------------------------------------------ accessors
    def active_alerts(self) -> "list[str]":
        return sorted(n for n, a in self.alerts.items() if a.firing)

    # ---------------------------------------------------------- from_flags
    @classmethod
    def from_flags(cls, on_alert=None) -> "HealthMonitor":
        """The built-in rule set, thresholds from the ``health_*`` flags
        (a threshold of 0 disables its rule).  The span rule reads the
        monitor's ``baseline_span`` (set by ``run_online`` post-fit), so
        its value is the *ratio* windowed avg span / baseline and the
        threshold is ``health_span_slo`` directly."""
        F = _flags.FLAGS
        w = int(F.get("health_window", 8))
        if w < 2:
            raise ValueError(f"health_window must be >= 2, got {w}")
        fire_after = 1
        resolve_after = int(F.get("health_hysteresis", 2))
        if resolve_after < 1:
            raise ValueError(
                f"health_hysteresis must be >= 1, got {resolve_after}"
            )
        monitor: dict = {}  # forward cell so closures see the instance

        def span_ratio(store: TimeSeriesStore) -> "float | None":
            base = monitor["m"].baseline_span
            if base is None or base <= 0:
                return None
            ds = store.delta("online_span_sum", w)
            dq = store.delta("online_served_queries", w)
            if ds is None or dq is None or dq <= 0:
                return None
            return (ds / dq) / base

        def degraded_rate(store: TimeSeriesStore) -> "float | None":
            dd = store.delta("online_degraded_queries", w)
            dq = store.delta("online_served_queries", w)
            if dd is None or dq is None or dd + dq <= 0:
                return None
            return dd / (dd + dq)

        def load_skew(store: TimeSeriesStore) -> "float | None":
            d = store.vector_delta("online_partition_load", w)
            if not len(d):
                return None
            m = float(d.mean())
            if m <= 1e-12:
                return None
            return float(np.quantile(d, 0.99)) / m

        def p99_latency(store: TimeSeriesStore) -> "float | None":
            return store.histogram_quantile(
                "router_microbatch_seconds", 0.99, w
            )

        def backlog(store: TimeSeriesStore) -> "float | None":
            return store.mean("migration_inflight", w)

        specs = [
            ("span_slo", span_ratio, float(F.get("health_span_slo", 0.0))),
            ("degraded_rate", degraded_rate,
             float(F.get("health_degraded_slo", 0.0))),
            ("load_skew", load_skew, float(F.get("health_skew_slo", 0.0))),
            ("latency_p99", p99_latency,
             float(F.get("health_p99_slo", 0.0))),
            ("migration_backlog", backlog,
             float(F.get("health_backlog_slo", 0.0))),
        ]
        rules = [
            SLORule(name, fn, ">", thr, fire_after=fire_after,
                    resolve_after=resolve_after)
            for name, fn, thr in specs if thr > 0
        ]
        m = cls(rules, on_alert=on_alert,
                anomaly_z=float(F.get("health_anomaly_z", 0.0)))
        monitor["m"] = m
        return m
