"""Sharded checkpoint save/restore.

Format: one .npz bundle per logical SHARD (a slice of the flattened param +
optimizer-state tree) plus a JSON manifest with the tree structure, shapes,
dtypes and step metadata.  Atomicity: writes go to <dir>.tmp then rename.

Shards are the unit the paper's placement engine reasons about: the manager
(manager.py) builds restore-sets (which host needs which shards) and places
shard REPLICAS with PRA-3W so single-host restart touches few storage nodes
while surviving RF-1 storage failures — fault tolerance and restart locality
from the same mechanism (DESIGN.md §2.3).

Elastic rescale: restore() re-shards to whatever mesh is active — arrays are
saved UNSHARDED per shard-file (host-local numpy), so a 512-chip checkpoint
restores onto 256 chips (or any mesh) unchanged.
"""

from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np

MANIFEST = "manifest.json"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat]
    return keys, [leaf for _, leaf in flat], treedef


def save_checkpoint(path: str, tree, step: int, num_shards: int = 8) -> dict:
    """Returns the manifest (incl. shard -> keys map)."""
    keys, leaves, _ = _flatten(tree)
    order = np.argsort([-np.prod(np.asarray(l.shape, dtype=np.int64))
                        if hasattr(l, "shape") else 0 for l in leaves])
    # round-robin by size: balances shard bytes
    shard_of = {}
    loads = [0] * num_shards
    for i in order:
        s = int(np.argmin(loads))
        shard_of[int(i)] = s
        loads[s] += int(np.prod(leaves[i].shape)) if hasattr(leaves[i], "shape") else 1
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    shard_keys: dict[int, list[int]] = {s: [] for s in range(num_shards)}
    for i, s in shard_of.items():
        shard_keys[s].append(i)
    for s, idxs in shard_keys.items():
        arrays = {str(i): np.asarray(leaves[i]) for i in idxs}
        np.savez(os.path.join(tmp, f"shard_{s:05d}.npz"), **arrays)
    manifest = dict(
        step=step,
        num_shards=num_shards,
        keys=keys,
        shard_of={str(i): s for i, s in shard_of.items()},
    )
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    return manifest


def load_checkpoint(path: str, tree_like, shardings=None):
    """Restore into the structure of `tree_like`; apply `shardings` tree (or
    replicate) — this is the elastic-rescale entry point."""
    with open(os.path.join(path, MANIFEST)) as f:
        manifest = json.load(f)
    keys, leaves, treedef = _flatten(tree_like)
    assert keys == manifest["keys"], "checkpoint/model structure mismatch"
    loaded: dict[int, np.ndarray] = {}
    for s in range(manifest["num_shards"]):
        f = os.path.join(path, f"shard_{s:05d}.npz")
        if not os.path.exists(f):
            continue
        with np.load(f) as z:
            for k in z.files:
                loaded[int(k)] = z[k]
    missing = [i for i in range(len(keys)) if i not in loaded]
    if missing:
        raise FileNotFoundError(
            f"checkpoint missing {len(missing)} leaves (lost shards?): "
            f"{[keys[i] for i in missing[:4]]}"
        )
    new_leaves = []
    flat_shard = (jax.tree_util.tree_leaves(shardings)
                  if shardings is not None else [None] * len(keys))
    for i in range(len(keys)):
        arr = loaded[i]
        if flat_shard[i] is not None:
            new_leaves.append(jax.device_put(arr, flat_shard[i]))
        else:
            new_leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, new_leaves), manifest["step"]
