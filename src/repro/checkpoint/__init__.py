from .checkpoint import load_checkpoint, save_checkpoint  # noqa: F401
from .manager import CheckpointManager  # noqa: F401
