"""Checkpoint manager: async saves, keep-K retention, restart discovery, and
placement-driven replica distribution of checkpoint shards.

The replica placement is the paper's machinery verbatim: shards are items,
each host's restore-set is a hyperedge, storage nodes are partitions; PRA-3W
places RF copies so that (a) any RF-1 storage-node failures leave every shard
recoverable and (b) a restarting host reads from few storage nodes (restore
span — measured in benchmarks/placement_applications.py).
"""

from __future__ import annotations

import os
import re
import threading

import numpy as np

from repro.core import plan_shard_placement

from .checkpoint import load_checkpoint, save_checkpoint

_STEP_RE = re.compile(r"step_(\d+)$")


class CheckpointManager:
    def __init__(
        self,
        directory: str,
        keep: int = 3,
        num_shards: int = 8,
        num_storage_nodes: int = 4,
        replication: int = 2,
        async_save: bool = True,
    ):
        self.dir = directory
        self.keep = keep
        self.num_shards = num_shards
        self.async_save = async_save
        self.num_storage_nodes = num_storage_nodes
        self.replication = min(replication, num_storage_nodes)
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self.replica_plan = None

    # ---------------------------------------------------------------- paths
    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            m = _STEP_RE.search(d)
            if m and not d.endswith(".tmp"):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ----------------------------------------------------------------- save
    def save(self, step: int, tree, restore_sets=None, blocking=None):
        """`restore_sets`: optional list of shard-id arrays (one per restoring
        host) used to fit the replica placement for this checkpoint."""
        self.wait()

        def _do():
            save_checkpoint(self._path(step), tree, step, self.num_shards)
            self._gc()
            if restore_sets is not None:
                self.replica_plan = plan_shard_placement(
                    restore_sets, self.num_shards, self.num_storage_nodes,
                    capacity=max(
                        2.0,
                        np.ceil(self.num_shards * self.replication
                                / self.num_storage_nodes) + 1,
                    ),
                    algorithm="pra3", rf=self.replication,
                )

        if self.async_save if blocking is None else not blocking:
            self._thread = threading.Thread(target=_do, daemon=True)
            self._thread.start()
        else:
            _do()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            import shutil
            shutil.rmtree(self._path(s), ignore_errors=True)

    # -------------------------------------------------------------- restore
    def restore_latest(self, tree_like, shardings=None):
        self.wait()
        step = self.latest_step()
        if step is None:
            return None, None
        tree, saved_step = load_checkpoint(self._path(step), tree_like,
                                           shardings)
        return tree, saved_step

    def restore_span(self, host_restore_set) -> int:
        """Storage nodes one host touches to restore (needs a replica plan)."""
        if self.replica_plan is None:
            raise RuntimeError("no replica plan fitted (pass restore_sets to save)")
        return self.replica_plan.span(np.asarray(host_restore_set))
