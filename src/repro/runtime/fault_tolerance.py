"""Fault-tolerant training runner.

Failure model (multi-pod fleets): data hosts die or slow down (input side),
accelerator workers die (step side), storage nodes die (checkpoint side).
Responses, in order of cheapness:

  1. input-host failure  -> replica re-cover via the paper's placement
     (pipeline.cover_excluding) — zero step disruption, the span increase is
     bounded and measured;
  2. straggling host     -> same mechanism, proactively (StragglerDetector);
  3. worker/step failure -> restart from the CheckpointManager's latest
     step, whose shard replicas survive storage failures (PRA-3W placement);
  4. fleet resize        -> elastic_remesh: restore onto a different mesh.

This runner simulates the control flow end-to-end on CPU (the integration
test injects failures at every layer and asserts the run completes with the
right number of optimizer steps).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data import PlacementAwarePipeline

from .straggler import StragglerDetector


@dataclasses.dataclass
class HostHealth:
    alive: bool = True
    slow: bool = False


class StepFailure(Exception):
    """Raised by the step function when an accelerator worker dies."""


class FaultTolerantRunner:
    def __init__(
        self,
        step_fn: Callable,            # (state, batch) -> (state, metrics)
        state,                        # pytree (params, opt_state, ...)
        pipeline: PlacementAwarePipeline,
        ckpt: CheckpointManager,
        ckpt_every: int = 20,
        max_restarts: int = 8,
    ):
        self.step_fn = step_fn
        self.state = state
        self.pipeline = pipeline
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.straggler = StragglerDetector(pipeline.num_hosts)
        self.step = 0
        self.restarts = 0
        self.events: list[tuple[int, str]] = []

    # ------------------------------------------------------------- failures
    def kill_input_host(self, host: int):
        self.pipeline.mark_dead(host)
        self.events.append((self.step, f"input_host_dead:{host}"))

    def report_host_latency(self, host: int, seconds: float):
        if self.straggler.observe(host, seconds):
            self.pipeline.mark_slow(host)
            self.events.append((self.step, f"straggler_avoided:{host}"))

    # ----------------------------------------------------------------- run
    def run(self, num_steps: int) -> dict:
        while self.step < num_steps:
            try:
                batch = self.pipeline.next_batch()
                t0 = time.perf_counter()
                self.state, metrics = self.step_fn(self.state, batch)
                dt = time.perf_counter() - t0
                for h in batch["hosts"]:
                    self.report_host_latency(h, dt / max(len(batch["hosts"]), 1))
                self.step += 1
                if self.step % self.ckpt_every == 0:
                    self.ckpt.save(self.step, self.state)
            except StepFailure as exc:
                self.restarts += 1
                self.events.append((self.step, f"step_failure:{exc}"))
                if self.restarts > self.max_restarts:
                    raise RuntimeError("restart budget exhausted") from exc
                restored, saved_step = self.ckpt.restore_latest(self.state)
                if restored is not None:
                    self.state = restored
                    self.step = saved_step
                else:
                    self.step = 0  # cold restart
        self.ckpt.save(self.step, self.state, blocking=True)
        return dict(
            steps=self.step,
            restarts=self.restarts,
            avg_input_span=self.pipeline.avg_span(),
            events=self.events,
        )
