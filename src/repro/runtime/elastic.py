"""Elastic rescaling: move a training state between meshes.

Checkpoints store host-local full arrays per shard (never device layouts), so
restoring onto a different mesh is just re-applying the sharding rules for
the new mesh.  `elastic_remesh` does the same for an in-memory state — used
when a pod is drained/added mid-run: the runner saves, the fleet re-forms,
and the state is re-dealt onto the surviving topology."""

from __future__ import annotations

import jax

from repro.parallel import param_shardings


def elastic_remesh(state_tree, new_mesh, fsdp: bool = True):
    """Re-shard every leaf of `state_tree` for `new_mesh` (same global
    values, new layout)."""
    struct = jax.eval_shape(lambda: state_tree)
    shardings = param_shardings(struct, new_mesh, fsdp=fsdp)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), state_tree, shardings
    )
