from .fault_tolerance import FaultTolerantRunner, HostHealth  # noqa: F401
from .straggler import StragglerDetector  # noqa: F401
from .elastic import elastic_remesh  # noqa: F401
