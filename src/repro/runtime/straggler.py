"""Straggler detection: per-host latency EWMA vs fleet median.

A host whose smoothed latency exceeds `threshold` x the fleet median is
flagged; the caller re-covers its work from replicas (the paper's replica
selection), which is cheaper than speculative re-execution because the
placement guarantees low-span alternatives exist."""

from __future__ import annotations

import numpy as np


class StragglerDetector:
    def __init__(self, num_hosts: int, alpha: float = 0.3,
                 threshold: float = 3.0, min_samples: int = 5):
        self.ewma = np.zeros(num_hosts)
        self.count = np.zeros(num_hosts, dtype=np.int64)
        self.alpha = alpha
        self.threshold = threshold
        self.min_samples = min_samples

    def observe(self, host: int, seconds: float) -> bool:
        """Returns True when `host` should be treated as a straggler."""
        if self.count[host] == 0:
            self.ewma[host] = seconds
        else:
            self.ewma[host] = (
                self.alpha * seconds + (1 - self.alpha) * self.ewma[host]
            )
        self.count[host] += 1
        seen = self.count >= 1
        if self.count[host] < self.min_samples or seen.sum() < 3:
            return False
        med = float(np.median(self.ewma[seen]))
        return bool(self.ewma[host] > self.threshold * max(med, 1e-9))
