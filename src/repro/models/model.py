"""Full model assembly: init, forward, train loss, prefill, decode.

Layer iteration modes:
  * scan (default): per-layer params stacked on a leading axis, lax.scan with
    optional remat — compact HLO even for 61-layer models.
  * unrolled: per-layer python loop (used by hymba, whose global-attention
    layers carry full-length caches while SWA layers carry ring buffers).

Cache layout: {"layers": <stacked or list of block caches>,
               "encoder": (enc_hidden, enc_pos) | None}   (enc-dec serving
reuses the encoder states computed at prefill instead of re-running the
encoder every decode step.)

Steps exposed to the launcher:
  * train_loss(cfg, params, batch)                    (train_4k)
  * prefill(cfg, params, batch)   -> logits, cache    (prefill_32k)
  * decode_step(cfg, params, cache, tokens, positions) (decode_*, long_*)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel import constrain

from . import blocks
from .layers import apply_norm, embed_lookup, init_embed, init_norm, unembed

__all__ = [
    "init_params", "forward", "train_loss", "prefill", "decode_step",
    "init_cache", "layer_windows", "uses_scan",
]


# ---------------------------------------------------------------- structure
HUGE_WINDOW = 1 << 30


def uses_scan(cfg) -> bool:
    """Hymba mixes cache SHAPES across layers (full vs ring KV), so its
    cached (serving) path unrolls; every cache-free path scans (per-layer
    SWA windows ride along as traced scan inputs).  Parameters are always
    stored layer-stacked."""
    return cfg.attention != "hybrid"


def layer_windows(cfg) -> list:
    """Per-layer attention window (None = full attention)."""
    out = []
    for i in range(cfg.num_layers):
        if cfg.attention == "hybrid" and cfg.global_attn_every:
            is_global = (i % cfg.global_attn_every == 0) or (i == cfg.num_layers - 1)
            out.append(None if is_global else cfg.sliding_window)
        else:
            out.append(cfg.sliding_window)
    return out


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# --------------------------------------------------------------------- init
def init_params(cfg, key, moe_dispatch=None) -> dict:
    dtype = _dtype(cfg)
    keys = jax.random.split(key, 8)
    p: dict = {"embed": init_embed(keys[0], cfg.vocab_size, cfg.d_model, dtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = init_embed(keys[1], cfg.vocab_size, cfg.d_model, dtype)
    p["final_norm"] = init_norm(cfg.norm, cfg.d_model, dtype)
    cross = cfg.encoder_layers > 0

    kd = cfg.moe.first_k_dense if cfg.moe else 0
    if kd:
        p["dense_blocks"] = jax.vmap(
            lambda k: blocks.init_block(
                k, cfg, dtype, layer_idx=0, cross_attention=cross,
                force_dense=True)
        )(jax.random.split(keys[2], kd))
    p["blocks"] = jax.vmap(
        lambda k: blocks.init_block(
            k, cfg, dtype, layer_idx=kd, cross_attention=cross,
            moe_dispatch=moe_dispatch)
    )(jax.random.split(keys[3], cfg.num_layers - kd))
    if cfg.encoder_layers:
        p["enc_blocks"] = jax.vmap(
            lambda k: blocks.init_block(k, cfg, dtype, layer_idx=0)
        )(jax.random.split(keys[4], cfg.encoder_layers))
        p["enc_final_norm"] = init_norm(cfg.norm, cfg.d_model, dtype)
    if cfg.frontend:
        from .layers import dense_init
        p["frontend_proj"] = dense_init(keys[5], (cfg.d_model, cfg.d_model),
                                        dtype)
    if cfg.mtp_depth:
        from .layers import dense_init
        p["mtp"] = {
            "proj": dense_init(keys[6], (2 * cfg.d_model, cfg.d_model), dtype),
            "block": blocks.init_block(keys[7], cfg, dtype, layer_idx=0,
                                       force_dense=True),
            "norm": init_norm(cfg.norm, cfg.d_model, dtype),
        }
    return p


# ------------------------------------------------------------------- caches
def init_cache(cfg, batch: int, max_len: int, *, window_only: bool = False):
    """window_only=True sizes SWA-layer caches at the window width
    (long-context serving: ring buffers instead of 500k dense caches)."""
    dtype = _dtype(cfg)
    wins = layer_windows(cfg)
    if uses_scan(cfg):
        w = wins[0]
        one = blocks.init_block_cache(
            cfg, batch, max_len, dtype,
            window=(w if (window_only and w) else None),
        )
        layers = jax.tree.map(
            lambda x: jnp.broadcast_to(
                x[None], (cfg.num_layers,) + x.shape).copy(),
            one,
        )
    else:
        layers = [
            blocks.init_block_cache(
                cfg, batch, max_len, dtype,
                window=(wins[i] if (window_only and wins[i]) else None),
            )
            for i in range(cfg.num_layers)
        ]
    return {"layers": layers, "encoder": None}


# ------------------------------------------------------------------ forward
def _embed_inputs(cfg, params, tokens, frontend_embeds):
    x = embed_lookup(params["embed"], tokens)
    if cfg.frontend == "vision_patches" and frontend_embeds is not None:
        # VLM stub: visual tokens replace the first F decoder positions
        f = frontend_embeds.shape[1]
        vis = jnp.einsum("bfd,de->bfe", frontend_embeds, params["frontend_proj"])
        x = jnp.concatenate([vis.astype(x.dtype), x[:, f:]], axis=1)
    return x


def _run_encoder(cfg, params, frontend_embeds):
    """Seamless audio stub: frame embeddings -> encoder stack."""
    x = jnp.einsum("bfd,de->bfe", frontend_embeds, params["frontend_proj"])
    x = x.astype(_dtype(cfg))
    pos = jnp.broadcast_to(
        jnp.arange(x.shape[1], dtype=jnp.int32)[None], x.shape[:2]
    )

    def body(h, layer_params):
        h, _, _ = blocks.apply_block(layer_params, cfg, h, pos, causal=False)
        return h, None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    x = apply_norm(cfg.norm, params["enc_final_norm"], x)
    return x, pos


def _cross_kv_from(cfg, layer_params, enc_states):
    enc_h, enc_pos = enc_states
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    b, f, _ = enc_h.shape
    k = jnp.einsum("bfd,de->bfe", enc_h,
                   layer_params["cross"]["wk"]).reshape(b, f, kv, hd)
    v = jnp.einsum("bfd,de->bfe", enc_h,
                   layer_params["cross"]["wv"]).reshape(b, f, kv, hd)
    return (k, v, enc_pos)


def _decoder_stack(
    cfg, params, x, positions, *, layer_caches=None, enc_states=None,
    moe_dispatch=None, remat=False, chunk=512,
):
    """Returns (hidden, new_layer_caches, aux_sums)."""
    wins = layer_windows(cfg)
    zero_aux = {"lb_loss": jnp.zeros((), jnp.float32),
                "z_loss": jnp.zeros((), jnp.float32)}

    def one_layer(h, aux, layer_params, layer_cache, window):
        cross_kv = None
        if enc_states is not None and "cross" in layer_params:
            cross_kv = _cross_kv_from(cfg, layer_params, enc_states)
        h, new_c, a = blocks.apply_block(
            layer_params, cfg, h, positions, window=window,
            cache=layer_cache, cross_kv=cross_kv, moe_dispatch=moe_dispatch,
            chunk=chunk,
        )
        # keep activations batch-DP-sharded across layers: without this pin,
        # GSPMD may gather the batch to exploit weight shardings (measured
        # multi-GB all-gathers on the production mesh)
        h = constrain(h, "act")
        aux = {k: aux[k] + a[k].astype(jnp.float32) if k in a else aux[k]
               for k in aux}
        return h, aux, new_c

    if uses_scan(cfg) or layer_caches is None:
        # mixed per-layer windows (hymba) ride along as a traced scan input;
        # HUGE_WINDOW disables the window mask numerically
        mixed_windows = len(set(wins)) > 1
        window_arr = jnp.asarray(
            [w if w is not None else HUGE_WINDOW for w in wins], jnp.int32
        )
        kd = cfg.moe.first_k_dense if cfg.moe else 0
        groups = ([("dense_blocks", kd)] if kd else []) + [
            ("blocks", cfg.num_layers - kd)
        ]

        h, aux = x, zero_aux
        new_caches, offset = [], 0
        for gname, glen in groups:
            gparams = params[gname]
            gwin = window_arr[offset : offset + glen]
            static_window = None if mixed_windows else wins[0]
            if layer_caches is not None:
                gcache = jax.tree.map(
                    lambda c, off=offset, n=glen: c[off : off + n], layer_caches
                )

                def body_c(carry, xs):
                    h, aux = carry
                    lp, lc, w = xs
                    h, aux, new_c = one_layer(
                        h, aux, lp, lc,
                        w if mixed_windows else static_window)
                    return (h, aux), new_c

                (h, aux), upd = jax.lax.scan(body_c, (h, aux),
                                             (gparams, gcache, gwin))
                new_caches.append(upd)
            else:

                def body_nc(carry, xs):
                    h, aux = carry
                    lp, w = xs
                    h, aux, _ = one_layer(
                        h, aux, lp, None,
                        w if mixed_windows else static_window)
                    return (h, aux), None

                fn = body_nc
                if remat:
                    fn = jax.checkpoint(
                        body_nc,
                        policy=jax.checkpoint_policies.nothing_saveable,
                    )
                (h, aux), _ = jax.lax.scan(fn, (h, aux), (gparams, gwin))
            offset += glen
        if layer_caches is not None:
            new_layer_caches = (
                jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                             *new_caches)
                if len(new_caches) > 1 else new_caches[0]
            )
        else:
            new_layer_caches = None
        return h, new_layer_caches, aux

    # ---- unrolled serving path (hymba: per-layer cache shapes differ)
    h, aux = x, zero_aux
    new_list = []
    for i in range(cfg.num_layers):
        layer_params = jax.tree.map(lambda v: v[i], params["blocks"])
        lc = layer_caches[i]
        h, aux, new_c = one_layer(h, aux, layer_params, lc, wins[i])
        new_list.append(new_c)
    return h, new_list, aux


def forward(
    cfg, params, tokens, *, positions=None, frontend_embeds=None,
    cache=None, moe_dispatch=None, remat=False, chunk=512,
):
    """Returns (logits_fp32, new_cache, aux, hidden)."""
    b, s = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                     (b, s))
    enc_states = None
    if cfg.encoder_layers:
        if cache is not None and cache.get("encoder") is not None:
            enc_states = cache["encoder"]
        elif frontend_embeds is not None:
            enc_states = _run_encoder(cfg, params, frontend_embeds)
        else:
            raise ValueError("encoder-decoder model needs frontend_embeds "
                             "or cached encoder states")
        x = embed_lookup(params["embed"], tokens)
    else:
        x = _embed_inputs(cfg, params, tokens, frontend_embeds)
    x = constrain(x, "act")
    layer_caches = cache["layers"] if cache is not None else None
    h, new_layer_caches, aux = _decoder_stack(
        cfg, params, x, positions, layer_caches=layer_caches,
        enc_states=enc_states, moe_dispatch=moe_dispatch, remat=remat,
        chunk=chunk,
    )
    h = apply_norm(cfg.norm, params["final_norm"], h)
    emb = params["unembed"] if "unembed" in params else params["embed"]
    logits = constrain(unembed(emb, h), "logits")
    new_cache = None
    if cache is not None:
        new_cache = {"layers": new_layer_caches, "encoder": enc_states}
    return logits, new_cache, aux, h


# -------------------------------------------------------------------- steps
def softmax_xent(logits, targets, mask=None):
    """One-hot-einsum cross entropy: unlike take_along_axis, the label pick
    partitions cleanly when the vocab dim is TP-sharded (no (B,S,V)
    all-gather; GSPMD reduces the partial picks with a (B,S) all-reduce)."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
    picked = jnp.einsum("bsv,bsv->bs", logits, onehot)
    nll = lse - picked
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def train_loss(cfg, params, batch, *, moe_dispatch=None, chunk=512):
    """batch: tokens (B,S), targets (B,S), optional frontend (B,F,d)."""
    logits, _, aux, h = forward(
        cfg, params, batch["tokens"],
        frontend_embeds=batch.get("frontend"),
        moe_dispatch=moe_dispatch, remat=True, chunk=chunk,
    )
    loss = softmax_xent(logits, batch["targets"], batch.get("mask"))
    metrics = {"xent": loss}
    if cfg.moe:
        loss = loss + 0.01 * aux["lb_loss"] + 1e-4 * aux["z_loss"]
        metrics.update(lb_loss=aux["lb_loss"], z_loss=aux["z_loss"])
    if cfg.mtp_depth and "mtp" in params:
        # deepseek MTP: one extra block predicts t+2 from [h_t ; emb(y_{t+1})]
        emb_next = embed_lookup(params["embed"], batch["targets"])
        mtp_in = jnp.einsum(
            "bse,ed->bsd",
            jnp.concatenate([h, emb_next.astype(h.dtype)], axis=-1),
            params["mtp"]["proj"],
        )
        pos = jnp.broadcast_to(
            jnp.arange(mtp_in.shape[1], dtype=jnp.int32)[None],
            mtp_in.shape[:2],
        )
        mh, _, _ = blocks.apply_block(params["mtp"]["block"], cfg, mtp_in, pos,
                                      chunk=chunk)
        mh = apply_norm(cfg.norm, params["mtp"]["norm"], mh)
        emb = params["unembed"] if "unembed" in params else params["embed"]
        mtp_logits = unembed(emb, mh[:, :-1])
        mtp_loss = softmax_xent(mtp_logits, batch["targets"][:, 1:])
        loss = loss + 0.3 * mtp_loss
        metrics["mtp_loss"] = mtp_loss
    metrics["loss"] = loss
    return loss, metrics


def prefill(cfg, params, batch, *, max_len=None, moe_dispatch=None, chunk=512,
            window_only=False):
    """Run the full prompt, building the serving cache.  Returns
    (last_token_logits, cache)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    cache = init_cache(cfg, b, max_len or s, window_only=window_only)
    if cfg.encoder_layers:
        cache["encoder"] = _run_encoder(cfg, params, batch["frontend"])
    logits, cache, _, _ = forward(
        cfg, params, tokens, cache=cache,
        frontend_embeds=batch.get("frontend"),
        moe_dispatch=moe_dispatch, chunk=chunk,
    )
    return logits[:, -1], cache


def decode_step(cfg, params, cache, tokens, positions, *, moe_dispatch=None,
                chunk=512):
    """One serving step: tokens (B,1) at `positions` (B,1).  Returns
    (logits (B,V), new_cache)."""
    logits, new_cache, _, _ = forward(
        cfg, params, tokens, positions=positions, cache=cache,
        moe_dispatch=moe_dispatch, chunk=chunk,
    )
    return logits[:, -1], new_cache
