"""Mixture-of-Experts block with workload-driven expert placement.

Routing: top-k softmax router (+ optional shared experts, deepseek-style).
Dispatch: sort-based ragged dispatch into per-SLOT capacity buffers — no
(tokens, E, C) one-hot materialization, so 1M-token steps lower to compact
HLO.  The slot buffer (num_slots, C, d) is sharded over the `model` mesh
axis (expert parallelism); GSPMD inserts the token all-to-all.

THE PAPER'S TECHNIQUE lives in the expert->slot mapping: `slot_of` is a
(num_experts, num_ranks) replica-selection table produced by
repro.core.expert_placement (LMBR/PRA over a routing trace).  Hot or
co-firing experts occupy multiple slots; each token group selects the
replica that minimizes the EP ranks it must reach (greedy set cover on the
placement).  With the identity placement (slots == experts, no replicas)
this reduces to standard EP.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel import constrain

from .layers import dense_init


def _shard_map(body, *, mesh, in_specs, out_specs):
    """Replication checking was renamed check_rep -> check_vma when shard_map
    graduated from jax.experimental to jax.shard_map; disable it under either
    spelling (the MoE body mixes replicated aux losses with sharded tokens,
    which the checker rejects)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map

    return shard_map(body, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)

__all__ = ["init_moe", "apply_moe", "identity_dispatch", "MoEDispatch"]


@dataclasses.dataclass(frozen=True)
class MoEDispatch:
    """Device-side expert->slot routing tables (from the placement engine).

    slot_of[e, r]: the slot id a token originating on EP rank r should use
    for expert e (replica selection baked into a lookup).  num_slots >=
    num_experts; slot s on rank s // slots_per_rank.
    """

    num_slots: int
    num_ranks: int
    slot_of: np.ndarray          # (num_experts, num_ranks) int32
    slot_to_expert: np.ndarray   # (num_slots,) int32 (for weight gathering)

    @property
    def slots_per_rank(self) -> int:
        return self.num_slots // self.num_ranks


def identity_dispatch(num_experts: int, num_ranks: int = 1) -> MoEDispatch:
    slot_of = np.tile(np.arange(num_experts, dtype=np.int32)[:, None],
                      (1, num_ranks))
    return MoEDispatch(num_experts, num_ranks, slot_of,
                       np.arange(num_experts, dtype=np.int32))


def dispatch_from_plan(plan) -> MoEDispatch:
    """Build device tables from a repro.core ExpertPlacementPlan."""
    num_slots = plan.num_ranks * plan.slots_per_rank
    slot_to_expert = np.full((num_slots,), 0, dtype=np.int32)
    for r in range(plan.num_ranks):
        for s in range(plan.slots_per_rank):
            e = plan.slot_to_expert[r, s]
            slot_to_expert[r * plan.slots_per_rank + s] = max(int(e), 0)
    slot_of = np.zeros((plan.num_experts, plan.num_ranks), dtype=np.int32)
    for e in range(plan.num_experts):
        ranks = np.flatnonzero(plan.expert_slot_table[e] >= 0)
        for r in range(plan.num_ranks):
            # replica selection: prefer a copy on the token's own rank, else
            # the first (deterministic) replica — the greedy-cover choice for
            # a single-expert read
            src = r if r in set(ranks.tolist()) else int(ranks[0])
            slot_of[e, r] = src * plan.slots_per_rank + int(
                plan.expert_slot_table[e, src]
            )
    return MoEDispatch(num_slots, plan.num_ranks, slot_of, slot_to_expert)


def init_moe(key, cfg, dtype, dispatch: MoEDispatch | None = None) -> dict:
    """Expert weights are stored SLOT-major (replicated experts share values
    via slot_to_expert gather at init / checkpoint load)."""
    m = cfg.moe
    d = cfg.d_model
    dispatch = dispatch or identity_dispatch(m.num_experts)
    ks = jax.random.split(key, 5)
    n_slots = dispatch.num_slots
    # init per-EXPERT then gather to slots so replicas start identical
    we_gate = dense_init(ks[0], (m.num_experts, d, m.d_ff_expert), dtype)
    we_up = dense_init(ks[1], (m.num_experts, d, m.d_ff_expert), dtype)
    we_down = dense_init(ks[2], (m.num_experts, m.d_ff_expert, d), dtype)
    s2e = jnp.asarray(dispatch.slot_to_expert)
    params = {
        "router": dense_init(ks[3], (d, m.num_experts), jnp.float32),
        "we_gate": we_gate[s2e] if n_slots != m.num_experts else we_gate,
        "we_up": we_up[s2e] if n_slots != m.num_experts else we_up,
        "we_down": we_down[s2e] if n_slots != m.num_experts else we_down,
    }
    if m.num_shared_experts:
        ff_sh = m.d_ff_expert * m.num_shared_experts
        params["shared"] = {
            "wi_gate": dense_init(ks[4], (d, ff_sh), dtype),
            "wi_up": dense_init(jax.random.fold_in(ks[4], 1), (d, ff_sh), dtype),
            "wo": dense_init(jax.random.fold_in(ks[4], 2), (ff_sh, d), dtype),
        }
    return params


def apply_moe(
    params: dict,
    cfg,
    x: jax.Array,                 # (B, S, d)
    dispatch: MoEDispatch | None = None,
    capacity_factor: float | None = None,
):
    """Returns (y, aux) with aux = load-balancing loss terms.

    Two implementations:
      * distributed (active mesh with model-axis > 1): explicit shard_map
        all-to-all dispatch — the production EP pattern.  GSPMD's automatic
        partitioning of the scatter/gather formulation was measured to
        produce TB-scale all-reduces on deepseek-v3 train_4k (EXPERIMENTS.md
        §Perf), so the collective schedule is written by hand here.
      * local (tests / single device): sort-based ragged dispatch below.
    """
    from repro.parallel import active_mesh

    mesh = active_mesh()
    if mesh is not None and mesh.shape.get("model", 1) > 1:
        return _apply_moe_shard_map(params, cfg, x, dispatch, mesh,
                                    capacity_factor)

    from repro.flags import FLAGS

    m = cfg.moe
    dispatch = dispatch or identity_dispatch(m.num_experts)
    b, s, d = x.shape
    n = b * s
    k = m.top_k
    cf = capacity_factor or FLAGS["moe_cf"] or m.capacity_factor
    n_slots = dispatch.num_slots
    xf = x.reshape(n, d)

    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)            # (n, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # ---- paper technique: expert id -> slot id via replica-selection table.
    # token's EP rank = its position among the model-axis shards
    tokens_per_rank = max(1, n // dispatch.num_ranks)
    src_rank = jnp.minimum(
        jnp.arange(n, dtype=jnp.int32) // tokens_per_rank,
        dispatch.num_ranks - 1,
    )
    slot_of = jnp.asarray(dispatch.slot_of)           # (E, R)
    top_slot = slot_of[top_e, src_rank[:, None]]      # (n, k)

    # ---- sort-based ragged dispatch to per-slot capacity buffers
    capacity = int(max(8, np.ceil(n * k / n_slots * cf)))
    flat_slot = top_slot.reshape(-1)                  # (n*k,)
    sort_idx = jnp.argsort(flat_slot)
    sorted_slot = flat_slot[sort_idx]
    token_idx = sort_idx // k
    seg_start = jnp.searchsorted(sorted_slot, jnp.arange(n_slots))
    pos_in_slot = jnp.arange(n * k) - seg_start[sorted_slot]
    keep = pos_in_slot < capacity
    pos_in_slot = jnp.where(keep, pos_in_slot, 0)

    buf = jnp.zeros((n_slots, capacity, d), x.dtype)
    buf = buf.at[sorted_slot, pos_in_slot].add(
        jnp.where(keep[:, None], xf[token_idx], 0).astype(x.dtype)
    )
    # EP layout: slots across 'model' (the token routing between the DP-
    # sharded stream and the EP-sharded buffer is GSPMD's all-to-all)
    buf = constrain(buf, "moe_buf")
    # expert FFN per slot (swiglu)
    h = jnp.einsum("ecd,edf->ecf", buf, params["we_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, params["we_up"])
    obuf = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, params["we_down"])
    obuf = constrain(obuf, "moe_buf")

    vals = obuf[sorted_slot, pos_in_slot]             # (n*k, d)
    w = top_w.reshape(-1)[sort_idx]
    contrib = jnp.where(keep[:, None], vals * w[:, None].astype(vals.dtype), 0)
    y = jnp.zeros((n, d), x.dtype).at[token_idx].add(contrib.astype(x.dtype))
    y = constrain(y, "moe_tokens")

    if m.num_shared_experts:
        sh = params["shared"]
        g = jnp.einsum("nd,df->nf", xf, sh["wi_gate"])
        uu = jnp.einsum("nd,df->nf", xf, sh["wi_up"])
        y = y + jnp.einsum("nf,fd->nd", jax.nn.silu(g) * uu, sh["wo"])

    # aux: switch-style load-balance loss + router z-loss
    me = probs.mean(axis=0)                               # (E,)
    ce = jnp.zeros((m.num_experts,), jnp.float32).at[top_e.reshape(-1)].add(
        1.0
    ) / (n * k)
    lb_loss = m.num_experts * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = dict(lb_loss=lb_loss, z_loss=z_loss,
               drop_frac=1.0 - keep.mean())
    return y.reshape(b, s, d), aux


# ------------------------------------------------------- distributed (EP)
def _bucket_by(ids: jax.Array, num_buckets: int, capacity: int):
    """Sort-based bucketing: ids (n,) -> (sorted order, bucket, pos, keep)."""
    order = jnp.argsort(ids)
    sorted_ids = ids[order]
    seg_start = jnp.searchsorted(sorted_ids, jnp.arange(num_buckets))
    pos = jnp.arange(ids.shape[0]) - seg_start[jnp.clip(sorted_ids, 0,
                                                        num_buckets - 1)]
    keep = (pos < capacity) & (sorted_ids >= 0) & (sorted_ids < num_buckets)
    return order, sorted_ids, jnp.where(keep, pos, 0), keep


def _expert_ffn(params, buf):
    h = jnp.einsum("ecd,edf->ecf", buf, params["we_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, params["we_up"])
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, params["we_down"])


def _apply_moe_shard_map(params, cfg, x, dispatch, mesh, capacity_factor):
    """Explicit-collective EP: per device, route my token slice, all-to-all
    tokens to their expert-owning ranks, run local experts, all-to-all back,
    combine, all-gather across the model axis (activations are TP-replicated
    outside this block).

    The paper's technique enters at `slot_of[:, my_rank]`: each source rank
    selects the REPLICA of each expert that the placement engine anchored
    for it (greedy-cover choice), so hot experts are served from multiple
    ranks and the a2a fan-out shrinks."""
    from jax.sharding import PartitionSpec as P

    from repro.flags import FLAGS

    m = cfg.moe
    n_model = mesh.shape["model"]
    dispatch = dispatch or identity_dispatch(m.num_experts, n_model)
    assert dispatch.num_slots % n_model == 0, "slots must divide EP ranks"
    slots_per_rank = dispatch.num_slots // n_model
    b, s, d = x.shape
    k = m.top_k
    cf = capacity_factor or FLAGS["moe_cf"] or m.capacity_factor
    dp = ("pod", "data") if "pod" in mesh.axis_names else "data"
    dp_size = _axis_prod(mesh, dp)
    b_local = b // dp_size if b % dp_size == 0 else b
    n_local = b_local * s
    n_slice = max(1, -(-n_local // n_model))       # my token slice (padded)
    pad_tokens = n_model * n_slice - n_local
    c_send = int(max(8, np.ceil(n_slice * k / n_model * cf)))
    c_local = int(max(8, np.ceil(n_model * c_send / slots_per_rank * cf)))
    slot_table = jnp.asarray(dispatch.slot_of)     # (E, R)

    in_param_specs = jax.tree_util.tree_map_with_path(
        lambda kp, leaf: (P("model", None, None)
                          if str(getattr(kp[-1], "key", "")).startswith("we_")
                          else P(*([None] * leaf.ndim))),
        params,
    )

    def body(prms, xl):
        mi = jax.lax.axis_index("model")
        flat = xl.reshape(n_local, d)
        if pad_tokens:
            flat = jnp.pad(flat, ((0, pad_tokens), (0, 0)))
        xs = jax.lax.dynamic_index_in_dim(
            flat.reshape(n_model, n_slice, d), mi, 0, keepdims=False
        )                                               # (n_slice, d)
        logits = jnp.einsum("nd,de->ne", xs.astype(jnp.float32),
                            prms["router"])
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_e = jax.lax.top_k(probs, k)          # (n_slice, k)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
        # --- replica selection for THIS source rank (paper technique)
        my_slots = jnp.take(slot_table, mi, axis=1)     # (E,)
        slot = my_slots[top_e]                          # (n_slice, k)
        dst = slot // slots_per_rank
        slot_local = slot % slots_per_rank

        flat_dst = dst.reshape(-1)
        order, sdst, pos, keep = _bucket_by(flat_dst, n_model, c_send)
        tok_idx = order // k
        send_tok = jnp.zeros((n_model, c_send, d), x.dtype).at[
            sdst, pos
        ].add(jnp.where(keep[:, None], xs[tok_idx], 0).astype(x.dtype))
        send_slot = jnp.full((n_model, c_send), -1, jnp.int32).at[
            sdst, pos
        ].max(jnp.where(keep, slot_local.reshape(-1)[order], -1).astype(jnp.int32))

        recv_tok = jax.lax.all_to_all(send_tok, "model", 0, 0)
        recv_slot = jax.lax.all_to_all(send_slot, "model", 0, 0)

        # --- local expert compute
        rflat = recv_tok.reshape(n_model * c_send, d)
        rslot = recv_slot.reshape(-1)
        o2, ss2, pos2, keep2 = _bucket_by(rslot, slots_per_rank, c_local)
        buf = jnp.zeros((slots_per_rank, c_local, d), x.dtype).at[
            jnp.clip(ss2, 0, slots_per_rank - 1), pos2
        ].add(jnp.where(keep2[:, None], rflat[o2], 0).astype(x.dtype))
        obuf = _expert_ffn(prms, buf)
        vals2 = obuf[jnp.clip(ss2, 0, slots_per_rank - 1), pos2]
        out_flat = jnp.zeros_like(rflat).at[o2].add(
            jnp.where(keep2[:, None], vals2, 0).astype(x.dtype)
        )
        ret = jax.lax.all_to_all(
            out_flat.reshape(n_model, c_send, d), "model", 0, 0
        )

        # --- combine at source with router weights
        vals = ret[sdst, pos]
        w = top_w.reshape(-1)[order].astype(vals.dtype)
        contrib = jnp.where(keep[:, None], vals * w[:, None], 0)
        ys = jnp.zeros((n_slice, d), x.dtype).at[tok_idx].add(
            contrib.astype(x.dtype)
        )
        if m.num_shared_experts:
            sh = prms["shared"]
            g = jnp.einsum("nd,df->nf", xs, sh["wi_gate"])
            uu = jnp.einsum("nd,df->nf", xs, sh["wi_up"])
            ys = ys + jnp.einsum("nf,fd->nd", jax.nn.silu(g) * uu, sh["wo"])
        # restore TP replication of activations
        y_full = jax.lax.all_gather(ys, "model", axis=0, tiled=True)
        y_full = y_full[:n_local].reshape(b_local, s, d)

        # aux (globally averaged -> replicated)
        me = probs.mean(axis=0)
        ce = jnp.zeros((m.num_experts,), jnp.float32).at[
            top_e.reshape(-1)
        ].add(1.0) / (n_slice * k)
        lb = m.num_experts * jnp.sum(me * ce)
        zl = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
        dropf = 1.0 - keep.mean()
        axes = ("pod", "data", "model") if "pod" in mesh.axis_names else (
            "data", "model")
        lb = jax.lax.pmean(lb, axes)
        zl = jax.lax.pmean(zl, axes)
        dropf = jax.lax.pmean(dropf, axes)
        return y_full, dict(lb_loss=lb, z_loss=zl, drop_frac=dropf)

    y, aux = _shard_map(
        body, mesh=mesh,
        in_specs=(in_param_specs, P(dp, None, None)),
        out_specs=(P(dp, None, None),
                   dict(lb_loss=P(), z_loss=P(), drop_frac=P())),
    )(params, x)
    return y, aux


def _axis_prod(mesh, axes):
    if isinstance(axes, tuple):
        out = 1
        for a in axes:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axes]
