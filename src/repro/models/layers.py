"""Shared layer primitives: norms, MLPs, embeddings, RoPE.

Pure-functional JAX: parameters are nested dicts of arrays, every layer is an
(init, apply) pair.  Norm/softmax math runs in fp32 regardless of activation
dtype (bf16 on TPU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Initializer = jax.nn.initializers.Initializer


def dense_init(key, shape, dtype, scale: float = 1.0):
    """Truncated-normal fan-in init (maxtext-style)."""
    fan_in = shape[0] if len(shape) > 1 else 1
    std = scale / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


# ------------------------------------------------------------------- norms
def init_norm(kind: str, d: int, dtype) -> dict:
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    if kind == "nonparametric_ln":
        return {}
    raise ValueError(kind)


def apply_norm(kind: str, params: dict, x: jax.Array, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)
    # layernorm family: center + scale
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.var(xf, -1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if kind == "layernorm":
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(
            jnp.float32
        )
    # nonparametric_ln (olmo): no affine parameters
    return y.astype(x.dtype)


# -------------------------------------------------------------------- MLPs
def init_mlp(key, kind: str, d: int, ff: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {
            "wi_gate": dense_init(ks[0], (d, ff), dtype),
            "wi_up": dense_init(ks[1], (d, ff), dtype),
            "wo": dense_init(ks[2], (ff, d), dtype),
        }
    # non-gated: squared_relu (nemotron) / gelu (seamless)
    return {
        "wi": dense_init(ks[0], (d, ff), dtype),
        "wo": dense_init(ks[1], (ff, d), dtype),
    }


def apply_mlp(kind: str, params: dict, x: jax.Array) -> jax.Array:
    if kind == "swiglu":
        g = jnp.einsum("...d,df->...f", x, params["wi_gate"])
        u = jnp.einsum("...d,df->...f", x, params["wi_up"])
        h = jax.nn.silu(g) * u
    else:
        h = jnp.einsum("...d,df->...f", x, params["wi"])
        if kind == "squared_relu":
            h = jnp.square(jax.nn.relu(h))
        elif kind == "gelu":
            h = jax.nn.gelu(h)
        else:
            raise ValueError(kind)
    return jnp.einsum("...f,fd->...d", h, params["wo"])


# -------------------------------------------------------------- embeddings
def init_embed(key, vocab: int, d: int, dtype) -> dict:
    return {"table": dense_init(key, (vocab, d), dtype, scale=1.0)}


def embed_lookup(params: dict, ids: jax.Array) -> jax.Array:
    return jnp.take(params["table"], ids, axis=0)


def unembed(params: dict, x: jax.Array) -> jax.Array:
    """Logits in fp32 (stable loss)."""
    return jnp.einsum(
        "...d,vd->...v", x.astype(jnp.float32),
        params["table"].astype(jnp.float32),
    )


# -------------------------------------------------------------------- RoPE
def rope_angles(positions: jax.Array, head_dim: int, theta: float):
    """positions: (...,) int -> cos/sin of shape (..., head_dim//2), fp32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    half = x.shape[-1] // 2
    cos, sin = rope_angles(positions, x.shape[-1], theta)  # (..., seq, half)
    cos = cos[..., None, :]  # broadcast over heads
    sin = sin[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
