from .model import (  # noqa: F401
    decode_step,
    forward,
    init_cache,
    init_params,
    layer_windows,
    prefill,
    train_loss,
    uses_scan,
)
from .moe import MoEDispatch, dispatch_from_plan, identity_dispatch  # noqa: F401
