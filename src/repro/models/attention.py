"""Attention: GQA / sliding-window / MLA, for train, prefill and decode.

All softmax(QK^T)V paths use a CHUNKED online-softmax formulation
(lax.scan over KV chunks, flash-attention math in pure jnp): peak memory is
O(S * chunk) instead of O(S^2), so 32k prefill and 500k decode lower to
compact HLO.  The Pallas kernels in repro.kernels implement the same math for
the TPU hot path; this module is also their numerical oracle at the model
level.

Shapes: q (B, S, H, D); k/v (B, T, K, D) with H = K * G (GQA groups).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .layers import apply_rope, dense_init

NEG_INF = -1e30


# ------------------------------------------------------- chunked core
def chunked_attention(
    q: jax.Array,               # (B, S, H, D)
    k: jax.Array,               # (B, T, K, D)
    v: jax.Array,               # (B, T, K, Dv)
    q_positions: jax.Array,     # (B, S) int32 absolute positions
    kv_positions: jax.Array,    # (B, T) int32; -1 marks invalid (empty cache)
    causal: bool = True,
    window: int | None = None,  # sliding-window width (tokens), None = full
    chunk: int = 512,
    softmax_scale: float | None = None,
) -> jax.Array:
    """Online-softmax attention over KV chunks.  Returns (B, S, H, Dv)."""
    b, s, h, d = q.shape
    t, kheads = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = h // kheads
    scale = softmax_scale if softmax_scale is not None else d ** -0.5
    qg = q.reshape(b, s, kheads, g, d)

    # pad T to a chunk multiple; padded slots get position -1 (masked)
    nchunks = max(1, -(-t // chunk))
    pad = nchunks * chunk - t
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad)),
                               constant_values=-1)
    kc = k.reshape(b, nchunks, chunk, kheads, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nchunks, chunk, kheads, dv).transpose(1, 0, 2, 3, 4)
    pc = kv_positions.reshape(b, nchunks, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        m, l, acc = carry
        kci, vci, pci = xs  # (B, chunk, K, D), (B, chunk, K, Dv), (B, chunk)
        s_ij = jnp.einsum(
            "bskgd,btkd->bskgt", qg, kci, preferred_element_type=jnp.float32
        ) * scale  # (B, S, K, G, chunk) fp32
        valid = pci[:, None, :] >= 0  # (B, 1, chunk)
        if causal:
            valid &= pci[:, None, :] <= q_positions[:, :, None]
        if window is not None:
            valid &= pci[:, None, :] > q_positions[:, :, None] - window
        s_ij = jnp.where(valid[:, :, None, None, :], s_ij, NEG_INF)
        # clamp the running max so a fully-masked chunk (all NEG_INF) yields
        # p == 0 rather than exp(0) == 1
        m_new = jnp.maximum(jnp.maximum(m, s_ij.max(axis=-1)), -1e4)
        p = jnp.exp(s_ij - m_new[..., None])
        correction = jnp.exp(m - m_new)
        l_new = l * correction + p.sum(axis=-1)
        pv = jnp.einsum(
            "bskgt,btkd->bskgd", p, vci.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        acc_new = acc * correction[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, s, kheads, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, s, kheads, g), jnp.float32)
    acc0 = jnp.zeros((b, s, kheads, g, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (kc, vc, pc))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, s, h, dv).astype(q.dtype)


# ------------------------------------------------------------------- GQA
def init_gqa(key, cfg, dtype) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, h * hd), dtype),
        "wk": dense_init(ks[1], (d, kv * hd), dtype),
        "wv": dense_init(ks[2], (d, kv * hd), dtype),
        "wo": dense_init(ks[3], (h * hd, d), dtype),
    }


def gqa_qkv(params, cfg, x, positions, rope: bool = True):
    from repro.flags import FLAGS
    from repro.parallel import constrain

    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,de->bse", x, params["wq"]).reshape(b, s, h, hd)
    k = jnp.einsum("bsd,de->bse", x, params["wk"]).reshape(b, s, kv, hd)
    v = jnp.einsum("bsd,de->bse", x, params["wv"]).reshape(b, s, kv, hd)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if FLAGS["sp_attn"] and s > 1:
        # sp2: queries stay sequence-sharded; only the (GQA-small) K/V are
        # gathered — per-layer gather drops from S*d to S*K*hd bytes
        q = constrain(q, "q_sp")
        k = constrain(k, "kv_rep")
        v = constrain(v, "kv_rep")
    return q, k, v


def gqa_attention(
    params, cfg, x, positions, *, window=None, causal=True,
    kv_cache: dict | None = None, cross_kv=None, chunk=512,
):
    """Full GQA block.  kv_cache (decode): dict(k, v, pos, cursor) updated
    functionally and returned.  cross_kv: precomputed (k, v, kv_positions)
    for encoder-decoder cross-attention (no rope on q in that case)."""
    b, s, _ = x.shape
    if cross_kv is not None:
        h, hd = cfg.num_heads, cfg.resolved_head_dim
        q = jnp.einsum("bsd,de->bse", x, params["wq"]).reshape(b, s, h, hd)
        k, v, kv_pos = cross_kv
        out = chunked_attention(q, k, v, positions, kv_pos, causal=False,
                                chunk=chunk)
        new_cache = None
    elif kv_cache is None:
        q, k, v = gqa_qkv(params, cfg, x, positions)
        out = chunked_attention(
            q, k, v, positions, positions, causal=causal, window=window,
            chunk=chunk,
        )
        new_cache = None
    else:
        q, k, v = gqa_qkv(params, cfg, x, positions)
        ck, cv, cpos, cursor = (
            kv_cache["k"], kv_cache["v"], kv_cache["pos"], kv_cache["cursor"],
        )
        t_max = ck.shape[1]
        # ring-buffer write (windowed caches wrap; full caches never do)
        idx = cursor % t_max
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, idx, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, idx, 0, 0))
        cpos = jax.lax.dynamic_update_slice(
            cpos, positions.astype(cpos.dtype), (0, idx)
        )
        out = chunked_attention(q, ck, cv, positions, cpos, causal=True,
                                window=window, chunk=chunk)
        new_cache = dict(k=ck, v=cv, pos=cpos, cursor=cursor + s)
    y = out.reshape(b, s, -1)
    return jnp.einsum("bse,ed->bsd", y, params["wo"]), new_cache


def init_gqa_cache(cfg, batch, max_len, dtype, window=None) -> dict:
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    t = min(max_len, window) if window else max_len
    return dict(
        k=jnp.zeros((batch, t, kv, hd), dtype),
        v=jnp.zeros((batch, t, kv, hd), dtype),
        pos=jnp.full((batch, t), -1, jnp.int32),
        cursor=jnp.zeros((), jnp.int32),
    )


# ------------------------------------------------------------------- MLA
def init_mla(key, cfg, dtype) -> dict:
    m, d, h = cfg.mla, cfg.d_model, cfg.num_heads
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], (d, m.q_lora_rank), dtype),
        "q_norm": {"scale": jnp.ones((m.q_lora_rank,), dtype)},
        "wq_b": dense_init(ks[1], (m.q_lora_rank, h * qk_hd), dtype),
        "wkv_a": dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim), dtype),
        "kv_norm": {"scale": jnp.ones((m.kv_lora_rank,), dtype)},
        "wk_b": dense_init(ks[3], (m.kv_lora_rank, h * m.qk_nope_head_dim), dtype),
        "wv_b": dense_init(ks[4], (m.kv_lora_rank, h * m.v_head_dim), dtype),
        "wo": dense_init(ks[5], (h * m.v_head_dim, d), dtype),
    }


def _mla_q(params, cfg, x, positions):
    from .layers import apply_norm
    m, h = cfg.mla, cfg.num_heads
    b, s, _ = x.shape
    cq = apply_norm("rmsnorm", params["q_norm"],
                    jnp.einsum("bsd,dr->bsr", x, params["wq_a"]))
    q = jnp.einsum("bsr,re->bse", cq, params["wq_b"]).reshape(
        b, s, h, m.qk_nope_head_dim + m.qk_rope_head_dim
    )
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_ckv(params, cfg, x, positions):
    from .layers import apply_norm
    m = cfg.mla
    kv = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"])
    c_kv = apply_norm("rmsnorm", params["kv_norm"], kv[..., : m.kv_lora_rank])
    k_rope = kv[..., m.kv_lora_rank:][:, :, None, :]  # (B,S,1,rope_d) shared
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def mla_attention(params, cfg, x, positions, *, kv_cache=None, chunk=512):
    """MLA in two formulations:

    * ABSORBED (decode, always; train/prefill by default): attention runs in
      latent space against the compressed cache (c_kv, k_rope) — never
      decompressing per-head K/V.  Ideal for long-KV decode; for s>1 the
      (B,S,H,kv_lora) query/accumulator tensors are large.
    * DECOMPRESSED (train/prefill with flags.FLAGS['mla_decomp']): per-head
      K/V materialized per chunk — deepseek's own training-time choice; the
      §Perf hillclimb measures the memory-term delta.

    score(h) = q_nope(h)^T W_kb(h) c_kv / sqrt(D) + q_rope^T k_rope / sqrt(D)
    out(h)   = [softmax @ c_kv] W_vb(h)
    """
    from repro.flags import FLAGS

    m, h = cfg.mla, cfg.num_heads
    b, s, _ = x.shape
    q_nope, q_rope = _mla_q(params, cfg, x, positions)
    c_kv_new, k_rope_new = _mla_ckv(params, cfg, x, positions)
    if kv_cache is None:
        c_kv, k_rope, kv_pos = c_kv_new, k_rope_new, positions
        new_cache = None
    else:
        cursor = kv_cache["cursor"]
        c_kv = jax.lax.dynamic_update_slice(
            kv_cache["c_kv"], c_kv_new.astype(kv_cache["c_kv"].dtype),
            (0, cursor, 0))
        k_rope = jax.lax.dynamic_update_slice(
            kv_cache["k_rope"], k_rope_new.astype(kv_cache["k_rope"].dtype),
            (0, cursor, 0))
        kv_pos = jax.lax.dynamic_update_slice(
            kv_cache["pos"], positions.astype(jnp.int32), (0, cursor))
        new_cache = dict(c_kv=c_kv, k_rope=k_rope, pos=kv_pos,
                         cursor=cursor + s)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5

    if FLAGS["mla_decomp"] and s > 1:
        # decompressed path: per-head K/V from the latent cache
        wkb = params["wk_b"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim)
        wvb = params["wv_b"].reshape(m.kv_lora_rank, h, m.v_head_dim)
        t = c_kv.shape[1]
        k_nope = jnp.einsum("btr,rhd->bthd", c_kv, wkb)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (b, t, h, m.qk_rope_head_dim))], -1)
        v_full = jnp.einsum("btr,rhv->bthv", c_kv, wvb)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = chunked_attention(
            q_full, k_full.astype(q_full.dtype), v_full.astype(q_full.dtype),
            positions, kv_pos, causal=True, chunk=chunk, softmax_scale=scale,
        ).reshape(b, s, -1)
        return (jnp.einsum("bse,ed->bsd", out.astype(x.dtype), params["wo"]),
                new_cache)

    # absorbed path: q_lat (B,S,H,R)
    wkb = params["wk_b"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, wkb)
    # attention in latent space: keys = [c_kv ; k_rope], queries = [q_lat ; q_rope]
    qq = jnp.concatenate([q_lat, jnp.broadcast_to(
        q_rope[:, :, :, :], (b, s, h, m.qk_rope_head_dim))], axis=-1)
    kk = jnp.concatenate([c_kv, k_rope], axis=-1)[:, :, None, :]  # (B,T,1,R+rd)
    lat = chunked_attention(
        qq, kk, c_kv[:, :, None, :], positions, kv_pos, causal=True,
        chunk=chunk, softmax_scale=scale,
    )  # (B,S,H,R) — attention-weighted latent
    wvb = params["wv_b"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    out = jnp.einsum("bshr,rhv->bshv", lat, wvb).reshape(b, s, -1)
    return jnp.einsum("bse,ed->bsd", out.astype(x.dtype), params["wo"]), new_cache


def init_mla_cache(cfg, batch, max_len, dtype) -> dict:
    m = cfg.mla
    return dict(
        c_kv=jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        k_rope=jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
        pos=jnp.full((batch, max_len), -1, jnp.int32),
        cursor=jnp.zeros((), jnp.int32),
    )
