"""Transformer / SSM / hybrid blocks: init + apply for one layer.

A block is family-polymorphic:
  dense/moe/vlm : pre-norm attn (GQA or MLA) -> residual -> pre-norm FFN/MoE
  ssm (mamba2)  : pre-norm mamba2 -> residual (no FFN)
  hybrid (hymba): pre-norm -> attn AND mamba2 in PARALLEL on the same input,
                  per-path RMS-normalized then averaged -> residual -> FFN
  encoder       : non-causal self-attn -> FFN
  cross-decoder : causal self-attn -> cross-attn -> FFN (seamless)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import apply_mlp, apply_norm, init_mlp, init_norm

__all__ = ["init_block", "apply_block", "init_block_cache"]


def _has_attn(cfg) -> bool:
    return cfg.attention != "none"


def _has_ssm(cfg) -> bool:
    return cfg.attention in ("none", "hybrid")


def _is_moe_layer(cfg, layer_idx: int) -> bool:
    return cfg.moe is not None and layer_idx >= cfg.moe.first_k_dense


def init_block(
    key, cfg, dtype, *, layer_idx: int = 0, cross_attention: bool = False,
    moe_dispatch=None, force_dense: bool = False,
) -> dict:
    ks = jax.random.split(key, 8)
    p: dict = {}
    d = cfg.d_model
    if _has_attn(cfg):
        p["ln_attn"] = init_norm(cfg.norm, d, dtype)
        if cfg.attention == "mla":
            p["attn"] = attn.init_mla(ks[0], cfg, dtype)
        else:
            p["attn"] = attn.init_gqa(ks[0], cfg, dtype)
    if _has_ssm(cfg):
        p["ln_ssm"] = init_norm(cfg.norm, d, dtype)
        p["ssm"] = ssm_mod.init_mamba2(ks[1], cfg, dtype)
    if cfg.attention == "hybrid":
        # per-path output norms before averaging (hymba)
        p["out_norm_attn"] = {"scale": jnp.ones((d,), dtype)}
        p["out_norm_ssm"] = {"scale": jnp.ones((d,), dtype)}
    if cross_attention:
        p["ln_cross"] = init_norm(cfg.norm, d, dtype)
        p["cross"] = attn.init_gqa(ks[2], cfg, dtype)
    if cfg.d_ff and cfg.attention != "none":
        p["ln_mlp"] = init_norm(cfg.norm, d, dtype)
        if _is_moe_layer(cfg, layer_idx) and not force_dense:
            p["moe"] = moe_mod.init_moe(ks[3], cfg, dtype, moe_dispatch)
        else:
            p["mlp"] = init_mlp(ks[3], cfg.mlp, d, cfg.d_ff, dtype)
    return p


def apply_block(
    params: dict,
    cfg,
    x: jax.Array,                 # (B, S, d)
    positions: jax.Array,         # (B, S)
    *,
    layer_idx: int = 0,
    causal: bool = True,
    window=None,                  # None | int | traced scalar
    cache: dict | None = None,    # per-layer cache dict
    cross_kv=None,                # (k, v, pos) for enc-dec decoders
    moe_dispatch=None,
    is_moe: bool | None = None,
    chunk: int = 512,
):
    """Returns (y, new_cache, aux)."""
    aux = {}
    new_cache: dict = {}
    if _has_attn(cfg) and cfg.attention != "hybrid":
        h = apply_norm(cfg.norm, params["ln_attn"], x)
        if cfg.attention == "mla":
            a_out, c = attn.mla_attention(
                params["attn"], cfg, h, positions,
                kv_cache=cache.get("attn") if cache else None, chunk=chunk,
            )
        else:
            a_out, c = attn.gqa_attention(
                params["attn"], cfg, h, positions, window=window,
                causal=causal,
                kv_cache=cache.get("attn") if cache else None, chunk=chunk,
            )
        if c is not None:
            new_cache["attn"] = c
        x = x + a_out
    elif cfg.attention == "hybrid":
        h = apply_norm(cfg.norm, params["ln_attn"], x)
        a_out, c_attn = attn.gqa_attention(
            params["attn"], cfg, h, positions, window=window, causal=causal,
            kv_cache=cache.get("attn") if cache else None, chunk=chunk,
        )
        s_out, c_ssm = ssm_mod.apply_mamba2(
            params["ssm"], cfg, h,
            cache=cache.get("ssm") if cache else None,
        )
        # hymba: average of per-path normalized outputs
        a_n = apply_norm("rmsnorm", params["out_norm_attn"], a_out)
        s_n = apply_norm("rmsnorm", params["out_norm_ssm"], s_out)
        x = x + 0.5 * (a_n + s_n)
        if c_attn is not None:
            new_cache["attn"] = c_attn
        if c_ssm is not None:
            new_cache["ssm"] = c_ssm
    else:  # pure SSM (mamba2)
        h = apply_norm(cfg.norm, params["ln_ssm"], x)
        s_out, c_ssm = ssm_mod.apply_mamba2(
            params["ssm"], cfg, h, cache=cache.get("ssm") if cache else None,
        )
        if c_ssm is not None:
            new_cache["ssm"] = c_ssm
        return x + s_out, (new_cache or None), aux

    if cross_kv is not None:
        h = apply_norm(cfg.norm, params["ln_cross"], x)
        c_out, _ = attn.gqa_attention(
            params["cross"], cfg, h, positions, cross_kv=cross_kv, chunk=chunk,
        )
        x = x + c_out

    if "moe" in params or "mlp" in params:
        h = apply_norm(cfg.norm, params["ln_mlp"], x)
        use_moe = is_moe if is_moe is not None else ("moe" in params)
        if use_moe:
            m_out, moe_aux = moe_mod.apply_moe(
                params["moe"], cfg, h, moe_dispatch
            )
            aux.update(moe_aux)
        else:
            m_out = apply_mlp(cfg.mlp, params["mlp"], h)
        x = x + m_out
    return x, (new_cache or None), aux


def init_block_cache(
    cfg, batch: int, max_len: int, dtype, *, window=None,
    cross_attention: bool = False,
) -> dict:
    c: dict = {}
    if _has_attn(cfg):
        if cfg.attention == "mla":
            c["attn"] = attn.init_mla_cache(cfg, batch, max_len, dtype)
        else:
            c["attn"] = attn.init_gqa_cache(cfg, batch, max_len, dtype,
                                            window=window)
    if _has_ssm(cfg):
        c["ssm"] = ssm_mod.init_ssm_cache(cfg, batch, dtype)
    return c
