"""Mamba2 block: state-space duality (SSD) with chunked matmul scan.

TPU adaptation of the SSD algorithm (arXiv:2405.21060): instead of the
GPU-style per-thread selective scan, sequences are split into chunks of
`chunk_size`; intra-chunk terms are dense matmuls (MXU-friendly, quadratic
only within a chunk) and inter-chunk state is carried by a lax.scan — the
same decomposition the paper's Listing 1 uses, mapped to einsums.

Block structure follows Mamba-2: fused in_proj -> (z, x, B, C, dt),
short causal conv on (x, B, C), SSD core over heads, gated RMSNorm, out_proj.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import apply_norm, dense_init

__all__ = ["init_mamba2", "apply_mamba2", "init_ssm_cache", "ssd_chunked"]


def _ssm_dims(cfg):
    s = cfg.ssm
    d_in = cfg.d_model * s.expand
    nh = s.num_heads or d_in // s.head_dim
    return s, d_in, nh


def init_mamba2(key, cfg, dtype) -> dict:
    s, d_in, nh = _ssm_dims(cfg)
    ks = jax.random.split(key, 4)
    d_conv = d_in + 2 * s.state_dim  # conv over x, B, C
    return {
        # in_proj -> [z (d_in), x (d_in), B (state), C (state), dt (nh)]
        "w_in": dense_init(ks[0], (cfg.d_model, 2 * d_in + 2 * s.state_dim + nh),
                           dtype),
        "conv_w": dense_init(ks[1], (s.conv_width, d_conv), dtype, scale=1.0),
        "conv_b": jnp.zeros((d_conv,), dtype),
        "a_log": jnp.zeros((nh,), jnp.float32),      # A = -exp(a_log)
        "dt_bias": jnp.full((nh,), -2.0, jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "gate_norm": {"scale": jnp.ones((d_in,), dtype)},
        "w_out": dense_init(ks[2], (d_in, cfg.d_model), dtype),
    }


def _causal_conv(x, w, b, state=None):
    """x: (B, S, C); w: (W, C) depthwise.  state: (B, W-1, C) carry for
    decode.  Returns (y, new_state)."""
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)          # (B, W-1+S, C)
    y = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :]
        for i in range(width)
    )
    new_state = xp[:, -(width - 1):, :] if width > 1 else state
    return jax.nn.silu(y + b[None, None, :]), new_state


def ssd_chunked(xh, dt, a, bmat, cmat, chunk: int, h0=None):
    """SSD core.  xh: (B, S, H, P); dt: (B, S, H); a: (H,) negative;
    bmat/cmat: (B, S, N).  Returns (y (B,S,H,P), h_last (B,H,P,N)).

    Discretization: h_t = exp(a*dt_t) h_{t-1} + dt_t * B_t x_t^T
                    y_t = C_t h_t
    Chunked: dense intra-chunk attention-like matmul + inter-chunk scan.
    """
    b, s, nh, p = xh.shape
    n = bmat.shape[-1]
    nchunks = max(1, -(-s // chunk))
    pad = nchunks * chunk - s
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    L = chunk
    xc = xh.reshape(b, nchunks, L, nh, p).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(b, nchunks, L, nh).transpose(1, 0, 2, 3)
    bc = bmat.reshape(b, nchunks, L, n).transpose(1, 0, 2, 3)
    cc = cmat.reshape(b, nchunks, L, n).transpose(1, 0, 2, 3)

    if h0 is None:
        h0 = jnp.zeros((b, nh, p, n), jnp.float32)

    def body(h, xs):
        xci, dtci, bci, cci = xs        # (B,L,H,P), (B,L,H), (B,L,N), (B,L,N)
        adt = a[None, None, :] * dtci   # (B,L,H) negative
        cum = jnp.cumsum(adt, axis=1)   # running log-decay within chunk
        # intra-chunk: y_intra[t] = sum_{u<=t} C_t . B_u x_u dt_u exp(cum_t-cum_u)
        decay = cum[:, :, None, :] - cum[:, None, :, :]       # (B,L,L,H)
        mask = jnp.tril(jnp.ones((L, L), bool))
        gate = jnp.where(mask[None, :, :, None], jnp.exp(decay), 0.0)
        cb = jnp.einsum("bln,bmn->blm", cci, bci)             # (B,L,L)
        att = cb[:, :, :, None] * gate                        # (B,L,L,H)
        y_intra = jnp.einsum(
            "blmh,bmh,bmhp->blhp", att, dtci, xci.astype(jnp.float32)
        )
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum(
            "bln,bhpn,blh->blhp", cci, h, jnp.exp(cum)
        )
        # state update: h' = exp(sum adt) h + sum_u exp(cum_L - cum_u) dt_u B_u x_u^T
        tail = jnp.exp(cum[:, -1:, :] - cum)                  # (B,L,H)
        dx = xci.astype(jnp.float32) * (dtci * tail)[..., None]  # (B,L,H,P)
        h_new = (
            jnp.exp(cum[:, -1, :])[:, :, None, None] * h
            + jnp.einsum("blhp,bln->bhpn", dx, bci)
        )
        return h_new, y_intra + y_inter

    h_last, yc = jax.lax.scan(body, h0, (xc, dtc, bc, cc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(b, nchunks * L, nh, p)[:, :s]
    return y, h_last


def apply_mamba2(params, cfg, x, *, cache: dict | None = None):
    """x: (B, S, d_model).  cache (decode): dict(conv, h).  Returns (y, cache)."""
    s_cfg, d_in, nh = _ssm_dims(cfg)
    b, s, _ = x.shape
    proj = jnp.einsum("bsd,de->bse", x, params["w_in"])
    z = proj[..., :d_in]
    xbc = proj[..., d_in : d_in + d_in + 2 * s_cfg.state_dim]
    dt_raw = proj[..., -nh:]
    conv_state = cache["conv"] if cache else None
    xbc, conv_state = _causal_conv(
        xbc, params["conv_w"], params["conv_b"], conv_state
    )
    xs = xbc[..., :d_in].reshape(b, s, nh, s_cfg.head_dim)
    bmat = xbc[..., d_in : d_in + s_cfg.state_dim]
    cmat = xbc[..., d_in + s_cfg.state_dim :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])
    a = -jnp.exp(params["a_log"])
    h0 = cache["h"] if cache else None
    if s == 1 and cache is not None:
        # decode fast path: one recurrence step, no chunking
        adt = jnp.exp(a[None, :] * dt[:, 0])                    # (B,H)
        dx = xs[:, 0].astype(jnp.float32) * dt[:, 0][..., None]  # (B,H,P)
        h_new = (
            adt[:, :, None, None] * h0
            + jnp.einsum("bhp,bn->bhpn", dx, bmat[:, 0].astype(jnp.float32))
        )
        y = jnp.einsum("bn,bhpn->bhp", cmat[:, 0].astype(jnp.float32), h_new)
        y = y[:, None]                                          # (B,1,H,P)
        h_last = h_new
    else:
        y, h_last = ssd_chunked(xs, dt, a, bmat.astype(jnp.float32),
                                cmat.astype(jnp.float32),
                                s_cfg.chunk_size, h0)
    y = y + params["d_skip"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, s, d_in).astype(x.dtype)
    # gated RMSNorm (mamba2): norm(y) * silu(z)
    y = apply_norm("rmsnorm", params["gate_norm"], y) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"])
    new_cache = dict(conv=conv_state, h=h_last) if cache is not None else None
    return out, new_cache


def init_ssm_cache(cfg, batch, dtype) -> dict:
    s, d_in, nh = _ssm_dims(cfg)
    return dict(
        conv=jnp.zeros((batch, s.conv_width - 1, d_in + 2 * s.state_dim), dtype),
        h=jnp.zeros((batch, nh, s.head_dim, s.state_dim), jnp.float32),
    )
