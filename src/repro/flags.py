"""Perf-variant knobs for the §Perf hillclimb.

Defaults reproduce the BASELINE; the dry-run's --variant flag (e.g.
``--variant mla_decomp+accum8+sp``) flips knobs so each hypothesis gets its
own lowered artifact, before/after recorded side by side in EXPERIMENTS.md.
"""

from __future__ import annotations

FLAGS = {
    # MLA: use the decompressed (per-head K/V) formulation for s>1 paths
    # instead of the absorbed latent form (hypothesis: absorbed q_lat/lat
    # accumulators of (B,S,H,kv_lora) fp32 dominate train/prefill memory).
    "mla_decomp": False,
    # gradient accumulation: microbatch the train step (activation memory /
    # accum_steps at the cost of accum_steps serial sub-steps).
    "accum_steps": 1,
    # sequence parallelism: keep inter-layer activations sequence-sharded on
    # the model axis (norms/residuals run sharded; reduce-scatter+all-gather
    # replaces all-reduce around TP blocks).
    "sp": False,
    # sp2: additionally keep attention QUERIES sequence-sharded (each query
    # attends the full gathered K/V — K/V bytes are GQA-small, so the
    # per-layer gather shrinks from activations (S*d) to caches (S*K*hd)).
    "sp_attn": False,
    # MoE dispatch capacity factor override (None = config value)
    "moe_cf": None,
    # span engine gain backend.  "auto" (default) dispatches per bucket: gain
    # rounds whose word count is below span_dispatch_threshold run on numpy
    # (bitwise_count, the oracle), larger ones on the accelerated path (the
    # Pallas span_gain kernel on TPU, the jitted jnp popcount elsewhere).
    # "numpy" / "jax" / "pallas" pin one backend globally.  Every backend is
    # bit-identical, so the flag is purely a performance knob and placement
    # results never depend on jax being importable.
    "span_backend": "auto",
    # auto-dispatch crossover, in gain-matrix words (A * N * W) per greedy
    # round.  Calibrated by benchmarks/kernel_bench.py (span_gain_calibration
    # rows): on this container numpy's bitwise_count wins below ~30-70k words
    # and the jitted backend past that (dispatch + uint32-view overhead
    # amortized), so the default sits mid-band.
    "span_dispatch_threshold": 48_000,
    # whole-round cover-loop backend.  "auto" (default) dispatches per
    # BUCKET: buckets whose packed gain work (B * N * W words) is below
    # span_round_threshold run the per-round host loop (one numpy/jax gain
    # matrix per greedy round, the PR 5 engine); larger buckets run the
    # device-resident round loop — packed membership words and cover state
    # are uploaded once and a jitted lax.while_loop fuses
    # mask+popcount+argmax+scatter across ALL greedy rounds, so the bucket
    # costs one host<->device transfer total instead of one per round.
    # "numpy" / "device" pin one path.  Bit-identical covers either way
    # (integer popcount, argmax ties -> lowest partition id), so this is
    # purely a performance knob; without jax the numpy loop serves.
    "span_round_backend": "auto",
    # auto crossover for span_round_backend, in packed words (B * N * W)
    # per bucket.  Calibrated by benchmarks/kernel_bench.py
    # (span_round_calibration rows): the jitted loop amortizes its dispatch
    # + compile-cache lookup over every greedy round, so it wins earlier
    # than the per-round threshold; small refresh buckets (a few edges
    # after an LMBR move) stay on numpy.
    "span_round_threshold": 200_000,
    # LMBR Algorithm-5 peel backend.  "vector" (default) runs the batched
    # CSR peel (flat pin-attribution projection + scatter-add degree
    # updates); "reference" the retained pure-Python oracle; "device" the
    # jitted dense lockstep peel (repro.kernels.lockstep_peel, jnp path);
    # "pallas" the Pallas lockstep-peel kernel (interpret mode on CPU).
    # Device backends emit the free-space-independent peel TRAJECTORY in
    # integer-exact f32 and the (gain, subset) selection happens on host in
    # f64 — shared with the cache re-evaluation path — so results stay
    # bit-identical (same subsets, same gains, same tie-breaks) and the
    # flag is purely a performance knob; benchmarks/bench_lmbr.py and
    # benchmarks/kernel_bench.py time the backends.  Device peels require
    # integer-valued weights below 2^24 (asserted per workload) and fall
    # back to "vector" otherwise, or when jax is unavailable.
    "lmbr_peel": "vector",
    # LMBR gain-cache granularity.  "item" (default) keys cache validity on
    # a global move tick: each cached (src, dest) entry stores the tick it
    # was filled at, its shared-edge set + count, and its candidate pool;
    # it stays valid while the pair's shared-edge count is unchanged (O(1)
    # Gram-matrix lookup), no shared edge was re-stamped by a later cover
    # recompute (per-edge tick), and no pooled item gained residency after
    # the fill (per-item tick) — so untouched candidate pools survive moves
    # that only graze their partitions, with a projection-fingerprint second
    # level and re-evaluable cached peel trajectories behind it.
    # "partition" restores the PR 5 cache (per-partition cov/mem epochs,
    # <1% hit rate under the move loop).  Both are exactness-neutral; the
    # bench's engine comparison pins "partition" for the baseline rows.
    "lmbr_epochs": "item",
    # epoch-keyed (src, dest) -> (gain, items) memo in the LMBR move loop:
    # a pair is only re-peeled when a partition epoch it depends on moved
    # (cover/pin-attribution epoch of either side, membership epoch of the
    # destination).  Exactness-neutral; off reproduces the uncached engine.
    "lmbr_gain_cache": True,
    # hybrid-peel crossover for ``lmbr_peel="auto"``: a candidate (src, dest)
    # pair whose degree-matrix width estimate (shared-edge count * mean edge
    # size, an O(1) lookup off the maintained pair-count matrix) is below
    # this runs the pure-Python reference peel — on sparse near-span-1
    # workloads (fig9 circuits) tiny peels beat the batch-array assembly.
    # Both backends are bit-identical, so this is a pure perf knob;
    # calibrated by benchmarks/bench_lmbr.py's vectorized-auto rows.
    "lmbr_peel_threshold": 256,
    # online router: queries per batched_cover_csr call in the streaming
    # replica-selection router (repro.online.ReplicaRouter).  Calibrated by
    # benchmarks/bench_online.py's router sweep: big enough to amortize the
    # per-call bitset packing, small enough that every gain round stays in
    # the numpy band of the span dispatch rule.
    "router_microbatch": 384,
    # online router: load-aware tie-break.  Off (default) the router is
    # bit-identical to per-query cover_for_query (ties -> lowest partition
    # id); on, same-gain covers prefer the partition with the lowest entry in
    # the router's running access-load ledger (power-of-two-choices style).
    "router_balance": False,
    # drift detector: sliding window size W (queries) for the workload sketch
    # and the windowed avg_span monitor.
    "drift_window": 512,
    # drift detector: refit trigger — fires when the windowed avg_span
    # exceeds (fit-time baseline) * drift_threshold.
    "drift_threshold": 1.25,
    # online router (balanced mode): stale-ledger tolerance for the
    # tie-break row permutation.  The lexsort over (load, id) is only
    # rebuilt when some partition's ledger load has shifted by more than
    # epsilon * max(load at last sort, 1.0) since the last sort.  0.0 (the
    # default) rebuilds on ANY shift — bit-identical to re-sorting every
    # microbatch (an unchanged ledger lexsorts to the same permutation);
    # > 0 keeps the lexsort off the steady-state hot path at the cost of
    # routing against a slightly stale load ordering (spans are unaffected
    # — only which equal-gain replica serves).
    "router_ledger_epsilon": 0.0,
    # cluster-scale sharded fit (repro.scale): number of workload shards.
    # 0 = auto (max(1, num_partitions // 8)); explicit values pin the
    # decomposition width.
    "scale_shards": 0,
    # cluster-scale sharded fit: per-shard fit processes.  1 (default) runs
    # the deterministic serial path; > 1 dispatches shards onto a process
    # pool (results are merged in shard order, so worker count never
    # changes the fitted placement — asserted by tests/test_scale.py).
    "scale_workers": 1,
    # cluster-scale sharded fit: LMBR move budget for the bounded repair
    # pass restricted to cross-shard boundary edges after the merge
    # (0 disables the pass; repair only ever copies into free space, so it
    # is capacity-safe by construction).
    "scale_boundary_repair": 256,
    # placement objective.  "span" (default) is the paper's objective:
    # balance load across all partitions and minimize average span.
    # "energy" concentrates the fit onto a capacity-descending prefix of
    # ACTIVE partitions (smallest prefix holding ~1.25x the total item
    # weight) so the remaining rows stay empty and can be powered down —
    # the LMBR cold start and its dest_mask are restricted to the active
    # set; the simulator reports active_machines / cluster power per fit.
    "placement_objective": "span",
    # per-item durability ceiling eps for Π p_fail ≤ eps (independent
    # partition failures, repro.core.cluster).  0.0 (default) disables the
    # constraint; > 0 makes PlacementService fits add greedy low-fail-prob
    # replicas post-fit until every item meets the ceiling (capacity-safe,
    # validated by validate_durability).
    "durability_eps": 0.0,
    # LMBR gain penalty weight for destination access cost: a candidate
    # move's gain is charged node_cost_weight * access_cost[dest] before
    # the accept test, steering replicas toward cheap nodes.  0.0 (default)
    # is bit-identical to the unpenalized engine; only engages when the fit
    # is given a per-partition cost vector (NodeProfile.access_cost).
    "node_cost_weight": 0.0,
    # online router (balanced mode): cost-aware tie-break.  Off (default)
    # equal-gain covers prefer the least-loaded partition.  On, the ledger
    # permutation sorts by load * routing_cost (access cost + normalized
    # active power from the NodeProfile) — a uniform profile gives a
    # constant cost vector, so the permutation (and every routing decision)
    # stays bit-identical to least-loaded.
    "router_cost_aware": False,
    # live plan migration: transfer bandwidth in item-weight units per
    # served query (the executor's tick).  0.0 (the default) keeps the
    # legacy ATOMIC hot-swap — plan changes in run_online (drift refits,
    # "migrate" events) apply instantly between microbatches, bit-identical
    # to the pre-migration behavior.  > 0 streams the plan diff as paced
    # replica transfers through repro.online.migration, serving from the
    # union layout until every copy lands.
    "migration_bandwidth": 0.0,
    # live plan migration: maximum concurrent in-flight transfers per
    # DESTINATION partition (rucio-conveyor-style per-destination
    # throttling).  Together with the largest scheduled copy this bounds
    # the concurrent in-flight bytes by construction
    # (MigrationPlan.inflight_bound).
    "migration_concurrency": 4,
    # live plan migration: capacity slack fraction for the union layout —
    # a transfer only starts while the destination's committed + reserved
    # load stays within capacity * (1 + headroom).  Too-tight headroom on
    # a diff whose copies all wait on drops raises RuntimeError instead of
    # deadlocking silently.
    "migration_headroom": 0.10,
    # observability level (repro.obs).  "off" (default) swaps in the no-op
    # null registry/tracer — zero allocations on hot paths, bit-identical
    # and timing-neutral (gated by benchmarks/bench_obs.py).  "counters"
    # turns on the metrics registry (counters/gauges/histograms, Prometheus
    # exposition via to_prom_text); "trace" additionally records nested
    # spans/events as Chrome-trace JSON (to_chrome_trace).  No level may
    # change results: hooks only observe.
    "obs_level": "off",
    # run_online: emit a periodic metrics snapshot (registry gauges + a
    # Chrome-trace counter event when tracing) every N served queries.
    # 0 (default) disables periodic snapshots.
    "obs_snapshot_every": 0,
    # health monitoring (repro.obs.health): arm the HealthMonitor inside
    # run_online.  Requires obs_level != "off" AND obs_snapshot_every > 0
    # (the monitor consumes the periodic snapshots) — run_online raises
    # ValueError otherwise.  Monitoring is read-only: it changes no
    # placement, routing, or stats values (same contract as obs_level).
    "obs_health": False,
    # health: window size in SNAPSHOTS for every windowed SLO rule (avg
    # span, degraded rate, load skew, p99 latency, backlog).
    "health_window": 8,
    # health: consecutive clear evaluations before a firing alert
    # resolves (hysteresis; firing happens on the first breach).
    "health_hysteresis": 2,
    # health SLO thresholds — 0 disables the individual rule:
    # windowed avg span / fit-time baseline ratio ceiling,
    "health_span_slo": 1.5,
    # p99 serve.microbatch latency ceiling in seconds (from the
    # router_microbatch_seconds histogram; wall-clock, so 0/off by
    # default — enable for real deployments, not unit tests),
    "health_p99_slo": 0.0,
    # windowed degraded-query rate ceiling (degraded / attempted),
    "health_degraded_slo": 0.02,
    # windowed per-partition load-delta skew ceiling (p99 / mean),
    "health_skew_slo": 4.0,
    # windowed mean migration in-flight backlog ceiling (item-weight
    # units; 0/off by default — only meaningful with paced migrations),
    "health_backlog_slo": 0.0,
    # health: EWMA z-score anomaly detection threshold on every rule's
    # value stream (|z| above this fires "<rule>_anomaly" through the
    # same state machine).  0 (default) disables anomaly rules.
    "health_anomaly_z": 0.0,
}


def set_variant(spec: str):
    """'mla_decomp+accum8+sp+cf1.0' -> flag settings."""
    reset()
    for part in filter(None, spec.split("+")):
        if part == "baseline":
            continue
        elif part == "mla_decomp":
            FLAGS["mla_decomp"] = True
        elif part.startswith("accum"):
            FLAGS["accum_steps"] = int(part[len("accum"):])
        elif part == "sp":
            FLAGS["sp"] = True
        elif part == "sp2":
            FLAGS["sp"] = True
            FLAGS["sp_attn"] = True
        elif part.startswith("cf"):
            FLAGS["moe_cf"] = float(part[2:])
        elif part.startswith("spanth"):
            FLAGS["span_dispatch_threshold"] = int(part[len("spanth"):])
        elif part.startswith("spanroundth"):
            FLAGS["span_round_threshold"] = int(part[len("spanroundth"):])
        elif part.startswith("spanround"):
            backend = part[len("spanround"):]
            if backend not in ("auto", "numpy", "device"):
                raise ValueError(f"unknown span round backend {backend!r}")
            FLAGS["span_round_backend"] = backend
        elif part.startswith("peelth"):
            FLAGS["lmbr_peel_threshold"] = int(part[len("peelth"):])
        elif part.startswith("peel"):
            backend = part[len("peel"):]
            if backend not in ("vector", "reference", "auto", "device",
                               "pallas"):
                raise ValueError(f"unknown lmbr peel backend {backend!r}")
            FLAGS["lmbr_peel"] = backend
        elif part.startswith("lmbrepoch"):
            mode = part[len("lmbrepoch"):]
            if mode not in ("item", "partition"):
                raise ValueError(f"unknown lmbr epoch mode {mode!r}")
            FLAGS["lmbr_epochs"] = mode
        elif part.startswith("lmbrcache"):
            FLAGS["lmbr_gain_cache"] = bool(int(part[len("lmbrcache"):]))
        elif part.startswith("routereps"):
            eps = float(part[len("routereps"):])
            if eps < 0:
                raise ValueError(f"router_ledger_epsilon must be >= 0, got {eps}")
            FLAGS["router_ledger_epsilon"] = eps
        elif part.startswith("routerbal"):
            FLAGS["router_balance"] = bool(int(part[len("routerbal"):]))
        elif part.startswith("routermb"):
            FLAGS["router_microbatch"] = int(part[len("routermb"):])
        elif part.startswith("shards"):
            shards = int(part[len("shards"):])
            if shards < 0:
                raise ValueError(f"scale_shards must be >= 0, got {shards}")
            FLAGS["scale_shards"] = shards
        elif part.startswith("scalew"):
            workers = int(part[len("scalew"):])
            if workers < 1:
                raise ValueError(f"scale_workers must be >= 1, got {workers}")
            FLAGS["scale_workers"] = workers
        elif part.startswith("brepair"):
            moves = int(part[len("brepair"):])
            if moves < 0:
                raise ValueError(
                    f"scale_boundary_repair must be >= 0, got {moves}"
                )
            FLAGS["scale_boundary_repair"] = moves
        elif part.startswith("driftw"):
            FLAGS["drift_window"] = int(part[len("driftw"):])
        elif part.startswith("driftth"):
            FLAGS["drift_threshold"] = float(part[len("driftth"):])
        elif part == "energy":
            FLAGS["placement_objective"] = "energy"
        elif part.startswith("durab"):
            eps = float(part[len("durab"):])
            if eps < 0:
                raise ValueError(f"durability_eps must be >= 0, got {eps}")
            FLAGS["durability_eps"] = eps
        elif part.startswith("nodecost"):
            w = float(part[len("nodecost"):])
            if w < 0:
                raise ValueError(f"node_cost_weight must be >= 0, got {w}")
            FLAGS["node_cost_weight"] = w
        elif part.startswith("routercost"):
            FLAGS["router_cost_aware"] = bool(int(part[len("routercost"):]))
        elif part.startswith("migbw"):
            bw = float(part[len("migbw"):])
            if bw < 0:
                raise ValueError(f"migration_bandwidth must be >= 0, got {bw}")
            FLAGS["migration_bandwidth"] = bw
        elif part.startswith("migconc"):
            conc = int(part[len("migconc"):])
            if conc < 1:
                raise ValueError(
                    f"migration_concurrency must be >= 1, got {conc}"
                )
            FLAGS["migration_concurrency"] = conc
        elif part.startswith("mighead"):
            head = float(part[len("mighead"):])
            if head < 0:
                raise ValueError(f"migration_headroom must be >= 0, got {head}")
            FLAGS["migration_headroom"] = head
        elif part.startswith("obshealth"):
            FLAGS["obs_health"] = bool(int(part[len("obshealth"):]))
        elif part.startswith("obssnap"):
            every = int(part[len("obssnap"):])
            if every < 0:
                raise ValueError(f"obs_snapshot_every must be >= 0, got {every}")
            FLAGS["obs_snapshot_every"] = every
        elif part.startswith("obs"):
            lv = part[len("obs"):]
            if lv not in ("off", "counters", "trace"):
                raise ValueError(f"unknown obs level {lv!r}")
            FLAGS["obs_level"] = lv
        elif part.startswith("healthw"):
            w = int(part[len("healthw"):])
            if w < 2:
                raise ValueError(f"health_window must be >= 2, got {w}")
            FLAGS["health_window"] = w
        elif part.startswith("healthhyst"):
            h = int(part[len("healthhyst"):])
            if h < 1:
                raise ValueError(f"health_hysteresis must be >= 1, got {h}")
            FLAGS["health_hysteresis"] = h
        elif part.startswith("healthspan"):
            FLAGS["health_span_slo"] = float(part[len("healthspan"):])
        elif part.startswith("healthp99"):
            FLAGS["health_p99_slo"] = float(part[len("healthp99"):])
        elif part.startswith("healthdeg"):
            FLAGS["health_degraded_slo"] = float(part[len("healthdeg"):])
        elif part.startswith("healthskew"):
            FLAGS["health_skew_slo"] = float(part[len("healthskew"):])
        elif part.startswith("healthbacklog"):
            FLAGS["health_backlog_slo"] = float(part[len("healthbacklog"):])
        elif part.startswith("healthz"):
            z = float(part[len("healthz"):])
            if z < 0:
                raise ValueError(f"health_anomaly_z must be >= 0, got {z}")
            FLAGS["health_anomaly_z"] = z
        elif part.startswith("span"):
            backend = part[len("span"):]
            if backend not in ("auto", "numpy", "jax", "pallas"):
                raise ValueError(f"unknown span backend {backend!r}")
            FLAGS["span_backend"] = backend
        else:
            raise ValueError(f"unknown variant component {part!r}")


def reset():
    FLAGS.update(mla_decomp=False, accum_steps=1, sp=False, sp_attn=False,
                 moe_cf=None, span_backend="auto",
                 span_dispatch_threshold=48_000, span_round_backend="auto",
                 span_round_threshold=200_000, lmbr_peel="vector",
                 lmbr_epochs="item",
                 lmbr_gain_cache=True, lmbr_peel_threshold=256,
                 router_microbatch=384, router_balance=False,
                 drift_window=512, drift_threshold=1.25,
                 router_ledger_epsilon=0.0, scale_shards=0, scale_workers=1,
                 scale_boundary_repair=256, placement_objective="span",
                 durability_eps=0.0, node_cost_weight=0.0,
                 router_cost_aware=False, migration_bandwidth=0.0,
                 migration_concurrency=4, migration_headroom=0.10,
                 obs_level="off", obs_snapshot_every=0, obs_health=False,
                 health_window=8, health_hysteresis=2, health_span_slo=1.5,
                 health_p99_slo=0.0, health_degraded_slo=0.02,
                 health_skew_slo=4.0, health_backlog_slo=0.0,
                 health_anomaly_z=0.0)
