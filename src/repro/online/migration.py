"""Live plan migration: bandwidth-paced replica transfers, union serving.

A drift refit or a `fit_sharded` hot-swap produces a NEW `PlacementPlan`;
teleporting the live layout onto it between two router microbatches moves
data for free, which no real cluster gets.  This module treats a placement
change as the incremental transfer problem it is (rucio's conveyor daemons
are the operational exemplar: queued transfers, bandwidth-aware pacing,
per-destination throttling):

* `diff_plans(old, new)` — the replica delta between two layouts: `copies`
  (destination gains a replica) and `drops` (destination loses one).  The
  vectorized diff is asserted equal to a brute-force pairwise sweep
  (`diff_plans_reference`) by tests/test_migration.py.
* `MigrationPlan` — a serializable, deterministic transfer schedule: the
  diff in a fixed order (ascending (item, destination)), a preferred source
  per copy (the lowest-id old holder), and the pacing configuration
  (``migration_bandwidth`` weight-units per served query,
  ``migration_concurrency`` in-flight transfers per destination,
  ``migration_headroom`` capacity slack).  ``apply`` replays the whole diff
  instantly — ``apply(diff_plans(a, b), a) == b`` is the round-trip
  property the suite pins.
* `MigrationExecutor` — streams the plan against the LIVE `Placement` the
  router serves from, one tick per served query.  Mid-migration the live
  member matrix is exactly the **union layout**: an item stays routable at
  its old locations until its copy lands, new locations appear as copies
  complete, and an old replica is dropped only once EVERY new copy of its
  item has landed and is live (copies-before-drops, per item).  Space for
  an incoming copy is reserved when its transfer starts, and a transfer
  never starts unless the destination's reserved load stays within
  ``capacity * (1 + headroom)`` — so the headroom bound holds by
  construction at every tick, and coverage is never lost.

Failure interaction (`on_partition_down` / `on_partition_up`): when a
transfer endpoint dies, its in-flight transfers abort (bytes wasted, the
copy re-queues at the head of the schedule), copies already landed there
are masked with the row and counted un-landed again, and the drops waiting
on them are deferred — old replicas are retained until the destination
recovers, so the union layout keeps serving through the outage and the
migration completes to the exact target once the partition returns.  A
migration may also START during an outage: the constructor's ``down``
argument seeds the already-dead partitions so their copies and drops are
deferred from tick zero exactly like a mid-flight failure (the plan should
be diffed against the post-restore layout — see
`FailoverManager.restored_member` — so the dead partition's stale replicas
get scheduled drops instead of silently surviving the row restore).
"""

from __future__ import annotations

import dataclasses
import json
import time

import numpy as np

from .. import flags as _flags
from .. import obs as _obs
from ..core.setcover import Placement

__all__ = [
    "PlanDiff",
    "diff_plans",
    "diff_plans_reference",
    "MigrationPlan",
    "plan_migration",
    "TransferEvent",
    "MigrationExecutor",
]


def _as_member(obj) -> np.ndarray:
    member = getattr(obj, "member", obj)
    member = np.asarray(member)
    if member.dtype != bool or member.ndim != 2:
        raise TypeError("expected a (N, V) bool member matrix "
                        "(or a Placement/PlacementPlan holding one)")
    return member


@dataclasses.dataclass
class PlanDiff:
    """Replica delta old -> new, in ascending (item, partition) order.

    copy_dest[i] gains a replica of copy_item[i]; drop_part[j] loses its
    replica of drop_item[j]."""

    copy_dest: np.ndarray  # (C,) int64
    copy_item: np.ndarray  # (C,) int64
    drop_part: np.ndarray  # (D,) int64
    drop_item: np.ndarray  # (D,) int64

    @property
    def num_copies(self) -> int:
        return len(self.copy_dest)

    @property
    def num_drops(self) -> int:
        return len(self.drop_part)


def diff_plans(old, new) -> PlanDiff:
    """Vectorized replica delta between two layouts of the same shape."""
    old_m, new_m = _as_member(old), _as_member(new)
    if old_m.shape != new_m.shape:
        raise ValueError(
            f"layout shapes differ: {old_m.shape} vs {new_m.shape}"
        )
    cp, ci = np.nonzero((new_m & ~old_m).T)  # transpose: (item, dest) order
    dp, di = np.nonzero((old_m & ~new_m).T)
    return PlanDiff(
        copy_dest=ci.astype(np.int64), copy_item=cp.astype(np.int64),
        drop_part=di.astype(np.int64), drop_item=dp.astype(np.int64),
    )


def diff_plans_reference(old, new) -> PlanDiff:
    """Brute-force pairwise sweep over every (partition, item) cell — the
    retained oracle `diff_plans` is asserted equal to."""
    old_m, new_m = _as_member(old), _as_member(new)
    if old_m.shape != new_m.shape:
        raise ValueError(
            f"layout shapes differ: {old_m.shape} vs {new_m.shape}"
        )
    copies, drops = [], []
    n, v = old_m.shape
    for item in range(v):
        for p in range(n):
            if new_m[p, item] and not old_m[p, item]:
                copies.append((p, item))
            elif old_m[p, item] and not new_m[p, item]:
                drops.append((p, item))
    return PlanDiff(
        copy_dest=np.array([p for p, _ in copies], dtype=np.int64),
        copy_item=np.array([i for _, i in copies], dtype=np.int64),
        drop_part=np.array([p for p, _ in drops], dtype=np.int64),
        drop_item=np.array([i for _, i in drops], dtype=np.int64),
    )


@dataclasses.dataclass
class MigrationPlan:
    """Deterministic transfer schedule from one layout to another.

    The copy/drop arrays are a `PlanDiff` in ascending (item, destination)
    order; ``copy_src`` is the preferred source per copy (lowest-id holder
    in the OLD layout; the executor re-picks a live source at transfer
    start, so a dead preferred source never stalls a copy).  ``target`` is
    the destination `PlacementPlan` when the plan came out of
    `PlacementService.refit(as_migration=True)`; it is a convenience
    pointer, never serialized."""

    num_partitions: int
    num_items: int
    copy_dest: np.ndarray
    copy_item: np.ndarray
    copy_src: np.ndarray
    drop_part: np.ndarray
    drop_item: np.ndarray
    bandwidth: float
    concurrency: int
    headroom: float
    target: "object | None" = None  # PlacementPlan; not serialized

    # ------------------------------------------------------------ accessors
    @property
    def num_copies(self) -> int:
        return len(self.copy_dest)

    @property
    def num_drops(self) -> int:
        return len(self.drop_part)

    @property
    def is_noop(self) -> bool:
        return self.num_copies == 0 and self.num_drops == 0

    def bytes_to_move(self, node_weights) -> float:
        """Total transfer volume (weight units) of the copy schedule."""
        w = np.asarray(node_weights, dtype=np.float64)
        return float(w[self.copy_item].sum())

    def inflight_bound(self, node_weights) -> float:
        """Worst-case concurrent in-flight volume (weight units), by
        construction: at most ``concurrency`` active transfers per distinct
        destination, each no larger than the biggest scheduled copy."""
        if not self.num_copies:
            return 0.0
        w = np.asarray(node_weights, dtype=np.float64)
        n_dests = len(np.unique(self.copy_dest))
        return float(self.concurrency * n_dests * w[self.copy_item].max())

    # ------------------------------------------------------------- instant
    def apply(self, member: np.ndarray) -> np.ndarray:
        """Replay the whole diff instantly (the legacy atomic hot-swap),
        in place: copies first, then drops."""
        member[self.copy_dest, self.copy_item] = True
        member[self.drop_part, self.drop_item] = False
        return member

    def schedule(self, placement: Placement) -> "list[TransferEvent]":
        """The failure-free event schedule from ``placement`` (the starting
        layout; copied — running a schedule never mutates the input):
        executes the plan on a scratch executor and returns its events."""
        scratch = Placement(
            placement.member.copy(), placement.capacity,
            placement.node_weights,
        )
        ex = MigrationExecutor(self, scratch)
        guard = 0
        while not ex.done:
            ex.advance(1)
            guard += 1
            if guard > 10_000_000:  # pragma: no cover - defensive
                raise RuntimeError("migration schedule failed to converge")
        return ex.events

    # ------------------------------------------------------- serialization
    def to_json(self) -> str:
        return json.dumps(dict(
            num_partitions=int(self.num_partitions),
            num_items=int(self.num_items),
            copies=[
                [int(d), int(v), int(s)] for d, v, s in
                zip(self.copy_dest, self.copy_item, self.copy_src)
            ],
            drops=[
                [int(p), int(v)] for p, v in
                zip(self.drop_part, self.drop_item)
            ],
            bandwidth=float(self.bandwidth),
            concurrency=int(self.concurrency),
            headroom=float(self.headroom),
        ))

    @staticmethod
    def from_json(s: str) -> "MigrationPlan":
        d = json.loads(s)
        copies = np.asarray(d["copies"], dtype=np.int64).reshape(-1, 3)
        drops = np.asarray(d["drops"], dtype=np.int64).reshape(-1, 2)
        return MigrationPlan(
            num_partitions=int(d["num_partitions"]),
            num_items=int(d["num_items"]),
            copy_dest=copies[:, 0], copy_item=copies[:, 1],
            copy_src=copies[:, 2],
            drop_part=drops[:, 0], drop_item=drops[:, 1],
            bandwidth=float(d["bandwidth"]),
            concurrency=int(d["concurrency"]),
            headroom=float(d["headroom"]),
        )


def plan_migration(
    old, new,
    node_weights: np.ndarray | None = None,
    bandwidth: float | None = None,
    concurrency: int | None = None,
    headroom: float | None = None,
    target=None,
) -> MigrationPlan:
    """Diff ``old`` -> ``new`` (each a `Placement`, `PlacementPlan` or bool
    member matrix) into a `MigrationPlan`.  Pacing parameters default to
    ``flags.FLAGS["migration_bandwidth" / "migration_concurrency" /
    "migration_headroom"]``.  With ``node_weights`` the target layout is
    checked for coverage (every weight > 0 item must be placed somewhere —
    migrating to a layout that loses an item would break serving)."""
    old_m, new_m = _as_member(old), _as_member(new)
    diff = diff_plans(old_m, new_m)
    if node_weights is not None:
        w = np.asarray(node_weights, dtype=np.float64)
        missing = np.flatnonzero(~new_m.any(axis=0) & (w > 0))
        if len(missing):
            raise ValueError(
                f"target layout leaves {len(missing)} items uncovered, "
                f"e.g. {missing[:5]}"
            )
    # preferred source: lowest-id OLD holder (argmax of a bool column); an
    # item never held in the old layout has no source (-1) and its copy can
    # only start once some live replica exists (e.g. placed by repair)
    held = old_m.any(axis=0)
    src = np.where(
        held[diff.copy_item],
        old_m[:, diff.copy_item].argmax(axis=0) if diff.num_copies
        else np.zeros(0, dtype=np.int64),
        -1,
    ).astype(np.int64)
    bw = (float(_flags.FLAGS.get("migration_bandwidth", 0.0))
          if bandwidth is None else float(bandwidth))
    conc = (int(_flags.FLAGS.get("migration_concurrency", 4))
            if concurrency is None else int(concurrency))
    head = (float(_flags.FLAGS.get("migration_headroom", 0.10))
            if headroom is None else float(headroom))
    if bw < 0:
        raise ValueError(f"migration bandwidth must be >= 0, got {bw}")
    if conc < 1:
        raise ValueError(f"migration concurrency must be >= 1, got {conc}")
    if head < 0:
        raise ValueError(f"migration headroom must be >= 0, got {head}")
    return MigrationPlan(
        num_partitions=old_m.shape[0], num_items=old_m.shape[1],
        copy_dest=diff.copy_dest, copy_item=diff.copy_item, copy_src=src,
        drop_part=diff.drop_part, drop_item=diff.drop_item,
        bandwidth=bw, concurrency=conc, headroom=head, target=target,
    )


@dataclasses.dataclass
class TransferEvent:
    """One state change of the live layout: a copy landing or a drop.

    ``tick`` is the serving-time position (queries served since the
    migration began); ``src`` is the partition the copy streamed from
    (-1 for drops and for copies satisfied without a transfer, e.g. a
    repair already placed the replica)."""

    tick: int
    kind: str  # "copy" | "drop"
    partition: int
    item: int
    src: int = -1


class _Transfer:
    """An in-flight copy: schedule index, remaining volume, live source."""

    __slots__ = ("idx", "dest", "item", "src", "size", "remaining",
                 "t0", "tick0")

    def __init__(self, idx: int, dest: int, item: int, src: int,
                 size: float, t0: float = 0.0, tick0: int = 0):
        self.idx = idx
        self.dest = dest
        self.item = item
        self.src = src
        self.size = size
        self.remaining = size
        self.t0 = t0        # perf_counter at start (trace mode only)
        self.tick0 = tick0  # executor tick at start


class MigrationExecutor:
    """Streams a `MigrationPlan` against the live `Placement`, one tick per
    served query.

    Per tick, in order: (1) deferred drops whose partitions came back are
    executed, (2) eligible pending copies are started — schedule order,
    skipping (not blocking on) copies whose destination is down, over its
    concurrency cap, or out of headroom, and reserving the copy's weight at
    the destination on start, (3) the tick's ``bandwidth`` budget is spent
    over the active transfers in start order (sequential fill, FIFO-biased),
    landed copies flip their member bit, and (4) items whose LAST copy just
    landed release their drops.  The member matrix is the router's, mutated
    in place — serving reads the union layout with no notification needed.

    ``refresh_loads`` must be called after any external mutation of the
    member matrix (failover repair); down/up notifications refresh
    implicitly.  ``down`` seeds partitions that are ALREADY down at
    migration start (their member rows masked by the caller): copies
    to/from them are deferred exactly like a mid-flight failure and
    `on_partition_up` re-arms them once the row is restored.  A migration
    that can make no progress with nothing down raises RuntimeError, naming
    the cause: a pending copy whose item no live partition holds (the plan
    only validates coverage of the TARGET layout), or headroom too tight
    (every pending copy is blocked on space only drops can free, and every
    drop waits on a blocked copy).
    """

    def __init__(self, plan: MigrationPlan, placement: Placement,
                 down=()):
        if placement.member.shape != (plan.num_partitions, plan.num_items):
            raise ValueError(
                f"placement shape {placement.member.shape} does not match "
                f"plan ({plan.num_partitions}, {plan.num_items})"
            )
        if plan.bandwidth <= 0 and plan.num_copies:
            raise ValueError(
                "executing a migration needs bandwidth > 0; "
                "bandwidth 0 means the instant swap (MigrationPlan.apply)"
            )
        self.plan = plan
        self.pl = placement
        self.now = 0
        self.events: list[TransferEvent] = []
        self._cap = placement.capacity_vec * (1.0 + plan.headroom)
        self._w = placement.node_weights
        self._pending: list[int] = list(range(plan.num_copies))
        self._active: list[_Transfer] = []
        self._landed = np.zeros(plan.num_copies, dtype=bool)
        # copies of each item still missing from the live layout (drops of
        # the item wait for this to reach zero with every copy host live)
        self._unlanded = np.bincount(
            plan.copy_item, minlength=plan.num_items
        ).astype(np.int64)
        self._drops_of: dict[int, list[int]] = {}
        for j, v in enumerate(plan.drop_item):
            self._drops_of.setdefault(int(v), []).append(j)
        self._drop_done = np.zeros(plan.num_drops, dtype=bool)
        # drops ready to execute but deferred (down partition) or ready at
        # start (items whose copies all pre-exist / pure-drop items)
        self._ready_drops: list[int] = [
            j for v, js in sorted(self._drops_of.items())
            if self._unlanded[v] == 0 for j in js
        ]
        self._down: set[int] = {int(p) for p in down}
        self._base_load = placement.partition_weights()
        self._reserved = np.zeros(plan.num_partitions, dtype=np.float64)
        self._inflight = 0.0
        self._dirty = True  # attempt starts on the next tick
        self.stats = dict(
            copies_done=0, drops_done=0,
            migration_transferred=0.0, migration_wasted=0.0,
            max_inflight=0.0, stall_ticks=0, aborted_transfers=0,
        )

    # ------------------------------------------------------------ accessors
    @property
    def done(self) -> bool:
        return (
            not self._pending and not self._active
            and bool(self._landed.all())
            and bool(self._drop_done.all())
            and not self._ready_drops
        )

    @property
    def inflight_bytes(self) -> float:
        """Summed size of the active transfers (weight units)."""
        return self._inflight

    def loads(self) -> np.ndarray:
        """Per-partition committed + reserved load the headroom bound is
        enforced against."""
        return self._base_load + self._reserved

    def refresh_loads(self) -> None:
        """Re-sync the committed-load ledger with the member matrix after an
        external mutation (failover repair copies, row restores)."""
        self._base_load = self.pl.partition_weights()
        self._dirty = True

    # ------------------------------------------------------------- failover
    def on_partition_down(self, p: int) -> None:
        """A transfer endpoint died (the caller has already masked the
        member row): abort its in-flight transfers (bytes wasted, copies
        re-queued at the schedule head in stable order), count its landed
        copies un-landed again, and defer the drops that waited on them."""
        p = int(p)
        self._down.add(p)
        keep: list[_Transfer] = []
        requeue: list[int] = []
        for tr in self._active:
            if tr.dest == p or tr.src == p:
                self.stats["migration_wasted"] += tr.size - tr.remaining
                self.stats["aborted_transfers"] += 1
                self._reserved[tr.dest] -= tr.size
                self._inflight -= tr.size
                requeue.append(tr.idx)
                reg = _obs.registry()
                if reg.active:
                    reg.inc("migration_wasted_total", tr.size - tr.remaining)
                    _obs.tracer().event(
                        "migration.abort", item=tr.item, dest=tr.dest,
                        src=tr.src, moved=tr.size - tr.remaining,
                    )
            else:
                keep.append(tr)
        self._active = keep
        self._pending = sorted(requeue) + self._pending
        # landed copies on p were just masked with the row: they must land
        # again (partition_up restores them without a second transfer)
        masked = np.flatnonzero(self._landed & (self.plan.copy_dest == p))
        if len(masked):
            self._landed[masked] = False
            np.add.at(self._unlanded, self.plan.copy_item[masked], 1)
            # the restore-time re-land will count them again
            self.stats["copies_done"] -= len(masked)
        self.refresh_loads()

    def on_partition_up(self, p: int) -> None:
        """A dead endpoint returned (the caller has already restored its
        saved row): copies that had landed before the failure are live
        again, and their items' deferred drops re-arm."""
        p = int(p)
        self._down.discard(p)
        restored = np.flatnonzero(
            ~self._landed
            & (self.plan.copy_dest == p)
            & self.pl.member[p, self.plan.copy_item]
        )
        for i in restored:
            self._land(int(i), transfer=None)
        self._pending = [i for i in self._pending if i not in set(restored)]
        self.refresh_loads()

    # ----------------------------------------------------------------- tick
    def advance(self, nticks: int) -> None:
        """Advance serving time by ``nticks`` queries, progressing transfers
        at ``bandwidth`` weight-units per tick.  Returns as soon as the
        migration is done — ``now`` stops at the completing tick, so it
        reads as the actual migration duration."""
        for _ in range(int(nticks)):
            if self.done:
                return
            self._step()

    def _step(self) -> None:
        self._run_ready_drops()
        if self._dirty:
            started = self._try_start()
            self._dirty = False
            if (
                not started and not self._active and self._pending
                and not self._down and not self._ready_drops
            ):
                no_src = sorted({
                    int(self.plan.copy_item[idx]) for idx in self._pending
                    if self._pick_source(int(self.plan.copy_item[idx])) < 0
                })
                if no_src:
                    raise RuntimeError(
                        f"migration stalled at tick {self.now}: "
                        f"{len(no_src)} pending items have no live source "
                        f"replica to copy from (e.g. {no_src[:5]}) — "
                        f"plan_migration only validates coverage of the "
                        f"target layout; the live layout must hold every "
                        f"item being copied"
                    )
                raise RuntimeError(
                    f"migration stalled at tick {self.now}: "
                    f"{len(self._pending)} pending copies are blocked and "
                    f"no transfer is active — migration_headroom "
                    f"{self.plan.headroom} is too tight for this diff"
                )
        if not self._active:
            if self._pending:
                self.stats["stall_ticks"] += 1
            self.now += 1
            return
        budget = self.plan.bandwidth
        finished: list[_Transfer] = []
        for tr in self._active:
            if budget <= 0:
                break
            take = min(tr.remaining, budget)
            tr.remaining -= take
            budget -= take
            self.stats["migration_transferred"] += take
            if tr.remaining <= 1e-12:
                finished.append(tr)
        if finished:
            self._active = [tr for tr in self._active if tr.remaining > 1e-12]
            for tr in finished:  # start order == completion order
                self._reserved[tr.dest] -= tr.size
                self._base_load[tr.dest] += tr.size
                self._inflight -= tr.size
                self._land(tr.idx, transfer=tr)
            self._dirty = True  # slots and/or space freed
        reg = _obs.registry()
        if reg.active:
            spent = self.plan.bandwidth - budget
            if spent > 0:
                reg.inc("migration_transferred_total", spent)
            reg.set("migration_inflight", self._inflight)
        self.now += 1

    def _land(self, idx: int, transfer: _Transfer | None) -> None:
        """Copy ``idx`` is live: flip the member bit, emit the event, and
        release the item's drops when it was the last missing copy."""
        dest = int(self.plan.copy_dest[idx])
        v = int(self.plan.copy_item[idx])
        self.pl.member[dest, v] = True
        self._landed[idx] = True
        self._unlanded[v] -= 1
        self.stats["copies_done"] += 1
        reg = _obs.registry()
        if reg.active:
            reg.inc("migration_copies_total")
        if transfer is not None:
            self.events.append(
                TransferEvent(self.now, "copy", dest, v, transfer.src)
            )
            tr_ = _obs.tracer()
            if tr_.active:
                tr_.complete(
                    "migration.transfer", transfer.t0, time.perf_counter(),
                    item=v, dest=dest, src=transfer.src, size=transfer.size,
                    ticks=self.now - transfer.tick0,
                )
        if self._unlanded[v] == 0:
            self._ready_drops.extend(self._drops_of.get(v, ()))
            self._run_ready_drops()

    def _run_ready_drops(self) -> None:
        """Execute released drops whose partition is live; an old replica
        on a down partition keeps its drop deferred (executing it against a
        masked row would resurrect on restore), and an item with ANY copy
        host currently down holds all its drops (the landed copy is masked,
        so the old replica is still load-bearing)."""
        if not self._ready_drops:
            return
        deferred: list[int] = []
        for j in self._ready_drops:
            if self._drop_done[j]:
                # a down/up cycle re-released an item whose drop already ran
                continue
            p = int(self.plan.drop_part[j])
            v = int(self.plan.drop_item[j])
            if p in self._down or self._unlanded[v] > 0:
                deferred.append(j)
                continue
            self.pl.member[p, v] = False
            self._base_load[p] -= float(self._w[v])
            self._drop_done[j] = True
            self.stats["drops_done"] += 1
            _obs.registry().inc("migration_drops_total")
            self.events.append(TransferEvent(self.now, "drop", p, v))
        self._ready_drops = deferred
        self._dirty = True  # drops freed space: retry blocked starts

    def _try_start(self) -> int:
        """First-fit scan of the pending schedule: start every copy whose
        destination is live, under its concurrency cap, and inside the
        headroom bound, with a live source available.  Blocked copies are
        skipped, not head-of-line blocking."""
        if not self._pending:
            return 0
        active_per_dest = np.bincount(
            [tr.dest for tr in self._active],
            minlength=self.plan.num_partitions,
        ) if self._active else np.zeros(self.plan.num_partitions,
                                        dtype=np.int64)
        started = 0
        still: list[int] = []
        for idx in self._pending:
            dest = int(self.plan.copy_dest[idx])
            v = int(self.plan.copy_item[idx])
            if dest in self._down:
                still.append(idx)
                continue
            if self.pl.member[dest, v]:
                # already live (a failover repair beat the transfer to it):
                # no bytes to move, but the landing still gates drops
                self._land(idx, transfer=None)
                started += 1
                continue
            if active_per_dest[dest] >= self.plan.concurrency:
                still.append(idx)
                continue
            wv = float(self._w[v])
            if (self._base_load[dest] + self._reserved[dest] + wv
                    > self._cap[dest] + 1e-9):
                still.append(idx)
                continue
            src = self._pick_source(v)
            if src < 0:
                still.append(idx)
                continue
            tr_ = _obs.tracer()
            self._active.append(_Transfer(
                idx, dest, v, src, wv,
                t0=time.perf_counter() if tr_.active else 0.0,
                tick0=self.now,
            ))
            self._reserved[dest] += wv
            self._inflight += wv
            active_per_dest[dest] += 1
            started += 1
        self._pending = still
        if self._inflight > self.stats["max_inflight"]:
            self.stats["max_inflight"] = self._inflight
        reg = _obs.registry()
        if reg.active:
            reg.set("migration_inflight", self._inflight)
        return started

    def _pick_source(self, v: int) -> int:
        """Lowest-id live partition currently holding ``v`` (the preferred
        plan source when it is alive and still a holder, since the old
        holders precede any landed copies in id order only by accident —
        the live matrix is the single source of truth)."""
        holders = np.flatnonzero(self.pl.member[:, v])
        for p in holders:
            if int(p) not in self._down:
                return int(p)
        return -1
