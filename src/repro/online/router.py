"""Streaming replica-selection router.

The batch engine (`setcover.batched_cover_csr`) resolves every query of a
static trace at once; serving is different — queries arrive a few at a time
and the layout underneath can change (drift refits, partition failures).
`ReplicaRouter` bridges the two: it accumulates incoming queries into
microbatches of ``flags.FLAGS["router_microbatch"]`` and resolves each
microbatch with ONE `batched_cover_csr` call, so the serving hot path stays
vectorized while the layout may be hot-swapped between microbatches.

Tie-break modes
---------------
* default (``router_balance=False``): bit-identical to per-query
  `cover_for_query` — maximal intersection gain, ties -> lowest partition id.
* balanced (``router_balance=True``): among maximal-gain partitions, prefer
  the one with the LOWEST entry in the router's running access-load ledger
  (power-of-two-choices style, at microbatch granularity).  Implemented by
  routing against the member matrix with rows permuted ascending by
  (load, partition id): the engine's argmax then picks the least-loaded
  maximal-gain partition, and the permutation is inverted on the way out.
  The greedy gain sequence is unchanged (only which *equal-gain* replica
  serves), so spans are typically identical and load spreads across replicas.

  The permutation is rebuilt lazily: ``flags.FLAGS["router_ledger_epsilon"]``
  is the stale-ledger tolerance — the (load, id) lexsort only re-runs when
  some partition's load has shifted by more than
  ``epsilon * max(its load at the last sort, 1.0)`` since that sort.  At
  epsilon=0 (the default) ANY shift re-sorts, which is bit-identical to
  sorting every microbatch (an unshifted ledger lexsorts to the same
  permutation — asserted by tests/test_online.py); larger epsilons keep the
  O(N log N) sort off the steady-state hot path and only ever trade which
  equal-gain replica serves.

The ledger counts partition accesses (one per chosen cover member, the same
unit as ``SimulationResult.access_load``) and is updated once per microbatch.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from .. import flags as _flags
from .. import obs as _obs
from ..core.setcover import Placement, batched_cover_csr, queries_to_csr

__all__ = ["RoutedBatch", "ReplicaRouter", "queries_to_csr"]


@dataclasses.dataclass
class RoutedBatch:
    """Replica selection for one routed batch of queries.

    spans:       (B,) cover size per query
    cover_ptr:   (B+1,) CSR offsets into cover_parts
    cover_parts: (sum spans,) chosen partitions in greedy selection order
    pin_parts:   (P,) serving partition of every pin, aligned with the input
                 CSR (edge_ptr/edge_nodes)
    edge_ptr/edge_nodes: the input queries, CSR form
    """

    spans: np.ndarray
    cover_ptr: np.ndarray
    cover_parts: np.ndarray
    pin_parts: np.ndarray
    edge_ptr: np.ndarray
    edge_nodes: np.ndarray

    def chosen(self, i: int) -> np.ndarray:
        return self.cover_parts[self.cover_ptr[i]: self.cover_ptr[i + 1]]

    def cover(self, i: int) -> dict[int, np.ndarray]:
        """{partition: items read from it} for query i, partitions in greedy
        selection order (same shape as ``cover_for_query``'s output)."""
        lo, hi = self.edge_ptr[i], self.edge_ptr[i + 1]
        q = self.edge_nodes[lo:hi]
        pp = self.pin_parts[lo:hi]
        return {int(p): q[pp == p] for p in self.chosen(i)}


def _concat_batches(parts: list[RoutedBatch]) -> RoutedBatch:
    if len(parts) == 1:
        return parts[0]
    spans = np.concatenate([b.spans for b in parts])
    cover_ptr = np.zeros(len(spans) + 1, dtype=np.int64)
    np.cumsum(spans, out=cover_ptr[1:])
    eptr = np.zeros(len(spans) + 1, dtype=np.int64)
    np.cumsum(np.concatenate([np.diff(b.edge_ptr) for b in parts]),
              out=eptr[1:])
    return RoutedBatch(
        spans, cover_ptr,
        np.concatenate([b.cover_parts for b in parts]),
        np.concatenate([b.pin_parts for b in parts]),
        eptr,
        np.concatenate([b.edge_nodes for b in parts]),
    )


class ReplicaRouter:
    """Microbatching online replica selector over a live member matrix.

    ``member`` is held BY REFERENCE: in-place membership edits (failover
    masking, repair copies) are visible to the next microbatch without any
    router-side notification, and `swap_plan` replaces the whole matrix
    between microbatches (drift refits).  The access-load ledger and serving
    counters survive swaps — load history is a property of the traffic, not
    of one layout.
    """

    def __init__(self, member, microbatch: int | None = None,
                 balance: bool | None = None, node_cost=None):
        self.member = self._as_member(member)
        self.load = np.zeros(self.member.shape[0], dtype=np.float64)
        self._microbatch = microbatch
        self._balance = balance
        self._perm: np.ndarray | None = None       # cached tie-break rows
        self._perm_load: np.ndarray | None = None  # ledger at last sort
        self._perm_cost_aware = False              # key mode at last sort
        self._node_cost: np.ndarray | None = None
        self.stats = dict(served_queries=0, microbatches=0, plan_swaps=0,
                          ledger_sorts=0)
        if node_cost is not None:
            self.set_node_cost(node_cost)
        self._bind_load_gauge()

    def _bind_load_gauge(self) -> None:
        """(Re)bind the exported per-partition load GaugeVector to THIS
        router's live ledger.  The gauge holds a live reference (copied
        out lazily at snapshot time), so it must rebind whenever the
        ledger's identity could differ from what the registry last saw:
        at construction (a fresh router must not leave the gauge pointing
        at a previous router's ledger) and after ``swap_plan``."""
        reg = _obs.registry()
        if reg.active:
            reg.gauge_vector("router_partition_load").set(self.load)

    def set_node_cost(self, node_cost) -> None:
        """Install the per-partition serving-cost key the cost-aware
        tie-break multiplies into the ledger (typically
        `NodeProfile.routing_cost()`: access cost + normalized active
        power).  Only read when ``flags.FLAGS["router_cost_aware"]`` is on;
        a UNIFORM cost vector scales every ledger entry equally, so the
        permutation — and routing — stay bit-identical to least-loaded."""
        if node_cost is None:
            self._node_cost = None
        else:
            nc = np.asarray(node_cost, dtype=np.float64)
            if nc.shape != (self.num_partitions,):
                raise ValueError(
                    f"node_cost must be ({self.num_partitions},), "
                    f"got {nc.shape}"
                )
            if (nc <= 0).any():
                raise ValueError("node_cost entries must be positive")
            self._node_cost = nc
        self._perm = None  # cached permutation keyed on the old cost

    @staticmethod
    def _as_member(obj) -> np.ndarray:
        member = getattr(obj, "member", obj)
        member = np.asarray(member)
        if member.dtype != bool or member.ndim != 2:
            raise TypeError("router needs a (N, V) bool member matrix")
        return member

    @property
    def num_partitions(self) -> int:
        return self.member.shape[0]

    # --------------------------------------------------------------- config
    def _cfg(self) -> tuple[int, bool]:
        mb = self._microbatch
        if mb is None:
            mb = int(_flags.FLAGS.get("router_microbatch", 384))
        bal = self._balance
        if bal is None:
            bal = bool(_flags.FLAGS.get("router_balance", False))
        return max(1, mb), bal

    # ----------------------------------------------------------------- swap
    def swap_plan(self, member) -> None:
        """Hot-swap the layout (drift refit): takes effect at the next
        microbatch; ledger and counters carry over."""
        member = self._as_member(member)
        if member.shape[0] != self.num_partitions:
            raise ValueError("swap_plan cannot change the partition count")
        self.member = member
        self.stats["plan_swaps"] += 1
        reg = _obs.registry()
        if reg.active:
            reg.inc("router_plan_swaps_total")
            _obs.tracer().event("router.swap_plan",
                                swaps=self.stats["plan_swaps"])
        self._bind_load_gauge()

    # ---------------------------------------------------------------- route
    def route_one(self, query):
        """Scalar reference path: route a single query through the same
        selection the microbatched path performs (used by tests and the
        throughput benchmark's scalar-loop row)."""
        batch = self.route([np.asarray(query, dtype=np.int64)])
        return batch.chosen(0), batch.cover(0)

    def route(self, queries) -> RoutedBatch:
        """Resolve `queries` (list of pin-deduplicated int sequences) in
        microbatches — one `batched_cover_csr` call each — and update the
        access-load ledger per microbatch.  Raises ValueError if a query
        contains an item with no live replica (pre-filter such queries with
        `FailoverManager.serveable_mask` during an outage)."""
        ptr, nodes = queries_to_csr(queries)
        return self.route_csr(ptr, nodes)

    def route_csr(self, edge_ptr, edge_nodes) -> RoutedBatch:
        """CSR-form `route` (the zero-copy path for Hypergraph traces)."""
        edge_ptr = np.asarray(edge_ptr, dtype=np.int64)
        edge_nodes = np.asarray(edge_nodes, dtype=np.int64)
        nq = len(edge_ptr) - 1
        mb, bal = self._cfg()
        out: list[RoutedBatch] = []
        for lo in range(0, max(nq, 1), mb):
            hi = min(lo + mb, nq)
            if hi <= lo:
                break
            ptr = edge_ptr[lo: hi + 1] - edge_ptr[lo]
            nodes = edge_nodes[edge_ptr[lo]: edge_ptr[hi]]
            out.append(self._route_microbatch(ptr, nodes, bal))
        if not out:
            z = np.zeros(0, dtype=np.int64)
            return RoutedBatch(z, np.zeros(1, dtype=np.int64), z, z,
                               np.zeros(1, dtype=np.int64), z)
        return _concat_batches(out)

    def _ledger_perm(self) -> np.ndarray:
        """Rows ascending by (ledger load, id), rebuilt only when the ledger
        has drifted past ``router_ledger_epsilon`` since the last sort.
        With ``router_cost_aware`` on and a node-cost vector installed the
        sort key becomes ``load * node_cost`` — least COST, not least
        load — steering equal-gain ties toward cheap partitions."""
        cost_aware = (
            bool(_flags.FLAGS.get("router_cost_aware", False))
            and self._node_cost is not None
        )
        if self._perm is not None and cost_aware == self._perm_cost_aware:
            eps = float(_flags.FLAGS.get("router_ledger_epsilon", 0.0))
            drift = np.abs(self.load - self._perm_load)
            if not (drift > eps * np.maximum(self._perm_load, 1.0)).any():
                return self._perm
        key = self.load * self._node_cost if cost_aware else self.load
        self._perm = np.lexsort(
            (np.arange(self.num_partitions), key)
        ).astype(np.int64)
        self._perm_load = self.load.copy()
        self._perm_cost_aware = cost_aware
        self.stats["ledger_sorts"] += 1
        return self._perm

    def _route_microbatch(self, ptr, nodes, balance: bool) -> RoutedBatch:
        reg = _obs.registry()
        t0 = time.perf_counter() if reg.active else 0.0
        if balance:
            # rows ascending by (ledger load, id): the engine's lowest-row-id
            # tie-break becomes "least-loaded maximal-gain partition"
            order = self._ledger_perm()
            cov = batched_cover_csr(
                ptr, nodes, self.member[order], with_pin_parts=True
            )
            cover_parts = order[cov.cover_parts]
            pin_parts = order[cov.pin_parts]
        else:
            cov = batched_cover_csr(
                ptr, nodes, self.member, with_pin_parts=True
            )
            cover_parts = cov.cover_parts
            pin_parts = cov.pin_parts
        if len(cover_parts):
            self.load += np.bincount(
                cover_parts, minlength=self.num_partitions
            )
        self.stats["served_queries"] += len(ptr) - 1
        self.stats["microbatches"] += 1
        if reg.active:
            t1 = time.perf_counter()
            reg.observe("router_microbatch_seconds", t1 - t0)
            reg.inc("router_served_queries_total", len(ptr) - 1)
            reg.inc("router_microbatches_total")
            # live reference: copied out lazily at snapshot time
            reg.gauge_vector("router_partition_load").set(self.load)
            tr = _obs.tracer()
            if tr.active:
                tr.complete("serve.microbatch", t0, t1,
                            queries=len(ptr) - 1,
                            span_sum=int(cov.spans.sum()))
        return RoutedBatch(cov.spans, cov.cover_ptr, cover_parts, pin_parts,
                           ptr, nodes)

    # ------------------------------------------------------------- accessors
    def load_imbalance(self) -> float:
        """max / mean of the access-load ledger (1.0 = perfectly spread)."""
        m = self.load.mean()
        return float(self.load.max() / m) if m > 0 else 0.0

    def as_placement(self, capacity: float, node_weights) -> Placement:
        return Placement(self.member, capacity, np.asarray(node_weights))
