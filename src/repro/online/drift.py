"""Workload-drift detection: sliding-window sketch + refit trigger.

A placement is fitted against yesterday's trace; when the live workload
drifts (new co-access patterns), the plan's spans regress.  Two pieces turn
that observation into an online repair:

* `WorkloadSketch` — a sliding window of the last W served queries with
  exponentially decayed edge-frequency weights, rebuildable into a
  `Hypergraph` at any time (``to_hypergraph``).  With ``decay=1.0`` (the
  default) the rebuild is exactly ``Hypergraph.from_edges(window)`` — same
  CSR, unit edge weights — which `tests/test_online.py` asserts; a decay
  < 1 down-weights older queries so refits chase the live mixture.

* `DriftDetector` — monitors the windowed average span of served queries
  against the plan's fit-time baseline and, past
  ``baseline * flags.FLAGS["drift_threshold"]``, requests an incremental
  refit: `PlacementService.refit` warm-starts LMBR from the live plan on the
  sketch's window, so new replicas only move into free space and the
  resulting plan is cheap to hot-swap between router microbatches.
"""

from __future__ import annotations

from collections import deque

import numpy as np

from .. import flags as _flags
from .. import obs as _obs
from ..core.hypergraph import Hypergraph
from ..core.placement_service import PlacementPlan, PlacementService

__all__ = ["WorkloadSketch", "DriftDetector"]


class WorkloadSketch:
    """Sliding window of the last ``window`` queries, decayed.

    ``observe`` appends served queries (pin-deduplicated int arrays, the
    router's input form); ``to_hypergraph`` rebuilds the window into a
    `Hypergraph` whose edges are the window queries in arrival order (oldest
    first) and whose edge weight for the query at age ``a`` (0 = newest) is
    ``decay ** a``.  ``decay=1.0`` therefore reproduces
    ``Hypergraph.from_edges(window_queries)`` exactly.
    """

    def __init__(self, num_items: int, window: int | None = None,
                 decay: float = 1.0):
        if window is None:
            window = int(_flags.FLAGS.get("drift_window", 512))
        self.num_items = int(num_items)
        self.window = int(window)
        self.decay = float(decay)
        self._queries: deque[np.ndarray] = deque(maxlen=self.window)
        self.total_observed = 0

    def __len__(self) -> int:
        return len(self._queries)

    @property
    def full(self) -> bool:
        return len(self._queries) == self.window

    def observe(self, query) -> None:
        self._queries.append(np.asarray(query, dtype=np.int64))
        self.total_observed += 1

    def observe_batch(self, queries) -> None:
        for q in queries:
            self.observe(q)

    def window_queries(self) -> list[np.ndarray]:
        """The window's queries, oldest first."""
        return list(self._queries)

    def edge_weights(self) -> np.ndarray:
        """decay**age per window query (aligned with `window_queries`)."""
        n = len(self._queries)
        ages = np.arange(n - 1, -1, -1, dtype=np.float64)
        return self.decay ** ages

    def to_hypergraph(self) -> Hypergraph:
        """Rebuild the window into a Hypergraph (arrival order, decayed
        edge weights; ``decay=1.0`` == direct construction; an empty window
        rebuilds to an edge-free hypergraph)."""
        qs = self.window_queries()
        return Hypergraph.from_edges(
            qs, num_nodes=self.num_items,
            edge_weights=self.edge_weights() if qs else None,
        )


class DriftDetector:
    """Windowed avg_span monitor + `PlacementService.refit` trigger.

    ``baseline`` is the plan's fit-time average span (computed over the
    training workload by the caller, or over the first full window via
    `seed_baseline`).  After `observe` ingests each routed microbatch's
    queries and spans, `should_refit` is True once the window is full and

        windowed_avg_span > baseline * threshold.

    `refit` then rebuilds the window hypergraph, runs the incremental LMBR
    refit, adopts the new plan, and re-baselines against it — the caller
    hot-swaps the returned plan into its router.
    """

    def __init__(self, plan: PlacementPlan,
                 service: PlacementService | None = None,
                 window: int | None = None, threshold: float | None = None,
                 decay: float = 1.0, refit_moves: int = 256):
        if window is None:
            window = int(_flags.FLAGS.get("drift_window", 512))
        if threshold is None:
            threshold = float(_flags.FLAGS.get("drift_threshold", 1.25))
        self.plan = plan
        self.service = service or PlacementService("lmbr")
        self.threshold = float(threshold)
        self.refit_moves = int(refit_moves)
        self.sketch = WorkloadSketch(plan.member.shape[1], window, decay)
        self._span_window: deque[int] = deque(maxlen=window)
        self.baseline: float | None = None
        self.stats = dict(drift_checks=0, drift_fires=0, refits=0)

    # ------------------------------------------------------------- observe
    def set_baseline(self, avg_span: float) -> None:
        """Pin the fit-time baseline (avg span of the training workload
        under the freshly fitted plan)."""
        self.baseline = float(avg_span)

    def seed_baseline_from(self, queries) -> float:
        """Baseline = the live plan's avg span over `queries`."""
        self.baseline = float(self.plan.avg_span(queries))
        return self.baseline

    def observe(self, queries, spans) -> None:
        """Ingest one routed microbatch: the served queries (router input
        order) and their spans (RoutedBatch.spans)."""
        self.sketch.observe_batch(queries)
        self._span_window.extend(int(s) for s in np.asarray(spans))

    @property
    def windowed_avg_span(self) -> float:
        if not self._span_window:
            return 0.0
        return float(np.mean(self._span_window))

    # ------------------------------------------------------------- trigger
    def should_refit(self) -> bool:
        self.stats["drift_checks"] += 1
        if self.baseline is None:
            # no fit-time baseline given: adopt the first full window as one
            if self.sketch.full:
                self.baseline = self.windowed_avg_span
            return False
        if not self.sketch.full:
            return False
        fired = self.windowed_avg_span > self.baseline * self.threshold
        if fired:
            self.stats["drift_fires"] += 1
            reg = _obs.registry()
            if reg.active:
                reg.inc("drift_fires_total")
                _obs.tracer().event(
                    "drift.fire", windowed=self.windowed_avg_span,
                    baseline=self.baseline, threshold=self.threshold,
                )
        return fired

    def refit(self, dest_mask: np.ndarray | None = None) -> PlacementPlan:
        """Incremental refit on the sketch window; adopts and returns the
        new plan, with spans re-baselined against it.  The span window is
        cleared so the trigger re-arms on post-swap traffic only.

        ``dest_mask`` ((N,) bool) is the outage path: when the live layout
        has partitions down, the caller passes the surviving rows so the
        refit keeps adapting WITHOUT copying anything onto dead partitions
        (the down rows of ``self.plan.member`` are already masked, since the
        plan shares the live membership matrix)."""
        window = self.sketch.window_queries()
        with _obs.tracer().span("drift.refit", window=len(window)):
            new_plan = self.service.refit(
                self.plan, window, max_moves=self.refit_moves,
                dest_mask=dest_mask,
            )
        self.plan = new_plan
        self.stats["refits"] += 1
        _obs.registry().inc("drift_refits_total")
        self._span_window.clear()
        self.baseline = float(new_plan.avg_span(window))
        return new_plan
