"""Span-aware failover: partition down/up masking, coverage audit, repair.

Replication exists for fault tolerance; the paper exploits it for
co-location.  This module closes the loop in the other direction: when a
partition dies, the layout loses both a fault domain and part of its
co-location structure, and the repair should restore the former without
squandering the latter.

`FailoverManager` wraps the LIVE `Placement` the router serves from (the
member matrix is mutated in place, so masking and repair are visible to the
next router microbatch):

* `partition_down(p)` saves p's membership row and zeroes it; queries then
  cover against surviving replicas only.  Items whose last replica lived on
  p are reported lost.
* `coverage_audit` / `serveable_mask` identify lost items and the queries
  that cannot be served until repair (the replay counts these as degraded
  rather than crashing the batched engine's unplaced-item ValueError).
* `repair(hg, k)` re-replicates under-replicated items into surviving free
  space by LMBR-style gain: items are processed hottest-first (descending
  weighted incident-edge degree, ties -> lowest item id) and each new copy
  goes to the surviving partition with the largest co-location benefit —
  the summed weight of the item's incident edges that already read another
  item from that partition — so repair copies land where they keep spans
  low.  Ties -> most free space, then lowest partition id; capacity is never
  exceeded (items that fit nowhere stay lost and are reported).

  Since PR 5 the benefit vectors come from ONE batched engine call per
  repair *wave* (`_batched_benefits`: a single gather over every pending
  item's incident-edge pins + one `logical_or.reduceat` + one sequential
  scatter-add) instead of a per-item Python loop over edges.  Placement
  stays strictly sequential in the same hottest-first order, and a wave
  ends exactly when a just-placed copy could invalidate the next item's
  precomputed benefit (they share an edge) — so the batched path is
  BIT-IDENTICAL to the retained per-item reference (`repair_reference`),
  asserted on the bench_online kill scenarios and in tests/test_online.py.
* `partition_up(p)` restores the saved row (the replicas come back; repair
  copies made meanwhile simply remain as extra replicas).
"""

from __future__ import annotations

import time

import numpy as np

from .. import obs as _obs
from ..core.cluster import NodeProfile
from ..core.hypergraph import Hypergraph
from ..core.setcover import Placement

__all__ = ["FailoverManager"]


class FailoverManager:
    def __init__(self, placement: Placement,
                 profile: NodeProfile | None = None):
        self.pl = placement
        self._saved: dict[int, np.ndarray] = {}
        self._loads = placement.partition_weights()
        # per-partition failure probability: repair prefers reliable
        # survivors among equal-benefit candidates.  Without a profile the
        # vector is constant, which degenerates the preference away —
        # bit-identical to the pre-profile tie-break.
        self._fail = (
            np.asarray(profile.fail_prob, dtype=np.float64)
            if profile is not None
            else np.zeros(placement.num_partitions, dtype=np.float64)
        )
        if len(self._fail) != placement.num_partitions:
            raise ValueError(
                f"profile has {len(self._fail)} partitions, placement has "
                f"{placement.num_partitions}"
            )
        self.stats = dict(
            partitions_down=0, repaired_items=0, unrepairable_items=0,
        )

    # ------------------------------------------------------------- accessors
    @property
    def member(self) -> np.ndarray:
        return self.pl.member

    @property
    def down_partitions(self) -> list[int]:
        return sorted(self._saved)

    def restored_member(self) -> np.ndarray:
        """The member matrix as it will read once every down partition's
        saved row is restored by `partition_up` (a copy; the live matrix is
        untouched).  Migration planning diffs against this view so a down
        partition's stale replicas get scheduled (deferred) drops instead
        of silently surviving the row restore."""
        m = self.pl.member.copy()
        for p, row in self._saved.items():
            m[p] = row
        return m

    def rebase(self, placement: Placement) -> None:
        """Adopt a hot-swapped live placement (drift refit).

        Legal during an outage only when the new layout keeps every down
        partition's membership row EMPTY (the outage-refit contract: the
        fit ran on the failure-masked matrix with down rows excluded from
        receiving copies), so the saved pre-failure rows stay restorable by
        `partition_up` and the load ledger stays consistent."""
        for p in self._saved:
            if placement.member[p].any():
                raise RuntimeError(
                    f"cannot rebase: new placement stores items on down "
                    f"partition {p}"
                )
        self.pl = placement
        self._loads = placement.partition_weights()

    def resync_loads(self) -> None:
        """Re-sync the load ledger with the live member matrix after an
        external in-place mutation (live-migration copies and drops land
        directly in the shared matrix, bypassing this manager)."""
        self._loads = self.pl.partition_weights()

    # ------------------------------------------------------------ down / up
    def partition_down(self, p: int) -> np.ndarray:
        """Mask partition p's membership row.  Returns the items that lost
        their LAST live replica (weight > 0)."""
        p = int(p)
        if p in self._saved:
            raise ValueError(f"partition {p} is already down")
        self._saved[p] = self.pl.member[p].copy()
        self.pl.member[p] = False
        self._loads[p] = 0.0
        self.stats["partitions_down"] += 1
        reg = _obs.registry()
        if reg.active:
            reg.inc("failover_partitions_down_total")
            reg.gauge("failover_down_now").add(1.0)
            _obs.tracer().event("failover.down", partition=p)
        lost = (
            self._saved[p]
            & ~self.pl.member.any(axis=0)
            & (self.pl.node_weights > 0)
        )
        return np.flatnonzero(lost)

    def partition_up(self, p: int) -> None:
        """Restore partition p's saved membership row."""
        p = int(p)
        if p not in self._saved:
            raise ValueError(f"partition {p} is not down")
        row = self._saved.pop(p)
        self.pl.member[p] = row
        self._loads[p] = float(self.pl.node_weights[row].sum())
        reg = _obs.registry()
        if reg.active:
            reg.gauge("failover_down_now").add(-1.0)
            _obs.tracer().event("failover.up", partition=p)

    # ---------------------------------------------------------------- audit
    def uncovered_items(self) -> np.ndarray:
        """Items with weight > 0 and no live replica."""
        return np.flatnonzero(
            ~self.pl.member.any(axis=0) & (self.pl.node_weights > 0)
        )

    def serveable_mask(self, edge_ptr, edge_nodes) -> np.ndarray:
        """Per-CSR-query bool: True iff every pin has a live replica."""
        edge_ptr = np.asarray(edge_ptr, dtype=np.int64)
        edge_nodes = np.asarray(edge_nodes, dtype=np.int64)
        bad = (~self.pl.member.any(axis=0))[edge_nodes].astype(np.int64)
        cb = np.concatenate([[0], np.cumsum(bad)])
        return (cb[edge_ptr[1:]] - cb[edge_ptr[:-1]]) == 0

    def coverage_audit(self, hg: Hypergraph | None = None):
        """(lost_items, affected_edge_ids) — edge ids only when a workload
        hypergraph is given."""
        lost = self.uncovered_items()
        if hg is None:
            return lost, None
        affected = np.flatnonzero(
            ~self.serveable_mask(hg.edge_ptr, hg.edge_nodes)
        )
        return lost, affected

    # --------------------------------------------------------------- repair
    def replica_counts(self) -> np.ndarray:
        return self.pl.member.sum(axis=0)

    def _repair_order(self, hg: Hypergraph, k: int,
                      items: np.ndarray | None) -> np.ndarray:
        """Under-replicated items in repair order: hottest first (descending
        weighted degree, stable -> lowest item id on ties)."""
        if items is None:
            need = np.flatnonzero(
                (self.replica_counts() < k) & (self.pl.node_weights > 0)
            )
        else:
            need = np.asarray(items, dtype=np.int64)
        if not len(need):
            return need
        deg = hg.degrees()
        return need[np.argsort(-deg[need], kind="stable")]

    def _place_copies(self, hg: Hypergraph, v: int, k: int,
                      live_rows: np.ndarray, benefit: np.ndarray,
                      repaired: list[int]) -> bool:
        """Bring item v up to k live copies using a precomputed benefit
        vector (valid while no edge of v gains a new co-located pin).
        Returns True iff at least one copy was placed."""
        pl = self.pl
        placed = False
        while int(pl.member[live_rows, v].sum()) < k:
            wv = float(pl.node_weights[v])
            fits = (
                live_rows
                & (self._loads + wv <= pl.capacity + 1e-9)
                & ~pl.member[:, v]
            )
            if not fits.any():
                self.stats["unrepairable_items"] += 1
                break
            # max benefit; ties -> most reliable survivor, then most free
            # space, then lowest id (the fail key is constant without a
            # profile, so the legacy tie-break is untouched)
            cand = np.flatnonzero(fits)
            key = np.lexsort((
                cand,                       # lowest id last resort
                self._loads[cand],          # least loaded
                self._fail[cand],           # lowest failure probability
                -benefit[cand],             # max co-location benefit
            ))
            d = int(cand[key[0]])
            pl.member[d, v] = True
            self._loads[d] += wv
            repaired.append(int(v))
            placed = True
        return placed

    def _benefit_reference(self, hg: Hypergraph, v: int) -> np.ndarray:
        """Per-item co-location benefit, the retained per-edge oracle."""
        node_ptr, node_edges = hg.incidence()
        ev = node_edges[node_ptr[v]: node_ptr[v + 1]]
        benefit = np.zeros(self.pl.num_partitions, dtype=np.float64)
        for e in ev:
            pins = hg.edge(int(e))
            pins = pins[pins != v]
            if len(pins):
                benefit += float(hg.edge_weights[e]) * (
                    self.pl.member[:, pins].any(axis=1)
                )
        return benefit

    def _batched_benefits(self, hg: Hypergraph, items: np.ndarray) -> np.ndarray:
        """(len(items), N) co-location benefit matrix against the CURRENT
        layout, one vectorized engine pass for the whole repair wave.

        Exactness: row i accumulates `w_e * (partition holds another pin of
        e)` over item i's incident edges in incidence order — `np.add.at`
        is sequential over its index arrays, so each row's float-sum order
        matches `_benefit_reference`'s per-edge loop bit-for-bit."""
        pl = self.pl
        N = pl.num_partitions
        node_ptr, node_edges = hg.incidence()
        cnt = node_ptr[items + 1] - node_ptr[items]
        total = int(cnt.sum())
        out = np.zeros((len(items), N), dtype=np.float64)
        if not total:
            return out
        base = np.repeat(node_ptr[items], cnt)
        off = np.arange(total, dtype=np.int64) - np.repeat(
            np.concatenate([[0], np.cumsum(cnt[:-1])]), cnt
        )
        pair_edge = node_edges[base + off]          # (F,) incident edges
        pair_row = np.repeat(
            np.arange(len(items), dtype=np.int64), cnt
        )
        pair_item = np.repeat(items, cnt)
        ptr, pidx = hg.pin_indices(pair_edge)
        pins = hg.edge_nodes[pidx]
        ppair = np.repeat(
            np.arange(len(pair_edge), dtype=np.int64), np.diff(ptr)
        )
        kept = np.flatnonzero(pins != pair_item[ppair])  # "other" pins only
        held = np.zeros((len(pair_edge), N), dtype=bool)
        if len(kept):
            kp = ppair[kept]
            starts = np.flatnonzero(
                np.concatenate([[True], kp[1:] != kp[:-1]])
            )
            red = np.logical_or.reduceat(
                pl.member[:, pins[kept]], starts, axis=1
            )  # (N, groups)
            held[kp[starts]] = red.T
        np.add.at(
            out, pair_row, hg.edge_weights[pair_edge][:, None] * held
        )
        return out

    def repair(self, hg: Hypergraph, k: int = 1,
               items: np.ndarray | None = None) -> np.ndarray:
        """Re-replicate under-replicated items into surviving free space.

        Ensures every item with weight > 0 (or the explicit `items`) has at
        least `k` live replicas where capacity allows.  Sequential greedy in
        hottest-first order; each copy's destination maximizes co-location
        benefit against the CURRENT live layout, so items repaired earlier
        attract their co-accessed peers.  Returns the unique repaired item
        ids; ``stats["repaired_items"]`` counts replica COPIES placed (== the
        returned length for k=1, larger when one item needs several copies).

        Benefits are computed one batched call per WAVE; a wave restarts at
        the first item whose benefit could be stale (it shares an edge with
        an item that just received a copy), so the placements — order,
        destinations, float ties — are bit-identical to `repair_reference`.
        """
        _tr = _obs.tracer()
        _t0 = time.perf_counter() if _tr.active else 0.0
        pl = self.pl
        live_rows = np.ones(pl.num_partitions, dtype=bool)
        live_rows[self.down_partitions] = False
        order = self._repair_order(hg, k, items)
        if not len(order):
            return order
        node_ptr, node_edges = hg.incidence()
        repaired: list[int] = []
        pos = 0
        while pos < len(order):
            # capped wave: on clustered workloads consecutive hot items
            # often share edges, so a wave can end after one placement —
            # the cap bounds the recompute waste to a constant factor
            # instead of going quadratic over the remaining tail
            wave = order[pos: pos + 64]
            benefits = self._batched_benefits(hg, wave)
            touched = np.zeros(hg.num_edges, dtype=bool)
            i = 0
            while i < len(wave):
                v = int(wave[i])
                ev = node_edges[node_ptr[v]: node_ptr[v + 1]]
                if i > 0 and len(ev) and touched[ev].any():
                    break  # precomputed benefit may be stale: new wave
                if self._place_copies(hg, v, k, live_rows, benefits[i],
                                      repaired):
                    touched[ev] = True
                i += 1
            pos += max(i, 1)
        self.stats["repaired_items"] += len(repaired)
        reg = _obs.registry()
        if reg.active:
            reg.inc("failover_repaired_items_total", len(repaired))
        if _tr.active:
            _tr.complete("failover.repair", _t0, time.perf_counter(),
                         copies=len(repaired))
        return np.asarray(sorted(set(repaired)), dtype=np.int64)

    def repair_reference(self, hg: Hypergraph, k: int = 1,
                         items: np.ndarray | None = None) -> np.ndarray:
        """The retained per-item oracle `repair` is asserted against:
        identical greedy order and tie-breaks, one per-edge Python benefit
        loop per copy instead of one batched call per wave."""
        pl = self.pl
        live_rows = np.ones(pl.num_partitions, dtype=bool)
        live_rows[self.down_partitions] = False
        order = self._repair_order(hg, k, items)
        if not len(order):
            return order
        repaired: list[int] = []
        for v in order:
            v = int(v)
            self._place_copies(
                hg, v, k, live_rows, self._benefit_reference(hg, v), repaired
            )
        self.stats["repaired_items"] += len(repaired)
        return np.asarray(sorted(set(repaired)), dtype=np.int64)
