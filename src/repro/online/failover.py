"""Span-aware failover: partition down/up masking, coverage audit, repair.

Replication exists for fault tolerance; the paper exploits it for
co-location.  This module closes the loop in the other direction: when a
partition dies, the layout loses both a fault domain and part of its
co-location structure, and the repair should restore the former without
squandering the latter.

`FailoverManager` wraps the LIVE `Placement` the router serves from (the
member matrix is mutated in place, so masking and repair are visible to the
next router microbatch):

* `partition_down(p)` saves p's membership row and zeroes it; queries then
  cover against surviving replicas only.  Items whose last replica lived on
  p are reported lost.
* `coverage_audit` / `serveable_mask` identify lost items and the queries
  that cannot be served until repair (the replay counts these as degraded
  rather than crashing the batched engine's unplaced-item ValueError).
* `repair(hg, k)` re-replicates under-replicated items into surviving free
  space by LMBR-style gain: items are processed hottest-first (descending
  weighted incident-edge degree, ties -> lowest item id) and each new copy
  goes to the surviving partition with the largest co-location benefit —
  the summed weight of the item's incident edges that already read another
  item from that partition — so repair copies land where they keep spans
  low.  Ties -> most free space, then lowest partition id; capacity is never
  exceeded (items that fit nowhere stay lost and are reported).
* `partition_up(p)` restores the saved row (the replicas come back; repair
  copies made meanwhile simply remain as extra replicas).
"""

from __future__ import annotations

import numpy as np

from ..core.hypergraph import Hypergraph
from ..core.setcover import Placement

__all__ = ["FailoverManager"]


class FailoverManager:
    def __init__(self, placement: Placement):
        self.pl = placement
        self._saved: dict[int, np.ndarray] = {}
        self._loads = placement.partition_weights()
        self.stats = dict(
            partitions_down=0, repaired_items=0, unrepairable_items=0,
        )

    # ------------------------------------------------------------- accessors
    @property
    def member(self) -> np.ndarray:
        return self.pl.member

    @property
    def down_partitions(self) -> list[int]:
        return sorted(self._saved)

    def rebase(self, placement: Placement) -> None:
        """Adopt a hot-swapped live placement (drift refit).  Only legal with
        no partition down — refits are deferred during an outage."""
        if self._saved:
            raise RuntimeError("cannot rebase while partitions are down")
        self.pl = placement
        self._loads = placement.partition_weights()

    # ------------------------------------------------------------ down / up
    def partition_down(self, p: int) -> np.ndarray:
        """Mask partition p's membership row.  Returns the items that lost
        their LAST live replica (weight > 0)."""
        p = int(p)
        if p in self._saved:
            raise ValueError(f"partition {p} is already down")
        self._saved[p] = self.pl.member[p].copy()
        self.pl.member[p] = False
        self._loads[p] = 0.0
        self.stats["partitions_down"] += 1
        lost = (
            self._saved[p]
            & ~self.pl.member.any(axis=0)
            & (self.pl.node_weights > 0)
        )
        return np.flatnonzero(lost)

    def partition_up(self, p: int) -> None:
        """Restore partition p's saved membership row."""
        p = int(p)
        if p not in self._saved:
            raise ValueError(f"partition {p} is not down")
        row = self._saved.pop(p)
        self.pl.member[p] = row
        self._loads[p] = float(self.pl.node_weights[row].sum())

    # ---------------------------------------------------------------- audit
    def uncovered_items(self) -> np.ndarray:
        """Items with weight > 0 and no live replica."""
        return np.flatnonzero(
            ~self.pl.member.any(axis=0) & (self.pl.node_weights > 0)
        )

    def serveable_mask(self, edge_ptr, edge_nodes) -> np.ndarray:
        """Per-CSR-query bool: True iff every pin has a live replica."""
        edge_ptr = np.asarray(edge_ptr, dtype=np.int64)
        edge_nodes = np.asarray(edge_nodes, dtype=np.int64)
        bad = (~self.pl.member.any(axis=0))[edge_nodes].astype(np.int64)
        cb = np.concatenate([[0], np.cumsum(bad)])
        return (cb[edge_ptr[1:]] - cb[edge_ptr[:-1]]) == 0

    def coverage_audit(self, hg: Hypergraph | None = None):
        """(lost_items, affected_edge_ids) — edge ids only when a workload
        hypergraph is given."""
        lost = self.uncovered_items()
        if hg is None:
            return lost, None
        affected = np.flatnonzero(
            ~self.serveable_mask(hg.edge_ptr, hg.edge_nodes)
        )
        return lost, affected

    # --------------------------------------------------------------- repair
    def replica_counts(self) -> np.ndarray:
        return self.pl.member.sum(axis=0)

    def repair(self, hg: Hypergraph, k: int = 1,
               items: np.ndarray | None = None) -> np.ndarray:
        """Re-replicate under-replicated items into surviving free space.

        Ensures every item with weight > 0 (or the explicit `items`) has at
        least `k` live replicas where capacity allows.  Sequential greedy in
        hottest-first order; each copy's destination maximizes co-location
        benefit against the CURRENT live layout, so items repaired earlier
        attract their co-accessed peers.  Returns the unique repaired item
        ids; ``stats["repaired_items"]`` counts replica COPIES placed (== the
        returned length for k=1, larger when one item needs several copies).
        """
        pl = self.pl
        live_rows = np.ones(pl.num_partitions, dtype=bool)
        live_rows[self.down_partitions] = False
        if items is None:
            need = np.flatnonzero(
                (self.replica_counts() < k) & (pl.node_weights > 0)
            )
        else:
            need = np.asarray(items, dtype=np.int64)
        if not len(need):
            return need
        deg = hg.degrees()
        order = need[np.argsort(-deg[need], kind="stable")]
        node_ptr, node_edges = hg.incidence()
        repaired: list[int] = []
        for v in order:
            v = int(v)
            while int(pl.member[live_rows, v].sum()) < k:
                wv = float(pl.node_weights[v])
                fits = (
                    live_rows
                    & (self._loads + wv <= pl.capacity + 1e-9)
                    & ~pl.member[:, v]
                )
                if not fits.any():
                    self.stats["unrepairable_items"] += 1
                    break
                ev = node_edges[node_ptr[v]: node_ptr[v + 1]]
                benefit = np.zeros(pl.num_partitions, dtype=np.float64)
                for e in ev:
                    pins = hg.edge(int(e))
                    pins = pins[pins != v]
                    if len(pins):
                        benefit += float(hg.edge_weights[e]) * (
                            pl.member[:, pins].any(axis=1)
                        )
                # max benefit; ties -> most free space, then lowest id
                cand = np.flatnonzero(fits)
                key = np.lexsort((
                    cand,                       # lowest id last resort
                    self._loads[cand],          # least loaded
                    -benefit[cand],             # max co-location benefit
                ))
                d = int(cand[key[0]])
                pl.member[d, v] = True
                self._loads[d] += wv
                repaired.append(v)
        self.stats["repaired_items"] += len(repaired)
        return np.asarray(sorted(set(repaired)), dtype=np.int64)
