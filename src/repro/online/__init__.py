"""repro.online — online serving over the placement engine.

The batch pipeline (``repro.core``) fits a layout and replays a static
trace; this package serves queries AGAINST that layout while it changes:

  router    — streaming replica-selection router: microbatched
              batched_cover_csr calls, optional load-aware tie-break
              (``flags.FLAGS["router_balance"]``)
  drift     — sliding-window workload sketch + windowed-avg-span drift
              trigger invoking PlacementService.refit (hot-swap between
              microbatches)
  failover  — partition down/up masking, coverage audit, span-aware repair
              of lost replicas into surviving free space
  migration — live plan migration: old-vs-new layout diff, bandwidth-paced
              replica transfer schedule (``flags.FLAGS
              ["migration_bandwidth"]``), union-layout serving until every
              copy lands, copies-before-drops per item

`Simulator.run_online` (``repro.core.simulator``) wires them into an
event-capable trace replay; `benchmarks/bench_online.py` and
`benchmarks/bench_migration.py` measure them.
"""

from .router import ReplicaRouter, RoutedBatch, queries_to_csr  # noqa: F401
from .drift import DriftDetector, WorkloadSketch  # noqa: F401
from .failover import FailoverManager  # noqa: F401
from .migration import (  # noqa: F401
    MigrationExecutor,
    MigrationPlan,
    PlanDiff,
    TransferEvent,
    diff_plans,
    diff_plans_reference,
    plan_migration,
)
