"""repro.online — online serving over the placement engine.

The batch pipeline (``repro.core``) fits a layout and replays a static
trace; this package serves queries AGAINST that layout while it changes:

  router    — streaming replica-selection router: microbatched
              batched_cover_csr calls, optional load-aware tie-break
              (``flags.FLAGS["router_balance"]``)
  drift     — sliding-window workload sketch + windowed-avg-span drift
              trigger invoking PlacementService.refit (hot-swap between
              microbatches)
  failover  — partition down/up masking, coverage audit, span-aware repair
              of lost replicas into surviving free space

`Simulator.run_online` (``repro.core.simulator``) wires the three into an
event-capable trace replay; `benchmarks/bench_online.py` measures them.
"""

from .router import ReplicaRouter, RoutedBatch, queries_to_csr  # noqa: F401
from .drift import DriftDetector, WorkloadSketch  # noqa: F401
from .failover import FailoverManager  # noqa: F401
