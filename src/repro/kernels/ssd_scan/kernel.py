"""Pallas TPU kernel for the Mamba2 SSD chunk scan.

TPU adaptation of the SSD algorithm: the GPU implementation leans on warp
shuffles and shared-memory scans; on TPU we express each chunk as dense
(L x L) / (L x N) matmuls (MXU work) and carry the (P x N) inter-chunk state
in VMEM scratch across the sequential chunk grid axis — the memory hierarchy
analogue of the paper's Listing-1 decomposition.

Grid: (batch, heads, num_chunks[sequential]).  Per step the kernel consumes
x (L, P), dt (L,), B (L, N), C (L, N) VMEM tiles and emits y (L, P).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import _compiler_params


def _ssd_kernel(
    a_ref,                       # (1,) per-head decay A (negative)
    x_ref, dt_ref, b_ref, c_ref,  # VMEM tiles
    y_ref,
    h_scr,                        # (P, N) carried state
    *, chunk: int,
):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0, 0].astype(jnp.float32)    # (L, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)  # (L,)
    bm = b_ref[0, 0, 0].astype(jnp.float32)   # (L, N)
    cm = c_ref[0, 0, 0].astype(jnp.float32)   # (L, N)
    a = a_ref[0]                              # scalar (negative)

    adt = a * dt                              # (L,)
    cum = jnp.cumsum(adt)                     # (L,)
    L = chunk
    # intra-chunk: gate[t, u] = exp(cum_t - cum_u) for u <= t
    decay = cum[:, None] - cum[None, :]
    mask = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1) <= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    gate = jnp.where(mask, jnp.exp(decay), 0.0)
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, L)
    att = cb * gate * dt[None, :]             # (L, L)
    y_intra = jax.lax.dot_general(att, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    # inter-chunk: y_inter[t] = exp(cum_t) * C_t . h   (h: (P, N))
    ch = jax.lax.dot_general(cm, h_scr[...], (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, P)
    y_ref[0, 0, 0] = (y_intra + jnp.exp(cum)[:, None] * ch).astype(y_ref.dtype)
    # state update: h' = exp(cum_L) h + sum_u exp(cum_L - cum_u) dt_u x_u B_u^T
    tail = jnp.exp(cum[-1] - cum) * dt        # (L,)
    dx = x * tail[:, None]                    # (L, P)
    h_scr[...] = jnp.exp(cum[-1]) * h_scr[...] + jax.lax.dot_general(
        dx, bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                          # (P, N)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array,      # (B, S, H, P)
    dt: jax.Array,     # (B, S, H) fp32 (softplus'd)
    a: jax.Array,      # (H,) fp32 negative decay
    bmat: jax.Array,   # (B, S, N)
    cmat: jax.Array,   # (B, S, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    if s % chunk:
        raise ValueError("sequence length must be a multiple of chunk")
    nc = s // chunk
    # (B, H, nc, L, ...) layouts so the chunk axis is a clean grid dim
    xT = x.transpose(0, 2, 1, 3).reshape(b, h, nc, chunk, p)
    dtT = dt.transpose(0, 2, 1).reshape(b, h, nc, chunk)
    bT = jnp.broadcast_to(bmat[:, None], (b, h, s, n)).reshape(b, h, nc, chunk, n)
    cT = jnp.broadcast_to(cmat[:, None], (b, h, s, n)).reshape(b, h, nc, chunk, n)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1,), lambda ib, ih, ic: (ih,)),
            pl.BlockSpec((1, 1, 1, chunk, p), lambda ib, ih, ic: (ib, ih, ic, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda ib, ih, ic: (ib, ih, ic, 0)),
            pl.BlockSpec((1, 1, 1, chunk, n), lambda ib, ih, ic: (ib, ih, ic, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, n), lambda ib, ih, ic: (ib, ih, ic, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, chunk, p),
                               lambda ib, ih, ic: (ib, ih, ic, 0, 0)),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
    )
    y = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, nc, chunk, p), x.dtype),
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, xT, dtT, bT, cT)
    return y.reshape(b, h, s, p).transpose(0, 2, 1, 3)
