"""Pure-jnp oracle for the SSD kernel: the naive O(S) recurrence.

    h_t = exp(a * dt_t) h_{t-1} + dt_t * x_t B_t^T
    y_t = C_t . h_t
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x, dt, a, bmat, cmat):
    """x: (B,S,H,P); dt: (B,S,H); a: (H,); bmat/cmat: (B,S,N) -> (B,S,H,P)."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]

    def step(hstate, inp):
        xt, dtt, bt, ct = inp            # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(a[None, :] * dtt)                    # (B,H)
        upd = (xt * dtt[..., None])[..., None] * bt[:, None, None, :]
        hstate = decay[..., None, None] * hstate + upd       # (B,H,P,N)
        yt = jnp.einsum("bn,bhpn->bhp", ct, hstate)
        return hstate, yt

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    xs = (
        x.transpose(1, 0, 2, 3).astype(jnp.float32),
        dt.transpose(1, 0, 2).astype(jnp.float32),
        bmat.transpose(1, 0, 2).astype(jnp.float32),
        cmat.transpose(1, 0, 2).astype(jnp.float32),
    )
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)  # (B,S,H,P)
