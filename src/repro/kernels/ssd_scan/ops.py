"""jit'd wrapper for the SSD chunk scan."""

from __future__ import annotations

import jax

from .kernel import ssd_scan as _kernel
from .ref import ssd_scan_ref as _ref


def ssd_scan(x, dt, a, bmat, cmat, *, chunk=128, force=None):
    impl = force or ("kernel" if jax.default_backend() == "tpu" else "ref")
    if impl == "kernel":
        return _kernel(x, dt, a, bmat, cmat, chunk=chunk)
    if impl == "interpret":
        return _kernel(x, dt, a, bmat, cmat, chunk=chunk, interpret=True)
    return _ref(x, dt, a, bmat, cmat)
