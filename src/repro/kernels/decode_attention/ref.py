"""Pure-jnp oracle for the flash-decode kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k, v, kv_pos, q_pos, *, window=None):
    """q: (B,H,D); k/v: (B,K,T,D); kv_pos: (B,T); q_pos: (B,)."""
    b, h, d = q.shape
    kheads = k.shape[1]
    g = h // kheads
    kx = jnp.repeat(k, g, axis=1)  # (B,H,T,D)
    vx = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhd,bhtd->bht", q.astype(jnp.float32),
                   kx.astype(jnp.float32)) * (d ** -0.5)
    valid = (kv_pos >= 0) & (kv_pos <= q_pos[:, None])
    if window is not None:
        valid &= kv_pos > (q_pos[:, None] - window)
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bht,bhtd->bhd", p, vx.astype(jnp.float32)).astype(q.dtype)
