"""Pallas TPU flash-decode kernel: one query token against a long KV cache.

Decode attention is HBM-bandwidth-bound (it streams the whole KV cache per
token), so the kernel's job is to keep the MXU busy on (block_kv, D) tiles
while the online softmax runs in VMEM scratch.  The kv axis is the sequential
grid dimension; invalid cache slots (position < 0, e.g. unfilled ring-buffer
lanes) and out-of-window slots are masked via the positions array, which is
streamed alongside K/V.

Layout: q (B, H, D); k/v (B, K, T, D); kv_pos (B, T) int32; out (B, H, D).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import _compiler_params

NEG_INF = -1e30


def _decode_kernel(
    qpos_ref,                      # scalar prefetch: (B,) current positions
    q_ref, k_ref, v_ref, pos_ref,  # VMEM blocks
    o_ref,
    m_scr, l_scr, acc_scr,
    *, scale: float, window: int | None, block_kv: int, num_kv_blocks: int,
    group: int,
):
    ib = pl.program_id(0)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                 # (G, D) grouped heads
    k = k_ref[0, 0].astype(jnp.float32)                 # (bkv, D)
    v = v_ref[0, 0].astype(jnp.float32)
    kpos = pos_ref[0]                                   # (bkv,) int32
    cur = qpos_ref[ib]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                           # (G, bkv)
    valid = (kpos >= 0) & (kpos <= cur)
    if window is not None:
        valid &= kpos > cur - window
    s = jnp.where(valid[None, :], s, NEG_INF)
    m_prev = m_scr[...]
    m_new = jnp.maximum(jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True)),
                        -1e4)
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(
            o_ref.dtype
        )


@functools.partial(
    jax.jit, static_argnames=("window", "block_kv", "interpret")
)
def decode_attention(
    q: jax.Array,        # (B, H, D) one token per sequence
    k: jax.Array,        # (B, K, T, D)
    v: jax.Array,        # (B, K, T, D)
    kv_pos: jax.Array,   # (B, T) int32, -1 = invalid slot
    q_pos: jax.Array,    # (B,) int32 current decode positions
    *,
    window: int | None = None,
    block_kv: int = 256,
    interpret: bool = False,
) -> jax.Array:
    b, h, d = q.shape
    kheads, t = k.shape[1], k.shape[2]
    g = h // kheads
    if t % block_kv:
        raise ValueError("cache length must be a multiple of block_kv")
    nk = t // block_kv
    # group query heads by kv head: (B, K, G, D)
    qg = q.reshape(b, kheads, g, d)

    kernel = functools.partial(
        _decode_kernel, scale=d ** -0.5, window=window, block_kv=block_kv,
        num_kv_blocks=nk, group=g,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kheads, nk),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda ib, ih, ik, qpos: (ib, ih, 0, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda ib, ih, ik, qpos: (ib, ih, ik, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda ib, ih, ik, qpos: (ib, ih, ik, 0)),
            pl.BlockSpec((1, block_kv), lambda ib, ih, ik, qpos: (ib, ik)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda ib, ih, ik, qpos: (ib, ih, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kheads, g, d), q.dtype),
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q_pos, qg.reshape(b, kheads, g, d), k, v, kv_pos)
    return out.reshape(b, h, d)
