"""jit'd wrapper for flash-decode: kernel on TPU, reference elsewhere."""

from __future__ import annotations

import jax

from .kernel import decode_attention as _kernel
from .ref import decode_attention_ref as _ref


def decode_attention(q, k, v, kv_pos, q_pos, *, window=None, force=None,
                     block_kv=256):
    """Model layout: q (B,1,H,D) or (B,H,D); k/v (B,T,K,D)."""
    squeeze = False
    if q.ndim == 4:
        q = q[:, 0]
        squeeze = True
    kT = k.transpose(0, 2, 1, 3)
    vT = v.transpose(0, 2, 1, 3)
    impl = force or ("kernel" if jax.default_backend() == "tpu" else "ref")
    if impl == "kernel":
        o = _kernel(q, kT, vT, kv_pos, q_pos, window=window, block_kv=block_kv)
    elif impl == "interpret":
        o = _kernel(q, kT, vT, kv_pos, q_pos, window=window,
                    block_kv=block_kv, interpret=True)
    else:
        o = _ref(q, kT, vT, kv_pos, q_pos, window=window)
    return o[:, None] if squeeze else o
