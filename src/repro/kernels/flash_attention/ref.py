"""Pure-jnp oracle for the flash-attention kernel (materializes the full
(S, T) score matrix — only for test shapes)."""

from __future__ import annotations

import jax.numpy as jnp
import jax

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=None):
    """q: (B,H,S,D); k/v: (B,K,T,D).  Returns (B,H,S,D)."""
    b, h, s, d = q.shape
    kheads, t = k.shape[1], k.shape[2]
    g = h // kheads
    kx = jnp.repeat(k, g, axis=1)
    vx = jnp.repeat(v, g, axis=1)
    scores = jnp.einsum(
        "bhsd,bhtd->bhst", q.astype(jnp.float32), kx.astype(jnp.float32)
    ) * (d ** -0.5)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", p, vx.astype(jnp.float32))
    return out.astype(q.dtype)
