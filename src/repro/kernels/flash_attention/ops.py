"""jit'd public wrapper: Pallas kernel on TPU, reference elsewhere.

The model layer (repro.models.attention.chunked_attention) is layout
(B, S, H, D); kernels use (B, H, S, D) — this wrapper transposes at the
boundary."""

from __future__ import annotations

import jax

from .kernel import flash_attention as _kernel
from .ref import flash_attention_ref as _ref


def flash_attention(q, k, v, *, causal=True, window=None, force=None,
                    block_q=128, block_kv=128):
    """q: (B, S, H, D); k/v: (B, T, K, D) — model layout.  `force` in
    {None, "kernel", "interpret", "ref"} selects the implementation."""
    qT = q.transpose(0, 2, 1, 3)
    kT = k.transpose(0, 2, 1, 3)
    vT = v.transpose(0, 2, 1, 3)
    backend = jax.default_backend()
    impl = force or ("kernel" if backend == "tpu" else "ref")
    if impl == "kernel":
        o = _kernel(qT, kT, vT, causal=causal, window=window,
                    block_q=block_q, block_kv=block_kv)
    elif impl == "interpret":
        o = _kernel(qT, kT, vT, causal=causal, window=window,
                    block_q=block_q, block_kv=block_kv, interpret=True)
    else:
        o = _ref(qT, kT, vT, causal=causal, window=window)
    return o.transpose(0, 2, 1, 3)
