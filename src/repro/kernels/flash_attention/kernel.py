"""Pallas TPU flash-attention (prefill) kernel.

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks); the kv-block axis is
sequential ("arbitrary") so the online-softmax running max / sum / accumulator
live in VMEM scratch across kv iterations.  BlockSpecs tile Q/K/V into
(block_q, head_dim) / (block_kv, head_dim) VMEM windows — MXU-aligned when
block sizes are multiples of 128.  GQA is handled in the K/V index_map
(kv head = q head // group size), so no KV replication in HBM.

Causal and sliding-window masking is done by position arithmetic on program
ids; fully-masked kv blocks are skipped with pl.when (no FLOPs, no loads
consumed downstream).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .._compat import _compiler_params

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,            # VMEM blocks
    o_ref,                          # output block
    m_scr, l_scr, acc_scr,          # scratch: (bq,1), (bq,1), (bq,d)
    *, scale: float, causal: bool, window: int | None,
    block_q: int, block_kv: int, num_kv_blocks: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_kv
    # block-level skip: no query in this q block attends into this kv block
    run = ik >= 0  # traced True
    if causal:
        run &= k_start <= q_start + block_q - 1
    if window is not None:
        run &= k_start + block_kv - 1 > q_start - window

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (bkv, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                       # (bq, bkv)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        mask = jnp.ones((block_q, block_kv), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]                             # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        m_new = jnp.maximum(m_new, -1e4)                # masked-block guard
        p = jnp.exp(s - m_new)                          # (bq, bkv)
        corr = jnp.exp(m_prev - m_new)                  # (bq, 1)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_kv", "interpret"),
)
def flash_attention(
    q: jax.Array,        # (B, H, S, D)
    k: jax.Array,        # (B, K, T, D)
    v: jax.Array,        # (B, K, T, D)
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, h, s, d = q.shape
    kheads, t = k.shape[1], k.shape[2]
    g = h // kheads
    scale = d ** -0.5
    nq = -(-s // block_q)
    nk = -(-t // block_kv)
    if s % block_q or t % block_kv:
        raise ValueError("seq lengths must be multiples of the block sizes")

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, num_kv_blocks=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, block_kv, d), lambda ib, ih, iq, ik: (ib, ih // g, ik, 0)),
            pl.BlockSpec((1, 1, block_kv, d), lambda ib, ih, iq, ik: (ib, ih // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
