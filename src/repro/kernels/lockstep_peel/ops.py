"""Backend dispatcher for the dense lockstep LMBR peel.

numpy-in / numpy-out, mirroring ``span_gain.ops``: the LMBR move loop is a
numpy control loop and treats one peel batch as a single op.  Backends:

  * "numpy"     — float64 dense oracle (``ref.lockstep_peel_ref``).
  * "jax"       — jitted f32 jnp lockstep (``ref.lockstep_peel_jnp``).
  * "kernel"    — the Pallas kernel, compiled (TPU).
  * "interpret" — the Pallas kernel in interpreter mode (CPU tests).
  * "pallas"    — kernel on TPU, interpreter elsewhere.

All backends emit the same free-space-independent trajectories
(peel order, head-of-round pool weight and benefit); on the
integer-valued-weight domain the LMBR dispatcher enforces, the f32 device
arithmetic is exact and the trajectories are bit-identical to the f64
oracle after the widening cast.

Shape discipline: callers bucket batches into pow2 (U, K) classes so jit
recompilation is bounded; the kernel path additionally pads K to the f32
sublane multiple (8) and U to the lane width (128).  Padding is inert —
zero incidence/weights never create degree, +inf degrees never win argmin,
and rounds never exceed the unpadded nvalid.
"""

from __future__ import annotations

import numpy as np

from .ref import lockstep_peel_ref

_JNP_PEEL = None


def _pad_axis(a: np.ndarray, axis: int, to: int) -> np.ndarray:
    if a.shape[axis] == to:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, to - a.shape[axis])
    return np.pad(a, pad)


def lockstep_peel(
    inc: np.ndarray,      # (G, K, U) 0/1 incidence, zero-padded
    we: np.ndarray,       # (G, K) edge weights, zero-padded
    nodew: np.ndarray,    # (G, U) item weights, zero-padded
    nvalid: np.ndarray,   # (G,) valid item slots per pair
    *,
    force: str | None = None,
):
    """Peel trajectories (peel (G, U) int64, rtot/rben (G, U) float64)."""
    if force == "numpy":
        return lockstep_peel_ref(inc, we, nodew, nvalid)
    import jax  # callers guard importability before dispatching here

    impl = force or ("kernel" if jax.default_backend() == "tpu" else "jax")
    if impl == "pallas":
        impl = "kernel" if jax.default_backend() == "tpu" else "interpret"
    G, K, U = inc.shape
    inc32 = np.asarray(inc, dtype=np.float32)
    we32 = np.asarray(we, dtype=np.float32)
    nodew32 = np.asarray(nodew, dtype=np.float32)
    nv32 = np.asarray(nvalid, dtype=np.int32)
    if impl == "jax":
        global _JNP_PEEL
        if _JNP_PEEL is None:
            from .ref import lockstep_peel_jnp

            _JNP_PEEL = jax.jit(lockstep_peel_jnp)
        peel, rtot, rben = _JNP_PEEL(inc32, we32, nodew32, nv32)
        return (
            np.asarray(peel).astype(np.int64),
            np.asarray(rtot).astype(np.float64),
            np.asarray(rben).astype(np.float64),
        )

    from .kernel import lockstep_peel as _kernel

    k2 = -(-max(K, 1) // 8) * 8
    u2 = -(-max(U, 1) // 128) * 128
    inc32 = _pad_axis(_pad_axis(inc32, 1, k2), 2, u2)
    we32 = _pad_axis(we32, 1, k2)
    nodew32 = _pad_axis(nodew32, 1, u2)
    peel, rtot, rben = _kernel(
        inc32, we32, nodew32, nv32[:, None], interpret=(impl == "interpret")
    )
    # rounds never exceed nvalid <= U, so the U pad columns are all -1/0
    return (
        np.asarray(peel)[:, :U].astype(np.int64),
        np.asarray(rtot)[:, :U].astype(np.float64),
        np.asarray(rben)[:, :U].astype(np.float64),
    )
