"""Reference implementations of the dense lockstep LMBR peel.

One peel "cell" is Algorithm 5's densest-subset loop for a single
(src, dest) candidate pair, densified: the pair's K kept shared edges and U
candidate items become a (K, U) incidence matrix, per-round degree updates
become two small matmuls (edge death detection and degree subtraction), and
the lowest-degree pick is a row argmin.  G pairs run in lockstep as a
(G, K, U) batch.

The dense backends emit the free-space-independent peel TRAJECTORY — the
slot peeled each round plus the pool weight / alive-edge benefit at each
round head — NOT the final (gain, items) answer.  Selecting the best round
under the destination's free space (argmax of benefit/weight over fitting
rounds, earliest round on ties) happens on the host in float64, shared with
the gain-cache re-evaluation path, so every backend produces bit-identical
placements.

Exactness domain: callers dispatch here only for integer-valued edge and
node weights with totals below 2**24 (asserted upstream).  Then every
accumulated quantity — degrees, benefits, pool weights — is an integer
representable exactly in float32, sums are exact under ANY association
order, and the f32 device trajectory equals the f64 host trajectory
bit-for-bit after the (exact) widening cast.

Round semantics (mirrors ``algorithms._lmbr_peel_flat`` / the pure-Python
oracle): a pair is active while its alive-edge benefit is positive and
items remain; each active round records (totw, benefit) at the round head,
peels the lowest-degree item (ties -> lowest slot id = lowest item id,
because slots are sorted by item id), retires edges that lose a pin, and
subtracts their weights from the degrees of their still-alive items.
"""

from __future__ import annotations

import numpy as np


def lockstep_peel_ref(
    inc: np.ndarray,      # (G, K, U) 0/1 incidence, zero-padded
    we: np.ndarray,       # (G, K) edge weights, zero-padded
    nodew: np.ndarray,    # (G, U) item weights, zero-padded
    nvalid: np.ndarray,   # (G,) valid item slots (prefix 0..nvalid-1)
):
    """Float64 numpy oracle.  Returns (peel, rtot, rben):

    peel (G, U) int64 — slot peeled at round r, -1 once the pair finished
    rtot (G, U) f64   — pool weight at the head of each recorded round
    rben (G, U) f64   — alive-edge benefit at the head of each round
    """
    inc = np.asarray(inc, dtype=np.float64)
    we = np.asarray(we, dtype=np.float64)
    nodew = np.asarray(nodew, dtype=np.float64)
    nvalid = np.asarray(nvalid, dtype=np.int64)
    G, K, U = inc.shape
    peel = np.full((G, U), -1, dtype=np.int64)
    rtot = np.zeros((G, U), dtype=np.float64)
    rben = np.zeros((G, U), dtype=np.float64)
    valid = np.arange(U, dtype=np.int64)[None, :] < nvalid[:, None]
    cand = np.einsum("gku,gk->gu", inc, we)
    cand = np.where(valid, cand, np.inf)
    ealive = np.ones((G, K), dtype=bool)
    ben = we.sum(axis=1)
    totw = nodew.sum(axis=1)          # padding weights are zero
    nal = nvalid.copy()
    for r in range(U):
        act = (ben > 0.5) & (nal > 0)
        if not act.any():
            break
        rows = np.flatnonzero(act)
        rtot[rows, r] = totw[rows]
        rben[rows, r] = ben[rows]
        j = np.argmin(cand[rows], axis=1)     # ties -> lowest slot id
        peel[rows, r] = j
        cand[rows, j] = np.inf
        totw[rows] -= nodew[rows, j]
        nal[rows] -= 1
        hit = inc[rows, :, j] > 0.5           # (A, K)
        dying = ealive[rows] & hit
        dw = we[rows] * dying
        ben[rows] -= dw.sum(axis=1)
        # dead/invalid slots sit at +inf; inf - finite stays inf
        cand[rows] -= np.einsum("aku,ak->au", inc[rows], dw)
        ealive[rows] &= ~dying
    return peel, rtot, rben


def lockstep_peel_jnp(inc, we, nodew, nvalid):
    """jnp float32 lockstep peel (jit-compiled by the ops dispatcher).

    Same trajectory contract as ``lockstep_peel_ref``; the early-exit
    ``lax.while_loop`` keeps device round count equal to the longest pair's
    peel instead of the static U bound.
    """
    import jax.numpy as jnp
    from jax import lax

    G, K, U = inc.shape
    iota_u = jnp.arange(U, dtype=jnp.int32)[None, :]
    valid = iota_u < nvalid[:, None]
    cand0 = jnp.where(valid, jnp.einsum("gku,gk->gu", inc, we), jnp.inf)
    state0 = (
        jnp.int32(0),
        cand0,
        jnp.ones((G, K), dtype=bool),
        we.sum(axis=1),
        nodew.sum(axis=1),
        nvalid.astype(jnp.int32),
        jnp.full((G, U), -1, dtype=jnp.int32),
        jnp.zeros((G, U), dtype=jnp.float32),
        jnp.zeros((G, U), dtype=jnp.float32),
    )

    def active(ben, nal):
        return (ben > 0.5) & (nal > 0)

    def cond(st):
        r, _, _, ben, _, nal, _, _, _ = st
        return (r < U) & jnp.any(active(ben, nal))

    def body(st):
        r, cand, ealive, ben, totw, nal, peel, rtot, rben = st
        act = active(ben, nal)
        rtot = rtot.at[:, r].set(jnp.where(act, totw, 0.0))
        rben = rben.at[:, r].set(jnp.where(act, ben, 0.0))
        j = jnp.argmin(cand, axis=1).astype(jnp.int32)
        onehot = (iota_u == j[:, None]) & act[:, None]
        ohf = onehot.astype(inc.dtype)
        hit = jnp.einsum("gku,gu->gk", inc, ohf) > 0.5
        dying = ealive & hit
        dw = we * dying.astype(we.dtype)
        ben = ben - dw.sum(axis=1)
        cand = jnp.where(onehot, jnp.inf,
                         cand - jnp.einsum("gku,gk->gu", inc, dw))
        totw = totw - (nodew * ohf).sum(axis=1)
        nal = nal - act.astype(jnp.int32)
        peel = peel.at[:, r].set(jnp.where(act, j, jnp.int32(-1)))
        return (r + 1, cand, ealive & ~dying, ben, totw, nal, peel, rtot,
                rben)

    st = lax.while_loop(cond, body, state0)
    return st[6], st[7], st[8]
