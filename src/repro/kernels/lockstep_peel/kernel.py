"""Pallas TPU kernel for the dense lockstep LMBR peel.

One grid cell = one (src, dest) candidate pair.  The pair's (K, U)
incidence tile, edge weights and item weights live in VMEM for the WHOLE
peel — a `fori_loop` runs every round in-register, so the peel costs one
upload of the dense batch and one download of the trajectories instead of
a host round-trip per peeled item.

Per round (matching `ref.lockstep_peel_ref` bit-for-bit on the
integer-valued-weight domain the dispatcher guarantees):

  * argmin over the (1, U) degree row picks the peeled slot (+inf padding
    and first-minimum semantics give the oracle's lowest-item-id tie-break)
  * a one-hot contraction against the incidence tile flags edges losing a
    pin (edge death), a second contraction subtracts the dying edge weights
    from the degrees of their remaining items
  * head-of-round (pool weight, alive benefit) snapshots write into the
    trajectory rows via an iota==r select, so every store is static-shape

Trajectories only — the free-space-dependent (gain, items) selection is
host-side f64, shared with the gain-cache re-evaluation path.

Layout: U rides the 128-wide lane dimension, K the sublanes (f32 tiles are
(8, 128)-aligned; the ops dispatcher pads).  The grid axis is a pure map
over pairs, so it is parallel.  CPU runs this kernel in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .._compat import _compiler_params


def _peel_kernel(inc_ref, we_ref, nodew_ref, nvalid_ref,
                 peel_ref, rtot_ref, rben_ref):
    inc = inc_ref[0]                      # (K, U) f32
    we = we_ref[...]                      # (1, K) f32
    nodew = nodew_ref[...]                # (1, U) f32
    nv = nvalid_ref[0, 0]                 # scalar int32
    U = inc.shape[1]
    iota_u = lax.broadcasted_iota(jnp.int32, (1, U), 1)
    valid = iota_u < nv
    cand0 = jnp.where(valid, jnp.dot(we, inc), jnp.inf)
    carry0 = (
        cand0,
        jnp.ones(we.shape, dtype=jnp.float32),      # alive-edge mask (1, K)
        jnp.sum(we),
        jnp.sum(nodew),
        nv,
        jnp.full((1, U), -1, dtype=jnp.int32),
        jnp.zeros((1, U), dtype=jnp.float32),
        jnp.zeros((1, U), dtype=jnp.float32),
    )

    def body(r, carry):
        cand, ealive, ben, totw, nal, peel, rtot, rben = carry
        act = (ben > 0.5) & (nal > 0)
        here = (iota_u == r) & act
        rtot = jnp.where(here, totw, rtot)
        rben = jnp.where(here, ben, rben)
        j = jnp.argmin(cand, axis=1)[0].astype(jnp.int32)
        onehot = (iota_u == j) & act
        ohf = onehot.astype(jnp.float32)
        # (1, U) x (K, U) contracting U -> (1, K): edges hit by the peel
        hit = lax.dot_general(ohf, inc, (((1,), (1,)), ((), ())))
        dying = jnp.where((ealive > 0.5) & (hit > 0.5), 1.0, 0.0)
        dw = we * dying
        ben = ben - jnp.sum(dw)
        cand = jnp.where(onehot, jnp.inf, cand - jnp.dot(dw, inc))
        totw = totw - jnp.sum(nodew * ohf)
        nal = nal - jnp.where(act, 1, 0)
        peel = jnp.where(here, j, peel)
        return (cand, ealive * (1.0 - dying), ben, totw, nal, peel, rtot,
                rben)

    carry = lax.fori_loop(0, U, body, carry0)
    peel_ref[...] = carry[5]
    rtot_ref[...] = carry[6]
    rben_ref[...] = carry[7]


@functools.partial(jax.jit, static_argnames=("interpret",))
def lockstep_peel(
    inc32: jax.Array,     # (G, K, U) f32 incidence, zero-padded
    we32: jax.Array,      # (G, K) f32 edge weights, zero-padded
    nodew32: jax.Array,   # (G, U) f32 item weights, zero-padded
    nvalid: jax.Array,    # (G, 1) int32 valid item slots per pair
    *,
    interpret: bool = False,
):
    """Peel trajectories: peel (G, U) int32, rtot/rben (G, U) f32.
    K must be a multiple of 8 and U of 128 (the ops dispatcher pads;
    padding is inert — zero incidence, zero weights, +inf degrees)."""
    g, k, u = inc32.shape
    if k % 8 or u % 128:
        raise ValueError("K / U must be multiples of the (8, 128) f32 tile")
    out = pl.pallas_call(
        _peel_kernel,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, k, u), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, u), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, u), lambda i: (i, 0)),
            pl.BlockSpec((1, u), lambda i: (i, 0)),
            pl.BlockSpec((1, u), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, u), jnp.int32),
            jax.ShapeDtypeStruct((g, u), jnp.float32),
            jax.ShapeDtypeStruct((g, u), jnp.float32),
        ],
        compiler_params=_compiler_params(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(inc32, we32, nodew32, nvalid)
    return out
