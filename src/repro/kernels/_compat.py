"""Pallas API drift shims shared by every kernel package.

The pallas TPU surface renamed ``TPUCompilerParams`` to ``CompilerParams``
across jax releases; the kernels must lower on both spellings (the container
pins one, CI images may pin the other).
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_CompilerParams = getattr(
    pltpu, "TPUCompilerParams", getattr(pltpu, "CompilerParams", None)
)


def _compiler_params(**kwargs):
    """Build TPU compiler params under whichever name this jax exposes."""
    if _CompilerParams is None:  # pallas without a TPU lowering at all
        return None
    return _CompilerParams(**kwargs)
