"""Oracles for the span-gain kernel.

``span_gain_ref`` is the numpy popcount the whole span engine is specified
against (bit-exact integer math, no jax required).  ``span_gain_jnp`` is the
same contraction in jnp — it backs the "jax" dispatch tier and is what the
interpret-mode Pallas kernel is asserted against in tests.
"""

from __future__ import annotations

import numpy as np


def span_gain_ref(codes: np.ndarray, rem: np.ndarray) -> np.ndarray:
    """codes (A, N, W) uint64, rem (A, W) uint64 -> gains (A, N) int64."""
    return np.bitwise_count(codes & rem[:, None, :]).sum(axis=2, dtype=np.int64)


def span_gain_jnp(c32, r32):
    """uint32-lane jnp reference: c32 (A, N, W2), r32 (A, W2) -> (A, N) int32."""
    import jax.numpy as jnp
    from jax import lax

    masked = jnp.bitwise_and(c32, r32[:, None, :])
    return lax.population_count(masked).astype(jnp.int32).sum(axis=-1)
