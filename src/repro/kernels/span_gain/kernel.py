"""Pallas TPU kernel for the span-engine gain matrix (replica selection).

One greedy set-cover round needs, for every still-active query e and every
partition p, the popcount of ``codes[e, p, :] & rem[e, :]`` — how many still
uncovered pins of e partition p stores.  That masked popcount-reduce is the
span engine's only O(A*N*W) operation, and as a plain jitted op it writes
the (A, N, W) masked intermediate back to HBM before reducing.  Fusing
mask + popcount + word-reduce into one VMEM-tiled kernel streams ``codes``
through VMEM exactly once and emits only the (A, N) gain tile.

Layout: the engine's uint64 words arrive pre-split into uint32 lanes and
transposed to (A, W2, N), so the partition axis — the long one — lies on the
128-wide lane dimension and the word axis W2 (= 2*ceil(|q|/64), typically
2-8) rides the sublanes and reduces in-register.

Grid: (A / block_a, N / block_n).  Tiles are independent (a pure map), so
both grid axes are parallel.  Integer kernel: results are bit-exact against
the numpy oracle, which the backend-equivalence tests enforce.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .._compat import _compiler_params


def _span_gain_kernel(codes_ref, rem_ref, out_ref):
    c = codes_ref[...]                    # (BA, W2, BN) uint32
    r = rem_ref[...]                      # (BA, W2) uint32
    masked = jnp.bitwise_and(c, r[:, :, None])
    out_ref[...] = lax.population_count(masked).astype(jnp.int32).sum(axis=1)


@functools.partial(jax.jit, static_argnames=("block_a", "block_n", "interpret"))
def span_gain(
    codes32: jax.Array,   # (A, W2, N) uint32 — word-major packed membership
    rem32: jax.Array,     # (A, W2) uint32 — still-uncovered pin masks
    *,
    block_a: int = 8,
    block_n: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Gain matrix (A, N) int32.  A must divide block_a, N block_n (callers
    zero-pad; zero words contribute zero gain, so padding is inert)."""
    a, w2, n = codes32.shape
    if a % block_a or n % block_n:
        raise ValueError("A / N must be multiples of the block sizes")
    return pl.pallas_call(
        _span_gain_kernel,
        grid=(a // block_a, n // block_n),
        in_specs=[
            pl.BlockSpec((block_a, w2, block_n), lambda ia, jn: (ia, 0, jn)),
            pl.BlockSpec((block_a, w2), lambda ia, jn: (ia, 0)),
        ],
        out_specs=pl.BlockSpec((block_a, block_n), lambda ia, jn: (ia, jn)),
        out_shape=jax.ShapeDtypeStruct((a, n), jnp.int32),
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(codes32, rem32)
