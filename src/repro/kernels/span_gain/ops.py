"""Backend dispatcher for the span-gain matrix.

Unlike the attention/SSD packages this wrapper is numpy-in / numpy-out: the
span engine is a numpy control loop (greedy rounds, argmax tie-breaks) that
treats the gain matrix as one batched op per round.  All backends are
bit-exact integer math, so the choice is purely a performance decision:

  * "numpy"     — ``np.bitwise_count`` oracle, zero dispatch overhead; wins
                  on small buckets where crossing into jax costs more than
                  the popcount itself.
  * "jax"       — jitted jnp popcount-reduce (XLA fuses the mask).
  * "kernel"    — the Pallas kernel, compiled (TPU).
  * "interpret" — the Pallas kernel in interpreter mode (CPU tests).
  * "pallas"    — kernel on TPU, interpreter elsewhere.

The query-batch axis is padded to the next power of two before any jax
call: greedy rounds shrink the active set every iteration and one XLA
program per distinct batch size would dominate wall-clock.  Padded rows are
all-zero and sliced off.
"""

from __future__ import annotations

import numpy as np

from .ref import span_gain_ref

_JNP_GAINS = None


def _pow2_pad(codes: np.ndarray, rem: np.ndarray, multiple: int = 1):
    a = codes.shape[0]
    pad = max(multiple, 1 << (a - 1).bit_length()) if a else multiple
    if pad != a:
        codes = np.concatenate(
            [codes, np.zeros((pad - a,) + codes.shape[1:], dtype=codes.dtype)]
        )
        rem = np.concatenate(
            [rem, np.zeros((pad - a, rem.shape[1]), dtype=rem.dtype)]
        )
    return codes, rem


def span_gains(
    codes: np.ndarray,   # (A, N, W) uint64 packed membership submatrices
    rem: np.ndarray,     # (A, W) uint64 still-uncovered masks
    *,
    force: str | None = None,
) -> np.ndarray:
    """Gain matrix (A, N) int64 for one greedy cover round."""
    if force == "numpy":
        return span_gain_ref(codes, rem)
    import jax  # the caller's per-bucket dispatch guards importability

    impl = force or ("kernel" if jax.default_backend() == "tpu" else "jax")
    if impl == "pallas":
        impl = "kernel" if jax.default_backend() == "tpu" else "interpret"
    a = codes.shape[0]
    if impl == "jax":
        global _JNP_GAINS
        if _JNP_GAINS is None:
            from .ref import span_gain_jnp

            _JNP_GAINS = jax.jit(span_gain_jnp)
        codes, rem = _pow2_pad(codes, rem)
        c32 = np.ascontiguousarray(codes).view(np.uint32)   # (A2, N, W2)
        r32 = np.ascontiguousarray(rem).view(np.uint32)     # (A2, W2)
        out = np.asarray(_JNP_GAINS(c32, r32))
        return out[:a].astype(np.int64)

    from .kernel import span_gain as _kernel

    block_a, block_n = 8, 128
    codes, rem = _pow2_pad(codes, rem, multiple=block_a)
    n = codes.shape[1]
    n2 = -(-n // block_n) * block_n
    # uint64 -> uint32 lanes, partition axis onto the 128-wide lane dim
    c32 = np.ascontiguousarray(codes).view(np.uint32)       # (A2, N, W2)
    c32 = np.ascontiguousarray(c32.transpose(0, 2, 1))      # (A2, W2, N)
    if n2 != n:
        c32 = np.concatenate(
            [c32, np.zeros(c32.shape[:2] + (n2 - n,), dtype=c32.dtype)], axis=2
        )
    r32 = np.ascontiguousarray(rem).view(np.uint32)         # (A2, W2)
    out = np.asarray(
        _kernel(c32, r32, block_a=block_a, block_n=block_n,
                interpret=(impl == "interpret"))
    )
    return out[:a, :n].astype(np.int64)
