"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel directory holds kernel.py (pl.pallas_call + BlockSpec VMEM
tiling), ops.py (jit'd wrapper: kernel on TPU, jnp reference elsewhere) and
ref.py (the pure-jnp oracle the tests assert against, in interpret mode).

The paper's own contribution is control-plane (data placement) — these
kernels are the substrate hot spots under the assigned shape grid: 32k
prefill attention, 32k-500k decode attention, the Mamba2 SSD scan, the
span-gain popcount that batches the paper's greedy replica selection, and
the lockstep densest-subset peel behind the LMBR move engine.
"""

from .flash_attention.ops import flash_attention  # noqa: F401
from .decode_attention.ops import decode_attention  # noqa: F401
from .ssd_scan.ops import ssd_scan  # noqa: F401
from .span_gain.ops import span_gains  # noqa: F401
from .lockstep_peel.ops import lockstep_peel  # noqa: F401
