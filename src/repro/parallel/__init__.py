from .sharding import (  # noqa: F401
    active_mesh,
    batch_shardings,
    cache_shardings,
    constrain,
    param_shardings,
    set_active_mesh,
    spec_for_param_path,
)
