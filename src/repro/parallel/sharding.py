"""Sharding rules: parameter / batch / cache PartitionSpecs per architecture.

Parallelism layout on the production mesh (DESIGN.md §5):
  * batch  -> ('pod', 'data')  — plain DP across pods (DCN crossed once per
    step for the gradient all-reduce), DP/FSDP inside a pod.
  * FSDP   -> 'data' — parameters sharded along a non-TP dimension and
    all-gathered per layer inside the scan.
  * TP     -> 'model' — attention heads / FFN hidden / vocab.
  * EP     -> 'model' — MoE expert (slot) dimension.
  * SP     -> 'model' — sequence dim of long prefill activations (hillclimb).

Rules are name-based over the parameter tree path, dimension-count aware
(scan-stacked block params carry a leading layer axis).  A dimension is only
sharded when the axis size divides it — otherwise it degrades to replication
(e.g. glm4's kv=2 heads across model=16 stay replicated while q-heads shard).
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DP = "data"
TP = "model"
POD = "pod"

# ---------------------------------------------------------- ambient mesh
# The launcher installs the active mesh here; model code then pins activation
# shardings via `constrain`.  Without an active mesh (unit tests, single
# device) every constrain is a no-op, so model code stays mesh-agnostic.
_ACTIVE_MESH: Mesh | None = None


def set_active_mesh(mesh: Mesh | None):
    global _ACTIVE_MESH
    _ACTIVE_MESH = mesh


def active_mesh() -> Mesh | None:
    return _ACTIVE_MESH


def constrain(x, kind: str):
    """Pin an activation's sharding (no-op without an active mesh).

    kinds: 'act'    (B, S, D)   batch over (pod,)data
           'act_sp' (B, S, D)   batch over (pod,)data, seq over model (SP)
           'logits' (B, S, V)   batch over (pod,)data, vocab over model
           'tokens' (B, S)      batch over (pod,)data
    """
    mesh = _ACTIVE_MESH
    if mesh is None:
        return x
    from repro.flags import FLAGS

    if kind == "act" and FLAGS["sp"]:
        kind = "act_sp"   # sequence parallelism (hillclimb variant)
    dp = _dp_axes(mesh)
    spec = {
        "act": P(dp, None, None),
        "act_sp": P(dp, TP, None),
        "logits": P(dp, None, TP),
        "tokens": P(dp, None),
        "moe_tokens": P(dp, None),       # (N, d) flattened token stream
        "moe_buf": P(TP, dp, None),      # (slots, capacity, d): slots = EP
        "q_sp": P(dp, TP, None, None),   # (B, S, H, D) seq-sharded queries
        "kv_rep": P(dp, None, None, None),  # (B, T, K, D) gathered K/V
    }[kind]
    # degrade unsatisfiable dims (e.g. batch < dp size) to replication
    sizes = x.shape
    fixed = []
    for i, a in enumerate(spec):
        if a is None:
            fixed.append(None)
        elif sizes[i] % _axis_size(mesh, a) == 0:
            fixed.append(a)
        else:
            fixed.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed))
    )


def _dp_axes(mesh: Mesh):
    return (POD, DP) if POD in mesh.axis_names else DP


def _axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        return int(np.prod([mesh.shape[n] for n in name]))
    return mesh.shape[name]


def _fit(mesh: Mesh, dim: int, axis):
    """Shard `dim` on `axis` only if divisible; else replicate."""
    if axis is None or dim is None:
        return None
    return axis if dim % _axis_size(mesh, axis) == 0 else None


# --------------------------------------------------------------- param rules
# (last-dim axis, second-to-last-dim axis); leading layer/expert dims handled
# separately.  fsdp = DP axis, tp = TP axis.
_LAST_TP = {"wq", "wk", "wv", "wi", "wi_gate", "wi_up", "wq_b", "wkv_a",
            "wq_a", "wk_b", "wv_b", "w_in"}
_LAST_DP = {"wo", "w_out"}


def _axes_for(name: str, nd: int, dp):
    """Logical axes for a parameter leaf of `nd` dims named `name` (no
    divisibility applied yet)."""
    if nd == 0:
        return []
    # embeddings: (V, d) -> vocab on TP only: sharding d on DP would misalign
    # the unembed contraction with batch-DP activations and force GSPMD to
    # gather the batch (measured: 13GB all-gathers on olmo train_4k)
    if name == "table" and nd >= 2:
        return [None] * (nd - 2) + [TP, None]
    # MoE experts: (..., slots, d, ff) / (..., slots, ff, d): slots = EP
    if name in ("we_gate", "we_up") and nd >= 3:
        return [None] * (nd - 3) + [TP, dp, None]
    if name == "we_down" and nd >= 3:
        return [None] * (nd - 3) + [TP, None, dp]
    if name == "router" and nd >= 2:
        return [None] * (nd - 2) + [dp, None]
    if nd >= 2 and name in _LAST_TP:
        return [None] * (nd - 2) + [dp, TP]
    if nd >= 2 and name in _LAST_DP:
        return [None] * (nd - 2) + [TP, dp]
    if nd >= 2 and name in ("proj", "frontend_proj"):
        return [None] * (nd - 2) + [dp, TP]
    # norms / biases / conv weights / scalars: replicate
    return [None] * nd


def spec_for_param_path(path: tuple[str, ...], shape: tuple[int, ...],
                        mesh: Mesh, fsdp: bool = True) -> P:
    """PartitionSpec for one parameter (or optimizer-state leaf mirroring a
    parameter), given its tree path and shape.

    Adafactor's factored stats drop one dim relative to their parameter:
    `vr` drops the last, `vc` the second-to-last — their specs drop the
    matching axis entry."""
    names = [p.lstrip(".") for p in path]
    name = names[-1]
    dp = DP if fsdp else None
    nd = len(shape)
    reduced = next((n for n in names if n in ("vr", "vc")), None)
    if reduced and nd >= 1:
        full = _axes_for(name, nd + 1, dp)
        axes = full[:-1] if reduced == "vr" else full[:-2] + full[-1:]
    else:
        axes = _axes_for(name, nd, dp)
    return P(*[_fit(mesh, shape[i], a) for i, a in enumerate(axes)])


def _tree_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return flat, treedef


def _path_names(keypath) -> tuple[str, ...]:
    names = []
    for k in keypath:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(str(k.idx))
        else:
            names.append(str(k))
    return tuple(names)


def param_shardings(params_shape_tree, mesh: Mesh, fsdp: bool = True):
    """NamedSharding tree matching the (eval_shape'd) parameter tree."""
    flat, treedef = _tree_paths(params_shape_tree)
    out = []
    for keypath, leaf in flat:
        spec = spec_for_param_path(_path_names(keypath), leaf.shape, mesh,
                                   fsdp=fsdp)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


# ------------------------------------------------------------------- batches
def batch_shardings(batch_shape_tree, mesh: Mesh):
    dp = _dp_axes(mesh)

    def one(keypath, leaf):
        nd = len(leaf.shape)
        # leading dim is global batch; replicate when it can't split (e.g.
        # long_500k's batch of 1)
        bax = _fit(mesh, leaf.shape[0], dp) if nd else None
        return NamedSharding(mesh, P(bax, *([None] * (nd - 1))))

    flat, treedef = _tree_paths(batch_shape_tree)
    return jax.tree_util.tree_unflatten(
        treedef, [one(kp, lf) for kp, lf in flat]
    )


# -------------------------------------------------------------------- caches
def cache_shardings(cache_shape_tree, mesh: Mesh):
    """KV/SSM caches: batch on DP; kv-heads (GQA) or latent dim (MLA) or SSM
    heads on TP when divisible.  Layer-stacked leading dims replicate."""
    dp = _dp_axes(mesh)
    dp_size = _axis_size(mesh, dp)

    def one(keypath, leaf):
        names = _path_names(keypath)
        name = names[-1]
        nd = len(leaf.shape)
        has_layer = nd >= 1 and names and any(
            n in ("layers",) for n in names
        )
        # identify batch dim: first dim after optional layer dim
        # layouts: k/v (L,B,T,K,D) | pos (L,B,T) | cursor (L,)
        #          c_kv (L,B,T,R) | conv (L,B,W,C) | h (L,B,H,P,N)
        if name == "cursor":
            return NamedSharding(mesh, P(*([None] * nd)))
        if nd < 2:
            return NamedSharding(mesh, P(*([None] * nd)))
        axes: list[Any] = [None] * nd
        bdim = 1 if has_layer else 0
        if leaf.shape[bdim] % dp_size == 0:
            axes[bdim] = dp
        if name in ("k", "v") and nd >= bdim + 4:
            axes[bdim + 2] = _fit(mesh, leaf.shape[bdim + 2], TP)  # kv heads
        elif name == "c_kv" and nd >= bdim + 3:
            axes[bdim + 2] = _fit(mesh, leaf.shape[bdim + 2], TP)  # latent
        elif name == "h" and nd >= bdim + 3:
            axes[bdim + 1] = _fit(mesh, leaf.shape[bdim + 1], TP)  # ssm heads
        elif name == "conv" and nd >= bdim + 3:
            axes[bdim + 2] = _fit(mesh, leaf.shape[bdim + 2], TP)  # channels
        return NamedSharding(mesh, P(*axes))

    flat, treedef = _tree_paths(cache_shape_tree)
    return jax.tree_util.tree_unflatten(
        treedef, [one(kp, lf) for kp, lf in flat]
    )
