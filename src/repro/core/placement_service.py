"""Production placement API.

Wraps the paper's algorithms behind a serializable, hierarchical service used
by the framework's data pipeline, MoE runtime and checkpoint manager:

  * PlacementPlan   — frozen result; JSON-serializable; answers
    `partitions_of(item)`, `select(query)` (greedy-set-cover replica
    selection), span statistics.
  * PlacementService.fit        — one-level placement (paper §4).
  * PlacementService.fit_sharded — cluster-scale placement through the
    `repro.scale` pipeline: workload sharding (connected components + HPA
    coarse cut), parallel per-shard fits (process pool with a bit-identical
    serial fallback), deterministic merge, bounded boundary-edge repair.
  * PlacementService.fit_hierarchical — two-level pod/host placement for TPU
    fleets (ICI inside a pod ≫ DCN across pods); span is minimized at the pod
    level first, then per pod at the host level.  Faithful generalization —
    the paper notes partitions may be "racks or even datacenters".
  * PlacementService.refit      — incremental re-placement when the workload
    drifts: LMBR warm-started from the current plan (new replicas only move
    into free space; no full repartition, cheap to apply online).  A
    ``dest_mask`` confines new copies to surviving partitions, so drift
    adaptation keeps running through an outage.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Sequence

import numpy as np

from .. import flags as _flags
from .. import obs as _obs
from .algorithms import ALGORITHMS, lmbr, min_partitions
from .cluster import (
    NodeProfile,
    ensure_durability,
    normalize_capacity,
    validate_durability,
)
from .hypergraph import Hypergraph
from .setcover import (
    Placement,
    batched_spans_csr,
    cover_for_query,
    greedy_set_cover,
    queries_to_csr,
)

__all__ = ["PlacementPlan", "HierarchicalPlan", "PlacementService"]


@dataclasses.dataclass
class PlacementPlan:
    member: np.ndarray  # (N, V) bool
    capacity: "float | np.ndarray"  # scalar, or (N,) per-partition vector
    node_weights: np.ndarray
    algorithm: str
    # optional fitter diagnostics (the sharded pipeline's stage stats, the
    # LMBR engine's gain-cache hit rate / device-cover round counters);
    # never serialized, never placement-semantic
    stats: dict | None = None

    # --------------------------------------------------------------- queries
    def partitions_of(self, item: int) -> np.ndarray:
        return np.flatnonzero(self.member[:, item])

    def select(self, query: Sequence[int]):
        """Replica selection: (partitions, items-read-from-each)."""
        return cover_for_query(np.asarray(query, dtype=np.int64), self.member)

    def span(self, query: Sequence[int]) -> int:
        """Greedy cover size of one query (item set; duplicate ids are
        deduplicated like `Hypergraph` edges).  Batched engine, bit-identical
        to `greedy_set_cover` on the deduplicated query."""
        return int(self.spans([query])[0])

    def spans(self, queries: Sequence[Sequence[int]]) -> np.ndarray:
        """Spans of many queries in ONE batched engine call (the per-query
        reference loop this replaces is `greedy_set_cover` per query)."""
        ptr, nodes = queries_to_csr(
            [np.unique(np.asarray(q, dtype=np.int64)) for q in queries]
        )
        return batched_spans_csr(ptr, nodes, self.member)

    def avg_span(self, queries: Sequence[Sequence[int]]) -> float:
        return float(self.spans(queries).mean()) if len(queries) else 0.0

    def as_placement(self) -> Placement:
        return Placement(self.member, self.capacity, self.node_weights)

    @property
    def num_partitions(self) -> int:
        return self.member.shape[0]

    # --------------------------------------------------------- serialization
    def to_json(self) -> str:
        cap = self.capacity
        return json.dumps(
            dict(
                # heterogeneous vectors serialize as a per-partition list;
                # scalars stay a bare float (the historical wire format)
                capacity=(
                    np.asarray(cap, dtype=np.float64).tolist()
                    if isinstance(cap, np.ndarray) and cap.ndim
                    else float(cap)
                ),
                algorithm=self.algorithm,
                node_weights=self.node_weights.tolist(),
                partitions=[
                    np.flatnonzero(self.member[p]).tolist()
                    for p in range(self.member.shape[0])
                ],
                num_items=int(self.member.shape[1]),
            )
        )

    @staticmethod
    def from_json(s: str) -> "PlacementPlan":
        d = json.loads(s)
        member = np.zeros((len(d["partitions"]), d["num_items"]), dtype=bool)
        for p, items in enumerate(d["partitions"]):
            member[p, np.asarray(items, dtype=np.int64)] = True
        cap = d["capacity"]
        return PlacementPlan(
            member,
            # lists restore the per-partition vector (uniform ones collapse
            # back to the scalar path); bare numbers stay floats
            normalize_capacity(np.asarray(cap, dtype=np.float64))
            if isinstance(cap, list) else float(cap),
            np.asarray(d["node_weights"], dtype=np.float64),
            d["algorithm"],
        )


@dataclasses.dataclass
class HierarchicalPlan:
    """Two-level placement: pods then hosts-within-pod.

    host_member is the flat (num_pods*hosts_per_pod, V) matrix; global host id
    = pod * hosts_per_pod + local host."""

    pod_plan: PlacementPlan
    host_member: np.ndarray
    hosts_per_pod: int
    host_capacity: float
    node_weights: np.ndarray

    def select(self, query: Sequence[int]):
        return cover_for_query(
            np.asarray(query, dtype=np.int64), self.host_member
        )

    def spans(self, query: Sequence[int]) -> tuple[int, int]:
        """(pod_span, host_span) via hierarchical set cover: pods first, then
        hosts restricted to the chosen pods."""
        q = np.asarray(query, dtype=np.int64)
        pods = greedy_set_cover(q, self.pod_plan.member)
        host_rows = []
        for p in pods:
            lo = p * self.hosts_per_pod
            host_rows.extend(range(lo, lo + self.hosts_per_pod))
        sub = self.host_member[host_rows]
        hosts = greedy_set_cover(q, sub)
        return len(pods), len(hosts)

    def weighted_span(self, query, pod_weight: float = 8.0) -> float:
        """DCN hops are ~pod_weight x pricier than ICI hops."""
        ps, hs = self.spans(query)
        return pod_weight * (ps - 1) + (hs - 1)


class PlacementService:
    def __init__(self, algorithm: str = "lmbr", seed: int = 0, nruns: int = 2):
        if algorithm not in ALGORITHMS:
            raise KeyError(f"unknown algorithm {algorithm!r}; have {list(ALGORITHMS)}")
        self.algorithm = algorithm
        self.seed = seed
        self.nruns = nruns

    # ------------------------------------------------------------- profiles
    @staticmethod
    def _resolve_profile(profile, num_partitions, capacity):
        """(capacity, profile) from the scalar-or-profile surface.  A
        profile supplies (and must agree on) the partition count; its
        capacity normalizes to the scalar float when uniform, so a
        homogeneous profile drives byte-for-byte the scalar code paths."""
        if profile is None:
            return capacity, None
        if profile.num_partitions != num_partitions:
            raise ValueError(
                f"profile has {profile.num_partitions} partitions, "
                f"want {num_partitions}"
            )
        if capacity is not None and not np.array_equal(
            np.asarray(capacity, dtype=np.float64),
            np.asarray(normalize_capacity(profile.capacity)),
        ):
            raise ValueError("capacity and profile.capacity disagree")
        return profile.capacity_arg(), profile

    def _apply_durability(self, pl, profile, num_partitions, capacity,
                          durability_eps):
        """Post-fit durability pass (``flags.durability_eps`` or the
        explicit argument): greedily copy under-replicated items onto
        low-fail-prob partitions until every item meets the ceiling, then
        re-validate both capacity and the ceiling."""
        eps = (float(_flags.FLAGS.get("durability_eps", 0.0))
               if durability_eps is None else float(durability_eps))
        if eps <= 0:
            return
        prof = profile if profile is not None else NodeProfile.homogeneous(
            num_partitions, float(np.min(np.asarray(capacity)))
        )
        touched = ensure_durability(pl, prof, eps)
        pl.validate()
        validate_durability(pl, prof, eps)
        if pl.stats is not None:
            pl.stats["durability_copies"] = int(len(touched))
        _obs.registry().inc("durability_copies_total", len(touched))

    # ------------------------------------------------------------------ fit
    def fit(
        self,
        queries: Sequence[Sequence[int]],
        num_items: int,
        num_partitions: int,
        capacity: float | None = None,
        node_weights: np.ndarray | None = None,
        query_weights: np.ndarray | None = None,
        profile: NodeProfile | None = None,
        durability_eps: float | None = None,
    ) -> PlacementPlan:
        capacity, profile = self._resolve_profile(
            profile, num_partitions, capacity
        )
        if capacity is None:
            raise ValueError("pass capacity or a NodeProfile")
        hg = Hypergraph.from_edges(
            queries, num_nodes=num_items,
            node_weights=node_weights, edge_weights=query_weights,
        )
        fn = ALGORITHMS[self.algorithm]
        algo_kwargs = {}
        if profile is not None:
            # the LMBR engine's optional access-cost penalty; other
            # algorithms swallow the kwarg
            algo_kwargs["node_cost"] = profile.access_cost
        with _obs.tracer().span("service.fit", algorithm=self.algorithm,
                                n=num_partitions):
            pl = fn(hg, num_partitions, capacity, seed=self.seed,
                    nruns=self.nruns, **algo_kwargs)
        pl.validate()
        self._apply_durability(
            pl, profile, num_partitions, capacity, durability_eps
        )
        return PlacementPlan(
            pl.member, capacity, hg.node_weights, self.algorithm,
            stats=pl.stats,
        )

    # -------------------------------------------------------------- sharded
    def fit_sharded(
        self,
        workload,
        num_partitions: int,
        capacity: float | None = None,
        num_items: int | None = None,
        node_weights: np.ndarray | None = None,
        query_weights: np.ndarray | None = None,
        num_shards: int | None = None,
        workers: int | None = None,
        boundary_repair: int | None = None,
        profile: NodeProfile | None = None,
        durability_eps: float | None = None,
        **algo_kwargs,
    ) -> PlacementPlan:
        """Cluster-scale fit through the `repro.scale` pipeline.

        ``workload`` is either a built `Hypergraph` (the streaming-ingestion
        path — e.g. `StreamingHypergraphBuilder.build()`) or a query list as
        `fit` takes.  ``num_shards`` / ``workers`` / ``boundary_repair``
        default to ``flags.FLAGS["scale_shards" / "scale_workers" /
        "scale_boundary_repair"]``.  The result is deterministic for fixed
        inputs and seed regardless of worker count (serial and pooled
        execution are bit-identical), and the returned plan carries the
        pipeline diagnostics in ``.stats`` (shards, boundary_edges,
        boundary_cost, per-stage seconds, ...)."""
        from ..scale import fit_sharded_placement

        capacity, profile = self._resolve_profile(
            profile, num_partitions, capacity
        )
        if capacity is None:
            raise ValueError("pass capacity or a NodeProfile")
        if isinstance(workload, Hypergraph):
            hg = workload
            if node_weights is not None or query_weights is not None:
                raise ValueError(
                    "pass weights inside the Hypergraph, not alongside it"
                )
        else:
            hg = Hypergraph.from_edges(
                workload, num_nodes=num_items,
                node_weights=node_weights, edge_weights=query_weights,
            )
        with _obs.tracer().span("service.fit_sharded",
                                algorithm=self.algorithm, n=num_partitions):
            res = fit_sharded_placement(
                hg, num_partitions, capacity, algorithm=self.algorithm,
                seed=self.seed, nruns=self.nruns, num_shards=num_shards,
                workers=workers, boundary_repair=boundary_repair,
                **algo_kwargs,
            )
        res.placement.validate()
        self._apply_durability(
            res.placement, profile, num_partitions, capacity, durability_eps
        )
        return PlacementPlan(
            res.placement.member, normalize_capacity(capacity),
            hg.node_weights, f"{self.algorithm}+sharded", stats=res.stats,
        )

    # -------------------------------------------------------------- 2-level
    def fit_hierarchical(
        self,
        queries: Sequence[Sequence[int]],
        num_items: int,
        num_pods: int,
        hosts_per_pod: int,
        host_capacity: float,
        node_weights: np.ndarray | None = None,
    ) -> HierarchicalPlan:
        pod_capacity = host_capacity * hosts_per_pod
        pod_plan = self.fit(
            queries, num_items, num_pods, pod_capacity, node_weights
        )
        hg = Hypergraph.from_edges(queries, num_nodes=num_items,
                                   node_weights=node_weights)
        host_member = np.zeros(
            (num_pods * hosts_per_pod, num_items), dtype=bool
        )
        fn = ALGORITHMS[self.algorithm]
        for pod in range(num_pods):
            pod_items = np.flatnonzero(pod_plan.member[pod])
            if len(pod_items) == 0:
                continue
            # queries restricted to this pod's replica of their items
            local_queries = []
            mask = np.zeros(num_items, dtype=bool)
            mask[pod_items] = True
            for e in range(hg.num_edges):
                q = hg.edge(e)
                lq = q[mask[q]]
                if len(lq) >= 2:
                    local_queries.append(lq)
            remap = np.full(num_items, -1, dtype=np.int64)
            remap[pod_items] = np.arange(len(pod_items))
            sub_hg = Hypergraph.from_edges(
                [remap[q] for q in local_queries] or [[]],
                num_nodes=len(pod_items),
                node_weights=hg.node_weights[pod_items],
            )
            sub_pl = fn(
                sub_hg, hosts_per_pod, host_capacity,
                seed=self.seed + pod, nruns=self.nruns,
            )
            for h in range(hosts_per_pod):
                host_member[pod * hosts_per_pod + h, pod_items] = sub_pl.member[h]
        return HierarchicalPlan(
            pod_plan, host_member, hosts_per_pod, host_capacity, hg.node_weights
        )

    # ---------------------------------------------------------------- refit
    def refit(
        self,
        plan: PlacementPlan,
        queries: Sequence[Sequence[int]],
        max_moves: int = 64,
        dest_mask: np.ndarray | None = None,
        profile: NodeProfile | None = None,
        as_migration: bool = False,
    ):
        """Incremental adaptation to workload drift: LMBR warm-started from
        the current placement; only copies items into free space (existing
        replicas never move, so the delta is cheap to apply online).
        ``dest_mask`` ((N,) bool) excludes partitions from receiving copies
        — the outage path: refitting on a failure-masked layout must never
        target a down partition.  A ``profile`` supplies the access-cost
        vector for the engine's optional ``node_cost_weight`` penalty.

        ``as_migration=True`` returns the change as a
        `repro.online.MigrationPlan` (pacing from the ``migration_*``
        flags, ``.target`` carrying the new `PlacementPlan`) instead of a
        plan to swap atomically — a warm-started refit only adds replicas,
        so the schedule is pure copies and serving from the union layout
        while they stream in never loses coverage."""
        hg = Hypergraph.from_edges(
            queries, num_nodes=plan.member.shape[1],
            node_weights=plan.node_weights,
        )
        with _obs.tracer().span("service.refit", max_moves=max_moves):
            pl = lmbr(
                hg, plan.num_partitions, plan.capacity,
                seed=self.seed, initial=plan.as_placement(),
                max_moves=max_moves, dest_mask=dest_mask,
                node_cost=profile.access_cost if profile is not None else None,
            )
        pl.validate()
        new_plan = PlacementPlan(
            pl.member, plan.capacity, plan.node_weights,
            f"{plan.algorithm}+refit", stats=pl.stats,
        )
        if as_migration:
            return self.plan_migration(plan, new_plan)
        return new_plan

    def plan_migration(
        self,
        old_plan: PlacementPlan,
        new_plan: PlacementPlan,
        bandwidth: float | None = None,
        concurrency: int | None = None,
        headroom: float | None = None,
    ):
        """Diff two plans into a `repro.online.MigrationPlan` (deterministic
        copies-before-drops transfer schedule; pacing defaults to the
        ``migration_*`` flags).  The returned plan's ``.target`` is
        ``new_plan``, so callers hand the schedule to a
        `MigrationExecutor` / ``Simulator.run_online`` ``("migrate", ...)``
        event and adopt the target once the last copy lands."""
        from ..online.migration import plan_migration as _plan_migration

        return _plan_migration(
            old_plan, new_plan, node_weights=new_plan.node_weights,
            bandwidth=bandwidth, concurrency=concurrency, headroom=headroom,
            target=new_plan,
        )
