"""HPA: a multilevel k-way hypergraph partitioner (hMETIS stand-in).

The paper uses hMETIS as a black box.  hMETIS is closed-source and not
installable offline, so we implement our own multilevel partitioner with the
same interface semantics the paper relies on:

  * k-way partitioning of a node-weighted hypergraph,
  * a hard per-partition capacity (the paper drives hMETIS's UBfactor so that
    no partition exceeds C; we take C directly),
  * minimizes the connectivity metric  sum_e w_e * (lambda_e - 1)  which is
    exactly (total span - #queries) when each item has a single copy — i.e.
    the right objective for the paper's average-span goal.

Structure: (1) coarsening by connectivity-weighted matching, (2) greedy
initial partitioning with random restarts, (3) FM-style refinement at every
uncoarsening level, (4) capacity fixup.
"""

from __future__ import annotations

import contextlib
import hashlib
import time
from collections import OrderedDict

import numpy as np

from .. import obs as _obs
from .hypergraph import Hypergraph

__all__ = ["partition", "connectivity_cost", "ubfactor", "fresh_partition_cache"]

_MAX_EDGE_FOR_MATCH = 64  # skip huge hyperedges during matching (hMETIS-like)


def _cap_at(capacity, p):
    """Capacity of part p: the scalar itself (unchanged object — the
    bit-identity path for homogeneous fits) or the vector entry."""
    if isinstance(capacity, np.ndarray) and capacity.ndim:
        return float(capacity[p])
    return capacity


def ubfactor(capacity: float, num_partitions: int, total_items: float) -> float:
    """The paper's UBfactor formula (§4.1) — retained for interface parity.

    UBfactor = 100 * (C*N - totalItems) / (totalItems * N)
    """
    return 100.0 * (capacity * num_partitions - total_items) / (
        total_items * num_partitions
    )


def connectivity_cost(hg: Hypergraph, assign: np.ndarray, k: int) -> float:
    """sum_e w_e * (lambda_e - 1), vectorized over the pin-count matrix."""
    if hg.num_edges == 0:
        return 0.0
    cnt = _edge_part_counts(hg, assign, k)
    lam = (cnt > 0).sum(axis=1)
    return float((hg.edge_weights * (lam - 1)).sum())


def _edge_part_counts(hg: Hypergraph, assign: np.ndarray, k: int) -> np.ndarray:
    """cnt[e, p] = number of pins of edge e in partition p."""
    cnt = np.zeros((hg.num_edges, k), dtype=np.int32)
    pin_edge = np.repeat(
        np.arange(hg.num_edges, dtype=np.int64), np.diff(hg.edge_ptr)
    )
    np.add.at(cnt, (pin_edge, assign[hg.edge_nodes]), 1)
    return cnt


# --------------------------------------------------------------- coarsening
def _coarsen_once(hg: Hypergraph, capacity: float, rng: np.random.Generator):
    """One level of connectivity-weighted matching.  Returns (coarse_hg, map)
    where map[v] = coarse cluster id.

    CSR-vectorized but bit-identical to the original per-node dict loop:
    neighbor scores accumulate in the same (incident-edge, pin) stream order,
    and ties between equal scores resolve to the first-encountered neighbor.
    """
    n = hg.num_nodes
    node_ptr, node_edges = hg.incidence()
    order = rng.permutation(n).tolist()
    esz = hg.edge_sizes()
    edge_ok = (esz >= 2) & (esz <= _MAX_EDGE_FOR_MATCH)
    wpe = np.where(edge_ok, hg.edge_weights / np.maximum(esz - 1, 1), 0.0)
    # per node, the concatenated pins of its eligible incident edges — the
    # neighbor-candidate stream, in the original scan order.  The scan below
    # is the original dict loop verbatim, just over plain Python lists (CSR
    # slicing and numpy scalar boxing were the cost, not the dict).
    counts = np.where(edge_ok[node_edges], esz[node_edges], 0)
    total = int(counts.sum())
    cstart = np.zeros(len(counts) + 1, dtype=np.int64)
    np.cumsum(counts, out=cstart[1:])
    entry = np.repeat(np.arange(len(counts), dtype=np.int64), counts)
    off = np.arange(total, dtype=np.int64) - cstart[entry]
    s_edges = node_edges[entry]
    s_pins = hg.edge_nodes[hg.edge_ptr[s_edges] + off].tolist()
    s_w = wpe[s_edges].tolist()
    v_start = cstart[node_ptr].tolist()
    nw = hg.node_weights.tolist()
    match = [-1] * n
    for v in order:
        if match[v] != -1:
            continue
        scores: dict[int, float] = {}
        for i in range(v_start[v], v_start[v + 1]):
            u = s_pins[i]
            if u != v and match[u] == -1:
                scores[u] = scores.get(u, 0.0) + s_w[i]
        best_u, best_s = -1, 0.0
        wv = nw[v]
        for u, s in scores.items():
            if s > best_s and wv + nw[u] <= capacity:
                best_u, best_s = u, s
        if best_u >= 0:
            match[v] = best_u
            match[best_u] = v
        else:
            match[v] = v
    # build cluster ids
    cmap = np.full(n, -1, dtype=np.int64)
    nxt = 0
    for v in range(n):
        if cmap[v] == -1:
            cmap[v] = nxt
            if match[v] != v and match[v] != -1:
                cmap[match[v]] = nxt
            nxt += 1
    # contract
    cw = np.zeros(nxt, dtype=np.float64)
    np.add.at(cw, cmap, hg.node_weights)
    # rebuild edges on clusters: within-edge sort+dedup vectorized, then
    # identical edges merged in first-occurrence order (same as the dict)
    E = hg.num_edges
    cpins = cmap[hg.edge_nodes]
    pin_edge = np.repeat(np.arange(E, dtype=np.int64), esz)
    so = np.lexsort((cpins, pin_edge))
    sc, se = cpins[so], pin_edge[so]
    keep = np.ones(len(sc), dtype=bool)
    keep[1:] = (sc[1:] != sc[:-1]) | (se[1:] != se[:-1])
    sc, se = sc[keep], se[keep]
    new_sz = np.bincount(se, minlength=E)
    ptr2 = np.zeros(E + 1, dtype=np.int64)
    np.cumsum(new_sz, out=ptr2[1:])
    edge_map: dict[bytes, int] = {}
    slices: list[np.ndarray] = []
    weights: list[float] = []
    for e in range(E):
        if new_sz[e] < 2:
            continue
        pins = sc[ptr2[e]: ptr2[e + 1]]
        key = pins.tobytes()
        i = edge_map.get(key)
        if i is None:
            edge_map[key] = len(slices)
            slices.append(pins)
            weights.append(float(hg.edge_weights[e]))
        else:
            weights[i] += float(hg.edge_weights[e])
    cptr = np.zeros(len(slices) + 1, dtype=np.int64)
    if slices:
        np.cumsum([len(s) for s in slices], out=cptr[1:])
        cnodes = np.concatenate(slices)
    else:
        cnodes = np.zeros(0, dtype=np.int64)
    coarse = Hypergraph(
        cptr, cnodes, cw, np.asarray(weights, dtype=np.float64)
    )
    return coarse, cmap


# ------------------------------------------------------- initial partitioning
def _initial_partition(
    hg: Hypergraph, k: int, capacity: float, rng: np.random.Generator
) -> np.ndarray:
    """Greedy growth: place heavy nodes first into the partition with max
    connectivity gain that still has room."""
    n = hg.num_nodes
    assign = np.full(n, -1, dtype=np.int64)
    loads = np.zeros(k, dtype=np.float64)
    node_ptr, node_edges = hg.incidence()
    # heaviest-first (FFD-style, keeps weighted instances packable), degree
    # as tie-break so connected nodes cluster; random jitter de-correlates runs
    deg = hg.degrees()
    wspan = hg.node_weights.max() - hg.node_weights.min()
    key = deg + rng.random(n)
    if wspan > 1e-12:
        key = hg.node_weights * (2 * deg.max() + 2) + key
    order = np.argsort(-key, kind="stable")
    cnt = np.zeros((hg.num_edges, k), dtype=np.int32)
    for v in order:
        wv = hg.node_weights[v]
        edges = node_edges[node_ptr[v] : node_ptr[v + 1]]
        gain = np.zeros(k, dtype=np.float64)
        if len(edges):
            sub = cnt[edges]  # (d, k)
            gain = (sub > 0).astype(np.float64).T @ hg.edge_weights[edges]
        feasible = loads + wv <= capacity
        if not feasible.any():
            p = int(np.argmin(loads))  # fixup pass will repair
        else:
            gain = np.where(feasible, gain, -np.inf)
            # tie-break toward least-loaded partitions for balance
            p = int(np.argmax(gain - 1e-9 * loads))
        assign[v] = p
        loads[p] += wv
        if len(edges):
            cnt[edges, p] += 1
    return assign


# ----------------------------------------------------------------- refinement
def _move_gains(cnt, edges, w, a):
    """Connectivity gain of moving a node (with incident `edges`, weights `w`,
    currently in part `a`) to every part.  gain[b]: edges where the node is
    the sole pin in `a` stop spanning `a` (gain w_e if `b` already pinned);
    edges unpinned in `b` start spanning it (loss w_e unless the sole pin
    travels along).  Computed as two masked vector-matrix products."""
    sub = cnt[edges]  # (d, k)
    sole = sub[:, a] == 1
    nz = sub > 0
    gain = (w * sole) @ nz - (w * ~sole) @ ~nz
    gain[a] = 0.0
    return gain


def _refine(
    hg: Hypergraph,
    assign: np.ndarray,
    k: int,
    capacity: float,
    rng: np.random.Generator,
    passes: int = 3,
    swap_candidates: int = 24,
) -> np.ndarray:
    """FM-style greedy passes on the connectivity objective, with pairwise
    swaps as a fallback when capacity blocks a single move (the zero-slack
    regime: |V| == k*C).

    Hot-path shortcut (exact): a move or swap of node v can only trigger if
    some gain[b] > 1e-12, and for non-negative edge weights that requires v
    to be the SOLE pin of an incident edge in its own partition.  Nodes whose
    best gain is known to be <= 1e-12 are "settled" and skipped without
    recomputing gains or touching the RNG (the skipped iteration is a no-op
    in the original loop too).  Settled status depends only on the pin-count
    rows of v's incident edges — NOT on loads or feasibility — so it stays
    valid across passes and is invalidated exactly when a pin of one of
    those edges moves."""
    if hg.num_edges == 0 or k == 1:
        return assign
    node_ptr, node_edges = hg.incidence()
    cnt = _edge_part_counts(hg, assign, k)
    loads = np.zeros(k, dtype=np.float64)
    np.add.at(loads, assign, hg.node_weights)
    part_nodes: list[set[int]] = [set() for _ in range(k)]
    for v, p in enumerate(assign):
        part_nodes[int(p)].add(v)
    w_stream = hg.edge_weights[node_edges]
    deg = np.diff(node_ptr)

    # nodes with no sole pin in their own partition start out settled
    col = cnt[node_edges, np.repeat(assign, deg)] == 1
    cum = np.zeros(len(col) + 1, dtype=np.int64)
    np.cumsum(col, out=cum[1:])
    settled = ~(cum[node_ptr[1:]] > cum[node_ptr[:-1]])
    cache_ok = np.ones(hg.num_nodes, dtype=bool)

    def invalidate(edge_ids):
        for e in edge_ids:
            cache_ok[hg.edge(int(e))] = False

    for _ in range(passes):
        improved = False
        for v in rng.permutation(hg.num_nodes):
            if cache_ok[v] and settled[v]:
                continue
            edges = node_edges[node_ptr[v] : node_ptr[v + 1]]
            if len(edges) == 0:
                continue
            a = int(assign[v])
            if not cache_ok[v]:
                cache_ok[v] = True
                if not (cnt[edges, a] == 1).any():
                    settled[v] = True
                    continue
            wv = hg.node_weights[v]
            w = w_stream[node_ptr[v] : node_ptr[v + 1]]
            gain = _move_gains(cnt, edges, w, a)
            settled[v] = bool(gain.max() <= 1e-12)
            feasible = loads + wv <= capacity
            feasible[a] = True
            move_gain = np.where(feasible, gain, -np.inf)
            b = int(np.argmax(move_gain))
            if b != a and move_gain[b] > 1e-12:
                assign[v] = b
                loads[a] -= wv
                loads[b] += wv
                cnt[edges, a] -= 1
                cnt[edges, b] += 1
                invalidate(edges)
                part_nodes[a].discard(int(v))
                part_nodes[b].add(int(v))
                improved = True
                continue
            # ---- swap fallback: the best *infeasible* target might pay for
            # sending one of its nodes back
            b = int(np.argmax(gain))
            if b == a or gain[b] <= 1e-12 or len(part_nodes[b]) == 0:
                continue
            # tentatively move v -> b
            cnt[edges, a] -= 1
            cnt[edges, b] += 1
            cand = list(part_nodes[b])
            if len(cand) > swap_candidates:
                cand = [cand[i] for i in rng.choice(len(cand),
                                                    swap_candidates,
                                                    replace=False)]
            best_u, best_total = -1, 1e-12
            for u in cand:
                wu = hg.node_weights[u]
                if (loads[a] - wv + wu > _cap_at(capacity, a)
                        or loads[b] + wv - wu > _cap_at(capacity, b)):
                    continue
                eu = node_edges[node_ptr[u] : node_ptr[u + 1]]
                if len(eu) == 0:
                    g_u = 0.0
                else:
                    g_u = _move_gains(cnt, eu, hg.edge_weights[eu], b)[a]
                total = gain[b] + g_u
                if total > best_total:
                    best_u, best_total = int(u), total
            if best_u >= 0:
                u = best_u
                eu = node_edges[node_ptr[u] : node_ptr[u + 1]]
                cnt[eu, b] -= 1
                cnt[eu, a] += 1
                invalidate(edges)
                invalidate(eu)
                assign[v], assign[u] = b, a
                loads[a] += hg.node_weights[u] - wv
                loads[b] += wv - hg.node_weights[u]
                part_nodes[a].discard(int(v))
                part_nodes[a].add(u)
                part_nodes[b].discard(u)
                part_nodes[b].add(int(v))
                improved = True
            else:
                cnt[edges, a] += 1  # revert tentative
                cnt[edges, b] -= 1
        if not improved:
            break
    return assign


def _fixup_capacity(
    hg: Hypergraph, assign: np.ndarray, k: int, capacity: float
) -> np.ndarray:
    """Repair capacity violations by evicting the loosest nodes (the paper
    uses an LMBR-style move for this; greedy lowest-connectivity move is the
    same idea without replication)."""
    loads = np.zeros(k, dtype=np.float64)
    np.add.at(loads, assign, hg.node_weights)
    node_ptr, node_edges = hg.incidence()
    for p in range(k):
        guard = 0
        while loads[p] > _cap_at(capacity, p) + 1e-9 and guard < hg.num_nodes:
            guard += 1
            members = np.flatnonzero(assign == p)
            # evict the node with the fewest incident pins in p (lightest on ties)
            best_v, best_key = -1, (np.inf, np.inf)
            for v in members:
                d = len(node_edges[node_ptr[v] : node_ptr[v + 1]])
                kkey = (d, -hg.node_weights[v])
                if kkey < best_key:
                    best_v, best_key = int(v), kkey
            wv = hg.node_weights[best_v]
            frees = capacity - loads
            frees[p] = -np.inf
            tgt = int(np.argmax(frees))
            if frees[tgt] >= wv - 1e-9:
                assign[best_v] = tgt
                loads[p] -= wv
                loads[tgt] += wv
                continue
            # swap fallback: exchange with a lighter node elsewhere
            done = False
            for q in np.argsort(-frees):
                q = int(q)
                if q == p:
                    continue
                for u in np.flatnonzero(assign == q):
                    wu = hg.node_weights[u]
                    if (wu < wv
                            and loads[q] - wu + wv <= _cap_at(capacity, q) + 1e-9
                            and loads[p] - wv + wu
                            <= _cap_at(capacity, p) + 1e-9 * 0 + loads[p]):
                        assign[best_v], assign[int(u)] = q, p
                        loads[p] += wu - wv
                        loads[q] += wv - wu
                        done = True
                        break
                if done:
                    break
            if not done:
                raise ValueError("cannot satisfy capacity constraints")
    return assign


# -------------------------------------------------------------------- driver
_PARTITION_CACHE: OrderedDict[str, np.ndarray] = OrderedDict()
_PARTITION_CACHE_MAX = 8


@contextlib.contextmanager
def fresh_partition_cache():
    """Scope the partition memo: run the body against an empty cache, then
    restore the previous one.

    `partition` is a pure function, so the memo never changes placements —
    only who gets billed for shared work.  Benchmarks that time algorithms
    individually (Simulator.run) enter this scope so each algorithm pays for
    its own partition calls instead of free-riding on whichever algorithm
    ran first; the memo still dedups identical calls *within* one run (e.g.
    IHPA's repeated base partition)."""
    global _PARTITION_CACHE
    saved = _PARTITION_CACHE
    _PARTITION_CACHE = OrderedDict()
    try:
        yield
    finally:
        _PARTITION_CACHE = saved


def _partition_key(hg, k, capacity, seed, nruns, passes, coarsen_to) -> str:
    h = hashlib.sha1()
    for arr in (hg.edge_ptr, hg.edge_nodes, hg.node_weights, hg.edge_weights):
        h.update(np.ascontiguousarray(arr).tobytes())
    if isinstance(capacity, np.ndarray) and capacity.ndim:
        h.update(np.ascontiguousarray(capacity, dtype=np.float64).tobytes())
        cap_repr = "het"
    else:
        cap_repr = float(capacity)
    h.update(
        repr((k, cap_repr, seed, nruns, passes, coarsen_to)).encode()
    )
    return h.hexdigest()


def partition(
    hg: Hypergraph,
    k: int,
    capacity: float | None = None,
    seed: int = 0,
    nruns: int = 2,
    passes: int = 3,
    coarsen_to: int | None = None,
) -> np.ndarray:
    """Partition `hg` into `k` parts under per-part `capacity`.

    Returns assign: (V,) int64, values in [0, k).  Items with zero degree are
    balanced across parts by weight.

    `partition` is a deterministic pure function of its arguments, and the
    placement algorithms routinely issue *identical* calls (HPA / IHPA / DS
    all start from the same N_e-way partition of the same workload), so
    results are memoized in a small content-addressed LRU."""
    n = hg.num_nodes
    if capacity is None:
        capacity = hg.total_node_weight() / k * 1.05 + hg.node_weights.max()
    het = isinstance(capacity, np.ndarray) and capacity.ndim
    if het and len(capacity) != k:
        raise ValueError(
            f"capacity vector has {len(capacity)} entries, want k={k}"
        )
    total_cap = float(capacity.sum()) if het else k * capacity
    if hg.total_node_weight() > total_cap + 1e-9:
        raise ValueError(
            f"items (w={hg.total_node_weight()}) cannot fit {k} x {capacity}"
        )
    if k <= 1:
        return np.zeros(n, dtype=np.int64)
    if coarsen_to is None:
        coarsen_to = max(128, 12 * k)

    key = _partition_key(hg, k, capacity, seed, nruns, passes, coarsen_to)
    cached = _PARTITION_CACHE.get(key)
    if cached is not None:
        _PARTITION_CACHE.move_to_end(key)
        return cached.copy()

    _tr = _obs.tracer()
    _t0 = time.perf_counter() if _tr.active else 0.0
    best_assign, best_cost = None, np.inf
    for run in range(max(1, nruns)):
        rng = np.random.default_rng(seed + 7919 * run)
        # ---- coarsening phase
        _tc = time.perf_counter() if _tr.active else 0.0
        levels: list[tuple[Hypergraph, np.ndarray]] = []
        cur = hg
        # heterogeneous capacities coarsen against the tightest part: no
        # cluster may exceed the smallest capacity (same semantics as the
        # scalar bound); the scalar object passes through untouched
        coarse_cap = float(np.min(capacity)) if het else capacity
        while cur.num_nodes > coarsen_to:
            coarse, cmap = _coarsen_once(cur, coarse_cap, rng)
            if coarse.num_nodes >= 0.95 * cur.num_nodes:
                break  # diminishing returns
            levels.append((cur, cmap))
            cur = coarse
        if _tr.active:
            _tr.complete("fit.hpa.coarsen", _tc, time.perf_counter(),
                         run=run, levels=len(levels), coarse_n=cur.num_nodes)
        # ---- initial partition on coarsest graph
        _tc = time.perf_counter() if _tr.active else 0.0
        assign = _initial_partition(cur, k, capacity, rng)
        assign = _refine(cur, assign, k, capacity, rng, passes)
        # ---- uncoarsen + refine
        for fine, cmap in reversed(levels):
            assign = assign[cmap]
            assign = _refine(fine, assign, k, capacity, rng, passes)
        assign = _fixup_capacity(hg, assign, k, capacity)
        if _tr.active:
            _tr.complete("fit.hpa.refine", _tc, time.perf_counter(), run=run)
        cost = connectivity_cost(hg, assign, k)
        if cost < best_cost:
            best_cost, best_assign = cost, assign.copy()
    _PARTITION_CACHE[key] = best_assign.copy()
    if len(_PARTITION_CACHE) > _PARTITION_CACHE_MAX:
        _PARTITION_CACHE.popitem(last=False)
    if _tr.active:
        _tr.complete("fit.hpa", _t0, time.perf_counter(), k=k,
                     n=n, nruns=nruns)
    return best_assign
