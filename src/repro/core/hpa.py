"""HPA: a multilevel k-way hypergraph partitioner (hMETIS stand-in).

The paper uses hMETIS as a black box.  hMETIS is closed-source and not
installable offline, so we implement our own multilevel partitioner with the
same interface semantics the paper relies on:

  * k-way partitioning of a node-weighted hypergraph,
  * a hard per-partition capacity (the paper drives hMETIS's UBfactor so that
    no partition exceeds C; we take C directly),
  * minimizes the connectivity metric  sum_e w_e * (lambda_e - 1)  which is
    exactly (total span - #queries) when each item has a single copy — i.e.
    the right objective for the paper's average-span goal.

Structure: (1) coarsening by connectivity-weighted matching, (2) greedy
initial partitioning with random restarts, (3) FM-style refinement at every
uncoarsening level, (4) capacity fixup.
"""

from __future__ import annotations

import numpy as np

from .hypergraph import Hypergraph

__all__ = ["partition", "connectivity_cost", "ubfactor"]

_MAX_EDGE_FOR_MATCH = 64  # skip huge hyperedges during matching (hMETIS-like)


def ubfactor(capacity: float, num_partitions: int, total_items: float) -> float:
    """The paper's UBfactor formula (§4.1) — retained for interface parity.

    UBfactor = 100 * (C*N - totalItems) / (totalItems * N)
    """
    return 100.0 * (capacity * num_partitions - total_items) / (
        total_items * num_partitions
    )


def connectivity_cost(hg: Hypergraph, assign: np.ndarray, k: int) -> float:
    """sum_e w_e * (lambda_e - 1)."""
    cost = 0.0
    for e in range(hg.num_edges):
        parts = np.unique(assign[hg.edge(e)])
        cost += hg.edge_weights[e] * (len(parts) - 1)
    return cost


def _edge_part_counts(hg: Hypergraph, assign: np.ndarray, k: int) -> np.ndarray:
    """cnt[e, p] = number of pins of edge e in partition p."""
    cnt = np.zeros((hg.num_edges, k), dtype=np.int32)
    pin_edge = np.repeat(
        np.arange(hg.num_edges, dtype=np.int64), np.diff(hg.edge_ptr)
    )
    np.add.at(cnt, (pin_edge, assign[hg.edge_nodes]), 1)
    return cnt


# --------------------------------------------------------------- coarsening
def _coarsen_once(hg: Hypergraph, capacity: float, rng: np.random.Generator):
    """One level of connectivity-weighted matching.  Returns (coarse_hg, map)
    where map[v] = coarse cluster id."""
    n = hg.num_nodes
    node_ptr, node_edges = hg.incidence()
    match = np.full(n, -1, dtype=np.int64)
    order = rng.permutation(n)
    esz = hg.edge_sizes()
    for v in order:
        if match[v] != -1:
            continue
        # score neighbors by sum(w_e / (|e|-1)) over shared edges
        scores: dict[int, float] = {}
        for e in node_edges[node_ptr[v] : node_ptr[v + 1]]:
            s = esz[e]
            if s < 2 or s > _MAX_EDGE_FOR_MATCH:
                continue
            we = hg.edge_weights[e] / (s - 1)
            for u in hg.edge(int(e)):
                if u != v and match[u] == -1:
                    scores[int(u)] = scores.get(int(u), 0.0) + we
        best_u, best_s = -1, 0.0
        wv = hg.node_weights[v]
        for u, s in scores.items():
            if s > best_s and wv + hg.node_weights[u] <= capacity:
                best_u, best_s = u, s
        if best_u >= 0:
            match[v] = best_u
            match[best_u] = v
        else:
            match[v] = v
    # build cluster ids
    cmap = np.full(n, -1, dtype=np.int64)
    nxt = 0
    for v in range(n):
        if cmap[v] == -1:
            cmap[v] = nxt
            if match[v] != v and match[v] != -1:
                cmap[match[v]] = nxt
            nxt += 1
    # contract
    cw = np.zeros(nxt, dtype=np.float64)
    np.add.at(cw, cmap, hg.node_weights)
    # rebuild edges on clusters, dedup identical edges
    edge_map: dict[tuple, float] = {}
    for e in range(hg.num_edges):
        pins = tuple(sorted(set(int(cmap[u]) for u in hg.edge(e))))
        if len(pins) < 2:
            continue
        edge_map[pins] = edge_map.get(pins, 0.0) + float(hg.edge_weights[e])
    edges = list(edge_map.keys())
    weights = np.asarray([edge_map[e] for e in edges], dtype=np.float64)
    coarse = Hypergraph.from_edges(
        edges, num_nodes=nxt, node_weights=cw, edge_weights=weights
    )
    return coarse, cmap


# ------------------------------------------------------- initial partitioning
def _initial_partition(
    hg: Hypergraph, k: int, capacity: float, rng: np.random.Generator
) -> np.ndarray:
    """Greedy growth: place heavy nodes first into the partition with max
    connectivity gain that still has room."""
    n = hg.num_nodes
    assign = np.full(n, -1, dtype=np.int64)
    loads = np.zeros(k, dtype=np.float64)
    node_ptr, node_edges = hg.incidence()
    # heaviest-first (FFD-style, keeps weighted instances packable), degree
    # as tie-break so connected nodes cluster; random jitter de-correlates runs
    deg = hg.degrees()
    wspan = hg.node_weights.max() - hg.node_weights.min()
    key = deg + rng.random(n)
    if wspan > 1e-12:
        key = hg.node_weights * (2 * deg.max() + 2) + key
    order = np.argsort(-key, kind="stable")
    cnt = np.zeros((hg.num_edges, k), dtype=np.int32)
    for v in order:
        wv = hg.node_weights[v]
        edges = node_edges[node_ptr[v] : node_ptr[v + 1]]
        gain = np.zeros(k, dtype=np.float64)
        if len(edges):
            sub = cnt[edges]  # (d, k)
            gain = (sub > 0).astype(np.float64).T @ hg.edge_weights[edges]
        feasible = loads + wv <= capacity
        if not feasible.any():
            p = int(np.argmin(loads))  # fixup pass will repair
        else:
            gain = np.where(feasible, gain, -np.inf)
            # tie-break toward least-loaded partitions for balance
            p = int(np.argmax(gain - 1e-9 * loads))
        assign[v] = p
        loads[p] += wv
        if len(edges):
            cnt[edges, p] += 1
    return assign


# ----------------------------------------------------------------- refinement
def _move_gains(cnt, edges, w, a):
    """Connectivity gain of moving a node (with incident `edges`, weights `w`,
    currently in part `a`) to every part.  gain[b]: edges where the node is
    the sole pin in `a` stop spanning `a` (gain w_e if `b` already pinned);
    edges unpinned in `b` start spanning it (loss w_e unless the sole pin
    travels along)."""
    sub = cnt[edges]  # (d, k)
    col_a = sub[:, a]
    sole = col_a == 1
    gain = ((sole[:, None] & (sub > 0)) * w[:, None]).sum(axis=0) - (
        ((~sole)[:, None] & (sub == 0)) * w[:, None]
    ).sum(axis=0)
    gain[a] = 0.0
    return gain


def _refine(
    hg: Hypergraph,
    assign: np.ndarray,
    k: int,
    capacity: float,
    rng: np.random.Generator,
    passes: int = 3,
    swap_candidates: int = 24,
) -> np.ndarray:
    """FM-style greedy passes on the connectivity objective, with pairwise
    swaps as a fallback when capacity blocks a single move (the zero-slack
    regime: |V| == k*C)."""
    if hg.num_edges == 0 or k == 1:
        return assign
    node_ptr, node_edges = hg.incidence()
    cnt = _edge_part_counts(hg, assign, k)
    loads = np.zeros(k, dtype=np.float64)
    np.add.at(loads, assign, hg.node_weights)
    part_nodes: list[set[int]] = [set() for _ in range(k)]
    for v, p in enumerate(assign):
        part_nodes[int(p)].add(v)
    for _ in range(passes):
        improved = False
        for v in rng.permutation(hg.num_nodes):
            edges = node_edges[node_ptr[v] : node_ptr[v + 1]]
            if len(edges) == 0:
                continue
            a = int(assign[v])
            wv = hg.node_weights[v]
            w = hg.edge_weights[edges]
            gain = _move_gains(cnt, edges, w, a)
            feasible = loads + wv <= capacity
            feasible[a] = True
            move_gain = np.where(feasible, gain, -np.inf)
            b = int(np.argmax(move_gain))
            if b != a and move_gain[b] > 1e-12:
                assign[v] = b
                loads[a] -= wv
                loads[b] += wv
                cnt[edges, a] -= 1
                cnt[edges, b] += 1
                part_nodes[a].discard(int(v))
                part_nodes[b].add(int(v))
                improved = True
                continue
            # ---- swap fallback: the best *infeasible* target might pay for
            # sending one of its nodes back
            b = int(np.argmax(gain))
            if b == a or gain[b] <= 1e-12 or len(part_nodes[b]) == 0:
                continue
            # tentatively move v -> b
            cnt[edges, a] -= 1
            cnt[edges, b] += 1
            cand = list(part_nodes[b])
            if len(cand) > swap_candidates:
                cand = [cand[i] for i in rng.choice(len(cand),
                                                    swap_candidates,
                                                    replace=False)]
            best_u, best_total = -1, 1e-12
            for u in cand:
                wu = hg.node_weights[u]
                if loads[a] - wv + wu > capacity or loads[b] + wv - wu > capacity:
                    continue
                eu = node_edges[node_ptr[u] : node_ptr[u + 1]]
                if len(eu) == 0:
                    g_u = 0.0
                else:
                    g_u = _move_gains(cnt, eu, hg.edge_weights[eu], b)[a]
                total = gain[b] + g_u
                if total > best_total:
                    best_u, best_total = int(u), total
            if best_u >= 0:
                u = best_u
                eu = node_edges[node_ptr[u] : node_ptr[u + 1]]
                cnt[eu, b] -= 1
                cnt[eu, a] += 1
                assign[v], assign[u] = b, a
                loads[a] += hg.node_weights[u] - wv
                loads[b] += wv - hg.node_weights[u]
                part_nodes[a].discard(int(v))
                part_nodes[a].add(u)
                part_nodes[b].discard(u)
                part_nodes[b].add(int(v))
                improved = True
            else:
                cnt[edges, a] += 1  # revert tentative
                cnt[edges, b] -= 1
        if not improved:
            break
    return assign


def _fixup_capacity(
    hg: Hypergraph, assign: np.ndarray, k: int, capacity: float
) -> np.ndarray:
    """Repair capacity violations by evicting the loosest nodes (the paper
    uses an LMBR-style move for this; greedy lowest-connectivity move is the
    same idea without replication)."""
    loads = np.zeros(k, dtype=np.float64)
    np.add.at(loads, assign, hg.node_weights)
    node_ptr, node_edges = hg.incidence()
    for p in range(k):
        guard = 0
        while loads[p] > capacity + 1e-9 and guard < hg.num_nodes:
            guard += 1
            members = np.flatnonzero(assign == p)
            # evict the node with the fewest incident pins in p (lightest on ties)
            best_v, best_key = -1, (np.inf, np.inf)
            for v in members:
                d = len(node_edges[node_ptr[v] : node_ptr[v + 1]])
                kkey = (d, -hg.node_weights[v])
                if kkey < best_key:
                    best_v, best_key = int(v), kkey
            wv = hg.node_weights[best_v]
            frees = capacity - loads
            frees[p] = -np.inf
            tgt = int(np.argmax(frees))
            if frees[tgt] >= wv - 1e-9:
                assign[best_v] = tgt
                loads[p] -= wv
                loads[tgt] += wv
                continue
            # swap fallback: exchange with a lighter node elsewhere
            done = False
            for q in np.argsort(-frees):
                q = int(q)
                if q == p:
                    continue
                for u in np.flatnonzero(assign == q):
                    wu = hg.node_weights[u]
                    if (wu < wv
                            and loads[q] - wu + wv <= capacity + 1e-9
                            and loads[p] - wv + wu <= capacity + 1e-9 * 0 + loads[p]):
                        assign[best_v], assign[int(u)] = q, p
                        loads[p] += wu - wv
                        loads[q] += wv - wu
                        done = True
                        break
                if done:
                    break
            if not done:
                raise ValueError("cannot satisfy capacity constraints")
    return assign


# -------------------------------------------------------------------- driver
def partition(
    hg: Hypergraph,
    k: int,
    capacity: float | None = None,
    seed: int = 0,
    nruns: int = 2,
    passes: int = 3,
    coarsen_to: int | None = None,
) -> np.ndarray:
    """Partition `hg` into `k` parts under per-part `capacity`.

    Returns assign: (V,) int64, values in [0, k).  Items with zero degree are
    balanced across parts by weight.
    """
    n = hg.num_nodes
    if capacity is None:
        capacity = hg.total_node_weight() / k * 1.05 + hg.node_weights.max()
    if hg.total_node_weight() > k * capacity + 1e-9:
        raise ValueError(
            f"items (w={hg.total_node_weight()}) cannot fit {k} x {capacity}"
        )
    if k <= 1:
        return np.zeros(n, dtype=np.int64)
    if coarsen_to is None:
        coarsen_to = max(128, 12 * k)

    best_assign, best_cost = None, np.inf
    for run in range(max(1, nruns)):
        rng = np.random.default_rng(seed + 7919 * run)
        # ---- coarsening phase
        levels: list[tuple[Hypergraph, np.ndarray]] = []
        cur = hg
        while cur.num_nodes > coarsen_to:
            coarse, cmap = _coarsen_once(cur, capacity, rng)
            if coarse.num_nodes >= 0.95 * cur.num_nodes:
                break  # diminishing returns
            levels.append((cur, cmap))
            cur = coarse
        # ---- initial partition on coarsest graph
        assign = _initial_partition(cur, k, capacity, rng)
        assign = _refine(cur, assign, k, capacity, rng, passes)
        # ---- uncoarsen + refine
        for fine, cmap in reversed(levels):
            assign = assign[cmap]
            assign = _refine(fine, assign, k, capacity, rng, passes)
        assign = _fixup_capacity(hg, assign, k, capacity)
        cost = connectivity_cost(hg, assign, k)
        if cost < best_cost:
            best_cost, best_assign = cost, assign.copy()
    return best_assign
