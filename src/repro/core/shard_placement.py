"""Dataset-shard placement for the input pipeline (paper technique at the
storage layer).

Mapping onto the paper's model:
  data items  -> dataset shards (files / file chunks)
  query       -> one global batch's shard-set (mixture sampling reads several
                 shards together; the batch is the read unit)
  partitions  -> data hosts, capacity = local shard cache size
  span        -> hosts a batch must gather from (cross-host input traffic)

Shards are replicated RF-way for fault tolerance anyway (HDFS-style); placing
those replicas with PRA-3W/LMBR makes most batches assemble from few hosts,
and — per the paper — lets untouched hosts idle.  The same plan doubles as
the straggler/failure story: when a host is slow or dead, replica selection
re-covers its shards from surviving replicas with minimal extra span
(`cover_excluding`).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .algorithms import ALGORITHMS
from .three_way import THREE_WAY_ALGORITHMS
from .hypergraph import Hypergraph
from .setcover import cover_for_query, greedy_set_cover

__all__ = ["ShardPlacementPlan", "plan_shard_placement", "mixture_batch_recipes"]


def mixture_batch_recipes(
    num_shards: int,
    num_batches: int,
    shards_per_batch: int = 8,
    num_mixtures: int = 12,
    zipf_a: float = 1.3,
    seed: int = 0,
) -> list[np.ndarray]:
    """Batch recipes under mixture sampling: each training batch draws from
    one of a few data mixtures (web/code/math/...), and each mixture reads a
    stable subset of shards — exactly the 'same queries run regularly'
    workload the paper assumes."""
    rng = np.random.default_rng(seed)
    pop = 1.0 / np.arange(1, num_mixtures + 1) ** zipf_a
    pop /= pop.sum()
    mixture_pools = [
        rng.choice(num_shards, size=min(num_shards, 4 * shards_per_batch),
                   replace=False)
        for _ in range(num_mixtures)
    ]
    recipes = []
    for _ in range(num_batches):
        m = int(rng.choice(num_mixtures, p=pop))
        recipes.append(
            np.unique(rng.choice(mixture_pools[m], size=shards_per_batch))
        )
    return recipes


@dataclasses.dataclass
class ShardPlacementPlan:
    member: np.ndarray  # (hosts, shards) bool
    capacity: float
    algorithm: str
    shard_weights: np.ndarray

    @property
    def num_hosts(self) -> int:
        return self.member.shape[0]

    def hosts_for_batch(self, recipe: np.ndarray):
        """(hosts, shards-read-from-each): replica selection for one batch."""
        return cover_for_query(np.asarray(recipe, dtype=np.int64), self.member)

    def span(self, recipe: np.ndarray) -> int:
        return len(greedy_set_cover(np.asarray(recipe, dtype=np.int64), self.member))

    def avg_span(self, recipes: list[np.ndarray]) -> float:
        return float(np.mean([self.span(r) for r in recipes]))

    def cover_excluding(self, recipe: np.ndarray, dead_hosts: set[int]):
        """Failure/straggler path: cover the batch without `dead_hosts`.
        Raises if some shard's every replica is dead."""
        mask = np.ones(self.member.shape[0], dtype=bool)
        for h in dead_hosts:
            mask[h] = False
        sub = self.member[mask]
        alive_ids = np.flatnonzero(mask)
        chosen, accessed = cover_for_query(
            np.asarray(recipe, dtype=np.int64), sub
        )
        return [int(alive_ids[c]) for c in chosen], accessed

    def survives_failures(self, max_failures: int = 1) -> bool:
        """Every shard keeps >=1 replica after any `max_failures` host losses
        iff every shard has > max_failures replicas."""
        return bool((self.member.sum(axis=0) > max_failures).all())


def plan_shard_placement(
    recipes: list[np.ndarray],
    num_shards: int,
    num_hosts: int,
    capacity: float,
    algorithm: str = "pra3",
    rf: int = 3,
    shard_weights: np.ndarray | None = None,
    seed: int = 0,
) -> ShardPlacementPlan:
    """Fit placement.  `algorithm` may be any unconstrained paper algorithm
    (lmbr/ihpa/ds/pra) or a fixed-RF one (pra3/sda/ihpa3/random3) when the
    deployment mandates exactly `rf` copies for durability."""
    hg = Hypergraph.from_edges(
        recipes, num_nodes=num_shards, node_weights=shard_weights
    )
    if algorithm in THREE_WAY_ALGORITHMS:
        pl = THREE_WAY_ALGORITHMS[algorithm](
            hg, n=num_hosts, capacity=capacity, rf=rf, seed=seed
        )
    else:
        pl = ALGORITHMS[algorithm](hg, num_hosts, capacity, seed=seed)
    # durability floor: every shard (even never-sampled ones) placed once
    placed = pl.member.any(axis=0)
    loads = pl.partition_weights()
    w = hg.node_weights
    for s in np.flatnonzero(~placed):
        r = int(np.argmin(loads))
        pl.member[r, s] = True
        loads[r] += w[s]
    return ShardPlacementPlan(pl.member, capacity, algorithm, hg.node_weights)
