"""repro.core — the paper's contribution: workload-driven data placement and
replica selection minimizing average query span (Kumar, Deshpande, Khuller).

Layout:
  hypergraph  — workload model (queries = hyperedges over data items)
  cluster     — heterogeneous node profiles (per-partition capacity /
                failure probability / power / access cost) + durability
  setcover    — greedy replica selection / span computation
  hpa         — multilevel hypergraph partitioner (hMETIS stand-in)
  algorithms  — IHPA / DS / PRA / LMBR (+ Random, HPA baselines)
  three_way   — fixed RF=3 variants (PRA-3W, SDA, IHPA-3W)
  simulator   — trace-driven simulator + energy model; run_online streams
                the trace through the serving subsystem (``repro.online``:
                router / drift detector / failover) with down-up events
  workloads   — Random / Snowflake / ISPD-like / TPC-H-hetero generators
  placement_service — production fit/refit API with hierarchical (pod/host) span
  expert_placement  — MoE expert->EP-rank placement from routing traces
  shard_placement   — dataset shard->host placement for the input pipeline
"""

from .hypergraph import (  # noqa: F401
    Hypergraph,
    MutableHypergraph,
    canonicalize_csr,
)
from .cluster import (  # noqa: F401
    NodeProfile,
    capacity_vector,
    ensure_durability,
    min_replicas,
    normalize_capacity,
    validate_durability,
)
from .setcover import (  # noqa: F401
    Placement,
    SpanMaintainer,
    WorkloadCover,
    batched_cover_csr,
    batched_spans_csr,
    cover_for_query,
    greedy_set_cover,
    queries_to_csr,
    query_span,
    spans_for_workload,
)
from .hpa import partition as hpa_partition  # noqa: F401
from .algorithms import (  # noqa: F401
    ALGORITHMS,
    ds,
    hpa_placement,
    ihpa,
    lmbr,
    min_partitions,
    pra,
    random_placement,
)
from .three_way import (  # noqa: F401
    THREE_WAY_ALGORITHMS,
    ihpa_3way,
    pra_3way,
    random_3way,
    sda,
)
from .simulator import EnergyModel, SimulationResult, Simulator  # noqa: F401
from .workloads import (  # noqa: F401
    LMBR_STRESS_DEFAULTS,
    PAPER_DEFAULTS,
    WEB_SCALE_DEFAULTS,
    Workload,
    ispd_like_workload,
    lmbr_stress_workload,
    random_workload,
    snowflake_workload,
    tpch_heterogeneous,
    web_scale_chunks,
    web_scale_workload,
)
from .placement_service import (  # noqa: F401
    HierarchicalPlan,
    PlacementPlan,
    PlacementService,
)
from .expert_placement import (  # noqa: F401
    ExpertPlacementPlan,
    baseline_contiguous_placement,
    plan_expert_placement,
    routing_trace_to_hypergraph,
    synthetic_routing_trace,
)
from .shard_placement import (  # noqa: F401
    ShardPlacementPlan,
    mixture_batch_recipes,
    plan_shard_placement,
)
