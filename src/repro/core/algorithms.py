"""Data placement algorithms with replication (paper §4).

Implemented faithfully from the paper's pseudocode:

  * random_placement — Random baseline (replicate & distribute randomly)
  * hpa_placement    — HPA baseline, no replication (straight line in fig. 6)
  * ihpa             — Algorithm 1, Iterative HPA
  * ds               — Algorithm 2, Dense-Subgraph based
  * pra              — Algorithm 3, Pre-Replication via hitting sets
  * lmbr             — Algorithms 4+5, improved Local-Move-Based Replication

All return a `Placement` (membership matrix), on which spans are computed by
greedy set cover (replica selection).
"""

from __future__ import annotations

import heapq
from typing import Callable

import numpy as np

from .. import flags as _flags
from . import hpa as hpa_mod
from .hypergraph import Hypergraph
from .setcover import (
    Placement,
    SpanMaintainer,
    batched_spans_csr,
    greedy_set_cover,
)

__all__ = [
    "random_placement", "hpa_placement", "ihpa", "ds", "pra", "lmbr",
    "min_partitions", "ALGORITHMS",
]


def min_partitions(hg: Hypergraph, capacity: float) -> int:
    """N_e = ceil(total item weight / C): the minimum number of partitions
    that can hold one copy of every item (exact up to the 1e-9 guard against
    float round-up on integer-weight workloads)."""
    return int(np.ceil(hg.total_node_weight() / capacity - 1e-9))


def _assign_to_placement(
    hg: Hypergraph, assign: np.ndarray, num_partitions: int, capacity: float
) -> Placement:
    pl = Placement.empty(num_partitions, hg.num_nodes, capacity, hg.node_weights)
    for v in range(hg.num_nodes):
        pl.member[assign[v], v] = True
    return pl


# ------------------------------------------------------------------ baselines
def random_placement(
    hg: Hypergraph, n: int, capacity: float, seed: int = 0, **_
) -> Placement:
    """Place every item once at random, then fill all remaining space with
    random replicas (the paper's Random baseline uses all available space).
    Deterministic for a given `seed` (single `default_rng` stream)."""
    rng = np.random.default_rng(seed)
    pl = Placement.empty(n, hg.num_nodes, capacity, hg.node_weights)
    loads = np.zeros(n, dtype=np.float64)
    for v in rng.permutation(hg.num_nodes):
        wv = hg.node_weights[v]
        ok = np.flatnonzero(loads + wv <= capacity)
        if len(ok) == 0:
            raise ValueError("random placement cannot fit items")
        p = int(rng.choice(ok))
        pl.member[p, v] = True
        loads[p] += wv
    # replicate randomly into leftover space
    order = rng.permutation(hg.num_nodes)
    for p in range(n):
        for v in order:
            if loads[p] + hg.node_weights[v] > capacity:
                continue
            if pl.member[p, v]:
                continue
            pl.member[p, v] = True
            loads[p] += hg.node_weights[v]
    return pl


def hpa_placement(
    hg: Hypergraph, n: int, capacity: float, seed: int = 0, nruns: int = 2, **_
) -> Placement:
    """Plain HPA into N_e partitions; no replication (extra partitions idle).

    This is the paper's no-replication baseline: its span does not improve as
    partitions are added (fig. 6a's flat line)."""
    ne = min_partitions(hg, capacity)
    assign = hpa_mod.partition(hg, ne, capacity, seed=seed, nruns=nruns)
    return _assign_to_placement(hg, assign, n, capacity)


# ----------------------------------------------------------- residual helpers
def _residual_edges(hg: Hypergraph, pl: Placement, min_span: int) -> np.ndarray:
    """Edge ids with span > min_span (pruneHypergraphBySpan keeps these)."""
    spans = batched_spans_csr(hg.edge_ptr, hg.edge_nodes, pl.member)
    return np.flatnonzero(spans > min_span)


# ------------------------------------------------------------ Algorithm 1: IHPA
def ihpa(
    hg: Hypergraph, n: int, capacity: float, seed: int = 0, nruns: int = 2, **_
) -> Placement:
    """Algorithm 1, Iterative HPA: partition, then repeatedly re-partition
    the residual hypergraph (edges with span > 1) into the spare partitions,
    replicating its items.

    Exactness/determinism: residual spans come from the batched engine via
    an incremental SpanMaintainer (bit-identical to per-edge greedy cover,
    ties -> lowest partition id); when the residual must shrink (§4.2),
    lowest-span hyperedges are dropped in stable ascending-span order, so
    repeated runs with one seed produce identical placements."""
    ne = min_partitions(hg, capacity)
    assign = hpa_mod.partition(hg, ne, capacity, seed=seed, nruns=nruns)
    pl = _assign_to_placement(hg, assign, n, capacity)
    spans = SpanMaintainer(hg, pl)  # incremental: only touched edges recompute
    used = ne
    round_ = 0
    while used < n:
        round_ += 1
        edge_ids = spans.residual_edges(1)
        if len(edge_ids) == 0:
            break
        resid = hg.subhypergraph_edges(edge_ids)
        resid, old_ids = resid.relabel()
        rem_parts = n - used
        rem_cap = rem_parts * capacity
        if resid.total_node_weight() > rem_cap:
            # §4.2 text: drop lowest-span hyperedges one at a time (these gain
            # least from replication) until the residual fits
            spans_r = batched_spans_csr(
                resid.edge_ptr, old_ids[resid.edge_nodes], pl.member
            )
            order = np.argsort(spans_r, kind="stable")  # ascending span
            pin_deg = np.bincount(resid.edge_nodes, minlength=resid.num_nodes)
            live_w = float(
                resid.node_weights[np.flatnonzero(pin_deg > 0)].sum()
            )
            keep_mask = np.ones(resid.num_edges, dtype=bool)
            for e in order:
                if live_w <= rem_cap:
                    break
                keep_mask[e] = False
                for u in resid.edge(int(e)):
                    pin_deg[u] -= 1
                    if pin_deg[u] == 0:
                        live_w -= float(resid.node_weights[u])
            resid = resid.subhypergraph_edges(np.flatnonzero(keep_mask))
            sub, sub_ids = resid.relabel()
            old_ids = old_ids[sub_ids]
            resid = sub
            if resid.num_edges == 0 or resid.num_nodes == 0:
                break
        n_new = min(rem_parts,
                    max(1, int(np.ceil(resid.total_node_weight() / capacity))))
        sub_assign = hpa_mod.partition(
            resid, n_new, capacity, seed=seed + round_, nruns=nruns
        )
        pl.member[used + sub_assign, old_ids] = True
        spans.notify_items(old_ids)
        used += n_new
    return pl


# -------------------------------------------------------------- Algorithm 2: DS
def ds(
    hg: Hypergraph, n: int, capacity: float, seed: int = 0, nruns: int = 2, **_
) -> Placement:
    """Algorithm 2, Dense-Subgraph based: fill each spare partition with the
    densest capacity-bounded node set of the current residual hypergraph.

    Exactness/determinism: the peel inside `k_densest_nodes` removes the
    lowest-degree node first, ties -> lowest node id (heap order), and
    residual spans come from the batched engine — repeated runs with one
    seed are bit-identical."""
    ne = min_partitions(hg, capacity)
    assign = hpa_mod.partition(hg, ne, capacity, seed=seed, nruns=nruns)
    pl = _assign_to_placement(hg, assign, n, capacity)
    spans = SpanMaintainer(hg, pl)
    used = ne
    while used < n:
        edge_ids = spans.residual_edges(1)
        if len(edge_ids) == 0:
            break
        resid = hg.subhypergraph_edges(edge_ids)
        dense_nodes = resid.k_densest_nodes(capacity)
        if len(dense_nodes) == 0:
            break
        pl.member[used, dense_nodes] = True
        spans.notify_items(dense_nodes)
        used += 1
    return pl


# ------------------------------------------------------------- Algorithm 3: PRA
def _hitting_set(sets: list[list[int]]) -> list[int]:
    """Greedy hitting set: repeatedly take the element in the most sets."""
    remaining = [set(s) for s in sets if s]
    hit: list[int] = []
    while remaining:
        counts: dict[int, int] = {}
        for s in remaining:
            for x in s:
                counts[x] = counts.get(x, 0) + 1
        best = max(counts.items(), key=lambda kv: (kv[1], -kv[0]))[0]
        hit.append(best)
        remaining = [s for s in remaining if best not in s]
    return hit


def pra(
    hg: Hypergraph, n: int, capacity: float, seed: int = 0, nruns: int = 2, **_
) -> Placement:
    """Algorithm 3, Pre-Replication: score items by how often they are the
    sole partition-local member of an edge, then clone high scorers across
    the partitions their edges must visit anyway (greedy hitting sets), and
    re-partition the rewired hypergraph.

    Exactness/determinism: scores accumulate in edge-major CSR order
    (matching the original per-edge loop); items are processed in stable
    descending-score order (ties -> lowest item id via stable argsort); the
    hitting-set greedy picks the most frequent element, ties -> LOWEST
    element id (`max` on (count, -id))."""
    ne = min_partitions(hg, capacity)
    assign = hpa_mod.partition(hg, ne, capacity, seed=seed, nruns=nruns)
    pl0 = _assign_to_placement(hg, assign, ne, capacity)

    # score_v = #edges where v is the only member of its partition (line 4):
    # a pin is "solo" iff its (edge, partition) pin-count is exactly 1.
    # CSR-vectorized; the bincount accumulates the same weights in the same
    # (edge-major) order as the original per-edge loop.
    score = np.zeros(hg.num_nodes, dtype=np.float64)
    if hg.num_pins:
        pin_edge = np.repeat(
            np.arange(hg.num_edges, dtype=np.int64), hg.edge_sizes()
        )
        pin_part = assign[hg.edge_nodes]
        cnt = np.zeros((hg.num_edges, ne), dtype=np.int32)
        np.add.at(cnt, (pin_edge, pin_part), 1)
        solo = cnt[pin_edge, pin_part] == 1
        score = np.bincount(
            hg.edge_nodes[solo],
            weights=hg.edge_weights[pin_edge[solo]],
            minlength=hg.num_nodes,
        )

    budget = n * capacity - hg.total_node_weight()  # spare replication room
    mutable = hg.copy_mutable()
    origins = list(range(hg.num_nodes))  # origins[new_id] = original item id
    node_ptr, node_edges = hg.incidence()
    order = np.argsort(-score, kind="stable")
    for v in order:
        if budget < hg.node_weights[v] or score[v] <= 0:
            continue
        ev = node_edges[node_ptr[v] : node_ptr[v + 1]]
        # spanning partitions of e \ {v}: the partitions each edge must visit
        # anyway for its *other* items — copies of v are anchored to those
        # (fig. 3: distribute copies so incident hyperedges entangle)
        span_sets = []
        for e in ev:
            others = hg.edge(int(e))
            others = others[others != v]
            span_sets.append(
                list(greedy_set_cover(others, pl0.member)) if len(others) else []
            )
        hit = _hitting_set(span_sets)
        if len(hit) <= 1:
            continue
        # original v serves the first hitting-set member; each further member
        # gets a fresh copy, and edges spanned by it are rewired to that copy
        copies = {hit[0]: int(v)}
        for g in hit[1:]:
            if budget < hg.node_weights[v]:
                break
            copies[g] = mutable.add_node_copy(int(v))
            origins.append(int(v))
            budget -= hg.node_weights[v]
        for e, spans in zip(ev, span_sets):
            for g in hit:
                if g in spans and g in copies:
                    mutable.replace_in_edge(int(e), int(v), copies[g])
                    break
    replicated = mutable.freeze()
    final_assign = hpa_mod.partition(
        replicated, n, capacity, seed=seed + 1, nruns=nruns
    )
    # map copies back onto original item ids
    pl = Placement.empty(n, hg.num_nodes, capacity, hg.node_weights)
    copy_origin = np.asarray(origins, dtype=np.int64)
    for new_v in range(replicated.num_nodes):
        pl.member[final_assign[new_v], copy_origin[new_v]] = True
    return pl


# ----------------------------------------------------- Algorithms 4+5: LMBR
class _LMBRState:
    """Live set-cover assignment: for each edge, the partitions in its cover
    and the items it reads from each (the 'improved' LMBR bookkeeping).

    Covers live in a SpanMaintainer (cover mode), so both the initial build
    and every move's invalidation run through the batched bitset engine —
    no per-edge greedy Python loops.  The partition <-> edge incidence is a
    boolean matrix ``_edge_mask[p, e]`` (True iff e's cover touches p), so
    ``shared_edges`` / ``union_edges`` are single AND/OR + flatnonzero ops
    and edge ids come out ascending by construction.  DETERMINISTIC-ORDER is
    the access contract: every downstream float accumulation and tie-break
    depends only on edge ids, never on Python set iteration order.

    Epoch-keyed gain cache
    ----------------------
    ``max_gain(src, dest)`` memoizes Algorithm 5's (gain, items) per ordered
    pair, stamped with three epochs it is a pure function of:

      * ``cov_epoch[p]``  — bumped by ``recompute_edges`` for every partition
        that gained or lost a pin attribution (the old and new serving
        partitions of every changed pin; a superset of all part_edges /
        cover-content changes, since both are functions of pin attribution);
      * ``mem_epoch[d]``  — bumped by ``apply_move`` when d's membership row
        (and hence its free space and the free-pin mask) changes.

    A cached (src, dest) entry is valid iff cov_epoch[src], cov_epoch[dest]
    and mem_epoch[dest] are all unchanged — then the recompute is skipped
    and the cached result is returned verbatim (bit-identical by purity).
    This collapses the O(N^2)-per-move rescan of Algorithm 4's refresh loop
    to the touched frontier: pairs whose covers, shared sets, and destination
    row did not change never re-peel.

    Mutation contract: membership changes MUST go through ``apply_move`` (or
    epochs go stale and the cache may serve outdated gains; direct
    ``pl.member`` writes are only safe with the cache unused)."""

    def __init__(self, hg: Hypergraph, pl: Placement):
        self.hg = hg
        self.pl = pl
        self.sm = SpanMaintainer(hg, pl, with_covers=True)
        n, E = pl.num_partitions, hg.num_edges
        self._edge_mask = np.zeros((n, E), dtype=bool)
        if E:
            counts = np.fromiter(
                (len(self.sm.chosen(e)) for e in range(E)), dtype=np.int64,
                count=E,
            )
            parts = (
                np.concatenate([self.sm.chosen(e) for e in range(E)])
                if counts.sum() else np.zeros(0, dtype=np.int64)
            )
            self._edge_mask[parts, np.repeat(np.arange(E), counts)] = True
        self.cov_epoch = np.zeros(n, dtype=np.int64)
        self.mem_epoch = np.zeros(n, dtype=np.int64)
        sizes = np.diff(hg.edge_ptr)
        self._esz_mean = float(sizes.mean()) if E else 0.0
        # pairwise shared-edge counts for the "auto" peel dispatch: built on
        # first use, then maintained by rank-k updates in recompute_edges
        self._shared_cnt: np.ndarray | None = None
        self._loads = pl.partition_weights()
        self._gain_cache: dict[tuple[int, int], tuple] = {}
        self.stats = dict(gain_calls=0, gain_cache_hits=0, moves=0)

    @property
    def part_edges(self) -> list[set[int]]:
        """Per-partition edge sets (compat view of the incidence mask)."""
        return [set(np.flatnonzero(row).tolist()) for row in self._edge_mask]

    def cover(self, e: int) -> dict[int, np.ndarray]:
        return self.sm.cover(e)

    def free_space(self, p: int) -> float:
        """Capacity headroom of p, tracked incrementally across moves
        (exact for integer item weights; for float weights it may differ
        from ``Placement.free_space`` in the last ulp — summation order)."""
        return self.pl.capacity - float(self._loads[p])

    def shared_edges(self, src: int, dest: int) -> list[int]:
        """Edges accessing both partitions, ascending edge id."""
        return np.flatnonzero(
            self._edge_mask[src] & self._edge_mask[dest]
        ).tolist()

    def union_edges(self, src: int, dest: int) -> np.ndarray:
        """Edges accessing either partition, ascending edge id."""
        return np.flatnonzero(self._edge_mask[src] | self._edge_mask[dest])

    def apply_move(self, dest: int, items: np.ndarray) -> None:
        """Copy `items` into partition dest (the only legal membership
        mutation): updates the load ledger and stamps dest's mem epoch."""
        self.pl.member[dest, items] = True
        self._loads[dest] += float(self.hg.node_weights[items].sum())
        self.mem_epoch[dest] += 1
        self.stats["moves"] += 1

    def recompute_edges(self, edges: np.ndarray) -> None:
        """Re-derive the covers of `edges` in ONE batched engine call
        (bit-identical to per-edge cover_for_query), resync the incidence
        mask, and stamp the cov epoch of every partition whose pin
        attribution changed."""
        edges = np.asarray(edges, dtype=np.int64)
        if not len(edges):
            return
        _, pidx = self.hg.pin_indices(edges)
        old_pp = self.sm.pin_parts[pidx].copy()
        old_sub = (
            self._edge_mask[:, edges].astype(np.int64)
            if self._shared_cnt is not None else None
        )
        self._edge_mask[:, edges] = False
        self.sm.refresh_edges(edges)
        new_pp = self.sm.pin_parts[pidx]
        counts = np.fromiter(
            (len(self.sm.chosen(int(e))) for e in edges), dtype=np.int64,
            count=len(edges),
        )
        parts = (
            np.concatenate([self.sm.chosen(int(e)) for e in edges])
            if counts.sum() else np.zeros(0, dtype=np.int64)
        )
        self._edge_mask[parts, np.repeat(edges, counts)] = True
        if old_sub is not None:
            new_sub = self._edge_mask[:, edges].astype(np.int64)
            self._shared_cnt += new_sub @ new_sub.T - old_sub @ old_sub.T
        changed = old_pp != new_pp
        if changed.any():
            touched = np.unique(
                np.concatenate([old_pp[changed], new_pp[changed]])
            )
            self.cov_epoch[touched] += 1

    def _stamp(self, key: tuple[int, int]) -> tuple[int, int, int]:
        """The epochs (gain of key) is a pure function of."""
        src, dest = key
        return (
            int(self.cov_epoch[src]), int(self.cov_epoch[dest]),
            int(self.mem_epoch[dest]),
        )

    def max_gain(self, src: int, dest: int):
        """Algorithm 5 through the epoch cache: recompute only when an epoch
        the pair depends on moved, else return the memoized (gain, items)."""
        return self.max_gain_many([(src, dest)])[(src, dest)]

    def _peel_width_bounds(self, pairs: list[tuple[int, int]]) -> np.ndarray:
        """Per-pair degree-matrix width estimate for the ``lmbr_peel="auto"``
        size dispatch: (shared-edge count) * (mean edge size).  The count
        matrix is built once (edge-mask Gram product) and then maintained by
        rank-k updates in `recompute_edges`, so each estimate is an O(1)
        lookup — the dispatch signal never costs O(E) per pair.  The signal
        only picks a backend; both backends are bit-identical."""
        if self._shared_cnt is None:
            m = self._edge_mask.astype(np.int64)
            self._shared_cnt = m @ m.T
        srcs = np.fromiter((s for s, _ in pairs), dtype=np.int64,
                           count=len(pairs))
        dests = np.fromiter((d for _, d in pairs), dtype=np.int64,
                            count=len(pairs))
        return self._shared_cnt[srcs, dests] * self._esz_mean

    def max_gain_many(self, pairs: list[tuple[int, int]]):
        """Epoch-cached batch gain evaluation.  Cache hits are answered from
        the memo; the misses run through ONE lockstep batched peel (or the
        pure-Python oracle pair-by-pair under ``lmbr_peel="reference"``;
        ``"auto"`` routes pairs whose degree-matrix width estimate is below
        ``flags.FLAGS["lmbr_peel_threshold"]`` to the oracle — on sparse
        near-span-1 workloads tiny peels beat the batch-array assembly —
        and batches the rest; all backends are bit-identical).
        Returns {pair: (gain, items)} covering every requested pair."""
        self.stats["gain_calls"] += len(pairs)
        use_cache = _flags.FLAGS.get("lmbr_gain_cache", True)
        out: dict[tuple[int, int], tuple] = {}
        misses: list[tuple[int, int]] = []
        pending: set[tuple[int, int]] = set()
        for key in pairs:
            if key in out or key in pending:
                continue
            if use_cache:
                hit = self._gain_cache.get(key)
                if hit is not None and hit[0] == self._stamp(key):
                    self.stats["gain_cache_hits"] += 1
                    out[key] = (hit[1], hit[2])
                    continue
            misses.append(key)
            pending.add(key)
        if misses:
            backend = _flags.FLAGS.get("lmbr_peel", "vector")
            if backend == "reference":
                computed = {
                    k: _lmbr_max_gain_reference(self, *k) for k in misses
                }
            elif backend == "auto":
                thresh = int(_flags.FLAGS.get("lmbr_peel_threshold", 256))
                bounds = self._peel_width_bounds(misses)
                computed = {
                    k: _lmbr_max_gain_reference(self, *k)
                    for k, b in zip(misses, bounds) if b < thresh
                }
                big = [k for k, b in zip(misses, bounds) if b >= thresh]
                if big:
                    computed.update(_lmbr_gain_batch(self, big))
            else:
                computed = _lmbr_gain_batch(self, misses)
            if use_cache:
                for k, v in computed.items():
                    self._gain_cache[k] = (self._stamp(k), *v)
            out.update(computed)
        return out

    def spans(self) -> np.ndarray:
        return self.sm.spans()


def _lmbr_max_gain_reference(state: _LMBRState, src: int, dest: int):
    """Algorithm 5: best group of items to copy src->dest and its gain
    (benefit per unit weight copied).  Returns (gain, items) or (0, None).

    Pure-Python peel, the executable specification (kept as the oracle the
    vectorized engine is tested against — `_LMBRState.max_gain_many`
    dispatches between the two on ``flags.FLAGS["lmbr_peel"]``; both are
    bit-identical: same densest subset, same gain float, same tie-breaks —
    ascending edge id in the projection scan, lowest item id on density
    ties — enforced by tests/test_lmbr_peel.py).

    Projection: for each edge accessing both partitions (ascending edge id),
    the items it reads from src that are NOT already on dest — items already
    resident on dest are free pins (cost 0, never peeled), the weighted
    generalization of the paper's getKDensestNodes accounting.  The peel
    then repeatedly removes the lowest-degree item (ties -> lowest item id)
    and records the best benefit/weight ratio among states that fit dest's
    free space."""
    hg, pl = state.hg, state.pl
    shared = state.shared_edges(src, dest)  # ascending edge id, deterministic
    if not shared:
        return 0.0, None
    c_dest = state.free_space(dest)
    if c_dest <= 1e-12:
        return 0.0, None
    node_w = hg.node_weights
    dest_row = pl.member[dest]
    # project: for each shared edge, the items it reads from src
    proj: list[tuple[float, list[int]]] = []  # (edge_weight, costly pins)
    total_benefit = 0.0
    for e in shared:
        items = state.cover(e).get(src)
        if items is None or not len(items):
            continue
        costly = [int(v) for v in items if not dest_row[v]]
        if not costly:
            continue  # free benefit is claimed lazily by recompute_edges
        we = float(hg.edge_weights[e])
        proj.append((we, costly))
        total_benefit += we
    if not proj:
        return 0.0, None
    inc: dict[int, list[int]] = {}
    for i, (_, pins) in enumerate(proj):
        for v in pins:
            inc.setdefault(v, []).append(i)
    deg = {v: 0.0 for v in inc}
    for i, (we, pins) in enumerate(proj):
        for v in pins:
            deg[v] += we
    alive_nodes = set(inc)
    alive_edge = [True] * len(proj)
    # accumulate in inc insertion order (first-encounter over the ascending
    # shared-edge scan) — never in set iteration order
    total_w = sum(float(node_w[v]) for v in inc)
    heap = [(d, v) for v, d in deg.items()]
    heapq.heapify(heap)
    best_gain, best_items = 0.0, None
    while total_benefit > 1e-12 and alive_nodes:
        if total_w <= c_dest + 1e-12:
            gain = total_benefit / max(total_w, 1e-12)
            if gain > best_gain:
                best_gain = gain
                best_items = list(alive_nodes)
        # peel the lowest-degree alive node
        while heap:
            d, v = heapq.heappop(heap)
            if v in alive_nodes and abs(d - deg[v]) < 1e-9:
                break
        else:
            break
        alive_nodes.discard(v)
        total_w -= float(node_w[v])
        for i in inc[v]:
            if alive_edge[i]:
                alive_edge[i] = False
                we, pins = proj[i]
                total_benefit -= we
                for u in pins:
                    if u != v and u in alive_nodes:
                        deg[u] -= we
                        heapq.heappush(heap, (deg[u], u))
    if best_items is None:
        return 0.0, None
    return best_gain, np.asarray(sorted(best_items), dtype=np.int64)


def _ranged_gather(lo: np.ndarray, hi: np.ndarray):
    """Flat indices of the concatenated ranges [lo_i, hi_i); also sizes."""
    sizes = hi - lo
    total = int(sizes.sum())
    if not total:
        return np.zeros(0, dtype=np.int64), sizes
    start = np.zeros(len(sizes) + 1, dtype=np.int64)
    np.cumsum(sizes, out=start[1:])
    idx = np.repeat(lo, sizes) + (
        np.arange(total, dtype=np.int64) - np.repeat(start[:-1], sizes)
    )
    return idx, sizes


def _lmbr_max_gain_vectorized(state: _LMBRState, src: int, dest: int):
    """Single-pair view of the batched peel (`_lmbr_gain_batch`)."""
    return _lmbr_gain_batch(state, [(src, dest)])[(src, dest)]


def _proj_entry(key, c_dest, bpins, bedges, node_w, edge_w):
    """One pair's peel inputs from its costly-pin subsequence.

    ``bpins``/``bedges`` hold the pair's costly pins in projection scan
    order — edges ascending, pins in edge order — exactly the sequence the
    pure-Python oracle iterates, so every left-fold below reproduces its
    float accumulations bit-for-bit."""
    first = np.concatenate([[True], bedges[1:] != bedges[:-1]])
    starts = np.flatnonzero(first)
    kept = bedges[starts]            # edges with >= 1 costly pin, ascending
    pin_cnt = np.diff(np.concatenate([starts, [len(bedges)]]))
    we = edge_w[kept].astype(np.float64)
    cedge = np.repeat(np.arange(len(kept), dtype=np.int64), pin_cnt)
    uniq, first_idx = np.unique(bpins, return_index=True)
    loc = np.searchsorted(uniq, bpins)
    # item pool weight: left-fold in first-encounter order, matching the
    # oracle's sequential sum over dict insertion order
    totw0 = float(np.cumsum(node_w[bpins[np.sort(first_idx)]])[-1])
    return (key, c_dest, we, uniq, loc, cedge, pin_cnt, totw0)


def _project_fan_in(state, dest, srcs, out, proj):
    """Project every (src, dest) pair of one destination in one pass: gather
    the pins of dest's covered edges once, drop the free ones (already on
    dest), and split the remainder into per-serving-partition blocks with a
    single stable argsort.  Each block is exactly the costly-pin sequence
    the per-pair projection would produce (edges ascending, pin order)."""
    hg, pl = state.hg, state.pl
    e_d = np.flatnonzero(state._edge_mask[dest])
    # span-1 edges live on dest alone: they are never shared with a source
    # and all their pins are resident (free), so drop them before gathering
    e_d = e_d[state.sm.spans()[e_d] > 1]
    c_dest = state.free_space(dest)
    if not len(e_d) or c_dest <= 1e-12:
        for s in srcs:
            out[(s, dest)] = (0.0, None)
        return
    ptr, pidx = hg.pin_indices(e_d)
    nodes = hg.edge_nodes[pidx]
    sp = state.sm.pin_parts[pidx]
    eids = np.repeat(e_d, np.diff(ptr))
    sel = np.flatnonzero(~pl.member[dest, nodes])  # costly pins only
    order = sel[np.argsort(sp[sel], kind="stable")]
    svals = sp[order]
    bstart = np.flatnonzero(
        np.concatenate([[True], svals[1:] != svals[:-1]])
    ) if len(order) else np.zeros(0, dtype=np.int64)
    bend = np.concatenate([bstart[1:], [len(order)]])
    lookup = {int(s): i for i, s in enumerate(svals[bstart])}
    for s in srcs:
        i = lookup.get(s)
        if i is None:  # no shared edge reads a costly item from s
            out[(s, dest)] = (0.0, None)
            continue
        block = order[bstart[i]: bend[i]]
        proj.append(_proj_entry(
            (s, dest), c_dest, nodes[block], eids[block],
            hg.node_weights, hg.edge_weights,
        ))


def _project_fan_out(state, src, dests, out, proj):
    """Project every (src, dest) pair of one source in one pass: gather the
    pins src serves once; each destination then masks that block to its
    shared edges and non-resident items (2 row gathers per pair)."""
    hg, pl = state.hg, state.pl
    e_s = np.flatnonzero(state._edge_mask[src])
    # span-1 edges live on src alone: never shared with any destination
    e_s = e_s[state.sm.spans()[e_s] > 1]
    if not len(e_s):
        for d in dests:
            out[(src, d)] = (0.0, None)
        return
    ptr, pidx = hg.pin_indices(e_s)
    nodes = hg.edge_nodes[pidx]
    served = np.flatnonzero(state.sm.pin_parts[pidx] == src)
    bpins = nodes[served]
    bedges = np.repeat(e_s, np.diff(ptr))[served]
    for d in dests:
        c_dest = state.free_space(d)
        if c_dest <= 1e-12:
            out[(src, d)] = (0.0, None)
            continue
        keep = state._edge_mask[d, bedges] & ~pl.member[d, bpins]
        if not keep.any():
            out[(src, d)] = (0.0, None)
            continue
        sub = np.flatnonzero(keep)
        proj.append(_proj_entry(
            (src, d), c_dest, bpins[sub], bedges[sub],
            hg.node_weights, hg.edge_weights,
        ))


def _lmbr_gain_batch(state: _LMBRState, pairs: list[tuple[int, int]]):
    """Batched Algorithm 5: evaluate MANY (src, dest) candidates in one
    lockstep peel.  Returns {(src, dest): (gain, items-or-None)}, each entry
    bit-identical to the pure-Python oracle run on that pair alone.

    Projection (per pair, flat): the pins of all shared edges are gathered
    once and masked to the costly ones — served by src per the maintainer's
    flat ``pin_parts`` attribution, and not already resident on dest (free
    pins cost 0 and are never peeled).  No per-edge cover dicts are built.

    Peel (all pairs in lockstep): pair-local items live in dense (G, Umax)
    matrices (degree, alive, weight), edges in flat CSR arrays.  Each round
    peels one item from every still-active pair: a single row-wise
    ``argmin`` picks each pair's lowest-degree item (+inf padding; ties ->
    lowest item id because columns are sorted by item id), and scatter-adds
    (``np.add.at`` — sequential over its index arrays) retire dying edges
    and their degree contributions in the oracle's exact accumulation order
    (edges ascending within a pair, pins in edge order).  Pairs drop out of
    the round set when their remaining benefit or item pool is exhausted.
    Because every pair's float-op sequence is unchanged from its solo run,
    lockstep execution cannot perturb results — same subsets, same gain
    floats, even under adversarial near-ties."""
    hg = state.hg
    node_w = hg.node_weights
    out: dict[tuple[int, int], tuple] = {}
    proj = []  # (key, c_dest, we, uniq, loc, cedge, pin_cnt, totw0)
    # shared-projection grouping: fan-in pairs (*, d) reuse one gather of
    # d's covered edges (blocks split by serving partition); the rest group
    # by src, reusing one gather of src's served pins across destinations
    by_dest: dict[int, list[int]] = {}
    for s, d in pairs:
        by_dest.setdefault(d, []).append(s)
    by_src: dict[int, list[int]] = {}
    for d, srcs in by_dest.items():
        if len(srcs) >= 2:
            _project_fan_in(state, d, srcs, out, proj)
        else:
            by_src.setdefault(srcs[0], []).append(d)
    for s, dests in by_src.items():
        _project_fan_out(state, s, dests, out, proj)
    if not proj:
        return out

    # ---- flat batch assembly
    G = len(proj)
    U = np.array([len(p[3]) for p in proj], dtype=np.int64)
    K = np.array([len(p[2]) for p in proj], dtype=np.int64)
    Umax = int(U.max())
    ebase = np.zeros(G + 1, dtype=np.int64)
    np.cumsum(K, out=ebase[1:])
    we_flat = np.concatenate([p[2] for p in proj])
    pair_of_edge = np.repeat(np.arange(G, dtype=np.int64), K)
    # edge -> costly pins CSR (pins are pair-major, edge-major, pin order)
    pin_cnt_flat = np.concatenate([p[6] for p in proj])
    eptr = np.zeros(int(ebase[-1]) + 1, dtype=np.int64)
    np.cumsum(pin_cnt_flat, out=eptr[1:])
    pin_col = np.concatenate([p[4] for p in proj])
    pin_edge = np.concatenate(
        [p[5] + ebase[i] for i, p in enumerate(proj)]
    )
    pin_row = pair_of_edge[pin_edge]
    # item slot (pair, col) -> incident kept edges, ascending scan order
    inc_edges = np.concatenate([
        (p[5] + ebase[i])[np.argsort(p[4], kind="stable")]
        for i, p in enumerate(proj)
    ])
    inc_cnt = np.zeros((G, Umax), dtype=np.int64)
    for i, p in enumerate(proj):
        inc_cnt[i, : U[i]] = np.bincount(p[4], minlength=U[i])
    inc_ptr = np.zeros(G * Umax + 1, dtype=np.int64)
    np.cumsum(inc_cnt.ravel(), out=inc_ptr[1:])
    # dense per-item state: +inf padding so argmin never picks a pad slot
    valid = np.arange(Umax, dtype=np.int64)[None, :] < U[:, None]
    cand = np.full((G, Umax), np.inf, dtype=np.float64)
    cand[valid] = 0.0
    # degrees accumulate in the oracle's scan order (np.add.at is
    # sequential over its index arrays), bit-for-bit the dict loop
    np.add.at(cand, (pin_row, pin_col), we_flat[pin_edge])
    alive = valid.copy()
    nodew = np.zeros((G, Umax), dtype=np.float64)
    nodew[valid] = np.concatenate([node_w[p[3]] for p in proj])
    # left-fold cumsum == the oracle's sequential `total_benefit += we`
    benefit = np.array(
        [float(np.cumsum(p[2])[-1]) for p in proj], dtype=np.float64
    )
    totw = np.array([p[7] for p in proj], dtype=np.float64)
    c_arr = np.array([p[1] for p in proj], dtype=np.float64)
    n_alive = U.copy()
    edge_alive = np.ones(int(ebase[-1]), dtype=bool)
    best_gain = np.zeros(G, dtype=np.float64)
    best_set = np.zeros((G, Umax), dtype=bool)
    has_best = np.zeros(G, dtype=bool)

    # ---- lockstep weighted peel (getKDensestNodes, Asahiro-style greedy)
    act = np.flatnonzero((benefit > 1e-12) & (n_alive > 0))
    while len(act):
        # record states that fit the destination's free space
        t = totw[act]
        fits = t <= c_arr[act] + 1e-12
        if fits.any():
            rows = act[fits]
            g = benefit[rows] / np.maximum(t[fits], 1e-12)
            imp = g > best_gain[rows]
            if imp.any():
                r2 = rows[imp]
                best_gain[r2] = g[imp]
                best_set[r2] = alive[r2]
                has_best[r2] = True
        # peel each active pair's lowest-degree item (ties -> lowest id)
        j = np.argmin(cand[act], axis=1)
        alive[act, j] = False
        cand[act, j] = np.inf
        n_alive[act] -= 1
        totw[act] -= nodew[act, j]
        # retire this round's dying edges (ascending within each pair)
        slot = act * Umax + j
        idx, _ = _ranged_gather(inc_ptr[slot], inc_ptr[slot + 1])
        cand_e = inc_edges[idx]
        de = cand_e[edge_alive[cand_e]]
        if len(de):
            edge_alive[de] = False
            np.add.at(benefit, pair_of_edge[de], -we_flat[de])
            pidx2, dsz = _ranged_gather(eptr[de], eptr[de + 1])
            cols = pin_col[pidx2]
            rows_t = np.repeat(pair_of_edge[de], dsz)
            wrep = np.repeat(we_flat[de], dsz)
            lv = alive[rows_t, cols]     # dead items never re-compared
            np.add.at(cand, (rows_t[lv], cols[lv]), -wrep[lv])
        act = act[(benefit[act] > 1e-12) & (n_alive[act] > 0)]

    for i, p in enumerate(proj):
        if has_best[i]:
            out[p[0]] = (float(best_gain[i]), p[3][best_set[i, : U[i]]])
        else:
            out[p[0]] = (0.0, None)
    return out


def lmbr(
    hg: Hypergraph,
    n: int,
    capacity: float,
    seed: int = 0,
    nruns: int = 2,
    max_moves: int | None = None,
    initial: Placement | None = None,
    dest_mask: np.ndarray | None = None,
    **_,
) -> Placement:
    """Improved LMBR (Algorithm 4 + Algorithm 5).

    `initial` warm-starts from an existing placement (incremental refits and
    the paper's use of LMBR as a capacity-fixup subroutine).

    `dest_mask` (optional, (n,) bool) restricts which partitions may RECEIVE
    copies: pairs with a masked destination are never evaluated or pushed.
    Sources are unrestricted — a masked partition that serves no covers
    (e.g. a failed partition whose membership row is zeroed) simply yields
    no gain.  An all-True mask is bit-identical to no mask; this is how
    online drift refits keep adapting during an outage (down rows masked)
    without ever copying data onto dead partitions.

    Determinism contract: moves are applied in descending-gain order from a
    heap whose entries tie-break on (src, dest, version); candidate subsets
    come from the Algorithm 5 peel (ascending edge id in the projection,
    lowest item id on density ties), so repeated runs produce bit-identical
    placements regardless of peel backend (``flags.FLAGS["lmbr_peel"]``) or
    gain-cache setting (``flags.FLAGS["lmbr_gain_cache"]``).  The fitted
    ``Placement`` carries the move-engine counters in ``.stats`` (moves,
    gain_calls, gain_cache_hits, peel backend)."""
    if initial is not None:
        pl = Placement(
            initial.member.copy(), capacity, hg.node_weights
        )
    else:
        # Algorithm 4 line 1: balanced N-way start (hMETIS's UBfactor formula
        # allows only ~(C*N-total)/total slack, i.e. near-balance); the spare
        # capacity in every partition is the replication budget for the moves
        bal_cap = min(
            capacity,
            hg.total_node_weight() / n * 1.1 + float(hg.node_weights.max()),
        )
        assign = hpa_mod.partition(hg, n, bal_cap, seed=seed, nruns=nruns)
        pl = _assign_to_placement(hg, assign, n, capacity)
    state = _LMBRState(hg, pl)
    if max_moves is None:
        max_moves = 50 * n
    if dest_mask is None:
        dest_ok = np.ones(n, dtype=bool)
    else:
        dest_ok = np.asarray(dest_mask, dtype=bool)
        if dest_ok.shape != (n,):
            raise ValueError(f"dest_mask must be ({n},) bool")

    # priority queue of (-gain, src, dest, version)
    version = np.zeros((n, n), dtype=np.int64)
    pq: list[tuple[float, int, int, int]] = []

    def push_many(pairlist: list[tuple[int, int]]):
        # one batched (epoch-cached) gain evaluation for the whole refresh
        # set; heap-entry content is insertion-order independent, so this is
        # behaviorally identical to pushing pair-by-pair
        results = state.max_gain_many(pairlist)
        for s, d in pairlist:
            gain, items = results[(s, d)]
            version[s, d] += 1
            if gain > 0 and items is not None:
                heapq.heappush(pq, (-gain, s, d, int(version[s, d])))

    push_many([(s, d) for s in range(n) for d in range(n)
               if s != d and dest_ok[d]])

    moves = 0
    while pq and moves < max_moves:
        neg_gain, src, dest, ver = heapq.heappop(pq)
        if ver != version[src, dest]:
            continue  # stale entry
        gain, items = state.max_gain(src, dest)  # re-verify vs live state
        if items is None or gain <= 0:
            continue
        w = hg.node_weights[items].sum()
        if w > state.free_space(dest) + 1e-9:
            push_many([(src, dest)])
            continue
        # apply the move: copy items into dest
        state.apply_move(dest, items)
        moves += 1
        # recompute covers of edges that might benefit (those accessing src
        # or dest and touching a moved item) — ONE batched engine call over
        # the ascending-id affected set; per-edge covers are independent, so
        # refresh order cannot influence results.
        cand_arr = state.union_edges(src, dest)
        if len(cand_arr):
            ptr, nodes_ = hg.edges_csr(cand_arr)
            hit = np.isin(nodes_, items)
            ch = np.concatenate([[0], np.cumsum(hit)])
            touches = ch[ptr[1:]] > ch[ptr[:-1]]
            state.recompute_edges(cand_arr[touches])
        # refresh PQ entries involving dest (Algorithm 4 lines 12-15)
        pairs: list[tuple[int, int]] = []
        for g in range(n):
            if g != dest:
                pairs.append((g, dest))
                if dest_ok[g]:
                    pairs.append((dest, g))
        pairs.append((src, dest))
        push_many(pairs)
    pl.stats = dict(
        state.stats, peel=_flags.FLAGS.get("lmbr_peel", "vector"),
        gain_cache=bool(_flags.FLAGS.get("lmbr_gain_cache", True)),
    )
    return pl


ALGORITHMS: dict[str, Callable[..., Placement]] = {
    "random": random_placement,
    "hpa": hpa_placement,
    "ihpa": ihpa,
    "ds": ds,
    "pra": pra,
    "lmbr": lmbr,
}
