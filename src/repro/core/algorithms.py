"""Data placement algorithms with replication (paper §4).

Implemented faithfully from the paper's pseudocode:

  * random_placement — Random baseline (replicate & distribute randomly)
  * hpa_placement    — HPA baseline, no replication (straight line in fig. 6)
  * ihpa             — Algorithm 1, Iterative HPA
  * ds               — Algorithm 2, Dense-Subgraph based
  * pra              — Algorithm 3, Pre-Replication via hitting sets
  * lmbr             — Algorithms 4+5, improved Local-Move-Based Replication

All return a `Placement` (membership matrix), on which spans are computed by
greedy set cover (replica selection).
"""

from __future__ import annotations

import heapq
from typing import Callable

import numpy as np

from . import hpa as hpa_mod
from .hypergraph import Hypergraph
from .setcover import (
    Placement,
    SpanMaintainer,
    batched_spans_csr,
    greedy_set_cover,
)

__all__ = [
    "random_placement", "hpa_placement", "ihpa", "ds", "pra", "lmbr",
    "min_partitions", "ALGORITHMS",
]


def min_partitions(hg: Hypergraph, capacity: float) -> int:
    """N_e = ceil(total item weight / C)."""
    return int(np.ceil(hg.total_node_weight() / capacity - 1e-9))


def _assign_to_placement(
    hg: Hypergraph, assign: np.ndarray, num_partitions: int, capacity: float
) -> Placement:
    pl = Placement.empty(num_partitions, hg.num_nodes, capacity, hg.node_weights)
    for v in range(hg.num_nodes):
        pl.member[assign[v], v] = True
    return pl


# ------------------------------------------------------------------ baselines
def random_placement(
    hg: Hypergraph, n: int, capacity: float, seed: int = 0, **_
) -> Placement:
    """Place every item once at random, then fill all remaining space with
    random replicas (the paper's Random baseline uses all available space)."""
    rng = np.random.default_rng(seed)
    pl = Placement.empty(n, hg.num_nodes, capacity, hg.node_weights)
    loads = np.zeros(n, dtype=np.float64)
    for v in rng.permutation(hg.num_nodes):
        wv = hg.node_weights[v]
        ok = np.flatnonzero(loads + wv <= capacity)
        if len(ok) == 0:
            raise ValueError("random placement cannot fit items")
        p = int(rng.choice(ok))
        pl.member[p, v] = True
        loads[p] += wv
    # replicate randomly into leftover space
    order = rng.permutation(hg.num_nodes)
    for p in range(n):
        for v in order:
            if loads[p] + hg.node_weights[v] > capacity:
                continue
            if pl.member[p, v]:
                continue
            pl.member[p, v] = True
            loads[p] += hg.node_weights[v]
    return pl


def hpa_placement(
    hg: Hypergraph, n: int, capacity: float, seed: int = 0, nruns: int = 2, **_
) -> Placement:
    """Plain HPA into N_e partitions; no replication (extra partitions idle).

    This is the paper's no-replication baseline: its span does not improve as
    partitions are added (fig. 6a's flat line)."""
    ne = min_partitions(hg, capacity)
    assign = hpa_mod.partition(hg, ne, capacity, seed=seed, nruns=nruns)
    return _assign_to_placement(hg, assign, n, capacity)


# ----------------------------------------------------------- residual helpers
def _residual_edges(hg: Hypergraph, pl: Placement, min_span: int) -> np.ndarray:
    """Edge ids with span > min_span (pruneHypergraphBySpan keeps these)."""
    spans = batched_spans_csr(hg.edge_ptr, hg.edge_nodes, pl.member)
    return np.flatnonzero(spans > min_span)


# ------------------------------------------------------------ Algorithm 1: IHPA
def ihpa(
    hg: Hypergraph, n: int, capacity: float, seed: int = 0, nruns: int = 2, **_
) -> Placement:
    ne = min_partitions(hg, capacity)
    assign = hpa_mod.partition(hg, ne, capacity, seed=seed, nruns=nruns)
    pl = _assign_to_placement(hg, assign, n, capacity)
    spans = SpanMaintainer(hg, pl)  # incremental: only touched edges recompute
    used = ne
    round_ = 0
    while used < n:
        round_ += 1
        edge_ids = spans.residual_edges(1)
        if len(edge_ids) == 0:
            break
        resid = hg.subhypergraph_edges(edge_ids)
        resid, old_ids = resid.relabel()
        rem_parts = n - used
        rem_cap = rem_parts * capacity
        if resid.total_node_weight() > rem_cap:
            # §4.2 text: drop lowest-span hyperedges one at a time (these gain
            # least from replication) until the residual fits
            spans_r = batched_spans_csr(
                resid.edge_ptr, old_ids[resid.edge_nodes], pl.member
            )
            order = np.argsort(spans_r, kind="stable")  # ascending span
            pin_deg = np.bincount(resid.edge_nodes, minlength=resid.num_nodes)
            live_w = float(
                resid.node_weights[np.flatnonzero(pin_deg > 0)].sum()
            )
            keep_mask = np.ones(resid.num_edges, dtype=bool)
            for e in order:
                if live_w <= rem_cap:
                    break
                keep_mask[e] = False
                for u in resid.edge(int(e)):
                    pin_deg[u] -= 1
                    if pin_deg[u] == 0:
                        live_w -= float(resid.node_weights[u])
            resid = resid.subhypergraph_edges(np.flatnonzero(keep_mask))
            sub, sub_ids = resid.relabel()
            old_ids = old_ids[sub_ids]
            resid = sub
            if resid.num_edges == 0 or resid.num_nodes == 0:
                break
        n_new = min(rem_parts,
                    max(1, int(np.ceil(resid.total_node_weight() / capacity))))
        sub_assign = hpa_mod.partition(
            resid, n_new, capacity, seed=seed + round_, nruns=nruns
        )
        pl.member[used + sub_assign, old_ids] = True
        spans.notify_items(old_ids)
        used += n_new
    return pl


# -------------------------------------------------------------- Algorithm 2: DS
def ds(
    hg: Hypergraph, n: int, capacity: float, seed: int = 0, nruns: int = 2, **_
) -> Placement:
    ne = min_partitions(hg, capacity)
    assign = hpa_mod.partition(hg, ne, capacity, seed=seed, nruns=nruns)
    pl = _assign_to_placement(hg, assign, n, capacity)
    spans = SpanMaintainer(hg, pl)
    used = ne
    while used < n:
        edge_ids = spans.residual_edges(1)
        if len(edge_ids) == 0:
            break
        resid = hg.subhypergraph_edges(edge_ids)
        dense_nodes = resid.k_densest_nodes(capacity)
        if len(dense_nodes) == 0:
            break
        pl.member[used, dense_nodes] = True
        spans.notify_items(dense_nodes)
        used += 1
    return pl


# ------------------------------------------------------------- Algorithm 3: PRA
def _hitting_set(sets: list[list[int]]) -> list[int]:
    """Greedy hitting set: repeatedly take the element in the most sets."""
    remaining = [set(s) for s in sets if s]
    hit: list[int] = []
    while remaining:
        counts: dict[int, int] = {}
        for s in remaining:
            for x in s:
                counts[x] = counts.get(x, 0) + 1
        best = max(counts.items(), key=lambda kv: (kv[1], -kv[0]))[0]
        hit.append(best)
        remaining = [s for s in remaining if best not in s]
    return hit


def pra(
    hg: Hypergraph, n: int, capacity: float, seed: int = 0, nruns: int = 2, **_
) -> Placement:
    ne = min_partitions(hg, capacity)
    assign = hpa_mod.partition(hg, ne, capacity, seed=seed, nruns=nruns)
    pl0 = _assign_to_placement(hg, assign, ne, capacity)

    # score_v = #edges where v is the only member of its partition (line 4):
    # a pin is "solo" iff its (edge, partition) pin-count is exactly 1.
    # CSR-vectorized; the bincount accumulates the same weights in the same
    # (edge-major) order as the original per-edge loop.
    score = np.zeros(hg.num_nodes, dtype=np.float64)
    if hg.num_pins:
        pin_edge = np.repeat(
            np.arange(hg.num_edges, dtype=np.int64), hg.edge_sizes()
        )
        pin_part = assign[hg.edge_nodes]
        cnt = np.zeros((hg.num_edges, ne), dtype=np.int32)
        np.add.at(cnt, (pin_edge, pin_part), 1)
        solo = cnt[pin_edge, pin_part] == 1
        score = np.bincount(
            hg.edge_nodes[solo],
            weights=hg.edge_weights[pin_edge[solo]],
            minlength=hg.num_nodes,
        )

    budget = n * capacity - hg.total_node_weight()  # spare replication room
    mutable = hg.copy_mutable()
    origins = list(range(hg.num_nodes))  # origins[new_id] = original item id
    node_ptr, node_edges = hg.incidence()
    order = np.argsort(-score, kind="stable")
    for v in order:
        if budget < hg.node_weights[v] or score[v] <= 0:
            continue
        ev = node_edges[node_ptr[v] : node_ptr[v + 1]]
        # spanning partitions of e \ {v}: the partitions each edge must visit
        # anyway for its *other* items — copies of v are anchored to those
        # (fig. 3: distribute copies so incident hyperedges entangle)
        span_sets = []
        for e in ev:
            others = hg.edge(int(e))
            others = others[others != v]
            span_sets.append(
                list(greedy_set_cover(others, pl0.member)) if len(others) else []
            )
        hit = _hitting_set(span_sets)
        if len(hit) <= 1:
            continue
        # original v serves the first hitting-set member; each further member
        # gets a fresh copy, and edges spanned by it are rewired to that copy
        copies = {hit[0]: int(v)}
        for g in hit[1:]:
            if budget < hg.node_weights[v]:
                break
            copies[g] = mutable.add_node_copy(int(v))
            origins.append(int(v))
            budget -= hg.node_weights[v]
        for e, spans in zip(ev, span_sets):
            for g in hit:
                if g in spans and g in copies:
                    mutable.replace_in_edge(int(e), int(v), copies[g])
                    break
    replicated = mutable.freeze()
    final_assign = hpa_mod.partition(
        replicated, n, capacity, seed=seed + 1, nruns=nruns
    )
    # map copies back onto original item ids
    pl = Placement.empty(n, hg.num_nodes, capacity, hg.node_weights)
    copy_origin = np.asarray(origins, dtype=np.int64)
    for new_v in range(replicated.num_nodes):
        pl.member[final_assign[new_v], copy_origin[new_v]] = True
    return pl


# ----------------------------------------------------- Algorithms 4+5: LMBR
class _LMBRState:
    """Live set-cover assignment: for each edge, the partitions in its cover
    and the items it reads from each (the 'improved' LMBR bookkeeping).

    Covers live in a SpanMaintainer (cover mode), so both the initial build
    and every move's invalidation run through the batched bitset engine —
    no per-edge greedy Python loops.  `part_edges[p]` (the edges whose cover
    touches partition p) is held as a set, but DETERMINISTIC-ORDER is the
    access contract: consumers never iterate raw sets, they go through
    `shared_edges` / `union_edges`, which return edge ids ascending.  Every
    downstream float accumulation and tie-break therefore depends only on
    edge ids, not on Python set iteration order."""

    def __init__(self, hg: Hypergraph, pl: Placement):
        self.hg = hg
        self.pl = pl
        self.sm = SpanMaintainer(hg, pl, with_covers=True)
        self.part_edges: list[set[int]] = [set() for _ in range(pl.num_partitions)]
        for e in range(hg.num_edges):
            for p in self.sm.cover(e):
                self.part_edges[p].add(e)

    def cover(self, e: int) -> dict[int, np.ndarray]:
        return self.sm.cover(e)

    def shared_edges(self, src: int, dest: int) -> list[int]:
        """Edges accessing both partitions, ascending edge id."""
        return sorted(self.part_edges[src] & self.part_edges[dest])

    def union_edges(self, src: int, dest: int) -> np.ndarray:
        """Edges accessing either partition, ascending edge id."""
        return np.fromiter(
            sorted(self.part_edges[src] | self.part_edges[dest]),
            dtype=np.int64,
        )

    def recompute_edges(self, edges: np.ndarray) -> None:
        """Re-derive the covers of `edges` in ONE batched engine call
        (bit-identical to per-edge cover_for_query) and resync part_edges."""
        for e in edges:
            e = int(e)
            for p in self.sm.cover(e):
                self.part_edges[p].discard(e)
        self.sm.refresh_edges(edges)
        for e in edges:
            e = int(e)
            for p in self.sm.cover(e):
                self.part_edges[p].add(e)

    def spans(self) -> np.ndarray:
        return self.sm.spans()


def _lmbr_max_gain(state: _LMBRState, src: int, dest: int):
    """Algorithm 5: best group of items to copy src->dest and its gain
    (benefit per unit weight copied).  Returns (gain, items) or (0, None).

    Pure-Python peeling (no Hypergraph construction): this is LMBR's inner
    loop, called O(N^2) times per move.  Items already resident on dest are
    free pins (cost 0, never peeled) — the weighted generalization of the
    paper's getKDensestNodes accounting."""
    hg, pl = state.hg, state.pl
    shared = state.shared_edges(src, dest)  # ascending edge id, deterministic
    if not shared:
        return 0.0, None
    c_dest = pl.free_space(dest)
    if c_dest <= 1e-12:
        return 0.0, None
    node_w = hg.node_weights
    dest_row = pl.member[dest]
    # project: for each shared edge, the items it reads from src
    proj: list[tuple[float, list[int]]] = []  # (edge_weight, costly pins)
    total_benefit = 0.0
    for e in shared:
        items = state.cover(e).get(src)
        if items is None or not len(items):
            continue
        costly = [int(v) for v in items if not dest_row[v]]
        if not costly:
            continue  # free benefit is claimed lazily by recompute_edges
        we = float(hg.edge_weights[e])
        proj.append((we, costly))
        total_benefit += we
    if not proj:
        return 0.0, None
    inc: dict[int, list[int]] = {}
    for i, (_, pins) in enumerate(proj):
        for v in pins:
            inc.setdefault(v, []).append(i)
    deg = {v: 0.0 for v in inc}
    for i, (we, pins) in enumerate(proj):
        for v in pins:
            deg[v] += we
    alive_nodes = set(inc)
    alive_edge = [True] * len(proj)
    # accumulate in inc insertion order (first-encounter over the ascending
    # shared-edge scan) — never in set iteration order
    total_w = sum(float(node_w[v]) for v in inc)
    heap = [(d, v) for v, d in deg.items()]
    heapq.heapify(heap)
    best_gain, best_items = 0.0, None
    while total_benefit > 1e-12 and alive_nodes:
        if total_w <= c_dest + 1e-12:
            gain = total_benefit / max(total_w, 1e-12)
            if gain > best_gain:
                best_gain = gain
                best_items = list(alive_nodes)
        # peel the lowest-degree alive node
        while heap:
            d, v = heapq.heappop(heap)
            if v in alive_nodes and abs(d - deg[v]) < 1e-9:
                break
        else:
            break
        alive_nodes.discard(v)
        total_w -= float(node_w[v])
        for i in inc[v]:
            if alive_edge[i]:
                alive_edge[i] = False
                we, pins = proj[i]
                total_benefit -= we
                for u in pins:
                    if u != v and u in alive_nodes:
                        deg[u] -= we
                        heapq.heappush(heap, (deg[u], u))
    if best_items is None:
        return 0.0, None
    return best_gain, np.asarray(sorted(best_items), dtype=np.int64)


def lmbr(
    hg: Hypergraph,
    n: int,
    capacity: float,
    seed: int = 0,
    nruns: int = 2,
    max_moves: int | None = None,
    initial: Placement | None = None,
    **_,
) -> Placement:
    """Improved LMBR (Algorithm 4 + Algorithm 5).

    `initial` warm-starts from an existing placement (incremental refits and
    the paper's use of LMBR as a capacity-fixup subroutine)."""
    if initial is not None:
        pl = Placement(
            initial.member.copy(), capacity, hg.node_weights
        )
    else:
        # Algorithm 4 line 1: balanced N-way start (hMETIS's UBfactor formula
        # allows only ~(C*N-total)/total slack, i.e. near-balance); the spare
        # capacity in every partition is the replication budget for the moves
        bal_cap = min(
            capacity,
            hg.total_node_weight() / n * 1.1 + float(hg.node_weights.max()),
        )
        assign = hpa_mod.partition(hg, n, bal_cap, seed=seed, nruns=nruns)
        pl = _assign_to_placement(hg, assign, n, capacity)
    state = _LMBRState(hg, pl)
    if max_moves is None:
        max_moves = 50 * n

    # priority queue of (-gain, src, dest, version)
    version = np.zeros((n, n), dtype=np.int64)
    pq: list[tuple[float, int, int, int]] = []

    def push(src: int, dest: int):
        gain, items = _lmbr_max_gain(state, src, dest)
        version[src, dest] += 1
        if gain > 0 and items is not None:
            heapq.heappush(pq, (-gain, src, dest, int(version[src, dest])))

    for src in range(n):
        for dest in range(n):
            if src != dest:
                push(src, dest)

    moves = 0
    while pq and moves < max_moves:
        neg_gain, src, dest, ver = heapq.heappop(pq)
        if ver != version[src, dest]:
            continue  # stale entry
        gain, items = _lmbr_max_gain(state, src, dest)  # re-verify vs live state
        if items is None or gain <= 0:
            continue
        w = hg.node_weights[items].sum()
        if w > pl.free_space(dest) + 1e-9:
            push(src, dest)
            continue
        # apply the move: copy items into dest
        pl.member[dest, items] = True
        moves += 1
        # recompute covers of edges that might benefit (those accessing src
        # or dest and touching a moved item) — ONE batched engine call over
        # the ascending-id affected set; per-edge covers are independent, so
        # refresh order cannot influence results.
        cand_arr = state.union_edges(src, dest)
        if len(cand_arr):
            ptr, nodes_ = hg.edges_csr(cand_arr)
            hit = np.isin(nodes_, items)
            ch = np.concatenate([[0], np.cumsum(hit)])
            touches = ch[ptr[1:]] > ch[ptr[:-1]]
            state.recompute_edges(cand_arr[touches])
        # refresh PQ entries involving dest (Algorithm 4 lines 12-15)
        for g in range(n):
            if g != dest:
                push(g, dest)
                push(dest, g)
        push(src, dest)
    return pl


ALGORITHMS: dict[str, Callable[..., Placement]] = {
    "random": random_placement,
    "hpa": hpa_placement,
    "ihpa": ihpa,
    "ds": ds,
    "pra": pra,
    "lmbr": lmbr,
}
