"""Data placement algorithms with replication (paper §4).

Implemented faithfully from the paper's pseudocode:

  * random_placement — Random baseline (replicate & distribute randomly)
  * hpa_placement    — HPA baseline, no replication (straight line in fig. 6)
  * ihpa             — Algorithm 1, Iterative HPA
  * ds               — Algorithm 2, Dense-Subgraph based
  * pra              — Algorithm 3, Pre-Replication via hitting sets
  * lmbr             — Algorithms 4+5, improved Local-Move-Based Replication

All return a `Placement` (membership matrix), on which spans are computed by
greedy set cover (replica selection).
"""

from __future__ import annotations

import heapq
import time
from typing import Callable

import numpy as np

from .. import flags as _flags
from .. import obs as _obs
from . import hpa as hpa_mod
from .cluster import capacity_vector, normalize_capacity
from .hypergraph import Hypergraph
from .setcover import (
    Placement,
    SpanMaintainer,
    _accel_backend,
    batched_spans_csr,
    engine_counters,
    greedy_set_cover,
)

__all__ = [
    "random_placement", "hpa_placement", "ihpa", "ds", "pra", "lmbr",
    "min_partitions", "ALGORITHMS",
]


def min_partitions(hg: Hypergraph, capacity) -> int:
    """N_e = ceil(total item weight / C): the minimum number of partitions
    that can hold one copy of every item (exact up to the 1e-9 guard against
    float round-up on integer-weight workloads).  For a heterogeneous
    capacity vector, the count of largest-capacity partitions whose sum
    holds the total."""
    total = hg.total_node_weight()
    if isinstance(capacity, np.ndarray) and capacity.ndim:
        caps = np.sort(np.asarray(capacity, dtype=np.float64))[::-1]
        cum = np.cumsum(caps)
        k = int(np.searchsorted(cum, total - 1e-9)) + 1
        return min(k, len(caps))
    return int(np.ceil(total / capacity - 1e-9))


def _is_cap_vec(capacity) -> bool:
    return isinstance(capacity, np.ndarray) and capacity.ndim


def _cap_at(capacity, p: int):
    """Capacity of partition p: the scalar itself (unchanged object — the
    bit-identity path) or the vector entry."""
    return float(capacity[p]) if _is_cap_vec(capacity) else capacity


def _cap_slice(capacity, lo: int, hi: int):
    """Capacity restricted to partitions [lo, hi): scalar passes through;
    uniform vector slices collapse back to the scalar path."""
    return normalize_capacity(capacity[lo:hi]) if _is_cap_vec(capacity) \
        else capacity


def _base_partitions(hg: Hypergraph, capacity) -> int:
    """Rows [0, ne) for the base no-replication fit.  Scalar capacities use
    `min_partitions`; a heterogeneous vector takes the shortest PREFIX of
    rows whose capacities hold one copy of everything, because the base
    fits always fill rows in ascending id order."""
    if _is_cap_vec(capacity):
        cum = np.cumsum(np.asarray(capacity, dtype=np.float64))
        ne = int(np.searchsorted(cum, hg.total_node_weight() - 1e-9)) + 1
        return min(ne, len(cum))
    return min_partitions(hg, capacity)


def _assign_to_placement(
    hg: Hypergraph, assign: np.ndarray, num_partitions: int, capacity: float
) -> Placement:
    pl = Placement.empty(num_partitions, hg.num_nodes, capacity, hg.node_weights)
    for v in range(hg.num_nodes):
        pl.member[assign[v], v] = True
    return pl


# ------------------------------------------------------------------ baselines
def random_placement(
    hg: Hypergraph, n: int, capacity: float, seed: int = 0, **_
) -> Placement:
    """Place every item once at random, then fill all remaining space with
    random replicas (the paper's Random baseline uses all available space).
    Deterministic for a given `seed` (single `default_rng` stream)."""
    rng = np.random.default_rng(seed)
    pl = Placement.empty(n, hg.num_nodes, capacity, hg.node_weights)
    loads = np.zeros(n, dtype=np.float64)
    for v in rng.permutation(hg.num_nodes):
        wv = hg.node_weights[v]
        ok = np.flatnonzero(loads + wv <= capacity)
        if len(ok) == 0:
            raise ValueError("random placement cannot fit items")
        p = int(rng.choice(ok))
        pl.member[p, v] = True
        loads[p] += wv
    # replicate randomly into leftover space
    order = rng.permutation(hg.num_nodes)
    for p in range(n):
        cap_p = _cap_at(capacity, p)
        for v in order:
            if loads[p] + hg.node_weights[v] > cap_p:
                continue
            if pl.member[p, v]:
                continue
            pl.member[p, v] = True
            loads[p] += hg.node_weights[v]
    return pl


def hpa_placement(
    hg: Hypergraph, n: int, capacity: float, seed: int = 0, nruns: int = 2, **_
) -> Placement:
    """Plain HPA into N_e partitions; no replication (extra partitions idle).

    This is the paper's no-replication baseline: its span does not improve as
    partitions are added (fig. 6a's flat line)."""
    ne = _base_partitions(hg, capacity)
    assign = hpa_mod.partition(
        hg, ne, _cap_slice(capacity, 0, ne), seed=seed, nruns=nruns
    )
    return _assign_to_placement(hg, assign, n, capacity)


# ----------------------------------------------------------- residual helpers
def _residual_edges(hg: Hypergraph, pl: Placement, min_span: int) -> np.ndarray:
    """Edge ids with span > min_span (pruneHypergraphBySpan keeps these)."""
    spans = batched_spans_csr(hg.edge_ptr, hg.edge_nodes, pl.member)
    return np.flatnonzero(spans > min_span)


# ------------------------------------------------------------ Algorithm 1: IHPA
def ihpa(
    hg: Hypergraph, n: int, capacity: float, seed: int = 0, nruns: int = 2, **_
) -> Placement:
    """Algorithm 1, Iterative HPA: partition, then repeatedly re-partition
    the residual hypergraph (edges with span > 1) into the spare partitions,
    replicating its items.

    Exactness/determinism: residual spans come from the batched engine via
    an incremental SpanMaintainer (bit-identical to per-edge greedy cover,
    ties -> lowest partition id); when the residual must shrink (§4.2),
    lowest-span hyperedges are dropped in stable ascending-span order, so
    repeated runs with one seed produce identical placements."""
    ne = _base_partitions(hg, capacity)
    assign = hpa_mod.partition(
        hg, ne, _cap_slice(capacity, 0, ne), seed=seed, nruns=nruns
    )
    pl = _assign_to_placement(hg, assign, n, capacity)
    spans = SpanMaintainer(hg, pl)  # incremental: only touched edges recompute
    used = ne
    round_ = 0
    while used < n:
        round_ += 1
        edge_ids = spans.residual_edges(1)
        if len(edge_ids) == 0:
            break
        resid = hg.subhypergraph_edges(edge_ids)
        resid, old_ids = resid.relabel()
        rem_parts = n - used
        rem_cap = (float(capacity[used:n].sum()) if _is_cap_vec(capacity)
                   else rem_parts * capacity)
        if resid.total_node_weight() > rem_cap:
            # §4.2 text: drop lowest-span hyperedges one at a time (these gain
            # least from replication) until the residual fits
            spans_r = batched_spans_csr(
                resid.edge_ptr, old_ids[resid.edge_nodes], pl.member
            )
            order = np.argsort(spans_r, kind="stable")  # ascending span
            pin_deg = np.bincount(resid.edge_nodes, minlength=resid.num_nodes)
            live_w = float(
                resid.node_weights[np.flatnonzero(pin_deg > 0)].sum()
            )
            keep_mask = np.ones(resid.num_edges, dtype=bool)
            for e in order:
                if live_w <= rem_cap:
                    break
                keep_mask[e] = False
                for u in resid.edge(int(e)):
                    pin_deg[u] -= 1
                    if pin_deg[u] == 0:
                        live_w -= float(resid.node_weights[u])
            resid = resid.subhypergraph_edges(np.flatnonzero(keep_mask))
            sub, sub_ids = resid.relabel()
            old_ids = old_ids[sub_ids]
            resid = sub
            if resid.num_edges == 0 or resid.num_nodes == 0:
                break
        if _is_cap_vec(capacity):
            # shortest prefix of the spare rows that holds the residual
            cum = np.cumsum(capacity[used:n])
            n_new = min(rem_parts, max(1, int(np.searchsorted(
                cum, resid.total_node_weight() - 1e-9)) + 1))
        else:
            n_new = min(rem_parts,
                        max(1, int(np.ceil(resid.total_node_weight()
                                           / capacity))))
        sub_assign = hpa_mod.partition(
            resid, n_new, _cap_slice(capacity, used, used + n_new),
            seed=seed + round_, nruns=nruns
        )
        pl.member[used + sub_assign, old_ids] = True
        spans.notify_items(old_ids)
        used += n_new
    return pl


# -------------------------------------------------------------- Algorithm 2: DS
def ds(
    hg: Hypergraph, n: int, capacity: float, seed: int = 0, nruns: int = 2, **_
) -> Placement:
    """Algorithm 2, Dense-Subgraph based: fill each spare partition with the
    densest capacity-bounded node set of the current residual hypergraph.

    Exactness/determinism: the peel inside `k_densest_nodes` removes the
    lowest-degree node first, ties -> lowest node id (heap order), and
    residual spans come from the batched engine — repeated runs with one
    seed are bit-identical."""
    ne = _base_partitions(hg, capacity)
    assign = hpa_mod.partition(
        hg, ne, _cap_slice(capacity, 0, ne), seed=seed, nruns=nruns
    )
    pl = _assign_to_placement(hg, assign, n, capacity)
    spans = SpanMaintainer(hg, pl)
    used = ne
    while used < n:
        edge_ids = spans.residual_edges(1)
        if len(edge_ids) == 0:
            break
        resid = hg.subhypergraph_edges(edge_ids)
        dense_nodes = resid.k_densest_nodes(_cap_at(capacity, used))
        if len(dense_nodes) == 0:
            break
        pl.member[used, dense_nodes] = True
        spans.notify_items(dense_nodes)
        used += 1
    return pl


# ------------------------------------------------------------- Algorithm 3: PRA
def _hitting_set(sets: list[list[int]]) -> list[int]:
    """Greedy hitting set: repeatedly take the element in the most sets."""
    remaining = [set(s) for s in sets if s]
    hit: list[int] = []
    while remaining:
        counts: dict[int, int] = {}
        for s in remaining:
            for x in s:
                counts[x] = counts.get(x, 0) + 1
        best = max(counts.items(), key=lambda kv: (kv[1], -kv[0]))[0]
        hit.append(best)
        remaining = [s for s in remaining if best not in s]
    return hit


def pra(
    hg: Hypergraph, n: int, capacity: float, seed: int = 0, nruns: int = 2, **_
) -> Placement:
    """Algorithm 3, Pre-Replication: score items by how often they are the
    sole partition-local member of an edge, then clone high scorers across
    the partitions their edges must visit anyway (greedy hitting sets), and
    re-partition the rewired hypergraph.

    Exactness/determinism: scores accumulate in edge-major CSR order
    (matching the original per-edge loop); items are processed in stable
    descending-score order (ties -> lowest item id via stable argsort); the
    hitting-set greedy picks the most frequent element, ties -> LOWEST
    element id (`max` on (count, -id))."""
    ne = _base_partitions(hg, capacity)
    assign = hpa_mod.partition(
        hg, ne, _cap_slice(capacity, 0, ne), seed=seed, nruns=nruns
    )
    pl0 = _assign_to_placement(hg, assign, ne, _cap_slice(capacity, 0, ne))

    # score_v = #edges where v is the only member of its partition (line 4):
    # a pin is "solo" iff its (edge, partition) pin-count is exactly 1.
    # CSR-vectorized; the bincount accumulates the same weights in the same
    # (edge-major) order as the original per-edge loop.
    score = np.zeros(hg.num_nodes, dtype=np.float64)
    if hg.num_pins:
        pin_edge = np.repeat(
            np.arange(hg.num_edges, dtype=np.int64), hg.edge_sizes()
        )
        pin_part = assign[hg.edge_nodes]
        cnt = np.zeros((hg.num_edges, ne), dtype=np.int32)
        np.add.at(cnt, (pin_edge, pin_part), 1)
        solo = cnt[pin_edge, pin_part] == 1
        score = np.bincount(
            hg.edge_nodes[solo],
            weights=hg.edge_weights[pin_edge[solo]],
            minlength=hg.num_nodes,
        )

    budget = (float(capacity.sum()) if _is_cap_vec(capacity)
              else n * capacity) - hg.total_node_weight()  # spare room
    mutable = hg.copy_mutable()
    origins = list(range(hg.num_nodes))  # origins[new_id] = original item id
    node_ptr, node_edges = hg.incidence()
    order = np.argsort(-score, kind="stable")
    for v in order:
        if budget < hg.node_weights[v] or score[v] <= 0:
            continue
        ev = node_edges[node_ptr[v] : node_ptr[v + 1]]
        # spanning partitions of e \ {v}: the partitions each edge must visit
        # anyway for its *other* items — copies of v are anchored to those
        # (fig. 3: distribute copies so incident hyperedges entangle)
        span_sets = []
        for e in ev:
            others = hg.edge(int(e))
            others = others[others != v]
            span_sets.append(
                list(greedy_set_cover(others, pl0.member)) if len(others) else []
            )
        hit = _hitting_set(span_sets)
        if len(hit) <= 1:
            continue
        # original v serves the first hitting-set member; each further member
        # gets a fresh copy, and edges spanned by it are rewired to that copy
        copies = {hit[0]: int(v)}
        for g in hit[1:]:
            if budget < hg.node_weights[v]:
                break
            copies[g] = mutable.add_node_copy(int(v))
            origins.append(int(v))
            budget -= hg.node_weights[v]
        for e, spans in zip(ev, span_sets):
            for g in hit:
                if g in spans and g in copies:
                    mutable.replace_in_edge(int(e), int(v), copies[g])
                    break
    replicated = mutable.freeze()
    final_assign = hpa_mod.partition(
        replicated, n, capacity, seed=seed + 1, nruns=nruns
    )
    # map copies back onto original item ids
    pl = Placement.empty(n, hg.num_nodes, capacity, hg.node_weights)
    copy_origin = np.asarray(origins, dtype=np.int64)
    for new_v in range(replicated.num_nodes):
        pl.member[final_assign[new_v], copy_origin[new_v]] = True
    return pl


# ----------------------------------------------------- Algorithms 4+5: LMBR
class _LMBRState:
    """Live set-cover assignment: for each edge, the partitions in its cover
    and the items it reads from each (the 'improved' LMBR bookkeeping).

    Covers live in a SpanMaintainer (cover mode), so both the initial build
    and every move's invalidation run through the batched bitset engine —
    no per-edge greedy Python loops.  The partition <-> edge incidence is a
    boolean matrix ``_edge_mask[p, e]`` (True iff e's cover touches p), so
    ``shared_edges`` / ``union_edges`` are single AND/OR + flatnonzero ops
    and edge ids come out ascending by construction.  DETERMINISTIC-ORDER is
    the access contract: every downstream float accumulation and tie-break
    depends only on edge ids, never on Python set iteration order.

    Epoch-keyed gain cache
    ----------------------
    ``max_gain(src, dest)`` memoizes Algorithm 5's (gain, items) per ordered
    pair.  Validity is checked at one of two granularities
    (``flags.FLAGS["lmbr_epochs"]``):

    ``"partition"`` (the PR 5 scheme) stamps each entry with the epochs it
    is a pure function of:

      * ``cov_epoch[p]``  — bumped by ``recompute_edges`` for every partition
        that gained or lost a pin attribution (the old and new serving
        partitions of every changed pin; a superset of all part_edges /
        cover-content changes, since both are functions of pin attribution);
      * ``mem_epoch[d]``  — bumped by ``apply_move`` when d's membership row
        (and hence its free space and the free-pin mask) changes.

    A cached (src, dest) entry is valid iff cov_epoch[src], cov_epoch[dest]
    and mem_epoch[dest] are all unchanged.  Under the move loop nearly every
    move grazes some partition pair, so the hit rate is <1%.

    ``"item"`` (default, PR 6) revalidates from the entry's OWN dependency
    set instead: a global move ``tick``, ``edge_tick[e]`` (last tick whose
    ``recompute_edges`` refreshed e's cover — conservative, stamps every
    refreshed edge), and ``item_tick[v]`` (last tick that copied item v
    somewhere).  An entry filled at tick t with shared-edge set ``sh`` and
    candidate pool ``pool`` is valid iff the pair's shared-edge COUNT is
    unchanged (O(1) off the maintained Gram matrix — an edge leaving the
    shared set was re-stamped, so count-neutral swaps are caught by the
    stamp, net changes by the count), ``edge_tick[sh].max() <= t`` and
    ``item_tick[pool].max() <= t``; free space is re-evaluated live from
    the cached trajectory (``_eval_traj``).  See ``_entry_hit`` for the
    full soundness argument.

    Either way a hit skips the recompute and returns the cached result
    verbatim (bit-identical by purity).  This collapses the
    O(N^2)-per-move rescan of Algorithm 4's refresh loop to the touched
    frontier: pairs whose covers, shared sets, and destination row did not
    change never re-peel.

    Mutation contract: membership changes MUST go through ``apply_move`` (or
    epochs go stale and the cache may serve outdated gains; direct
    ``pl.member`` writes are only safe with the cache unused)."""

    def __init__(self, hg: Hypergraph, pl: Placement):
        self.hg = hg
        self.pl = pl
        self.sm = SpanMaintainer(hg, pl, with_covers=True)
        n, E = pl.num_partitions, hg.num_edges
        self._edge_mask = np.zeros((n, E), dtype=bool)
        if E:
            counts = np.fromiter(
                (len(self.sm.chosen(e)) for e in range(E)), dtype=np.int64,
                count=E,
            )
            parts = (
                np.concatenate([self.sm.chosen(e) for e in range(E)])
                if counts.sum() else np.zeros(0, dtype=np.int64)
            )
            self._edge_mask[parts, np.repeat(np.arange(E), counts)] = True
        self.cov_epoch = np.zeros(n, dtype=np.int64)
        self.mem_epoch = np.zeros(n, dtype=np.int64)
        # item-granular cache state (``flags.lmbr_epochs="item"``):
        # edge_tick[e] records the move tick that last recomputed e's cover
        # (conservative: any refresh stamps, changed or not), item_tick[v]
        # the tick that last copied item v somewhere.  A cached pair
        # revalidates from gathers over ITS OWN shared edges and candidate
        # pool, so moves that cannot affect it never invalidate it.
        self.edge_tick = np.zeros(E, dtype=np.int64)
        self.item_tick = np.zeros(hg.num_nodes, dtype=np.int64)
        self.tick = 0
        sizes = np.diff(hg.edge_ptr)
        self._esz_mean = float(sizes.mean()) if E else 0.0
        # pairwise shared-edge counts for the "auto" peel dispatch: built on
        # first use, then maintained by rank-k updates in recompute_edges
        self._shared_cnt: np.ndarray | None = None
        self._loads = pl.partition_weights()
        self._gain_cache: dict[tuple[int, int], tuple] = {}
        self._traj_cache: dict[tuple[int, int], dict] = {}
        # device-peel exactness gate: f32 sums of integer-valued weights
        # below 2^24 are exact under any association order, so the dense
        # backends are bit-identical to the f64 oracle exactly then
        ew, nw = hg.edge_weights, hg.node_weights
        self._int_exact = bool(
            (ew.size == 0
             or (np.all(ew == np.rint(ew)) and float(ew.sum()) < 2 ** 24))
            and (nw.size == 0
                 or (np.all(nw == np.rint(nw)) and float(nw.sum()) < 2 ** 24))
        )
        self.stats = dict(gain_calls=0, gain_cache_hits=0, gain_fp_hits=0,
                          peel_pairs=0, moves=0)

    @property
    def part_edges(self) -> list[set[int]]:
        """Per-partition edge sets (compat view of the incidence mask)."""
        return [set(np.flatnonzero(row).tolist()) for row in self._edge_mask]

    def cover(self, e: int) -> dict[int, np.ndarray]:
        return self.sm.cover(e)

    def free_space(self, p: int) -> float:
        """Capacity headroom of p, tracked incrementally across moves
        (exact for integer item weights; for float weights it may differ
        from ``Placement.free_space`` in the last ulp — summation order)."""
        return self.pl.cap_of(p) - float(self._loads[p])

    def shared_edges(self, src: int, dest: int) -> list[int]:
        """Edges accessing both partitions, ascending edge id."""
        return np.flatnonzero(
            self._edge_mask[src] & self._edge_mask[dest]
        ).tolist()

    def union_edges(self, src: int, dest: int) -> np.ndarray:
        """Edges accessing either partition, ascending edge id."""
        return np.flatnonzero(self._edge_mask[src] | self._edge_mask[dest])

    def apply_move(self, dest: int, items: np.ndarray) -> None:
        """Copy `items` into partition dest (the only legal membership
        mutation): updates the load ledger and stamps dest's mem epoch."""
        self.pl.member[dest, items] = True
        self._loads[dest] += float(self.hg.node_weights[items].sum())
        self.mem_epoch[dest] += 1
        self.tick += 1
        self.item_tick[items] = self.tick
        self.stats["moves"] += 1

    def recompute_edges(self, edges: np.ndarray) -> None:
        """Re-derive the covers of `edges` in ONE batched engine call
        (bit-identical to per-edge cover_for_query), resync the incidence
        mask, and stamp the cov epoch of every partition whose pin
        attribution changed."""
        edges = np.asarray(edges, dtype=np.int64)
        if not len(edges):
            return
        _, pidx = self.hg.pin_indices(edges)
        old_pp = self.sm.pin_parts[pidx].copy()
        old_sub = self._edge_mask[:, edges].copy()
        self._edge_mask[:, edges] = False
        self.sm.refresh_edges(edges)
        new_pp = self.sm.pin_parts[pidx]
        counts = np.fromiter(
            (len(self.sm.chosen(int(e))) for e in edges), dtype=np.int64,
            count=len(edges),
        )
        parts = (
            np.concatenate([self.sm.chosen(int(e)) for e in edges])
            if counts.sum() else np.zeros(0, dtype=np.int64)
        )
        self._edge_mask[parts, np.repeat(edges, counts)] = True
        new_sub = self._edge_mask[:, edges]
        # any refresh stamps its edges (conservative: attribution can change
        # even when the cover set does not), behind its own tick bump so
        # entries cached earlier in the same move can never alias the stamp
        self.tick += 1
        self.edge_tick[edges] = self.tick
        if self._shared_cnt is not None:
            o64 = old_sub.astype(np.int64)
            n64 = new_sub.astype(np.int64)
            self._shared_cnt += n64 @ n64.T - o64 @ o64.T
        changed = old_pp != new_pp
        if changed.any():
            touched = np.unique(
                np.concatenate([old_pp[changed], new_pp[changed]])
            )
            self.cov_epoch[touched] += 1

    def _stamp(self, key: tuple[int, int]) -> tuple[int, int, int]:
        """The epochs (gain of key) is a pure function of."""
        src, dest = key
        return (
            int(self.cov_epoch[src]), int(self.cov_epoch[dest]),
            int(self.mem_epoch[dest]),
        )

    def max_gain(self, src: int, dest: int):
        """Algorithm 5 through the epoch cache: recompute only when an epoch
        the pair depends on moved, else return the memoized (gain, items)."""
        return self.max_gain_many([(src, dest)])[(src, dest)]

    def _peel_width_bounds(self, pairs: list[tuple[int, int]]) -> np.ndarray:
        """Per-pair degree-matrix width estimate for the ``lmbr_peel="auto"``
        size dispatch: (shared-edge count) * (mean edge size).  The count
        matrix is built once (edge-mask Gram product) and then maintained by
        rank-k updates in `recompute_edges`, so each estimate is an O(1)
        lookup — the dispatch signal never costs O(E) per pair.  The signal
        only picks a backend; both backends are bit-identical."""
        if self._shared_cnt is None:
            m = self._edge_mask.astype(np.int64)
            self._shared_cnt = m @ m.T
        srcs = np.fromiter((s for s, _ in pairs), dtype=np.int64,
                           count=len(pairs))
        dests = np.fromiter((d for _, d in pairs), dtype=np.int64,
                            count=len(pairs))
        return self._shared_cnt[srcs, dests] * self._esz_mean

    # ----------------------------------------- item-granular gain cache
    def _shared_count(self, key: tuple[int, int]) -> int:
        """O(1) shared-edge count off the maintained Gram matrix."""
        if self._shared_cnt is None:
            m = self._edge_mask.astype(np.int64)
            self._shared_cnt = m @ m.T
        return int(self._shared_cnt[key])

    def _entry_hit(self, key: tuple[int, int], ent: dict) -> bool:
        """Level-1 validity of a trajectory-cache entry: two tick gathers
        over the entry's OWN dependency footprint, no projection.

        Soundness — the pair's projection is a pure function of:

        * the covers / pin attributions of its shared edges, and every such
          change goes through ``recompute_edges``, which stamps
          ``edge_tick`` for all refreshed edges (conservatively: refreshed
          but unchanged still stamps), so ``edge_tick[sh].max() <= tick``
          proves the cached shared edges are untouched;
        * the shared-edge SET itself — an edge can only LEAVE it via a
          cover change (stamped, and it is in the cached ``sh``), so a
          count-preserving swap is caught by the leaving edge's tick and a
          net gain by the O(1) count compare;
        * which candidate-pool items are resident on dest — items only ever
          gain residency, and any copy of a pool item is caught by the
          per-item tick check (a copy of a non-pool item cannot change this
          pair's costly-pin set);
        * immutable node / edge weights.

        The destination's free space is NOT part of validity: trajectories
        are free-space-independent and re-evaluated under the live free
        space on every hit (empty projections stay empty under any of these
        checks, and a zero from exhausted free space stays zero because
        free space only shrinks).  Result-only entries (``strict``: the
        pure-Python oracle emits no trajectory) instead pin the global move
        tick, so they only serve while no mutation at all intervened."""
        if ent["strict"]:
            return ent["tick"] == self.tick
        if ent["scnt"] != self._shared_count(key):
            return False
        t = ent["tick"]
        sh = ent["sh"]
        if len(sh) and int(self.edge_tick[sh].max()) > t:
            return False
        pool = ent["pool"]
        if pool is None or not len(pool):
            return True
        return int(self.item_tick[pool].max()) <= t

    def _entry_eval(self, key: tuple[int, int], ent: dict):
        if ent["res"] is not None:
            return ent["res"]
        return _eval_traj(ent["pool"], ent["traj"], self.free_space(key[1]))

    def _cache_put(self, key, *, pool=None, fp=None, traj=None, res=None,
                   strict=False):
        if strict:
            sh, scnt = None, -1
        else:
            sh = np.flatnonzero(
                self._edge_mask[key[0]] & self._edge_mask[key[1]]
            )
            scnt = len(sh)
        self._traj_cache[key] = dict(
            tick=self.tick, sh=sh, scnt=scnt, pool=pool, fp=fp,
            traj=traj, res=res, strict=strict,
        )

    def _peel_with_traj(self, proj: list[tuple], backend: str):
        """Peel projected pairs, returning {key: (pool, fp, traj)}.  The
        dense device backends only engage on the integer-exact domain (and
        with jax importable); everything else — including fallback — runs
        the flat numpy lockstep with trajectory recording."""
        if (backend in ("device", "pallas") and self._int_exact
                and _accel_backend() is not None):
            try:
                return _lmbr_peel_dense(self, proj, backend)
            except Exception:
                pass  # fall through to the bit-identical flat engine
        return _lmbr_peel_flat(self, proj, collect_traj=True)

    def _max_gain_many_item(self, pairs, use_cache: bool):
        out: dict[tuple[int, int], tuple] = {}
        cache = self._traj_cache
        misses: list[tuple[int, int]] = []
        seen: set[tuple[int, int]] = set()
        for key in pairs:
            if key in seen:
                continue
            seen.add(key)
            if use_cache:
                ent = cache.get(key)
                if ent is not None and self._entry_hit(key, ent):
                    self.stats["gain_cache_hits"] += 1
                    out[key] = self._entry_eval(key, ent)
                    continue
            misses.append(key)
        if not misses:
            return out
        backend = _flags.FLAGS.get("lmbr_peel", "vector")
        if backend == "reference":
            ref_keys, rest = misses, []
        elif backend == "auto":
            thresh = int(_flags.FLAGS.get("lmbr_peel_threshold", 256))
            bounds = self._peel_width_bounds(misses)
            ref_keys = [k for k, b in zip(misses, bounds) if b < thresh]
            rest = [k for k, b in zip(misses, bounds) if b >= thresh]
        else:
            ref_keys, rest = [], misses
        for k in ref_keys:
            res = _lmbr_max_gain_reference(self, *k)
            out[k] = res
            if use_cache:
                self._cache_put(k, res=res, strict=True)
        if rest:
            zero, proj = _lmbr_project(self, rest)
            for k, res in zero.items():
                out[k] = res
                if use_cache:
                    # empty projections are free-space-monotone (free space
                    # only shrinks within a fit), so stamp-valid is enough
                    self._cache_put(k, res=res)
            peel_list = []
            for p in proj:
                k = p[0]
                ent = cache.get(k) if use_cache else None
                if (ent is not None and ent["fp"] is not None
                        and _fp_equal(ent["fp"], p)):
                    # level 2: identical projection -> the cached trajectory
                    # is byte-for-byte what a re-peel would produce; re-file
                    # it under the CURRENT dependency footprint
                    self.stats["gain_fp_hits"] += 1
                    self._cache_put(k, pool=ent["pool"], fp=ent["fp"],
                                    traj=ent["traj"])
                    out[k] = _eval_traj(ent["pool"], ent["traj"], p[1])
                    continue
                peel_list.append(p)
            if peel_list:
                self.stats["peel_pairs"] += len(peel_list)
                reg = _obs.registry()
                if reg.active:
                    reg.inc("lmbr_peel_rounds")
                    reg.inc("lmbr_peel_pairs", len(peel_list))
                peeled = self._peel_with_traj(peel_list, backend)
                for p in peel_list:
                    k = p[0]
                    pool, fp, traj = peeled[k]
                    out[k] = _eval_traj(pool, traj, p[1])
                    if use_cache:
                        self._cache_put(k, pool=pool, fp=fp, traj=traj)
        return out

    def max_gain_many(self, pairs: list[tuple[int, int]]):
        """Epoch-cached batch gain evaluation.  Cache hits are answered from
        the memo; the misses run through ONE lockstep batched peel (or the
        pure-Python oracle pair-by-pair under ``lmbr_peel="reference"``;
        ``"auto"`` routes pairs whose degree-matrix width estimate is below
        ``flags.FLAGS["lmbr_peel_threshold"]`` to the oracle — on sparse
        near-span-1 workloads tiny peels beat the batch-array assembly —
        and batches the rest; all backends are bit-identical).

        Cache granularity follows ``flags.lmbr_epochs``: "item" (default)
        runs the two-level item-granular cache — per-pair epoch stamps plus
        a per-item tick intersection, then a projection fingerprint — and
        re-evaluates cached free-space-independent peel trajectories under
        the live free space; "partition" restores the PR 5 per-partition
        epoch memo.  Both are exactness-neutral.
        Returns {pair: (gain, items)} covering every requested pair."""
        self.stats["gain_calls"] += len(pairs)
        use_cache = _flags.FLAGS.get("lmbr_gain_cache", True)
        if _flags.FLAGS.get("lmbr_epochs", "item") == "item":
            return self._max_gain_many_item(pairs, use_cache)
        out: dict[tuple[int, int], tuple] = {}
        misses: list[tuple[int, int]] = []
        pending: set[tuple[int, int]] = set()
        for key in pairs:
            if key in out or key in pending:
                continue
            if use_cache:
                hit = self._gain_cache.get(key)
                if hit is not None and hit[0] == self._stamp(key):
                    self.stats["gain_cache_hits"] += 1
                    out[key] = (hit[1], hit[2])
                    continue
            misses.append(key)
            pending.add(key)
        if misses:
            backend = _flags.FLAGS.get("lmbr_peel", "vector")
            if backend == "reference":
                computed = {
                    k: _lmbr_max_gain_reference(self, *k) for k in misses
                }
            elif backend == "auto":
                thresh = int(_flags.FLAGS.get("lmbr_peel_threshold", 256))
                bounds = self._peel_width_bounds(misses)
                computed = {
                    k: _lmbr_max_gain_reference(self, *k)
                    for k, b in zip(misses, bounds) if b < thresh
                }
                big = [k for k, b in zip(misses, bounds) if b >= thresh]
                if big:
                    computed.update(_lmbr_gain_batch(self, big))
            else:
                computed = _lmbr_gain_batch(self, misses)
            if use_cache:
                for k, v in computed.items():
                    self._gain_cache[k] = (self._stamp(k), *v)
            out.update(computed)
        return out

    def spans(self) -> np.ndarray:
        return self.sm.spans()


def _lmbr_max_gain_reference(state: _LMBRState, src: int, dest: int):
    """Algorithm 5: best group of items to copy src->dest and its gain
    (benefit per unit weight copied).  Returns (gain, items) or (0, None).

    Pure-Python peel, the executable specification (kept as the oracle the
    vectorized engine is tested against — `_LMBRState.max_gain_many`
    dispatches between the two on ``flags.FLAGS["lmbr_peel"]``; both are
    bit-identical: same densest subset, same gain float, same tie-breaks —
    ascending edge id in the projection scan, lowest item id on density
    ties — enforced by tests/test_lmbr_peel.py).

    Projection: for each edge accessing both partitions (ascending edge id),
    the items it reads from src that are NOT already on dest — items already
    resident on dest are free pins (cost 0, never peeled), the weighted
    generalization of the paper's getKDensestNodes accounting.  The peel
    then repeatedly removes the lowest-degree item (ties -> lowest item id)
    and records the best benefit/weight ratio among states that fit dest's
    free space."""
    hg, pl = state.hg, state.pl
    shared = state.shared_edges(src, dest)  # ascending edge id, deterministic
    if not shared:
        return 0.0, None
    c_dest = state.free_space(dest)
    if c_dest <= 1e-12:
        return 0.0, None
    node_w = hg.node_weights
    dest_row = pl.member[dest]
    # project: for each shared edge, the items it reads from src
    proj: list[tuple[float, list[int]]] = []  # (edge_weight, costly pins)
    total_benefit = 0.0
    for e in shared:
        items = state.cover(e).get(src)
        if items is None or not len(items):
            continue
        costly = [int(v) for v in items if not dest_row[v]]
        if not costly:
            continue  # free benefit is claimed lazily by recompute_edges
        we = float(hg.edge_weights[e])
        proj.append((we, costly))
        total_benefit += we
    if not proj:
        return 0.0, None
    inc: dict[int, list[int]] = {}
    for i, (_, pins) in enumerate(proj):
        for v in pins:
            inc.setdefault(v, []).append(i)
    deg = {v: 0.0 for v in inc}
    for i, (we, pins) in enumerate(proj):
        for v in pins:
            deg[v] += we
    alive_nodes = set(inc)
    alive_edge = [True] * len(proj)
    # accumulate in inc insertion order (first-encounter over the ascending
    # shared-edge scan) — never in set iteration order
    total_w = sum(float(node_w[v]) for v in inc)
    heap = [(d, v) for v, d in deg.items()]
    heapq.heapify(heap)
    best_gain, best_items = 0.0, None
    while total_benefit > 1e-12 and alive_nodes:
        if total_w <= c_dest + 1e-12:
            gain = total_benefit / max(total_w, 1e-12)
            if gain > best_gain:
                best_gain = gain
                best_items = list(alive_nodes)
        # peel the lowest-degree alive node
        while heap:
            d, v = heapq.heappop(heap)
            if v in alive_nodes and abs(d - deg[v]) < 1e-9:
                break
        else:
            break
        alive_nodes.discard(v)
        total_w -= float(node_w[v])
        for i in inc[v]:
            if alive_edge[i]:
                alive_edge[i] = False
                we, pins = proj[i]
                total_benefit -= we
                for u in pins:
                    if u != v and u in alive_nodes:
                        deg[u] -= we
                        heapq.heappush(heap, (deg[u], u))
    if best_items is None:
        return 0.0, None
    return best_gain, np.asarray(sorted(best_items), dtype=np.int64)


def _ranged_gather(lo: np.ndarray, hi: np.ndarray):
    """Flat indices of the concatenated ranges [lo_i, hi_i); also sizes."""
    sizes = hi - lo
    total = int(sizes.sum())
    if not total:
        return np.zeros(0, dtype=np.int64), sizes
    start = np.zeros(len(sizes) + 1, dtype=np.int64)
    np.cumsum(sizes, out=start[1:])
    idx = np.repeat(lo, sizes) + (
        np.arange(total, dtype=np.int64) - np.repeat(start[:-1], sizes)
    )
    return idx, sizes


def _lmbr_max_gain_vectorized(state: _LMBRState, src: int, dest: int):
    """Single-pair view of the batched peel (`_lmbr_gain_batch`)."""
    return _lmbr_gain_batch(state, [(src, dest)])[(src, dest)]


def _proj_entry(key, c_dest, bpins, bedges, node_w, edge_w):
    """One pair's peel inputs from its costly-pin subsequence.

    ``bpins``/``bedges`` hold the pair's costly pins in projection scan
    order — edges ascending, pins in edge order — exactly the sequence the
    pure-Python oracle iterates, so every left-fold below reproduces its
    float accumulations bit-for-bit."""
    first = np.concatenate([[True], bedges[1:] != bedges[:-1]])
    starts = np.flatnonzero(first)
    kept = bedges[starts]            # edges with >= 1 costly pin, ascending
    pin_cnt = np.diff(np.concatenate([starts, [len(bedges)]]))
    we = edge_w[kept].astype(np.float64)
    cedge = np.repeat(np.arange(len(kept), dtype=np.int64), pin_cnt)
    uniq, first_idx = np.unique(bpins, return_index=True)
    loc = np.searchsorted(uniq, bpins)
    # item pool weight: left-fold in first-encounter order, matching the
    # oracle's sequential sum over dict insertion order
    totw0 = float(np.cumsum(node_w[bpins[np.sort(first_idx)]])[-1])
    return (key, c_dest, we, uniq, loc, cedge, pin_cnt, totw0)


def _project_fan_in(state, dest, srcs, out, proj):
    """Project every (src, dest) pair of one destination in one pass: gather
    the pins of dest's covered edges once, drop the free ones (already on
    dest), and split the remainder into per-serving-partition blocks with a
    single stable argsort.  Each block is exactly the costly-pin sequence
    the per-pair projection would produce (edges ascending, pin order)."""
    hg, pl = state.hg, state.pl
    e_d = np.flatnonzero(state._edge_mask[dest])
    # span-1 edges live on dest alone: they are never shared with a source
    # and all their pins are resident (free), so drop them before gathering
    e_d = e_d[state.sm.spans()[e_d] > 1]
    c_dest = state.free_space(dest)
    if not len(e_d) or c_dest <= 1e-12:
        for s in srcs:
            out[(s, dest)] = (0.0, None)
        return
    ptr, pidx = hg.pin_indices(e_d)
    nodes = hg.edge_nodes[pidx]
    sp = state.sm.pin_parts[pidx]
    eids = np.repeat(e_d, np.diff(ptr))
    sel = np.flatnonzero(~pl.member[dest, nodes])  # costly pins only
    order = sel[np.argsort(sp[sel], kind="stable")]
    svals = sp[order]
    bstart = np.flatnonzero(
        np.concatenate([[True], svals[1:] != svals[:-1]])
    ) if len(order) else np.zeros(0, dtype=np.int64)
    bend = np.concatenate([bstart[1:], [len(order)]])
    lookup = {int(s): i for i, s in enumerate(svals[bstart])}
    for s in srcs:
        i = lookup.get(s)
        if i is None:  # no shared edge reads a costly item from s
            out[(s, dest)] = (0.0, None)
            continue
        block = order[bstart[i]: bend[i]]
        proj.append(_proj_entry(
            (s, dest), c_dest, nodes[block], eids[block],
            hg.node_weights, hg.edge_weights,
        ))


def _project_fan_out(state, src, dests, out, proj):
    """Project every (src, dest) pair of one source in one pass: gather the
    pins src serves once; each destination then masks that block to its
    shared edges and non-resident items (2 row gathers per pair)."""
    hg, pl = state.hg, state.pl
    e_s = np.flatnonzero(state._edge_mask[src])
    # span-1 edges live on src alone: never shared with any destination
    e_s = e_s[state.sm.spans()[e_s] > 1]
    if not len(e_s):
        for d in dests:
            out[(src, d)] = (0.0, None)
        return
    ptr, pidx = hg.pin_indices(e_s)
    nodes = hg.edge_nodes[pidx]
    served = np.flatnonzero(state.sm.pin_parts[pidx] == src)
    bpins = nodes[served]
    bedges = np.repeat(e_s, np.diff(ptr))[served]
    for d in dests:
        c_dest = state.free_space(d)
        if c_dest <= 1e-12:
            out[(src, d)] = (0.0, None)
            continue
        keep = state._edge_mask[d, bedges] & ~pl.member[d, bpins]
        if not keep.any():
            out[(src, d)] = (0.0, None)
            continue
        sub = np.flatnonzero(keep)
        proj.append(_proj_entry(
            (src, d), c_dest, bpins[sub], bedges[sub],
            hg.node_weights, hg.edge_weights,
        ))


def _eval_traj(pool: np.ndarray, traj, c: float):
    """Select (gain, items) from a peel trajectory under free space ``c``.

    The single selection rule shared by the cache-revalidation path and the
    dense device backends: float64 ``benefit / max(weight, 1e-12)`` over
    the head-of-round states that fit (``totw <= c + 1e-12``), earliest
    round on gain ties (``argmax`` first occurrence == the oracle's
    strict-improvement recording), surviving items = pool minus the first r
    peeled.  Trajectories never depend on ``c`` (the peel order ignores
    free space), which is what makes cached entries re-evaluable as the
    destination fills up."""
    if traj is None or c <= 1e-12:
        return 0.0, None
    order, rtot, rben = traj
    fits = rtot <= c + 1e-12
    if not fits.any():
        return 0.0, None
    gains = rben / np.maximum(rtot, 1e-12)
    r = int(np.argmax(np.where(fits, gains, -np.inf)))
    keep = np.ones(len(pool), dtype=bool)
    keep[order[:r]] = False
    return float(gains[r]), pool[keep]


def _fp_equal(fp: tuple, p: tuple) -> bool:
    """Projection fingerprint equality: identical kept-edge weights, item
    pool, pin->item and pin->edge maps, and per-edge pin counts.  Equal
    fingerprints mean the peel inputs are identical, so the cached
    trajectory is exactly what a re-peel would produce."""
    return all(
        x.shape == y.shape and np.array_equal(x, y)
        for x, y in zip(fp, (p[2], p[3], p[4], p[5], p[6]))
    )


def _lmbr_project(state: _LMBRState, pairs: list[tuple[int, int]]):
    """Shared-gather projection of many pairs.  Returns (zero, proj):
    ``zero`` maps pairs with an empty projection to (0.0, None); ``proj``
    holds one peel-input tuple per remaining pair.

    Grouping: fan-in pairs (*, d) reuse one gather of d's covered edges
    (blocks split by serving partition); the rest group by src, reusing one
    gather of src's served pins across destinations."""
    zero: dict[tuple[int, int], tuple] = {}
    proj: list[tuple] = []  # (key, c_dest, we, uniq, loc, cedge, pin_cnt, totw0)
    by_dest: dict[int, list[int]] = {}
    for s, d in pairs:
        by_dest.setdefault(d, []).append(s)
    by_src: dict[int, list[int]] = {}
    for d, srcs in by_dest.items():
        if len(srcs) >= 2:
            _project_fan_in(state, d, srcs, zero, proj)
        else:
            by_src.setdefault(srcs[0], []).append(d)
    for s, dests in by_src.items():
        _project_fan_out(state, s, dests, zero, proj)
    return zero, proj


def _lmbr_gain_batch(state: _LMBRState, pairs: list[tuple[int, int]]):
    """Batched Algorithm 5: evaluate MANY (src, dest) candidates in one
    lockstep peel.  Returns {(src, dest): (gain, items-or-None)}, each entry
    bit-identical to the pure-Python oracle run on that pair alone."""
    out, proj = _lmbr_project(state, pairs)
    if proj:
        out.update(_lmbr_peel_flat(state, proj))
    return out


def _lmbr_peel_dense(state: _LMBRState, proj: list[tuple], backend: str):
    """Device-resident lockstep peel (``lmbr_peel="device"|"pallas"``):
    densify each pair's projection into a (K, U) incidence cell and run
    every round on device via ``repro.kernels.lockstep_peel``.  Only the
    free-space-independent trajectories come back; selection happens in
    ``_eval_traj``.  Caller guarantees the integer-exact weight domain, so
    the f32 trajectories are bit-identical to the flat f64 engine's.
    Returns {key: (pool, fp, traj)} like ``_lmbr_peel_flat``."""
    from ..kernels.lockstep_peel.ops import lockstep_peel

    force = "jax" if backend == "device" else "pallas"
    node_w = state.hg.node_weights
    out: dict[tuple[int, int], tuple] = {}
    classes: dict[tuple[int, int], list[tuple]] = {}
    huge: list[tuple] = []
    for p in proj:
        u2 = 1 << max(2, (len(p[3]) - 1).bit_length())
        k2 = 1 << max(2, (len(p[2]) - 1).bit_length())
        # a single pathological pair can dwarf the batch; densifying it
        # would blow memory, so it keeps the flat CSR engine
        if u2 * k2 > 1 << 22:
            huge.append(p)
        else:
            classes.setdefault((u2, k2), []).append(p)
    for (u2, k2), plist in classes.items():
        chunk = max(1, (1 << 22) // (u2 * k2))
        for lo in range(0, len(plist), chunk):
            sub = plist[lo: lo + chunk]
            G = len(sub)
            inc = np.zeros((G, k2, u2), dtype=np.float64)
            wem = np.zeros((G, k2), dtype=np.float64)
            nwm = np.zeros((G, u2), dtype=np.float64)
            nv = np.zeros(G, dtype=np.int64)
            for i, p in enumerate(sub):
                _, _, we, uniq, loc, cedge, _, _ = p
                inc[i, cedge, loc] = 1.0
                wem[i, : len(we)] = we
                nwm[i, : len(uniq)] = node_w[uniq]
                nv[i] = len(uniq)
            peel, rtot, rben = lockstep_peel(inc, wem, nwm, nv, force=force)
            done = peel < 0  # -1s are a suffix: active never resumes
            for i, p in enumerate(sub):
                R = int(np.argmax(done[i])) if done[i].any() else peel.shape[1]
                traj = (
                    (peel[i, :R].copy(), rtot[i, :R].copy(),
                     rben[i, :R].copy())
                    if R else None
                )
                out[p[0]] = (p[3], (p[2], p[3], p[4], p[5], p[6]), traj)
    if huge:
        out.update(_lmbr_peel_flat(state, huge, collect_traj=True))
    return out


def _lmbr_peel_flat(state: _LMBRState, proj: list[tuple],
                    collect_traj: bool = False):
    """Flat lockstep peel over projected pairs.

    Peel (all pairs in lockstep): pair-local items live in dense (G, Umax)
    matrices (degree, alive, weight), edges in flat CSR arrays.  Each round
    peels one item from every still-active pair: a single row-wise
    ``argmin`` picks each pair's lowest-degree item (+inf padding; ties ->
    lowest item id because columns are sorted by item id), and scatter-adds
    (``np.add.at`` — sequential over its index arrays) retire dying edges
    and their degree contributions in the oracle's exact accumulation order
    (edges ascending within a pair, pins in edge order).  Pairs drop out of
    the round set when their remaining benefit or item pool is exhausted.
    Because every pair's float-op sequence is unchanged from its solo run,
    lockstep execution cannot perturb results — same subsets, same gain
    floats, even under adversarial near-ties.

    Returns {key: (gain, items)} by default (best state tracked in-loop);
    with ``collect_traj`` the head-of-round states are recorded instead and
    the return is {key: (pool, fp, traj)} for ``_eval_traj`` / the
    trajectory cache — same rounds, same floats, one selection rule."""
    hg = state.hg
    node_w = hg.node_weights
    out: dict[tuple[int, int], tuple] = {}

    # ---- flat batch assembly
    G = len(proj)
    U = np.array([len(p[3]) for p in proj], dtype=np.int64)
    K = np.array([len(p[2]) for p in proj], dtype=np.int64)
    Umax = int(U.max())
    ebase = np.zeros(G + 1, dtype=np.int64)
    np.cumsum(K, out=ebase[1:])
    we_flat = np.concatenate([p[2] for p in proj])
    pair_of_edge = np.repeat(np.arange(G, dtype=np.int64), K)
    # edge -> costly pins CSR (pins are pair-major, edge-major, pin order)
    pin_cnt_flat = np.concatenate([p[6] for p in proj])
    eptr = np.zeros(int(ebase[-1]) + 1, dtype=np.int64)
    np.cumsum(pin_cnt_flat, out=eptr[1:])
    pin_col = np.concatenate([p[4] for p in proj])
    pin_edge = np.concatenate(
        [p[5] + ebase[i] for i, p in enumerate(proj)]
    )
    pin_row = pair_of_edge[pin_edge]
    # item slot (pair, col) -> incident kept edges, ascending scan order
    inc_edges = np.concatenate([
        (p[5] + ebase[i])[np.argsort(p[4], kind="stable")]
        for i, p in enumerate(proj)
    ])
    inc_cnt = np.zeros((G, Umax), dtype=np.int64)
    for i, p in enumerate(proj):
        inc_cnt[i, : U[i]] = np.bincount(p[4], minlength=U[i])
    inc_ptr = np.zeros(G * Umax + 1, dtype=np.int64)
    np.cumsum(inc_cnt.ravel(), out=inc_ptr[1:])
    # dense padded index tables: slot -> incident edges and edge -> pin
    # indices, -1-padded to the widest row.  Each round then runs ONE fancy
    # gather + mask instead of a CSR ranged gather (whose cumsum/repeat
    # chains dominate the loop); row-major flattening preserves the exact
    # scan order (edges ascending within a slot, pins in edge order), so
    # every np.add.at sequence — hence every float — is unchanged.  CSR
    # stays the fallback for pathologically wide rows.
    emax = int(inc_cnt.max()) if inc_cnt.size else 0
    pmax = int(pin_cnt_flat.max()) if pin_cnt_flat.size else 0
    E_flat = int(ebase[-1])
    use_dense = (0 < emax <= 32 and G * Umax * emax < (1 << 24)
                 and 0 < pmax <= 64 and E_flat * pmax < (1 << 24))
    if use_dense:
        cnt_r = inc_cnt.ravel()
        inc_dense = np.full((G * Umax, emax), -1, dtype=np.int64)
        inc_dense[
            np.repeat(np.arange(G * Umax, dtype=np.int64), cnt_r),
            np.arange(len(inc_edges), dtype=np.int64)
            - np.repeat(inc_ptr[:-1], cnt_r),
        ] = inc_edges
        pin_dense = np.full((E_flat, pmax), -1, dtype=np.int64)
        pin_dense[
            np.repeat(np.arange(E_flat, dtype=np.int64), pin_cnt_flat),
            np.arange(len(pin_col), dtype=np.int64)
            - np.repeat(eptr[:-1], pin_cnt_flat),
        ] = np.arange(len(pin_col), dtype=np.int64)
    # dense per-item state: +inf padding so argmin never picks a pad slot
    valid = np.arange(Umax, dtype=np.int64)[None, :] < U[:, None]
    cand = np.full((G, Umax), np.inf, dtype=np.float64)
    cand[valid] = 0.0
    # degrees accumulate in the oracle's scan order (np.add.at is
    # sequential over its index arrays), bit-for-bit the dict loop
    np.add.at(cand, (pin_row, pin_col), we_flat[pin_edge])
    alive = valid.copy()
    nodew = np.zeros((G, Umax), dtype=np.float64)
    nodew[valid] = np.concatenate([node_w[p[3]] for p in proj])
    # left-fold cumsum == the oracle's sequential `total_benefit += we`
    benefit = np.array(
        [float(np.cumsum(p[2])[-1]) for p in proj], dtype=np.float64
    )
    totw = np.array([p[7] for p in proj], dtype=np.float64)
    c_arr = np.array([p[1] for p in proj], dtype=np.float64)
    n_alive = U.copy()
    edge_alive = np.ones(int(ebase[-1]), dtype=bool)
    best_gain = np.zeros(G, dtype=np.float64)
    best_set = np.zeros((G, Umax), dtype=bool)
    has_best = np.zeros(G, dtype=bool)

    # ---- lockstep weighted peel (getKDensestNodes, Asahiro-style greedy)
    rec_rows: list[np.ndarray] = []
    rec_j: list[np.ndarray] = []
    rec_tot: list[np.ndarray] = []
    rec_ben: list[np.ndarray] = []
    act = np.flatnonzero((benefit > 1e-12) & (n_alive > 0))
    while len(act):
        t = totw[act]
        if collect_traj:
            # head-of-round snapshot (the fancy-index gathers are already
            # fresh arrays); selection is deferred to _eval_traj
            rec_rows.append(act)
            rec_tot.append(t)
            rec_ben.append(benefit[act])
        else:
            # record states that fit the destination's free space
            fits = t <= c_arr[act] + 1e-12
            if fits.any():
                rows = act[fits]
                g = benefit[rows] / np.maximum(t[fits], 1e-12)
                imp = g > best_gain[rows]
                if imp.any():
                    r2 = rows[imp]
                    best_gain[r2] = g[imp]
                    best_set[r2] = alive[r2]
                    has_best[r2] = True
        # peel each active pair's lowest-degree item (ties -> lowest id)
        j = np.argmin(cand[act], axis=1)
        if collect_traj:
            rec_j.append(j)
        alive[act, j] = False
        cand[act, j] = np.inf
        n_alive[act] -= 1
        totw[act] -= nodew[act, j]
        # retire this round's dying edges (ascending within each pair)
        slot = act * Umax + j
        if use_dense:
            ec = inc_dense[slot]                  # (A, emax), -1 padded
            cand_e = ec[ec >= 0]
        else:
            idx, _ = _ranged_gather(inc_ptr[slot], inc_ptr[slot + 1])
            cand_e = inc_edges[idx]
        de = cand_e[edge_alive[cand_e]]
        if len(de):
            edge_alive[de] = False
            np.add.at(benefit, pair_of_edge[de], -we_flat[de])
            if use_dense:
                pd = pin_dense[de]                # (D, pmax), -1 padded
                pm = pd >= 0
                cols = pin_col[pd[pm]]
                rows_t = np.broadcast_to(
                    pair_of_edge[de][:, None], pd.shape)[pm]
                wrep = np.broadcast_to(we_flat[de][:, None], pd.shape)[pm]
            else:
                pidx2, dsz = _ranged_gather(eptr[de], eptr[de + 1])
                cols = pin_col[pidx2]
                rows_t = np.repeat(pair_of_edge[de], dsz)
                wrep = np.repeat(we_flat[de], dsz)
            lv = alive[rows_t, cols]     # dead items never re-compared
            np.add.at(cand, (rows_t[lv], cols[lv]), -wrep[lv])
        act = act[(benefit[act] > 1e-12) & (n_alive[act] > 0)]

    if not collect_traj:
        for i, p in enumerate(proj):
            if has_best[i]:
                out[p[0]] = (float(best_gain[i]), p[3][best_set[i, : U[i]]])
            else:
                out[p[0]] = (0.0, None)
        return out

    # ---- group the recorded rounds back into per-pair trajectories
    # (stable sort by pair keeps round order within each pair)
    rows_all = (np.concatenate(rec_rows) if rec_rows
                else np.zeros(0, dtype=np.int64))
    j_all = (np.concatenate(rec_j) if rec_j
             else np.zeros(0, dtype=np.int64))
    tot_all = (np.concatenate(rec_tot) if rec_tot
               else np.zeros(0, dtype=np.float64))
    ben_all = (np.concatenate(rec_ben) if rec_ben
               else np.zeros(0, dtype=np.float64))
    order = np.argsort(rows_all, kind="stable")
    counts = np.bincount(rows_all, minlength=G)
    ptr = np.zeros(G + 1, dtype=np.int64)
    np.cumsum(counts, out=ptr[1:])
    for i, p in enumerate(proj):
        sl = order[ptr[i]: ptr[i + 1]]
        traj = (
            (j_all[sl].astype(np.int64), tot_all[sl], ben_all[sl])
            if len(sl) else None
        )
        out[p[0]] = (p[3], (p[2], p[3], p[4], p[5], p[6]), traj)
    return out


def _energy_active_rows(hg: Hypergraph, n: int, capacity,
                        slack: float = 1.25) -> np.ndarray:
    """Active-partition mask for ``placement_objective="energy"``: the
    smallest capacity-descending prefix of rows (ties -> lowest id) whose
    total capacity holds ``slack``x the item weight.  Everything outside
    the mask stays empty — those machines can be powered down — while the
    in-mask slack is the replication budget the move engine spends."""
    caps = capacity_vector(capacity, n)
    order = np.lexsort((np.arange(n), -caps))
    cum = np.cumsum(caps[order])
    need = min(hg.total_node_weight() * slack, float(cum[-1]))
    k = int(np.searchsorted(cum, need - 1e-9)) + 1
    k = min(max(k, 1), n)
    mask = np.zeros(n, dtype=bool)
    mask[order[:k]] = True
    return mask


def lmbr(
    hg: Hypergraph,
    n: int,
    capacity: float,
    seed: int = 0,
    nruns: int = 2,
    max_moves: int | None = None,
    initial: Placement | None = None,
    dest_mask: np.ndarray | None = None,
    node_cost: np.ndarray | None = None,
    **_,
) -> Placement:
    """Improved LMBR (Algorithm 4 + Algorithm 5).

    `initial` warm-starts from an existing placement (incremental refits and
    the paper's use of LMBR as a capacity-fixup subroutine).

    `dest_mask` (optional, (n,) bool) restricts which partitions may RECEIVE
    copies: pairs with a masked destination are never evaluated or pushed.
    Sources are unrestricted — a masked partition that serves no covers
    (e.g. a failed partition whose membership row is zeroed) simply yields
    no gain.  An all-True mask is bit-identical to no mask; this is how
    online drift refits keep adapting during an outage (down rows masked)
    without ever copying data onto dead partitions.

    ``flags.placement_objective="energy"`` reuses the same plumbing on a
    cold start: the balanced start and the dest mask are restricted to a
    capacity-descending active-row prefix (`_energy_active_rows`), so the
    remaining partitions finish the fit empty and can be powered down.

    `node_cost` (optional, (n,) per-partition access cost, e.g.
    ``NodeProfile.access_cost``) with ``flags.node_cost_weight`` > 0
    charges each candidate move ``weight * node_cost[dest]`` against its
    gain before the accept test, steering replicas toward cheap nodes.
    The default (weight 0 or no vector) leaves every gain untouched —
    bit-identical to the unpenalized engine.

    Determinism contract: moves are applied in descending-gain order from a
    heap whose entries tie-break on (src, dest, version); candidate subsets
    come from the Algorithm 5 peel (ascending edge id in the projection,
    lowest item id on density ties), so repeated runs produce bit-identical
    placements regardless of peel backend (``flags.FLAGS["lmbr_peel"]``) or
    gain-cache setting (``flags.FLAGS["lmbr_gain_cache"]``).  The fitted
    ``Placement`` carries the move-engine counters in ``.stats`` (moves,
    gain_calls, gain_cache_hits, peel backend)."""
    _tr = _obs.tracer()
    _t0 = time.perf_counter() if _tr.active else 0.0
    energy_mask: np.ndarray | None = None
    if initial is not None:
        pl = Placement(
            initial.member.copy(), capacity, hg.node_weights
        )
    elif _flags.FLAGS.get("placement_objective", "span") == "energy":
        # energy objective: fit into the active-row prefix only; idle rows
        # never receive copies (masked below), so they finish empty
        energy_mask = _energy_active_rows(hg, n, capacity)
        active = np.flatnonzero(energy_mask)
        k = len(active)
        caps_a = capacity_vector(capacity, n)[active]
        # capacity-proportional balance targets: each active row's share of
        # the load follows its share of the active capacity, so the clamped
        # sum always covers the total weight (flat per-row targets starve
        # rows smaller than the average)
        bal = (
            caps_a / float(caps_a.sum()) * hg.total_node_weight() * 1.1
            + float(hg.node_weights.max())
        )
        bal_cap = normalize_capacity(np.minimum(caps_a, bal))
        sub_assign = hpa_mod.partition(hg, k, bal_cap, seed=seed, nruns=nruns)
        pl = _assign_to_placement(hg, active[sub_assign], n, capacity)
    else:
        # Algorithm 4 line 1: balanced N-way start (hMETIS's UBfactor formula
        # allows only ~(C*N-total)/total slack, i.e. near-balance); the spare
        # capacity in every partition is the replication budget for the moves
        if _is_cap_vec(capacity):
            # heterogeneous rows: balance targets proportional to each
            # row's capacity share (a flat per-row target would starve the
            # sub-average rows and can make the start infeasible)
            bal_cap = normalize_capacity(np.minimum(
                capacity,
                capacity / float(capacity.sum())
                * hg.total_node_weight() * 1.1
                + float(hg.node_weights.max()),
            ))
        else:
            bal_cap = min(
                capacity,
                hg.total_node_weight() / n * 1.1
                + float(hg.node_weights.max()),
            )
        assign = hpa_mod.partition(hg, n, bal_cap, seed=seed, nruns=nruns)
        pl = _assign_to_placement(hg, assign, n, capacity)
    eng0 = engine_counters()
    state = _LMBRState(hg, pl)
    if max_moves is None:
        max_moves = 50 * n
    if dest_mask is None:
        dest_ok = np.ones(n, dtype=bool)
    else:
        dest_ok = np.asarray(dest_mask, dtype=bool)
        if dest_ok.shape != (n,):
            raise ValueError(f"dest_mask must be ({n},) bool")
    if energy_mask is not None:
        dest_ok = dest_ok & energy_mask
    # optional access-cost gain penalty (off by default: cost_pen is None
    # and every gain flows through unmodified — bit-identical)
    ncw = float(_flags.FLAGS.get("node_cost_weight", 0.0))
    cost_pen = (
        ncw * np.asarray(node_cost, dtype=np.float64)
        if ncw > 0 and node_cost is not None else None
    )

    # priority queue of (-gain, src, dest, version)
    version = np.zeros((n, n), dtype=np.int64)
    pq: list[tuple[float, int, int, int]] = []

    def _penalized(gain: float, d: int) -> float:
        return gain - float(cost_pen[d]) if cost_pen is not None else gain

    def push_many(pairlist: list[tuple[int, int]]):
        # one batched (epoch-cached) gain evaluation for the whole refresh
        # set; heap-entry content is insertion-order independent, so this is
        # behaviorally identical to pushing pair-by-pair
        results = state.max_gain_many(pairlist)
        for s, d in pairlist:
            gain, items = results[(s, d)]
            gain = _penalized(gain, d)
            version[s, d] += 1
            if gain > 0 and items is not None:
                heapq.heappush(pq, (-gain, s, d, int(version[s, d])))

    push_many([(s, d) for s in range(n) for d in range(n)
               if s != d and dest_ok[d]])

    moves = 0
    while pq and moves < max_moves:
        neg_gain, src, dest, ver = heapq.heappop(pq)
        if ver != version[src, dest]:
            continue  # stale entry
        gain, items = state.max_gain(src, dest)  # re-verify vs live state
        gain = _penalized(gain, dest)
        if items is None or gain <= 0:
            continue
        w = hg.node_weights[items].sum()
        if w > state.free_space(dest) + 1e-9:
            push_many([(src, dest)])
            continue
        # apply the move: copy items into dest
        state.apply_move(dest, items)
        moves += 1
        # recompute covers of edges that might benefit (those accessing src
        # or dest and touching a moved item) — ONE batched engine call over
        # the ascending-id affected set; per-edge covers are independent, so
        # refresh order cannot influence results.
        cand_arr = state.union_edges(src, dest)
        if len(cand_arr):
            ptr, nodes_ = hg.edges_csr(cand_arr)
            hit = np.isin(nodes_, items)
            ch = np.concatenate([[0], np.cumsum(hit)])
            touches = ch[ptr[1:]] > ch[ptr[:-1]]
            state.recompute_edges(cand_arr[touches])
        # refresh PQ entries involving dest (Algorithm 4 lines 12-15)
        pairs: list[tuple[int, int]] = []
        for g in range(n):
            if g != dest:
                pairs.append((g, dest))
                if dest_ok[g]:
                    pairs.append((dest, g))
        pairs.append((src, dest))
        push_many(pairs)
    calls = state.stats["gain_calls"]
    hits = state.stats["gain_cache_hits"] + state.stats["gain_fp_hits"]
    eng1 = engine_counters()
    pl.stats = dict(
        state.stats, peel=_flags.FLAGS.get("lmbr_peel", "vector"),
        gain_cache=bool(_flags.FLAGS.get("lmbr_gain_cache", True)),
        lmbr_epochs=_flags.FLAGS.get("lmbr_epochs", "item"),
        cache_hit_rate=(hits / calls) if calls else 0.0,
        cover_engine={k: eng1[k] - eng0[k] for k in eng0},
    )
    reg = _obs.registry()
    if reg.active:
        # mirror the move-engine counters into the registry; misses are
        # derivable as lmbr_gain_calls - hits
        for k in ("moves", "gain_calls", "gain_cache_hits", "gain_fp_hits"):
            reg.inc("lmbr_" + k, state.stats[k])
    if _tr.active:
        _tr.complete("fit.lmbr", _t0, time.perf_counter(), n=n,
                     moves=state.stats["moves"],
                     gain_calls=state.stats["gain_calls"])
    return pl


ALGORITHMS: dict[str, Callable[..., Placement]] = {
    "random": random_placement,
    "hpa": hpa_placement,
    "ihpa": ihpa,
    "ds": ds,
    "pra": pra,
    "lmbr": lmbr,
}
