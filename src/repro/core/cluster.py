"""Heterogeneous cluster model: per-partition node profiles + durability.

The paper's partitions are interchangeable slots with one scalar capacity.
Real clusters are not: machines differ in disk size, failure rate, power
draw and access cost, and both the energy-efficient-cluster literature
(Lang et al.) and the data-grid replication surveys motivate placing
replicas *against* those differences — concentrate onto efficient nodes,
keep the loss probability of every item below a durability ceiling.

`NodeProfile` is the per-partition attribute table every layer consumes:

  * ``capacity``      — storage budget per partition (the old scalar C),
  * ``fail_prob``     — independent per-partition failure probability,
  * ``power_idle`` / ``power_active`` — draw (W) when empty vs loaded
    (the simulator's per-node energy accounting and the energy-aware
    placement objective read these),
  * ``access_cost``   — relative per-access serving cost (the cost-aware
    router tie-break reads this).

Bit-identity contract
---------------------
``NodeProfile.homogeneous(...)`` must reproduce today's scalar-capacity
behavior bit-for-bit on every fitter, router and benchmark gate.  The
mechanism is `normalize_capacity`: every entry point that accepts a
scalar-or-vector capacity first collapses a UNIFORM vector back to the
plain Python float, so a homogeneous profile takes byte-for-byte the same
code paths (same comparisons, same hash keys, same reprs) as the scalar it
replaces.  Only genuinely heterogeneous vectors flow through the (N,)
broadcasting paths.

Durability (snippet-style greedy)
---------------------------------
Under the independent-failure model an item stored on partitions S is lost
with probability ``p_loss = prod_{p in S} fail_prob[p]``.  `min_replicas`
returns the smallest k whose k best (lowest-fail) partitions satisfy
``p_loss <= eps``; `ensure_durability` greedily adds copies —
lowest-fail-prob candidate first, ties -> least loaded, then lowest id —
until every item meets the ceiling, never exceeding capacity;
`validate_durability` re-checks the invariant from scratch.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "NodeProfile", "normalize_capacity", "capacity_vector",
    "min_replicas", "ensure_durability", "validate_durability",
    "DEFAULT_FAIL_PROB", "DEFAULT_POWER_IDLE", "DEFAULT_POWER_ACTIVE",
    "DEFAULT_ACCESS_COST",
]

DEFAULT_FAIL_PROB = 0.01     # per-partition loss probability
DEFAULT_POWER_IDLE = 100.0   # W drawn by an empty (powered-down) partition
DEFAULT_POWER_ACTIVE = 250.0  # W drawn by a loaded partition (~ e_machine)
DEFAULT_ACCESS_COST = 1.0    # relative per-access serving cost


def normalize_capacity(capacity):
    """Collapse a uniform per-partition capacity vector to the scalar float
    path.

    This is the bit-identity seam: `NodeProfile.homogeneous(...).capacity`
    normalizes to the plain float the scalar-capacity code has always seen,
    so homogeneous profiles cannot perturb any existing result.  Genuinely
    non-uniform vectors pass through as float64 (N,) arrays."""
    if isinstance(capacity, np.ndarray):
        cap = np.asarray(capacity, dtype=np.float64)
        if cap.ndim == 0:
            return float(cap)
        if cap.ndim != 1:
            raise ValueError(f"capacity must be scalar or 1-D, got {cap.shape}")
        if cap.size and np.all(cap == cap[0]):
            return float(cap[0])
        return cap
    return float(capacity)


def capacity_vector(capacity, n: int) -> np.ndarray:
    """(n,) float64 view of a scalar-or-vector capacity."""
    if isinstance(capacity, np.ndarray) and capacity.ndim:
        cap = np.asarray(capacity, dtype=np.float64)
        if len(cap) != n:
            raise ValueError(f"capacity vector has {len(cap)} entries, want {n}")
        return cap
    return np.full(n, float(capacity))


def _as_col(x, n: int, name: str) -> np.ndarray:
    arr = np.asarray(x, dtype=np.float64)
    if arr.ndim == 0:
        arr = np.full(n, float(arr))
    if arr.shape != (n,):
        raise ValueError(f"{name} must be scalar or ({n},), got {arr.shape}")
    return arr


@dataclasses.dataclass(frozen=True)
class NodeProfile:
    """Per-partition attribute table (see module docstring).

    Every column is an (N,) float64 array; scalars broadcast at
    construction.  Instances are immutable — fitters and routers read
    columns, they never write them."""

    capacity: np.ndarray
    fail_prob: np.ndarray
    power_idle: np.ndarray
    power_active: np.ndarray
    access_cost: np.ndarray

    def __post_init__(self):
        n = len(np.atleast_1d(np.asarray(self.capacity, dtype=np.float64)))
        for name in ("capacity", "fail_prob", "power_idle", "power_active",
                     "access_cost"):
            object.__setattr__(
                self, name, _as_col(getattr(self, name), n, name)
            )
        if (self.capacity <= 0).any():
            raise ValueError("capacity must be positive")
        if ((self.fail_prob <= 0) | (self.fail_prob >= 1)).any():
            raise ValueError("fail_prob must lie strictly in (0, 1)")

    @classmethod
    def homogeneous(
        cls,
        num_partitions: int,
        capacity: float,
        fail_prob: float = DEFAULT_FAIL_PROB,
        power_idle: float = DEFAULT_POWER_IDLE,
        power_active: float = DEFAULT_POWER_ACTIVE,
        access_cost: float = DEFAULT_ACCESS_COST,
    ) -> "NodeProfile":
        """N identical partitions — bit-identical to the scalar-capacity
        model on every fitter / router / gate (see `normalize_capacity`)."""
        n = int(num_partitions)
        return cls(
            capacity=np.full(n, float(capacity)),
            fail_prob=np.full(n, float(fail_prob)),
            power_idle=np.full(n, float(power_idle)),
            power_active=np.full(n, float(power_active)),
            access_cost=np.full(n, float(access_cost)),
        )

    @property
    def num_partitions(self) -> int:
        return len(self.capacity)

    @property
    def is_homogeneous(self) -> bool:
        return all(
            col.size == 0 or bool(np.all(col == col[0]))
            for col in (self.capacity, self.fail_prob, self.power_idle,
                        self.power_active, self.access_cost)
        )

    def capacity_arg(self):
        """The capacity to hand the fitters: the plain scalar float when
        uniform (the bit-identity path), the (N,) vector otherwise."""
        return normalize_capacity(self.capacity)

    def routing_cost(self) -> np.ndarray:
        """Static per-partition serving-cost key for the cost-aware router
        tie-break: access cost plus mean-normalized active power.  Uniform
        profiles yield a constant vector, which degenerates the tie-break
        to pure least-loaded (bit-identical)."""
        pa = self.power_active
        scale = float(pa.mean()) if pa.size and float(pa.mean()) > 0 else 1.0
        return self.access_cost + pa / scale

    def subset(self, rows) -> "NodeProfile":
        """Profile restricted to a row subset (sharded fits hand each shard
        its partition slice)."""
        rows = np.asarray(rows)
        return NodeProfile(
            capacity=self.capacity[rows].copy(),
            fail_prob=self.fail_prob[rows].copy(),
            power_idle=self.power_idle[rows].copy(),
            power_active=self.power_active[rows].copy(),
            access_cost=self.access_cost[rows].copy(),
        )


# ------------------------------------------------------------- durability
def min_replicas(fail_probs, eps: float) -> int:
    """Smallest k such that the k most reliable partitions satisfy
    ``prod(fail_prob) <= eps`` (independent failures).  Returns
    ``len(fail_probs) + 1`` when no subset does — callers treat that as
    infeasible."""
    p = np.sort(np.asarray(fail_probs, dtype=np.float64))
    prod = 1.0
    for k in range(len(p)):
        prod *= float(p[k])
        if prod <= eps:
            return k + 1
    return len(p) + 1


def _loss_probs(member: np.ndarray, fail: np.ndarray) -> np.ndarray:
    """(V,) per-item loss probability ``prod_{p holds v} fail[p]``.
    One pass per partition: exact products, O(N) memory."""
    loss = np.ones(member.shape[1], dtype=np.float64)
    for p in range(member.shape[0]):
        row = member[p]
        if row.any():
            loss[row] *= float(fail[p])
    return loss


def ensure_durability(pl, profile: NodeProfile, eps: float) -> np.ndarray:
    """Greedily add replicas until every placed item (weight > 0) has loss
    probability <= ``eps``.

    Deterministic: items ascend by id; each copy goes to the feasible
    partition with the lowest ``fail_prob`` (ties -> least loaded, then
    lowest id).  Mutates ``pl.member`` in place (copies only — existing
    replicas never move, the same online-cheap contract as refit/repair)
    and returns the ids of items that received copies.  Raises ValueError
    when capacity cannot satisfy the ceiling."""
    if eps <= 0:
        raise ValueError(f"durability_eps must be > 0, got {eps}")
    member = pl.member
    n = member.shape[0]
    fail = _as_col(profile.fail_prob, n, "fail_prob")
    cap = capacity_vector(pl.capacity, n)
    weights = np.asarray(pl.node_weights, dtype=np.float64)
    loads = member @ weights
    loss = _loss_probs(member, fail)
    placed = member.any(axis=0)
    need = np.flatnonzero((loss > eps) & placed & (weights > 0))
    touched: list[int] = []
    for v in need:
        v = int(v)
        wv = float(weights[v])
        p_loss = float(loss[v])
        while p_loss > eps:
            cand = np.flatnonzero(
                ~member[:, v] & (loads + wv <= cap + 1e-9)
            )
            if not len(cand):
                raise ValueError(
                    f"cannot satisfy durability_eps={eps}: item {v} at "
                    f"p_loss={p_loss:.2e} has no feasible partition left"
                )
            key = np.lexsort((cand, loads[cand], fail[cand]))
            d = int(cand[key[0]])
            member[d, v] = True
            loads[d] += wv
            p_loss *= float(fail[d])
            touched.append(v)
    return np.unique(np.asarray(touched, dtype=np.int64))


def validate_durability(pl, profile: NodeProfile, eps: float,
                        rtol: float = 1e-9) -> None:
    """Raise ValueError unless every placed item (weight > 0) satisfies
    ``prod fail_prob <= eps`` (small relative tolerance for float
    products)."""
    member = pl.member
    fail = _as_col(profile.fail_prob, member.shape[0], "fail_prob")
    weights = np.asarray(pl.node_weights, dtype=np.float64)
    loss = _loss_probs(member, fail)
    bad = np.flatnonzero(
        (loss > eps * (1 + rtol)) & member.any(axis=0) & (weights > 0)
    )
    if len(bad):
        v = int(bad[0])
        raise ValueError(
            f"{len(bad)} items violate durability_eps={eps}, e.g. item {v} "
            f"at p_loss={loss[v]:.2e}"
        )
