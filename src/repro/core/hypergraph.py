"""Hypergraph workload model (paper §3).

Nodes are data items (possibly weighted, for heterogeneous item sizes);
hyperedges are queries (possibly weighted by frequency).  Backed by CSR-style
numpy arrays so the placement algorithms scale to ISPD98-sized inputs
(~70k nodes / ~75k hyperedges) in pure Python.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

__all__ = ["Hypergraph", "build_incidence", "canonicalize_csr", "csr_ranges"]


def csr_ranges(ptr: np.ndarray, ids: np.ndarray):
    """Flat-gather indices of the CSR rows `ids`: returns (out_ptr, idx)
    where ``idx`` concatenates the ranges ``[ptr[i], ptr[i+1])`` for each
    id in order and ``out_ptr`` is the CSR of the result.  Row order is
    preserved; shared by `Hypergraph.pin_indices` and the streaming
    builder."""
    ids = np.asarray(ids, dtype=np.int64)
    sizes = ptr[ids + 1] - ptr[ids]
    out_ptr = np.zeros(len(ids) + 1, dtype=np.int64)
    np.cumsum(sizes, out=out_ptr[1:])
    total = int(out_ptr[-1])
    base = np.repeat(ptr[ids], sizes)
    off = np.arange(total, dtype=np.int64) - np.repeat(out_ptr[:-1], sizes)
    return out_ptr, base + off


def build_incidence(edge_ptr: np.ndarray, edge_nodes: np.ndarray, num_nodes: int):
    """Invert the edge->node CSR into a node->edge CSR."""
    num_edges = len(edge_ptr) - 1
    # edge id for every pin
    pin_edge = np.repeat(np.arange(num_edges, dtype=np.int64), np.diff(edge_ptr))
    order = np.argsort(edge_nodes, kind="stable")
    node_edges = pin_edge[order]
    sorted_nodes = edge_nodes[order]
    node_ptr = np.zeros(num_nodes + 1, dtype=np.int64)
    counts = np.bincount(sorted_nodes, minlength=num_nodes)
    node_ptr[1:] = np.cumsum(counts)
    return node_ptr, node_edges


def canonicalize_csr(edge_ptr: np.ndarray, edge_nodes: np.ndarray):
    """Sort and deduplicate the pins of every CSR edge in one vectorized
    pass.  Returns a new (edge_ptr, edge_nodes) pair whose per-edge pin
    arrays are exactly ``np.unique(edge)`` — the canonical form
    `Hypergraph.from_edges` produces — without a per-edge Python loop, so a
    million-query chunk canonicalizes in one lexsort instead of a million
    `np.unique` calls (the streaming builder's hot path)."""
    edge_ptr = np.asarray(edge_ptr, dtype=np.int64)
    edge_nodes = np.asarray(edge_nodes, dtype=np.int64)
    E = len(edge_ptr) - 1
    sizes = np.diff(edge_ptr)
    if len(edge_nodes) == 0:
        return edge_ptr.copy(), edge_nodes.copy()
    eid = np.repeat(np.arange(E, dtype=np.int64), sizes)
    order = np.lexsort((edge_nodes, eid))
    nodes = edge_nodes[order]
    eids = eid[order]
    keep = np.ones(len(nodes), dtype=bool)
    keep[1:] = (nodes[1:] != nodes[:-1]) | (eids[1:] != eids[:-1])
    new_nodes = nodes[keep]
    counts = np.bincount(eids[keep], minlength=E)
    new_ptr = np.zeros(E + 1, dtype=np.int64)
    np.cumsum(counts, out=new_ptr[1:])
    return new_ptr, new_nodes


@dataclasses.dataclass
class Hypergraph:
    """Immutable CSR hypergraph.

    edge_ptr:    (E+1,) int64 — CSR offsets into edge_nodes
    edge_nodes:  (P,)   int64 — node ids, pins of each hyperedge
    node_weights:(V,)   float64 — item sizes (1.0 for homogeneous)
    edge_weights:(E,)   float64 — query frequencies (1.0 default)
    """

    edge_ptr: np.ndarray
    edge_nodes: np.ndarray
    node_weights: np.ndarray
    edge_weights: np.ndarray
    # lazily built node->edge incidence
    _node_ptr: np.ndarray | None = None
    _node_edges: np.ndarray | None = None

    # ------------------------------------------------------------------ build
    @staticmethod
    def from_edges(
        edges: Sequence[Iterable[int]],
        num_nodes: int | None = None,
        node_weights: np.ndarray | None = None,
        edge_weights: np.ndarray | None = None,
    ) -> "Hypergraph":
        edge_lists = [np.unique(np.asarray(list(e), dtype=np.int64)) for e in edges]
        if num_nodes is None:
            num_nodes = (
                int(max((int(e.max()) for e in edge_lists if len(e)), default=-1)) + 1
            )
        edge_ptr = np.zeros(len(edge_lists) + 1, dtype=np.int64)
        edge_ptr[1:] = np.cumsum([len(e) for e in edge_lists])
        edge_nodes = (
            np.concatenate(edge_lists)
            if edge_lists
            else np.zeros(0, dtype=np.int64)
        )
        if node_weights is None:
            node_weights = np.ones(num_nodes, dtype=np.float64)
        else:
            node_weights = np.asarray(node_weights, dtype=np.float64)
            assert len(node_weights) == num_nodes
        if edge_weights is None:
            edge_weights = np.ones(len(edge_lists), dtype=np.float64)
        else:
            edge_weights = np.asarray(edge_weights, dtype=np.float64)
        return Hypergraph(edge_ptr, edge_nodes, node_weights, edge_weights)

    # ------------------------------------------------------------- properties
    @property
    def num_nodes(self) -> int:
        return len(self.node_weights)

    @property
    def num_edges(self) -> int:
        return len(self.edge_ptr) - 1

    @property
    def num_pins(self) -> int:
        return len(self.edge_nodes)

    def edge(self, e: int) -> np.ndarray:
        return self.edge_nodes[self.edge_ptr[e] : self.edge_ptr[e + 1]]

    def edge_sizes(self) -> np.ndarray:
        return np.diff(self.edge_ptr)

    def total_node_weight(self) -> float:
        return float(self.node_weights.sum())

    def density(self) -> float:
        return self.num_edges / max(self.num_nodes, 1)

    def avg_items_per_query(self) -> float:
        """avgDataItemsPerQuery subroutine (paper §4.1)."""
        if self.num_edges == 0:
            return 0.0
        return float(self.edge_sizes().mean())

    # ------------------------------------------------------------- incidence
    def incidence(self):
        if self._node_ptr is None:
            self._node_ptr, self._node_edges = build_incidence(
                self.edge_ptr, self.edge_nodes, self.num_nodes
            )
        return self._node_ptr, self._node_edges

    def node_edges_of(self, v: int) -> np.ndarray:
        node_ptr, node_edges = self.incidence()
        return node_edges[node_ptr[v] : node_ptr[v + 1]]

    def degrees(self, edge_mask: np.ndarray | None = None) -> np.ndarray:
        """Weighted degree of every node (sum of incident edge weights)."""
        if edge_mask is None:
            w = self.edge_weights
        else:
            w = self.edge_weights * edge_mask
        pin_edge = np.repeat(
            np.arange(self.num_edges, dtype=np.int64), np.diff(self.edge_ptr)
        )
        return np.bincount(
            self.edge_nodes, weights=w[pin_edge], minlength=self.num_nodes
        )

    # ------------------------------------------------------------ subgraphs
    def pin_indices(self, edge_ids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """CSR (ptr, idx) of the given hyperedges where ``idx`` are positions
        into the global pin arrays (``edge_nodes`` and anything aligned with
        it, e.g. a per-pin replica-selection array).  Pin order within each
        edge is preserved; edges appear in ``edge_ids`` order."""
        return csr_ranges(self.edge_ptr, edge_ids)

    def edges_csr(self, edge_ids: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """CSR (ptr, nodes) of the given hyperedges, vectorized gather."""
        ptr, idx = self.pin_indices(edge_ids)
        return ptr, self.edge_nodes[idx]

    def subhypergraph_edges(self, edge_ids: np.ndarray) -> "Hypergraph":
        """Keep the given hyperedges; node ids are preserved (no relabel)."""
        edge_ids = np.asarray(edge_ids, dtype=np.int64)
        ptr, nodes = self.edges_csr(edge_ids)
        return Hypergraph(
            ptr, nodes, self.node_weights, self.edge_weights[edge_ids]
        )

    def active_nodes(self) -> np.ndarray:
        """Nodes with degree >= 1 (contained in at least one hyperedge)."""
        return np.unique(self.edge_nodes)

    def relabel(self) -> tuple["Hypergraph", np.ndarray]:
        """Compact to active nodes.  Returns (new_graph, old_ids) where
        old_ids[new_id] = original node id."""
        old_ids = self.active_nodes()
        remap = np.full(self.num_nodes, -1, dtype=np.int64)
        remap[old_ids] = np.arange(len(old_ids))
        g = Hypergraph(
            self.edge_ptr.copy(),
            remap[self.edge_nodes],
            self.node_weights[old_ids].copy(),
            self.edge_weights.copy(),
        )
        return g, old_ids

    # ------------------------------------------------- dense subgraph peeling
    def k_densest_nodes(self, max_weight: float) -> np.ndarray:
        """getKDensestNodes (paper §4.1): greedily peel the lowest-degree node
        until total remaining node weight <= max_weight (Asahiro et al.).

        Returns the surviving node ids (original labels).
        """
        alive_nodes, alive_edges, deg, _ = self._peel_to_weight(max_weight)
        return np.flatnonzero(alive_nodes)

    def prune_to_size(self, max_weight: float) -> "Hypergraph":
        """pruneHypergraphToSize: same peeling, returns the hypergraph induced
        by the surviving nodes (edges fully contained in survivors)."""
        alive_nodes, alive_edges, _, _ = self._peel_to_weight(max_weight)
        keep = np.flatnonzero(alive_edges)
        return self.subhypergraph_edges(keep)

    def _peel_to_weight(self, max_weight: float):
        node_ptr, node_edges = self.incidence()
        deg = self.degrees().astype(np.float64)
        alive_nodes = np.zeros(self.num_nodes, dtype=bool)
        active = self.active_nodes()
        alive_nodes[active] = True
        alive_edges = np.ones(self.num_edges, dtype=bool)
        # edge pin counters: when a node dies, each incident edge dies
        total_w = float(self.node_weights[alive_nodes].sum())
        import heapq

        heap = [(deg[v], int(v)) for v in active]
        heapq.heapify(heap)
        while total_w > max_weight and heap:
            d, v = heapq.heappop(heap)
            if not alive_nodes[v] or d != deg[v]:
                continue  # stale
            alive_nodes[v] = False
            total_w -= float(self.node_weights[v])
            for e in node_edges[node_ptr[v] : node_ptr[v + 1]]:
                if alive_edges[e]:
                    alive_edges[e] = False
                    w = self.edge_weights[e]
                    for u in self.edge(int(e)):
                        if alive_nodes[u]:
                            deg[u] -= w
                            heapq.heappush(heap, (deg[u], int(u)))
        return alive_nodes, alive_edges, deg, total_w

    # ----------------------------------------------------------------- misc
    def equals(self, other: "Hypergraph") -> bool:
        """Exact structural equality: same CSR arrays, same weights (the
        contract the streaming builder is tested against)."""
        return (
            np.array_equal(self.edge_ptr, other.edge_ptr)
            and np.array_equal(self.edge_nodes, other.edge_nodes)
            and np.array_equal(self.node_weights, other.node_weights)
            and np.array_equal(self.edge_weights, other.edge_weights)
        )

    def copy_mutable(self) -> "MutableHypergraph":
        return MutableHypergraph(
            [list(self.edge(e)) for e in range(self.num_edges)],
            list(self.node_weights),
            list(self.edge_weights),
        )

    def __repr__(self):
        return (
            f"Hypergraph(V={self.num_nodes}, E={self.num_edges}, "
            f"pins={self.num_pins}, density={self.density():.2f})"
        )


class MutableHypergraph:
    """List-of-lists hypergraph used by PRA, which rewrites hyperedges while
    replicating nodes (paper Algorithm 3)."""

    def __init__(self, edges, node_weights, edge_weights):
        self.edges = [list(e) for e in edges]
        self.node_weights = list(node_weights)
        self.edge_weights = list(edge_weights)

    @property
    def num_nodes(self):
        return len(self.node_weights)

    def add_node_copy(self, v: int) -> int:
        """makeNewCopy: clone node v, return the new node id."""
        self.node_weights.append(self.node_weights[v])
        return len(self.node_weights) - 1

    def replace_in_edge(self, e: int, old: int, new: int):
        edge = self.edges[e]
        for i, u in enumerate(edge):
            if u == old:
                edge[i] = new
                return True
        return False

    def freeze(self) -> Hypergraph:
        return Hypergraph.from_edges(
            self.edges,
            num_nodes=self.num_nodes,
            node_weights=np.asarray(self.node_weights),
            edge_weights=np.asarray(self.edge_weights),
        )
