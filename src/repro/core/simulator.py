"""Trace-driven simulation framework (paper §5).

Instantiates N partitions of capacity C, runs a placement algorithm, then
replays a query trace measuring: span profile, per-partition load, activated
machines, estimated communication bytes, and estimated energy.

Energy model
------------
The paper estimates energy with a Mantis-style full-system power model fed by
hardware counters; no counters exist in this container, so we use the affine
model the paper's measurements support (fig. 1/5: energy grows ~linearly with
span at fixed work):

    E(query) = e_work * W + e_machine * span + e_net * bytes_shipped

with  bytes_shipped = sum of item sizes read from non-coordinator partitions
(every remote partition ships its partial result; span-1 remote reads).
Constants default to an Itanium-server-like profile (the paper's testbed):
~250 J of fixed per-machine activation+coordination cost for a ~1 s analytical
query slice, ~60 J/GB on the wire, e_work scaling with the bytes scanned.
These reproduce the paper's observed 31-79 % energy reductions when span
drops from ~20 to ~3 (validated in benchmarks/energy_model.py).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from .. import obs as _obs
from . import hpa as hpa_mod
from .cluster import (
    DEFAULT_POWER_ACTIVE, DEFAULT_POWER_IDLE, NodeProfile, normalize_capacity,
)
from .hypergraph import Hypergraph
from .setcover import Placement, batched_cover_csr

__all__ = ["SimulationResult", "Simulator", "EnergyModel"]


@dataclasses.dataclass
class EnergyModel:
    e_work_per_gb: float = 120.0  # J per GB scanned (CPU+IO)
    e_machine: float = 250.0      # J per activated machine per query
    e_net_per_gb: float = 60.0    # J per GB shipped cross-machine

    def query_energy(self, scanned_gb: float, span: int, shipped_gb: float) -> float:
        return (
            self.e_work_per_gb * scanned_gb
            + self.e_machine * span
            + self.e_net_per_gb * shipped_gb
        )

    def cluster_power(self, loads: np.ndarray,
                      profile: NodeProfile | None = None) -> float:
        """Steady-state cluster draw (W): a loaded partition bills its
        active power, an empty one its idle (powered-down) draw.  With a
        `NodeProfile` the draw is per-node; otherwise the homogeneous
        defaults apply — this is the machine-count half of the
        span-vs-active-machines Pareto the energy objective targets."""
        active = np.asarray(loads, dtype=np.float64) > 0
        if profile is not None:
            return float(
                np.where(active, profile.power_active,
                         profile.power_idle).sum()
            )
        return float(
            active.sum() * DEFAULT_POWER_ACTIVE
            + (~active).sum() * DEFAULT_POWER_IDLE
        )


@dataclasses.dataclass
class SimulationResult:
    algorithm: str
    spans: np.ndarray               # (NQ,) spans of the SERVED queries
    loads: np.ndarray               # (N,) storage load (weight)
    access_load: np.ndarray         # (N,) #query-accesses per partition
    energy_joules: float
    shipped_gb: float
    placement_seconds: float
    replication_factor: float
    placement_stats: dict | None = None  # fitter diagnostics (Placement.stats)
    online_stats: dict | None = None     # serving counters (run_online)
    active_machines: int = 0             # partitions holding any data
    cluster_power_w: float = 0.0         # steady-state draw (EnergyModel)

    @property
    def avg_span(self) -> float:
        return float(self.spans.mean()) if len(self.spans) else 0.0

    @property
    def max_span(self) -> int:
        return int(self.spans.max()) if len(self.spans) else 0

    @property
    def load_imbalance(self) -> float:
        """max access load / mean access load (1.0 = perfectly balanced)."""
        m = self.access_load.mean()
        return float(self.access_load.max() / m) if m > 0 else 0.0

    def summary(self) -> dict:
        out = dict(
            algorithm=self.algorithm,
            avg_span=round(self.avg_span, 4),
            max_span=self.max_span,
            energy_kj=round(self.energy_joules / 1e3, 2),
            shipped_gb=round(self.shipped_gb, 3),
            rf=round(self.replication_factor, 3),
            placement_s=round(self.placement_seconds, 3),
            load_imbalance=round(self.load_imbalance, 3),
            active_machines=int(self.active_machines),
            cluster_power_w=round(self.cluster_power_w, 1),
        )
        if self.placement_stats:
            # fitter-side counters (e.g. LMBR moves / gain-cache hit rate)
            out.update(
                {f"fit_{k}": v for k, v in self.placement_stats.items()}
            )
        if self.online_stats:
            # serving-side counters (router / drift / failover), same flow:
            # served_queries, plan_swaps, repaired_items, degraded_queries, ...
            out.update(self.online_stats)
        return out


def _traffic_gb(edge_ptr, edge_nodes, spans, cover_ptr, cover_parts,
                pin_parts, node_weights, item_gb):
    """Per-query (scanned_gb, shipped_gb) from a batched cover: the
    coordinator is the first chosen partition, every other cover member
    ships the bytes it serves."""
    w_pins = node_weights[edge_nodes]
    cw = np.concatenate([[0.0], np.cumsum(w_pins)])
    scanned = (cw[edge_ptr[1:]] - cw[edge_ptr[:-1]]) * item_gb
    first = np.full(len(edge_ptr) - 1, -1, dtype=np.int64)
    nz = spans > 0
    first[nz] = cover_parts[cover_ptr[:-1][nz]]
    local_w = np.where(
        pin_parts == np.repeat(first, np.diff(edge_ptr)), w_pins, 0.0,
    )
    cl = np.concatenate([[0.0], np.cumsum(local_w)])
    shipped = scanned - (cl[edge_ptr[1:]] - cl[edge_ptr[:-1]]) * item_gb
    return scanned, shipped


class Simulator:
    """Paper §5's simulator: place once, replay the trace (`run`), or serve
    it online through the streaming router with failure/drift events
    (`run_online`)."""

    def __init__(
        self,
        num_partitions: int,
        capacity: "float | np.ndarray | None" = None,
        energy_model: EnergyModel | None = None,
        item_gb: float = 1.0,
        profile: NodeProfile | None = None,
    ):
        self.n = num_partitions
        if capacity is None:
            if profile is None:
                raise ValueError("pass capacity or a NodeProfile")
            capacity = profile.capacity_arg()
        elif isinstance(capacity, np.ndarray):
            capacity = normalize_capacity(capacity)
        self.capacity = capacity
        self.profile = profile
        self.energy = energy_model or EnergyModel()
        self.item_gb = item_gb  # GB per unit of item weight

    def run(
        self,
        hg: Hypergraph,
        algorithm: Callable[..., Placement],
        name: str | None = None,
        trace: Hypergraph | None = None,
        validate: bool = True,
        **algo_kwargs,
    ) -> SimulationResult:
        """Fit `algorithm` on workload `hg`, then replay `trace` (defaults to
        the training workload itself — the paper replays the same trace)."""
        algo_name = name or getattr(algorithm, "__name__", "custom")
        # fresh partition memo per run: each algorithm pays for its own
        # hpa.partition work, so placement_seconds is run-order independent
        with hpa_mod.fresh_partition_cache():
            with _obs.timed("fit.place", algorithm=algo_name) as _t:
                pl = algorithm(hg, self.n, self.capacity, **algo_kwargs)
            dt = _t.seconds
        if validate:
            pl.validate()
        replay = trace if trace is not None else hg
        # one batched greedy cover for the whole trace (replica selection for
        # every query at once); pin_parts is the per-item serving partition
        with _obs.tracer().span("replay.cover", queries=replay.num_edges):
            cov = batched_cover_csr(
                replay.edge_ptr, replay.edge_nodes, pl.member,
                with_pin_parts=True,
            )
        spans = cov.spans
        access_load = np.bincount(
            cov.cover_parts, minlength=self.n
        ).astype(np.float64)
        # coordinator = first chosen partition; others ship their reads
        scanned, shipped = _traffic_gb(
            replay.edge_ptr, replay.edge_nodes, spans, cov.cover_ptr,
            cov.cover_parts, cov.pin_parts, hg.node_weights, self.item_gb,
        )
        total_shipped = float(shipped.sum())
        total_energy = float(
            self.energy.query_energy(scanned, spans, shipped).sum()
        )
        loads = pl.partition_weights()
        return SimulationResult(
            algorithm=algo_name,
            spans=spans,
            loads=loads,
            access_load=access_load,
            energy_joules=total_energy,
            shipped_gb=total_shipped,
            placement_seconds=dt,
            replication_factor=pl.replication_factor(),
            placement_stats=pl.stats,
            active_machines=int((loads > 0).sum()),
            cluster_power_w=self.energy.cluster_power(loads, self.profile),
        )

    def run_online(
        self,
        hg: Hypergraph,
        algorithm: Callable[..., Placement],
        name: str | None = None,
        trace: Hypergraph | None = None,
        events=None,
        service=None,
        refit_moves: int = 256,
        repair_k: int = 1,
        auto_repair: bool = True,
        validate: bool = True,
        health=None,
        on_alert=None,
        **algo_kwargs,
    ) -> SimulationResult:
        """Event-capable online replay: fit once, then SERVE the trace
        through the streaming router (`repro.online.ReplicaRouter`) in
        microbatches of ``flags.FLAGS["router_microbatch"]``.

        ``events`` is an iterable of ``(query_index, kind, arg)`` applied
        just before the query at that trace position is served:

          * ``("down", p)`` — partition p fails (membership row masked); with
            ``auto_repair`` the failover manager immediately re-replicates
            items that fell below ``repair_k`` live copies into surviving
            free space (span-aware gain).  Queries that still reference an
            uncovered item are counted ``degraded_queries``, not served.
          * ``("up", p)`` — p's saved replicas come back.
          * ``("repair", k)`` — explicit repair pass to k live copies.
          * ``("migrate", target)`` — begin migrating the live layout onto
            ``target`` (a `PlacementPlan` / `Placement` / bool member
            matrix, or a prebuilt `repro.online.MigrationPlan`).  With
            ``flags.FLAGS["migration_bandwidth"]`` == 0 (the default) the
            diff applies instantly between microbatches (the legacy atomic
            hot-swap); > 0 streams it as bandwidth-paced replica transfers
            (one tick per served query) while queries keep routing against
            the union layout, old replicas dropped only after every new
            copy of their item has landed.  Down/up events interact: a dead
            transfer destination holds its copies (and the drops waiting on
            them) until it returns, and a paced migration may START during
            an outage — the diff is taken against the post-restore layout
            and the already-down partitions' copies and drops defer until
            their rows come back.

        Passing a `PlacementService` as ``service`` arms the drift detector:
        after each microbatch the windowed avg span is compared against the
        fit-time baseline and a regression past
        ``flags.FLAGS["drift_threshold"]`` triggers an incremental refit on
        the sketch window, hot-swapped into the router between microbatches.
        During an outage the refit runs on the failure-masked surviving
        layout (down rows excluded from receiving copies), so adaptation
        continues while partitions are dead.  The returned result's
        ``spans`` cover the served queries only, and ``summary()`` carries
        the serving counters (served_queries, plan_swaps, repaired_items,
        degraded_queries, ...).

        Health monitoring (``flags.FLAGS["obs_health"]`` or an explicit
        ``health=HealthMonitor``): every periodic snapshot
        (``obs_snapshot_every``, required > 0 along with
        ``obs_level != "off"``) is fed to the monitor, whose SLO rules
        (windowed avg span vs the fit-time baseline, degraded rate, load
        skew, p99 microbatch latency, migration backlog) drive the
        firing/resolved alert machine — surfaced via ``on_alert``,
        tracer ``alert.*`` events, and
        ``online_stats["alerts_fired"/"alerts_resolved"]``.  Monitoring
        is read-only: it never changes placement, routing, or stats."""
        from .. import flags as _flags
        from ..online import DriftDetector, FailoverManager, ReplicaRouter
        from ..online.migration import (
            MigrationExecutor,
            MigrationPlan,
            plan_migration,
        )
        from .placement_service import PlacementPlan
        from .setcover import batched_spans_csr

        algo_name = name or getattr(algorithm, "__name__", "custom")
        with hpa_mod.fresh_partition_cache():
            with _obs.timed("fit.place", algorithm=algo_name) as _t:
                pl = algorithm(hg, self.n, self.capacity, **algo_kwargs)
            dt = _t.seconds
        if validate:
            pl.validate()
        replay = trace if trace is not None else hg
        # the live layout: plan, router and failover manager SHARE the
        # member matrix, so masking/repair is visible to the next microbatch
        live = Placement(pl.member, self.capacity, pl.node_weights)
        router = ReplicaRouter(
            live.member,
            node_cost=(self.profile.routing_cost()
                       if self.profile is not None else None),
        )
        failover = FailoverManager(live, profile=self.profile)

        _fit_base: list = []  # lazy cache: detector AND health share it

        def _fit_baseline() -> float:
            if not _fit_base:
                _fit_base.append(float(batched_spans_csr(
                    hg.edge_ptr, hg.edge_nodes, pl.member
                ).mean()) if hg.num_edges else 0.0)
            return _fit_base[0]

        detector = None
        if service is not None:
            detector = DriftDetector(
                PlacementPlan(pl.member, self.capacity, pl.node_weights,
                              algo_name),
                service, refit_moves=refit_moves,
            )
            detector.set_baseline(_fit_baseline())

        migrator: MigrationExecutor | None = None
        migration_ticks = 0
        mig_totals = dict(
            migrations=0, migration_copies=0, migration_drops=0,
            transferred=0.0, wasted=0.0, max_inflight=0.0,
        )

        def _fold_migration_stats(ex: MigrationExecutor) -> None:
            nonlocal migration_ticks
            migration_ticks += ex.now
            mig_totals["migration_copies"] += ex.stats["copies_done"]
            mig_totals["migration_drops"] += ex.stats["drops_done"]
            mig_totals["transferred"] += ex.stats["migration_transferred"]
            mig_totals["wasted"] += ex.stats["migration_wasted"]
            mig_totals["max_inflight"] = max(
                mig_totals["max_inflight"], ex.stats["max_inflight"]
            )

        def _finish_migration() -> None:
            # transfers landed in-place in the shared live matrix; count the
            # completed swap, re-sync the failover load ledger, and point the
            # drift detector's warm-start plan at the (now target) layout
            nonlocal migrator
            _fold_migration_stats(migrator)
            migrator = None
            failover.resync_loads()
            router.swap_plan(live.member)
            if detector is not None:
                detector.plan.member = live.member

        def _start_migration(target) -> None:
            nonlocal migrator
            if migrator is not None:
                raise ValueError(
                    "a migration is already in flight; issue the next "
                    "migrate event after it completes"
                )
            if isinstance(target, MigrationPlan):
                mplan = target
            else:
                member = getattr(target, "member", target)
                # diff against the post-restore view: a down partition's
                # saved row comes back verbatim on 'up', so its stale
                # replicas need scheduled (deferred) drops, not silence
                old = (failover.restored_member()
                       if failover.down_partitions else live.member)
                mplan = plan_migration(
                    old, member, node_weights=live.node_weights,
                )
            mig_totals["migrations"] += 1
            if mplan.bandwidth <= 0 or mplan.is_noop:
                # legacy path: atomic hot-swap between microbatches
                down = failover.down_partitions
                if len(down) and (
                    np.isin(mplan.copy_dest, down).any()
                    or np.isin(mplan.drop_part, down).any()
                ):
                    raise ValueError(
                        "instant migrate touches a down partition; set "
                        "migration_bandwidth > 0 to pace it through the "
                        "outage instead"
                    )
                mplan.apply(live.member)
                mig_totals["migration_copies"] += mplan.num_copies
                mig_totals["migration_drops"] += mplan.num_drops
                mig_totals["transferred"] += mplan.bytes_to_move(
                    live.node_weights
                )
                failover.resync_loads()
                router.swap_plan(live.member)
                if detector is not None:
                    detector.plan.member = live.member
            else:
                # partitions already down at migration start are seeded so
                # their copies/drops defer exactly like mid-flight failures
                migrator = MigrationExecutor(
                    mplan, live, down=failover.down_partitions
                )
                _obs.tracer().event(
                    "migration.start", copies=mplan.num_copies,
                    drops=mplan.num_drops,
                )

        def _repair_workload() -> Hypergraph:
            # repair against the live window when the sketch has traffic,
            # else against the fit workload
            if detector is not None and len(detector.sketch):
                return detector.sketch.to_hypergraph()
            return hg

        def _repair(k: int) -> None:
            if migrator is not None:
                failover.resync_loads()  # landed copies bypass the ledger
            failover.repair(_repair_workload(), k=k)
            if migrator is not None:
                migrator.refresh_loads()  # repair copies bypass the executor

        def _apply(kind: str, arg) -> None:
            if kind == "down":
                failover.partition_down(int(arg))
                if migrator is not None:
                    migrator.on_partition_down(int(arg))
                if auto_repair:
                    _repair(repair_k)
            elif kind == "up":
                failover.partition_up(int(arg))
                if migrator is not None:
                    migrator.on_partition_up(int(arg))
            elif kind == "repair":
                _repair(int(arg) if arg else repair_k)
            elif kind == "migrate":
                _start_migration(arg)
            else:
                raise ValueError(f"unknown online event kind {kind!r}")

        ev = sorted(
            ((int(at), kind, arg) for at, kind, arg in (events or [])),
            key=lambda t: t[0],
        )
        ev_i = 0
        nq = replay.num_edges
        mb = max(1, int(_flags.FLAGS.get("router_microbatch", 384)))
        pos = 0
        degraded = 0
        span_total = 0
        spans_parts: list[np.ndarray] = []
        total_energy = 0.0
        total_shipped = 0.0

        # periodic metrics snapshot every obs_snapshot_every served queries
        # (registry gauges always; a Chrome-trace counter event when tracing)
        snap_every = int(_flags.FLAGS.get("obs_snapshot_every", 0))
        _reg = _obs.registry()
        next_snap = snap_every if (snap_every > 0 and _reg.active) else 0

        # health monitoring rides on the periodic snapshots: flags-armed
        # construction here, or a caller-supplied monitor (inspectable
        # after the run).  Read-only by contract — evaluation happens
        # between microbatches and changes no serving state.
        if health is None and bool(_flags.FLAGS.get("obs_health", False)):
            from ..obs.health import HealthMonitor

            health = HealthMonitor.from_flags(on_alert=on_alert)
        if health is not None:
            if on_alert is not None and health.on_alert is None:
                health.on_alert = on_alert
            if not _reg.active or snap_every <= 0:
                raise ValueError(
                    "health monitoring needs obs_level != 'off' and "
                    "obs_snapshot_every > 0 (the monitor consumes the "
                    "periodic registry snapshots)"
                )
            if health.baseline_span is None:
                health.set_baseline(_fit_baseline())

        def _emit_snapshot() -> None:
            served = int(router.stats["served_queries"])
            _reg.set("online_served_queries", served)
            _reg.set("online_degraded_queries", degraded)
            _reg.set("online_span_sum", float(span_total))
            _reg.gauge_vector("online_partition_load").set(router.load.copy())
            inflight = (migrator.inflight_bytes if migrator is not None
                        else 0.0)
            _reg.set("migration_inflight", inflight)
            tr = _obs.tracer()
            if tr.active:
                tr.counter(
                    "online.snapshot", served=served, degraded=degraded,
                    migration_inflight=inflight,
                    windowed_avg_span=(detector.windowed_avg_span
                                       if detector is not None else 0.0),
                )
            if health is not None:
                # deterministic time axis: attempted queries, so windows
                # and rates are reproducible run-to-run
                health.observe(_reg.snapshot(), t=float(served + degraded))

        while pos < nq:
            while ev_i < len(ev) and ev[ev_i][0] <= pos:
                _apply(ev[ev_i][1], ev[ev_i][2])
                ev_i += 1
            stop = min(pos + mb, nq)
            if ev_i < len(ev):
                stop = min(stop, max(ev[ev_i][0], pos + 1))
            ptr = replay.edge_ptr[pos: stop + 1] - replay.edge_ptr[pos]
            nodes = replay.edge_nodes[
                replay.edge_ptr[pos]: replay.edge_ptr[stop]
            ]
            ok = failover.serveable_mask(ptr, nodes)
            if not ok.all():
                degraded += int((~ok).sum())
                sptr, sidx = Hypergraph(
                    ptr, nodes, live.node_weights,
                    np.ones(len(ptr) - 1),
                ).pin_indices(np.flatnonzero(ok))
                ptr, nodes = sptr, nodes[sidx]
            batch = router.route_csr(ptr, nodes)
            spans_parts.append(batch.spans)
            if next_snap:  # running span sum only feeds snapshot gauges
                span_total += int(batch.spans.sum())
            scanned, shipped = _traffic_gb(
                batch.edge_ptr, batch.edge_nodes, batch.spans,
                batch.cover_ptr, batch.cover_parts, batch.pin_parts,
                live.node_weights, self.item_gb,
            )
            total_energy += float(
                self.energy.query_energy(scanned, batch.spans, shipped).sum()
            )
            total_shipped += float(shipped.sum())
            if migrator is not None:
                # one migration tick per served query: transfers pace
                # against traffic, so bandwidth is "bytes per query"
                migrator.advance(stop - pos)
                if migrator.done:
                    _finish_migration()
            if detector is not None:
                detector.observe(
                    [nodes[ptr[i]: ptr[i + 1]] for i in range(len(ptr) - 1)],
                    batch.spans,
                )
                # hot-swap between microbatches.  During an outage the refit
                # runs on the failure-masked layout with the down rows
                # excluded from receiving copies (dest_mask), so drift
                # adaptation continues through arbitrarily long outages —
                # skipped only while coverage is still broken (a refit
                # cannot warm-start from a layout with unplaced items) or
                # while a migration is in flight (the live layout is a
                # union, not a fit result to warm-start from).
                if migrator is None and detector.should_refit():
                    down = failover.down_partitions
                    if not down:
                        new_plan = detector.refit()
                    elif len(failover.uncovered_items()) == 0:
                        survivors = np.ones(self.n, dtype=bool)
                        survivors[down] = False
                        new_plan = detector.refit(dest_mask=survivors)
                    else:
                        new_plan = None
                    if new_plan is None:
                        pass
                    elif float(_flags.FLAGS["migration_bandwidth"]) > 0:
                        # pace the hot-swap: stream the refit diff as
                        # transfers instead of swapping atomically.  `live`
                        # keeps serving (union layout) and adopts the target
                        # in place as copies land.
                        _start_migration(new_plan)
                    else:
                        router.swap_plan(new_plan.member)
                        live = new_plan.as_placement()
                        failover.rebase(live)
            if next_snap and router.stats["served_queries"] >= next_snap:
                _emit_snapshot()
                while next_snap <= router.stats["served_queries"]:
                    next_snap += snap_every
            pos = stop
        while ev_i < len(ev):  # events scheduled at/after the trace end
            _apply(ev[ev_i][1], ev[ev_i][2])
            ev_i += 1

        online_stats = dict(
            served_queries=int(router.stats["served_queries"]),
            microbatches=int(router.stats["microbatches"]),
            plan_swaps=int(router.stats["plan_swaps"]),
            degraded_queries=int(degraded),
            partitions_down=int(failover.stats["partitions_down"]),
            repaired_items=int(failover.stats["repaired_items"]),
            unrepairable_items=int(failover.stats["unrepairable_items"]),
        )
        if detector is not None:
            online_stats.update(
                drift_fires=int(detector.stats["drift_fires"]),
                refits=int(detector.stats["refits"]),
                windowed_avg_span=round(detector.windowed_avg_span, 4),
            )
        if health is not None:
            online_stats.update(
                alerts_fired=int(health.stats["alerts_fired"]),
                alerts_resolved=int(health.stats["alerts_resolved"]),
            )
        if mig_totals["migrations"]:
            if migrator is not None:  # trace ended mid-migration
                _fold_migration_stats(migrator)
            online_stats.update(
                migrations=int(mig_totals["migrations"]),
                migration_copies=int(mig_totals["migration_copies"]),
                migration_drops=int(mig_totals["migration_drops"]),
                migration_transfer_gb=round(
                    mig_totals["transferred"] * self.item_gb, 4
                ),
                migration_wasted_gb=round(
                    mig_totals["wasted"] * self.item_gb, 4
                ),
                migration_max_inflight_gb=round(
                    mig_totals["max_inflight"] * self.item_gb, 4
                ),
                migration_ticks=int(migration_ticks),
                migration_done=bool(migrator is None),
            )
        spans = (
            np.concatenate(spans_parts) if spans_parts
            else np.zeros(0, dtype=np.int64)
        )
        live = failover.pl  # the final hot-swapped layout
        final_loads = live.partition_weights()
        return SimulationResult(
            algorithm=algo_name,
            spans=spans,
            loads=final_loads,
            access_load=router.load.copy(),
            energy_joules=total_energy,
            shipped_gb=total_shipped,
            placement_seconds=dt,
            replication_factor=live.replication_factor(),
            placement_stats=pl.stats,
            online_stats=online_stats,
            active_machines=int((final_loads > 0).sum()),
            cluster_power_w=self.energy.cluster_power(
                final_loads, self.profile
            ),
        )

    def compare(
        self, hg: Hypergraph, algorithms: dict[str, Callable[..., Placement]],
        **kw,
    ) -> dict[str, SimulationResult]:
        return {
            name: self.run(hg, fn, name=name, **kw)
            for name, fn in algorithms.items()
        }
