"""Trace-driven simulation framework (paper §5).

Instantiates N partitions of capacity C, runs a placement algorithm, then
replays a query trace measuring: span profile, per-partition load, activated
machines, estimated communication bytes, and estimated energy.

Energy model
------------
The paper estimates energy with a Mantis-style full-system power model fed by
hardware counters; no counters exist in this container, so we use the affine
model the paper's measurements support (fig. 1/5: energy grows ~linearly with
span at fixed work):

    E(query) = e_work * W + e_machine * span + e_net * bytes_shipped

with  bytes_shipped = sum of item sizes read from non-coordinator partitions
(every remote partition ships its partial result; span-1 remote reads).
Constants default to an Itanium-server-like profile (the paper's testbed):
~250 J of fixed per-machine activation+coordination cost for a ~1 s analytical
query slice, ~60 J/GB on the wire, e_work scaling with the bytes scanned.
These reproduce the paper's observed 31-79 % energy reductions when span
drops from ~20 to ~3 (validated in benchmarks/energy_model.py).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

from . import hpa as hpa_mod
from .hypergraph import Hypergraph
from .setcover import Placement, batched_cover_csr

__all__ = ["SimulationResult", "Simulator", "EnergyModel"]


@dataclasses.dataclass
class EnergyModel:
    e_work_per_gb: float = 120.0  # J per GB scanned (CPU+IO)
    e_machine: float = 250.0      # J per activated machine per query
    e_net_per_gb: float = 60.0    # J per GB shipped cross-machine

    def query_energy(self, scanned_gb: float, span: int, shipped_gb: float) -> float:
        return (
            self.e_work_per_gb * scanned_gb
            + self.e_machine * span
            + self.e_net_per_gb * shipped_gb
        )


@dataclasses.dataclass
class SimulationResult:
    algorithm: str
    spans: np.ndarray               # (NQ,)
    loads: np.ndarray               # (N,) storage load (weight)
    access_load: np.ndarray         # (N,) #query-accesses per partition
    energy_joules: float
    shipped_gb: float
    placement_seconds: float
    replication_factor: float
    placement_stats: dict | None = None  # fitter diagnostics (Placement.stats)

    @property
    def avg_span(self) -> float:
        return float(self.spans.mean()) if len(self.spans) else 0.0

    @property
    def max_span(self) -> int:
        return int(self.spans.max()) if len(self.spans) else 0

    @property
    def load_imbalance(self) -> float:
        """max access load / mean access load (1.0 = perfectly balanced)."""
        m = self.access_load.mean()
        return float(self.access_load.max() / m) if m > 0 else 0.0

    def summary(self) -> dict:
        out = dict(
            algorithm=self.algorithm,
            avg_span=round(self.avg_span, 4),
            max_span=self.max_span,
            energy_kj=round(self.energy_joules / 1e3, 2),
            shipped_gb=round(self.shipped_gb, 3),
            rf=round(self.replication_factor, 3),
            placement_s=round(self.placement_seconds, 3),
            load_imbalance=round(self.load_imbalance, 3),
        )
        if self.placement_stats:
            # fitter-side counters (e.g. LMBR moves / gain-cache hit rate)
            out.update(
                {f"fit_{k}": v for k, v in self.placement_stats.items()}
            )
        return out


class Simulator:
    """Paper §5's simulator: place once, replay the trace."""

    def __init__(
        self,
        num_partitions: int,
        capacity: float,
        energy_model: EnergyModel | None = None,
        item_gb: float = 1.0,
    ):
        self.n = num_partitions
        self.capacity = capacity
        self.energy = energy_model or EnergyModel()
        self.item_gb = item_gb  # GB per unit of item weight

    def run(
        self,
        hg: Hypergraph,
        algorithm: Callable[..., Placement],
        name: str | None = None,
        trace: Hypergraph | None = None,
        validate: bool = True,
        **algo_kwargs,
    ) -> SimulationResult:
        """Fit `algorithm` on workload `hg`, then replay `trace` (defaults to
        the training workload itself — the paper replays the same trace)."""
        # fresh partition memo per run: each algorithm pays for its own
        # hpa.partition work, so placement_seconds is run-order independent
        with hpa_mod.fresh_partition_cache():
            t0 = time.perf_counter()
            pl = algorithm(hg, self.n, self.capacity, **algo_kwargs)
            dt = time.perf_counter() - t0
        if validate:
            pl.validate()
        replay = trace if trace is not None else hg
        # one batched greedy cover for the whole trace (replica selection for
        # every query at once); pin_parts is the per-item serving partition
        cov = batched_cover_csr(
            replay.edge_ptr, replay.edge_nodes, pl.member, with_pin_parts=True
        )
        spans = cov.spans
        access_load = np.bincount(
            cov.cover_parts, minlength=self.n
        ).astype(np.float64)
        w_pins = hg.node_weights[replay.edge_nodes]
        cw = np.concatenate([[0.0], np.cumsum(w_pins)])
        scanned = (cw[replay.edge_ptr[1:]] - cw[replay.edge_ptr[:-1]]) \
            * self.item_gb
        # coordinator = first chosen partition; others ship their reads
        first = np.full(replay.num_edges, -1, dtype=np.int64)
        nz = spans > 0
        first[nz] = cov.cover_parts[cov.cover_ptr[:-1][nz]]
        local_w = np.where(
            cov.pin_parts == np.repeat(first, np.diff(replay.edge_ptr)),
            w_pins, 0.0,
        )
        cl = np.concatenate([[0.0], np.cumsum(local_w)])
        shipped = scanned - (cl[replay.edge_ptr[1:]] - cl[replay.edge_ptr[:-1]]) \
            * self.item_gb
        total_shipped = float(shipped.sum())
        total_energy = float(
            self.energy.query_energy(scanned, spans, shipped).sum()
        )
        return SimulationResult(
            algorithm=name or getattr(algorithm, "__name__", "custom"),
            spans=spans,
            loads=pl.partition_weights(),
            access_load=access_load,
            energy_joules=total_energy,
            shipped_gb=total_shipped,
            placement_seconds=dt,
            replication_factor=pl.replication_factor(),
            placement_stats=pl.stats,
        )

    def compare(
        self, hg: Hypergraph, algorithms: dict[str, Callable[..., Placement]],
        **kw,
    ) -> dict[str, SimulationResult]:
        return {
            name: self.run(hg, fn, name=name, **kw)
            for name, fn in algorithms.items()
        }
