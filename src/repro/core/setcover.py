"""Replica selection via greedy set cover (paper §3, §4.1).

With replication, computing a query's span is the minimum set-cover problem
(NP-hard); the greedy algorithm gives the best-known log|Q| approximation and
doubles as the *replica selection* policy: the chosen partitions tell each
query which copy of each item to read.

`Placement` is the layout object shared by every algorithm: a boolean
membership matrix (partitions x items) plus per-partition weight accounting.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = ["Placement", "greedy_set_cover", "cover_for_query"]


@dataclasses.dataclass
class Placement:
    """Layout of items onto partitions. member[p, v] == True iff a copy of
    item v is stored on partition p."""

    member: np.ndarray  # (N, V) bool
    capacity: float
    node_weights: np.ndarray  # (V,)

    @staticmethod
    def empty(num_partitions: int, num_items: int, capacity: float,
              node_weights: np.ndarray | None = None) -> "Placement":
        if node_weights is None:
            node_weights = np.ones(num_items, dtype=np.float64)
        return Placement(
            np.zeros((num_partitions, num_items), dtype=bool),
            float(capacity),
            np.asarray(node_weights, dtype=np.float64),
        )

    # ------------------------------------------------------------- accessors
    @property
    def num_partitions(self) -> int:
        return self.member.shape[0]

    @property
    def num_items(self) -> int:
        return self.member.shape[1]

    def partition_items(self, p: int) -> np.ndarray:
        return np.flatnonzero(self.member[p])

    def partition_weight(self, p: int) -> float:
        return float(self.node_weights[self.member[p]].sum())

    def partition_weights(self) -> np.ndarray:
        return self.member @ self.node_weights

    def free_space(self, p: int) -> float:
        return self.capacity - self.partition_weight(p)

    def replication_factor(self) -> float:
        placed = self.member.sum(axis=0)
        placed = placed[placed > 0]
        return float(placed.mean()) if len(placed) else 0.0

    def copies_of(self, v: int) -> np.ndarray:
        return np.flatnonzero(self.member[:, v])

    # ------------------------------------------------------------- mutation
    def add(self, p: int, items) -> None:
        self.member[p, np.asarray(items, dtype=np.int64)] = True

    def add_partition(self) -> int:
        self.member = np.vstack(
            [self.member, np.zeros((1, self.num_items), dtype=bool)]
        )
        return self.num_partitions - 1

    def validate(self, tol: float = 1e-9) -> None:
        w = self.partition_weights()
        if (w > self.capacity + tol).any():
            bad = int(np.argmax(w))
            raise ValueError(
                f"partition {bad} over capacity: {w[bad]:.1f} > {self.capacity}"
            )
        placed = self.member.any(axis=0)
        # items that appear in no partition are only legal if they are phantom
        # (weight 0) items
        missing = np.flatnonzero(~placed & (self.node_weights > 0))
        if len(missing):
            raise ValueError(f"{len(missing)} items unplaced, e.g. {missing[:5]}")


def greedy_set_cover(query: np.ndarray, member: np.ndarray) -> list[int]:
    """getSpanningPartitions: minimal-ish set of partitions covering `query`.

    Iteratively picks the partition with the largest intersection with the
    still-uncovered items (ties -> lowest partition id, deterministic).
    """
    query = np.asarray(query, dtype=np.int64)
    remaining = np.ones(len(query), dtype=bool)
    sub = member[:, query]  # (N, |q|)
    chosen: list[int] = []
    while remaining.any():
        gains = (sub & remaining[None, :]).sum(axis=1)
        p = int(np.argmax(gains))
        if gains[p] == 0:
            raise ValueError(
                f"query items {query[remaining][:5]} not stored on any partition"
            )
        chosen.append(p)
        remaining &= ~sub[p]
    return chosen


def cover_for_query(query: np.ndarray, member: np.ndarray):
    """Like greedy_set_cover but also returns, per chosen partition, the item
    ids the query reads from it (getAccessedItems for every member of the
    cover).  Items are attributed to the first chosen partition that holds
    them — i.e. the actual replica-selection decision."""
    query = np.asarray(query, dtype=np.int64)
    remaining = np.ones(len(query), dtype=bool)
    sub = member[:, query]
    chosen: list[int] = []
    accessed: list[np.ndarray] = []
    while remaining.any():
        gains = (sub & remaining[None, :]).sum(axis=1)
        p = int(np.argmax(gains))
        if gains[p] == 0:
            raise ValueError("query contains an unplaced item")
        newly = sub[p] & remaining
        chosen.append(p)
        accessed.append(query[newly])
        remaining &= ~newly
    return chosen, accessed


def query_span(query: np.ndarray, member: np.ndarray) -> int:
    """getQuerySpan."""
    return len(greedy_set_cover(query, member))


def spans_for_workload(hg, placement: Placement) -> np.ndarray:
    """Span of every hyperedge in `hg` under `placement` (vectorized loop)."""
    member = placement.member
    out = np.zeros(hg.num_edges, dtype=np.int64)
    for e in range(hg.num_edges):
        out[e] = len(greedy_set_cover(hg.edge(e), member))
    return out
