"""Replica selection via greedy set cover (paper §3, §4.1).

With replication, computing a query's span is the minimum set-cover problem
(NP-hard); the greedy algorithm gives the best-known log|Q| approximation and
doubles as the *replica selection* policy: the chosen partitions tell each
query which copy of each item to read.

`Placement` is the layout object shared by every algorithm: a boolean
membership matrix (partitions x items) plus per-partition weight accounting.

Span engine
-----------
Two evaluation paths produce bit-identical covers:

* the per-query reference (`greedy_set_cover` / `cover_for_query`): a Python
  loop over greedy rounds, kept as the executable specification;
* the batched bitset engine (`batched_cover_csr` / `batched_spans_csr`):
  queries are bucketed by word count W = ceil(|q|/64) and each query's
  membership submatrix is packed into uint64 words — ``codes[e, p, w]`` holds
  bit j iff partition p stores the query's (64*w + j)-th pin.  One greedy
  round for *every* still-uncovered query in the bucket is then a single
  popcount of ``codes & remaining`` followed by a row-wise argmax, instead
  of one Python loop per query.  The popcount backend is chosen PER BUCKET
  ROUND by ``_gain_matrix``: numpy ``bitwise_count`` below
  ``repro.flags.FLAGS["span_dispatch_threshold"]`` words, the accelerated
  path (Pallas span_gain kernel on TPU, jitted jnp elsewhere) above it;
  ``FLAGS["span_backend"]`` pins one backend globally instead.

Tie-break contract: every engine picks the LOWEST partition id among
partitions with maximal intersection gain (``np.argmax`` semantics).  The
batched engine is exact — same chosen partitions, same selection order, same
replica attribution, same ValueError on unplaced items — which the
equivalence tests in ``tests/test_span_engine.py`` enforce.

`SpanMaintainer` layers an incremental cache on top: per-edge covers are
recomputed only for edges incident to items whose membership changed
(dirty-set invalidation), which turns the inner loops of IHPA / DS / LMBR
from O(E) full sweeps into O(touched) batched refreshes.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .. import flags as _flags
from .. import obs as _obs
from .cluster import normalize_capacity

__all__ = [
    "Placement",
    "queries_to_csr",
    "greedy_set_cover",
    "cover_for_query",
    "query_span",
    "spans_for_workload",
    "WorkloadCover",
    "batched_cover_csr",
    "batched_spans_csr",
    "SpanMaintainer",
]

_WORD = 64


def queries_to_csr(queries) -> "tuple[np.ndarray, np.ndarray]":
    """CSR (ptr, nodes) of a list of queries (each an int sequence).  Pure
    packing — callers wanting set semantics deduplicate first (Hypergraph
    CSR edges and the online router's inputs already are)."""
    lists = [np.asarray(q, dtype=np.int64) for q in queries]
    ptr = np.zeros(len(lists) + 1, dtype=np.int64)
    ptr[1:] = np.cumsum([len(q) for q in lists])
    nodes = (
        np.concatenate(lists) if lists else np.zeros(0, dtype=np.int64)
    )
    return ptr, nodes


@dataclasses.dataclass
class Placement:
    """Layout of items onto partitions. member[p, v] == True iff a copy of
    item v is stored on partition p.

    ``stats`` is an optional fitting-diagnostics dict attached by the
    producing algorithm (e.g. LMBR's move-engine counters); it never
    influences placement semantics.

    ``capacity`` is either the classic scalar (every partition holds the
    same weight) or an (N,) per-partition vector for heterogeneous
    clusters (repro.core.cluster.NodeProfile).  Uniform vectors should be
    collapsed to the scalar via ``normalize_capacity`` before construction
    — `empty` does so — which keeps homogeneous profiles bit-identical to
    the scalar model."""

    member: np.ndarray  # (N, V) bool
    capacity: "float | np.ndarray"  # scalar, or (N,) per-partition vector
    node_weights: np.ndarray  # (V,)
    stats: dict | None = None

    @staticmethod
    def empty(num_partitions: int, num_items: int, capacity,
              node_weights: np.ndarray | None = None) -> "Placement":
        if node_weights is None:
            node_weights = np.ones(num_items, dtype=np.float64)
        return Placement(
            np.zeros((num_partitions, num_items), dtype=bool),
            normalize_capacity(capacity),
            np.asarray(node_weights, dtype=np.float64),
        )

    # ------------------------------------------------------------- accessors
    @property
    def num_partitions(self) -> int:
        return self.member.shape[0]

    @property
    def num_items(self) -> int:
        return self.member.shape[1]

    def partition_items(self, p: int) -> np.ndarray:
        return np.flatnonzero(self.member[p])

    def partition_weight(self, p: int) -> float:
        return float(self.node_weights[self.member[p]].sum())

    def partition_weights(self) -> np.ndarray:
        return self.member @ self.node_weights

    def cap_of(self, p: int) -> float:
        """Capacity of partition p (scalar capacities apply to every row)."""
        cap = self.capacity
        if isinstance(cap, np.ndarray) and cap.ndim:
            return float(cap[p])
        return float(cap)

    @property
    def capacity_vec(self) -> np.ndarray:
        """(N,) per-partition capacity (scalar capacity broadcast)."""
        cap = self.capacity
        if isinstance(cap, np.ndarray) and cap.ndim:
            return cap
        return np.full(self.num_partitions, float(cap))

    def free_space(self, p: int) -> float:
        return self.cap_of(p) - self.partition_weight(p)

    def replication_factor(self) -> float:
        placed = self.member.sum(axis=0)
        placed = placed[placed > 0]
        return float(placed.mean()) if len(placed) else 0.0

    def copies_of(self, v: int) -> np.ndarray:
        return np.flatnonzero(self.member[:, v])

    # ------------------------------------------------------------- mutation
    def add(self, p: int, items) -> None:
        self.member[p, np.asarray(items, dtype=np.int64)] = True

    def add_partition(self, capacity: float | None = None) -> int:
        self.member = np.vstack(
            [self.member, np.zeros((1, self.num_items), dtype=bool)]
        )
        cap = self.capacity
        if isinstance(cap, np.ndarray) and cap.ndim:
            new_cap = float(np.min(cap)) if capacity is None else float(capacity)
            self.capacity = np.append(cap, new_cap)
        elif capacity is not None and float(capacity) != float(cap):
            self.capacity = np.append(
                np.full(self.num_partitions - 1, float(cap)), float(capacity)
            )
        return self.num_partitions - 1

    def validate(self, tol: float = 1e-9) -> None:
        w = self.partition_weights()
        if (w > self.capacity + tol).any():
            cap = self.capacity_vec
            bad = int(np.argmax(w - cap))
            raise ValueError(
                f"partition {bad} over capacity: {w[bad]:.1f} > {cap[bad]}"
            )
        placed = self.member.any(axis=0)
        # items that appear in no partition are only legal if they are phantom
        # (weight 0) items
        missing = np.flatnonzero(~placed & (self.node_weights > 0))
        if len(missing):
            raise ValueError(f"{len(missing)} items unplaced, e.g. {missing[:5]}")


def greedy_set_cover(query: np.ndarray, member: np.ndarray) -> list[int]:
    """getSpanningPartitions: minimal-ish set of partitions covering `query`.

    Iteratively picks the partition with the largest intersection with the
    still-uncovered items (ties -> lowest partition id, deterministic).
    """
    query = np.asarray(query, dtype=np.int64)
    remaining = np.ones(len(query), dtype=bool)
    sub = member[:, query]  # (N, |q|)
    chosen: list[int] = []
    while remaining.any():
        gains = (sub & remaining[None, :]).sum(axis=1)
        p = int(np.argmax(gains))
        if gains[p] == 0:
            raise ValueError(
                f"query items {query[remaining][:5]} not stored on any partition"
            )
        chosen.append(p)
        remaining &= ~sub[p]
    return chosen


def cover_for_query(query: np.ndarray, member: np.ndarray):
    """Like greedy_set_cover but also returns, per chosen partition, the item
    ids the query reads from it (getAccessedItems for every member of the
    cover).  Items are attributed to the first chosen partition that holds
    them — i.e. the actual replica-selection decision.  Same tie-break as
    greedy_set_cover (maximal gain, ties -> lowest partition id), so the
    chosen list is identical to it; raises ValueError on unplaced items."""
    query = np.asarray(query, dtype=np.int64)
    remaining = np.ones(len(query), dtype=bool)
    sub = member[:, query]
    chosen: list[int] = []
    accessed: list[np.ndarray] = []
    while remaining.any():
        gains = (sub & remaining[None, :]).sum(axis=1)
        p = int(np.argmax(gains))
        if gains[p] == 0:
            raise ValueError("query contains an unplaced item")
        newly = sub[p] & remaining
        chosen.append(p)
        accessed.append(query[newly])
        remaining &= ~newly
    return chosen, accessed


def query_span(query: np.ndarray, member: np.ndarray) -> int:
    """getQuerySpan: size of the greedy cover (exact same selection as
    `greedy_set_cover`, ties -> lowest partition id)."""
    return len(greedy_set_cover(query, member))


# ===================================================================== engine
def _gains_numpy(codes: np.ndarray, rem: np.ndarray) -> np.ndarray:
    """Popcount gains: codes (A, N, W) uint64, rem (A, W) -> (A, N) int64."""
    return np.bitwise_count(codes & rem[:, None, :]).sum(axis=2, dtype=np.int64)


_ACCEL_BACKEND = None  # resolved once: "pallas" on TPU, "jax" elsewhere


def _accel_backend() -> str | None:
    """Pick the accelerated gain backend available on this host (None if jax
    is missing or fails to initialize — the numpy oracle then serves every
    bucket; all backends are bit-identical, so this only costs speed)."""
    global _ACCEL_BACKEND
    if _ACCEL_BACKEND is None:
        try:
            import jax

            _ACCEL_BACKEND = (
                "pallas" if jax.default_backend() == "tpu" else "jax"
            )
        except Exception:  # no jax, or a broken accelerator runtime
            _ACCEL_BACKEND = "none"
    return None if _ACCEL_BACKEND == "none" else _ACCEL_BACKEND


def _gain_matrix_w1(codes1: np.ndarray, rem1: np.ndarray) -> np.ndarray:
    """Single-word variant of `_gain_matrix`: codes1 (A, N) uint64, rem1
    (A,) -> (A, N) gains.  Same per-round dispatch rule; the numpy path
    skips the word-axis reduction (gain values are identical, only the
    dtype differs — argmax/zero tests are unaffected)."""
    backend = _flags.FLAGS.get("span_backend", "auto")
    if backend == "auto":
        thresh = int(_flags.FLAGS.get("span_dispatch_threshold", 48_000))
        backend = "numpy" if codes1.size < thresh else (
            _accel_backend() or "numpy"
        )
    if backend == "numpy":
        return np.bitwise_count(codes1 & rem1[:, None])
    try:
        from ..kernels.span_gain.ops import span_gains

        return span_gains(codes1[:, :, None], rem1[:, None], force=backend)
    except Exception:
        return np.bitwise_count(codes1 & rem1[:, None])


def _gain_matrix(codes: np.ndarray, rem: np.ndarray) -> np.ndarray:
    """Per-bucket backend dispatch for one greedy round.

    Every backend is bit-exact (integer popcount), so this is purely a
    performance decision: each call covers one (bucket, round) with
    codes.size = A * N * W words of gain work.  Small rounds stay on numpy
    (crossing into jax costs more than the popcount); rounds past the
    calibrated span_dispatch_threshold run on the accelerated backend — the
    Pallas span_gain kernel on TPU, the jitted jnp popcount elsewhere.
    """
    backend = _flags.FLAGS.get("span_backend", "auto")
    if backend == "auto":
        thresh = int(_flags.FLAGS.get("span_dispatch_threshold", 48_000))
        backend = "numpy" if codes.size < thresh else (
            _accel_backend() or "numpy"
        )
    if backend == "numpy":
        return _gains_numpy(codes, rem)
    try:
        from ..kernels.span_gain.ops import span_gains

        return span_gains(codes, rem, force=backend)
    except Exception:
        # no jax / broken accelerator runtime: the numpy oracle is
        # bit-identical, so degrade silently to it rather than fail placement
        return _gains_numpy(codes, rem)


# ---------------------------------------------- device-resident round loop
# Engine-level dispatch counters (observability, not control flow): how many
# word-count buckets resolved on which cover loop and how many greedy rounds
# each side ran.  `lmbr`/`PlacementService` snapshot deltas into
# Placement.stats; benchmarks read them to report transfer counts.
ENGINE_COUNTERS = {
    "device_buckets": 0,
    "host_buckets": 0,
    "device_rounds": 0,
    "host_rounds": 0,
}


def engine_counters() -> dict:
    """Snapshot of the cover-engine dispatch counters."""
    return dict(ENGINE_COUNTERS)


_ROUND_LOOPS: dict[tuple[int, int, int, int], object] = {}


def _round_loop_fn(B: int, N: int, W2: int, Rmax: int):
    """Compile (and cache) the jitted whole-round cover loop for one padded
    bucket shape.

    The loop fuses mask+popcount+argmax+scatter for EVERY greedy round of
    the bucket inside one `lax.while_loop`, so cover state (remaining-bit
    words, chosen matrix) stays device-resident: one upload of the packed
    codes, one download of the chosen matrix, zero per-round transfers.

    Exactness contract (mirrors the host loop bit-for-bit): gains are
    integer popcounts summed over uint32 lanes, `argmax` takes the first
    maximum (ties -> lowest partition id), and a query whose max gain hits
    zero while bits remain raises in the host path — here it sets a `bad`
    flag and terminates the row, and the caller re-runs the bucket on host
    to raise the identical ValueError.
    """
    key = (B, N, W2, Rmax)
    fn = _ROUND_LOOPS.get(key)
    if fn is not None:
        return fn
    reg = _obs.registry()
    if reg.active:
        # a compile-cache miss IS a jit retrace, keyed by batch-shape class
        reg.inc("jit_retraces", shape=f"B{B}.N{N}.W{W2}.R{Rmax}")
        _obs.tracer().event("jit.retrace", kernel="cover_round_loop",
                            B=B, N=N, W=W2, Rmax=Rmax)
    import jax
    import jax.numpy as jnp
    from jax import lax

    def loop(codes, rem):  # codes (B, N, W2) uint32, rem (B, W2) uint32
        ch0 = jnp.full((Rmax, B), -1, dtype=jnp.int32)
        bad0 = jnp.zeros((B,), dtype=bool)

        def cond(state):
            r, rem, ch, bad = state
            return (r < Rmax) & jnp.any(rem != 0)

        def body(state):
            r, rem, ch, bad = state
            active = jnp.any(rem != 0, axis=1)
            g = (
                lax.population_count(codes & rem[:, None, :])
                .astype(jnp.int32)
                .sum(axis=2)
            )
            p = jnp.argmax(g, axis=1).astype(jnp.int32)
            gmax = jnp.take_along_axis(g, p[:, None], axis=1)[:, 0]
            newbad = active & (gmax == 0)
            ok = active & ~newbad
            sel = jnp.take_along_axis(codes, p[:, None, None], axis=1)[:, 0]
            rem = jnp.where(ok[:, None], rem & ~sel, rem)
            # bad rows terminate (their chosen stays -1); the caller falls
            # back to the host loop to raise the exact engine error
            rem = jnp.where(newbad[:, None], jnp.uint32(0), rem)
            ch = ch.at[r].set(jnp.where(ok, p, jnp.int32(-1)))
            return r + 1, rem, ch, bad | newbad

        _, _, ch, bad = lax.while_loop(
            cond, body, (jnp.int32(0), rem, ch0, bad0)
        )
        return ch, bad

    fn = jax.jit(loop)
    _ROUND_LOOPS[key] = fn
    return fn


def _device_cover_rounds(codes: np.ndarray, rem: np.ndarray):
    """Resolve one packed bucket on device.  codes (B, N, W) uint64, rem
    (B, W) uint64 -> ch (B, R) int64, or None to fall back to the host loop
    (jax unavailable, or a query in the bucket is uncoverable — the host
    loop then raises the canonical error)."""
    B, N, W = codes.shape
    if B == 0:
        return np.zeros((0, 0), dtype=np.int64)
    try:
        B2 = 1 << max(3, (B - 1).bit_length())  # pow2 pad bounds jit churn
        Rmax = min(N, _WORD * W)
        fn = _round_loop_fn(B2, N, 2 * W, Rmax)
        c32 = np.zeros((B2, N, 2 * W), dtype=np.uint32)
        c32[:B] = codes.view(np.uint32).reshape(B, N, 2 * W)
        r32 = np.zeros((B2, 2 * W), dtype=np.uint32)
        r32[:B] = rem.view(np.uint32).reshape(B, 2 * W)
        ch_d, bad_d = fn(c32, r32)
        ch = np.asarray(ch_d)[:, :B]
        if np.asarray(bad_d)[:B].any():
            return None
    except Exception:
        return None
    used = int((ch >= 0).any(axis=1).sum())  # rounds are prefix-dense
    return ch[:used].T.astype(np.int64)


@dataclasses.dataclass
class WorkloadCover:
    """Batched cover of a CSR query set.

    spans:       (E,) greedy cover size per query
    cover_ptr:   (E+1,) CSR offsets into cover_parts
    cover_parts: (sum spans,) chosen partitions in greedy selection order
    pin_parts:   (P,) or None — for every pin of the input CSR, the partition
                 that serves it (the replica-selection decision); aligned with
                 the edge_nodes array the cover was computed from
    """

    spans: np.ndarray
    cover_ptr: np.ndarray
    cover_parts: np.ndarray
    pin_parts: np.ndarray | None = None

    def chosen(self, e: int) -> np.ndarray:
        return self.cover_parts[self.cover_ptr[e]: self.cover_ptr[e + 1]]


def _cover_bucket(edge_ptr, edge_nodes, member, b_idx, W, spans, pin_parts):
    """Run batched greedy cover for one word-count bucket.  Returns the
    per-round chosen matrix ch (B, R) with -1 padding."""
    sizes = edge_ptr[b_idx + 1] - edge_ptr[b_idx]
    B = len(b_idx)
    loc_ptr = np.zeros(B + 1, dtype=np.int64)
    np.cumsum(sizes, out=loc_ptr[1:])
    P = int(loc_ptr[-1])
    pin_e = np.repeat(np.arange(B, dtype=np.int64), sizes)
    pos = np.arange(P, dtype=np.int64) - loc_ptr[pin_e]
    pins = edge_nodes[edge_ptr[b_idx][pin_e] + pos]

    # pack the per-query membership submatrices into uint64 words
    codes = np.zeros((B, member.shape[0], W), dtype=np.uint64)
    L = int(sizes.max()) if P else 0
    if P and W == 1 and B * L * member.shape[0] <= 4_000_000:
        # single-word fast pack: pad each query's pins to (B, Lmax) indices
        # into a transposed member copy (dummy index -> all-False row) and
        # SUM the per-slot bit weights — bits are distinct within a query,
        # so the sum is exactly the OR, with no segment reduce.  The dense
        # (B, Lmax, N) temporaries make this a microbatch-sized path; huge
        # one-shot buckets (full-trace replays) keep the reduceat pack,
        # whose memory tracks total pins instead
        mt = np.zeros((member.shape[1] + 1, member.shape[0]), dtype=bool)
        mt[:-1] = member.T
        pinpad = np.full((B, L), member.shape[1], dtype=np.int64)
        pinpad[pin_e, pos] = pins
        bits_w = np.uint64(1) << np.arange(L, dtype=np.uint64)
        codes[:, :, 0] = (
            mt[pinpad] * bits_w[None, :, None]
        ).sum(axis=1, dtype=np.uint64)
    elif P:
        wid = pos >> 6
        bit = (pos & 63).astype(np.uint64)
        # bool * (1 << bit) fuses the astype+shift into one temporary
        shifted = member[:, pins] * (np.uint64(1) << bit)[None, :]  # (N, P)
        seg = pin_e * W + wid
        starts = np.flatnonzero(
            np.concatenate([[True], seg[1:] != seg[:-1]])
        )
        red = np.bitwise_or.reduceat(shifted, starts, axis=1)  # (N, G)
        codes[pin_e[starts], :, wid[starts]] = red.T

    # remaining-items masks: the low |q| bits set
    rem = np.zeros((B, W), dtype=np.uint64)
    for j in range(W):
        bits = np.clip(sizes - _WORD * j, 0, _WORD)
        low = (np.uint64(1) << bits.clip(0, _WORD - 1).astype(np.uint64)) - np.uint64(1)
        rem[:, j] = np.where(bits >= _WORD, np.uint64(0xFFFFFFFFFFFFFFFF), low)

    # whole-bucket backend dispatch: device-resident round loop for big
    # buckets (one transfer total), per-round host loop otherwise.  Both
    # are bit-identical (see _round_loop_fn), so this is purely perf.
    ch = None
    round_backend = _flags.FLAGS.get("span_round_backend", "auto")
    if round_backend == "auto":
        thresh = int(_flags.FLAGS.get("span_round_threshold", 200_000))
        round_backend = "device" if codes.size >= thresh else "numpy"
    if round_backend == "device" and _accel_backend() is not None:
        ch = _device_cover_rounds(codes, rem)
    if ch is not None:
        ENGINE_COUNTERS["device_buckets"] += 1
        ENGINE_COUNTERS["device_rounds"] += ch.shape[1]
        reg = _obs.registry()
        if reg.active:
            reg.inc("cover_buckets", backend="device")
            reg.inc("cover_rounds", ch.shape[1], backend="device")
        spans[b_idx] = (ch >= 0).sum(axis=1)
        _attribute_pins(ch, member, b_idx, edge_ptr, pin_e, pos, pins,
                        pin_parts)
        return ch

    rounds: list[tuple[np.ndarray, np.ndarray]] = []
    if W == 1:
        # single-word fast path (queries of <= 64 pins, the dominant online
        # serving shape): same greedy rounds with the word axis squeezed and
        # the still-active queries kept COMPACT (codes_a/rem_a/eidx shrink
        # together), so each round runs a minimal number of numpy dispatches
        # — identical gains, argmax, and tie-breaks to the generic loop
        eidx = np.flatnonzero(rem[:, 0])
        codes_a = codes[eidx, :, 0]
        rem_a = rem[eidx, 0]
        ar = np.arange(B, dtype=np.int64)
        while len(eidx):
            g = _gain_matrix_w1(codes_a, rem_a)
            p = g.argmax(axis=1)                # ties -> lowest partition id
            a = ar[: len(p)]
            gmax = g[a, p]
            if not gmax.all():
                bad = int(eidx[int(np.argmax(gmax == 0))])
                e = int(b_idx[bad])
                raise ValueError(
                    f"query {e} contains items not stored on any partition"
                )
            rounds.append((eidx, p))
            rem_a &= ~codes_a[a, p]
            alive = rem_a != 0
            if not alive.all():
                eidx = eidx[alive]
                codes_a = codes_a[alive]
                rem_a = rem_a[alive]
    else:
        active = np.flatnonzero(rem.any(axis=1))
        while len(active):
            sub = codes[active]                     # (A, N, W)
            g = _gain_matrix(sub, rem[active])      # (A, N)
            p = g.argmax(axis=1)                    # ties -> lowest partition id
            gmax = g[np.arange(len(p)), p]
            if (gmax == 0).any():
                bad = int(active[int(np.argmax(gmax == 0))])
                e = int(b_idx[bad])
                raise ValueError(
                    f"query {e} contains items not stored on any partition"
                )
            rounds.append((active, p))
            newly = sub[np.arange(len(p)), p]       # (A, W)
            rem[active] &= ~newly
            active = active[rem[active].any(axis=1)]

    R = len(rounds)
    ch = np.full((B, R), -1, dtype=np.int64)
    for r, (ai, pi) in enumerate(rounds):
        ch[ai, r] = pi
    ENGINE_COUNTERS["host_buckets"] += 1
    ENGINE_COUNTERS["host_rounds"] += R
    reg = _obs.registry()
    if reg.active:
        reg.inc("cover_buckets", backend="host")
        reg.inc("cover_rounds", R, backend="host")
    spans[b_idx] = (ch >= 0).sum(axis=1)
    _attribute_pins(ch, member, b_idx, edge_ptr, pin_e, pos, pins, pin_parts)
    return ch


def _attribute_pins(ch, member, b_idx, edge_ptr, pin_e, pos, pins, pin_parts):
    """Replica-selection attribution: for every pin, the first chosen round
    whose partition stores the item serves it (matches `greedy_set_cover`'s
    `accessed` ordering)."""
    if pin_parts is None or not len(pins):
        return
    assigned = np.full(len(pins), -1, dtype=np.int64)
    for r in range(ch.shape[1]):
        pe = ch[pin_e, r]
        idx = np.flatnonzero((assigned < 0) & (pe >= 0))
        if not len(idx):
            continue
        hit = member[pe[idx], pins[idx]]
        sel = idx[hit]
        assigned[sel] = pe[sel]
    pin_parts[edge_ptr[b_idx][pin_e] + pos] = assigned


def batched_cover_csr(
    edge_ptr: np.ndarray,
    edge_nodes: np.ndarray,
    member: np.ndarray,
    with_pin_parts: bool = False,
) -> WorkloadCover:
    """Greedy set cover of every CSR query against `member`, batched.

    Bit-identical to running `cover_for_query` per query (same covers in the
    same order, same lowest-id tie-break, ValueError on unplaced items), but
    one popcount matrix op per greedy round per size bucket instead of E
    Python loops.  Queries must be pin-deduplicated (Hypergraph CSR edges
    always are)."""
    edge_ptr = np.asarray(edge_ptr, dtype=np.int64)
    edge_nodes = np.asarray(edge_nodes, dtype=np.int64)
    E = len(edge_ptr) - 1
    spans = np.zeros(E, dtype=np.int64)
    pin_parts = (
        np.full(len(edge_nodes), -1, dtype=np.int64) if with_pin_parts else None
    )
    sizes = np.diff(edge_ptr)
    words = np.maximum((sizes + _WORD - 1) // _WORD, 1)
    bucket_chosen: list[tuple[np.ndarray, np.ndarray]] = []
    for W in np.unique(words[sizes > 0]) if E else []:
        b_idx = np.flatnonzero((words == W) & (sizes > 0))
        ch = _cover_bucket(edge_ptr, edge_nodes, member, b_idx, int(W),
                           spans, pin_parts)
        bucket_chosen.append((b_idx, ch))

    cover_ptr = np.zeros(E + 1, dtype=np.int64)
    np.cumsum(spans, out=cover_ptr[1:])
    cover_parts = np.zeros(int(cover_ptr[-1]), dtype=np.int64)
    for b_idx, ch in bucket_chosen:
        sp = spans[b_idx]
        total = int(sp.sum())
        if not total:
            continue
        # flat (edge-major, round-minor) order matches ch[ch >= 0] row-major
        base = np.zeros(len(b_idx) + 1, dtype=np.int64)
        np.cumsum(sp, out=base[1:])
        within = np.arange(total, dtype=np.int64) - base[
            np.repeat(np.arange(len(b_idx)), sp)
        ]
        cover_parts[np.repeat(cover_ptr[b_idx], sp) + within] = ch[ch >= 0]
    return WorkloadCover(spans, cover_ptr, cover_parts, pin_parts)


def batched_spans_csr(
    edge_ptr: np.ndarray, edge_nodes: np.ndarray, member: np.ndarray
) -> np.ndarray:
    """Spans only (cheapest batched path).  Inherits `batched_cover_csr`'s
    exactness contract: element-wise equal to `query_span` per query."""
    return batched_cover_csr(edge_ptr, edge_nodes, member).spans


def spans_for_workload(hg, placement: Placement) -> np.ndarray:
    """Span of every hyperedge in `hg` under `placement` (batched engine,
    bit-identical to the per-query reference)."""
    return batched_spans_csr(hg.edge_ptr, hg.edge_nodes, placement.member)


# ======================================================== incremental spans
class SpanMaintainer:
    """Per-edge span cache with dirty-set invalidation.

    Exactness contract: membership of an item only affects the covers of
    edges containing that item, so after `notify_items(touched)` recomputing
    just the incident (dirty) edges reproduces a full sweep bit-for-bit.
    Callers MUST notify every item whose membership row changed.

    With ``with_covers=True`` the maintainer additionally keeps every edge's
    full replica selection in FLAT form — ``pin_parts`` holds, for every pin
    of the hypergraph's CSR, the partition that serves it, and ``chosen(e)``
    the partitions of e's cover in greedy selection order.  ``cover(e)``
    synthesizes the {partition: accessed items} dict on demand (partitions in
    selection order, items in pin order — identical to ``cover_for_query``),
    and ``refresh_edges`` re-derives an explicit edge set in one batched
    cover instead of per-edge Python loops.  This is the LMBR consumption
    path: LMBR's move loop invalidates an algorithm-defined edge set
    (narrower than the full incidence of the moved items), so it bypasses
    the dirty set and names its edges directly — and LMBR's vectorized gain
    engine reads ``pin_parts`` directly instead of per-edge dicts."""

    def __init__(self, hg, placement: Placement, with_covers: bool = False):
        self.hg = hg
        self.placement = placement
        self._node_ptr, self._node_edges = hg.incidence()
        self._pin_part: np.ndarray | None = None  # (P,) serving partition
        self._chosen: list[np.ndarray] | None = None  # per edge, greedy order
        if with_covers:
            cov = batched_cover_csr(
                hg.edge_ptr, hg.edge_nodes, placement.member,
                with_pin_parts=True,
            )
            self._spans = cov.spans
            self._pin_part = cov.pin_parts
            self._chosen = [cov.chosen(e).copy() for e in range(hg.num_edges)]
        else:
            self._spans = batched_spans_csr(
                hg.edge_ptr, hg.edge_nodes, placement.member
            )
        self._dirty = np.zeros(hg.num_edges, dtype=bool)

    @property
    def pin_parts(self) -> np.ndarray:
        """Serving partition of every pin, aligned with ``hg.edge_nodes``
        (requires with_covers=True)."""
        return self._pin_part

    def chosen(self, e: int) -> np.ndarray:
        """Partitions of edge e's cover in greedy selection order (requires
        with_covers=True)."""
        return self._chosen[e]

    def cover(self, e: int) -> dict[int, np.ndarray]:
        """Replica selection of edge e (requires with_covers=True): maps each
        chosen partition, in greedy selection order, to the items the edge
        reads from it.  Built on demand from the flat pin attribution."""
        lo, hi = self.hg.edge_ptr[e], self.hg.edge_ptr[e + 1]
        q = self.hg.edge_nodes[lo:hi]
        pp = self._pin_part[lo:hi]
        return {int(p): q[pp == p] for p in self._chosen[e]}

    def refresh_edges(self, edge_ids) -> None:
        """Batched recompute of exactly `edge_ids` — bit-identical to calling
        `cover_for_query` per edge, one engine invocation total."""
        edge_ids = np.asarray(edge_ids, dtype=np.int64)
        if not len(edge_ids):
            return
        ptr, pidx = self.hg.pin_indices(edge_ids)
        nodes = self.hg.edge_nodes[pidx]
        cov = batched_cover_csr(
            ptr, nodes, self.placement.member,
            with_pin_parts=self._pin_part is not None,
        )
        self._spans[edge_ids] = cov.spans
        if self._pin_part is not None:
            self._pin_part[pidx] = cov.pin_parts
            for i, e in enumerate(edge_ids):
                self._chosen[int(e)] = cov.chosen(i).copy()
        self._dirty[edge_ids] = False

    def notify_items(self, items) -> None:
        """Mark every edge incident to `items` dirty."""
        items = np.asarray(items, dtype=np.int64)
        if not len(items):
            return
        cnt = self._node_ptr[items + 1] - self._node_ptr[items]
        total = int(cnt.sum())
        if not total:
            return
        base = np.repeat(self._node_ptr[items], cnt)
        off = np.arange(total, dtype=np.int64) - np.repeat(
            np.concatenate([[0], np.cumsum(cnt[:-1])]), cnt
        )
        self._dirty[self._node_edges[base + off]] = True

    def spans(self) -> np.ndarray:
        d = np.flatnonzero(self._dirty)
        if len(d):
            if self._pin_part is not None:
                self.refresh_edges(d)  # keeps covers consistent with spans
            else:
                ptr, nodes = self.hg.edges_csr(d)
                self._spans[d] = batched_spans_csr(
                    ptr, nodes, self.placement.member
                )
            self._dirty[:] = False
        return self._spans

    def residual_edges(self, min_span: int) -> np.ndarray:
        """Edge ids with span > min_span (pruneHypergraphBySpan keeps these)."""
        return np.flatnonzero(self.spans() > min_span)
