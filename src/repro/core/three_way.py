"""Fixed replication-factor (3-way) algorithms (paper §4.6).

Large-scale stores (HDFS et al.) replicate every item exactly RF times; these
variants honor that constraint:

  * pra_3way — PRA with the importance filter removed: every node is
    replicated RF-way, the hitting-set technique distributes the copies
    among its incident hyperedges.
  * sda      — Simple Distribution Algorithm: RF copies assigned to incident
    hyperedges at random, |E_d|/RF edges per copy.
  * ihpa_3way — RF rounds of HPA on span-pruned residuals.

All produce a placement where every item has exactly RF copies (on distinct
partitions), using N = RF * N_e partitions.
"""

from __future__ import annotations

import numpy as np

from . import hpa as hpa_mod
from .algorithms import _hitting_set, min_partitions
from .hypergraph import Hypergraph
from .setcover import Placement, batched_spans_csr, greedy_set_cover

__all__ = ["pra_3way", "sda", "ihpa_3way", "random_3way", "THREE_WAY_ALGORITHMS"]


def _partition_copies_placement(
    hg: Hypergraph,
    edge_copy_assign: dict[int, dict[int, int]],
    rf: int,
    n: int,
    capacity: float,
    seed: int,
    nruns: int,
) -> Placement:
    """Build the replicated hypergraph (each node -> rf copies, hyperedges
    rewired to copies per `edge_copy_assign[e][v] = copy_index`), partition it
    with HPA into n parts, and map back to original item ids."""
    num_copies = hg.num_nodes * rf
    copy_id = lambda v, c: v * rf + c  # noqa: E731
    edges = []
    for e in range(hg.num_edges):
        edges.append(
            [copy_id(int(v), edge_copy_assign[e].get(int(v), 0)) for v in hg.edge(e)]
        )
    # every copy exists even if no edge uses it (RF is a durability constraint)
    node_weights = np.repeat(hg.node_weights, rf)
    rep = Hypergraph.from_edges(
        edges, num_nodes=num_copies, node_weights=node_weights,
        edge_weights=hg.edge_weights.copy(),
    )
    assign = hpa_mod.partition(rep, n, capacity, seed=seed, nruns=nruns)
    # copies of one item must land on distinct partitions (durability).
    # With N = rf*Ne there may be zero slack, so collisions are repaired by
    # SWAPPING the duplicate copy with some copy resident in a partition that
    # lacks this item (keeps loads unchanged for homogeneous items).
    loads = np.zeros(n, dtype=np.float64)
    np.add.at(loads, assign, node_weights)
    part_copies: list[set[int]] = [set() for _ in range(n)]  # copy ids per part
    for cid, p in enumerate(assign):
        part_copies[int(p)].add(cid)
    rng = np.random.default_rng(seed + 17)

    def item_of(cid: int) -> int:
        return cid // rf

    for v in range(hg.num_nodes):
        seen: set[int] = set()
        for c in range(rf):
            cid = v * rf + c
            p = int(assign[cid])
            if p not in seen:
                seen.add(p)
                continue
            w = float(hg.node_weights[v])
            # try a pure move into free space first
            moved = False
            for q in np.argsort(loads):
                q = int(q)
                if q in seen:
                    continue
                if loads[q] + w <= capacity + 1e-9 and all(
                    item_of(x) != v for x in part_copies[q]
                ):
                    assign[cid] = q
                    part_copies[p].discard(cid)
                    part_copies[q].add(cid)
                    loads[p] -= w
                    loads[q] += w
                    seen.add(q)
                    moved = True
                    break
            if moved:
                continue
            # swap with a same-weight copy from a partition lacking item v
            done = False
            for q in rng.permutation(n):
                q = int(q)
                if q in seen or any(item_of(x) == v for x in part_copies[q]):
                    continue
                for other in list(part_copies[q]):
                    u = item_of(other)
                    if u == v:
                        continue
                    if abs(hg.node_weights[u] - w) > 1e-9:
                        continue
                    # u must not already be in p
                    if any(item_of(x) == u for x in part_copies[p]):
                        continue
                    assign[cid], assign[other] = q, p
                    part_copies[p].discard(cid)
                    part_copies[p].add(other)
                    part_copies[q].discard(other)
                    part_copies[q].add(cid)
                    seen.add(q)
                    done = True
                    break
                if done:
                    break
            if not done:
                seen.add(p)  # give up on strict distinctness for this copy
    pl = Placement.empty(n, hg.num_nodes, capacity, hg.node_weights)
    for v in range(hg.num_nodes):
        for c in range(rf):
            pl.member[assign[v * rf + c], v] = True
    return pl


def pra_3way(
    hg: Hypergraph, n: int | None = None, capacity: float = 0.0,
    rf: int = 3, seed: int = 0, nruns: int = 2, **_,
) -> Placement:
    ne = min_partitions(hg, capacity)
    if n is None:
        n = rf * ne
    assign = hpa_mod.partition(hg, ne, capacity, seed=seed, nruns=nruns)
    pl0 = Placement.empty(ne, hg.num_nodes, capacity, hg.node_weights)
    for v in range(hg.num_nodes):
        pl0.member[assign[v], v] = True

    node_ptr, node_edges = hg.incidence()
    edge_copy_assign: dict[int, dict[int, int]] = {e: {} for e in range(hg.num_edges)}
    for v in range(hg.num_nodes):
        ev = node_edges[node_ptr[v] : node_ptr[v + 1]]
        if len(ev) == 0:
            continue
        # anchor copies to partitions the edges visit for their *other* items
        span_sets = []
        for e in ev:
            others = hg.edge(int(e))
            others = others[others != v]
            span_sets.append(
                list(greedy_set_cover(others, pl0.member)) if len(others) else []
            )
        hit = _hitting_set(span_sets)[:rf]  # at most rf copy anchors
        for e, spans in zip(ev, span_sets):
            c = 0
            for ci, g in enumerate(hit):
                if g in spans:
                    c = ci
                    break
            edge_copy_assign[int(e)][int(v)] = c
    return _partition_copies_placement(
        hg, edge_copy_assign, rf, n, capacity, seed + 1, nruns
    )


def sda(
    hg: Hypergraph, n: int | None = None, capacity: float = 0.0,
    rf: int = 3, seed: int = 0, nruns: int = 2, **_,
) -> Placement:
    """Simple Distribution Algorithm: random copy-to-edge distribution."""
    ne = min_partitions(hg, capacity)
    if n is None:
        n = rf * ne
    rng = np.random.default_rng(seed)
    node_ptr, node_edges = hg.incidence()
    edge_copy_assign: dict[int, dict[int, int]] = {e: {} for e in range(hg.num_edges)}
    for v in range(hg.num_nodes):
        ev = node_edges[node_ptr[v] : node_ptr[v + 1]]
        if len(ev) == 0:
            continue
        perm = rng.permutation(len(ev))
        # contiguous |E_d|/rf chunks of the shuffled edges share one copy
        for rank, idx in enumerate(perm):
            c = int(rank * rf / len(ev))
            edge_copy_assign[int(ev[idx])][int(v)] = min(c, rf - 1)
    return _partition_copies_placement(
        hg, edge_copy_assign, rf, n, capacity, seed + 1, nruns
    )


def ihpa_3way(
    hg: Hypergraph, n: int | None = None, capacity: float = 0.0,
    rf: int = 3, seed: int = 0, nruns: int = 2, **_,
) -> Placement:
    """RF rounds of HPA; round r partitions the hypergraph with all edges of
    span<=r (w.r.t. the accumulated placement) removed, placing a fresh copy
    of every node each round."""
    ne = min_partitions(hg, capacity)
    if n is None:
        n = rf * ne
    pl = Placement.empty(n, hg.num_nodes, capacity, hg.node_weights)
    used = 0
    cur = hg
    for r in range(rf):
        k = min(ne, n - used)
        if k <= 0:
            break
        assign = hpa_mod.partition(cur, k, capacity, seed=seed + r, nruns=nruns)
        pl.member[used + assign, np.arange(hg.num_nodes)] = True
        used += k
        # prune edges already at span 1 for the next round (batched engine)
        spans = batched_spans_csr(cur.edge_ptr, cur.edge_nodes, pl.member)
        nxt = cur.subhypergraph_edges(np.flatnonzero(spans > 1))
        # keep all nodes (every node still gets a copy each round)
        cur = Hypergraph(
            nxt.edge_ptr, nxt.edge_nodes, hg.node_weights, nxt.edge_weights
        )
    # durability fixup: ensure rf distinct partitions per item
    loads = pl.partition_weights()
    for v in range(hg.num_nodes):
        have = np.flatnonzero(pl.member[:, v])
        need = rf - len(have)
        w = hg.node_weights[v]
        while need > 0:
            cand = np.argsort(loads)
            placed = False
            for q in cand:
                if not pl.member[q, v] and loads[q] + w <= pl.capacity + 1e-9:
                    pl.member[q, v] = True
                    loads[q] += w
                    placed = True
                    break
            if not placed:
                break
            need -= 1
    return pl


def random_3way(
    hg: Hypergraph, n: int | None = None, capacity: float = 0.0,
    rf: int = 3, seed: int = 0, **_,
) -> Placement:
    """Random RF-way replication (fig. 6f-h baseline).

    Partitions are split into rf zones of Ne partitions; each zone receives a
    random balanced deal of all items, guaranteeing rf distinct partitions per
    item even at zero slack (N = rf*Ne)."""
    ne = min_partitions(hg, capacity)
    if n is None:
        n = rf * ne
    zone = max(1, n // rf)
    rng = np.random.default_rng(seed)
    pl = Placement.empty(n, hg.num_nodes, capacity, hg.node_weights)
    for r in range(rf):
        lo = r * zone
        k = zone if r < rf - 1 else n - lo
        loads = np.zeros(k, dtype=np.float64)
        for v in rng.permutation(hg.num_nodes):
            w = hg.node_weights[v]
            ok = np.flatnonzero(loads + w <= capacity + 1e-9)
            p = int(rng.choice(ok)) if len(ok) else int(np.argmin(loads))
            pl.member[lo + p, v] = True
            loads[p] += w
    return pl


THREE_WAY_ALGORITHMS = {
    "random3": random_3way,
    "sda": sda,
    "ihpa3": ihpa_3way,
    "pra3": pra_3way,
}
