"""MoE expert placement from routing traces — the paper's technique applied
beyond the paper.

Mapping onto the paper's model:
  data items   -> experts
  query        -> the set of experts co-activated by one token group
                  (a sequence / microbatch shard; mined from routing traces)
  partitions   -> expert-parallel (EP) ranks, capacity = expert slots per rank
  query span   -> number of EP ranks one token group's all-to-all must reach

Standard EP assigns experts round-robin/contiguously and every token group
all-to-alls with every rank.  With workload-driven placement plus replicas of
hot/co-firing experts in spare slots, the average fan-out (span) drops, which
directly cuts all-to-all participants and bytes — the paper's
communication-minimization thesis restated for MoE.

The plan exposes device-side arrays (`expert_slot_table`, `slot_to_expert`)
that `repro.models.moe` uses for locality-aware dispatch, plus trace-level
estimates of the all-to-all reduction for EXPERIMENTS.md.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .algorithms import ALGORITHMS
from .hypergraph import Hypergraph
from .setcover import Placement, greedy_set_cover

__all__ = [
    "ExpertPlacementPlan",
    "routing_trace_to_hypergraph",
    "plan_expert_placement",
    "baseline_contiguous_placement",
    "synthetic_routing_trace",
]


def routing_trace_to_hypergraph(
    group_expert_sets: list[np.ndarray], num_experts: int
) -> Hypergraph:
    """Dedupe identical expert-sets, weighting hyperedges by frequency."""
    counts: dict[tuple, float] = {}
    for s in group_expert_sets:
        key = tuple(sorted(set(int(x) for x in s)))
        if len(key) < 1:
            continue
        counts[key] = counts.get(key, 0.0) + 1.0
    edges = list(counts.keys())
    return Hypergraph.from_edges(
        edges, num_nodes=num_experts,
        edge_weights=np.asarray([counts[e] for e in edges]),
    )


def synthetic_routing_trace(
    num_experts: int,
    num_groups: int,
    top_k: int = 8,
    zipf_a: float = 1.2,
    cluster_size: int = 16,
    seed: int = 0,
) -> list[np.ndarray]:
    """Synthetic but structured trace: expert popularity is Zipfian and
    co-activation is clustered (domain-specialized experts co-fire), which is
    what production MoE routing looks like after convergence."""
    rng = np.random.default_rng(seed)
    num_clusters = max(1, num_experts // cluster_size)
    cluster_pop = 1.0 / np.arange(1, num_clusters + 1) ** zipf_a
    cluster_pop /= cluster_pop.sum()
    perm = rng.permutation(num_experts)
    clusters = [
        perm[c * cluster_size : (c + 1) * cluster_size]
        for c in range(num_clusters)
    ]
    groups = []
    for _ in range(num_groups):
        c = int(rng.choice(num_clusters, p=cluster_pop))
        pool = clusters[c]
        # tokens in a group mostly hit one cluster, with some leakage
        n_local = max(1, int(round(top_k * 0.75)))
        local = rng.choice(pool, size=min(n_local, len(pool)), replace=False)
        n_leak = top_k - len(local)
        leak = rng.integers(0, num_experts, size=max(0, n_leak))
        groups.append(np.unique(np.concatenate([local, leak])))
    return groups


@dataclasses.dataclass
class ExpertPlacementPlan:
    num_experts: int
    num_ranks: int
    slots_per_rank: int
    member: np.ndarray           # (ranks, experts) bool
    slot_to_expert: np.ndarray   # (ranks, slots_per_rank) int32, -1 = empty
    expert_slot_table: np.ndarray  # (experts, ranks) int32: slot id on rank, -1
    algorithm: str

    # ------------------------------------------------------------- metrics
    def avg_span(self, group_expert_sets: list[np.ndarray]) -> float:
        return float(
            np.mean([
                len(greedy_set_cover(np.asarray(sorted(set(map(int, g)))),
                                     self.member))
                for g in group_expert_sets if len(g)
            ])
        )

    def a2a_bytes(
        self, group_expert_sets: list[np.ndarray],
        tokens_per_group: int, bytes_per_token: int,
    ) -> float:
        """Estimated all-to-all payload: each group ships its tokens to every
        rank in its cover and receives them back (2x)."""
        total = 0.0
        for g in group_expert_sets:
            if not len(g):
                continue
            span = len(
                greedy_set_cover(np.asarray(sorted(set(map(int, g)))), self.member)
            )
            # tokens split across `span` ranks; payload ~ tokens * bytes * 2
            total += 2.0 * tokens_per_group * bytes_per_token * max(span - 1, 0) / max(span, 1)
        return total

    def replica_counts(self) -> np.ndarray:
        return self.member.sum(axis=0)


def _plan_from_placement(
    pl: Placement, num_experts: int, num_ranks: int, slots: int, algo: str
) -> ExpertPlacementPlan:
    slot_to_expert = np.full((num_ranks, slots), -1, dtype=np.int32)
    expert_slot_table = np.full((num_experts, num_ranks), -1, dtype=np.int32)
    for r in range(num_ranks):
        experts = np.flatnonzero(pl.member[r])
        for s, e in enumerate(experts[:slots]):
            slot_to_expert[r, s] = e
            expert_slot_table[e, r] = s
    return ExpertPlacementPlan(
        num_experts, num_ranks, slots, pl.member.copy(),
        slot_to_expert, expert_slot_table, algo,
    )


def baseline_contiguous_placement(
    num_experts: int, num_ranks: int, slots_per_rank: int | None = None
) -> ExpertPlacementPlan:
    """Standard EP layout: expert e lives (only) on rank e // (E/R)."""
    per = int(np.ceil(num_experts / num_ranks))
    slots = slots_per_rank or per
    member = np.zeros((num_ranks, num_experts), dtype=bool)
    for e in range(num_experts):
        member[min(e // per, num_ranks - 1), e] = True
    pl = Placement(member, float(slots), np.ones(num_experts))
    return _plan_from_placement(pl, num_experts, num_ranks, slots, "contiguous")


def plan_expert_placement(
    group_expert_sets: list[np.ndarray],
    num_experts: int,
    num_ranks: int,
    slots_per_rank: int,
    algorithm: str = "lmbr",
    seed: int = 0,
) -> ExpertPlacementPlan:
    """Fit the paper's placement machinery to a routing trace.

    slots_per_rank * num_ranks >= num_experts must hold; the surplus is the
    replication budget (the paper's 'extra partitions')."""
    if slots_per_rank * num_ranks < num_experts:
        raise ValueError("not enough expert slots to place every expert once")
    hg = routing_trace_to_hypergraph(group_expert_sets, num_experts)
    from .three_way import THREE_WAY_ALGORITHMS

    if algorithm in THREE_WAY_ALGORITHMS:
        rf = max(1, (slots_per_rank * num_ranks) // num_experts)
        pl = THREE_WAY_ALGORITHMS[algorithm](
            hg, n=num_ranks, capacity=float(slots_per_rank), rf=rf, seed=seed
        )
    else:
        pl = ALGORITHMS[algorithm](hg, num_ranks, float(slots_per_rank), seed=seed)
    # every expert must exist somewhere even if it never fired in the trace
    placed = pl.member.any(axis=0)
    loads = pl.member.sum(axis=1).astype(np.int64)
    for e in np.flatnonzero(~placed):
        r = int(np.argmin(loads))
        pl.member[r, e] = True
        loads[r] += 1
    # enforce the slot cap strictly (placement capacity is in weight units,
    # which equals slot count for unit-weight experts)
    for r in range(num_ranks):
        experts = np.flatnonzero(pl.member[r])
        if len(experts) > slots_per_rank:
            # drop surplus replicas (never the last copy of an expert)
            copies = pl.member.sum(axis=0)
            removable = sorted(
                (int(e) for e in experts if copies[e] > 1),
                key=lambda e: -copies[e],
            )
            for e in removable:
                if len(np.flatnonzero(pl.member[r])) <= slots_per_rank:
                    break
                pl.member[r, e] = False
                copies[e] -= 1
    return _plan_from_placement(
        pl, num_experts, num_ranks, slots_per_rank, algorithm
    )
