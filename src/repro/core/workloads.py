"""Workload generators (paper §5.2).

  * random_workload   — queries are connected subgraphs of a random data-item
    graph of given density (paper's Random dataset).
  * snowflake_workload — data-item graph is a tree mimicking a star/snowflake
    SQL schema; queries are connected subgraphs (SQL w/o Cartesian products).
  * ispd_like_workload — sparse hypergraphs matching ISPD98 statistics
    (density ~= 1, 2-dominant hyperedge sizes with a heavy tail); the actual
    ISPD98 circuit files are not redistributable offline, so we generate
    structurally matched stand-ins (documented in DESIGN.md §8).
  * tpch_heterogeneous — snowflake with TPC-H-skewed item sizes (25KB..28GB at
    SF=25; fig. 8).

Paper defaults: |D|=1000, minQ=3, maxQ=11, NQ=4000, C=50, NPar=40, density=20.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .hypergraph import Hypergraph

__all__ = [
    "Workload", "random_workload", "snowflake_workload",
    "ispd_like_workload", "tpch_heterogeneous", "lmbr_stress_workload",
    "web_scale_chunks", "web_scale_workload",
    "PAPER_DEFAULTS", "LMBR_STRESS_DEFAULTS", "WEB_SCALE_DEFAULTS",
]

PAPER_DEFAULTS = dict(
    num_items=1000, min_query=3, max_query=11, num_queries=4000,
    capacity=50, num_partitions=40, density=20,
)


@dataclasses.dataclass
class Workload:
    hypergraph: Hypergraph
    name: str
    item_graph_edges: np.ndarray | None = None  # (M,2) underlying data-item graph

    @property
    def queries(self):
        return [self.hypergraph.edge(e) for e in range(self.hypergraph.num_edges)]


def _connected_subgraph_query(
    adj: list[np.ndarray], rng: np.random.Generator, size: int
) -> list[int]:
    """Random connected subgraph by frontier growth from a random seed."""
    n = len(adj)
    start = int(rng.integers(n))
    chosen = {start}
    frontier = list(adj[start])
    while len(chosen) < size and frontier:
        idx = int(rng.integers(len(frontier)))
        v = int(frontier.pop(idx))
        if v in chosen:
            continue
        chosen.add(v)
        frontier.extend(int(u) for u in adj[v] if u not in chosen)
    return sorted(chosen)


def _build_adj(num_items: int, edges: np.ndarray) -> list[np.ndarray]:
    adj: list[list[int]] = [[] for _ in range(num_items)]
    for a, b in edges:
        adj[int(a)].append(int(b))
        adj[int(b)].append(int(a))
    return [np.asarray(sorted(set(x)), dtype=np.int64) for x in adj]


def random_workload(
    num_items: int = 1000,
    num_queries: int = 4000,
    min_query: int = 3,
    max_query: int = 11,
    density: float = 20,
    seed: int = 0,
) -> Workload:
    rng = np.random.default_rng(seed)
    num_edges = int(density * num_items)
    # random item graph over a spanning-tree backbone (keeps it connected)
    tree = np.stack(
        [np.arange(1, num_items),
         rng.integers(0, np.arange(1, num_items))], axis=1
    )
    extra = rng.integers(0, num_items, size=(max(0, num_edges - num_items + 1), 2))
    extra = extra[extra[:, 0] != extra[:, 1]]
    edges = np.concatenate([tree, extra], axis=0)
    adj = _build_adj(num_items, edges)
    queries = []
    for _ in range(num_queries):
        size = int(rng.integers(min_query, max_query + 1))
        queries.append(_connected_subgraph_query(adj, rng, size))
    hg = Hypergraph.from_edges(queries, num_nodes=num_items)
    return Workload(hg, f"random(d={density})", edges)


def snowflake_workload(
    levels: int = 3,
    degree: int = 5,
    attrs_per_table: int = 15,
    num_items: int = 2000,
    num_queries: int = 4000,
    min_query: int = 3,
    max_query: int = 11,
    seed: int = 0,
    item_weights: np.ndarray | None = None,
) -> Workload:
    """Tree-shaped data-item graph: tables form a tree (fan-out `degree`,
    `levels` levels); each table contributes a key item plus attribute items
    hanging off the key. Queries = connected subgraphs (joins along the tree +
    attribute accesses)."""
    rng = np.random.default_rng(seed)
    edges = []
    table_keys = [0]  # item 0 = root fact-table key
    next_item = 1
    frontier = [0]
    level = 1
    while next_item < num_items and level < levels:
        new_frontier = []
        for parent_key in frontier:
            for _ in range(degree):
                if next_item >= num_items:
                    break
                child_key = next_item
                next_item += 1
                edges.append((parent_key, child_key))  # join edge
                table_keys.append(child_key)
                new_frontier.append(child_key)
        frontier = new_frontier
        level += 1
    # attach attribute items round-robin to table keys
    ti = 0
    while next_item < num_items:
        key = table_keys[ti % len(table_keys)]
        if True:
            edges.append((key, next_item))
            next_item += 1
        ti += 1
    edges = np.asarray(edges, dtype=np.int64)
    adj = _build_adj(num_items, edges)
    queries = []
    for _ in range(num_queries):
        size = int(rng.integers(min_query, max_query + 1))
        queries.append(_connected_subgraph_query(adj, rng, size))
    hg = Hypergraph.from_edges(
        queries, num_nodes=num_items, node_weights=item_weights
    )
    return Workload(hg, "snowflake", edges)


def tpch_heterogeneous(
    num_items: int = 2000,
    num_queries: int = 4000,
    scale_factor: int = 25,
    seed: int = 0,
    target_min_partitions: int = 20,
    capacity: float = 100.0,
    **kw,
) -> Workload:
    """Snowflake workload with TPC-H-skewed column sizes.

    Size(column) = Size(datatype) * noRows; at SF=25 the paper reports item
    sizes from 25KB to 28GB.  We draw log-uniform sizes in that range with a
    lineitem-like skew (a few giant fact-table columns, many small dims),
    expressed in GB so a partition capacity of 100 (GB) matches fig. 8.
    Sizes are normalized so N_e == target_min_partitions (paper: exactly 20
    partitions minimally required), preserving the skew ratio.
    """
    rng = np.random.default_rng(seed + 1)
    lo, hi = 25e-6, 28.0  # GB at SF=25
    # 2-component mixture: 15% fact-table columns (big), 85% dimension columns
    big = rng.uniform(np.log(1.0), np.log(hi), size=num_items)
    small = rng.uniform(np.log(lo), np.log(0.5), size=num_items)
    is_big = rng.random(num_items) < 0.15
    weights = np.exp(np.where(is_big, big, small))
    target_total = 0.97 * target_min_partitions * capacity
    weights = weights * (target_total / weights.sum())
    wl = snowflake_workload(
        num_items=num_items, num_queries=num_queries, seed=seed,
        item_weights=weights, **kw,
    )
    wl.name = f"tpch-hetero(sf={scale_factor})"
    return wl


# sized so the vectorized LMBR move engine finishes in tens of seconds while
# the pure-Python reference peel needs minutes (benchmarks/bench_lmbr.py runs
# the reference under a timeout and marks it infeasible when it blows it)
LMBR_STRESS_DEFAULTS = dict(
    num_items=2500, num_queries=10000, density=12,
    capacity=50, num_partitions=64, max_moves=1200,
)


def lmbr_stress_workload(
    num_items: int = LMBR_STRESS_DEFAULTS["num_items"],
    num_queries: int = LMBR_STRESS_DEFAULTS["num_queries"],
    density: float = LMBR_STRESS_DEFAULTS["density"],
    seed: int = 0,
) -> Workload:
    """The LMBR stress tier: a Random-dataset instance ~6x the paper's
    default LMBR workload (2.5x items, 2.5x queries, 64 partitions in
    ``LMBR_STRESS_DEFAULTS``), beyond what the pre-vectorization LMBR could
    process in an interactive budget.  Partition count and capacity live in
    ``LMBR_STRESS_DEFAULTS`` so benchmarks and tests agree on the tier."""
    wl = random_workload(
        num_items=num_items, num_queries=num_queries,
        min_query=3, max_query=11, density=density, seed=seed,
    )
    wl.name = f"lmbr-stress(V={num_items},E={num_queries})"
    return wl


# the ROADMAP's "heavy traffic from millions of users" tier: item catalog
# clustered into power-law content domains, a million queries with power-law
# domain popularity and a thin seam of cross-domain queries — the structure
# repro.scale's sharder exploits.  Partition count and capacity live here so
# benchmarks and tests agree on the tier (capacity ~2x the feasibility
# minimum, replication headroom like the paper's C=50 on |D|=1000).
WEB_SCALE_DEFAULTS = dict(
    num_items=100_000, num_queries=1_000_000, num_partitions=256,
    capacity=800, num_clusters=2048, min_query=2, max_query=8,
    cross_frac=0.02,
)


def web_scale_chunks(
    num_items: int = WEB_SCALE_DEFAULTS["num_items"],
    num_queries: int = WEB_SCALE_DEFAULTS["num_queries"],
    num_clusters: int = WEB_SCALE_DEFAULTS["num_clusters"],
    min_query: int = WEB_SCALE_DEFAULTS["min_query"],
    max_query: int = WEB_SCALE_DEFAULTS["max_query"],
    cross_frac: float = WEB_SCALE_DEFAULTS["cross_frac"],
    skew: float = 1.1,
    seed: int = 0,
    chunk: int = 200_000,
):
    """Yield the web-scale trace as raw CSR chunks ``(edge_ptr, edge_nodes)``
    — the streaming ingestion shape (`repro.scale.StreamingHypergraphBuilder
    .add_csr`).  Pins may repeat within a query (canonicalization dedups).

    Items split into ``num_clusters`` power-law-sized content clusters;
    each query samples one cluster by power-law popularity and draws
    ``min_query..max_query`` pins inside it; a ``cross_frac`` fraction
    draws its second half from another cluster (the cross-shard seam).
    Fully vectorized: a 1M-query trace generates in a couple of passes
    over flat arrays, never one Python object per query."""
    num_clusters = min(num_clusters, max(1, num_items // 4))
    rng = np.random.default_rng(seed)
    raw = (np.arange(1, num_clusters + 1, dtype=np.float64)) ** (-skew)
    csize = np.maximum(4, (raw / raw.sum() * num_items).astype(np.int64))
    # reconcile the rounding drift against the biggest cluster
    csize[0] += num_items - int(csize.sum())
    cstart = np.zeros(num_clusters, dtype=np.int64)
    np.cumsum(csize[:-1], out=cstart[1:])
    pop = np.cumsum(raw / raw.sum())
    done = 0
    while done < num_queries:
        B = min(chunk, num_queries - done)
        c1 = np.searchsorted(pop, rng.random(B)).clip(0, num_clusters - 1)
        c2 = rng.integers(0, num_clusters, size=B)
        cross = rng.random(B) < cross_frac
        k = rng.integers(min_query, max_query + 1, size=B)
        ptr = np.zeros(B + 1, dtype=np.int64)
        np.cumsum(k, out=ptr[1:])
        pin_q = np.repeat(np.arange(B, dtype=np.int64), k)
        pos = np.arange(int(ptr[-1]), dtype=np.int64) - np.repeat(ptr[:-1], k)
        use2 = cross[pin_q] & (pos >= (k[pin_q] // 2))
        cl = np.where(use2, c2[pin_q], c1[pin_q])
        pins = cstart[cl] + rng.integers(0, csize[cl])
        yield ptr, pins
        done += B


def web_scale_workload(seed: int = 0, chunk: int = 200_000, **kw) -> Workload:
    """The web-scale tier as a built `Workload` (streamed through
    `StreamingHypergraphBuilder`, so the build itself is the fast path the
    scale benchmarks gate).  ``**kw`` forwards to `web_scale_chunks`."""
    from ..scale.stream import StreamingHypergraphBuilder  # avoid cycle

    params = {k: v for k, v in WEB_SCALE_DEFAULTS.items()
              if k not in ("num_partitions", "capacity")}
    params.update(kw)
    builder = StreamingHypergraphBuilder(params["num_items"])
    for ptr, pins in web_scale_chunks(seed=seed, chunk=chunk, **params):
        builder.add_csr(ptr, pins)
    hg = builder.build()
    return Workload(hg, f"web-scale(V={hg.num_nodes},E={hg.num_edges})")


def ispd_like_workload(
    num_nodes: int = 12752,
    num_edges: int | None = None,
    seed: int = 0,
) -> Workload:
    """Sparse circuit-like hypergraph: density ~1.1, hyperedge sizes follow
    the ISPD98 profile (mostly 2-3 pins, geometric tail to ~20)."""
    rng = np.random.default_rng(seed)
    if num_edges is None:
        num_edges = int(1.1 * num_nodes)
    sizes = 2 + rng.geometric(0.55, size=num_edges)
    sizes = np.clip(sizes, 2, 24)
    # locality structure: nodes near each other (in a shuffled order) connect,
    # as placed circuits do
    perm = rng.permutation(num_nodes)
    queries = []
    for s in sizes:
        center = int(rng.integers(num_nodes))
        window = 64
        lo = max(0, center - window)
        hi = min(num_nodes, center + window)
        pick = rng.choice(np.arange(lo, hi), size=min(s, hi - lo), replace=False)
        queries.append(sorted(set(int(perm[i]) for i in pick)))
    hg = Hypergraph.from_edges(queries, num_nodes=num_nodes)
    return Workload(hg, f"ispd-like(n={num_nodes})")
