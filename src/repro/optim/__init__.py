from .optimizers import (  # noqa: F401
    Optimizer,
    adafactor,
    adamw,
    clip_by_global_norm,
    cosine_schedule,
)
from .compression import int8_compress, int8_decompress, compressed_mean  # noqa: F401
