"""Gradient compression for cross-pod (DCN) reduction.

int8 block-quantized all-reduce with error feedback: gradients crossing the
slow pod axis are quantized to int8 with per-block fp32 scales (~4x wire
reduction); the quantization residual is fed back into the next step's
gradient so the compression is unbiased over time.

Used by launch/train.py when the mesh has a 'pod' axis and
--grad-compression int8 is set; the collective-bytes term in the roofline
accounts the quantized payload.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def int8_compress(x: jax.Array):
    """x: any shape float -> (int8 values, fp32 scales per block)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-len(flat)) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)), -127, 127)
    return q.astype(jnp.int8), scale


def int8_decompress(q: jax.Array, scale: jax.Array, shape, size):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)[:size]
    return flat.reshape(shape)


def compressed_mean(x: jax.Array, axis_name: str):
    """Mean-reduce `x` over `axis_name` shipping int8 payloads + fp32 scales,
    instead of full-precision values.  Returns the decompressed mean plus the
    local quantization error (for error feedback)."""
    q, scale = int8_compress(x)
    local = int8_decompress(q, scale, x.shape, x.size)
    err = x.astype(jnp.float32) - local
    # all-reduce the (already-quantized) values; wire cost ~ 1B + 4B/256 per elt
    mean = jax.lax.pmean(local, axis_name)
    return mean.astype(x.dtype), err.astype(x.dtype)


def apply_error_feedback(grads, residuals):
    if residuals is None:
        return grads
    return jax.tree.map(lambda g, r: g + r.astype(g.dtype), grads, residuals)
