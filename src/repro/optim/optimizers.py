"""Optimizers (pure JAX, optax-style API but self-contained).

  * adamw     — default for <=100B-class models (fp32 m/v states).
  * adafactor — factored second moments + no first moment: the optimizer-state
    footprint that lets deepseek-v3-scale training fit v5e HBM (states are
    O(rows+cols) instead of O(params); see EXPERIMENTS.md memory table).
  * cosine_schedule, clip_by_global_norm — the usual training substrate.

Optimizer states mirror the parameter tree structure, so the parameter
sharding rules apply verbatim to the states (ZeRO-style sharded states for
free under pjit).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, new_state)


def cosine_schedule(peak_lr: float, warmup_steps: int, total_steps: int,
                    final_frac: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        progress = jnp.clip(
            (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1),
            0.0, 1.0,
        )
        cos = peak_lr * (final_frac + (1 - final_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * progress)))
        return jnp.where(step < warmup_steps, warm, cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    gnorm = jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree_util.tree_leaves(grads)
    ))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gnorm


# -------------------------------------------------------------------- AdamW
class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def adamw(lr, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
        return AdamWState(jnp.zeros((), jnp.int32),
                          jax.tree.map(zeros, params),
                          jax.tree.map(zeros, params))

    def update(grads, state, params):
        step = state.step + 1
        lr_t = lr_fn(step)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * g
            v_new = b2 * v + (1 - b2) * g * g
            mhat = m_new / (1 - b1 ** step.astype(jnp.float32))
            vhat = v_new / (1 - b2 ** step.astype(jnp.float32))
            delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(
                jnp.float32
            )
            return (-lr_t * delta).astype(p.dtype), m_new, v_new

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        updates = jax.tree.map(lambda t: t[0], out,
                               is_leaf=lambda t: isinstance(t, tuple))
        m_new = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        v_new = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return updates, AdamWState(step, m_new, v_new)

    return Optimizer(init, update)


# ---------------------------------------------------------------- Adafactor
class AdafactorState(NamedTuple):
    step: jax.Array
    vr: dict   # row stats (last dim reduced)
    vc: dict   # col stats (second-to-last dim reduced)
    v: dict    # full stats for <2D params only


def adafactor(lr, decay=0.8, eps=1e-30, clip_threshold=1.0,
              weight_decay=0.0) -> Optimizer:
    """Factored RMS optimizer (Shazeer & Stern).  For a (..., R, C) weight it
    stores (..., R) + (..., C) statistics — ~0.1% of AdamW's state."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def factored(p):
        return p.ndim >= 2

    def init(params):
        def vr0(p):
            return (jnp.zeros(p.shape[:-1], jnp.float32) if factored(p)
                    else jnp.zeros((), jnp.float32))

        def vc0(p):
            return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                    if factored(p) else jnp.zeros((), jnp.float32))

        def v0(p):
            return (jnp.zeros((), jnp.float32) if factored(p)
                    else jnp.zeros(p.shape, jnp.float32))

        return AdafactorState(
            jnp.zeros((), jnp.int32),
            jax.tree.map(vr0, params), jax.tree.map(vc0, params),
            jax.tree.map(v0, params),
        )

    def update(grads, state, params):
        step = state.step + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-decay)
        lr_t = lr_fn(step)

        def upd(g, vr, vc, v, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if factored(p):
                vr_new = beta * vr + (1 - beta) * g2.mean(axis=-1)
                vc_new = beta * vc + (1 - beta) * g2.mean(axis=-2)
                r = vr_new / jnp.maximum(
                    vr_new.mean(axis=-1, keepdims=True), eps
                )
                pre = g / jnp.sqrt(r[..., None] * vc_new[..., None, :] + eps)
                v_new = v
            else:
                v_new = beta * v + (1 - beta) * g2
                pre = g / jnp.sqrt(v_new + eps)
                vr_new, vc_new = vr, vc
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(pre * pre) + 1e-12)
            pre = pre / jnp.maximum(1.0, rms / clip_threshold)
            delta = pre + weight_decay * p.astype(jnp.float32)
            return (-lr_t * delta).astype(p.dtype), vr_new, vc_new, v_new

        out = jax.tree.map(upd, grads, state.vr, state.vc, state.v, params)
        pick = lambda i: jax.tree.map(  # noqa: E731
            lambda tup: tup[i], out, is_leaf=lambda x: isinstance(x, tuple)
        )
        return pick(0), AdafactorState(step, pick(1), pick(2), pick(3))

    return Optimizer(init, update)


def make_optimizer(name: str, lr, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(lr, **kw)
    if name == "adafactor":
        return adafactor(lr, **kw)
    raise KeyError(name)
