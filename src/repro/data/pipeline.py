"""Input pipeline with workload-driven shard placement (the paper's technique
at the storage layer).

The pipeline owns a set of dataset shards replicated RF-way across data
hosts.  At job setup it mines the mixture schedule for batch "recipes"
(shard-sets read together), fits the paper's placement (PRA-3W by default),
and thereafter assembles every global batch by greedy-set-cover replica
selection — touching as few hosts as possible, re-covering around dead or
straggling hosts from surviving replicas.

On a real cluster the `HostStore` would be per-machine file caches; here it
is an in-memory simulation with the same control flow, which lets the tests
assert the span/failure behaviour end-to-end with real token tensors.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import plan_shard_placement
from repro.core.shard_placement import ShardPlacementPlan, mixture_batch_recipes


class SyntheticTokenSource:
    """Deterministic synthetic corpus: shard s yields tokens from a stream
    seeded by s (stands in for tokenized files; statistics don't matter for
    systems tests, determinism does)."""

    def __init__(self, vocab_size: int, shard_tokens: int = 1 << 16):
        self.vocab = vocab_size
        self.shard_tokens = shard_tokens

    def read(self, shard: int, offset: int, n: int) -> np.ndarray:
        rng = np.random.default_rng(shard * 1_000_003 + offset)
        return rng.integers(0, self.vocab, size=n, dtype=np.int32)


@dataclasses.dataclass
class HostStats:
    reads: int = 0
    bytes: int = 0


class PlacementAwarePipeline:
    def __init__(
        self,
        num_shards: int,
        num_hosts: int,
        vocab_size: int,
        batch_size: int,
        seq_len: int,
        cache_capacity: int = 64,
        algorithm: str = "pra3",
        num_batches_trace: int = 512,
        shards_per_batch: int = 8,
        seed: int = 0,
    ):
        self.source = SyntheticTokenSource(vocab_size)
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.num_hosts = num_hosts
        self.seed = seed
        # workload trace -> the paper's placement
        self.recipes = mixture_batch_recipes(
            num_shards, num_batches_trace, shards_per_batch=shards_per_batch,
            seed=seed,
        )
        self.plan: ShardPlacementPlan = plan_shard_placement(
            self.recipes, num_shards, num_hosts, capacity=cache_capacity,
            algorithm=algorithm, seed=seed,
        )
        self.dead_hosts: set[int] = set()
        self.slow_hosts: set[int] = set()
        self.host_stats = [HostStats() for _ in range(num_hosts)]
        self._step = 0
        self.span_log: list[int] = []

    # ------------------------------------------------------------- failures
    def mark_dead(self, host: int):
        self.dead_hosts.add(host)

    def mark_slow(self, host: int):
        """Straggler mitigation: a slow host is avoided exactly like a dead
        one (its shards re-covered from replicas), but may recover."""
        self.slow_hosts.add(host)

    def mark_recovered(self, host: int):
        self.dead_hosts.discard(host)
        self.slow_hosts.discard(host)

    # --------------------------------------------------------------- batches
    def next_batch(self) -> dict:
        recipe = self.recipes[self._step % len(self.recipes)]
        avoid = self.dead_hosts | self.slow_hosts
        if avoid:
            hosts, accessed = self.plan.cover_excluding(recipe, avoid)
        else:
            hosts, accessed = self.plan.hosts_for_batch(recipe)
        self.span_log.append(len(hosts))
        # deterministic interleave of shard streams into (B, S+1)
        per = self.batch_size * (self.seq_len + 1)
        chunks = []
        for h, shard_ids in zip(hosts, accessed):
            st = self.host_stats[h]
            for s in shard_ids:
                take = per // max(1, sum(len(a) for a in accessed))
                tok = self.source.read(int(s), self._step, take + 1)
                chunks.append(tok)
                st.reads += 1
                st.bytes += tok.nbytes
        flat = np.concatenate(chunks)
        reps = -(-per // len(flat))
        flat = np.tile(flat, reps)[:per].reshape(
            self.batch_size, self.seq_len + 1
        )
        self._step += 1
        return {
            "tokens": flat[:, :-1].copy(),
            "targets": flat[:, 1:].copy(),
            "hosts": hosts,
        }

    # --------------------------------------------------------------- metrics
    def avg_span(self) -> float:
        return float(np.mean(self.span_log)) if self.span_log else 0.0

    def idle_host_fraction(self) -> float:
        """The paper's energy story: hosts untouched by the workload can
        sleep."""
        touched = sum(1 for s in self.host_stats if s.reads > 0)
        return 1.0 - touched / self.num_hosts
