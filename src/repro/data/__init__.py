from .pipeline import PlacementAwarePipeline, SyntheticTokenSource  # noqa: F401
