"""qwen3-moe-30b-a3b [moe]: 48L, d_model=2048, 32H (GQA kv=4, head_dim=128),
128 experts top-8, expert d_ff=768, vocab=151936 [hf:Qwen/Qwen3-30B-A3B]."""

from .base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=6144,                # unused (all layers MoE); kept for completeness
    vocab_size=151936,
    attention="gqa",
    moe=MoEConfig(
        num_experts=128,
        top_k=8,
        d_ff_expert=768,
        num_shared_experts=0,
        first_k_dense=0,
        placement_slack_slots=2,
    ),
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
))
