"""olmo-1b [dense]: 16L, d_model=2048, 16H (GQA kv=16 == MHA), d_ff=8192,
vocab=50304, non-parametric LayerNorm, tied embeddings [arXiv:2402.00838]."""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    attention="gqa",
    mlp="swiglu",
    norm="nonparametric_ln",
    tie_embeddings=True,
    rope_theta=10_000.0,
))
