"""h2o-danube-1.8b [dense]: 24L, d_model=2560, 32H (GQA kv=8), d_ff=6912,
vocab=32000; llama+mistral mix with sliding-window attention
[arXiv:2401.16818].  SWA makes long_500k decodable with a window KV cache."""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6912,
    vocab_size=32000,
    attention="gqa",
    mlp="swiglu",
    norm="rmsnorm",
    sliding_window=4096,
    rope_theta=10_000.0,
))
