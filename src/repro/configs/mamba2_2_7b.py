"""mamba2-2.7b [ssm]: 64L, d_model=2560, attention-free, SSD state=128,
vocab=50280 [arXiv:2405.21060].  Decodes at any context length with O(1)
state — runs the long_500k shape."""

from .base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=1,              # unused (attention-free)
    num_kv_heads=1,
    d_ff=0,                   # no FFN: mamba2 block only
    vocab_size=50280,
    attention="none",
    ssm=SSMConfig(
        state_dim=128,
        head_dim=64,
        expand=2,
        conv_width=4,
        chunk_size=256,
    ),
    norm="rmsnorm",
))
