"""deepseek-v3-671b [moe]: 61L, d_model=7168, 128H, MLA, MoE 256 routed
(top-8) + 1 shared expert, expert d_ff=2048, first 3 layers dense
(d_ff=18432), vocab=129280, 1 MTP head [arXiv:2412.19437].

This is the flagship target for the paper's expert-placement technique:
256 routed experts x 61 MoE layers across EP ranks, with replication slack
for hot/co-firing experts.
"""

from .base import MLAConfig, ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,          # MLA: KV heads == heads post-decompression
    head_dim=128,
    d_ff=18432,                # dense layers (first_k_dense=3)
    vocab_size=129280,
    attention="mla",
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=256,
        top_k=8,
        d_ff_expert=2048,
        num_shared_experts=1,
        first_k_dense=3,
        placement_slack_slots=2,   # replicas for hot experts (paper technique)
    ),
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    mtp_depth=1,
))
