"""seamless-m4t-medium [audio]: encoder-decoder multimodal transformer.

12L, d_model=1024, 16H (GQA kv=16 == MHA), d_ff=4096, vocab=256206
[arXiv:2308.11596; hf].  The speech frontend (w2v-BERT conformer) is a STUB:
`input_specs()` feeds precomputed frame embeddings (frontend="audio_frames").
"""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    num_layers=12,            # decoder layers
    encoder_layers=12,        # text/speech encoder layers
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    attention="gqa",
    mlp="gelu",               # m4t uses relu/gelu FFN, non-gated
    norm="layernorm",
    frontend="audio_frames",
    frontend_len=1024,        # stub: 1024 speech frames per utterance
))
