"""nemotron-4-15b [dense]: 32L, d_model=6144, 48H (GQA kv=8), d_ff=24576,
vocab=256000, squared-ReLU MLP (non-gated) [arXiv:2402.16819]."""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256000,
    attention="gqa",
    mlp="squared_relu",
    norm="layernorm",
    rope_theta=10_000.0,
))
