"""Model / run configuration dataclasses and the architecture registry.

One file per assigned architecture lives next to this module; each exposes
`CONFIG = ModelConfig(...)` with the published numbers and registers itself.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

__all__ = [
    "MoEConfig", "MLAConfig", "SSMConfig", "ModelConfig", "ShapeConfig",
    "SHAPE_GRID", "register", "get_config", "list_configs", "reduce_config",
]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    first_k_dense: int = 0          # leading dense layers (deepseek-v3: 3)
    router_noise: float = 0.0
    capacity_factor: float = 1.25
    # workload-driven expert placement (the paper's technique)
    placement_slack_slots: int = 0  # spare slots per EP rank for replicas


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk_size: int = 256
    # number of SSM heads = d_model * expand // head_dim unless overridden
    num_heads: int | None = None


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None            # default d_model // num_heads
    attention: Literal["gqa", "mla", "none", "hybrid"] = "gqa"
    mlp: Literal["swiglu", "squared_relu", "gelu"] = "swiglu"
    norm: Literal["rmsnorm", "layernorm", "nonparametric_ln"] = "rmsnorm"
    rope_theta: float = 10_000.0
    sliding_window: int | None = None      # SWA width where used
    global_attn_every: int | None = None   # hybrid SWA/global layer pattern
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    encoder_layers: int = 0                # >0 => encoder-decoder
    frontend: Literal[None, "audio_frames", "vision_patches"] = None
    frontend_len: int = 0                  # stub prefix length (frames/patches)
    mtp_depth: int = 0                     # deepseek multi-token prediction
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def is_subquadratic(self) -> bool:
        """Can this arch decode at 500k context without a dense KV cache?"""
        if self.attention == "none":
            return True
        if self.attention == "hybrid":
            return True  # SSM state + (mostly) windowed attention
        return self.sliding_window is not None

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for roofline's
        MODEL_FLOPS = 6*N*D."""
        d, v = self.d_model, self.vocab_size
        hd = self.resolved_head_dim
        total = v * d  # embed
        if not self.tie_embeddings:
            total += v * d
        def attn_params():
            if self.attention == "mla" and self.mla:
                m = self.mla
                qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
                p = d * m.q_lora_rank + m.q_lora_rank * self.num_heads * qk_hd
                p += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                p += m.kv_lora_rank * self.num_heads * (
                    m.qk_nope_head_dim + m.v_head_dim
                )
                p += self.num_heads * m.v_head_dim * d
                return p
            q = d * self.num_heads * hd
            kv = 2 * d * self.num_kv_heads * hd
            o = self.num_heads * hd * d
            return q + kv + o

        def mlp_params(ff):
            mult = 3 if self.mlp == "swiglu" else 2
            return mult * d * ff

        def ssm_params():
            s = self.ssm
            d_in = d * s.expand
            nh = s.num_heads or d_in // s.head_dim
            # in_proj (z, x, B, C, dt) + conv + out_proj (mamba2 fused proj)
            p = d * (2 * d_in + 2 * s.state_dim + nh)
            p += (d_in + 2 * s.state_dim) * s.conv_width
            p += nh * 2  # A, D
            p += d_in * d
            return p

        blocks = 0
        for layer in range(self.num_layers):
            if self.attention == "none":
                blocks += ssm_params()
            elif self.attention == "hybrid":
                blocks += attn_params() + ssm_params() + mlp_params(self.d_ff)
            else:
                blocks += attn_params()
                if self.moe and layer >= self.moe.first_k_dense:
                    m = self.moe
                    blocks += (m.num_experts + m.num_shared_experts) * mlp_params(
                        m.d_ff_expert
                    )
                    blocks += d * m.num_experts  # router
                else:
                    blocks += mlp_params(self.d_ff)
        total += blocks
        if self.encoder_layers:
            enc = self.encoder_layers * (attn_params() + mlp_params(self.d_ff))
            xattn = self.num_layers * attn_params()  # cross-attention blocks
            total += enc + xattn
        return int(total)

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k + shared only)."""
        if not self.moe:
            return self.param_count()
        m = self.moe
        full = self.param_count()
        mult = 3 if self.mlp == "swiglu" else 2
        expert_p = mult * self.d_model * m.d_ff_expert
        moe_layers = self.num_layers - m.first_k_dense
        inactive = moe_layers * (m.num_experts - m.top_k) * expert_p
        return int(full - inactive)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPE_GRID = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all():
    # import every sibling config module so it registers itself
    import importlib
    import pkgutil

    import repro.configs as pkg

    for m in pkgutil.iter_modules(pkg.__path__):
        if m.name not in ("base", "registry"):
            importlib.import_module(f"repro.configs.{m.name}")


def reduce_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Smoke-test-sized variant of an architecture: same family/wiring, tiny
    dims.  Keeps structural ratios (kv groups, expert count scaled down)."""
    small = dict(
        num_layers=min(cfg.num_layers, 2),
        d_model=128,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 4) * 4 // max(cfg.num_heads, 4)) or 1,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        encoder_layers=min(cfg.encoder_layers, 2),
        frontend_len=min(cfg.frontend_len, 8) if cfg.frontend else 0,
        name=cfg.name + "-smoke",
    )
    # keep GQA ratio sane: kv_heads must divide heads
    if small["num_heads"] % small["num_kv_heads"]:
        small["num_kv_heads"] = 1
    if cfg.moe:
        small["moe"] = dataclasses.replace(
            cfg.moe, num_experts=8, top_k=min(cfg.moe.top_k, 2), d_ff_expert=64,
            first_k_dense=min(cfg.moe.first_k_dense, 1),
            # no-drop capacity: keeps teacher-forced decode == full forward
            # (capacity drops are a train-time batch-size-dependent effect)
            capacity_factor=8.0,
        )
    if cfg.mla:
        small["mla"] = MLAConfig(
            q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=32,
            qk_rope_head_dim=16, v_head_dim=32,
        )
    if cfg.ssm:
        small["ssm"] = dataclasses.replace(
            cfg.ssm, state_dim=16, head_dim=16, chunk_size=32, num_heads=None,
        )
    if cfg.sliding_window:
        small["sliding_window"] = 64
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
