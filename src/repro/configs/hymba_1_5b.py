"""hymba-1.5b [hybrid]: 32L, d_model=1600, 25 attn heads (GQA kv=5) fused in
PARALLEL with SSM heads (state=16) in every block; SWA in all but 3 global
layers; d_ff=5504, vocab=32001 [arXiv:2411.13676].

Sub-quadratic (SSM + windowed attention) => runs the long_500k shape; the 3
global layers keep a full KV cache, the rest a ring buffer.
"""

from .base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    attention="hybrid",
    sliding_window=1024,
    global_attn_every=16,     # layers 0, 16, 31 -> ~3 global layers
    ssm=SSMConfig(
        state_dim=16,
        head_dim=64,
        expand=2,
        conv_width=4,
        chunk_size=256,
    ),
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
))
