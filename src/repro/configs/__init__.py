from .base import (  # noqa: F401
    SHAPE_GRID,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    get_config,
    list_configs,
    reduce_config,
    register,
)
