"""internvl2-2b [vlm]: InternViT-300M frontend (STUB) + InternLM2-1.8B LM.

24L, d_model=2048, 16H (GQA kv=8), d_ff=8192, vocab=92553
[arXiv:2404.16821; hf].  The vision tower is a STUB: `input_specs()` feeds
precomputed, d_model-projected patch embeddings (frontend="vision_patches").
"""

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    attention="gqa",
    mlp="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    frontend="vision_patches",
    frontend_len=256,         # stub: 256 visual tokens (one 448^2 tile)
))
