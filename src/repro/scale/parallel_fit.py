"""Parallel per-shard fits + deterministic merge + boundary repair.

The third stage of the cluster-scale pipeline: every `ShardSpec` from
`sharder.shard_workload` is an independent placement problem (its own
sub-hypergraph, partition slice, capacity), so the fits dispatch onto a
process pool (``flags.FLAGS["scale_workers"]``) — with a deterministic
serial fallback that produces BIT-IDENTICAL results, because

  * each shard's fit is a pure function of (algorithm, shard CSR, seed) —
    the shard seed is ``seed + shard_index``, never pool-order dependent;
  * results are merged in shard-index order regardless of completion order;
  * the flags snapshot rides along in the worker payload, so child
    processes compute under the caller's exact configuration.

Merge: shard s's fit occupies global partition rows
``part_offset[s]:part_offset[s+1]`` and its local item ids map back through
``ShardSpec.items`` — the merged membership matrix is block-structured, one
block per shard.  Capacity reconciliation then re-derives every row's load
from the merged matrix and validates it against the global capacity (each
shard fitted under the same per-partition capacity, so the merge cannot
overflow; the check guards the invariant rather than trusting it).

Boundary repair: the merged plan has never seen the cross-shard edges, so a
bounded LMBR pass (``flags.FLAGS["scale_boundary_repair"]`` moves) runs on
the hypergraph of exactly those edges, warm-started from the merged
placement.  LMBR only ever COPIES items into free space under the capacity
check, so the pass is capacity-safe by construction and strictly
non-destructive — existing replicas never move, matching
`PlacementService.refit`'s online-cheap contract.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .. import flags as _flags
from .. import obs as _obs
from ..core.algorithms import ALGORITHMS, lmbr
from ..core.cluster import normalize_capacity
from ..core.hypergraph import Hypergraph
from ..core.setcover import Placement
from .sharder import ShardingPlan, shard_workload

__all__ = ["ShardedFitResult", "fit_sharded_placement"]


def _fit_shard_worker(payload: tuple) -> tuple[np.ndarray, dict | None]:
    """Top-level (picklable) per-shard fit: rebuild the shard hypergraph
    from raw CSR arrays, restore the caller's flags, run the algorithm."""
    (algo_name, flag_snapshot, edge_ptr, edge_nodes, node_w, edge_w,
     n_parts, capacity, seed, nruns, algo_kwargs) = payload
    _flags.FLAGS.update(flag_snapshot)
    hg = Hypergraph(edge_ptr, edge_nodes, node_w, edge_w)
    fn = ALGORITHMS[algo_name]
    pl = fn(hg, n_parts, capacity, seed=seed, nruns=nruns, **algo_kwargs)
    pl.validate()
    return pl.member, pl.stats


@dataclasses.dataclass
class ShardedFitResult:
    """A merged sharded fit plus the pipeline's diagnostics."""

    placement: Placement
    sharding: ShardingPlan
    stats: dict

    @property
    def member(self) -> np.ndarray:
        return self.placement.member


def _shard_payloads(sharding: ShardingPlan, algorithm: str, seed: int,
                    nruns: int, algo_kwargs: dict) -> list[tuple | None]:
    snapshot = dict(_flags.FLAGS)
    payloads: list[tuple | None] = []
    for s, spec in enumerate(sharding.shards):
        if len(spec.items) == 0:
            payloads.append(None)  # empty shard: rows stay empty
            continue
        payloads.append((
            algorithm, snapshot,
            spec.sub_hg.edge_ptr, spec.sub_hg.edge_nodes,
            spec.sub_hg.node_weights, spec.sub_hg.edge_weights,
            spec.num_partitions, spec.capacity, seed + s, nruns,
            algo_kwargs,
        ))
    return payloads


def _run_fits(payloads, workers: int):
    """(results aligned with payloads, used_pool) — pool when workers > 1
    and a pool can be created, else the bit-identical serial path."""
    live = [(i, p) for i, p in enumerate(payloads) if p is not None]
    results: list = [None] * len(payloads)
    if workers > 1 and len(live) > 1:
        try:
            from concurrent.futures import ProcessPoolExecutor

            with ProcessPoolExecutor(max_workers=workers) as ex:
                outs = list(ex.map(_fit_shard_worker, [p for _, p in live]))
            for (i, _), out in zip(live, outs):
                results[i] = out
            return results, True
        except (ImportError, OSError, PermissionError):
            pass  # containers without /dev/shm etc.: fall through to serial
    for i, p in live:
        results[i] = _fit_shard_worker(p)
    return results, False


def fit_sharded_placement(
    hg: Hypergraph,
    num_partitions: int,
    capacity: "float | np.ndarray",
    algorithm: str = "lmbr",
    seed: int = 0,
    nruns: int = 2,
    num_shards: int | None = None,
    workers: int | None = None,
    boundary_repair: int | None = None,
    **algo_kwargs,
) -> ShardedFitResult:
    """The full pipeline: shard -> parallel per-shard fits -> merge ->
    bounded boundary repair.  Deterministic for fixed (inputs, seed)
    regardless of worker count."""
    if algorithm not in ALGORITHMS:
        raise KeyError(f"unknown algorithm {algorithm!r}")
    capacity = normalize_capacity(capacity)
    if num_shards is None:
        num_shards = int(_flags.FLAGS.get("scale_shards", 0))
    if num_shards <= 0:
        num_shards = max(1, num_partitions // 8)
    if workers is None:
        workers = int(_flags.FLAGS.get("scale_workers", 1))
    if boundary_repair is None:
        boundary_repair = int(_flags.FLAGS.get("scale_boundary_repair", 256))

    with _obs.timed("scale.shard", shards=num_shards) as _t:
        sharding = shard_workload(hg, num_partitions, capacity, num_shards,
                                  seed=seed)
    t_shard = _t.seconds

    with _obs.timed("scale.fit", workers=workers) as _t:
        payloads = _shard_payloads(sharding, algorithm, seed, nruns,
                                   algo_kwargs)
        results, used_pool = _run_fits(payloads, workers)
    t_fit = _t.seconds

    # ------------------------------------------------------------- merge
    with _obs.timed("scale.merge") as _t:
        member = np.zeros((num_partitions, hg.num_nodes), dtype=bool)
        shard_moves = 0
        for s, out in enumerate(results):
            if out is None:
                continue
            sub_member, sub_stats = out
            lo = int(sharding.part_offset[s])
            rows = np.arange(sub_member.shape[0]) + lo
            member[np.ix_(rows, sharding.shards[s].items)] = sub_member
            if sub_stats:
                shard_moves += int(sub_stats.get("moves", 0))
        merged = Placement(member, capacity, hg.node_weights)
        # capacity reconciliation: re-derive loads from the merged matrix
        # and enforce the global budget (raises on any overflowing row)
        merged.validate()
    t_merge = _t.seconds

    # -------------------------------------------------- boundary repair
    with _obs.timed("scale.repair") as _t:
        repair_moves = 0
        if boundary_repair > 0 and len(sharding.boundary_edges):
            bhg = hg.subhypergraph_edges(sharding.boundary_edges)
            repaired = lmbr(
                bhg, num_partitions, capacity, seed=seed,
                initial=merged, max_moves=int(boundary_repair),
            )
            repaired.validate()
            repair_moves = int((repaired.stats or {}).get("moves", 0))
            merged = Placement(
                repaired.member, capacity, hg.node_weights
            )
    t_repair = _t.seconds

    merged.stats = dict(
        shards=sharding.num_shards,
        components=sharding.num_components,
        boundary_edges=int(len(sharding.boundary_edges)),
        boundary_cost=round(float(sharding.boundary_cost), 3),
        workers=int(workers), used_pool=bool(used_pool),
        shard_moves=shard_moves, repair_moves=repair_moves,
        shard_seconds=round(t_shard, 3), fit_seconds=round(t_fit, 3),
        merge_seconds=round(t_merge, 3), repair_seconds=round(t_repair, 3),
    )
    return ShardedFitResult(placement=merged, sharding=sharding,
                            stats=merged.stats)
