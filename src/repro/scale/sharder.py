"""Workload decomposition: cut a trace into near-independent sub-workloads.

The paper's fit is monolithic — one hypergraph, one IHPA/DS/LMBR pass.  But
real traces decompose: queries touch items from one tenant / table family /
content cluster, so the co-access hypergraph splits into components that
never interact, plus a thin seam of cross-cluster queries.  Golab et al.
(arXiv:1312.0285) exploit exactly this structure for placement; here it
bounds fit cost — each sub-workload fits independently (and in parallel,
see `parallel_fit`), and only the seam needs global attention.

Decomposition runs in two stages:

1. **Connected components** of the item co-access graph (items are connected
   iff some query reads both), computed by vectorized label propagation with
   pointer jumping — per round, every hyperedge broadcasts its minimum label
   to its pins (`np.minimum.reduceat` + `np.minimum.at`), then labels
   pointer-jump to their root; rounds are O(log diameter), every round one
   pass over the pin array.  A component can never be split by a query, so
   per-component fits lose NOTHING — components are exactly independent.
2. **HPA-style coarse cut** of oversized components: a component heavier
   than the target shard weight is partitioned by the repo's multilevel
   partitioner (`hpa.partition`) into near-balanced pieces, minimizing the
   connectivity cost of the cut — the same objective the paper uses for
   placement, applied one level up.  This is where independence becomes
   approximate: edges crossing the cut become *boundary edges*.

Pieces then bin-pack into ``num_shards`` shards (worst-fit decreasing:
heaviest piece first into the currently LIGHTEST shard, ties -> lowest
shard id — deterministic, and it keeps shard weights balanced so partition
budgets and per-shard fit costs stay balanced too).

Boundary-edge cost model
------------------------
For edge e let ``lambda_e`` = number of distinct shards its pins land in.
``boundary_edges`` are those with lambda_e > 1.  Ignoring them during
per-shard fits costs at most

    boundary_cost = sum_e  w_e * (lambda_e - 1)

extra span: a query confined to one shard can always be covered within that
shard's partitions, while a boundary edge must touch >= lambda_e shards'
partition sets no matter how well each shard is fitted — (lambda_e - 1) is
the per-edge worst-case *additional* span versus a monolithic fit that
co-locates the edge (the same connectivity metric HPA minimizes, evaluated
at shard granularity).  `ShardingPlan.boundary_cost` reports it, and the
bounded LMBR repair pass in `parallel_fit` spends its move budget exactly
on these edges.

Each shard's sub-workload keeps every edge fully inside the shard, plus the
>= 2-pin *fragments* of boundary edges (their pins inside this shard, full
edge weight) — the same restriction `PlacementService.fit_hierarchical`
applies per pod, so the co-location signal of seam queries is not thrown
away, only their cross-shard part is deferred to the repair pass.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core import hpa as hpa_mod
from ..core.cluster import normalize_capacity
from ..core.hypergraph import Hypergraph

__all__ = ["connected_components", "ShardSpec", "ShardingPlan", "shard_workload"]


def connected_components(hg: Hypergraph) -> np.ndarray:
    """(V,) component label per item — the minimum item id reachable through
    shared hyperedges (items in no edge are their own singleton component).
    Deterministic and fully vectorized (label propagation + pointer jump).
    """
    V = hg.num_nodes
    label = np.arange(V, dtype=np.int64)
    if hg.num_pins == 0:
        return label
    sizes = hg.edge_sizes()
    ne = np.flatnonzero(sizes > 0)  # reduceat cannot take empty segments
    pin_e = np.repeat(np.arange(len(ne), dtype=np.int64), sizes[ne])
    # the CSR pin array restricted to nonempty edges, contiguous
    nz_pins = hg.edge_nodes if len(ne) == hg.num_edges else (
        hg.edges_csr(ne)[1]
    )
    starts = np.zeros(len(ne), dtype=np.int64)
    np.cumsum(sizes[ne][:-1], out=starts[1:])
    while True:
        edge_min = np.minimum.reduceat(label[nz_pins], starts)
        before = label.copy()
        np.minimum.at(label, nz_pins, edge_min[pin_e])
        # pointer jumping: compress label chains to their current root
        while True:
            nxt = label[label]
            if np.array_equal(nxt, label):
                break
            label = nxt
        if np.array_equal(label, before):
            return label


@dataclasses.dataclass
class ShardSpec:
    """One shard's sub-workload, ready for an independent fit.

    items:        global item ids homed on this shard (ascending)
    sub_hg:       relabeled hypergraph over those items (internal edges +
                  local fragments of boundary edges)
    num_partitions / capacity: this shard's slice of the global budget
                  (capacity is the global scalar, or — heterogeneous
                  clusters — this shard's contiguous slice of the global
                  per-partition capacity vector)
    weight:       total item weight homed here
    """

    items: np.ndarray
    sub_hg: Hypergraph
    num_partitions: int
    capacity: "float | np.ndarray"
    weight: float


@dataclasses.dataclass
class ShardingPlan:
    """The decomposition: item->shard map, per-shard specs, boundary model."""

    item_shard: np.ndarray        # (V,) shard id per item
    shards: list[ShardSpec]
    part_offset: np.ndarray       # (S+1,) global partition rows per shard
    boundary_edges: np.ndarray    # global edge ids with lambda_e > 1
    boundary_lambda: np.ndarray   # distinct shards per boundary edge
    boundary_cost: float          # sum w_e * (lambda_e - 1)
    num_components: int

    @property
    def num_shards(self) -> int:
        return len(self.shards)

    def summary(self) -> dict:
        return dict(
            shards=self.num_shards,
            components=self.num_components,
            boundary_edges=int(len(self.boundary_edges)),
            boundary_cost=round(float(self.boundary_cost), 3),
            shard_items=[len(s.items) for s in self.shards],
            shard_parts=[s.num_partitions for s in self.shards],
        )


def _cut_component(hg: Hypergraph, comp_items: np.ndarray, pieces: int,
                   seed: int) -> list[np.ndarray]:
    """HPA coarse cut of one oversized component into `pieces` near-balanced
    item sets (global ids)."""
    mask = np.zeros(hg.num_nodes, dtype=bool)
    mask[comp_items] = True
    # components never split an edge, so e is in the component iff its
    # first pin is — no incidence walk needed
    nonempty = np.flatnonzero(hg.edge_sizes() > 0)
    eids = nonempty[mask[hg.edge_nodes[hg.edge_ptr[:-1][nonempty]]]]
    sub = hg.subhypergraph_edges(eids) if len(eids) else Hypergraph(
        np.zeros(1, dtype=np.int64), np.zeros(0, dtype=np.int64),
        hg.node_weights, np.zeros(0, dtype=np.float64),
    )
    remap = np.full(hg.num_nodes, -1, dtype=np.int64)
    remap[comp_items] = np.arange(len(comp_items))
    local = Hypergraph(
        sub.edge_ptr, remap[sub.edge_nodes],
        hg.node_weights[comp_items].copy(), sub.edge_weights,
    )
    w = float(local.node_weights.sum())
    # near-balance capacity, same slack formula as lmbr's Algorithm-4 start
    cap = w / pieces * 1.1 + float(local.node_weights.max())
    assign = hpa_mod.partition(local, pieces, cap, seed=seed, nruns=1)
    return [comp_items[assign == p] for p in range(pieces)]


def _het_partition_budget(caps: np.ndarray, num_partitions: int,
                          shard_w: np.ndarray, total_w: float) -> np.ndarray:
    """Split a heterogeneous capacity vector's rows across shards.

    Shards own CONTIGUOUS row slices (the merge in `parallel_fit` maps
    shard s onto rows ``part_offset[s]:part_offset[s+1]``), so the budget
    is a vector of row COUNTS: start weight-proportional (largest
    remainder, >= 1 row each), then sweep left-to-right moving rows from
    the largest-count donor into any shard whose slice cannot hold its
    weight.  Deterministic; raises when no contiguous split fits."""
    num_shards = len(shard_w)
    if len(caps) != num_partitions:
        raise ValueError(
            f"capacity vector has {len(caps)} entries, want {num_partitions}"
        )
    if total_w > float(caps.sum()) + 1e-9:
        raise ValueError(
            f"{num_partitions} heterogeneous partitions (total capacity "
            f"{float(caps.sum()):.1f}) cannot hold the sharded workload "
            f"(weight {total_w:.1f})"
        )
    share = shard_w / max(total_w, 1e-12) * num_partitions
    n_parts = np.maximum(1, np.floor(share).astype(np.int64))
    # trim the >= 1 floor's overshoot from the largest counts
    while int(n_parts.sum()) > num_partitions:
        d = int(np.argmax(n_parts))
        n_parts[d] -= 1
    rem = num_partitions - int(n_parts.sum())
    if rem > 0:
        frac_order = np.lexsort(
            (np.arange(num_shards), -(share - np.floor(share)))
        )
        for i in range(rem):
            n_parts[frac_order[i % num_shards]] += 1
    # feasibility sweep: contiguous slice capacities change whenever a
    # count changes, so re-derive offsets each round; bounded rounds
    for _ in range(4 * num_partitions):
        off = np.zeros(num_shards + 1, dtype=np.int64)
        np.cumsum(n_parts, out=off[1:])
        slice_cap = np.add.reduceat(caps, off[:-1])
        bad = np.flatnonzero(shard_w > slice_cap + 1e-9)
        if not len(bad):
            return n_parts
        s = int(bad[0])
        donors = np.flatnonzero((n_parts > 1) & (np.arange(num_shards) != s))
        if not len(donors):
            break
        d = int(donors[np.lexsort((donors, -n_parts[donors]))[0]])
        n_parts[d] -= 1
        n_parts[s] += 1
    raise ValueError(
        "no contiguous heterogeneous partition split fits the shard "
        "weights; reduce num_shards or rebalance capacities"
    )


def shard_workload(
    hg: Hypergraph,
    num_partitions: int,
    capacity: "float | np.ndarray",
    num_shards: int,
    seed: int = 0,
) -> ShardingPlan:
    """Decompose `hg` into `num_shards` near-independent sub-workloads and
    allocate the `num_partitions` x `capacity` budget across them
    (``capacity`` may be the global per-partition vector; each shard then
    receives its contiguous slice)."""
    if num_shards < 1:
        raise ValueError("num_shards must be >= 1")
    num_shards = min(num_shards, num_partitions)
    V = hg.num_nodes
    total_w = hg.total_node_weight()
    target_w = total_w / num_shards

    label = connected_components(hg)
    comp_ids, comp_of = np.unique(label, return_inverse=True)
    comp_w = np.bincount(comp_of, weights=hg.node_weights)
    num_components = len(comp_ids)

    # pieces to pack: whole small components, HPA-cut slices of big ones
    pieces: list[np.ndarray] = []
    order = np.argsort(-comp_w, kind="stable")  # heaviest first
    for ci in order:
        items = np.flatnonzero(comp_of == ci)
        w = float(comp_w[ci])
        if w > 1.25 * target_w and len(items) > 1 and num_shards > 1:
            k = min(num_shards, max(2, int(np.ceil(w / target_w))))
            pieces.extend(_cut_component(hg, items, k, seed=seed + int(ci)))
        else:
            pieces.append(items)
    pieces = [p for p in pieces if len(p)]

    # worst-fit decreasing bin pack of pieces into shards by weight
    pw = np.array([float(hg.node_weights[p].sum()) for p in pieces])
    porder = np.argsort(-pw, kind="stable")
    shard_w = np.zeros(num_shards, dtype=np.float64)
    item_shard = np.zeros(V, dtype=np.int64)
    for pi in porder:
        # lightest shard (ties -> lowest id): keeps shards balanced, which
        # keeps per-shard partition counts (and fit costs) balanced too
        s = int(np.argmin(shard_w))
        item_shard[pieces[pi]] = s
        shard_w[s] += pw[pi]

    het = isinstance(capacity, np.ndarray) and capacity.ndim
    if het:
        n_parts = _het_partition_budget(
            np.asarray(capacity, dtype=np.float64), num_partitions,
            shard_w, total_w,
        )
    else:
        # partition budget: every shard gets at least its feasibility
        # minimum (ceil(weight / capacity)); the remainder follows weight
        # (largest remainder method, ties -> lowest shard id)
        n_min = np.maximum(
            1, np.ceil(shard_w / capacity - 1e-9).astype(np.int64)
        )
        if int(n_min.sum()) > num_partitions:
            raise ValueError(
                f"{num_partitions} partitions x {capacity} cannot hold the "
                f"sharded workload (needs >= {int(n_min.sum())})"
            )
        spare = num_partitions - int(n_min.sum())
        share = shard_w / max(total_w, 1e-12) * spare
        extra = np.floor(share).astype(np.int64)
        rem = spare - int(extra.sum())
        if rem > 0:
            frac_order = np.lexsort((np.arange(num_shards), -(share - extra)))
            extra[frac_order[:rem]] += 1
        n_parts = n_min + extra
    part_offset = np.zeros(num_shards + 1, dtype=np.int64)
    np.cumsum(n_parts, out=part_offset[1:])

    # boundary accounting: lambda_e = distinct shards among e's pins
    sizes = hg.edge_sizes()
    pin_e = np.repeat(np.arange(hg.num_edges, dtype=np.int64), sizes)
    pin_shard = item_shard[hg.edge_nodes]
    # distinct count per edge via sort-by-(edge, shard) adjacent-diff
    so = np.lexsort((pin_shard, pin_e))
    ps, pe = pin_shard[so], pin_e[so]
    newv = np.ones(len(so), dtype=bool)
    if len(so):
        newv[1:] = (ps[1:] != ps[:-1]) | (pe[1:] != pe[:-1])
    lam = np.bincount(pe[newv], minlength=hg.num_edges) if len(so) else (
        np.zeros(hg.num_edges, dtype=np.int64)
    )
    boundary = np.flatnonzero(lam > 1)
    boundary_cost = float(
        (hg.edge_weights[boundary] * (lam[boundary] - 1)).sum()
    )

    # per-shard sub-workloads: internal edges + local >=2-pin fragments
    shards: list[ShardSpec] = []
    internal_of = np.full(hg.num_edges, -1, dtype=np.int64)
    nonempty = sizes > 0
    internal = (lam == 1) & nonempty
    internal_of[internal] = pin_shard[hg.edge_ptr[:-1][internal]]
    for s in range(num_shards):
        items = np.flatnonzero(item_shard == s)
        remap = np.full(V, -1, dtype=np.int64)
        remap[items] = np.arange(len(items))
        # internal edges of this shard, ascending edge id
        own = np.flatnonzero(internal_of == s)
        ptr_i, nodes_i = hg.edges_csr(own)
        frag_w = []
        frag_sizes = []
        frag_nodes = []
        if len(boundary):
            bptr, bnodes = hg.edges_csr(boundary)
            local = item_shard[bnodes] == s
            cl = np.concatenate([[0], np.cumsum(local)])
            nloc = cl[bptr[1:]] - cl[bptr[:-1]]
            keepb = np.flatnonzero(nloc >= 2)
            if len(keepb):
                sel = local.copy()
                # drop pins of boundary edges with < 2 local pins
                kmask = np.zeros(len(boundary), dtype=bool)
                kmask[keepb] = True
                sel &= np.repeat(kmask, np.diff(bptr))
                frag_nodes = [bnodes[sel]]
                frag_sizes = [nloc[keepb]]
                frag_w = [hg.edge_weights[boundary[keepb]]]
        sub_sizes = np.concatenate(
            [np.diff(ptr_i)] + ([frag_sizes[0]] if frag_sizes else [])
        ) if len(own) or frag_sizes else np.zeros(0, dtype=np.int64)
        sub_ptr = np.zeros(len(sub_sizes) + 1, dtype=np.int64)
        np.cumsum(sub_sizes, out=sub_ptr[1:])
        sub_nodes = np.concatenate(
            [nodes_i] + (frag_nodes if frag_nodes else [])
        ) if len(nodes_i) or frag_nodes else np.zeros(0, dtype=np.int64)
        sub_w = np.concatenate(
            [hg.edge_weights[own]] + (frag_w if frag_w else [])
        ) if len(own) or frag_w else np.zeros(0, dtype=np.float64)
        sub_hg = Hypergraph(
            sub_ptr, remap[sub_nodes] if len(sub_nodes) else sub_nodes,
            hg.node_weights[items].copy(), sub_w,
        )
        shards.append(ShardSpec(
            items=items, sub_hg=sub_hg, num_partitions=int(n_parts[s]),
            capacity=(
                normalize_capacity(
                    capacity[part_offset[s]:part_offset[s + 1]].copy()
                ) if het else float(capacity)
            ),
            weight=float(shard_w[s]),
        ))
    return ShardingPlan(
        item_shard=item_shard, shards=shards, part_offset=part_offset,
        boundary_edges=boundary, boundary_lambda=lam[boundary],
        boundary_cost=boundary_cost, num_components=num_components,
    )
