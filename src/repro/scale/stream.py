"""Out-of-core streaming trace ingestion (`StreamingHypergraphBuilder`).

`Hypergraph.from_edges` is the dict-era constructor: one Python iteration +
`np.unique` per query.  Fine for the paper's 4k-query figures; at the
ROADMAP's web-scale tier (a million queries) the per-query interpreter
overhead alone dominates the build.  The streaming builder ingests the trace
in CHUNKS — each chunk arrives as raw CSR arrays (or a list of sequences)
straight off a log shard, is canonicalized in one vectorized pass
(`hypergraph.canonicalize_csr`: a single lexsort sorts and dedups every
query's pins at once), and is appended into growing amortized-doubling CSR
buffers.  No per-query Python object is ever materialized, and the source
trace never has to fit in memory as Python lists.

Exactness contract
------------------
* ``merge_duplicates=False`` (default): ``build()`` is bit-identical to
  ``Hypergraph.from_edges(all_queries, num_nodes, ...)`` — same
  ``edge_ptr`` / ``edge_nodes`` dtypes and values, same weights — which
  `tests/test_scale.py` asserts and `benchmarks/bench_scale.py` gates
  (the streaming path must also be >= 5x faster at the 1M tier).
* ``merge_duplicates=True``: queries with the same canonical pin set fold
  into ONE hyperedge, ordered by first occurrence, with their weights
  summed in arrival order — bit-identical to the dict-based reference
  (``{tuple(np.unique(q)): summed weight}`` in first-seen order).
  Duplicate detection is vectorized: every canonical edge gets a 64-bit
  position-mixed hash; edges group by (hash, size) via one argsort and each
  group is verified pin-exact against its first member (a verified hash
  collision falls back to an exact byte-keyed regroup of just that group,
  so correctness never rests on hash uniqueness).
"""

from __future__ import annotations

import numpy as np

from ..core.hypergraph import Hypergraph, canonicalize_csr, csr_ranges

__all__ = ["StreamingHypergraphBuilder"]

# splitmix64 constants for the per-pin mix; the per-edge hash is then a
# position-weighted sum, so permutations of DIFFERENT multisets that share a
# sum cannot collide silently (and any residual collision is verify-caught)
_MIX_MUL = np.uint64(0x9E3779B97F4A7C15)
_MIX_A = np.uint64(0xBF58476D1CE4E5B9)
_MIX_B = np.uint64(0x94D049BB133111EB)
_POS_MUL = np.uint64(0x100000001B3)  # FNV prime, position weighting


def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer, vectorized over uint64."""
    x = (x + _MIX_MUL).astype(np.uint64)
    x ^= x >> np.uint64(30)
    x *= _MIX_A
    x ^= x >> np.uint64(27)
    x *= _MIX_B
    x ^= x >> np.uint64(31)
    return x


def _edge_hashes(edge_ptr: np.ndarray, edge_nodes: np.ndarray) -> np.ndarray:
    """64-bit hash per canonical edge: sum of mixed pins weighted by an
    in-edge position power (wrapping uint64 arithmetic)."""
    E = len(edge_ptr) - 1
    if E == 0:
        return np.zeros(0, dtype=np.uint64)
    sizes = np.diff(edge_ptr)
    h = np.zeros(E, dtype=np.uint64)
    filled = sizes > 0
    if not filled.any():
        return h
    pos = np.arange(len(edge_nodes), dtype=np.int64) - np.repeat(
        edge_ptr[:-1], sizes
    )
    mixed = _mix64(edge_nodes.astype(np.uint64)) * (
        _POS_MUL ** pos.astype(np.uint64)
    )
    csum = np.concatenate([
        np.zeros(1, dtype=np.uint64), np.cumsum(mixed, dtype=np.uint64)
    ])
    h[filled] = csum[edge_ptr[1:][filled]] - csum[edge_ptr[:-1][filled]]
    return h


class _GrowBuf:
    """Amortized-doubling 1-D append buffer."""

    def __init__(self, dtype):
        self._arr = np.zeros(1024, dtype=dtype)
        self._len = 0

    def append(self, chunk: np.ndarray) -> None:
        need = self._len + len(chunk)
        if need > len(self._arr):
            cap = max(need, 2 * len(self._arr))
            grown = np.zeros(cap, dtype=self._arr.dtype)
            grown[: self._len] = self._arr[: self._len]
            self._arr = grown
        self._arr[self._len: need] = chunk
        self._len = need

    def view(self) -> np.ndarray:
        return self._arr[: self._len]


class StreamingHypergraphBuilder:
    """Chunked CSR ingester producing a `Hypergraph`.

    Feed chunks with ``add_csr(ptr, nodes[, weights])`` (raw per-chunk CSR;
    pins need not be sorted or deduplicated) or ``add_queries(list)``
    (convenience for small chunks), then call ``build()``.  ``build()`` is
    non-destructive — more chunks may be appended afterwards and ``build()``
    called again for the longer trace.
    """

    def __init__(self, num_items: int, node_weights: np.ndarray | None = None,
                 merge_duplicates: bool = False):
        self.num_items = int(num_items)
        if node_weights is None:
            self._node_weights = np.ones(self.num_items, dtype=np.float64)
        else:
            self._node_weights = np.asarray(node_weights, dtype=np.float64)
            assert len(self._node_weights) == self.num_items
        self.merge_duplicates = bool(merge_duplicates)
        self._nodes = _GrowBuf(np.int64)     # canonical pins, edge-major
        self._sizes = _GrowBuf(np.int64)     # canonical pins per edge
        self._weights = _GrowBuf(np.float64)  # per-edge weight, arrival order
        self._hashes = _GrowBuf(np.uint64)   # per-edge canonical hash
        self.num_chunks = 0

    # ------------------------------------------------------------- ingestion
    def __len__(self) -> int:
        return self._sizes._len  # edges ingested so far (pre-merge)

    def add_csr(self, edge_ptr, edge_nodes, edge_weights=None) -> None:
        """Append one chunk of queries in CSR form (`edge_ptr` offsets into
        `edge_nodes`; duplicate pins within a query are allowed and fold
        away during canonicalization)."""
        ptr, nodes = canonicalize_csr(edge_ptr, edge_nodes)
        E = len(ptr) - 1
        if nodes.size and int(nodes.max()) >= self.num_items:
            raise ValueError(
                f"pin {int(nodes.max())} out of range for {self.num_items} items"
            )
        if nodes.size and int(nodes.min()) < 0:
            raise ValueError("negative pin id in chunk")
        if edge_weights is None:
            w = np.ones(E, dtype=np.float64)
        else:
            w = np.asarray(edge_weights, dtype=np.float64)
            if len(w) != E:
                raise ValueError("edge_weights length != chunk edge count")
        self._nodes.append(nodes)
        self._sizes.append(np.diff(ptr))
        self._weights.append(w)
        if self.merge_duplicates:
            self._hashes.append(_edge_hashes(ptr, nodes))
        self.num_chunks += 1

    def add_queries(self, queries, edge_weights=None) -> None:
        """Append one chunk given as a list of int sequences (convenience;
        the packing loop is per-query, so prefer `add_csr` for big chunks)."""
        lists = [np.asarray(q, dtype=np.int64) for q in queries]
        ptr = np.zeros(len(lists) + 1, dtype=np.int64)
        ptr[1:] = np.cumsum([len(q) for q in lists])
        nodes = (
            np.concatenate(lists) if lists else np.zeros(0, dtype=np.int64)
        )
        self.add_csr(ptr, nodes, edge_weights)

    # ----------------------------------------------------------------- build
    def _csr(self):
        sizes = self._sizes.view()
        ptr = np.zeros(len(sizes) + 1, dtype=np.int64)
        np.cumsum(sizes, out=ptr[1:])
        return ptr, self._nodes.view()

    def build(self) -> Hypergraph:
        ptr, nodes = self._csr()
        weights = self._weights.view()
        if not self.merge_duplicates:
            return Hypergraph(
                ptr.copy(), nodes.copy(), self._node_weights.copy(),
                weights.copy(),
            )
        rep = self._dedup_map(ptr, nodes)
        # first-occurrence order: output slot k = k-th distinct edge seen
        first_seen = rep == np.arange(len(rep), dtype=np.int64)
        slot_of_rep = np.cumsum(first_seen) - 1
        slot = slot_of_rep[rep]
        keep = np.flatnonzero(first_seen)
        out_ptr = np.zeros(len(keep) + 1, dtype=np.int64)
        np.cumsum(ptr[keep + 1] - ptr[keep], out=out_ptr[1:])
        _, pidx = csr_ranges(ptr, keep)
        out_nodes = nodes[pidx]
        out_w = np.zeros(len(keep), dtype=np.float64)
        # np.add.at is sequential over its index array, so weights of
        # duplicates accumulate in arrival order — the dict reference's sum
        np.add.at(out_w, slot, weights)
        return Hypergraph(out_ptr, out_nodes, self._node_weights.copy(), out_w)

    # ------------------------------------------------------------- internals
    def _dedup_map(self, ptr: np.ndarray, nodes: np.ndarray) -> np.ndarray:
        """rep[e] = lowest edge id with the same canonical pin set as e.

        Candidate groups come from one stable argsort over (hash, size);
        every group member is then verified pin-exact against the group's
        first (lowest-id) edge in a single vectorized gather-compare.
        Verified mismatches (true 64-bit collisions) re-group exactly by
        pin bytes — a cold path that keeps the map correct regardless of
        hash quality."""
        E = len(ptr) - 1
        rep = np.arange(E, dtype=np.int64)
        if E <= 1:
            return rep
        sizes = np.diff(ptr)
        h = self._hashes.view()
        order = np.lexsort((np.arange(E), sizes, h))  # stable: lowest id first
        hs, ss = h[order], sizes[order]
        new_group = np.ones(E, dtype=bool)
        new_group[1:] = (hs[1:] != hs[:-1]) | (ss[1:] != ss[:-1])
        gid = np.cumsum(new_group) - 1
        first_of_group = order[np.flatnonzero(new_group)]  # lowest edge id
        cand_rep = first_of_group[gid]                     # per sorted pos
        # verify members against their representative pin-for-pin
        member = order
        _, m_idx = csr_ranges(ptr, member)
        _, r_idx = csr_ranges(ptr, cand_rep)
        same = np.ones(E, dtype=bool)
        neq_pin = nodes[m_idx] != nodes[r_idx]
        if neq_pin.any():
            pin_member = np.repeat(np.arange(E, dtype=np.int64),
                                   sizes[member])
            bad = np.unique(pin_member[neq_pin])
            same[bad] = False
        rep[member[same]] = cand_rep[same]
        mismatched = member[~same]
        if len(mismatched):
            # true hash collision: regroup those edges exactly by bytes
            # (an edge equal to its group's first member was caught above;
            # a mismatched edge can only equal another mismatched edge of
            # the same (hash, size) group)
            seen: dict[bytes, int] = {}
            for e in sorted(int(x) for x in mismatched):
                key = nodes[ptr[e]: ptr[e + 1]].tobytes()
                if key in seen:
                    rep[e] = seen[key]
                else:
                    seen[key] = e
        return rep
