"""repro.scale — the cluster-scale placement pipeline.

The paper fits one hypergraph in one pass; this package makes million-query
traces a first-class scenario by decomposing the problem along the
workload's own structure:

  stream        — `StreamingHypergraphBuilder`: out-of-core chunked trace
                  ingestion into growing CSR buffers (vectorized
                  canonicalization, optional duplicate-edge weight
                  merging); bit-identical to `Hypergraph.from_edges`
  sharder       — `shard_workload`: connected components + HPA coarse cut
                  of oversized components into near-independent
                  sub-workloads, with explicit boundary-edge accounting
                  (`boundary_cost` = sum w_e * (lambda_e - 1))
  parallel_fit  — `fit_sharded_placement`: per-shard fits on a process
                  pool (deterministic serial fallback, bit-identical),
                  block-structured merge + capacity reconciliation, and a
                  bounded LMBR repair pass restricted to cross-shard
                  boundary edges

`PlacementService.fit_sharded` (``repro.core.placement_service``) is the
production entry point; `benchmarks/bench_scale.py` gates the pipeline.
"""

from .stream import StreamingHypergraphBuilder  # noqa: F401
from .sharder import (  # noqa: F401
    ShardSpec,
    ShardingPlan,
    connected_components,
    shard_workload,
)
from .parallel_fit import (  # noqa: F401
    ShardedFitResult,
    fit_sharded_placement,
)
