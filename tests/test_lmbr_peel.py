"""LMBR move-engine exactness: vectorized batched peel vs pure-Python oracle.

The contract under test (tentpole of PR 3): `_lmbr_gain_batch` /
`_lmbr_max_gain_vectorized` reproduce `_lmbr_max_gain_reference`
BIT-IDENTICALLY — same gain floats, same item subsets, same tie-breaks
(ascending edge id in the projection scan, lowest item id on density ties) —
on weighted instances, free pins, and zero-capacity destinations; and the
epoch-keyed gain cache never changes any result.
"""

import numpy as np
import pytest

from repro import flags
from repro.core import hpa_partition, lmbr, random_workload
from repro.core.algorithms import (
    _assign_to_placement,
    _lmbr_gain_batch,
    _lmbr_max_gain_reference,
    _lmbr_max_gain_vectorized,
    _LMBRState,
)
from repro.core.hypergraph import Hypergraph


def _random_state(rng, *, weighted_nodes=False, weighted_edges=False,
                  num_items=60, num_edges=150, num_parts=8, capacity=40.0):
    """A random placement state: random hyperedges over `num_items` items,
    every item on >= 1 random partition plus random extra replicas."""
    edges = []
    for _ in range(num_edges):
        size = int(rng.integers(2, 8))
        edges.append(rng.choice(num_items, size=size, replace=False))
    node_w = (
        rng.uniform(0.5, 4.0, size=num_items) if weighted_nodes else None
    )
    edge_w = (
        rng.uniform(0.1, 3.0, size=num_edges) if weighted_edges else None
    )
    hg = Hypergraph.from_edges(edges, num_nodes=num_items,
                               node_weights=node_w, edge_weights=edge_w)
    assign = rng.integers(0, num_parts, size=num_items)
    pl = _assign_to_placement(hg, assign, num_parts, capacity)
    # random extra replicas (creates free pins: items already on dest)
    extra = rng.random((num_parts, num_items)) < 0.08
    pl.member |= extra
    return hg, _LMBRState(hg, pl)


def _assert_pair_equal(ref, vec, ctx):
    g_ref, it_ref = ref
    g_vec, it_vec = vec
    assert g_ref == g_vec, f"{ctx}: gain {g_ref} != {g_vec}"
    if it_ref is None:
        assert it_vec is None, ctx
    else:
        np.testing.assert_array_equal(it_ref, it_vec, err_msg=str(ctx))


@pytest.mark.parametrize("weighted_nodes,weighted_edges", [
    (False, False), (True, False), (False, True), (True, True),
])
def test_peel_matches_oracle_randomized(weighted_nodes, weighted_edges):
    """Property-style: on randomized (optionally weighted) instances, every
    (src, dest) pair peels to the oracle's exact (gain, items)."""
    rng = np.random.default_rng(11 + 2 * weighted_nodes + weighted_edges)
    for trial in range(4):
        hg, state = _random_state(
            rng, weighted_nodes=weighted_nodes, weighted_edges=weighted_edges,
        )
        n = state.pl.num_partitions
        pairs = [(s, d) for s in range(n) for d in range(n) if s != d]
        batch = _lmbr_gain_batch(state, pairs)
        for key in pairs:
            ref = _lmbr_max_gain_reference(state, *key)
            _assert_pair_equal(ref, batch[key], (trial, key))


def test_peel_zero_capacity_dest():
    """A destination with no free space never receives a candidate set."""
    rng = np.random.default_rng(3)
    hg, state = _random_state(rng, capacity=40.0)
    # drown partition 0 in replicas until it exceeds capacity
    state.pl.member[0, :] = True
    state._loads[0] = state.pl.partition_weight(0)
    assert state.free_space(0) <= 0
    for src in range(1, state.pl.num_partitions):
        _assert_pair_equal(
            _lmbr_max_gain_reference(state, src, 0),
            _lmbr_max_gain_vectorized(state, src, 0),
            ("zero-cap", src),
        )
        assert _lmbr_max_gain_vectorized(state, src, 0) == (0.0, None)


def test_peel_free_pins_are_never_candidates():
    """Items already resident on dest are free (cost 0): they never appear
    in the returned candidate subset, matching the oracle."""
    rng = np.random.default_rng(5)
    hg, state = _random_state(rng)
    n = state.pl.num_partitions
    checked = 0
    for src in range(n):
        for dest in range(n):
            if src == dest:
                continue
            ref = _lmbr_max_gain_reference(state, src, dest)
            vec = _lmbr_max_gain_vectorized(state, src, dest)
            _assert_pair_equal(ref, vec, (src, dest))
            if vec[1] is not None:
                assert not state.pl.member[dest, vec[1]].any()
                checked += 1
    assert checked > 0  # the instance must exercise the non-trivial path


def test_peel_after_moves_and_recompute():
    """Equivalence holds across a sequence of apply_move + recompute_edges
    (the exact mutation pattern of the LMBR move loop)."""
    rng = np.random.default_rng(7)
    hg, state = _random_state(rng)
    n = state.pl.num_partitions
    for step in range(4):
        # apply a random legal move and refresh the touched edges
        dest = int(rng.integers(n))
        items = rng.choice(hg.num_nodes, size=2, replace=False)
        items = items[~state.pl.member[dest, items]]
        if len(items) == 0:
            continue
        state.apply_move(dest, items)
        node_ptr, node_edges = hg.incidence()
        touched = np.unique(np.concatenate(
            [node_edges[node_ptr[v]: node_ptr[v + 1]] for v in items]
        ))
        state.recompute_edges(touched)
        pairs = [(s, d) for s in range(n) for d in range(n) if s != d]
        batch = _lmbr_gain_batch(state, pairs)
        for key in pairs:
            _assert_pair_equal(
                _lmbr_max_gain_reference(state, *key), batch[key],
                (step, key),
            )


def test_gain_cache_is_exactness_neutral():
    """max_gain_many with the epoch cache returns the same results as direct
    (uncached) evaluation across a mutation sequence, and actually hits."""
    rng = np.random.default_rng(9)
    hg, state = _random_state(rng)
    n = state.pl.num_partitions
    pairs = [(s, d) for s in range(n) for d in range(n) if s != d]
    flags.reset()
    first = state.max_gain_many(pairs)
    again = state.max_gain_many(pairs)  # all epochs unchanged -> all hits
    assert state.stats["gain_cache_hits"] >= len(pairs)
    for key in pairs:
        _assert_pair_equal(first[key], again[key], key)
        _assert_pair_equal(
            _lmbr_max_gain_reference(state, *key), again[key], key
        )
    # a move must invalidate exactly through the epochs: results stay
    # correct (vs oracle) after mutation, whether served cached or fresh
    dest = 0
    items = np.flatnonzero(~state.pl.member[dest])[:2]
    state.apply_move(dest, items)
    node_ptr, node_edges = hg.incidence()
    touched = np.unique(np.concatenate(
        [node_edges[node_ptr[v]: node_ptr[v + 1]] for v in items]
    ))
    state.recompute_edges(touched)
    post = state.max_gain_many(pairs)
    for key in pairs:
        _assert_pair_equal(
            _lmbr_max_gain_reference(state, *key), post[key], key
        )


def test_full_lmbr_bit_identical_across_engines():
    """End-to-end: reference peel (cache off) and vectorized peel (cache on
    and off) produce the same placement, bit for bit."""
    wl = random_workload(num_items=120, num_queries=260, density=5, seed=2)
    hg = wl.hypergraph
    flags.set_variant("peelreference+lmbrcache0")
    try:
        ref = lmbr(hg, 9, 25, seed=0)
    finally:
        flags.reset()
    flags.set_variant("lmbrcache0")
    try:
        nocache = lmbr(hg, 9, 25, seed=0)
    finally:
        flags.reset()
    vec = lmbr(hg, 9, 25, seed=0)
    np.testing.assert_array_equal(ref.member, vec.member)
    np.testing.assert_array_equal(ref.member, nocache.member)
    assert vec.stats["moves"] == ref.stats["moves"]
    assert vec.stats["peel"] == "vector" and ref.stats["peel"] == "reference"


def test_lmbr_warm_start_unchanged():
    """The move engine preserves the warm-start (`initial`) contract."""
    wl = random_workload(num_items=80, num_queries=150, density=5, seed=6)
    hg = wl.hypergraph
    assign = hpa_partition(hg, 8, 20, seed=0, nruns=2)
    pl0 = _assign_to_placement(hg, assign, 8, 20)
    out = lmbr(hg, 8, 20, seed=0, initial=pl0)
    # warm start only adds copies: the initial layout survives
    assert (out.member[pl0.member]).all()


def test_peelauto_bit_identical_and_mixed_dispatch():
    """The size-dispatched hybrid peel (`peelauto`) routes small pairs to the
    reference and large ones to the batch — and is bit-identical to the pure
    vectorized engine either way."""
    wl = random_workload(num_items=120, num_queries=260, density=5, seed=2)
    hg = wl.hypergraph
    vec = lmbr(hg, 9, 25, seed=0)
    for spec in ("peelauto", "peelauto+peelth1", "peelauto+peelth100000"):
        flags.set_variant(spec)
        try:
            auto = lmbr(hg, 9, 25, seed=0)
        finally:
            flags.reset()
        np.testing.assert_array_equal(vec.member, auto.member)
        assert auto.stats["peel"] == "auto"
        assert auto.stats["moves"] == vec.stats["moves"]


def test_variant_validation_errors():
    """set_variant rejects unknown backends/components instead of silently
    accepting them."""
    try:
        for bad in ("peelbogus", "spanbogus", "routerbalX", "driftwx",
                    "nonsense"):
            with pytest.raises(ValueError):
                flags.set_variant(bad)
    finally:
        flags.reset()


def test_variant_roundtrip_online_knobs():
    try:
        flags.set_variant("peelauto+peelth64+routerbal1+routermb512"
                          "+driftw256+driftth1.5")
        assert flags.FLAGS["lmbr_peel"] == "auto"
        assert flags.FLAGS["lmbr_peel_threshold"] == 64
        assert flags.FLAGS["router_balance"] is True
        assert flags.FLAGS["router_microbatch"] == 512
        assert flags.FLAGS["drift_window"] == 256
        assert flags.FLAGS["drift_threshold"] == 1.5
    finally:
        flags.reset()
