"""Heterogeneous cluster model (PR 7): NodeProfile, durability-constrained
fits, energy-aware placement, cost-aware routing, reliability-aware repair.

The load-bearing contract is BIT-IDENTITY: a homogeneous `NodeProfile` must
reproduce the scalar-capacity fits byte-for-byte on every tier and engine
variant (`normalize_capacity` collapses uniform vectors to the plain float
the legacy paths compare against).  Everything heterogeneous — per-partition
capacity vectors, durability ceilings, the energy objective, node-cost
tie-breaks — is opt-in and validated here end to end."""

import numpy as np
import pytest

from repro import flags
from repro.core import (
    ALGORITHMS,
    EnergyModel,
    Hypergraph,
    NodeProfile,
    Placement,
    PlacementPlan,
    PlacementService,
    Simulator,
    capacity_vector,
    ensure_durability,
    lmbr,
    min_partitions,
    min_replicas,
    normalize_capacity,
    random_workload,
    spans_for_workload,
    validate_durability,
)
from repro.core.workloads import ispd_like_workload, lmbr_stress_workload
from repro.online import FailoverManager, ReplicaRouter
from repro.scale import fit_sharded_placement


@pytest.fixture(autouse=True)
def _fresh_flags():
    flags.reset()
    yield
    flags.reset()


# --------------------------------------------------------------- primitives
def test_normalize_capacity_collapses_uniform():
    assert normalize_capacity(50) == 50.0
    assert isinstance(normalize_capacity(50), float)
    u = normalize_capacity(np.full(8, 7.0))
    assert isinstance(u, float) and u == 7.0
    v = normalize_capacity(np.array([5.0, 6.0]))
    assert isinstance(v, np.ndarray)
    with pytest.raises(ValueError):
        normalize_capacity(np.ones((2, 2)))


def test_capacity_vector_shapes():
    assert capacity_vector(3.0, 4).tolist() == [3.0] * 4
    v = np.array([1.0, 2.0, 3.0])
    assert capacity_vector(v, 3) is v or (capacity_vector(v, 3) == v).all()
    with pytest.raises(ValueError):
        capacity_vector(v, 4)


def test_node_profile_broadcast_and_validation():
    prof = NodeProfile(
        capacity=np.array([10.0, 20.0]), fail_prob=0.1,
        power_idle=50.0, power_active=200.0, access_cost=1.0,
    )
    assert prof.num_partitions == 2
    assert prof.fail_prob.shape == (2,)
    assert not prof.is_homogeneous
    assert isinstance(prof.capacity_arg(), np.ndarray)
    hom = NodeProfile.homogeneous(5, 50.0)
    assert hom.is_homogeneous
    assert hom.capacity_arg() == 50.0
    assert isinstance(hom.capacity_arg(), float)
    # uniform profile -> constant routing cost (degenerate tie-break)
    rc = hom.routing_cost()
    assert np.allclose(rc, rc[0])
    sub = prof.subset([1])
    assert sub.capacity.tolist() == [20.0]
    with pytest.raises(ValueError):
        NodeProfile.homogeneous(3, -1.0)
    with pytest.raises(ValueError):
        NodeProfile.homogeneous(3, 10.0, fail_prob=1.5)


def test_min_replicas():
    assert min_replicas([0.5, 0.01, 0.1], 1e-3) == 2  # 0.01 * 0.1 = 1e-3
    assert min_replicas([0.1], 0.2) == 1
    assert min_replicas([0.5, 0.5], 1e-3) == 3  # unsatisfiable -> len + 1


def test_ensure_and_validate_durability():
    # item 0 on the flakiest partition; eps forces two extra copies
    member = np.zeros((3, 2), dtype=bool)
    member[2, 0] = True
    member[0, 1] = True
    pl = Placement(member, 10.0, np.ones(2))
    prof = NodeProfile.homogeneous(3, 10.0, fail_prob=0.1)
    prof = NodeProfile(
        capacity=prof.capacity, fail_prob=np.array([0.01, 0.1, 0.5]),
        power_idle=prof.power_idle, power_active=prof.power_active,
        access_cost=prof.access_cost,
    )
    with pytest.raises(ValueError):
        validate_durability(pl, prof, 0.05)
    touched = ensure_durability(pl, prof, 0.05)
    validate_durability(pl, prof, 0.05)
    assert touched.tolist() == [0]  # item 1 (p_loss 0.01) already meets it
    # greedy adds the most reliable partition first: 0.5 * 0.01 <= 0.05
    assert member[:, 0].tolist() == [True, False, True]
    with pytest.raises(ValueError):
        ensure_durability(pl, prof, 0.0)


def test_ensure_durability_respects_capacity():
    member = np.zeros((2, 2), dtype=bool)
    member[0] = True  # both items on partition 0, which is now full
    pl = Placement(member, 2.0, np.ones(2))
    tight = NodeProfile(
        capacity=np.array([2.0, 0.5]), fail_prob=0.2,
        power_idle=1.0, power_active=2.0, access_cost=1.0,
    )
    with pytest.raises(ValueError, match="durability"):
        ensure_durability(pl, tight, 1e-3)  # item cannot fit anywhere


def test_min_partitions_vector():
    hg = Hypergraph.from_edges([[0, 1, 2]], num_nodes=3,
                               node_weights=np.array([4.0, 4.0, 4.0]))
    # descending-capacity prefix: 10 + 5 >= 12
    assert min_partitions(hg, np.array([5.0, 10.0, 1.0])) == 2
    assert min_partitions(hg, 5.0) == 3


# ---------------------------------------------- homogeneous identity (fits)
_TIERS = {
    "fig6": lambda: (random_workload(seed=0).hypergraph, 40, 50),
    "fig9-small": lambda: (ispd_like_workload(num_nodes=2000, seed=0)
                           .hypergraph, 32, 80),
    "lmbr-stress-small": lambda: (lmbr_stress_workload(
        num_items=1200, num_queries=5000, seed=0).hypergraph, 48, 50),
}
_VARIANTS = {
    "default": {},
    "numpy-round": {"span_round_backend": "numpy"},
    "reference-peel": {"lmbr_peel": "reference"},
}


@pytest.fixture(scope="module")
def tier_cache():
    return {}


@pytest.mark.parametrize("variant", sorted(_VARIANTS))
@pytest.mark.parametrize("tier", sorted(_TIERS))
def test_homogeneous_profile_bit_identical(tier, variant, tier_cache):
    if tier not in tier_cache:
        tier_cache[tier] = _TIERS[tier]()
    hg, n, cap = tier_cache[tier]
    prof = NodeProfile.homogeneous(n, cap)
    flags.FLAGS.update(_VARIANTS[variant])
    scalar = lmbr(hg, n, cap, seed=0, max_moves=200)
    viaprof = lmbr(hg, n, prof.capacity_arg(), seed=0, max_moves=200,
                   node_cost=prof.access_cost)
    assert (scalar.member == viaprof.member).all(), (tier, variant)


def test_service_fit_profile_bit_identical():
    wl = random_workload(num_items=400, num_queries=1200, seed=1)
    queries = wl.queries
    svc = PlacementService(seed=0)
    scalar = svc.fit(queries, 400, 20, 50)
    viaprof = svc.fit(queries, 400, 20,
                      profile=NodeProfile.homogeneous(20, 50))
    assert (scalar.member == viaprof.member).all()
    assert viaprof.capacity == 50.0 and isinstance(viaprof.capacity, float)
    with pytest.raises(ValueError, match="partitions"):
        svc.fit(queries, 400, 21, profile=NodeProfile.homogeneous(20, 50))
    with pytest.raises(ValueError, match="disagree"):
        svc.fit(queries, 400, 20, capacity=60,
                profile=NodeProfile.homogeneous(20, 50))


# -------------------------------------------------- heterogeneous capacity
def test_all_algorithms_respect_capacity_vector():
    wl = random_workload(num_items=300, num_queries=900, seed=2)
    hg = wl.hypergraph
    caps = np.concatenate([np.full(8, 60.0), np.full(8, 20.0)])
    for name, fn in ALGORITHMS.items():
        pl = fn(hg, 16, caps, seed=0)
        pl.validate()
        assert (pl.partition_weights() <= caps + 1e-9).all(), name


def test_placement_vector_capacity_helpers():
    member = np.zeros((3, 2), dtype=bool)
    member[0, 0] = True
    caps = np.array([4.0, 8.0, 2.0])
    pl = Placement(member, caps, np.array([3.0, 1.0]))
    assert pl.cap_of(1) == 8.0
    assert pl.free_space(0) == 1.0
    assert pl.capacity_vec.tolist() == caps.tolist()
    member[1, 0] = member[1, 1] = True  # load 4 > 2? no: row1 load 4 <= 8
    pl.validate()
    member[2] = True  # row 2 load 4 > cap 2
    with pytest.raises(ValueError, match="partition 2"):
        pl.validate()


def test_sharded_fit_heterogeneous_capacity():
    wl = random_workload(num_items=600, num_queries=2000, seed=3)
    hg = wl.hypergraph
    caps = np.concatenate([np.full(6, 120.0), np.full(6, 40.0)])
    res = fit_sharded_placement(hg, 12, caps, num_shards=3, workers=1,
                                seed=0, max_moves=80, boundary_repair=32)
    res.placement.validate()
    assert (res.placement.partition_weights() <= caps + 1e-9).all()
    # every shard received its contiguous slice of the global vector
    off = res.sharding.part_offset
    for s, spec in enumerate(res.sharding.shards):
        want = normalize_capacity(caps[off[s]:off[s + 1]])
        if isinstance(want, np.ndarray):
            assert np.array_equal(np.asarray(spec.capacity), want)
        else:
            assert spec.capacity == want


def test_sharded_fit_scalar_unchanged_by_refactor():
    wl = random_workload(num_items=600, num_queries=2000, seed=3)
    hg = wl.hypergraph
    a = fit_sharded_placement(hg, 12, 80.0, num_shards=3, workers=1,
                              seed=0, max_moves=80, boundary_repair=32)
    b = fit_sharded_placement(hg, 12, np.full(12, 80.0), num_shards=3,
                              workers=1, seed=0, max_moves=80,
                              boundary_repair=32)
    assert (a.member == b.member).all()


# ------------------------------------------------------- durability in fits
def test_service_fit_durability_constrained():
    wl = random_workload(num_items=500, num_queries=1500, seed=4)
    queries = wl.queries
    prof = NodeProfile.homogeneous(48, 50, fail_prob=0.05)
    svc = PlacementService(seed=0)
    plan = svc.fit(queries, 500, 48, profile=prof, durability_eps=1e-3)
    # 0.05^2 = 2.5e-3 > 1e-3 >= 0.05^3: every placed item needs 3 copies
    validate_durability(plan.as_placement(), prof, 1e-3)
    placed = plan.member.any(axis=0)
    assert (plan.member.sum(axis=0)[placed] >= 3).all()
    assert plan.stats["durability_copies"] > 0
    plan.as_placement().validate()


def test_durability_flag_variant():
    flags.set_variant("durab1e-3")
    assert flags.FLAGS["durability_eps"] == 1e-3
    flags.reset()
    with pytest.raises(ValueError):
        flags.set_variant("durab-0.5")


# --------------------------------------------------------- energy objective
def test_energy_objective_concentrates_replicas():
    wl = random_workload(num_items=600, num_queries=2000, seed=5)
    hg = wl.hypergraph
    n, cap = 24, 210
    span_fit = lmbr(hg, n, cap, seed=0, max_moves=150)
    span_active = int((span_fit.partition_weights() > 0).sum())
    flags.set_variant("energy")
    energy_fit = lmbr(hg, n, cap, seed=0, max_moves=150)
    flags.reset()
    energy_fit.validate()
    energy_active = int((energy_fit.partition_weights() > 0).sum())
    assert energy_active < span_active
    # concentration must not shred co-location: spans stay in the same league
    s_span = float(spans_for_workload(hg, span_fit).mean())
    s_energy = float(spans_for_workload(hg, energy_fit).mean())
    assert s_energy <= 1.25 * s_span


def test_energy_objective_ignored_on_warm_start():
    wl = random_workload(num_items=300, num_queries=900, seed=6)
    hg = wl.hypergraph
    cold = lmbr(hg, 12, 80, seed=0, max_moves=60)
    flags.set_variant("energy")
    warm = lmbr(hg, 12, 80, seed=0, max_moves=0, initial=cold)
    flags.reset()
    assert (warm.member == cold.member).all()


def test_energy_objective_warm_refit_byte_identical():
    """Regression pin on the cold-start-only contract: a service `refit`
    (warm-started LMBR with a real move budget) must be byte-identical
    with and without placement_objective="energy" — the objective shapes
    cold fits only, never online adaptation."""
    wl = random_workload(num_items=300, num_queries=900, seed=6)
    svc = PlacementService("lmbr", seed=0)
    plan = svc.fit(wl.queries, 300, 12, 80)
    drifted = wl.queries[:300]
    span_refit = svc.refit(plan, drifted, max_moves=64)
    flags.set_variant("energy")
    try:
        energy_refit = svc.refit(plan, drifted, max_moves=64)
    finally:
        flags.reset()
    assert (span_refit.member == energy_refit.member).all()
    # the paced-migration surface inherits the same contract: identical
    # refits diff into identical transfer schedules
    mig_span = svc.plan_migration(plan, span_refit)
    flags.set_variant("energy")
    try:
        mig_energy = svc.plan_migration(plan, energy_refit)
    finally:
        flags.reset()
    assert mig_span.to_json() == mig_energy.to_json()


def test_node_cost_weight_zero_bit_identical():
    wl = random_workload(num_items=300, num_queries=900, seed=7)
    hg = wl.hypergraph
    cost = np.linspace(1.0, 3.0, 12)
    base = lmbr(hg, 12, 80, seed=0, max_moves=100)
    withcost = lmbr(hg, 12, 80, seed=0, max_moves=100, node_cost=cost)
    assert (base.member == withcost.member).all()  # weight defaults to 0
    flags.set_variant("nodecost0.5")
    assert flags.FLAGS["node_cost_weight"] == 0.5
    penalized = lmbr(hg, 12, 80, seed=0, max_moves=100, node_cost=cost)
    flags.reset()
    penalized.validate()  # behavior-changing mode still fits validly


# -------------------------------------------------------- cost-aware router
def _replicated_member(n=4, v=6):
    return np.ones((n, v), dtype=bool)


def test_router_cost_aware_uniform_bit_identical():
    rng = np.random.default_rng(0)
    queries = [rng.choice(6, size=2, replace=False) for _ in range(200)]
    flags.set_variant("routerbal1")
    plain = ReplicaRouter(_replicated_member())
    got_plain = plain.route(queries)
    flags.set_variant("routerbal1+routercost1")
    uniform = ReplicaRouter(_replicated_member(),
                            node_cost=np.full(4, 2.5))
    got_uniform = uniform.route(queries)
    flags.reset()
    assert (got_plain.cover_parts == got_uniform.cover_parts).all()
    assert (plain.load == uniform.load).all()


def test_router_cost_aware_prefers_cheap_partitions():
    rng = np.random.default_rng(1)
    queries = [rng.choice(6, size=2, replace=False) for _ in range(400)]
    flags.set_variant("routerbal1+routercost1+routermb16")
    router = ReplicaRouter(_replicated_member(),
                           node_cost=np.array([1.0, 1.0, 10.0, 10.0]))
    router.route(queries)
    flags.reset()
    cheap, dear = router.load[:2].sum(), router.load[2:].sum()
    assert cheap > 2 * dear
    with pytest.raises(ValueError):
        router.set_node_cost(np.array([1.0, -1.0, 1.0, 1.0]))
    with pytest.raises(ValueError):
        router.set_node_cost(np.ones(3))


def test_router_cost_flag_off_ignores_cost():
    rng = np.random.default_rng(2)
    queries = [rng.choice(6, size=2, replace=False) for _ in range(200)]
    flags.set_variant("routerbal1")
    a = ReplicaRouter(_replicated_member())
    b = ReplicaRouter(_replicated_member(),
                      node_cost=np.array([1.0, 1.0, 10.0, 10.0]))
    ra, rb = a.route(queries), b.route(queries)
    flags.reset()
    assert (ra.cover_parts == rb.cover_parts).all()


# -------------------------------------------------- reliability-aware repair
def test_failover_repair_prefers_reliable_survivor():
    member = np.zeros((3, 1), dtype=bool)
    member[0, 0] = True
    hg = Hypergraph.from_edges([[0]], num_nodes=1)
    prof = NodeProfile(
        capacity=np.full(3, 10.0), fail_prob=np.array([0.1, 0.2, 0.05]),
        power_idle=1.0, power_active=2.0, access_cost=1.0,
    )
    fo = FailoverManager(Placement(member, 10.0, np.ones(1)), profile=prof)
    lost = fo.partition_down(0)
    assert lost.tolist() == [0]
    fo.repair(hg, k=1)
    assert member[2, 0] and not member[1, 0]  # lowest fail_prob survivor


def test_failover_uniform_profile_bit_identical():
    wl = random_workload(num_items=200, num_queries=600, seed=8)
    hg = wl.hypergraph
    base = lmbr(hg, 10, 40, seed=0, max_moves=60)
    prof = NodeProfile.homogeneous(10, 40, fail_prob=0.02)

    m1 = base.member.copy()
    fo1 = FailoverManager(Placement(m1, 40.0, hg.node_weights))
    fo1.partition_down(3)
    r1 = fo1.repair(hg, k=1)

    m2 = base.member.copy()
    fo2 = FailoverManager(Placement(m2, 40.0, hg.node_weights),
                          profile=prof)
    fo2.partition_down(3)
    r2 = fo2.repair(hg, k=1)

    assert (m1 == m2).all() and (r1 == r2).all()


# --------------------------------------------------- simulator energy model
def test_cluster_power_per_node():
    em = EnergyModel()
    loads = np.array([5.0, 0.0, 1.0])
    # defaults: 2 active * 250 + 1 idle * 100
    assert em.cluster_power(loads) == 600.0
    prof = NodeProfile(
        capacity=np.full(3, 10.0), fail_prob=0.01,
        power_idle=np.array([10.0, 20.0, 30.0]),
        power_active=np.array([100.0, 200.0, 300.0]), access_cost=1.0,
    )
    assert em.cluster_power(loads, prof) == 100.0 + 20.0 + 300.0


def test_simulator_profile_preserves_energy_numbers():
    wl = random_workload(num_items=300, num_queries=900, seed=9)
    hg = wl.hypergraph
    scalar = Simulator(12, 80).run(hg, ALGORITHMS["lmbr"], seed=0,
                                   max_moves=60)
    viaprof = Simulator(12, profile=NodeProfile.homogeneous(12, 80)).run(
        hg, ALGORITHMS["lmbr"], seed=0, max_moves=60,
    )
    assert scalar.energy_joules == viaprof.energy_joules
    assert scalar.avg_span == viaprof.avg_span
    assert viaprof.active_machines == int((viaprof.loads > 0).sum())
    # homogeneous defaults: active * 250 + idle * 100
    act = viaprof.active_machines
    assert viaprof.cluster_power_w == act * 250.0 + (12 - act) * 100.0
    assert "active_machines" in viaprof.summary()
    with pytest.raises(ValueError):
        Simulator(12)


# ------------------------------------------------- plan JSON round-trip (S1)
def test_plan_json_roundtrip_heterogeneous_vector():
    member = np.zeros((4, 6), dtype=bool)
    member[0, [0, 1]] = True
    member[2, [2, 3, 4, 5]] = True  # partitions 1 and 3 stay EMPTY
    caps = np.array([5.0, 7.0, 11.0, 9.0])
    plan = PlacementPlan(member, normalize_capacity(caps),
                         np.ones(6), "manual")
    back = PlacementPlan.from_json(plan.to_json())
    assert (back.member == member).all()
    assert isinstance(back.capacity, np.ndarray)
    assert np.array_equal(back.capacity, caps)
    assert not back.member[1].any() and not back.member[3].any()


def test_plan_json_roundtrip_uniform_collapses_to_scalar():
    member = np.zeros((3, 2), dtype=bool)
    member[0, 0] = member[1, 1] = True
    plan = PlacementPlan(member, np.full(3, 4.0), np.ones(2), "manual")
    back = PlacementPlan.from_json(plan.to_json())
    assert isinstance(back.capacity, float) and back.capacity == 4.0
    scalar = PlacementPlan(member, 4.0, np.ones(2), "manual")
    back2 = PlacementPlan.from_json(scalar.to_json())
    assert isinstance(back2.capacity, float) and back2.capacity == 4.0
    assert back2.to_json() == scalar.to_json()


def test_plan_json_roundtrip_empty_placement():
    member = np.zeros((2, 3), dtype=bool)
    plan = PlacementPlan(member, np.array([1.0, 2.0]), np.zeros(3), "manual")
    back = PlacementPlan.from_json(plan.to_json())
    assert back.member.shape == (2, 3) and not back.member.any()
    assert np.array_equal(back.capacity, np.array([1.0, 2.0]))
