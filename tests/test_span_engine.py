"""Equivalence tests: the batched bitset span engine and the incremental
SpanMaintainer must agree BIT-FOR-BIT with the per-edge reference greedy
cover (same spans, same chosen partitions in the same order, same replica
attribution, same unplaced-item error)."""

import numpy as np
import pytest

from _pbt import given, settings, st

from repro import flags
from repro.core.hypergraph import Hypergraph
from repro.core.setcover import (
    Placement,
    SpanMaintainer,
    batched_cover_csr,
    batched_spans_csr,
    cover_for_query,
    greedy_set_cover,
)


def random_instance(rng, *, weighted=False, phantoms=False, cover_all=True):
    """A random membership matrix + workload, optionally with weighted and
    phantom (weight-0) items."""
    num_items = int(rng.integers(3, 120))
    n_parts = int(rng.integers(1, 9))
    member = rng.random((n_parts, num_items)) < rng.uniform(0.1, 0.7)
    if cover_all:
        member[rng.integers(0, n_parts), :] |= ~member.any(axis=0)
    weights = (
        rng.uniform(0.5, 5.0, num_items) if weighted
        else np.ones(num_items)
    )
    if phantoms:
        weights[rng.random(num_items) < 0.2] = 0.0
    edges = [
        rng.choice(num_items, size=int(rng.integers(1, min(num_items, 90) + 1)),
                   replace=False)
        for _ in range(int(rng.integers(1, 40)))
    ]
    hg = Hypergraph.from_edges(edges, num_nodes=num_items)
    return hg, member, weights


def assert_batched_matches_reference(hg, member):
    cov = batched_cover_csr(hg.edge_ptr, hg.edge_nodes, member,
                            with_pin_parts=True)
    for e in range(hg.num_edges):
        q = hg.edge(e)
        chosen, accessed = cover_for_query(q, member)
        assert list(cov.chosen(e)) == chosen
        assert cov.spans[e] == len(greedy_set_cover(q, member))
        pp = cov.pin_parts[hg.edge_ptr[e]: hg.edge_ptr[e + 1]]
        for p, items in zip(chosen, accessed):
            np.testing.assert_array_equal(q[pp == p], items)


@given(st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_batched_cover_equals_reference(seed):
    rng = np.random.default_rng(seed)
    hg, member, _ = random_instance(rng)
    assert_batched_matches_reference(hg, member)


@given(st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_batched_cover_weighted_and_phantom_items(seed):
    """Item weights (incl. phantom weight-0 items) never change covers —
    only capacity accounting — but the instances exercise the same paths the
    placement algorithms hit."""
    rng = np.random.default_rng(seed)
    hg, member, weights = random_instance(rng, weighted=True, phantoms=True)
    pl = Placement(member, capacity=1e9, node_weights=weights)
    ref = np.asarray([
        len(greedy_set_cover(hg.edge(e), pl.member))
        for e in range(hg.num_edges)
    ])
    np.testing.assert_array_equal(
        batched_spans_csr(hg.edge_ptr, hg.edge_nodes, pl.member), ref
    )


def test_multiword_queries():
    """Queries above 64 pins use multi-word bitsets."""
    V = 400
    member = np.zeros((5, V), dtype=bool)
    member[0] = True
    member[1, ::2] = True
    member[2, ::7] = True
    edges = [range(0, 200), range(37, 391), [3], range(V)]
    hg = Hypergraph.from_edges(edges, num_nodes=V)
    assert_batched_matches_reference(hg, member)


def test_unplaced_item_raises_like_reference():
    member = np.zeros((2, 4), dtype=bool)
    member[0, [0, 1]] = True
    hg = Hypergraph.from_edges([[0, 1], [1, 2, 3]], num_nodes=4)
    with pytest.raises(ValueError):
        greedy_set_cover(hg.edge(1), member)
    with pytest.raises(ValueError):
        batched_spans_csr(hg.edge_ptr, hg.edge_nodes, member)


def test_empty_and_trivial_queries():
    member = np.ones((3, 2), dtype=bool)
    ptr = np.array([0, 0, 1, 2])  # one empty query
    nodes = np.array([0, 1])
    cov = batched_cover_csr(ptr, nodes, member, with_pin_parts=True)
    np.testing.assert_array_equal(cov.spans, [0, 1, 1])
    assert list(cov.chosen(0)) == []
    assert list(cov.chosen(1)) == [0]  # tie -> lowest partition id


@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_span_maintainer_tracks_mutations(seed):
    """Incremental spans after notify_items == full batched recompute ==
    per-edge reference, across a random mutation sequence."""
    rng = np.random.default_rng(seed)
    hg, member, _ = random_instance(rng)
    pl = Placement(member.copy(), capacity=1e9,
                   node_weights=np.ones(hg.num_nodes))
    sm = SpanMaintainer(hg, pl)
    for _ in range(8):
        items = rng.choice(hg.num_nodes,
                           size=int(rng.integers(1, 6)), replace=False)
        pl.member[int(rng.integers(0, pl.num_partitions)), items] = True
        sm.notify_items(items)
        want = batched_spans_csr(hg.edge_ptr, hg.edge_nodes, pl.member)
        np.testing.assert_array_equal(sm.spans(), want)
        np.testing.assert_array_equal(
            sm.residual_edges(1), np.flatnonzero(want > 1)
        )


def test_jax_backend_matches_numpy():
    jax = pytest.importorskip("jax")  # noqa: F841
    rng = np.random.default_rng(7)
    hg, member, _ = random_instance(rng)
    ref = batched_spans_csr(hg.edge_ptr, hg.edge_nodes, member)
    flags.FLAGS["span_backend"] = "jax"
    try:
        got = batched_spans_csr(hg.edge_ptr, hg.edge_nodes, member)
    finally:
        flags.reset()
    np.testing.assert_array_equal(got, ref)


def test_span_backend_variant_flag():
    flags.set_variant("spanjax")
    assert flags.FLAGS["span_backend"] == "jax"
    flags.set_variant("spanpallas+spanth12345")
    assert flags.FLAGS["span_backend"] == "pallas"
    assert flags.FLAGS["span_dispatch_threshold"] == 12345
    flags.reset()
    # auto = per-bucket dispatch (numpy below the threshold, accelerated
    # above); every backend is bit-identical so the default is purely perf
    assert flags.FLAGS["span_backend"] == "auto"
    assert flags.FLAGS["span_dispatch_threshold"] == 48_000


def test_auto_dispatch_threshold_boundaries():
    """auto mode is exact at both extremes of the threshold: everything on
    numpy (huge threshold) and everything accelerated (threshold 0)."""
    rng = np.random.default_rng(3)
    hg, member, _ = random_instance(rng)
    flags.FLAGS["span_backend"] = "numpy"
    try:
        ref = batched_spans_csr(hg.edge_ptr, hg.edge_nodes, member)
    finally:
        flags.reset()
    for thresh in (0, 1 << 60):
        flags.FLAGS.update(span_backend="auto",
                           span_dispatch_threshold=thresh)
        try:
            got = batched_spans_csr(hg.edge_ptr, hg.edge_nodes, member)
        finally:
            flags.reset()
        np.testing.assert_array_equal(got, ref)


def test_maintainer_cover_mode_matches_reference():
    """SpanMaintainer(with_covers=True): covers after refresh_edges equal
    per-edge cover_for_query across membership mutations."""
    rng = np.random.default_rng(17)
    hg, member, _ = random_instance(rng)
    pl = Placement(member.copy(), capacity=1e9,
                   node_weights=np.ones(hg.num_nodes))
    sm = SpanMaintainer(hg, pl, with_covers=True)
    for _ in range(5):
        items = rng.choice(hg.num_nodes, size=int(rng.integers(1, 5)),
                           replace=False)
        pl.member[int(rng.integers(0, pl.num_partitions)), items] = True
        sm.refresh_edges(np.arange(hg.num_edges))
        for e in range(hg.num_edges):
            chosen, accessed = cover_for_query(hg.edge(e), pl.member)
            cov = sm.cover(e)
            assert list(cov) == chosen  # same partitions, selection order
            for p, its in zip(chosen, accessed):
                np.testing.assert_array_equal(cov[p], its)
        np.testing.assert_array_equal(
            sm.spans(),
            batched_spans_csr(hg.edge_ptr, hg.edge_nodes, pl.member),
        )
