"""Minimal, dependency-free stand-in for the `hypothesis` API surface these
tests use, so the property tests stay runnable in offline containers.

Not a shrinking property-based tester: `given` simply reruns the test body
`max_examples` times with a deterministically seeded numpy Generator per
example, drawing values from the tiny strategy combinators below.  If real
hypothesis is installed the test modules import it instead of this stub.
"""

from __future__ import annotations

import numpy as np


class _Strategy:
    def __init__(self, draw_fn):
        self._draw = draw_fn

    def draw(self, rng: np.random.Generator):
        return self._draw(rng)


class _DataObject:
    def __init__(self, rng: np.random.Generator):
        self._rng = rng

    def draw(self, strategy: _Strategy):
        return strategy.draw(self._rng)


class _DataStrategy(_Strategy):
    def __init__(self):
        super().__init__(lambda rng: _DataObject(rng))


class strategies:
    """Namespace mirroring `hypothesis.strategies` (the used subset)."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1))
        )

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value))
        )

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10,
              unique: bool = False) -> _Strategy:
        def draw(rng: np.random.Generator):
            size = int(rng.integers(min_size, max_size + 1))
            out: list = []
            seen: set = set()
            attempts = 0
            while len(out) < size and attempts < 1000:
                attempts += 1
                x = elements.draw(rng)
                if unique:
                    if x in seen:
                        continue
                    seen.add(x)
                out.append(x)
            return out

        return _Strategy(draw)

    @staticmethod
    def data() -> _Strategy:
        return _DataStrategy()


def settings(max_examples: int = 20, deadline=None):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(*strategies_args: _Strategy):
    def deco(fn):
        n = getattr(fn, "_stub_max_examples", 20)

        def wrapper():
            for ex in range(n):
                rng = np.random.default_rng(0xC0FFEE + ex)
                fn(*[s.draw(rng) for s in strategies_args])

        # NB: deliberately no functools.wraps — pytest must see a zero-arg
        # signature, not the example parameters of the wrapped function
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco
