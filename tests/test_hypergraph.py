"""Unit + property tests for the hypergraph substrate."""

import numpy as np
import pytest

from _pbt import given, settings, st

from repro.core.hypergraph import Hypergraph, build_incidence


def small_hg():
    return Hypergraph.from_edges(
        [[0, 1, 2], [2, 3], [3, 4, 5], [0, 5], [1, 2, 3]], num_nodes=6
    )


def test_basic_shapes():
    hg = small_hg()
    assert hg.num_nodes == 6
    assert hg.num_edges == 5
    assert hg.num_pins == 13
    assert hg.avg_items_per_query() == pytest.approx(13 / 5)
    np.testing.assert_array_equal(hg.edge(0), [0, 1, 2])
    np.testing.assert_array_equal(hg.edge_sizes(), [3, 2, 3, 2, 3])


def test_from_edges_dedupes_pins():
    hg = Hypergraph.from_edges([[1, 1, 2]])
    np.testing.assert_array_equal(hg.edge(0), [1, 2])


def test_incidence_inverse():
    hg = small_hg()
    node_ptr, node_edges = hg.incidence()
    # node 2 appears in edges 0, 1, 4
    np.testing.assert_array_equal(sorted(hg.node_edges_of(2)), [0, 1, 4])
    np.testing.assert_array_equal(sorted(hg.node_edges_of(4)), [2])


def test_degrees_weighted():
    hg = Hypergraph.from_edges(
        [[0, 1], [1, 2]], edge_weights=np.array([2.0, 3.0])
    )
    np.testing.assert_allclose(hg.degrees(), [2.0, 5.0, 3.0])


def test_subhypergraph_edges_preserves_node_ids():
    hg = small_hg()
    sub = hg.subhypergraph_edges(np.array([1, 3]))
    assert sub.num_edges == 2
    np.testing.assert_array_equal(sub.edge(0), [2, 3])
    np.testing.assert_array_equal(sub.edge(1), [0, 5])
    assert sub.num_nodes == 6  # labels preserved


def test_relabel_compacts():
    hg = small_hg().subhypergraph_edges(np.array([1]))
    g, old_ids = hg.relabel()
    assert g.num_nodes == 2
    np.testing.assert_array_equal(old_ids, [2, 3])
    np.testing.assert_array_equal(old_ids[g.edge(0)], [2, 3])


def test_peel_densest():
    # clique on 0-3 (dense) plus pendant edges to 4,5,6
    edges = [[0, 1], [0, 2], [0, 3], [1, 2], [1, 3], [2, 3], [3, 4], [4, 5], [5, 6]]
    hg = Hypergraph.from_edges(edges, num_nodes=7)
    dense = set(hg.k_densest_nodes(4))
    assert dense == {0, 1, 2, 3}


def test_prune_to_size_keeps_contained_edges():
    edges = [[0, 1], [0, 2], [1, 2], [2, 3], [3, 4]]
    hg = Hypergraph.from_edges(edges, num_nodes=5)
    pruned = hg.prune_to_size(3)
    survivors = set(pruned.active_nodes())
    # every surviving edge is fully inside the surviving node set
    for e in range(pruned.num_edges):
        assert set(int(v) for v in pruned.edge(e)) <= survivors


def test_mutable_roundtrip():
    hg = small_hg()
    m = hg.copy_mutable()
    new = m.add_node_copy(2)
    assert new == 6
    assert m.node_weights[new] == hg.node_weights[2]
    assert m.replace_in_edge(0, 2, new)
    frozen = m.freeze()
    assert frozen.num_nodes == 7
    np.testing.assert_array_equal(frozen.edge(0), [0, 1, 6])


# --------------------------------------------------------------- properties
edge_strategy = st.lists(
    st.lists(st.integers(0, 19), min_size=1, max_size=6),
    min_size=1, max_size=30,
)


@given(edge_strategy)
@settings(max_examples=50, deadline=None)
def test_incidence_is_inverse_property(edges):
    hg = Hypergraph.from_edges(edges, num_nodes=20)
    node_ptr, node_edges = build_incidence(hg.edge_ptr, hg.edge_nodes, 20)
    # pin count conserved
    assert node_ptr[-1] == hg.num_pins
    for v in range(20):
        for e in node_edges[node_ptr[v]:node_ptr[v + 1]]:
            assert v in set(hg.edge(int(e)))


@given(edge_strategy, st.floats(1.0, 15.0))
@settings(max_examples=50, deadline=None)
def test_peel_respects_weight_budget(edges, budget):
    hg = Hypergraph.from_edges(edges, num_nodes=20)
    nodes = hg.k_densest_nodes(budget)
    assert hg.node_weights[nodes].sum() <= budget + 1e-9
