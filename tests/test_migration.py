"""Live plan migration invariants (repro.online.migration): schedule-diff
oracle equality, union-layout routability at every tick, copy-before-drop
ordering per item, capacity/headroom safety by construction, mid-migration
failover, and bit-identity of the final layout with the target plan."""

import numpy as np
import pytest

from _pbt import given, settings, st
from repro import flags
from repro.core import (
    ALGORITHMS,
    PlacementService,
    Simulator,
    random_workload,
)
from repro.core.placement_service import PlacementPlan
from repro.core.setcover import Placement
from repro.online import (
    MigrationExecutor,
    MigrationPlan,
    diff_plans,
    diff_plans_reference,
    plan_migration,
)


@pytest.fixture(scope="module")
def plans():
    """A workload and two genuinely different layouts for it (hpa vs lmbr):
    the diff has both copies and drops."""
    wl = random_workload(num_items=150, num_queries=400, density=4, seed=7)
    hg = wl.hypergraph
    pa = ALGORITHMS["hpa"](hg, 10, 32, seed=0)
    pb = ALGORITHMS["lmbr"](hg, 10, 32, seed=0, max_moves=400)
    pa.validate()
    pb.validate()
    d = diff_plans(pa.member, pb.member)
    assert d.num_copies > 0 and d.num_drops > 0, "fixture diff degenerate"
    return hg, pa, pb


def _fresh_old(plans):
    _, pa, _ = plans
    return Placement(pa.member.copy(), pa.capacity, pa.node_weights)


def _target_loads(pl):
    return np.array([pl.node_weights[row].sum() for row in pl.member])


# ------------------------------------------------------------- diff oracle
def test_diff_matches_reference_on_fits(plans):
    _, pa, pb = plans
    d = diff_plans(pa.member, pb.member)
    r = diff_plans_reference(pa.member, pb.member)
    assert np.array_equal(d.copy_dest, r.copy_dest)
    assert np.array_equal(d.copy_item, r.copy_item)
    assert np.array_equal(d.drop_part, r.drop_part)
    assert np.array_equal(d.drop_item, r.drop_item)


def test_diff_matches_reference_on_random_matrices():
    rng = np.random.default_rng(0)
    for _ in range(20):
        n = int(rng.integers(1, 7))
        v = int(rng.integers(1, 30))
        a = rng.random((n, v)) < 0.4
        b = rng.random((n, v)) < 0.4
        d, r = diff_plans(a, b), diff_plans_reference(a, b)
        assert np.array_equal(d.copy_dest, r.copy_dest)
        assert np.array_equal(d.copy_item, r.copy_item)
        assert np.array_equal(d.drop_part, r.drop_part)
        assert np.array_equal(d.drop_item, r.drop_item)


def test_diff_shape_mismatch_raises():
    with pytest.raises(ValueError, match="shapes differ"):
        diff_plans(np.zeros((2, 3), dtype=bool), np.zeros((2, 4), dtype=bool))
    with pytest.raises(TypeError):
        diff_plans(np.zeros((2, 3)), np.zeros((2, 3)))  # not bool


# --------------------------------------------------------------- plan/json
def test_plan_migration_validates_target_coverage(plans):
    _, pa, _ = plans
    empty = np.zeros_like(pa.member)
    with pytest.raises(ValueError, match="uncovered"):
        plan_migration(pa.member, empty, node_weights=pa.node_weights)


def test_plan_migration_validates_pacing(plans):
    _, pa, pb = plans
    with pytest.raises(ValueError, match="bandwidth"):
        plan_migration(pa.member, pb.member, bandwidth=-1.0)
    with pytest.raises(ValueError, match="concurrency"):
        plan_migration(pa.member, pb.member, concurrency=0)
    with pytest.raises(ValueError, match="headroom"):
        plan_migration(pa.member, pb.member, headroom=-0.1)


def test_migration_plan_json_roundtrip(plans):
    _, pa, pb = plans
    mp = plan_migration(pa.member, pb.member, node_weights=pa.node_weights,
                        bandwidth=7.5, concurrency=3, headroom=0.2)
    back = MigrationPlan.from_json(mp.to_json())
    assert back.num_partitions == mp.num_partitions
    assert back.num_items == mp.num_items
    for f in ("copy_dest", "copy_item", "copy_src", "drop_part",
              "drop_item"):
        assert np.array_equal(getattr(back, f), getattr(mp, f)), f
    assert back.bandwidth == mp.bandwidth
    assert back.concurrency == mp.concurrency
    assert back.headroom == mp.headroom


# ---------------------------------------------------------------- schedule
def _paced_plan(plans, **kw):
    _, pa, pb = plans
    kw.setdefault("bandwidth", 8.0)
    kw.setdefault("concurrency", 3)
    kw.setdefault("headroom", 0.15)
    return plan_migration(pa.member, pb.member,
                          node_weights=pa.node_weights, **kw)


def test_schedule_deterministic(plans):
    mp = _paced_plan(plans)
    e1 = mp.schedule(_fresh_old(plans))
    e2 = mp.schedule(_fresh_old(plans))
    assert e1 == e2
    assert len(e1) == mp.num_copies + mp.num_drops


def test_schedule_copy_before_drop_per_item(plans):
    """Every copy event of an item precedes every drop event of that item
    — both in event order and in tick order."""
    mp = _paced_plan(plans)
    events = mp.schedule(_fresh_old(plans))
    last_copy_pos: dict[int, int] = {}
    last_copy_tick: dict[int, int] = {}
    for i, ev in enumerate(events):
        if ev.kind == "copy":
            last_copy_pos[ev.item] = i
            last_copy_tick[ev.item] = ev.tick
    for i, ev in enumerate(events):
        if ev.kind != "drop":
            continue
        if ev.item in last_copy_pos:  # pure-drop items have no copies
            assert i > last_copy_pos[ev.item]
            assert ev.tick >= last_copy_tick[ev.item]


def test_schedule_union_layout_every_event(plans):
    """Replaying the schedule, the live layout stays inside the union:
    old&new <= member <= old|new at every event, and no item that had
    coverage ever loses it (routability is preserved mid-migration)."""
    _, pa, pb = plans
    mp = _paced_plan(plans)
    events = mp.schedule(_fresh_old(plans))
    member = pa.member.copy()
    both = pa.member & pb.member
    union = pa.member | pb.member
    covered0 = member.any(axis=0)
    for ev in events:
        if ev.kind == "copy":
            assert not member[ev.partition, ev.item]
            member[ev.partition, ev.item] = True
        else:
            assert member[ev.partition, ev.item]
            member[ev.partition, ev.item] = False
        assert (member >= both).all(), "member lost an old&new replica"
        assert (member <= union).all(), "member left the old|new union"
        assert (member.any(axis=0) >= covered0).all(), "coverage lost"
    assert np.array_equal(member, pb.member), "final layout != target"


def test_executor_final_bit_identity_and_headroom(plans):
    """Stepping the executor tick by tick: reserved+committed loads never
    exceed capacity*(1+headroom), the in-flight volume stays inside the
    declared bound, the per-destination concurrency cap holds, and the
    final live matrix is bit-identical with the target plan."""
    _, pa, pb = plans
    mp = _paced_plan(plans)
    live = _fresh_old(plans)
    ex = MigrationExecutor(mp, live)
    cap_bound = live.capacity_vec * (1.0 + mp.headroom) + 1e-9
    infl_bound = mp.inflight_bound(pa.node_weights) + 1e-9
    guard = 0
    while not ex.done:
        ex.advance(1)
        guard += 1
        assert guard < 100_000
        assert (ex.loads() <= cap_bound).all(), "headroom bound violated"
        assert ex.inflight_bytes <= infl_bound, "in-flight bound violated"
        per_dest = np.bincount([t.dest for t in ex._active],
                               minlength=mp.num_partitions)
        assert per_dest.max(initial=0) <= mp.concurrency
        # real replica loads can never exceed the reserved-load ledger view
        assert (live.partition_weights() <= cap_bound).all()
    assert np.array_equal(live.member, pb.member)
    assert ex.stats["copies_done"] == mp.num_copies
    assert ex.stats["drops_done"] == mp.num_drops
    assert ex.stats["migration_transferred"] == pytest.approx(
        mp.bytes_to_move(pa.node_weights)
    )


def test_executor_requires_bandwidth(plans):
    mp = _paced_plan(plans, bandwidth=0.0)
    with pytest.raises(ValueError, match="bandwidth"):
        MigrationExecutor(mp, _fresh_old(plans))


def test_instant_apply_roundtrip(plans):
    _, pa, pb = plans
    mp = _paced_plan(plans)
    out = mp.apply(pa.member.copy())
    assert np.array_equal(out, pb.member)


def test_stalled_migration_raises():
    """Two full partitions swapping their single items with zero headroom
    can never start a transfer: the executor must refuse loudly instead of
    spinning or violating the capacity bound."""
    old = np.array([[True, False], [False, True]])
    new = np.array([[False, True], [True, False]])
    w = np.ones(2)
    mp = plan_migration(old, new, node_weights=w, bandwidth=5.0,
                        concurrency=2, headroom=0.0)
    ex = MigrationExecutor(mp, Placement(old.copy(), 1.0, w))
    with pytest.raises(RuntimeError, match="stalled"):
        ex.advance(10)
    # with headroom for one extra item the same swap completes
    live = Placement(old.copy(), 1.0, w)
    ex2 = MigrationExecutor(
        plan_migration(old, new, node_weights=w, bandwidth=5.0,
                       concurrency=2, headroom=1.0),
        live,
    )
    ex2.advance(10)
    assert ex2.done and np.array_equal(live.member, new)


def test_stalled_no_source_raises():
    """A pending copy of an item that NO live partition holds (the plan
    only validates coverage of the target layout) must stall with a
    diagnostic naming the missing source, not blame headroom."""
    old = np.array([[True, False]])
    new = np.array([[True, True]])
    mp = plan_migration(old, new, bandwidth=5.0, headroom=0.0)
    ex = MigrationExecutor(mp, Placement(old.copy(), 5.0, np.ones(2)))
    with pytest.raises(RuntimeError, match="no live source"):
        ex.advance(5)


def test_advance_stops_counting_after_done(plans):
    """`now` freezes at the completing tick: ticks past the end of the
    migration must not inflate the reported duration."""
    mp = _paced_plan(plans)
    ex = MigrationExecutor(mp, _fresh_old(plans))
    guard = 0
    while not ex.done:
        ex.advance(1)
        guard += 1
        assert guard < 100_000
    end = ex.now
    ex.advance(100)
    assert ex.now == end


def test_executor_seeded_down_completes_after_restore(plans):
    """The migration STARTS while a copy destination is already down.
    Seeded at construction, the executor never sets a member bit on the
    masked row, cannot finish while the destination is dark, and after the
    row restore lands bit-identical with the target."""
    _, pa, pb = plans
    d = diff_plans(pa.member, pb.member)
    dead = int(d.copy_dest[0])
    live = _fresh_old(plans)
    saved = live.member[dead].copy()
    live.member[dead] = False  # failover.partition_down already ran
    # the plan diffs against the post-restore layout (saved row included)
    mp = plan_migration(pa.member, pb.member, node_weights=pa.node_weights,
                        bandwidth=4.0, concurrency=3, headroom=0.25)
    ex = MigrationExecutor(mp, live, down=[dead])
    ex.advance(200)
    assert not live.member[dead].any(), "wrote a member bit on a dead row"
    assert not ex.done, "cannot finish while a copy destination is down"
    live.member[dead] = saved  # failover.partition_up restores the row
    ex.on_partition_up(dead)
    guard = 0
    while not ex.done:
        ex.advance(16)
        guard += 1
        assert guard < 10_000
    assert np.array_equal(live.member, pb.member)
    assert ex.stats["copies_done"] == mp.num_copies
    assert ex.stats["drops_done"] == mp.num_drops


def test_mid_migration_destination_failure(plans):
    """Kill a transfer destination mid-flight: its in-flight transfers
    abort (bytes wasted), landed copies are counted un-landed while masked,
    their drops are held, and after the partition returns the migration
    completes to the exact target."""
    _, pa, pb = plans
    mp = _paced_plan(plans, bandwidth=4.0, headroom=0.25)
    live = _fresh_old(plans)
    ex = MigrationExecutor(mp, live)
    dead = int(mp.copy_dest[0])
    ex.advance(8)  # let transfers to `dead` get in flight / land
    saved = live.member[dead].copy()
    live.member[dead] = False  # what failover.partition_down does
    ex.on_partition_down(dead)
    ex.advance(30)  # progress elsewhere while the destination is dark
    assert not ex.done, "cannot finish while a copy destination is down"
    live.member[dead] = saved | live.member[dead]  # row restore
    ex.on_partition_up(dead)
    guard = 0
    while not ex.done:
        ex.advance(16)
        guard += 1
        assert guard < 10_000
    assert np.array_equal(live.member, pb.member)
    assert ex.stats["copies_done"] == mp.num_copies
    assert ex.stats["drops_done"] == mp.num_drops
    assert ex.stats["aborted_transfers"] >= 1
    assert ex.stats["migration_wasted"] >= 0.0


# ------------------------------------------------------- property (shim'd)
@settings(max_examples=25, deadline=None)
@given(st.data())
def test_prop_diff_apply_roundtrip(data):
    """apply(diff(a, b), a) == b for arbitrary same-shape layouts, and the
    vectorized diff always agrees with the brute-force oracle."""
    n = data.draw(st.integers(min_value=1, max_value=5))
    v = data.draw(st.integers(min_value=1, max_value=20))
    bits_a = data.draw(st.lists(st.integers(min_value=0, max_value=1),
                                min_size=n * v, max_size=n * v))
    bits_b = data.draw(st.lists(st.integers(min_value=0, max_value=1),
                                min_size=n * v, max_size=n * v))
    a = np.array(bits_a, dtype=bool).reshape(n, v)
    b = np.array(bits_b, dtype=bool).reshape(n, v)
    mp = plan_migration(a, b, bandwidth=1.0)
    assert np.array_equal(mp.apply(a.copy()), b)
    d, r = diff_plans(a, b), diff_plans_reference(a, b)
    assert np.array_equal(d.copy_dest, r.copy_dest)
    assert np.array_equal(d.copy_item, r.copy_item)
    assert np.array_equal(d.drop_part, r.drop_part)
    assert np.array_equal(d.drop_item, r.drop_item)


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_prop_migration_plan_json_roundtrip(data):
    n = data.draw(st.integers(min_value=1, max_value=5))
    v = data.draw(st.integers(min_value=1, max_value=20))
    bits_a = data.draw(st.lists(st.integers(min_value=0, max_value=1),
                                min_size=n * v, max_size=n * v))
    bits_b = data.draw(st.lists(st.integers(min_value=0, max_value=1),
                                min_size=n * v, max_size=n * v))
    a = np.array(bits_a, dtype=bool).reshape(n, v)
    b = np.array(bits_b, dtype=bool).reshape(n, v)
    mp = plan_migration(
        a, b,
        bandwidth=data.draw(st.floats(min_value=0.0, max_value=50.0)),
        concurrency=data.draw(st.integers(min_value=1, max_value=8)),
        headroom=data.draw(st.floats(min_value=0.0, max_value=1.0)),
    )
    back = MigrationPlan.from_json(mp.to_json())
    assert back.to_json() == mp.to_json()
    assert np.array_equal(back.copy_dest, mp.copy_dest)
    assert np.array_equal(back.drop_item, mp.drop_item)
    assert back.bandwidth == mp.bandwidth
    assert back.concurrency == mp.concurrency
    assert back.headroom == mp.headroom


# ------------------------------------------------------------------- flags
def test_migration_flag_variants():
    flags.set_variant("migbw2.5+migconc8+mighead0.25")
    try:
        assert flags.FLAGS["migration_bandwidth"] == 2.5
        assert flags.FLAGS["migration_concurrency"] == 8
        assert flags.FLAGS["migration_headroom"] == 0.25
    finally:
        flags.reset()
    for bad in ("migbw-1", "migconc0", "mighead-0.5"):
        with pytest.raises(ValueError):
            flags.set_variant(bad)
        flags.reset()


# -------------------------------------------------------------- run_online
def _old_algo(plans):
    _, pa, _ = plans

    def fit_old(hg, n, cap, **kw):
        return Placement(pa.member.copy(), pa.capacity, pa.node_weights)

    return fit_old


def test_run_online_migrate_event_instant_default(plans):
    """migration_bandwidth 0 (the default): a migrate event is the legacy
    atomic hot-swap between microbatches — zero ticks, final loads equal
    the target's, every query served."""
    hg, pa, pb = plans
    sim = Simulator(10, 32)
    tgt = PlacementPlan(pb.member.copy(), 32.0, pb.node_weights, "lmbr")
    res = sim.run_online(hg, _old_algo(plans),
                         events=[(120, "migrate", tgt)])
    s = res.online_stats
    assert s["migrations"] == 1 and s["migration_done"]
    assert s["migration_ticks"] == 0
    assert s["plan_swaps"] == 1
    assert s["degraded_queries"] == 0
    assert s["migration_copies"] + s["migration_drops"] > 0
    assert np.array_equal(res.loads, _target_loads(pb))


def test_run_online_migrate_event_paced(plans):
    """Paced migration serves every query from the union layout while the
    transfers stream, and still lands bit-identical with the target."""
    hg, pa, pb = plans
    sim = Simulator(10, 32)
    tgt = PlacementPlan(pb.member.copy(), 32.0, pb.node_weights, "lmbr")
    flags.set_variant("migbw6.0+mighead0.15")
    try:
        res = sim.run_online(hg, _old_algo(plans),
                             events=[(120, "migrate", tgt)])
    finally:
        flags.reset()
    s = res.online_stats
    assert s["migrations"] == 1 and s["migration_done"]
    assert s["migration_ticks"] > 0
    assert s["degraded_queries"] == 0
    assert s["served_queries"] == hg.num_edges
    assert np.array_equal(res.loads, _target_loads(pb))
    mp = plan_migration(pa.member, pb.member, bandwidth=6.0)
    assert s["migration_transfer_gb"] <= mp.bytes_to_move(
        pa.node_weights
    ) * sim.item_gb + 1e-9
    assert s["migration_max_inflight_gb"] <= mp.inflight_bound(
        pa.node_weights
    ) * sim.item_gb + 1e-9


def test_run_online_migrate_while_inflight_raises(plans):
    hg, _, pb = plans
    sim = Simulator(10, 32)
    tgt = PlacementPlan(pb.member.copy(), 32.0, pb.node_weights, "lmbr")
    flags.set_variant("migbw0.5+mighead0.15")  # too slow to finish early
    try:
        with pytest.raises(ValueError, match="already in flight"):
            sim.run_online(hg, _old_algo(plans), events=[
                (10, "migrate", tgt), (20, "migrate", tgt),
            ])
    finally:
        flags.reset()


def test_run_online_instant_migrate_during_outage_raises(plans):
    """An atomic swap that writes a down partition would be resurrected by
    the row restore; the simulator refuses it and demands pacing."""
    hg, pa, pb = plans
    mp = plan_migration(pa.member, pb.member, node_weights=pa.node_weights)
    dead = int(mp.copy_dest[0])
    sim = Simulator(10, 32)
    tgt = PlacementPlan(pb.member.copy(), 32.0, pb.node_weights, "lmbr")
    with pytest.raises(ValueError, match="down partition"):
        sim.run_online(hg, _old_algo(plans), events=[
            (10, "down", dead), (50, "migrate", tgt),
        ])


def test_run_online_down_then_paced_migrate_then_up(plans):
    """A paced migration issued DURING an outage: the diff is taken against
    the post-restore layout, copies/drops on the dead partition defer until
    its row returns, and the run lands exactly on the target (auto_repair
    off, so no extra replicas blur the bit-identity check)."""
    hg, pa, pb = plans
    mp = plan_migration(pa.member, pb.member, node_weights=pa.node_weights)
    dead = int(mp.copy_dest[0])
    sim = Simulator(10, 32)
    tgt = PlacementPlan(pb.member.copy(), 32.0, pb.node_weights, "lmbr")
    flags.set_variant("migbw6.0+mighead0.25")
    try:
        res = sim.run_online(
            hg, _old_algo(plans), auto_repair=False,
            events=[(10, "down", dead), (50, "migrate", tgt),
                    (220, "up", dead)],
        )
    finally:
        flags.reset()
    s = res.online_stats
    assert s["migrations"] == 1 and s["migration_done"]
    assert s["migration_copies"] == mp.num_copies
    assert s["migration_drops"] == mp.num_drops
    assert s["served_queries"] + s["degraded_queries"] == hg.num_edges
    assert np.array_equal(res.loads, _target_loads(pb))


def test_run_online_migration_through_failover(plans):
    """The ISSUE scenario end to end: start a paced migration, kill a
    transfer destination mid-flight (auto-repair re-replicates what it
    held), bring it back — the migration completes, the ledger balances,
    and loads stay within the declared headroom."""
    hg, pa, pb = plans
    mp = plan_migration(pa.member, pb.member, node_weights=pa.node_weights)
    dead = int(mp.copy_dest[0])
    sim = Simulator(10, 32)
    tgt = PlacementPlan(pb.member.copy(), 32.0, pb.node_weights, "lmbr")
    flags.set_variant("migbw2.0+mighead0.25")
    try:
        res = sim.run_online(hg, _old_algo(plans), events=[
            (60, "migrate", tgt), (100, "down", dead), (250, "up", dead),
        ])
    finally:
        flags.reset()
    s = res.online_stats
    assert s["migrations"] == 1 and s["migration_done"]
    assert s["migration_copies"] == mp.num_copies
    assert s["migration_drops"] == mp.num_drops
    assert s["served_queries"] + s["degraded_queries"] == hg.num_edges
    assert s["partitions_down"] == 1
    # final loads: the exact target plus at most the repair copies the
    # outage added, all inside the declared headroom
    assert (res.loads <= 32.0 * 1.25 + 1e-9).all()
    assert (res.loads >= _target_loads(pb) - 1e-9).all()


def test_run_online_migration_under_fault_storm(plans, fault_injected_run):
    """Randomized (legal) down/up storms around a fast paced migration:
    the serving ledger must balance and the run must never crash or
    violate the headroom bound."""
    hg, _, pb = plans
    sim = Simulator(10, 32)
    tgt = PlacementPlan(pb.member.copy(), 32.0, pb.node_weights, "lmbr")
    flags.set_variant("migbw50.0+mighead0.35")
    try:
        res, events = fault_injected_run(
            sim, hg, _old_algo(plans), fault_seed=5, num_events=6,
            extra_events=[(5, "migrate", tgt)],
        )
    finally:
        flags.reset()
    s = res.online_stats
    assert s["migrations"] == 1
    assert (res.loads <= 32.0 * 1.35 + 1e-9).all()


# ------------------------------------------------- service / drift / scale
def test_refit_as_migration(plans):
    """A warm-started refit only adds replicas: as_migration returns a
    pure-copy MigrationPlan whose instant apply reproduces the refit
    layout, with .target carrying the new plan."""
    wl = random_workload(num_items=120, num_queries=500, density=5, seed=3)
    svc = PlacementService("lmbr", seed=0)
    plan = svc.fit(wl.queries, 120, 10, 40)
    mp = svc.refit(plan, wl.queries[:200], max_moves=64, as_migration=True)
    assert isinstance(mp, MigrationPlan)
    assert mp.num_drops == 0, "warm-start refit must never drop replicas"
    assert mp.target is not None
    assert mp.target.algorithm.endswith("+refit")
    out = mp.apply(plan.member.copy())
    assert np.array_equal(out, mp.target.member)


def test_run_online_paced_drift_hot_swap():
    """With migration_bandwidth set, a drift-triggered refit streams in as
    a paced migration instead of swapping atomically; every completed
    migration still counts one plan swap, so refits == plan_swaps holds
    once the last migration has drained."""
    from repro.core import Hypergraph

    old = random_workload(num_items=120, num_queries=600, density=6, seed=2)
    new = random_workload(num_items=120, num_queries=600, density=6, seed=9)
    trace = Hypergraph.from_edges(
        [old.hypergraph.edge(e) for e in range(200)]
        + [new.hypergraph.edge(e) for e in range(600)],
        num_nodes=120,
    )
    flags.set_variant("driftw128+driftth1.1+routermb64+migbw40.0"
                      "+mighead0.2")
    try:
        sim = Simulator(10, 40)  # slack capacity: the refit can add copies
        res = sim.run_online(
            old.hypergraph, ALGORITHMS["hpa"], name="hpa+drift",
            trace=trace, service=PlacementService("lmbr", seed=0),
            refit_moves=128, seed=0,
        )
    finally:
        flags.reset()
    s = res.online_stats
    assert s["drift_fires"] >= 1 and s["refits"] >= 1
    assert s["migrations"] == s["refits"]
    assert s["migration_done"]
    assert s["plan_swaps"] == s["refits"]
    assert (res.loads <= 40.0 * 1.2 + 1e-9).all()


def test_migrate_to_sharded_target(scale_workers):
    """Migrating onto a fit_sharded target works under both the serial and
    the process-pool sharded paths (make test-migration runs both), and the
    final loads match the target exactly."""
    wl = random_workload(num_items=200, num_queries=600, density=5, seed=11)
    hg = wl.hypergraph
    n, cap = 8, 60
    svc = PlacementService("lmbr", seed=0)
    tgt = svc.fit_sharded(hg, n, cap, num_shards=4, workers=scale_workers,
                          max_moves=60)
    old = ALGORITHMS["hpa"](hg, n, cap, seed=0)

    def fit_old(h, n_, c_, **kw):
        return Placement(old.member.copy(), old.capacity, old.node_weights)

    sim = Simulator(n, cap)
    flags.set_variant("migbw10.0+mighead0.2")
    try:
        res = sim.run_online(hg, fit_old, events=[(64, "migrate", tgt)])
    finally:
        flags.reset()
    s = res.online_stats
    assert s["migrations"] == 1 and s["migration_done"]
    assert s["degraded_queries"] == 0
    assert np.array_equal(
        res.loads,
        np.array([tgt.node_weights[row].sum() for row in tgt.member]),
    )
