"""End-to-end integration: the training driver must run through the full
substrate (placement pipeline -> fault-tolerant runner -> checkpointing) with
an injected host failure and REDUCE the loss."""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_train_driver_loss_improves(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train",
         "--arch", "olmo-1b", "--reduced", "--steps", "60",
         "--batch", "8", "--seq", "64", "--lr", "3e-3",
         "--ckpt-every", "25", "--inject-failures",
         "--ckpt-dir", str(tmp_path / "ckpt")],
        env=env, capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr[-2000:]
    assert "improved" in proc.stdout
    assert "event@0: input_host_dead:0" in proc.stdout
    # checkpoints exist
    assert any(d.startswith("step_") for d in os.listdir(tmp_path / "ckpt"))


@pytest.mark.slow
def test_serve_driver(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve",
         "--arch", "qwen3-moe-30b-a3b", "--reduced",
         "--requests", "4", "--prefill-len", "32", "--decode-len", "8",
         "--batch", "4"],
        env=env, capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr[-2000:]
    assert "served 4 requests" in proc.stdout
    assert "expert placement refit" in proc.stdout
