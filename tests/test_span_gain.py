"""Backend-equivalence tests for the span_gain kernel package.

The gain matrix is integer popcount math, so every backend — numpy oracle,
jitted jnp, Pallas kernel in interpret mode — must agree EXACTLY, including
over the padding seams (query-batch pow2 pad, partition-axis 128 pad,
uint64 -> uint32 lane split)."""

import numpy as np
import pytest

from repro import flags
from repro.kernels.span_gain.ops import span_gains
from repro.kernels.span_gain.ref import span_gain_ref

jax = pytest.importorskip("jax")

# (A, N, W): odd batch sizes straddle the pow2 pad, N > 128 straddles the
# lane pad, W > 1 exercises the multi-word reduce
SHAPES = [(1, 1, 1), (3, 5, 2), (17, 35, 2), (40, 7, 6), (64, 130, 1),
          (9, 129, 3)]


@pytest.mark.parametrize("shape", SHAPES, ids=lambda s: "x".join(map(str, s)))
@pytest.mark.parametrize("force", ["numpy", "jax", "interpret", "pallas"])
def test_backends_match_oracle(shape, force):
    A, N, W = shape
    rng = np.random.default_rng(A * 1000 + N * 10 + W)
    codes = rng.integers(0, 2**63, size=(A, N, W), dtype=np.uint64)
    rem = rng.integers(0, 2**63, size=(A, W), dtype=np.uint64)
    # exercise the full uint64 range incl. the sign bit of the int64 view
    codes[0, 0, 0] = np.uint64(0xFFFFFFFFFFFFFFFF)
    rem[0, 0] = np.uint64(0xFFFFFFFFFFFFFFFF)
    want = span_gain_ref(codes, rem)
    got = span_gains(codes, rem, force=force)
    assert got.dtype == np.int64
    np.testing.assert_array_equal(got, want)


def test_kernel_interpret_matches_jnp_ref():
    """The Pallas kernel itself (not just the dispatcher) against the jnp
    reference it shares lanes with, at an already-padded shape."""
    from repro.kernels.span_gain.kernel import span_gain
    from repro.kernels.span_gain.ref import span_gain_jnp

    rng = np.random.default_rng(7)
    A, W2, N = 16, 4, 256
    c32 = rng.integers(0, 2**32, size=(A, N, W2), dtype=np.uint64).astype(
        np.uint32
    )
    r32 = rng.integers(0, 2**32, size=(A, W2), dtype=np.uint64).astype(
        np.uint32
    )
    want = np.asarray(span_gain_jnp(c32, r32))
    got = np.asarray(
        span_gain(
            np.ascontiguousarray(c32.transpose(0, 2, 1)), r32, interpret=True
        )
    )
    np.testing.assert_array_equal(got, want)


def test_zero_rem_zero_gain():
    codes = np.full((4, 3, 2), 0xFFFFFFFFFFFFFFFF, dtype=np.uint64)
    rem = np.zeros((4, 2), dtype=np.uint64)
    for force in ("numpy", "jax", "interpret"):
        assert (span_gains(codes, rem, force=force) == 0).all()


def test_engine_per_bucket_dispatch_is_exact():
    """batched_cover_csr under forced thresholds: all-numpy, all-accelerated
    (threshold 0) and pinned-pallas must produce identical covers."""
    from repro.core.hypergraph import Hypergraph
    from repro.core.setcover import batched_cover_csr

    rng = np.random.default_rng(11)
    num_items, n_parts = 90, 6
    member = rng.random((n_parts, num_items)) < 0.35
    member[0] |= ~member.any(axis=0)
    edges = [
        rng.choice(num_items, size=int(rng.integers(1, 80)), replace=False)
        for _ in range(25)
    ]
    hg = Hypergraph.from_edges(edges, num_nodes=num_items)

    def run():
        cov = batched_cover_csr(hg.edge_ptr, hg.edge_nodes, member,
                                with_pin_parts=True)
        return cov.spans, cov.cover_ptr, cov.cover_parts, cov.pin_parts

    flags.FLAGS["span_backend"] = "numpy"
    try:
        want = run()
    finally:
        flags.reset()
    for setup in (
        dict(span_backend="auto", span_dispatch_threshold=0),
        dict(span_backend="jax"),
        dict(span_backend="pallas"),
    ):
        flags.FLAGS.update(setup)
        try:
            got = run()
        finally:
            flags.reset()
        for w, g in zip(want, got):
            np.testing.assert_array_equal(g, w, err_msg=str(setup))


def test_whole_round_device_loop_is_exact():
    """The jitted whole-round cover loop (``span_round_backend="device"``)
    must reproduce the per-round host loop bit-exactly — same covers, same
    pin_parts, same spans — and the auto threshold must route big buckets
    to it (counter check) without changing results."""
    from repro.core.hypergraph import Hypergraph
    from repro.core.setcover import ENGINE_COUNTERS, batched_cover_csr

    rng = np.random.default_rng(23)
    num_items, n_parts = 140, 9
    member = rng.random((n_parts, num_items)) < 0.3
    member[0] |= ~member.any(axis=0)
    edges = [
        rng.choice(num_items, size=int(rng.integers(2, 90)), replace=False)
        for _ in range(40)
    ]
    hg = Hypergraph.from_edges(edges, num_nodes=num_items)

    def run():
        cov = batched_cover_csr(hg.edge_ptr, hg.edge_nodes, member,
                                with_pin_parts=True)
        return cov.spans, cov.cover_ptr, cov.cover_parts, cov.pin_parts

    flags.FLAGS["span_round_backend"] = "numpy"
    try:
        want = run()
    finally:
        flags.reset()
    for setup in (
        dict(span_round_backend="device"),
        dict(span_round_backend="auto", span_round_threshold=0),
    ):
        flags.FLAGS.update(setup)
        before = ENGINE_COUNTERS["device_buckets"]
        try:
            got = run()
        finally:
            flags.reset()
        assert ENGINE_COUNTERS["device_buckets"] > before, setup
        for w, g in zip(want, got):
            np.testing.assert_array_equal(g, w, err_msg=str(setup))
