"""Health monitoring layer: time-series store, SLO engine, trace analytics.

Covers the PR 10 tentpole — `repro.obs.timeseries` windowed aggregations,
`repro.obs.health` alert state machine / anomaly detection / run_online
integration, `repro.obs.analyze` span-tree analytics and the
tools/obs_report.py CLI — plus the router load-gauge rebind regression.
The observation-changes-nothing contract (monitored serving bit-identical
to off) is asserted here AND gated by benchmarks/bench_obs.py's health
section.
"""

import subprocess
import sys
import os

import numpy as np
import pytest

from repro import flags, obs
from repro.core import ALGORITHMS, Simulator, random_workload
from repro.obs import (
    HealthMonitor,
    SLORule,
    SeriesRing,
    TimeSeriesStore,
    aggregate_spans,
    build_span_tree,
    critical_path,
    load_events,
    render_report,
    top_slowest,
)
from repro.online import ReplicaRouter

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True)
def _clean_obs():
    flags.reset()
    obs.reset()
    yield
    flags.reset()
    obs.reset()


# ---------------------------------------------------------- TimeSeriesStore
def test_series_ring_wraparound_chronological():
    r = SeriesRing(4)
    for i in range(6):
        r.append(float(i), float(i * 10))
    assert len(r) == 4
    assert r.values().tolist() == [20.0, 30.0, 40.0, 50.0]
    assert r.times().tolist() == [2.0, 3.0, 4.0, 5.0]
    assert r.values(2).tolist() == [40.0, 50.0]
    assert r.last() == 50.0


def test_series_ring_rejects_tiny_capacity():
    with pytest.raises(ValueError, match="capacity"):
        SeriesRing(1)


def test_store_delta_rate_need_two_samples():
    st = TimeSeriesStore()
    st.record("c", 0.0, 5.0)
    assert st.delta("c", 4) is None
    assert st.rate("c", 4) is None
    assert st.delta("missing", 4) is None
    st.record("c", 2.0, 25.0)
    assert st.delta("c", 4) == 20.0
    assert st.rate("c", 4) == 10.0  # per unit of the ingest time axis
    assert st.last("c") == 25.0


def test_store_windowed_aggregations():
    st = TimeSeriesStore(capacity=8)
    for t, v in enumerate([1.0, 3.0, 2.0, 6.0]):
        st.record("g", float(t), v)
    assert st.mean("g") == 3.0
    assert st.vmin("g") == 1.0
    assert st.vmax("g") == 6.0
    assert st.mean("g", 2) == 4.0
    # ewma: newest weighted alpha, seeded at the oldest sample
    assert st.ewma("g", alpha=0.5) == pytest.approx(
        0.5 * 6.0 + 0.5 * (0.5 * 2.0 + 0.5 * (0.5 * 3.0 + 0.5 * 1.0)))


def test_store_ingest_and_vector_delta():
    st = TimeSeriesStore()
    st.ingest({'load{index="0"}': 10.0, 'load{index="2"}': 5.0}, t=0.0)
    st.ingest({'load{index="0"}': 40.0, 'load{index="2"}': 6.0}, t=1.0)
    d = st.vector_delta("load", 4)
    # index 1 never reported: zero-filled; ordering is by index
    assert d.tolist() == [30.0, 0.0, 1.0]
    assert st.vector_delta("absent", 4).tolist() == []


def test_histogram_quantile_from_registry_snapshots():
    flags.FLAGS["obs_level"] = "counters"
    reg = obs.registry()
    reg.histogram("lat_seconds", buckets=(0.1, 0.25, 0.5, 1.0))
    for v in (0.05, 0.05, 0.15):
        reg.observe("lat_seconds", v)
    st = TimeSeriesStore()
    st.ingest(reg.snapshot(), t=0.0)
    for v in (0.3, 0.3, 0.3, 0.3):
        reg.observe("lat_seconds", v)
    st.ingest(reg.snapshot(), t=1.0)
    # whole-run: 7 observations, p50 interpolates inside the 0.25-0.5
    # bucket: 0.25 + 0.25 * (3.5 - 3) / 4
    q_all = st.histogram_quantile("lat_seconds", 0.5)
    assert q_all == pytest.approx(0.28125)
    # windowed delta: only the four 0.3s -> p50 at the bucket midpoint
    q_win = st.histogram_quantile("lat_seconds", 0.5, n=2)
    assert q_win == pytest.approx(0.375)
    assert st.histogram_quantile("lat_seconds", 0.0, n=2) >= 0.0
    with pytest.raises(ValueError, match="quantile"):
        st.histogram_quantile("lat_seconds", 1.5)


def test_histogram_quantile_inf_bucket_reports_highest_finite_bound():
    flags.FLAGS["obs_level"] = "counters"
    reg = obs.registry()
    reg.histogram("big_seconds", buckets=(0.1, 1.0)).observe(50.0)
    st = TimeSeriesStore()
    st.ingest(reg.snapshot(), t=0.0)
    assert st.histogram_quantile("big_seconds", 0.99) == 1.0
    assert st.histogram_quantile("empty_seconds", 0.5) is None


# ------------------------------------------------------- alert state machine
def _const_rule(name, values, **kw):
    """Rule whose value function replays `values` per evaluate() call."""
    it = iter(values)
    return SLORule(name, lambda store: next(it), ">", 5.0, **kw)


def test_alert_fires_first_breach_resolves_after_hysteresis():
    m = HealthMonitor([_const_rule("r", [1, 9, 9, 1, 9, 1, 1, 1],
                                   resolve_after=2)])
    for t in range(8):
        m.evaluate(float(t))
    kinds = [(h["kind"], h["t"]) for h in m.history]
    # fires at t=1; the lone clear at t=3 is cancelled by the breach at
    # t=4; two consecutive clears (t=5,6) resolve
    assert kinds == [("fire", 1.0), ("resolve", 6.0)]
    assert m.stats["alerts_fired"] == 1 and m.stats["alerts_resolved"] == 1
    assert m.alerts["r"].fires == 1 and m.alerts["r"].resolves == 1


def test_alert_fire_after_requires_consecutive_breaches():
    m = HealthMonitor([_const_rule("r", [9, 1, 9, 9, 9], fire_after=3)])
    for t in range(5):
        m.evaluate(float(t))
    assert [h["t"] for h in m.history if h["kind"] == "fire"] == [4.0]


def test_none_rule_values_freeze_the_state_machine():
    m = HealthMonitor([_const_rule("r", [9, None, None, 1, 1],
                                   resolve_after=2)])
    for t in range(5):
        m.evaluate(float(t))
    # fire at t=0; Nones neither clear nor re-breach; resolve needs the
    # two real clears at t=3,4
    assert [(h["kind"], h["t"]) for h in m.history] == [
        ("fire", 0.0), ("resolve", 4.0)]


def test_monitor_rejects_duplicate_rule_names():
    r = SLORule("dup", lambda s: 0.0, ">", 1.0)
    with pytest.raises(ValueError, match="duplicate"):
        HealthMonitor([r, SLORule("dup", lambda s: 0.0, ">", 1.0)])


def test_unknown_op_raises():
    with pytest.raises(ValueError, match="op"):
        SLORule("r", lambda s: 0.0, ">=", 1.0).breached(2.0)


def test_on_alert_callback_and_obs_surfacing():
    flags.FLAGS["obs_level"] = "trace"
    seen = []
    m = HealthMonitor([_const_rule("r", [9, 1, 1], resolve_after=2)],
                      on_alert=lambda a, firing: seen.append(
                          (a.name, firing, a.state)))
    for t in range(3):
        m.evaluate(float(t))
    assert seen == [("r", True, "firing"), ("r", False, "ok")]
    snap = obs.registry().snapshot()
    assert snap["health_alerts_fired_total"] == 1.0
    assert snap["health_alerts_resolved_total"] == 1.0
    assert snap["health_alerts_active"] == 0.0
    names = [e["name"] for e in obs.tracer().events]
    assert "alert.fire" in names and "alert.resolve" in names


def test_alert_surfacing_is_noop_when_obs_off():
    # monitor used standalone with obs off: transitions still recorded in
    # history/stats, registry and tracer untouched
    m = HealthMonitor([_const_rule("r", [9])])
    m.evaluate(0.0)
    assert m.stats["alerts_fired"] == 1
    assert obs.registry().snapshot() == {}
    assert obs.tracer().events == ()


# ------------------------------------------------------- anomaly detection
def test_ewma_zscore_anomaly_fires_on_regime_change():
    vals = [10.0] * 8 + [100.0, 100.0, 10.0, 10.0, 10.0]
    m = HealthMonitor([_const_rule("flat", vals, resolve_after=2)],
                      anomaly_z=3.0, anomaly_warmup=5)
    for t in range(len(vals)):
        m.evaluate(float(t))
    fired = [h["alert"] for h in m.history if h["kind"] == "fire"]
    # the absolute rule fires too (100 > 5); the anomaly alert must fire
    # on the jump and resolve once the EWMA re-adapts
    assert "flat_anomaly" in fired
    anomaly = m.alerts["flat_anomaly"]
    assert anomaly.threshold == 3.0
    assert anomaly.state == "ok"  # re-adapted after the jump


def test_anomaly_respects_warmup():
    vals = [10.0, 99.0, 10.0, 99.0]
    m = HealthMonitor([_const_rule("r", vals)], anomaly_z=0.1,
                      anomaly_warmup=10)
    for t in range(len(vals)):
        m.evaluate(float(t))
    assert "r_anomaly" not in m.alerts  # never armed inside warmup


# ------------------------------------------------------------- from_flags
def test_from_flags_builds_enabled_rules_only():
    flags.set_variant("obscounters+obssnap50+obshealth1+healthp990.25"
                      "+healthbacklog5.0")
    m = HealthMonitor.from_flags()
    names = {r.name for r in m.rules}
    assert names == {"span_slo", "degraded_rate", "load_skew",
                     "latency_p99", "migration_backlog"}
    flags.set_variant("obscounters+obssnap50+obshealth1+healthspan0"
                      "+healthdeg0+healthskew0")
    assert {r.name for r in HealthMonitor.from_flags().rules} == set()


def test_from_flags_validates_window_and_hysteresis():
    flags.FLAGS["health_window"] = 1
    with pytest.raises(ValueError, match="health_window"):
        HealthMonitor.from_flags()
    flags.reset()
    flags.FLAGS["health_hysteresis"] = 0
    with pytest.raises(ValueError, match="health_hysteresis"):
        HealthMonitor.from_flags()


def test_variant_spellings_round_trip():
    flags.set_variant("obshealth1+healthw16+healthhyst4+healthspan2.0"
                      "+healthp990.5+healthdeg0.1+healthskew5.0"
                      "+healthbacklog2.5+healthz3.0")
    F = flags.FLAGS
    assert F["obs_health"] is True
    assert F["health_window"] == 16
    assert F["health_hysteresis"] == 4
    assert F["health_span_slo"] == 2.0
    assert F["health_p99_slo"] == 0.5
    assert F["health_degraded_slo"] == 0.1
    assert F["health_skew_slo"] == 5.0
    assert F["health_backlog_slo"] == 2.5
    assert F["health_anomaly_z"] == 3.0
    with pytest.raises(ValueError, match="health_window"):
        flags.set_variant("healthw1")


# ------------------------------------------------- run_online integration
def test_run_online_health_requires_obs_and_snapshots():
    wl = random_workload(num_items=60, num_queries=200, density=5, seed=0)
    sim = Simulator(8, 24)
    flags.FLAGS["obs_health"] = True  # obs still off
    with pytest.raises(ValueError, match="obs_level"):
        sim.run_online(wl.hypergraph, ALGORITHMS["hpa"], seed=0)
    flags.FLAGS["obs_level"] = "counters"  # snapshots still 0
    with pytest.raises(ValueError, match="obs_snapshot_every"):
        sim.run_online(wl.hypergraph, ALGORITHMS["hpa"], seed=0)


def test_run_online_health_storm_fires_and_is_bit_identical(
        fault_injected_run):
    wl = random_workload(num_items=120, num_queries=3000, density=6, seed=2)
    sim = Simulator(10, 30)
    base, base_events = fault_injected_run(
        sim, wl.hypergraph, ALGORITHMS["hpa"], fault_seed=3, num_events=6,
        seed=0, auto_repair=False)

    flags.set_variant("obscounters+obssnap100+obshealth1+healthw4")
    obs.reset()
    fired = []
    mon = HealthMonitor.from_flags()
    res, _ = fault_injected_run(
        sim, wl.hypergraph, ALGORITHMS["hpa"], fault_seed=3, num_events=6,
        seed=0, auto_repair=False, health=mon,
        on_alert=lambda a, f: fired.append((a.name, f)))

    # observation-changes-nothing: monitored serving is bit-identical
    assert np.array_equal(base.spans, res.spans)
    assert np.array_equal(base.loads, res.loads)
    assert np.array_equal(base.access_load, res.access_load)
    s = res.summary()
    assert s["alerts_fired"] == mon.stats["alerts_fired"]
    assert s["alerts_resolved"] == mon.stats["alerts_resolved"]
    # the randomized storm degrades traffic without repair: the
    # degraded-rate SLO must have fired, via the callback too
    assert s["degraded_queries"] > 0
    assert any(h["alert"] == "degraded_rate" and h["kind"] == "fire"
               for h in mon.history)
    assert ("degraded_rate", True) in fired
    # monitor saw the span gauge and its baseline was pinned by the fit
    assert mon.baseline_span is not None and mon.baseline_span > 0
    assert mon.store.vmax("online_span_sum") > 0
    # span ratio hovered near 1.0 (no drift injected)
    span_alert = mon.alerts["span_slo"]
    assert span_alert.last_value is not None
    assert span_alert.last_value < 1.5


def test_run_online_clean_replay_fires_nothing():
    wl = random_workload(num_items=100, num_queries=1500, density=5, seed=7)
    flags.set_variant("obscounters+obssnap100+obshealth1+healthw4")
    obs.reset()
    mon = HealthMonitor.from_flags()
    res = Simulator(8, 24).run_online(wl.hypergraph, ALGORITHMS["hpa"],
                                      seed=0, health=mon)
    s = res.summary()
    assert s["alerts_fired"] == 0 and s["alerts_resolved"] == 0
    assert mon.history == []
    assert mon.stats["checks"] > 0


def test_run_online_flags_armed_monitor_without_explicit_instance():
    wl = random_workload(num_items=80, num_queries=800, density=5, seed=1)
    flags.set_variant("obscounters+obssnap100+obshealth1")
    obs.reset()
    res = Simulator(8, 24).run_online(wl.hypergraph, ALGORITHMS["hpa"],
                                      seed=0)
    s = res.summary()
    assert s["alerts_fired"] == 0 and s["alerts_resolved"] == 0
    # without obs_health the keys stay out of the summary
    flags.set_variant("obscounters+obssnap100")
    obs.reset()
    s2 = Simulator(8, 24).run_online(wl.hypergraph, ALGORITHMS["hpa"],
                                     seed=0).summary()
    assert "alerts_fired" not in s2


# --------------------------------------------- router load-gauge rebinding
def test_fresh_router_rebinds_load_gauge_at_construction():
    flags.FLAGS["obs_level"] = "counters"
    obs.reset()
    wl = random_workload(num_items=60, num_queries=300, density=5, seed=0)
    pl = ALGORITHMS["random"](wl.hypergraph, 6, 24, seed=0)
    r1 = ReplicaRouter(pl.member)
    r1.route_csr(wl.hypergraph.edge_ptr, wl.hypergraph.edge_nodes)
    assert sum(v for k, v in obs.registry().snapshot().items()
               if k.startswith("router_partition_load{")) > 0
    # a FRESH router must immediately own the exported gauge — before the
    # fix the gauge kept pointing at r1's ledger until r2's first batch
    r2 = ReplicaRouter(pl.member)
    vec = [v for k, v in sorted(obs.registry().snapshot().items())
           if k.startswith("router_partition_load{")]
    assert vec == [0.0] * 6
    assert r2.load.sum() == 0.0


def test_mid_run_migrate_swap_keeps_load_gauge_live():
    """Regression for the ISSUE satellite: after a mid-run ("migrate", ...)
    plan swap the exported gauge must track the router's live ledger."""
    wl = random_workload(num_items=100, num_queries=1200, density=5, seed=4)
    target = ALGORITHMS["lmbr"](wl.hypergraph, 8, 30, seed=1, max_moves=30)
    flags.set_variant("obscounters+obssnap100+routermb64")
    obs.reset()
    res = Simulator(8, 30).run_online(
        wl.hypergraph, ALGORITHMS["hpa"], seed=0,
        events=[(600, "migrate", target)],
    )
    snap = obs.registry().snapshot()
    vec = [snap[f'router_partition_load{{index="{i}"}}'] for i in range(8)]
    assert res.summary()["plan_swaps"] >= 1
    assert vec == [float(x) for x in res.access_load]


# ------------------------------------------------------------- analytics
def _x(name, ts, dur, tid=0, **args):
    return {"name": name, "ph": "X", "ts": float(ts), "dur": float(dur),
            "pid": 0, "tid": tid, "args": args}


def test_span_tree_containment_and_self_time():
    events = [
        _x("child.b", 50, 20),
        _x("grand", 12, 5),
        _x("child.a", 10, 30),
        _x("root", 0, 100),
        _x("async.transfer", 90, 50),   # partial overlap: parentless
    ]
    roots = build_span_tree(events)
    assert [r.name for r in roots] == ["root", "async.transfer"]
    root = roots[0]
    assert [c.name for c in root.children] == ["child.a", "child.b"]
    assert [c.name for c in root.children[0].children] == ["grand"]
    assert root.self_time == 100 - 30 - 20
    assert root.children[0].self_time == 30 - 5
    assert roots[1].parent is None and roots[1].children == []


def test_span_tree_separate_tids_do_not_nest():
    events = [_x("a", 0, 100, tid=0), _x("b", 10, 20, tid=1)]
    roots = build_span_tree(events)
    assert sorted(r.name for r in roots) == ["a", "b"]


def test_aggregate_and_critical_path_and_top_slowest():
    events = [
        _x("fit.place", 0, 100),
        _x("fit.hpa", 5, 80),
        _x("fit.hpa.refine", 10, 60),
        _x("serve.microbatch", 150, 9, queries=3),
        _x("serve.microbatch", 160, 5, queries=3),
        _x("serve.microbatch", 170, 12, queries=2),
    ]
    agg = aggregate_spans(events)
    assert agg["serve.microbatch"]["count"] == 3
    assert agg["serve.microbatch"]["total_us"] == 26.0
    assert agg["serve.microbatch"]["max_us"] == 12.0
    assert agg["fit.place"]["self_us"] == 20.0
    path = critical_path(events)
    assert [n.name for n in path] == ["fit.place", "fit.hpa",
                                      "fit.hpa.refine"]
    slow = top_slowest(events, k=2)
    assert [e["dur"] for e in slow] == [12.0, 9.0]
    assert critical_path([]) == []


def test_load_events_jsonl_and_chrome_json_agree():
    flags.FLAGS["obs_level"] = "trace"
    obs.reset()
    tr = obs.tracer()
    with tr.span("outer"):
        with tr.span("inner"):
            pass
    tr.event("mark")
    assert load_events(tr.to_jsonl()) == load_events(tr.to_chrome_trace())
    assert load_events("") == []
    assert load_events('{"name": "solo", "ph": "X", "ts": 0, "dur": 1}') \
        == [{"name": "solo", "ph": "X", "ts": 0, "dur": 1}]


def test_render_report_sections():
    events = [
        _x("fit.place", 0, 100),
        _x("serve.microbatch", 150, 9, queries=3),
        {"name": "alert.fire", "ph": "i", "ts": 155.0, "pid": 0, "tid": 0,
         "args": {"rule": "degraded_rate", "value": 0.5, "threshold": 0.02}},
    ]
    out = render_report(events, {"router_served_queries_total": 3.0,
                                 "health_alerts_fired_total": 1.0})
    assert "== trace ==" in out
    assert "critical path (fit.place)" in out
    assert "slowest serve.microbatch" in out
    assert "rule=degraded_rate" in out
    assert "router_served_queries_total" in out


def test_obs_report_cli_on_committed_fixtures():
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO_ROOT, "tools", "obs_report.py"),
         os.path.join(REPO_ROOT, "tools", "fixtures", "tiny_trace.jsonl"),
         "--prom",
         os.path.join(REPO_ROOT, "tools", "fixtures", "tiny_prom.txt")],
        capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stderr
    assert "== trace ==" in proc.stdout
    assert "== metrics ==" in proc.stdout
    assert "alert.fire" in proc.stdout  # the fixture run fired alerts


def test_obs_report_cli_missing_file_fails_cleanly():
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO_ROOT, "tools", "obs_report.py"),
         os.path.join(REPO_ROOT, "does_not_exist.jsonl")],
        capture_output=True, text=True, timeout=120)
    assert proc.returncode == 1
    assert "cannot load trace" in proc.stderr
