"""Tests for the online serving subsystem (repro.online): streaming router,
workload-drift sketch/detector, span-aware failover, and the event-capable
simulator replay."""

import numpy as np
import pytest

from repro import flags
from repro.core import (
    ALGORITHMS,
    Hypergraph,
    PlacementService,
    Simulator,
    cover_for_query,
    random_workload,
    spans_for_workload,
)
from repro.core.setcover import Placement
from repro.online import (
    DriftDetector,
    FailoverManager,
    ReplicaRouter,
    WorkloadSketch,
)


@pytest.fixture(scope="module")
def fitted():
    wl = random_workload(num_items=150, num_queries=400, density=6, seed=3)
    pl = ALGORITHMS["lmbr"](wl.hypergraph, 10, 32, seed=0, max_moves=40)
    pl.validate()
    return wl.hypergraph, pl


# ------------------------------------------------------------------- router
def test_router_default_bit_identical(fitted):
    """Default microbatched covers == per-query cover_for_query, including
    replica attribution, across microbatch boundaries."""
    hg, pl = fitted
    router = ReplicaRouter(pl.member, microbatch=64)
    batch = router.route_csr(hg.edge_ptr, hg.edge_nodes)
    for e in range(hg.num_edges):
        chosen, accessed = cover_for_query(hg.edge(e), pl.member)
        assert list(batch.chosen(e)) == chosen
        cov = batch.cover(e)
        assert list(cov) == chosen  # greedy selection order preserved
        for p, items in zip(chosen, accessed):
            assert np.array_equal(cov[p], items)
    assert router.stats["served_queries"] == hg.num_edges
    assert router.stats["microbatches"] == -(-hg.num_edges // 64)


def test_router_route_one_matches_batch(fitted):
    hg, pl = fitted
    router = ReplicaRouter(pl.member)
    for e in range(0, hg.num_edges, 37):
        chosen, cov = router.route_one(hg.edge(e))
        ref_chosen, ref_accessed = cover_for_query(hg.edge(e), pl.member)
        assert list(chosen) == ref_chosen
        for p, items in zip(ref_chosen, ref_accessed):
            assert np.array_equal(cov[p], items)


def test_router_ledger_matches_access_load(fitted):
    """The ledger counts one access per chosen cover member — the same unit
    as SimulationResult.access_load."""
    hg, pl = fitted
    router = ReplicaRouter(pl.member, microbatch=128)
    batch = router.route_csr(hg.edge_ptr, hg.edge_nodes)
    expect = np.bincount(batch.cover_parts, minlength=pl.num_partitions)
    assert np.array_equal(router.load, expect.astype(np.float64))


def test_router_balanced_reduces_imbalance_without_span_cost():
    """Skewed trace over a fully replicated layout: the default tie-break
    hammers the lowest partition id; the balanced mode spreads accesses
    across the equal-gain replicas at identical spans."""
    rng = np.random.default_rng(0)
    member = np.ones((4, 20), dtype=bool)  # every partition holds everything
    queries = [np.unique(rng.integers(0, 20, size=3)) for _ in range(200)]
    default = ReplicaRouter(member.copy(), microbatch=32, balance=False)
    balanced = ReplicaRouter(member.copy(), microbatch=32, balance=True)
    db = default.route(queries)
    bb = balanced.route(queries)
    assert balanced.load_imbalance() < default.load_imbalance()
    assert float(bb.spans.mean()) <= float(db.spans.mean())
    # every query is fully local somewhere -> spans stay 1 in both modes
    assert db.spans.max() == bb.spans.max() == 1


def test_router_balance_flag_and_swap(fitted):
    hg, pl = fitted
    flags.set_variant("routerbal1+routermb64")
    try:
        router = ReplicaRouter(pl.member)
        assert router._cfg() == (64, True)
    finally:
        flags.reset()
    router = ReplicaRouter(pl.member)
    other = np.ones_like(pl.member)
    router.swap_plan(other)
    assert router.member is other
    assert router.stats["plan_swaps"] == 1
    with pytest.raises(ValueError):
        router.swap_plan(np.ones((pl.num_partitions + 1, pl.num_items),
                                 dtype=bool))


# -------------------------------------------------------------------- drift
def test_sketch_rebuild_equals_direct_hypergraph(fitted):
    hg, _ = fitted
    sketch = WorkloadSketch(hg.num_nodes, window=50)
    empty = sketch.to_hypergraph()
    assert empty.num_edges == 0 and empty.num_nodes == hg.num_nodes
    for e in range(120):  # overflow the window: only the last 50 remain
        sketch.observe(hg.edge(e))
    assert sketch.full and len(sketch) == 50
    rebuilt = sketch.to_hypergraph()
    direct = Hypergraph.from_edges(
        [hg.edge(e) for e in range(70, 120)], num_nodes=hg.num_nodes
    )
    assert np.array_equal(rebuilt.edge_ptr, direct.edge_ptr)
    assert np.array_equal(rebuilt.edge_nodes, direct.edge_nodes)
    assert np.array_equal(rebuilt.edge_weights, direct.edge_weights)


def test_sketch_decay_weights(fitted):
    hg, _ = fitted
    sketch = WorkloadSketch(hg.num_nodes, window=4, decay=0.5)
    for e in range(4):
        sketch.observe(hg.edge(e))
    assert np.allclose(sketch.edge_weights(), [0.125, 0.25, 0.5, 1.0])
    assert np.allclose(sketch.to_hypergraph().edge_weights,
                       [0.125, 0.25, 0.5, 1.0])


def test_drift_detector_fires_and_refits():
    wl_old = random_workload(num_items=120, num_queries=300, density=6, seed=2)
    wl_new = random_workload(num_items=120, num_queries=300, density=6, seed=9)
    svc = PlacementService("hpa", seed=0)  # no replication -> room to refit
    plan = svc.fit(wl_old.queries, 120, 10, 30)
    det = DriftDetector(plan, PlacementService("lmbr", seed=0), window=100,
                        threshold=1.05, refit_moves=128)
    det.seed_baseline_from(wl_old.queries)
    # old traffic at the fit-time span level: no fire
    det.observe(wl_old.queries[:100], plan.spans(wl_old.queries[:100]))
    assert not det.should_refit()
    # shifted traffic regresses the windowed span past the threshold
    det.observe(wl_new.queries[:100], plan.spans(wl_new.queries[:100]))
    assert det.windowed_avg_span > det.baseline * det.threshold
    assert det.should_refit()
    before = det.windowed_avg_span
    new_plan = det.refit()
    assert det.plan is new_plan
    assert (new_plan.member >= plan.member).all()  # refit only adds copies
    # re-baselined against the new plan on the drifted window: trigger re-arms
    assert det.stats["refits"] == 1
    assert new_plan.avg_span(wl_new.queries[:100]) <= before


# ----------------------------------------------------------------- failover
def test_failover_down_audit_up(fitted):
    hg, pl = fitted
    live = Placement(pl.member.copy(), pl.capacity, hg.node_weights)
    fo = FailoverManager(live)
    before = pl.member.copy()
    sole = before[0] & ~(before[1:].any(axis=0))  # items only on partition 0
    lost = fo.partition_down(0)
    assert np.array_equal(lost, np.flatnonzero(sole))
    assert not live.member[0].any()
    assert np.array_equal(fo.uncovered_items(), np.flatnonzero(sole))
    # queries touching a lost item are flagged unserveable
    mask = fo.serveable_mask(hg.edge_ptr, hg.edge_nodes)
    for e in range(hg.num_edges):
        assert mask[e] == (not np.isin(hg.edge(e), lost).any())
    fo.partition_up(0)
    assert (live.member == before).all()
    with pytest.raises(ValueError):
        fo.partition_up(0)  # not down anymore


def test_failover_repair_restores_coverage_within_capacity(fitted):
    hg, pl = fitted
    live = Placement(pl.member.copy(), pl.capacity, hg.node_weights)
    fo = FailoverManager(live)
    fo.partition_down(2)
    fo.partition_down(5)
    repaired = fo.repair(hg, k=1)
    assert len(fo.uncovered_items()) == 0
    live.validate()  # never exceeds capacity
    assert fo.stats["repaired_items"] == len(repaired)
    # repaired copies only land on surviving partitions
    assert not live.member[2].any() and not live.member[5].any()


def test_failover_repair_k_safety(fitted):
    hg, pl = fitted
    live = Placement(pl.member.copy(), pl.capacity * 4, hg.node_weights)
    fo = FailoverManager(live)
    fo.partition_down(0)
    fo.repair(hg, k=2)
    counts = live.member.sum(axis=0)
    assert (counts[hg.node_weights > 0] >= 2).all()


def test_failover_repair_respects_tight_capacity():
    """With no free space anywhere, repair places nothing and reports the
    items as unrepairable instead of blowing capacity."""
    hg = Hypergraph.from_edges([[0, 1], [1, 2], [2, 3]], num_nodes=4)
    member = np.array([[True, True, False, False],
                       [False, False, True, True]])
    live = Placement(member.copy(), 2.0, np.ones(4))
    fo = FailoverManager(live)
    lost = fo.partition_down(0)
    assert np.array_equal(lost, [0, 1])
    repaired = fo.repair(hg, k=1)
    assert len(repaired) == 0
    assert fo.stats["unrepairable_items"] == 2
    assert (live.partition_weights() <= live.capacity + 1e-9).all()


def test_failover_rebase_rules_during_outage(fitted):
    """Rebasing during an outage is legal iff the new layout keeps every
    down partition's row empty (the outage-refit contract); a layout that
    stores items on a dead partition is rejected."""
    hg, pl = fitted
    live = Placement(pl.member.copy(), pl.capacity, hg.node_weights)
    fo = FailoverManager(live)
    fo.partition_down(1)
    bad = Placement(np.ones_like(pl.member), pl.capacity * 100,
                    hg.node_weights)
    with pytest.raises(RuntimeError):
        fo.rebase(bad)
    fo.rebase(live)  # masked layout: down row empty -> legal
    assert fo.pl is live and fo.down_partitions == [1]
    fo.partition_up(1)  # saved row still restorable after the rebase
    assert live.member[1].any()


def test_failover_repair_batched_matches_reference(fitted):
    """The wave-batched repair is bit-identical to the retained per-item
    reference on every single kill and a few pairs (the bench_online kill
    scenarios in miniature): same copies, same destinations, same stats."""
    hg, pl = fitted
    for kills in [[p] for p in range(pl.num_partitions)] + [[0, 1], [3, 7]]:
        batched = Placement(pl.member.copy(), pl.capacity, hg.node_weights)
        ref = Placement(pl.member.copy(), pl.capacity, hg.node_weights)
        fo_b, fo_r = FailoverManager(batched), FailoverManager(ref)
        for p in kills:
            fo_b.partition_down(p)
            fo_r.partition_down(p)
        got = fo_b.repair(hg, k=1)
        want = fo_r.repair_reference(hg, k=1)
        assert np.array_equal(got, want), f"repaired set diverged {kills}"
        assert (batched.member == ref.member).all(), f"layout diverged {kills}"
        assert fo_b.stats == fo_r.stats


def test_failover_repair_batched_matches_reference_k2(fitted):
    hg, pl = fitted
    batched = Placement(pl.member.copy(), pl.capacity * 4, hg.node_weights)
    ref = Placement(pl.member.copy(), pl.capacity * 4, hg.node_weights)
    fo_b, fo_r = FailoverManager(batched), FailoverManager(ref)
    fo_b.partition_down(0)
    fo_r.partition_down(0)
    got = fo_b.repair(hg, k=2)
    want = fo_r.repair_reference(hg, k=2)
    assert np.array_equal(got, want)
    assert (batched.member == ref.member).all()


# --------------------------------------------------------- ledger epsilon
def _route_always_sorted(member, load, queries, microbatch):
    """The pre-epsilon balanced loop: a fresh (load, id) lexsort EVERY
    microbatch — the oracle the cached permutation must reproduce."""
    from repro.core.setcover import batched_cover_csr
    from repro.online.router import queries_to_csr

    spans_all, parts_all = [], []
    for lo in range(0, len(queries), microbatch):
        ptr, nodes = queries_to_csr(queries[lo: lo + microbatch])
        order = np.lexsort((np.arange(member.shape[0]), load)).astype(np.int64)
        cov = batched_cover_csr(ptr, nodes, member[order])
        parts = order[cov.cover_parts]
        load += np.bincount(parts, minlength=member.shape[0])
        spans_all.append(cov.spans)
        parts_all.append(parts)
    return np.concatenate(spans_all), np.concatenate(parts_all)


def test_router_ledger_epsilon_zero_identical(fitted):
    """epsilon=0 (the default): the cached permutation rebuilds on any
    ledger shift, so routing is bit-identical to re-sorting every
    microbatch."""
    hg, pl = fitted
    queries = [hg.edge(e) for e in range(hg.num_edges)]
    router = ReplicaRouter(pl.member, microbatch=64, balance=True)
    batch = router.route(queries)
    ref_spans, ref_parts = _route_always_sorted(
        pl.member, np.zeros(pl.num_partitions), queries, 64
    )
    assert np.array_equal(batch.spans, ref_spans)
    assert np.array_equal(batch.cover_parts, ref_parts)


def test_router_ledger_epsilon_skips_sorts(fitted):
    """A loose epsilon keeps the lexsort off the steady-state hot path
    (fewer ledger_sorts than microbatches) while still serving every query
    with valid covers."""
    hg, pl = fitted
    queries = [hg.edge(e) for e in range(hg.num_edges)]
    flags.set_variant("routerbal1+routereps0.5+routermb32")
    try:
        router = ReplicaRouter(pl.member)
        batch = router.route(queries)
    finally:
        flags.reset()
    assert router.stats["ledger_sorts"] < router.stats["microbatches"]
    assert len(batch.spans) == hg.num_edges
    # covers are real covers: every span >= 1
    assert (batch.spans >= 1).all()


def test_router_epsilon_variant_validation():
    with pytest.raises(ValueError):
        flags.set_variant("routereps-1")
    flags.reset()


# --------------------------------------------------------------- run_online
def test_run_online_matches_batch_replay(fitted):
    """With no events and no drift service, online serving reproduces the
    batch replay exactly: same spans, access load, energy, shipped bytes."""
    hg, _ = fitted
    sim = Simulator(10, 32)
    batch = sim.run(hg, ALGORITHMS["lmbr"], name="lmbr", seed=0, max_moves=40)
    online = sim.run_online(hg, ALGORITHMS["lmbr"], name="lmbr", seed=0,
                            max_moves=40)
    assert np.array_equal(batch.spans, online.spans)
    assert np.array_equal(batch.access_load, online.access_load)
    assert np.isclose(batch.energy_joules, online.energy_joules)
    assert np.isclose(batch.shipped_gb, online.shipped_gb)
    s = online.summary()
    assert s["served_queries"] == hg.num_edges
    assert s["degraded_queries"] == 0 and s["plan_swaps"] == 0


def test_run_online_failure_event_counters(fitted):
    hg, _ = fitted
    sim = Simulator(10, 32)
    res = sim.run_online(
        hg, ALGORITHMS["lmbr"], name="lmbr", seed=0, max_moves=40,
        events=[(100, "down", 0), (250, "up", 0)],
    )
    s = res.summary()
    assert s["partitions_down"] == 1
    assert s["served_queries"] + s["degraded_queries"] == hg.num_edges
    assert s["degraded_queries"] == 0  # auto-repair restored coverage
    assert s["repaired_items"] >= 0
    assert len(res.spans) == s["served_queries"]


def test_run_online_degraded_without_repair(fitted):
    """auto_repair=False: queries touching items lost with the partition are
    counted degraded (not served, no crash) until the partition returns."""
    hg, pl = fitted
    sole = pl.member[0] & ~(pl.member[1:].any(axis=0))
    assert sole.any()  # partition 0 holds sole replicas in this fixture
    sim = Simulator(10, 32)
    res = sim.run_online(
        hg, ALGORITHMS["lmbr"], name="lmbr", seed=0, max_moves=40,
        events=[(0, "down", 0), (200, "up", 0)], auto_repair=False,
    )
    s = res.summary()
    assert s["degraded_queries"] > 0
    assert s["repaired_items"] == 0
    assert s["served_queries"] + s["degraded_queries"] == hg.num_edges


def test_run_online_drift_swaps_plan():
    old = random_workload(num_items=120, num_queries=600, density=6, seed=2)
    new = random_workload(num_items=120, num_queries=600, density=6, seed=9)
    trace = Hypergraph.from_edges(
        [old.hypergraph.edge(e) for e in range(200)]
        + [new.hypergraph.edge(e) for e in range(600)],
        num_nodes=120,
    )
    flags.set_variant("driftw128+driftth1.1+routermb64")
    try:
        sim = Simulator(10, 30)
        res = sim.run_online(
            old.hypergraph, ALGORITHMS["hpa"], name="hpa+drift", trace=trace,
            service=PlacementService("lmbr", seed=0), refit_moves=128,
            seed=0,
        )
    finally:
        flags.reset()
    s = res.summary()
    assert s["drift_fires"] >= 1 and s["plan_swaps"] >= 1
    assert s["refits"] == s["plan_swaps"]
    # the final layout must still honor capacity after every hot swap
    assert (res.loads <= 30 + 1e-9).all()


def test_run_online_drift_refits_through_long_outage():
    """A partition dies early and never comes back; the workload then
    shifts.  Drift adaptation must continue THROUGH the outage: the refit
    runs on the failure-masked surviving layout and never places anything
    on the dead partition."""
    old = random_workload(num_items=120, num_queries=600, density=6, seed=2)
    new = random_workload(num_items=120, num_queries=600, density=6, seed=9)
    trace = Hypergraph.from_edges(
        [old.hypergraph.edge(e) for e in range(200)]
        + [new.hypergraph.edge(e) for e in range(600)],
        num_nodes=120,
    )
    flags.set_variant("driftw128+driftth1.1+routermb64")
    try:
        sim = Simulator(10, 30)
        res = sim.run_online(
            old.hypergraph, ALGORITHMS["hpa"], name="hpa+drift", trace=trace,
            service=PlacementService("lmbr", seed=0), refit_moves=128,
            seed=0, events=[(50, "down", 0)],  # down for the whole trace
        )
    finally:
        flags.reset()
    s = res.summary()
    assert s["partitions_down"] == 1
    assert s["plan_swaps"] >= 1, "drift adaptation stalled during the outage"
    assert s["refits"] == s["plan_swaps"]
    # nothing was ever copied onto the dead partition, capacity holds
    assert res.loads[0] == 0.0
    assert (res.loads <= 30 + 1e-9).all()


def test_run_online_unknown_event_rejected(fitted):
    hg, _ = fitted
    sim = Simulator(10, 32)
    with pytest.raises(ValueError):
        sim.run_online(hg, ALGORITHMS["lmbr"], seed=0, max_moves=40,
                       events=[(0, "explode", 1)])


def test_run_online_fault_storm_ledger(fault_injected_run):
    """Randomized legal down/up storms: no query is ever lost — everything
    is either served or counted degraded — and capacity holds after the
    repairs the storm triggers."""
    wl = random_workload(num_items=120, num_queries=500, density=5, seed=4)
    sim = Simulator(10, 30)
    res, events = fault_injected_run(
        sim, wl.hypergraph, ALGORITHMS["lmbr"], fault_seed=3,
        num_events=10, seed=0, max_moves=40,
    )
    assert len(events) > 0
    assert (res.loads <= 30 + 1e-9).all()
