"""Tests for the observability layer (repro.obs): metrics registry,
structured tracer, level selection, hot-path instrumentation, and the
"observation changes nothing" contract."""

import json

import numpy as np
import pytest

from repro import flags, obs
from repro.core import (
    ALGORITHMS,
    Hypergraph,
    PlacementService,
    Simulator,
    random_workload,
)
from repro.obs import (
    NULL_REGISTRY,
    NULL_TRACER,
    Registry,
    Tracer,
    parse_prom_text,
)


@pytest.fixture(autouse=True)
def _clean_obs():
    flags.reset()
    obs.reset()
    yield
    flags.reset()
    obs.reset()


# ---------------------------------------------------------------- registry
def test_counter_and_labels():
    reg = Registry()
    reg.inc("queries_total")
    reg.inc("queries_total", 4.0)
    reg.inc("queries_total", backend="device")
    snap = reg.snapshot()
    assert snap["queries_total"] == 5.0
    assert snap['queries_total{backend="device"}'] == 1.0


def test_gauge_set_and_add():
    reg = Registry()
    reg.set("inflight", 3.0)
    reg.gauge("inflight").add(-1.0)
    assert reg.snapshot()["inflight"] == 2.0


def test_gauge_vector_live_reference_copied_at_snapshot():
    reg = Registry()
    load = np.zeros(3)
    reg.gauge_vector("part_load").set(load)
    load[1] = 7.0  # mutate AFTER set: snapshot must see the live value
    snap = reg.snapshot()
    assert snap['part_load{index="1"}'] == 7.0
    assert snap['part_load{index="0"}'] == 0.0


def test_histogram_cumulative_buckets():
    reg = Registry()
    h = reg.histogram("lat", buckets=(0.001, 0.01, 0.1))
    for x in (0.0005, 0.005, 0.005, 0.05, 5.0):
        h.observe(x)
    snap = reg.snapshot()
    assert snap['lat_bucket{le="0.001"}'] == 1.0
    assert snap['lat_bucket{le="0.01"}'] == 3.0
    assert snap['lat_bucket{le="0.1"}'] == 4.0
    assert snap['lat_bucket{le="+Inf"}'] == 5.0
    assert snap["lat_count"] == 5.0
    assert snap["lat_sum"] == pytest.approx(5.0605)


def test_kind_conflict_rejected():
    reg = Registry()
    reg.inc("x")
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x")


def test_prom_text_round_trip_exact():
    reg = Registry()
    reg.inc("a_total", 3.5)
    reg.inc("a_total", 1.0, shape="B64.N128")
    reg.set("g", -0.125)
    reg.gauge_vector("vec").set([1.0, 2.0])
    reg.observe("h", 0.0123)
    reg.observe("h", 7.7)
    snap = reg.snapshot()
    assert parse_prom_text(reg.to_prom_text()) == snap
    # TYPE lines present once per metric family
    text = reg.to_prom_text()
    assert text.count("# TYPE a_total counter") == 1
    assert "# TYPE h histogram" in text


def test_null_registry_is_inert():
    assert NULL_REGISTRY.active is False
    NULL_REGISTRY.inc("x", 5.0)
    NULL_REGISTRY.observe("y", 1.0)
    NULL_REGISTRY.gauge_vector("z").set([1.0])
    assert NULL_REGISTRY.snapshot() == {}
    assert NULL_REGISTRY.to_prom_text() == ""


# ------------------------------------------------------------------ tracer
def test_span_nesting_by_containment():
    tr = Tracer()
    with tr.span("outer", k=1):
        with tr.span("inner"):
            pass
    inner, outer = tr.events  # inner exits (and records) first
    assert inner["name"] == "inner" and outer["name"] == "outer"
    assert outer["ph"] == "X" and inner["ph"] == "X"
    assert outer["ts"] <= inner["ts"]
    assert outer["ts"] + outer["dur"] >= inner["ts"] + inner["dur"]
    assert outer["args"] == {"k": 1}


def test_instant_and_counter_events():
    tr = Tracer()
    tr.event("drift.fire", ratio=1.3)
    tr.counter("online", served=10, inflight=2)
    kinds = [e["ph"] for e in tr.events]
    assert kinds == ["i", "C"]
    assert tr.events[0]["s"] == "t"
    assert tr.events[1]["args"] == {"served": 10, "inflight": 2}


def test_chrome_trace_and_jsonl_serialise():
    tr = Tracer()
    with tr.span("a"):
        pass
    tr.event("b")
    doc = json.loads(tr.to_chrome_trace())
    assert doc["displayTimeUnit"] == "ms"
    assert [e["name"] for e in doc["traceEvents"]] == ["a", "b"]
    lines = tr.to_jsonl().splitlines()
    assert len(lines) == 2
    assert json.loads(lines[0])["name"] == "a"


def test_spans_filter_and_clear():
    tr = Tracer()
    with tr.span("x"):
        pass
    tr.event("x")
    assert len(tr.spans("x")) == 1
    assert len(tr.spans()) == 1
    tr.clear()
    assert tr.events == [] and tr.spans() == []


def test_null_tracer_is_inert():
    assert NULL_TRACER.active is False
    with NULL_TRACER.span("x"):
        pass
    NULL_TRACER.event("y")
    assert NULL_TRACER.events == ()
    assert json.loads(NULL_TRACER.to_chrome_trace()) == {"traceEvents": []}


# --------------------------------------------------- level selection / flags
def test_level_selection():
    assert obs.registry() is NULL_REGISTRY
    assert obs.tracer() is NULL_TRACER
    flags.FLAGS["obs_level"] = "counters"
    assert obs.registry().active and obs.tracer() is NULL_TRACER
    flags.FLAGS["obs_level"] = "trace"
    assert obs.registry().active and obs.tracer().active


def test_obs_flag_variants():
    flags.set_variant("obstrace")
    assert flags.FLAGS["obs_level"] == "trace"
    flags.set_variant("obscounters")
    assert flags.FLAGS["obs_level"] == "counters"
    flags.set_variant("obsoff")
    assert flags.FLAGS["obs_level"] == "off"
    flags.set_variant("obssnap100")
    assert flags.FLAGS["obs_snapshot_every"] == 100
    with pytest.raises(ValueError):
        flags.set_variant("obsbogus")
    with pytest.raises(ValueError):
        flags.set_variant("obssnap-5")


def test_timed_always_measures_trace_only_when_tracing():
    with obs.timed("work") as t:
        sum(range(1000))
    assert t.seconds > 0.0
    assert obs.tracer().spans() == []  # off: no event recorded
    flags.FLAGS["obs_level"] = "trace"
    with obs.timed("work", stage="x") as t:
        pass
    spans = obs.tracer().spans("work")
    assert len(spans) == 1 and spans[0]["args"] == {"stage": "x"}
    assert t.seconds >= 0.0


# --------------------------------------------- observation changes nothing
def _summary_no_wall_clock(res):
    return {k: v for k, v in res.summary().items() if k != "placement_s"}


def test_off_vs_trace_bit_identical_fit_and_serve():
    wl = random_workload(num_items=120, num_queries=300, density=5, seed=4)
    sim = Simulator(8, 32)

    base = sim.run_online(wl.hypergraph, ALGORITHMS["lmbr"], name="lmbr",
                          seed=0, max_moves=40)
    flags.FLAGS["obs_level"] = "trace"
    obs.reset()
    traced = sim.run_online(wl.hypergraph, ALGORITHMS["lmbr"], name="lmbr",
                            seed=0, max_moves=40)
    assert np.array_equal(base.spans, traced.spans)
    assert np.array_equal(base.access_load, traced.access_load)
    assert _summary_no_wall_clock(base) == _summary_no_wall_clock(traced)
    # and the traced run actually produced spans
    assert obs.tracer().spans("fit.lmbr")
    assert obs.tracer().spans("serve.microbatch")


# ------------------------------------------- end-to-end acceptance trace
def test_full_lifecycle_trace_and_prom_round_trip():
    """fit -> serve -> outage -> drift refit -> paced migration, traced:
    the Chrome trace must cover fit phases, router microbatches, the drift
    refit, and EVERY migration transfer; the registry must round-trip
    through the Prometheus text format."""
    old = random_workload(num_items=120, num_queries=500, density=6, seed=2)
    new = random_workload(num_items=120, num_queries=500, density=6, seed=9)
    trace = Hypergraph.from_edges(
        [old.hypergraph.edge(e) for e in range(200)]
        + [new.hypergraph.edge(e) for e in range(500)],
        num_nodes=120,
    )
    target = ALGORITHMS["lmbr"](old.hypergraph, 10, 30, seed=1, max_moves=40)
    flags.set_variant("driftw128+driftth1.1+routermb64+obstrace+obssnap100")
    flags.FLAGS["migration_bandwidth"] = 5.0
    obs.reset()
    sim = Simulator(10, 30)
    res = sim.run_online(
        old.hypergraph, ALGORITHMS["hpa"], name="hpa+drift", trace=trace,
        events=[(20, "down", 3), (60, "up", 3), (100, "migrate", target)],
        service=PlacementService("lmbr", seed=0), refit_moves=128, seed=0,
    )
    s = res.summary()
    tr = obs.tracer()

    # fit phases: hpa coarsen/refine under the top-level fit span
    assert tr.spans("fit.place") and tr.spans("fit.hpa")
    assert tr.spans("fit.hpa.coarsen") and tr.spans("fit.hpa.refine")
    # serving: one complete event per routed microbatch
    assert len(tr.spans("serve.microbatch")) > 0
    # drift fired and the refit was traced
    assert s["drift_fires"] >= 1
    assert tr.spans("drift.refit") and tr.spans("fit.lmbr")
    # failover events
    names = [e["name"] for e in tr.events]
    assert "failover.down" in names and "failover.up" in names
    # every migration transfer landed as a complete event
    assert s["migrations"] >= 1
    assert len(tr.spans("migration.transfer")) == s["migration_copies"]
    # periodic snapshots emitted as counter events
    snaps = [e for e in tr.events
             if e["ph"] == "C" and e["name"] == "online.snapshot"]
    assert len(snaps) >= 1
    assert s["served_queries"] >= 100  # snapshots had a chance to fire

    # the whole thing is valid Chrome trace JSON
    doc = json.loads(tr.to_chrome_trace())
    assert {e["name"] for e in doc["traceEvents"]} >= {
        "fit.hpa", "serve.microbatch", "migration.transfer"}

    # registry round-trips through the text exposition exactly
    reg = obs.registry()
    snap = reg.snapshot()
    assert snap["migration_copies_total"] == s["migration_copies"]
    assert snap["router_plan_swaps_total"] == s["plan_swaps"]
    assert parse_prom_text(reg.to_prom_text()) == snap


def test_migration_stats_canonical_only():
    """Executor stats carry ONLY the canonical migration_-prefixed keys;
    the deprecated bare transferred/wasted aliases (scheduled for removal
    after one release in PR 9) are gone."""
    from repro.online.migration import MigrationExecutor, plan_migration

    from repro.core.setcover import Placement

    wl = random_workload(num_items=80, num_queries=200, density=5, seed=1)
    src = ALGORITHMS["hpa"](wl.hypergraph, 8, 24, seed=0)
    dst = ALGORITHMS["lmbr"](wl.hypergraph, 8, 24, seed=0, max_moves=30)
    plan = plan_migration(src.member, dst.member,
                          wl.hypergraph.node_weights, bandwidth=4.0)
    live = Placement(src.member.copy(), 24, wl.hypergraph.node_weights)
    ex = MigrationExecutor(plan, live)
    while not ex.done:
        ex.advance(1)
    assert "transferred" not in ex.stats
    assert "wasted" not in ex.stats
    assert ex.stats["migration_transferred"] > 0.0
    assert ex.stats["migration_wasted"] >= 0.0


# ------------------------------------------------- prom exposition edge cases
def test_prom_label_value_escaping_round_trip():
    reg = Registry()
    reg.inc("esc_total", 1.0, path=r"C:\tmp\x")          # backslash
    reg.inc("esc_total", 2.0, msg='he said "hi"')        # quote
    reg.inc("esc_total", 3.0, text="line1\nline2")       # newline
    reg.inc("esc_total", 4.0, q="a b c")                 # spaces
    text = reg.to_prom_text()
    assert r'path="C:\\tmp\\x"' in text
    assert r'msg="he said \"hi\""' in text
    assert r'text="line1\nline2"' in text
    assert "\nline2" not in text.replace(r"\n", "")  # stays one line
    assert parse_prom_text(text) == reg.snapshot()


def test_prom_empty_registry_round_trip():
    reg = Registry()
    assert reg.snapshot() == {}
    assert reg.to_prom_text() == ""
    assert parse_prom_text("") == {}
    assert parse_prom_text(reg.to_prom_text()) == reg.snapshot()


def test_prom_histogram_inf_bucket_and_boundary():
    reg = Registry()
    h = reg.histogram("edge_seconds", buckets=(0.1, 1.0))
    h.observe(0.05)   # first bucket
    h.observe(0.1)    # boundary: bisect_left counts it IN le="0.1"
    h.observe(50.0)   # beyond the last bound: +Inf only
    snap = reg.snapshot()
    assert snap['edge_seconds_bucket{le="0.1"}'] == 2.0
    assert snap['edge_seconds_bucket{le="1.0"}'] == 2.0  # cumulative
    assert snap['edge_seconds_bucket{le="+Inf"}'] == 3.0
    assert snap["edge_seconds_count"] == 3.0
    assert snap["edge_seconds_sum"] == 50.15
    assert parse_prom_text(reg.to_prom_text()) == snap
