"""Greedy set cover (replica selection) tests incl. brute-force optimality gap."""

import itertools

import numpy as np
import pytest

from _pbt import given, settings, st

from repro.core.setcover import (
    Placement, cover_for_query, greedy_set_cover, query_span,
)


def member_from_parts(parts, num_items):
    m = np.zeros((len(parts), num_items), dtype=bool)
    for p, items in enumerate(parts):
        m[p, list(items)] = True
    return m


def test_greedy_picks_largest_overlap_first():
    member = member_from_parts([[0, 1, 2], [2, 3], [3, 4]], 5)
    chosen = greedy_set_cover(np.array([0, 1, 2, 3]), member)
    assert chosen[0] == 0  # covers 3 of 4
    assert query_span(np.array([0, 1, 2, 3]), member) == 2


def test_cover_attributes_items_to_first_holder():
    member = member_from_parts([[0, 1], [1, 2]], 3)
    chosen, accessed = cover_for_query(np.array([0, 1, 2]), member)
    assert chosen == [0, 1]
    np.testing.assert_array_equal(sorted(accessed[0]), [0, 1])
    np.testing.assert_array_equal(accessed[1], [2])  # 1 already read from p0


def test_unplaced_item_raises():
    member = member_from_parts([[0]], 2)
    with pytest.raises(ValueError):
        greedy_set_cover(np.array([1]), member)


def test_paper_fig2_style_example():
    """Replication reduces span (fig. 2): without replication span(e)=2, the
    replicated layout brings it to 1."""
    # items 0..5 on 3 partitions of capacity 3; query touches {2,3}
    no_rep = member_from_parts([[0, 1, 2], [3, 4], [5]], 6)
    with_rep = member_from_parts([[0, 1, 2], [2, 3, 4], [5]], 6)
    q = np.array([2, 3])
    assert query_span(q, no_rep) == 2
    assert query_span(q, with_rep) == 1


def brute_force_optimal(query, member):
    n = member.shape[0]
    for size in range(1, n + 1):
        for combo in itertools.combinations(range(n), size):
            if member[list(combo)][:, query].any(axis=0).all():
                return size
    raise AssertionError("uncoverable")


@given(st.data())
@settings(max_examples=60, deadline=None)
def test_greedy_within_log_bound_of_optimal(data):
    num_items = data.draw(st.integers(2, 8))
    n_parts = data.draw(st.integers(2, 5))
    member = np.zeros((n_parts, num_items), dtype=bool)
    for v in range(num_items):
        copies = data.draw(
            st.lists(st.integers(0, n_parts - 1), min_size=1, max_size=n_parts,
                     unique=True)
        )
        member[copies, v] = True
    q = np.asarray(
        data.draw(st.lists(st.integers(0, num_items - 1), min_size=1,
                           max_size=num_items, unique=True))
    )
    greedy = len(greedy_set_cover(q, member))
    opt = brute_force_optimal(q, member)
    # greedy is a (ln q + 1)-approximation of min set cover
    assert opt <= greedy <= opt * (np.log(len(q)) + 1)


def test_placement_accounting():
    pl = Placement.empty(3, 5, capacity=3.0)
    pl.add(0, [0, 1])
    pl.add(1, [1, 2, 3])
    pl.add(2, [4])
    assert pl.partition_weight(1) == 3.0
    assert pl.free_space(0) == 1.0
    assert pl.replication_factor() == pytest.approx(6 / 5)
    pl.validate()
    pl.add(1, [4])
    with pytest.raises(ValueError):
        pl.validate()


def test_placement_validate_catches_unplaced():
    pl = Placement.empty(2, 3, capacity=3.0)
    pl.add(0, [0, 1])
    with pytest.raises(ValueError, match="unplaced"):
        pl.validate()
