"""Behavioural tests for the paper's placement algorithms (§4)."""

import numpy as np
import pytest

from repro.core import (
    ALGORITHMS, THREE_WAY_ALGORITHMS, Simulator, ds, hpa_placement, ihpa,
    lmbr, min_partitions, pra, random_placement, random_workload,
    spans_for_workload,
)
from repro.core.hypergraph import Hypergraph


@pytest.fixture(scope="module")
def workload():
    return random_workload(num_items=150, num_queries=300, density=6, seed=4)


@pytest.fixture(scope="module")
def sim():
    return Simulator(num_partitions=10, capacity=25)


def test_min_partitions():
    hg = Hypergraph.from_edges([[0, 1]], num_nodes=101)
    assert min_partitions(hg, 25) == 5


@pytest.mark.parametrize("name", list(ALGORITHMS))
def test_placement_is_valid(name, workload, sim):
    pl = ALGORITHMS[name](workload.hypergraph, 10, 25, seed=0)
    pl.validate()  # capacity + every item placed
    assert pl.member.shape == (10, 150)


@pytest.mark.parametrize("name", ["ihpa", "ds", "pra", "lmbr"])
def test_replication_beats_no_replication(name, workload, sim):
    """Paper fig. 6a: all replication algorithms beat the HPA baseline."""
    base = sim.run(workload.hypergraph, hpa_placement, name="hpa", seed=0)
    r = sim.run(workload.hypergraph, ALGORITHMS[name], name=name, seed=0)
    assert r.avg_span <= base.avg_span + 1e-9, (
        f"{name}: {r.avg_span} vs hpa {base.avg_span}"
    )


def test_lmbr_is_best_or_close(workload, sim):
    """Paper: LMBR produces the best placement in almost all scenarios."""
    results = {
        name: sim.run(workload.hypergraph, fn, name=name, seed=0).avg_span
        for name, fn in ALGORITHMS.items()
    }
    best = min(results.values())
    assert results["lmbr"] <= best * 1.05


def test_hpa_flat_in_partitions(workload):
    """HPA ignores extra partitions (fig. 6a flat line)."""
    hg = workload.hypergraph
    spans = []
    for n in (6, 8, 12):
        pl = hpa_placement(hg, n, 25, seed=0)
        spans.append(spans_for_workload(hg, pl).mean())
    assert spans[0] == pytest.approx(spans[1]) == pytest.approx(spans[2])


def test_more_partitions_help_lmbr(workload):
    """More replication room -> lower span (fig. 6a downward curves)."""
    hg = workload.hypergraph
    s_small = spans_for_workload(hg, lmbr(hg, 7, 25, seed=0)).mean()
    s_large = spans_for_workload(hg, lmbr(hg, 12, 25, seed=0)).mean()
    assert s_large <= s_small + 1e-9


def test_lmbr_never_moves_existing_copies(workload):
    """LMBR only *copies*: the initial assignment survives."""
    hg = workload.hypergraph
    from repro.core import hpa_partition
    assign = hpa_partition(hg, 10, 25, seed=0, nruns=2)
    pl = lmbr(hg, 10, 25, seed=0)
    # every item still present on its original partition
    # (lmbr re-runs HPA internally with the same seed -> same base layout)
    for v in range(hg.num_nodes):
        assert pl.member[:, v].any()


def test_ds_fills_spare_partitions_with_dense_residual():
    edges = [[0, 1, 2]] * 5 + [[3, 4], [5, 6], [7, 8]]
    hg = Hypergraph.from_edges(edges, num_nodes=9)
    pl = ds(hg, 4, 3, seed=0)
    pl.validate()
    spans = spans_for_workload(hg, pl)
    # the hot query {0,1,2} must reach span 1
    assert spans[0] == 1


def test_pra_replicates_high_score_nodes():
    # star: node 0 joins many otherwise-disjoint pairs; replicating 0 wins
    edges = [[0, i] for i in range(1, 9)]
    hg = Hypergraph.from_edges(edges, num_nodes=9)
    pl = pra(hg, 5, 2, seed=0)
    pl.validate()
    assert pl.member[:, 0].sum() >= 2  # hub got replicated


def test_energy_tracks_span(workload):
    sim = Simulator(num_partitions=10, capacity=25)
    r_rand = sim.run(workload.hypergraph, random_placement, name="random", seed=0)
    r_lmbr = sim.run(workload.hypergraph, lmbr, name="lmbr", seed=0)
    assert r_lmbr.avg_span < r_rand.avg_span
    assert r_lmbr.energy_joules < r_rand.energy_joules


def test_lmbr_deterministic_across_runs(workload):
    """part_edges is consumed in ascending-edge-id order (never raw set
    iteration order), so two runs are bit-identical placements."""
    hg = workload.hypergraph
    a = lmbr(hg, 10, 25, seed=0)
    b = lmbr(hg, 10, 25, seed=0)
    np.testing.assert_array_equal(a.member, b.member)


def test_lmbr_state_matches_per_edge_reference(workload):
    """The rewritten _LMBRState (batched engine via SpanMaintainer) keeps
    covers and part_edges bit-identical to the per-edge reference across a
    sequence of membership mutations + batched recomputes, and its
    shared/union accessors pin the ascending-id order contract."""
    from repro.core.algorithms import _LMBRState, _assign_to_placement
    from repro.core.setcover import cover_for_query
    from repro.core import hpa_partition

    hg = workload.hypergraph
    assign = hpa_partition(hg, 10, 25, seed=0, nruns=2)
    pl = _assign_to_placement(hg, assign, 10, 100.0)
    state = _LMBRState(hg, pl)
    rng = np.random.default_rng(2)

    def check():
        part_edges_ref = [set() for _ in range(pl.num_partitions)]
        for e in range(hg.num_edges):
            chosen, accessed = cover_for_query(hg.edge(e), pl.member)
            cov = state.cover(e)
            assert list(cov) == chosen
            for p, its in zip(chosen, accessed):
                np.testing.assert_array_equal(cov[p], its)
            for p in chosen:
                part_edges_ref[p].add(e)
        assert [set(s) for s in state.part_edges] == part_edges_ref
        for src in range(pl.num_partitions):
            for dest in range(pl.num_partitions):
                sh = state.shared_edges(src, dest)
                assert sh == sorted(part_edges_ref[src] & part_edges_ref[dest])
                un = state.union_edges(src, dest)
                np.testing.assert_array_equal(
                    un, sorted(part_edges_ref[src] | part_edges_ref[dest])
                )

    check()
    for _ in range(4):
        items = rng.choice(hg.num_nodes, size=int(rng.integers(1, 6)),
                           replace=False)
        pl.member[int(rng.integers(0, pl.num_partitions)), items] = True
        # recompute every edge touching a mutated item (superset of LMBR's
        # own affected set; exactness must hold for any explicit edge set)
        node_ptr, node_edges = hg.incidence()
        touched = np.unique(np.concatenate(
            [node_edges[node_ptr[v]: node_ptr[v + 1]] for v in items]
        ))
        state.recompute_edges(touched)
        check()


# ------------------------------------------------------------------- 3-way
@pytest.mark.parametrize("name", list(THREE_WAY_ALGORITHMS))
def test_three_way_exact_rf(name):
    wl = random_workload(num_items=100, num_queries=200, density=5, seed=5)
    hg = wl.hypergraph
    n = 3 * min_partitions(hg, 25)
    pl = THREE_WAY_ALGORITHMS[name](hg, n=n, capacity=25, rf=3, seed=0)
    pl.validate()
    copies = pl.member.sum(axis=0)
    assert (copies == 3).mean() > 0.95, f"{name}: rf distribution {np.bincount(copies)}"


def test_pra3_beats_random3():
    wl = random_workload(num_items=100, num_queries=300, density=5, seed=6)
    hg = wl.hypergraph
    n = 3 * min_partitions(hg, 25)
    sim = Simulator(num_partitions=n, capacity=25)
    r_rand = sim.run(hg, THREE_WAY_ALGORITHMS["random3"], name="random3", seed=0)
    r_pra = sim.run(hg, THREE_WAY_ALGORITHMS["pra3"], name="pra3", seed=0)
    assert r_pra.avg_span < r_rand.avg_span
