"""Shared test fixtures: the fault-injection harness for online runs and
the ``scale_workers`` parametrization hook ``make test-migration`` uses to
exercise both the serial and the process-pool sharded-fit paths."""

import numpy as np
import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--scale-workers",
        action="store",
        default="1",
        help="comma-separated worker counts the scale_workers fixture "
        "parametrizes over (make test-migration runs the suite with 1 "
        "— serial sharded fits — and 2 — the process pool)",
    )


def pytest_generate_tests(metafunc):
    if "scale_workers" in metafunc.fixturenames:
        opt = metafunc.config.getoption("--scale-workers")
        metafunc.parametrize(
            "scale_workers", [int(x) for x in str(opt).split(",") if x]
        )


@pytest.fixture
def fault_injected_run():
    """Wrap `Simulator.run_online` in a randomized — but always legal —
    storm of down/up events and assert the serving ledger balances:
    every query is either served or counted degraded, never dropped.

    Returns ``(SimulationResult, events)`` so callers can layer their own
    assertions on top.  ``extra_events`` (e.g. a migrate) are merged in;
    the generated faults never take down more than a third of the cluster
    at once, and every ``down`` targets a live partition / every ``up`` a
    dead one, mirroring what the failover manager accepts.
    """

    def _run(sim, hg, algorithm, *, fault_seed=0, num_events=8,
             extra_events=(), **kw):
        rng = np.random.default_rng(fault_seed)
        n = sim.n
        trace = kw.get("trace")
        nq = (trace if trace is not None else hg).num_edges
        down: set[int] = set()
        events = list(extra_events)
        pos = 0
        for _ in range(int(num_events)):
            pos += int(rng.integers(1, max(2, nq // (num_events + 1))))
            if pos >= nq:
                break
            if down and (len(down) >= max(1, n // 3)
                         or rng.random() < 0.5):
                p = int(rng.choice(sorted(down)))
                down.discard(p)
                events.append((pos, "up", p))
            else:
                live = [p for p in range(n) if p not in down]
                p = int(rng.choice(live))
                down.add(p)
                events.append((pos, "down", p))
        res = sim.run_online(hg, algorithm, events=events, **kw)
        s = res.online_stats
        assert s["served_queries"] + s["degraded_queries"] == nq, (
            f"serving ledger leaked queries: {s['served_queries']} served "
            f"+ {s['degraded_queries']} degraded != {nq} total"
        )
        return res, events

    return _run
