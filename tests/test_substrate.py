"""Substrate integration tests: data pipeline, checkpointing, fault-tolerant
runner, straggler detection, optimizers, gradient compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.data import PlacementAwarePipeline
from repro.optim import adafactor, adamw, clip_by_global_norm, cosine_schedule
from repro.optim.compression import int8_compress, int8_decompress
from repro.runtime import FaultTolerantRunner, StragglerDetector
from repro.runtime.fault_tolerance import StepFailure


# ------------------------------------------------------------------ pipeline
def make_pipeline(**kw):
    defaults = dict(num_shards=64, num_hosts=8, vocab_size=1000,
                    batch_size=4, seq_len=32)
    defaults.update(kw)
    return PlacementAwarePipeline(**defaults)


def test_pipeline_batches_deterministic():
    p1, p2 = make_pipeline(), make_pipeline()
    b1, b2 = p1.next_batch(), p2.next_batch()
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 32)
    # targets are the shifted stream
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["targets"][:, :-1])


def test_pipeline_low_span_and_idle_hosts():
    pipe = make_pipeline()
    for _ in range(50):
        pipe.next_batch()
    assert pipe.avg_span() < 4.0  # placement keeps batches on few hosts
    assert 0.0 <= pipe.idle_host_fraction() < 1.0


def test_pipeline_survives_host_failure():
    pipe = make_pipeline()
    before = pipe.next_batch()
    pipe.mark_dead(before["hosts"][0])
    after = pipe.next_batch()
    assert before["hosts"][0] not in after["hosts"]


def test_pipeline_straggler_recovery():
    pipe = make_pipeline()
    pipe.mark_slow(0)
    b = pipe.next_batch()
    assert 0 not in b["hosts"]
    pipe.mark_recovered(0)  # host may be used again
    spans_with = pipe.avg_span()
    assert spans_with > 0


# ---------------------------------------------------------------- checkpoint
def tiny_state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (8, 16)),
        "nested": {"b": jnp.arange(5, dtype=jnp.float32)},
        "step_scalar": jnp.ones(()),
    }


def test_checkpoint_roundtrip(tmp_path):
    state = tiny_state()
    save_checkpoint(str(tmp_path / "c"), state, step=7, num_shards=3)
    restored, step = load_checkpoint(str(tmp_path / "c"), state)
    assert step == 7
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b), state, restored)


def test_checkpoint_atomic_and_keep_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, num_shards=2,
                            async_save=False)
    for s in (10, 20, 30):
        mgr.save(s, tiny_state(s))
    assert mgr.all_steps() == [20, 30]
    restored, step = mgr.restore_latest(tiny_state())
    assert step == 30


def test_checkpoint_detects_lost_shard(tmp_path):
    state = tiny_state()
    save_checkpoint(str(tmp_path / "c"), state, step=1, num_shards=4)
    os.remove(str(tmp_path / "c" / "shard_00001.npz"))
    with pytest.raises(FileNotFoundError):
        load_checkpoint(str(tmp_path / "c"), state)


def test_ckpt_restore_span_plan(tmp_path):
    mgr = CheckpointManager(str(tmp_path), num_shards=16,
                            num_storage_nodes=4, replication=2,
                            async_save=False)
    restore_sets = [np.arange(i, i + 4) % 16 for i in range(0, 16, 4)]
    mgr.save(1, tiny_state(), restore_sets=restore_sets)
    spans = [mgr.restore_span(rs) for rs in restore_sets]
    assert max(spans) <= 4
    assert mgr.replica_plan.survives_failures(1)


# -------------------------------------------------------------------- runner
def test_runner_restarts_from_checkpoint(tmp_path):
    pipe = make_pipeline()
    mgr = CheckpointManager(str(tmp_path), keep=3, num_shards=2,
                            async_save=False)
    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        if calls["n"] == 12:   # worker dies mid-run, after a checkpoint
            raise StepFailure("simulated accelerator loss")
        return {"w": state["w"] + 1}, {"loss": 0.0}

    runner = FaultTolerantRunner(step_fn, {"w": jnp.zeros(())}, pipe, mgr,
                                 ckpt_every=5)
    result = runner.run(20)
    assert result["steps"] == 20
    assert result["restarts"] == 1
    # state reflects exactly 20 successful optimizer steps after restart
    assert float(runner.state["w"]) == 20.0


def test_runner_straggler_event():
    pipe = make_pipeline()
    det = StragglerDetector(8, min_samples=2, threshold=2.0)
    for _ in range(3):
        for h in range(1, 8):
            det.observe(h, 0.1)
    assert det.observe(0, 1.0) is False  # first sample
    assert det.observe(0, 1.0) is True   # now clearly slow


# ---------------------------------------------------------------- optimizers
def _quadratic_losses(opt, steps=60):
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    losses = []
    for _ in range(steps):
        loss, grads = jax.value_and_grad(
            lambda p: jnp.sum((p["w"] - target) ** 2)
        )(params)
        updates, state = opt.update(grads, state, params)
        params = jax.tree.map(lambda p, u: p + u, params, updates)
        losses.append(float(loss))
    return losses


def test_adamw_converges():
    losses = _quadratic_losses(adamw(0.1, weight_decay=0.0))
    assert losses[-1] < 0.05 * losses[0]


def test_adafactor_converges():
    losses = _quadratic_losses(adafactor(0.3), steps=120)
    assert losses[-1] < 0.1 * losses[0]


def test_adafactor_state_is_factored():
    opt = adafactor(0.1)
    params = {"w": jnp.zeros((64, 128)), "b": jnp.zeros((7,))}
    st = opt.init(params)
    assert st.vr["w"].shape == (64,)
    assert st.vc["w"].shape == (128,)
    assert st.v["b"].shape == (7,)   # non-factored fallback


def test_grad_clip():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup_steps=10, total_steps=100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0)
    assert float(lr(100)) == pytest.approx(0.1, rel=1e-2)
    assert float(lr(55)) < float(lr(20))


# --------------------------------------------------------------- compression
def test_int8_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3
    q, s = int8_compress(x)
    y = int8_decompress(q, s, x.shape, x.size)
    err = np.abs(np.asarray(x - y))
    assert err.max() <= float(s.max()) * 0.51  # half-ULP of the block scale
    # wire bytes ~ 1/4 of fp32
    wire = q.size + s.size * 4
    assert wire < 0.3 * x.size * 4
