"""Tier-1 promotion of `benchmarks/energy_model.py`'s fig. 1/5 claim checks.

The benchmark reproduces the paper's span-vs-latency-vs-energy experiment in
the calibrated affine model and asserts three claims the paper measures:
complex joins get FASTER and cheaper under co-location, simple aggregates
get slower but still cheaper, and every query's energy drops (paper:
31-79%).  Promoting them here keeps the energy model honest against
`EnergyModel` refactors (the per-node `cluster_power` addition must not
perturb the per-query affine path)."""

import os
import sys

import pytest

sys.path.insert(
    0, os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
)

from benchmarks import energy_model  # noqa: E402


@pytest.fixture(scope="module")
def rows():
    return energy_model.run(quick=True)


def test_joins_faster_and_cheaper(rows):
    joins = [r for r in rows if r["kind"] == "join"]
    assert joins
    assert all(r["rt_change_pct"] < 0 for r in joins)
    assert all(r["energy_reduction_pct"] > 0 for r in joins)


def test_aggregates_trade_latency_for_energy(rows):
    aggs = [r for r in rows if r["kind"] == "aggregate"]
    assert aggs
    assert all(r["rt_change_pct"] > 0 for r in aggs)
    assert all(r["energy_reduction_pct"] > 0 for r in aggs)


def test_all_queries_cheaper_in_paper_range(rows):
    assert len(rows) == len(energy_model.QUERIES)
    for r in rows:
        assert 0 < r["energy_reduction_pct"] < 100
