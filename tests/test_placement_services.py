"""Tests for the production placement services (flat, hierarchical, refit,
expert placement, shard placement)."""

import numpy as np
import pytest

from repro.core import (
    PlacementPlan, PlacementService, baseline_contiguous_placement,
    greedy_set_cover, mixture_batch_recipes, plan_expert_placement,
    plan_shard_placement, random_workload, synthetic_routing_trace,
)


@pytest.fixture(scope="module")
def queries():
    return random_workload(num_items=120, num_queries=250, density=6, seed=2).queries


def test_fit_and_select(queries):
    svc = PlacementService("lmbr", seed=0)
    plan = svc.fit(queries, 120, 8, 30)
    parts, accessed = plan.select(queries[0])
    got = sorted(int(v) for items in accessed for v in items)
    assert got == sorted(int(v) for v in queries[0])
    assert plan.span(queries[0]) == len(parts)


def test_json_roundtrip(queries):
    svc = PlacementService("ds", seed=0)
    plan = svc.fit(queries, 120, 8, 30)
    plan2 = PlacementPlan.from_json(plan.to_json())
    assert (plan2.member == plan.member).all()
    assert plan2.capacity == plan.capacity


def test_json_roundtrip_empty_partitions_and_weights():
    """Round-trip must survive partitions holding nothing and heterogeneous
    item weights (both exercised by TPC-H-style layouts)."""
    member = np.zeros((4, 6), dtype=bool)
    member[0, [0, 2]] = True
    member[2, [1, 3, 4, 5]] = True  # partitions 1 and 3 stay empty
    weights = np.array([0.5, 2.0, 1.25, 3.0, 0.25, 1.0])
    plan = PlacementPlan(member, 7.5, weights, "custom")
    plan2 = PlacementPlan.from_json(plan.to_json())
    assert (plan2.member == member).all()
    assert plan2.member.shape == member.shape  # empty rows preserved
    assert plan2.capacity == 7.5
    assert np.array_equal(plan2.node_weights, weights)
    assert plan2.algorithm == "custom"


def test_plan_spans_match_reference_loop(queries):
    """The batched PlacementPlan.span/spans/avg_span equals the per-query
    greedy_set_cover loop it replaced, element-wise."""
    svc = PlacementService("lmbr", seed=0)
    plan = svc.fit(queries, 120, 8, 30)
    ref = np.array([
        len(greedy_set_cover(np.asarray(q, dtype=np.int64), plan.member))
        for q in queries
    ])
    assert np.array_equal(plan.spans(queries), ref)
    assert plan.span(queries[7]) == int(ref[7])
    assert plan.avg_span(queries) == float(ref.mean())
    assert plan.avg_span([]) == 0.0


def test_hierarchical_spans_and_weighted_span(queries):
    """HierarchicalPlan.spans == hierarchical greedy cover (pods first, then
    hosts restricted to the chosen pods); weighted_span is the DCN/ICI mix."""
    svc = PlacementService("lmbr", seed=0)
    hp = svc.fit_hierarchical(queries, 120, num_pods=2, hosts_per_pod=4,
                              host_capacity=30)
    for q in queries[:40]:
        q = np.asarray(q, dtype=np.int64)
        ps, hs = hp.spans(q)
        pods = greedy_set_cover(q, hp.pod_plan.member)
        assert ps == len(pods)
        rows = [p * hp.hosts_per_pod + h for p in pods
                for h in range(hp.hosts_per_pod)]
        assert hs == len(greedy_set_cover(q, hp.host_member[rows]))
        assert hp.weighted_span(q) == 8.0 * (ps - 1) + (hs - 1)
        assert hp.weighted_span(q, pod_weight=2.5) == 2.5 * (ps - 1) + (hs - 1)
        # a query served inside one pod costs no DCN hops
        if ps == 1:
            assert hp.weighted_span(q) == hs - 1


def test_hierarchical_spans(queries):
    svc = PlacementService("lmbr", seed=0)
    hp = svc.fit_hierarchical(queries, 120, num_pods=2, hosts_per_pod=4,
                              host_capacity=30)
    pod_spans, host_spans = zip(*(hp.spans(q) for q in queries[:50]))
    assert max(pod_spans) <= 2
    assert all(h >= p for p, h in zip(pod_spans, host_spans))
    # pod-level co-location: most queries stay inside one pod
    assert np.mean(np.asarray(pod_spans) == 1) > 0.5


def test_refit_improves_drifted_workload():
    wl_old = random_workload(num_items=120, num_queries=200, density=6, seed=2)
    wl_new = random_workload(num_items=120, num_queries=100, density=6, seed=99)
    svc = PlacementService("hpa", seed=0)  # no replication yet -> room to refit
    plan = svc.fit(wl_old.queries, 120, 10, 30)
    before = plan.avg_span(wl_new.queries)
    plan2 = svc.refit(plan, wl_new.queries)
    after = plan2.avg_span(wl_new.queries)
    assert after <= before
    # refit only adds copies, never removes
    assert (plan2.member >= plan.member).all()


def test_expert_placement_reduces_span_and_a2a():
    trace = synthetic_routing_trace(num_experts=64, num_groups=300, top_k=8,
                                    seed=0)
    base = baseline_contiguous_placement(64, 8, slots_per_rank=12)
    plan = plan_expert_placement(trace, 64, 8, slots_per_rank=12,
                                 algorithm="lmbr", seed=0)
    assert plan.avg_span(trace) < base.avg_span(trace)
    assert plan.a2a_bytes(trace, 1024, 2048) < base.a2a_bytes(trace, 1024, 2048)
    # structural invariants for the device tables
    assert plan.member.sum(axis=1).max() <= 12
    assert plan.member.any(axis=0).all()  # every expert placed
    for r in range(8):
        slots = plan.slot_to_expert[r]
        live = slots[slots >= 0]
        assert len(set(live.tolist())) == len(live)  # no dup expert per rank
        for s, e in enumerate(slots):
            if e >= 0:
                assert plan.expert_slot_table[e, r] == s


def test_expert_placement_needs_enough_slots():
    with pytest.raises(ValueError):
        plan_expert_placement([np.array([0, 1])], 64, 4, slots_per_rank=8)


def test_shard_placement_failover():
    recipes = mixture_batch_recipes(100, 150, seed=1)
    plan = plan_shard_placement(recipes, 100, 12, capacity=30, algorithm="pra3")
    assert plan.survives_failures(1)
    assert plan.survives_failures(2)
    hosts, accessed = plan.hosts_for_batch(recipes[0])
    # failure of the primary host still covers the batch
    hosts2, _ = plan.cover_excluding(recipes[0], {hosts[0]})
    assert hosts[0] not in hosts2
    got = sorted(int(v) for it in _ for v in it)
    assert got == sorted(set(int(v) for v in recipes[0]))


def test_shard_placement_beats_random():
    recipes = mixture_batch_recipes(100, 200, seed=3)
    rnd = plan_shard_placement(recipes, 100, 12, capacity=30, algorithm="random3")
    pra = plan_shard_placement(recipes, 100, 12, capacity=30, algorithm="pra3")
    assert pra.avg_span(recipes) < rnd.avg_span(recipes)
