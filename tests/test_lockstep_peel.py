"""Backend equivalence for the lockstep_peel kernel package and the
device-resident LMBR dispatch.

Contract (tentpole of PR 6): every peel backend — the f64 numpy oracle, the
jitted f32 jnp lockstep, the Pallas kernel in interpret mode — emits
BIT-IDENTICAL trajectories on the integer-valued-weight domain the LMBR
dispatcher enforces, and the full fit under ``lmbr_peel="device"|"pallas"``
reproduces the vector engine's placement exactly (same members, same covers,
same float-tie handling).  The flat engine also serves as fallback, so a
device failure can never change results."""

import numpy as np
import pytest

from repro import flags
from repro.core import lmbr, random_workload
from repro.core.workloads import ispd_like_workload
from repro.kernels.lockstep_peel.ops import lockstep_peel
from repro.kernels.lockstep_peel.ref import lockstep_peel_ref

jax = pytest.importorskip("jax")


# ------------------------------------------------------------- unit level
def _rand_instance(rng, G, K, U):
    """Random integer-weight peel batch; padding rules of the dispatcher:
    incidence / weights zero beyond each pair's ``nvalid`` prefix."""
    inc = np.zeros((G, K, U), dtype=np.float64)
    nvalid = rng.integers(1, U + 1, size=G).astype(np.int64)
    for g in range(G):
        u = int(nvalid[g])
        for k in range(K):
            pins = np.unique(rng.integers(0, u, size=int(rng.integers(1, 5))))
            inc[g, k, pins] = 1.0
    we = rng.integers(1, 9, size=(G, K)).astype(np.float64)
    nodew = np.zeros((G, U), dtype=np.float64)
    for g in range(G):
        nodew[g, : nvalid[g]] = rng.integers(1, 5, size=int(nvalid[g]))
    return inc, we, nodew, nvalid


# odd shapes straddle the kernel's (8, 128) tile pad; U=1 and K=1 are the
# degenerate single-slot cells
SHAPES = [(1, 1, 1), (3, 4, 7), (7, 13, 21), (5, 9, 130), (2, 17, 3)]


@pytest.mark.parametrize("shape", SHAPES, ids=lambda s: "x".join(map(str, s)))
@pytest.mark.parametrize("force", ["numpy", "jax", "interpret", "pallas"])
def test_backends_match_oracle(shape, force):
    G, K, U = shape
    rng = np.random.default_rng(G * 1000 + K * 10 + U)
    inc, we, nodew, nvalid = _rand_instance(rng, G, K, U)
    want = lockstep_peel_ref(inc, we, nodew, nvalid)
    got = lockstep_peel(inc, we, nodew, nvalid, force=force)
    for w, g, name in zip(want, got, ("peel", "rtot", "rben")):
        assert g.shape == w.shape, (force, name)
        np.testing.assert_array_equal(g, w, err_msg=f"{force}:{name}")


def test_trajectory_semantics_reference():
    """One hand-checked cell: two edges sharing an item.  Initial degrees
    are (2, 5, 3); peeling item 0 kills edge 0, leaving items 1 and 2 tied
    at degree 3 — the tie goes to the LOWEST slot (item 1), whose peel
    kills edge 1 and ends the pair.  Head-of-round (pool weight, benefit)
    snapshots land in the trajectory rows."""
    inc = np.zeros((1, 2, 3))
    inc[0, 0, [0, 1]] = 1.0   # edge 0 over items {0, 1}, weight 2
    inc[0, 1, [1, 2]] = 1.0   # edge 1 over items {1, 2}, weight 3
    we = np.array([[2.0, 3.0]])
    nodew = np.array([[1.0, 1.0, 1.0]])
    peel, rtot, rben = lockstep_peel_ref(inc, we, nodew, np.array([3]))
    np.testing.assert_array_equal(peel[0], [0, 1, -1])
    np.testing.assert_array_equal(rtot[0], [3.0, 2.0, 0.0])
    np.testing.assert_array_equal(rben[0], [5.0, 3.0, 0.0])


# ------------------------------------------------------------- fit level
def _fit_members(wl, n, cap, max_moves, setups):
    members = {}
    for name, setup in setups.items():
        flags.FLAGS.update(setup)
        try:
            pl = lmbr(wl.hypergraph, n, cap, seed=0, max_moves=max_moves)
        finally:
            flags.reset()
        members[name] = (pl.member.copy(), pl.stats["peel"])
    return members


@pytest.mark.parametrize("tier", ["fig6", "fig9", "lmbr-stress"])
def test_full_fit_backend_bit_identity(tier):
    """Placements (hence covers and every float tie-break) are identical
    across vector / device / pallas peel backends and both cache
    granularities on quick versions of the benchmark tiers."""
    if tier == "fig6":
        wl, n, cap, moves = random_workload(60, 200, seed=3), 6, 22.0, 24
    elif tier == "fig9":
        wl, n, cap, moves = ispd_like_workload(160, 200, seed=1), 6, 40.0, 24
    else:
        wl, n, cap, moves = random_workload(
            90, 260, min_query=3, max_query=9, seed=5), 8, 18.0, 24
    setups = {
        "vector": dict(lmbr_peel="vector"),
        "device": dict(lmbr_peel="device"),
        "partition-epochs": dict(lmbr_peel="vector", lmbr_epochs="partition"),
    }
    if tier == "fig6":  # interpret-mode Pallas is slow; one tier covers it
        setups["pallas"] = dict(lmbr_peel="pallas")
    members = _fit_members(wl, n, cap, moves, setups)
    want, _ = members["vector"]
    for name, (got, _) in members.items():
        np.testing.assert_array_equal(got, want, err_msg=name)


def test_device_peel_falls_back_on_float_weights():
    """Non-integer weights are outside the f32-exact domain: the dispatcher
    must keep the flat engine (stats record the requested backend, results
    stay bit-identical to vector)."""
    rng = np.random.default_rng(9)
    wl = random_workload(50, 150, seed=2)
    hg = wl.hypergraph
    hg.node_weights = rng.uniform(0.5, 2.0, size=hg.num_nodes)
    flags.FLAGS["lmbr_peel"] = "device"
    try:
        dev = lmbr(hg, 5, hg.total_node_weight() / 3, seed=0, max_moves=16)
    finally:
        flags.reset()
    vec = lmbr(hg, 5, hg.total_node_weight() / 3, seed=0, max_moves=16)
    np.testing.assert_array_equal(dev.member, vec.member)


# ------------------------------------------------------------ flag surface
@pytest.mark.parametrize("spec,key,val", [
    ("spanroundnumpy", "span_round_backend", "numpy"),
    ("spanrounddevice", "span_round_backend", "device"),
    ("spanroundauto", "span_round_backend", "auto"),
    ("spanroundth12345", "span_round_threshold", 12345),
    ("peeldevice", "lmbr_peel", "device"),
    ("peelpallas", "lmbr_peel", "pallas"),
    ("lmbrepochitem", "lmbr_epochs", "item"),
    ("lmbrepochpartition", "lmbr_epochs", "partition"),
])
def test_variant_spellings(spec, key, val):
    try:
        flags.set_variant(spec)
        assert flags.FLAGS[key] == val
    finally:
        flags.reset()


@pytest.mark.parametrize("spec", [
    "spanroundcuda", "peelfancy", "lmbrepochquery",
])
def test_variant_rejects_unknown_values(spec):
    with pytest.raises(ValueError):
        flags.set_variant(spec)
    flags.reset()
