"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train step on CPU, asserting shapes and finiteness; plus a
prefill->decode consistency check per family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPE_GRID, get_config, list_configs, reduce_config
from repro.models import (
    decode_step, forward, init_cache, init_params, prefill, train_loss,
)

ARCHS = [
    "seamless-m4t-medium", "internvl2-2b", "glm4-9b", "nemotron-4-15b",
    "h2o-danube-1.8b", "olmo-1b", "deepseek-v3-671b", "qwen3-moe-30b-a3b",
    "mamba2-2.7b", "hymba-1.5b",
]

B, S = 2, 64


def make_batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "targets": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
    }
    if cfg.frontend:
        batch["frontend"] = jax.random.normal(
            ks[2], (B, cfg.frontend_len, cfg.d_model), jnp.float32
        )
    return batch


def test_all_archs_registered():
    assert set(ARCHS) <= set(list_configs())


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = reduce_config(get_config(arch), dtype="float32")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    batch = make_batch(cfg, key)

    logits, _, _, _ = forward(cfg, params, batch["tokens"],
                              frontend_embeds=batch.get("frontend"),
                              chunk=32)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), f"{arch}: non-finite logits"

    def loss_fn(p):
        loss, metrics = train_loss(cfg, p, batch, chunk=32)
        return loss

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss {loss}"
    gnorm = jax.tree_util.tree_reduce(
        lambda a, g: a + float(jnp.sum(jnp.square(g.astype(jnp.float32)))),
        grads, 0.0,
    )
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: bad grad norm {gnorm}"
    # loss should be near log(V) at init (sanity on the head)
    assert abs(float(loss)) < 3 * np.log(cfg.vocab_size) + 5


@pytest.mark.parametrize("arch", ["glm4-9b", "deepseek-v3-671b", "mamba2-2.7b",
                                  "hymba-1.5b", "seamless-m4t-medium",
                                  "h2o-danube-1.8b"])
def test_prefill_then_decode_matches_forward(arch):
    """Teacher-forced decode after prefill must match the full forward pass
    (cache correctness across GQA/MLA/SSM/hybrid/enc-dec)."""
    cfg = reduce_config(get_config(arch), dtype="float32")
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    batch = make_batch(cfg, key)
    tokens = batch["tokens"]

    full_logits, _, _, _ = forward(cfg, params, tokens,
                                   frontend_embeds=batch.get("frontend"),
                                   chunk=32)

    n_prefill = S - 4
    pre_batch = {"tokens": tokens[:, :n_prefill]}
    if cfg.frontend:
        pre_batch["frontend"] = batch["frontend"]
    last, cache = prefill(cfg, params, pre_batch, max_len=S, chunk=32)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(full_logits[:, n_prefill - 1]),
        rtol=2e-3, atol=2e-3,
    )
    for t in range(n_prefill, S):
        pos = jnp.full((B, 1), t, jnp.int32)
        logits, cache = decode_step(cfg, params, cache, tokens[:, t:t+1], pos,
                                    chunk=32)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, t]),
            rtol=2e-3, atol=2e-3,
            err_msg=f"{arch}: decode diverges at t={t}",
        )


def test_swa_ring_cache_decode():
    """Sliding-window ring cache (window_only) must agree with the full cache
    once enough context has been consumed."""
    cfg = reduce_config(get_config("h2o-danube-1.8b"), dtype="float32",
                        sliding_window=16)
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    tokens = jax.random.randint(key, (1, 48), 0, cfg.vocab_size)

    full_logits, _, _, _ = forward(cfg, params, tokens, chunk=16)

    # ring cache sized at the window; feed tokens one by one
    cache = init_cache(cfg, 1, 48, window_only=True)
    for t in range(48):
        pos = jnp.full((1, 1), t, jnp.int32)
        logits, cache = decode_step(cfg, params, cache, tokens[:, t:t+1], pos,
                                    chunk=16)
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(full_logits[:, -1]), rtol=3e-3, atol=3e-3
    )


def test_moe_dispatch_with_placement_plan():
    """The paper's expert placement plugs into the MoE block: replicated
    experts produce the same function value as the identity placement when
    replicas share weights."""
    from repro.core import plan_expert_placement, synthetic_routing_trace
    from repro.models import dispatch_from_plan, identity_dispatch

    cfg = reduce_config(get_config("qwen3-moe-30b-a3b"), dtype="float32")
    key = jax.random.PRNGKey(3)
    trace = synthetic_routing_trace(cfg.moe.num_experts, 100,
                                    top_k=cfg.moe.top_k, seed=0)
    plan = plan_expert_placement(trace, cfg.moe.num_experts, num_ranks=2,
                                 slots_per_rank=6, algorithm="lmbr")
    disp = dispatch_from_plan(plan)
    assert disp.num_slots == 12
    params = init_params(cfg, key, moe_dispatch=disp)
    batch = make_batch(cfg, key)
    logits, _, _, _ = forward(cfg, params, batch["tokens"],
                              moe_dispatch=disp, chunk=32)
    assert np.isfinite(np.asarray(logits)).all()

    # identity-dispatch model with the same per-expert weights must agree
    ident = identity_dispatch(cfg.moe.num_experts)
    params_id = init_params(cfg, key, moe_dispatch=ident)
    logits_id, _, _, _ = forward(cfg, params_id, batch["tokens"],
                                 moe_dispatch=ident, chunk=32)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_id),
                               rtol=5e-3, atol=5e-3)
