"""Kernel validation: Pallas interpret mode vs pure-jnp oracles, swept over
shapes/dtypes (per-kernel allclose against ref.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention as fa_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.decode_attention.kernel import decode_attention as dec_kernel
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.ssd_scan.kernel import ssd_scan as ssd_kernel
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.models.attention import chunked_attention

TOL = dict(rtol=2e-2, atol=2e-2)  # bf16-ish tolerance
TOL32 = dict(rtol=2e-4, atol=2e-4)


def _qkv(key, b, h, kh, s, t, d, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, h, s, d), dtype)
    k = jax.random.normal(ks[1], (b, kh, t, d), dtype)
    v = jax.random.normal(ks[2], (b, kh, t, d), dtype)
    return q, k, v


# ----------------------------------------------------------- flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,kh,s,d,causal,window",
    [
        (1, 2, 2, 128, 32, True, None),
        (2, 4, 2, 256, 64, True, None),     # GQA
        (1, 2, 1, 256, 32, True, 128),      # sliding window
        (1, 2, 2, 128, 32, False, None),    # bidirectional (encoder)
    ],
)
def test_flash_attention_matches_ref(dtype, b, h, kh, s, d, causal, window):
    q, k, v = _qkv(jax.random.PRNGKey(0), b, h, kh, s, s, d, dtype)
    out = fa_kernel(q, k, v, causal=causal, window=window,
                    block_q=64, block_kv=64, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = TOL if dtype == jnp.bfloat16 else TOL32
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol
    )


def test_flash_attention_matches_model_chunked():
    """Kernel vs the model-layer chunked implementation (two independent
    flash formulations must agree)."""
    q, k, v = _qkv(jax.random.PRNGKey(1), 2, 4, 2, 128, 128, 32, jnp.float32)
    out = fa_kernel(q, k, v, causal=True, block_q=64, block_kv=64,
                    interpret=True)
    pos = jnp.broadcast_to(jnp.arange(128, dtype=jnp.int32)[None], (2, 128))
    ref = chunked_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), pos, pos, causal=True, chunk=64,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL32)


# ------------------------------------------------------------- flash decode
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,h,kh,t,d,window,fill",
    [
        (1, 2, 2, 256, 32, None, 256),
        (2, 4, 1, 512, 64, None, 300),      # partially-filled cache
        (1, 2, 2, 256, 32, 128, 256),       # sliding window
    ],
)
def test_decode_attention_matches_ref(dtype, b, h, kh, t, d, window, fill):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    q = jax.random.normal(ks[0], (b, h, d), dtype)
    k = jax.random.normal(ks[1], (b, kh, t, d), dtype)
    v = jax.random.normal(ks[2], (b, kh, t, d), dtype)
    kv_pos = jnp.where(jnp.arange(t)[None] < fill,
                       jnp.arange(t)[None], -1).astype(jnp.int32)
    kv_pos = jnp.broadcast_to(kv_pos, (b, t))
    q_pos = jnp.full((b,), fill - 1, jnp.int32)
    out = dec_kernel(q, k, v, kv_pos, q_pos, window=window, block_kv=128,
                     interpret=True)
    ref = decode_attention_ref(q, k, v, kv_pos, q_pos, window=window)
    tol = TOL if dtype == jnp.bfloat16 else TOL32
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol
    )


# ------------------------------------------------------------------ SSD scan
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,p,n,chunk",
    [(1, 128, 2, 16, 16, 32), (2, 256, 4, 32, 64, 64), (1, 64, 1, 64, 128, 64)],
)
def test_ssd_scan_matches_ref(dtype, b, s, h, p, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    x = jax.random.normal(ks[0], (b, s, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))) * 0.5
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bm = jax.random.normal(ks[3], (b, s, n), jnp.float32) * 0.3
    cm = jax.random.normal(jax.random.fold_in(ks[3], 1), (b, s, n),
                           jnp.float32) * 0.3
    out = ssd_kernel(x, dt.astype(jnp.float32), a, bm, cm, chunk=chunk,
                     interpret=True)
    ref = ssd_scan_ref(x, dt.astype(jnp.float32), a, bm, cm)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol
    )


def test_ssd_kernel_matches_model_chunked():
    """Kernel vs the model-layer ssd_chunked (independent formulations)."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    b, s, h, p, n = 1, 128, 2, 16, 16
    x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bm = jax.random.normal(ks[3], (b, s, n)) * 0.3
    cm = jax.random.normal(jax.random.fold_in(ks[3], 1), (b, s, n)) * 0.3
    out = ssd_kernel(x, dt, a, bm, cm, chunk=32, interpret=True)
    ref, _ = ssd_chunked(x, dt, a, bm, cm, chunk=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
