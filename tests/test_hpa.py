"""Tests for the multilevel hypergraph partitioner (hMETIS stand-in)."""

import numpy as np
import pytest

from _pbt import given, settings, st

from repro.core.hpa import connectivity_cost, partition, ubfactor
from repro.core.hypergraph import Hypergraph
from repro.core.workloads import random_workload


def test_respects_capacity_and_covers_all():
    wl = random_workload(num_items=120, num_queries=200, density=5, seed=0)
    hg = wl.hypergraph
    assign = partition(hg, 6, capacity=25, seed=0)
    assert assign.shape == (120,)
    assert assign.min() >= 0 and assign.max() < 6
    loads = np.bincount(assign, weights=hg.node_weights, minlength=6)
    assert (loads <= 25 + 1e-9).all()


def test_two_cliques_are_separated():
    """Two 6-cliques joined by one edge: a 2-way partition must cut ~1 edge."""
    edges = []
    for a in range(6):
        for b in range(a + 1, 6):
            edges.append([a, b])
            edges.append([a + 6, b + 6])
    edges.append([0, 6])
    hg = Hypergraph.from_edges(edges, num_nodes=12)
    assign = partition(hg, 2, capacity=6, seed=1, nruns=4)
    cost = connectivity_cost(hg, assign, 2)
    assert cost <= 2.0  # the bridge, maybe one more
    # each clique intact
    assert len(set(assign[:6])) == 1
    assert len(set(assign[6:])) == 1


def test_beats_random_assignment():
    wl = random_workload(num_items=200, num_queries=400, density=4, seed=3)
    hg = wl.hypergraph
    assign = partition(hg, 8, capacity=25, seed=0)
    rng = np.random.default_rng(0)
    rand_cost = np.mean([
        connectivity_cost(hg, rng.permutation(np.repeat(np.arange(8), 25)), 8)
        for _ in range(3)
    ])
    assert connectivity_cost(hg, assign, 8) < 0.8 * rand_cost


def test_weighted_nodes():
    w = np.array([5.0, 5.0, 1.0, 1.0, 1.0, 1.0])
    hg = Hypergraph.from_edges([[0, 2], [1, 3], [4, 5]], num_nodes=6,
                               node_weights=w)
    assign = partition(hg, 2, capacity=7.0, seed=0)
    loads = np.bincount(assign, weights=w, minlength=2)
    assert (loads <= 7.0 + 1e-9).all()


def test_infeasible_raises():
    hg = Hypergraph.from_edges([[0, 1]], num_nodes=2)
    with pytest.raises(ValueError):
        partition(hg, 1, capacity=1.0)


def test_k1_trivial():
    hg = Hypergraph.from_edges([[0, 1]], num_nodes=2)
    np.testing.assert_array_equal(partition(hg, 1, capacity=2.0), [0, 0])


def test_ubfactor_formula():
    # paper example semantics: zero slack -> UBfactor 0
    assert ubfactor(50, 20, 1000) == pytest.approx(0.0)
    assert ubfactor(50, 40, 1000) == pytest.approx(100 * 1000 / 40000)


@given(st.integers(2, 5), st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_partition_always_valid(k, seed):
    wl = random_workload(num_items=60, num_queries=80, density=3, seed=seed % 7)
    hg = wl.hypergraph
    cap = np.ceil(60 / k) + 4
    assign = partition(hg, k, capacity=cap, seed=seed)
    loads = np.bincount(assign, weights=hg.node_weights, minlength=k)
    assert (loads <= cap + 1e-9).all()
    assert len(assign) == 60
